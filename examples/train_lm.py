"""End-to-end training driver: ~100M-parameter LM, a few hundred steps,
with checkpointing/restart, watchdog, and continuous ALEA profiling.

Defaults are sized for a real (TPU) run; ``--smoke`` shrinks everything
for a CPU sanity pass. Kill the process mid-run and rerun: it resumes
from the latest atomic checkpoint.

    PYTHONPATH=src python examples/train_lm.py --smoke
    PYTHONPATH=src python examples/train_lm.py --steps 300
"""

import argparse

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import AttributionReport, EnergyProfiler
from repro.data.pipeline import SyntheticTokens
from repro.optim.adamw import AdamWConfig
from repro.train.step import init_state, make_train_step
from repro.train.trainer import Trainer, TrainerConfig

# ~100M params: 12L × d768 × ff3072, 32k vocab.
LM_100M = ModelConfig(
    name="lm-100m", family="dense", n_layers=12, d_model=768, n_heads=12,
    n_kv_heads=12, d_head=64, d_ff=3072, vocab_size=32000, remat="dots")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=512)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args()

    cfg = LM_100M
    if args.smoke:
        cfg = cfg.reduced()
        args.steps, args.batch, args.seq = 20, 4, 128

    opt_cfg = AdamWConfig(lr=3e-4, warmup_steps=20, total_steps=args.steps)
    state = init_state(jax.random.PRNGKey(0), cfg, opt_cfg)
    n = sum(x.size for x in jax.tree.leaves(state["params"]))
    print(f"model: {cfg.name}  params: {n/1e6:.1f}M")

    step = jax.jit(make_train_step(cfg, opt_cfg), donate_argnums=(0,))
    data = SyntheticTokens(vocab_size=cfg.vocab_size, seq_len=args.seq,
                           global_batch=args.batch)
    tcfg = TrainerConfig(total_steps=args.steps, ckpt_dir=args.ckpt_dir,
                         ckpt_every=max(args.steps // 4, 10), log_every=10)
    trainer = Trainer(tcfg, step, state, data,
                      put_batch=lambda b: {k: jnp.asarray(v)
                                           for k, v in b.items()})
    if trainer.try_resume():
        print(f"resumed from checkpoint at step {trainer.step}")

    prof = EnergyProfiler(period=5e-3)
    with prof.host_session() as sess:
        result = trainer.run()
    est = sess.estimates()

    for m in result["metrics"]:
        print(f"step {m['step']:5d} loss {m['loss']:.4f} "
              f"lr {m['lr']:.2e} {m['step_time_s']*1e3:.0f}ms")
    print(f"\nstragglers: {result['straggler_events']}")
    print("\nALEA energy attribution (host run):")
    print(AttributionReport(est).table(top=8))


if __name__ == "__main__":
    main()
