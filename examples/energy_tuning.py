"""§7 use case through the public API: profile → find hotspots →
search per-region knobs (DVFS × chips × impl) → report the plan.

    PYTHONPATH=src python examples/energy_tuning.py --arch yi-6b
"""

import argparse

from repro.configs.base import SHAPES
from repro.configs.registry import ARCH_IDS, get_config
from repro.core import (EnergyProfiler, ImplVariant, KnobSpace,
                        baseline_plan, optimize_regions, synthesize)
from repro.roofline.cost_model import step_region_costs


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b", choices=ARCH_IDS)
    ap.add_argument("--shape", default="train_4k", choices=list(SHAPES))
    ap.add_argument("--chips", type=int, default=8)
    ap.add_argument("--objective", default="energy",
                    choices=["energy", "ed", "ed2"])
    args = ap.parse_args()

    cfg = get_config(args.arch)
    costs = step_region_costs(cfg, SHAPES[args.shape], chips=args.chips)

    # 1. One-pass ALEA profile of the synthesized device timeline.
    tl = synthesize(costs, steps=150, chips=args.chips, seed=0)
    prof = EnergyProfiler(period=10e-3)
    est = prof.profile_timeline(tl, sensor="rapl")
    print(prof.report(est).table(top=8))

    # 2. Knob search over the dominant regions.
    top = {r.name for r in est.dominant(6)}
    top_costs = [c for c in costs if c.name in top]
    impl_space = {
        "attn_score": [ImplVariant("default"),
                       ImplVariant("flash", flop_mult=0.55, byte_mult=0.1)],
        "ssm_scan": [ImplVariant("default"),
                     ImplVariant("fused_chunk", byte_mult=0.5)],
    }
    space = KnobSpace(freq_scales=(1.0, 0.94, 0.88, 0.81),
                      chip_counts=(1, 2, 4, args.chips))
    base = baseline_plan(top_costs, chips=args.chips)
    plan = optimize_regions(top_costs, space, objective=args.objective,
                            impl_space=impl_space,
                            baseline_chips=args.chips, max_slowdown=2.0)
    print("\nbaseline (max perf):")
    print(base.table())
    print(f"\n{args.objective}-optimal per-region plan:")
    print(plan.table())
    print(f"\nwhole-hotspot energy saving: "
          f"{(1 - plan.energy / base.energy) * 100:.0f}%  "
          f"time: {(plan.time / base.time - 1) * 100:+.0f}%")


if __name__ == "__main__":
    main()
