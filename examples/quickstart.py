"""Quickstart: fine-grain energy profiling of a real training loop.

Runs a small LM training loop on CPU with ALEA's host-mode profiler (a
real control thread sampling a region marker + the best available power
sensor — the §4.8 architecture) and prints the per-region energy
attribution table with confidence intervals.

    PYTHONPATH=src python examples/quickstart.py [--steps 30]
"""

import argparse

import jax
import jax.numpy as jnp

from repro.configs.registry import get_config
from repro.core import AttributionReport, EnergyProfiler
from repro.core import regions as regions_mod
from repro.data.pipeline import SyntheticTokens
from repro.models import model as M
from repro.optim.adamw import AdamWConfig
from repro.train.step import init_state, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=30)
    args = ap.parse_args()

    cfg = get_config("qwen3-1.7b").reduced()
    opt_cfg = AdamWConfig(total_steps=args.steps)
    state = init_state(jax.random.PRNGKey(0), cfg, opt_cfg)
    step = jax.jit(make_train_step(cfg, opt_cfg))
    data = SyntheticTokens(vocab_size=cfg.vocab_size, seq_len=128,
                           global_batch=8)

    prof = EnergyProfiler(period=2e-3, jitter=3e-4)
    with prof.host_session() as sess:
        for i in range(args.steps):
            with regions_mod.region("data_load"):
                batch = {k: jnp.asarray(v) for k, v in data.batch(i).items()}
            with regions_mod.region("train_step"):
                state, metrics = step(state, batch)
                jax.block_until_ready(metrics["loss"])
    est = sess.estimates()
    print(f"\nfinal loss: {float(metrics['loss']):.4f}")
    print(f"samples: {est.n_total}  wall: {est.t_exec:.2f}s\n")
    print(AttributionReport(est).table())
    hot = est.dominant(1)[0]
    print(f"\nhotspot: {hot.name} — {hot.p_hat*100:.0f}% of time, "
          f"{hot.e_hat:.1f} J estimated")


if __name__ == "__main__":
    main()
