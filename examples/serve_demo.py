"""Serving demo: continuous-batching engine + per-phase energy profiling.

Serves a small causal LM with slot-based continuous batching and profiles
prefill vs decode energy with the host-mode ALEA profiler.

    PYTHONPATH=src python examples/serve_demo.py
"""

import argparse

import jax
import numpy as np

from repro.configs.registry import get_config
from repro.core import AttributionReport, EnergyProfiler
from repro.core import regions as regions_mod
from repro.models import model as M
from repro.serve.engine import Engine, Request, ServeConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--new-tokens", type=int, default=16)
    args = ap.parse_args()

    cfg = get_config("qwen3-1.7b").reduced()
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    engine = Engine(cfg, params, ServeConfig(max_batch=4, max_len=128,
                                             eos_token=-1))

    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=rng.integers(1, cfg.vocab_size,
                                        rng.integers(4, 12)).astype(np.int32),
                    max_new_tokens=args.new_tokens)
            for i in range(args.requests)]

    prof = EnergyProfiler(period=2e-3)
    with prof.host_session() as sess:
        with regions_mod.region("serve"):
            done = engine.run_until_drained(reqs)
    est = sess.estimates()

    for r in done:
        print(f"req {r.rid}: prompt {len(r.prompt)} toks → "
              f"{len(r.out_tokens)} generated")
    print(f"\ncompleted {len(done)}/{len(reqs)} requests")
    print("\nALEA per-phase attribution:")
    print(AttributionReport(est).table(top=8))


if __name__ == "__main__":
    main()
