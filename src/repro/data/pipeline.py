"""Deterministic, shard-aware token data pipeline.

Two sources:
  * ``SyntheticTokens`` — seeded on (seed, step, shard) so every host
    derives its own disjoint slice without coordination; fully
    reproducible across restarts and elastic re-sharding (the stream is a
    pure function of the global step).
  * ``MemmapTokens`` — flat binary token file (np.memmap) with the same
    (step → global batch window) indexing; hosts read disjoint slices.

Both yield {tokens, labels} with labels = next-token shift. Batches are
*global* logical arrays under pjit; per-host sharding comes from the mesh.
A background prefetch thread keeps ``prefetch`` batches ready (overlapping
host data work with device compute).
"""

from __future__ import annotations

import queue
import threading
from typing import Iterator

import numpy as np

__all__ = ["SyntheticTokens", "MemmapTokens", "Prefetcher"]


class SyntheticTokens:
    """Zipf-ish synthetic LM tokens; deterministic in (seed, step)."""

    def __init__(self, *, vocab_size: int, seq_len: int, global_batch: int,
                 seed: int = 0):
        self.vocab_size = vocab_size
        self.seq_len = seq_len
        self.global_batch = global_batch
        self.seed = seed

    def batch(self, step: int) -> dict[str, np.ndarray]:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step]))
        # Zipf-like marginal over the vocab (realistic token frequencies).
        u = rng.random((self.global_batch, self.seq_len + 1))
        toks = np.minimum(
            (self.vocab_size * (u ** 2.2)).astype(np.int32),
            self.vocab_size - 1)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def __iter__(self) -> Iterator[dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1


class MemmapTokens:
    """Flat uint16/uint32 token file → (step → window) batches."""

    def __init__(self, path: str, *, seq_len: int, global_batch: int,
                 dtype=np.uint16):
        self.data = np.memmap(path, dtype=dtype, mode="r")
        self.seq_len = seq_len
        self.global_batch = global_batch
        tokens_per_batch = global_batch * (seq_len + 1)
        self.n_batches = len(self.data) // tokens_per_batch
        if self.n_batches == 0:
            raise ValueError("token file smaller than one global batch")

    def batch(self, step: int) -> dict[str, np.ndarray]:
        per = self.global_batch * (self.seq_len + 1)
        off = (step % self.n_batches) * per
        window = np.asarray(self.data[off:off + per]).astype(np.int32)
        window = window.reshape(self.global_batch, self.seq_len + 1)
        return {"tokens": window[:, :-1], "labels": window[:, 1:]}

    def __iter__(self):
        step = 0
        while True:
            yield self.batch(step)
            step += 1


class Prefetcher:
    """Background-thread prefetch of ``depth`` upcoming batches."""

    def __init__(self, source, *, depth: int = 2, start_step: int = 0):
        self.source = source
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._step = start_step
        self._thread = threading.Thread(target=self._fill, daemon=True)
        self._thread.start()

    def _fill(self):
        step = self._step
        while not self._stop.is_set():
            try:
                self._q.put(self.source.batch(step), timeout=0.2)
                step += 1
            except queue.Full:
                continue

    def get(self) -> dict[str, np.ndarray]:
        return self._q.get()

    def close(self):
        self._stop.set()
        self._thread.join(timeout=2.0)
