"""Serving launcher: continuous batching + per-phase energy attribution.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b --smoke \\
        --requests 8 --new-tokens 16
"""

import argparse

import jax
import numpy as np

from repro.configs.registry import ARCH_IDS, get_config
from repro.core import AttributionReport, EnergyProfiler
from repro.models import model as M
from repro.serve.engine import Engine, Request, ServeConfig


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, required=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.reduced()
    if cfg.is_encoder:
        raise SystemExit(f"{args.arch} is encoder-only: no decode serving")

    params = M.init_params(jax.random.PRNGKey(0), cfg)
    engine = Engine(cfg, params,
                    ServeConfig(max_batch=args.max_batch,
                                max_len=args.max_len, eos_token=-1))
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=rng.integers(1, cfg.vocab_size,
                                        int(rng.integers(4, 16)))
                    .astype(np.int32),
                    max_new_tokens=args.new_tokens)
            for i in range(args.requests)]

    prof = EnergyProfiler(period=2e-3)
    with prof.host_session() as sess:
        done = engine.run_until_drained(reqs)
    print(f"served {len(done)}/{len(reqs)} requests "
          f"({sum(len(r.out_tokens) for r in done)} tokens)")
    print(AttributionReport(sess.estimates()).table(top=8))


if __name__ == "__main__":
    main()
