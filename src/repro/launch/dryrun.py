import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape) on the
production meshes, print memory/cost analysis, and emit roofline rows.

MUST be run as its own process (the two lines above must execute before
any jax import anywhere):

    PYTHONPATH=src python -m repro.launch.dryrun --arch yi-6b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod --json out.json
"""

import argparse
import json
import sys
import traceback

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import SHAPES, ModelConfig, ShapeConfig
from repro.configs.registry import ARCH_IDS, get_config, shape_applicable
from repro.launch.mesh import dp_axes_for, make_production_mesh
from repro.models import model as M
from repro.optim.adamw import AdamWConfig
from repro.roofline.analysis import roofline_terms
from repro.sharding import params as sp
from repro.sharding.rules import axis_rules, make_rules
from repro.train.step import init_state, make_train_step

N_PATCH = 256   # vlm stub frontend patch count


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """ShapeDtypeStruct stand-ins for every model input (no allocation)."""
    B, S = shape.global_batch, shape.seq_len
    f32 = jnp.float32
    i32 = jnp.int32
    if shape.kind in ("train", "prefill"):
        if cfg.embed_inputs:           # audio: precomputed frame embeddings
            specs = {"embeds": jax.ShapeDtypeStruct((B, S, cfg.d_model),
                                                    jnp.bfloat16)}
            if shape.kind == "train":
                specs["labels"] = jax.ShapeDtypeStruct((B, S), i32)
            return specs
        if cfg.family == "vlm":
            specs = {
                "patch_embeds": jax.ShapeDtypeStruct(
                    (B, N_PATCH, cfg.d_model), jnp.bfloat16),
                "tokens": jax.ShapeDtypeStruct((B, S - N_PATCH), i32),
            }
            if shape.kind == "train":
                specs["labels"] = jax.ShapeDtypeStruct((B, S - N_PATCH), i32)
            return specs
        specs = {"tokens": jax.ShapeDtypeStruct((B, S), i32)}
        if shape.kind == "train":
            specs["labels"] = jax.ShapeDtypeStruct((B, S), i32)
        return specs
    # decode: one new token against a seq_len cache
    return {"tokens": jax.ShapeDtypeStruct((B, 1), i32)}


def build_rules(cfg: ModelConfig, shape: ShapeConfig, mesh):
    dp = dp_axes_for(mesh)
    rules = make_rules(mesh, dp_axes=dp)
    rules = rules.resolve_divisibility({
        "batch": shape.global_batch,
        "heads": cfg.n_heads,
        "kv_heads": cfg.n_kv_heads,
        "vocab": cfg.vocab_size,
    })
    if (shape.is_decode and rules.mapping.get("kv_heads") is None
            and shape.seq_len % mesh.shape["model"] == 0):
        # GQA groups can't fill the TP axis → shard the cache sequence
        # instead (flash-decoding split-K combine under GSPMD).
        rules.mapping["kv_seq"] = "model"
    if (shape.kind in ("train", "prefill")
            and cfg.n_heads % mesh.shape["model"] != 0
            and shape.seq_len % mesh.shape["model"] == 0):
        # Heads indivisible by the TP width → attention would replicate
        # and its fp32 scores blow the memory budget (internvl2: 14 heads
        # on TP-16 → 25.9 GB/dev). Shard attention activations over the
        # *sequence* instead (context-parallel scores).
        rules.mapping["seq"] = "model"
    if (shape.kind in ("train", "prefill")
            and not cfg.disable_sp
            and shape.seq_len % mesh.shape["model"] == 0):
        # Megatron sequence parallelism: the residual stream between blocks
        # is sharded over the TP axis (all-gather at qkv/up-proj, reduce-
        # scatter after wo/down-proj) — 16x less activation memory.
        rules.mapping["seq_act"] = "model"
    return rules


def _build_fn(cfg: ModelConfig, shape: ShapeConfig, rules, mesh,
              attn_impl: str, donate: bool, *, unroll: bool, fsdp: bool):
    """jit-wrapped step fn + abstract args for one cell (no allocation).

    Under ``unroll`` (the COST compile, never executed) all inner chunk
    scans are widened to the full sequence: XLA counts while bodies once,
    so any surviving inner scan would undercount FLOPs/collectives by its
    trip count. The scanned (memory) compile keeps production chunk sizes.
    """
    key = jax.random.PRNGKey(0)
    batch = input_specs(cfg, shape)
    batch_sh = sp.to_shardings(sp.batch_specs(batch, rules), rules)
    S = shape.seq_len
    # Cost-compile chunk sizes: as large as XLA buffer limits allow (the
    # remaining Python-level chunk loops are unrolled via unroll_chunks).
    q_chunk = min(S, 8192) if unroll else 1024
    ssd_chunk = min(S, 2048) if unroll else 128
    ce_chunk = S if unroll else 512

    if shape.kind == "train":
        opt_cfg = AdamWConfig()
        state_shape = jax.eval_shape(
            lambda k: init_state(k, cfg, opt_cfg), key)
        specs = sp.param_specs(state_shape, rules, fsdp=fsdp)
        state_sh = sp.to_shardings(specs, rules)
        step = make_train_step(cfg, opt_cfg, attn_impl=attn_impl,
                               unroll=unroll, q_chunk=q_chunk,
                               ce_chunk=ce_chunk, ssd_chunk=ssd_chunk)
        fn = jax.jit(step,
                     in_shardings=(state_sh, batch_sh),
                     out_shardings=(state_sh, None),
                     donate_argnums=(0,) if donate else ())
        return fn, (state_shape, batch)

    params_shape = jax.eval_shape(lambda k: M.init_params(k, cfg), key)
    params_sh = sp.to_shardings(sp.param_specs(params_shape, rules), rules)

    if shape.kind == "prefill":
        if cfg.is_encoder:
            def fn_(p, b):
                return M.forward(p, cfg, b, attn_impl=attn_impl,
                                 unroll=unroll, q_chunk=q_chunk,
                                 ssd_chunk=ssd_chunk)[0]
        else:
            def fn_(p, b):
                return M.prefill(p, cfg, b, shape.seq_len,
                                 attn_impl=attn_impl, unroll=unroll,
                                 q_chunk=q_chunk, ssd_chunk=ssd_chunk)
        fn = jax.jit(fn_, in_shardings=(params_sh, batch_sh),
                     out_shardings=None)
        return fn, (params_shape, batch)

    # decode
    cache_dt = getattr(jnp, cfg.kv_cache_dtype)
    cache_shape = jax.eval_shape(
        lambda: M.init_cache(cfg, shape.global_batch, shape.seq_len,
                             dtype=cache_dt))
    cache_sh = sp.to_shardings(sp.cache_specs(cache_shape, rules), rules)

    def fn_(p, t, c, l):
        return M.decode_step(p, cfg, t, c, l, unroll=unroll)
    fn = jax.jit(fn_,
                 in_shardings=(params_sh, batch_sh["tokens"],
                               cache_sh, None),
                 out_shardings=(None, cache_sh),
                 donate_argnums=(2,) if donate else ())
    return fn, (params_shape, batch["tokens"], cache_shape,
                jax.ShapeDtypeStruct((), jnp.int32))


def lower_cell(arch: str, shape_name: str, *, multi_pod: bool,
               attn_impl: str = "chunked", donate: bool = True,
               mesh=None, cfg_override=None, unroll: bool = True,
               fsdp: bool = True):
    """Lower + compile one cell. Returns (report_dict, compiled)."""
    cfg = cfg_override or get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "skipped": why}, None

    if mesh is None:
        mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "x".join(str(s) for s in mesh.devices.shape)
    chips = int(np.prod(mesh.devices.shape))
    rules = build_rules(cfg, shape, mesh)
    training = shape.kind == "train"

    with axis_rules(rules):
        fn, args = _build_fn(cfg, shape, rules, mesh, attn_impl, donate,
                             unroll=unroll, fsdp=fsdp)
        with mesh:
            lowered = fn.lower(*args)
            compiled = lowered.compile()
            if unroll:
                # Second, scanned compile for the memory proof: XLA:CPU's
                # buffer liveness over an UNROLLED layer stack pessimizes
                # (every layer's buffers stay live → ~L× overcount), while
                # its cost analysis counts a while-loop body only ONCE
                # (~L× undercount of FLOPs/collectives). So: costs from
                # the unrolled module, memory from the scanned one.
                mem_fn, mem_args = _build_fn(cfg, shape, rules, mesh,
                                             attn_impl, donate,
                                             unroll=False, fsdp=fsdp)
                mem_compiled = mem_fn.lower(*mem_args).compile()
            else:
                mem_compiled = compiled

    mem = mem_compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):   # jax 0.4.x: one dict per program
        cost = cost[0] if cost else {}
    hlo_text = compiled.as_text()

    n_tokens = shape.global_batch * (shape.seq_len if not shape.is_decode
                                     else 1)
    bytes_per_device = getattr(mem, "temp_size_in_bytes", 0) + \
        getattr(mem, "argument_size_in_bytes", 0)
    report = roofline_terms(
        arch=arch, shape=shape_name, mesh_name=mesh_name, chips=chips,
        cost_analysis=cost or {}, hlo_text=hlo_text,
        n_params_active=cfg.active_param_count(), n_tokens=n_tokens,
        training=training, bytes_per_device=int(bytes_per_device))
    row = report.row()
    row["flops_per_device"] = float((cost or {}).get("flops", 0.0))
    row["hbm_bytes_per_device"] = float((cost or {}).get("bytes accessed", 0.0))
    row["coll_bytes_per_device"] = int(report.collective_bytes)
    row["mem_analysis"] = str(mem)
    row["warnings"] = rules.warnings
    row["collectives"] = report.collectives
    row["collective_counts"] = report.collective_counts
    return row, compiled


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--attn-impl", default="chunked")
    ap.add_argument("--no-unroll", action="store_true",
                    help="keep lax.scan over layers (faster compile, "
                         "undercounted roofline)")
    ap.add_argument("--no-fsdp", action="store_true")
    ap.add_argument("--json", default=None, help="write row(s) as JSON")
    args = ap.parse_args(argv)

    cells = ([(args.arch, args.shape)] if not args.all else
             [(a, s) for a in ARCH_IDS for s in SHAPES])
    rows = []
    failures = 0
    for arch, shape in cells:
        try:
            row, _ = lower_cell(arch, shape, multi_pod=args.multi_pod,
                                attn_impl=args.attn_impl,
                                unroll=not args.no_unroll,
                                fsdp=not args.no_fsdp)
            rows.append(row)
            if "skipped" in row:
                print(f"[SKIP] {arch} × {shape}: {row['skipped']}")
            else:
                print(f"[OK]   {arch} × {shape} mesh={row['mesh']} "
                      f"dominant={row['dominant']} "
                      f"frac={row['roofline_fraction']:.3f}")
                print(f"       compute {row['t_compute_s']*1e3:.2f}ms "
                      f"memory {row['t_memory_s']*1e3:.2f}ms "
                      f"collective {row['t_collective_s']*1e3:.2f}ms")
                print("       " + row["mem_analysis"])
        except Exception as e:
            failures += 1
            rows.append({"arch": arch, "shape": shape,
                         "error": f"{type(e).__name__}: {e}"})
            print(f"[FAIL] {arch} × {shape}: {type(e).__name__}: {e}")
            traceback.print_exc()
    if args.json:
        with open(args.json, "w") as f:
            json.dump(rows, f, indent=1, default=str)
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
