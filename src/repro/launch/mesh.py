"""Production mesh construction (assignment MULTI-POD DRY-RUN §1).

``make_production_mesh`` is a FUNCTION (never a module-level constant) so
importing this module never touches jax device state. The dry-run launcher
sets XLA_FLAGS=--xla_force_host_platform_device_count=512 before any jax
import; ordinary smoke tests and benches see 1 device.

``jax.sharding.AxisType`` (and the matching ``axis_types=`` kwarg of
``jax.make_mesh``) only exists from jax 0.5.x; on 0.4.x meshes are
implicitly Auto-typed. :func:`axis_types_kwargs` returns the kwarg dict
when supported and ``{}`` otherwise, and :func:`make_mesh_compat` is the
version-portable constructor every caller (launchers, tests) should use.
"""

from __future__ import annotations

from typing import Sequence

import jax

__all__ = ["axis_types_kwargs", "make_mesh_compat", "make_production_mesh",
           "make_small_mesh", "make_exchange_mesh", "dp_axes_for"]


def axis_types_kwargs(n: int) -> dict:
    """``axis_types=`` kwarg for ``jax.make_mesh``, empty pre-jax-0.5.

    jax 0.4.x raises AttributeError for ``jax.sharding.AxisType`` (its
    deprecation shim) and ``jax.make_mesh`` has no ``axis_types`` kwarg;
    an Auto-typed mesh is the implicit (and only) behavior there, so
    omitting the kwarg is semantically identical.
    """
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n}


def make_mesh_compat(shape: Sequence[int], axes: Sequence[str]):
    """``jax.make_mesh`` with Auto axis types on every jax version."""
    return jax.make_mesh(tuple(shape), tuple(axes),
                         **axis_types_kwargs(len(axes)))


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh_compat(shape, axes)


def make_small_mesh(data: int = 1, model: int = 1):
    """Tiny mesh for CPU tests (device count permitting)."""
    return make_mesh_compat((data, model), ("data", "model"))


def make_exchange_mesh(n_hosts: int | None = None, axis: str = "hosts"):
    """1-D mesh for the shard-exchange collectives (core/exchange.py).

    One position per participating host (in CI: per fake host device).
    Defaults to all visible devices.
    """
    if n_hosts is None:
        n_hosts = jax.device_count()
    return make_mesh_compat((n_hosts,), (axis,))


def dp_axes_for(mesh) -> tuple[str, ...]:
    """The data-parallel axes present in a mesh (pod spans pods)."""
    return tuple(a for a in ("pod", "data") if a in mesh.shape)
