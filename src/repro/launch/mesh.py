"""Production mesh construction (assignment MULTI-POD DRY-RUN §1).

``make_production_mesh`` is a FUNCTION (never a module-level constant) so
importing this module never touches jax device state. The dry-run launcher
sets XLA_FLAGS=--xla_force_host_platform_device_count=512 before any jax
import; ordinary smoke tests and benches see 1 device.
"""

from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_small_mesh", "dp_axes_for"]


def _auto(n):
    return (jax.sharding.AxisType.Auto,) * n


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, axis_types=_auto(len(axes)))


def make_small_mesh(data: int = 1, model: int = 1):
    """Tiny mesh for CPU tests (device count permitting)."""
    return jax.make_mesh((data, model), ("data", "model"), axis_types=_auto(2))


def dp_axes_for(mesh) -> tuple[str, ...]:
    """The data-parallel axes present in a mesh (pod spans pods)."""
    return tuple(a for a in ("pod", "data") if a in mesh.shape)
