"""Distributed training launcher: ``--arch <id>`` selectable configs.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-1.7b --smoke
    PYTHONPATH=src python -m repro.launch.train --arch yi-6b \\
        --seq 4096 --batch 256 --steps 1000 --mesh 16x16

On a real TPU pod this runs under ``jax.distributed`` (one process per
host); on CPU it runs the same code single-process. ``--smoke`` shrinks
the config for a laptop-scale sanity pass. ALEA host-mode profiling is on
by default (the paper's capped-overhead continuous-profiling deployment).
"""

import argparse

import jax
import jax.numpy as jnp

from repro.configs.base import ShapeConfig
from repro.configs.registry import ARCH_IDS, get_config
from repro.core import AttributionReport, EnergyProfiler
from repro.data.pipeline import SyntheticTokens
from repro.launch.mesh import make_mesh_compat
from repro.optim.adamw import AdamWConfig
from repro.sharding import params as sp
from repro.sharding.rules import axis_rules, make_rules
from repro.train.step import init_state, make_train_step
from repro.train.trainer import Trainer, TrainerConfig


def parse_mesh(spec: str | None):
    if not spec:
        return None
    dims = tuple(int(x) for x in spec.split("x"))
    axes = ("data", "model") if len(dims) == 2 else ("pod", "data", "model")
    return make_mesh_compat(dims, axes)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=512)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--mesh", default=None, help="e.g. 16x16 or 2x16x16")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--no-profile", action="store_true")
    ap.add_argument("--compression", action="store_true",
                    help="int8 gradient compression with error feedback")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.reduced()
        args.steps = min(args.steps, 20)
        args.batch, args.seq = 4, 128
    if cfg.embed_inputs:
        raise SystemExit(f"{args.arch} is encoder-only with a stub frontend;"
                         " use the masked-prediction example instead")

    mesh = parse_mesh(args.mesh)
    rules = make_rules(mesh) if mesh else None
    opt_cfg = AdamWConfig(lr=args.lr, total_steps=args.steps,
                          warmup_steps=max(args.steps // 20, 5))

    def build():
        state = init_state(jax.random.PRNGKey(0), cfg, opt_cfg,
                           compression=args.compression)
        step = make_train_step(cfg, opt_cfg, compression=args.compression)
        if mesh is None:
            return state, jax.jit(step, donate_argnums=(0,))
        st_sh = sp.to_shardings(sp.param_specs(state, rules, fsdp=True),
                                rules)
        return state, jax.jit(step, in_shardings=(st_sh, None),
                              out_shardings=(st_sh, None),
                              donate_argnums=(0,))

    if rules is not None:
        ctx = axis_rules(rules)
        ctx.__enter__()
    state, step = build()
    n = sum(x.size for x in jax.tree.leaves(state["params"]))
    print(f"arch={cfg.name} params={n/1e6:.1f}M steps={args.steps}")

    data = SyntheticTokens(vocab_size=cfg.vocab_size, seq_len=args.seq,
                           global_batch=args.batch)
    trainer = Trainer(
        TrainerConfig(total_steps=args.steps, ckpt_dir=args.ckpt_dir,
                      ckpt_every=max(args.steps // 4, 10), log_every=10),
        step, state, data,
        put_batch=lambda b: {k: jnp.asarray(v) for k, v in b.items()})
    if trainer.try_resume():
        print(f"resumed at step {trainer.step}")

    if args.no_profile:
        result = trainer.run()
    else:
        prof = EnergyProfiler(period=5e-3)
        with prof.host_session() as sess:
            result = trainer.run()
        print(AttributionReport(sess.estimates()).table(top=10))

    for m in result["metrics"][-5:]:
        print(f"step {m['step']:6d} loss {m['loss']:.4f} "
              f"({m['step_time_s']*1e3:.0f} ms)")
    print(f"stragglers: {result['straggler_events']}")


if __name__ == "__main__":
    main()
