"""Fault-tolerant checkpointing: atomic, content-addressed, elastic.

Layout per step::

    <dir>/step_000123.tmp-<nonce>/   (written, fsynced)
        manifest.json                (pytree structure, shapes, dtypes, crc)
        arr_00000.npy ...            (one file per leaf, np.save format)
    <dir>/step_000123/               (atomic rename on completion)
    <dir>/LATEST                     (text file, updated last)

Restore is *elastic*: leaves are saved as full logical arrays, so any
device count / mesh shape can reload them (resharding happens when arrays
are re-placed by pjit). Partial/corrupt checkpoints are never visible:
readers only trust directories named in LATEST whose manifest CRCs check.
Async mode snapshots device arrays to host then writes in a thread so the
train loop continues (write-behind).
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import uuid
import zlib
from typing import Any

import jax
import numpy as np

__all__ = ["save", "restore", "latest_step", "AsyncCheckpointer"]


def _flatten(tree: Any):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def save(path: str, step: int, tree: Any) -> str:
    """Blocking atomic save. Returns the final directory."""
    leaves, treedef = _flatten(tree)
    final = os.path.join(path, f"step_{step:09d}")
    tmp = final + f".tmp-{uuid.uuid4().hex[:8]}"
    os.makedirs(tmp, exist_ok=True)
    manifest = {"step": step, "treedef": str(treedef), "leaves": []}
    for i, leaf in enumerate(leaves):
        arr = np.asarray(leaf)
        fname = f"arr_{i:05d}.npy"
        np.save(os.path.join(tmp, fname), arr)
        with open(os.path.join(tmp, fname), "rb") as f:
            crc = zlib.crc32(f.read())
        manifest["leaves"].append({
            "file": fname, "shape": list(arr.shape),
            "dtype": str(arr.dtype), "crc32": crc})
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    with open(os.path.join(path, "LATEST.tmp"), "w") as f:
        f.write(str(step))
        f.flush()
        os.fsync(f.fileno())
    os.replace(os.path.join(path, "LATEST.tmp"),
               os.path.join(path, "LATEST"))
    return final


def latest_step(path: str) -> int | None:
    try:
        with open(os.path.join(path, "LATEST")) as f:
            return int(f.read().strip())
    except (FileNotFoundError, ValueError):
        return None


def restore(path: str, example_tree: Any, step: int | None = None) -> tuple[Any, int]:
    """Restore into the structure of ``example_tree`` (elastic re-shard via
    subsequent device_put/pjit placement). Verifies CRCs."""
    if step is None:
        step = latest_step(path)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {path}")
    d = os.path.join(path, f"step_{step:09d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    leaves_meta = manifest["leaves"]
    example_leaves, treedef = _flatten(example_tree)
    if len(example_leaves) != len(leaves_meta):
        raise ValueError(
            f"checkpoint has {len(leaves_meta)} leaves; expected "
            f"{len(example_leaves)} (structure changed?)")
    out = []
    for meta, ex in zip(leaves_meta, example_leaves):
        fp = os.path.join(d, meta["file"])
        with open(fp, "rb") as f:
            crc = zlib.crc32(f.read())
        if crc != meta["crc32"]:
            raise IOError(f"CRC mismatch in {fp} (corrupt checkpoint)")
        arr = np.load(fp)
        if list(arr.shape) != list(np.shape(ex)):
            raise ValueError(
                f"shape mismatch for {meta['file']}: {arr.shape} vs "
                f"{np.shape(ex)}")
        out.append(arr)
    return jax.tree.unflatten(treedef, out), step


class AsyncCheckpointer:
    """Write-behind checkpointing: snapshot to host, write in a thread."""

    def __init__(self, path: str, *, keep: int = 3):
        self.path = path
        self.keep = keep
        self._thread: threading.Thread | None = None
        os.makedirs(path, exist_ok=True)

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def save_async(self, step: int, tree: Any):
        self.wait()                                   # one in flight
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)

        def _write():
            save(self.path, step, host_tree)
            self._gc()

        self._thread = threading.Thread(target=_write, daemon=True)
        self._thread.start()

    def _gc(self):
        steps = sorted(
            int(n.split("_")[1]) for n in os.listdir(self.path)
            if n.startswith("step_") and not n.count(".tmp"))
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.path, f"step_{s:09d}"),
                          ignore_errors=True)
