"""Fault-tolerant checkpointing: atomic, content-addressed, elastic.

Layout per step::

    <dir>/step_000123.tmp-<nonce>/   (written, fsynced)
        manifest.json                (pytree structure, shapes, dtypes, crc)
        arr_00000.npy ...            (one file per leaf, np.save format)
    <dir>/step_000123/               (atomic rename on completion)
    <dir>/LATEST                     (text file, updated last)

Restore is *elastic*: leaves are saved as full logical arrays, so any
device count / mesh shape can reload them (resharding happens when arrays
are re-placed by pjit). Partial/corrupt checkpoints are never visible:
readers only trust directories named in LATEST whose manifest CRCs check.
Async mode snapshots device arrays to host then writes in a thread so the
train loop continues (write-behind).

The manifest+CRC+rename protocol is factored into reusable pieces
(:func:`write_manifest_dir`, :func:`read_manifest_dir`,
:func:`publish_latest`) so other durable artifacts — notably the
per-host shard spills of :mod:`repro.core.exchange` — share the exact
same atomicity and corruption-detection guarantees. Leaf CRCs are
computed on the in-memory ``np.save`` bytes during the write (one I/O
pass, not write-then-reread), and verified reads CRC the bytes they
just loaded for the same reason.
"""

from __future__ import annotations

import io
import json
import os
import shutil
import threading
import uuid
import zlib
from typing import Any, Sequence

import jax
import numpy as np

from repro.core.faults import (CorruptShardError, MissingArtifactError,
                               TornWriteError, declare_site, resolve_plan)

__all__ = ["save", "restore", "latest_step", "AsyncCheckpointer",
           "write_manifest_dir", "read_manifest_dir", "read_manifest_meta",
           "publish_latest"]

# Injection seams this module owns (see faults.FAULT_SITES): the leaf
# codec and the manifest codec, each on both the write and read side.
_SITE_LEAF_WRITE = declare_site("ckpt.leaf_write")
_SITE_LEAF_READ = declare_site("ckpt.leaf_read")
_SITE_MANIFEST_WRITE = declare_site("ckpt.manifest_write")
_SITE_MANIFEST_READ = declare_site("ckpt.manifest_read")


def _flatten(tree: Any):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def _write_leaf(dirpath: str, fname: str, arr: np.ndarray) -> int:
    """Serialize one leaf to ``<dirpath>/<fname>``; returns its CRC32.

    ``np.save`` targets an in-memory buffer so the CRC covers exactly the
    bytes written without re-reading the file from disk.
    """
    buf = io.BytesIO()
    np.save(buf, np.asarray(arr))
    data = buf.getvalue()
    fp = os.path.join(dirpath, fname)
    crc = zlib.crc32(data)
    plan = resolve_plan(None)
    if plan is not None:
        # Storage-layer rot model: the CRC covers the *intended* bytes,
        # the disk holds the corrupted ones, so verified reads detect it.
        data = plan.corrupt_bytes(fp, data, "write")
    with open(fp, "wb") as f:
        f.write(data)
    return crc


def _expected_leaf_bytes(meta: dict) -> int | None:
    """Lower bound on the leaf's npy byte length (payload, sans header)."""
    try:
        itemsize = np.dtype(meta["dtype"]).itemsize
        n = 1
        for s in meta["shape"]:
            n *= int(s)
        return n * itemsize
    except (KeyError, TypeError, ValueError):
        return None


def _read_leaf(dirpath: str, meta: dict) -> np.ndarray:
    """Load + CRC-verify one leaf described by a manifest entry.

    Failure typing: a missing or short file is a :class:`TornWriteError`
    (the writer — or the storage layer — lost bytes after publication);
    present-but-wrong bytes are a :class:`CorruptShardError`. Both are
    ``IOError`` subclasses, so callers' transient-race retry loops are
    unchanged.
    """
    try:
        fp = os.path.join(dirpath, meta["file"])
    except (KeyError, TypeError) as e:
        raise CorruptShardError(
            f"malformed leaf entry in {dirpath}/manifest.json: {e!r}") from e
    try:
        with open(fp, "rb") as f:
            data = f.read()
    except FileNotFoundError as e:
        raise TornWriteError(f"missing leaf {fp} (torn write)") from e
    plan = resolve_plan(None)
    if plan is not None:
        data = plan.corrupt_bytes(fp, data, "read")
    try:
        want_crc = meta["crc32"]
    except (KeyError, TypeError) as e:
        raise CorruptShardError(
            f"malformed leaf entry for {fp}: {e!r}") from e
    if zlib.crc32(data) != want_crc:
        expect = _expected_leaf_bytes(meta)
        if expect is not None and len(data) < expect:
            raise TornWriteError(
                f"truncated leaf {fp}: {len(data)} bytes < {expect} "
                f"expected (torn write)")
        raise CorruptShardError(f"CRC mismatch in {fp} (corrupt checkpoint)")
    try:
        return np.load(io.BytesIO(data))
    except Exception as e:
        raise CorruptShardError(f"undecodable leaf {fp}: {e}") from e


def write_manifest_dir(final: str, arrays: Sequence[np.ndarray],
                       meta: dict | None = None) -> str:
    """Atomically publish ``arrays`` + manifest under directory ``final``.

    The shared protocol: write into ``<final>.tmp-<nonce>/``, fsync the
    manifest, then atomically rename. A crashed writer leaves only a
    ``.tmp-`` directory, which readers never look at. ``meta`` is merged
    into the manifest (callers stash step numbers, treedefs, shard ids).
    """
    tmp = final + f".tmp-{uuid.uuid4().hex[:8]}"
    os.makedirs(tmp, exist_ok=True)
    manifest: dict = dict(meta or {})
    manifest["leaves"] = []
    for i, leaf in enumerate(arrays):
        arr = np.asarray(leaf)
        fname = f"arr_{i:05d}.npy"
        crc = _write_leaf(tmp, fname, arr)
        manifest["leaves"].append({
            "file": fname, "shape": list(arr.shape),
            "dtype": str(arr.dtype), "crc32": crc})
    mf = os.path.join(tmp, "manifest.json")
    mdata = json.dumps(manifest).encode()
    plan = resolve_plan(None)
    if plan is not None:
        mdata = plan.corrupt_bytes(mf, mdata, "write")
    with open(mf, "wb") as f:
        f.write(mdata)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def read_manifest_dir(d: str) -> tuple[list[np.ndarray], dict]:
    """Load (arrays, manifest) from a published dir, verifying every CRC."""
    manifest = read_manifest_meta(d)
    arrays = [_read_leaf(d, meta) for meta in manifest["leaves"]]
    return arrays, manifest


def read_manifest_meta(d: str) -> dict:
    """Manifest JSON of a published dir alone — no array I/O.

    The cheap half of the protocol: delta-chain walkers and shard-meta
    readers (:mod:`repro.core.exchange`) inspect epoch linkage and caller
    ``extra`` state without paying for (or CRC-checking) the leaves.
    """
    fp = os.path.join(d, "manifest.json")
    with open(fp, "rb") as f:
        data = f.read()
    plan = resolve_plan(None)
    if plan is not None:
        data = plan.corrupt_bytes(fp, data, "read")
    try:
        manifest = json.loads(data.decode())
    except (UnicodeDecodeError, json.JSONDecodeError) as e:
        raise CorruptShardError(f"unparseable manifest {fp}: {e}") from e
    if not isinstance(manifest, dict) or "leaves" not in manifest:
        raise CorruptShardError(f"manifest {fp} lacks a leaves table")
    return manifest


def publish_latest(path: str, step: int) -> None:
    """Atomically point ``<path>/LATEST`` at ``step`` (fsynced tmp+rename)."""
    with open(os.path.join(path, "LATEST.tmp"), "w") as f:
        f.write(str(step))
        f.flush()
        os.fsync(f.fileno())
    os.replace(os.path.join(path, "LATEST.tmp"),
               os.path.join(path, "LATEST"))


def save(path: str, step: int, tree: Any) -> str:
    """Blocking atomic save. Returns the final directory."""
    leaves, treedef = _flatten(tree)
    final = os.path.join(path, f"step_{step:09d}")
    write_manifest_dir(final, leaves,
                       meta={"step": step, "treedef": str(treedef)})
    publish_latest(path, step)
    return final


def latest_step(path: str) -> int | None:
    try:
        with open(os.path.join(path, "LATEST")) as f:
            return int(f.read().strip())
    except (FileNotFoundError, ValueError):
        return None


def restore(path: str, example_tree: Any, step: int | None = None) -> tuple[Any, int]:
    """Restore into the structure of ``example_tree`` (elastic re-shard via
    subsequent device_put/pjit placement). Verifies CRCs."""
    if step is None:
        step = latest_step(path)
        if step is None:
            raise MissingArtifactError(f"no checkpoint under {path}")
    d = os.path.join(path, f"step_{step:09d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    leaves_meta = manifest["leaves"]
    example_leaves, treedef = _flatten(example_tree)
    if len(example_leaves) != len(leaves_meta):
        raise ValueError(
            f"checkpoint has {len(leaves_meta)} leaves; expected "
            f"{len(example_leaves)} (structure changed?)")
    out = []
    for meta, ex in zip(leaves_meta, example_leaves):
        arr = _read_leaf(d, meta)
        if list(arr.shape) != list(np.shape(ex)):
            raise ValueError(
                f"shape mismatch for {meta['file']}: {arr.shape} vs "
                f"{np.shape(ex)}")
        out.append(arr)
    return jax.tree.unflatten(treedef, out), step


class AsyncCheckpointer:
    """Write-behind checkpointing: snapshot to host, write in a thread."""

    def __init__(self, path: str, *, keep: int = 3):
        self.path = path
        self.keep = keep
        self._thread: threading.Thread | None = None
        os.makedirs(path, exist_ok=True)

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def save_async(self, step: int, tree: Any):
        self.wait()                                   # one in flight
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)

        def _write():
            save(self.path, step, host_tree)
            self._gc()

        self._thread = threading.Thread(target=_write, daemon=True)
        self._thread.start()

    def _gc(self):
        steps = sorted(
            int(n.split("_")[1]) for n in os.listdir(self.path)
            if n.startswith("step_") and not n.count(".tmp"))
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.path, f"step_{s:09d}"),
                          ignore_errors=True)
