"""§7 engine: per-region energy optimization over TPU knobs.

The paper's use cases tune, *per basic block*: DVFS frequency, concurrency
(thread count), and compiler optimizations — and find that (a) the optimum
differs per block and per objective (time / energy / ED / ED²) and (b)
whole-program energy drops 33–37% vs the performance-tuned baseline.

TPU-native knob set per region:
  * ``freq_scale``  — modeled DVFS step (v5e-class chips expose SW clock caps),
  * ``chips``       — concurrency throttling = submesh size used for the region,
  * ``impl``        — compilation strategy: named implementation variants with
                      cost multipliers (e.g. Pallas flash attention halves HBM
                      traffic of naive attention; remat trades FLOPs for bytes).

Each region is evaluated through the activity power model; objectives follow
Table 2 (time, energy, ED, ED²). The search composes a whole-program plan and
reports savings vs a max-performance baseline — the Table 3 protocol.
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Mapping, Sequence

from repro.core.power_model import PowerModel
from repro.core.timeline import RegionCost

__all__ = ["ImplVariant", "KnobSpace", "RegionPlan", "ProgramPlan",
           "optimize_regions", "evaluate"]


@dataclasses.dataclass(frozen=True)
class ImplVariant:
    """A compilation strategy for a region, as cost multipliers.

    flop_mult/byte_mult scale the region's FLOPs / HBM bytes (e.g. flash
    attention: byte_mult ≪ 1; remat: flop_mult > 1, byte_mult < 1; unroll
     'hints': flop efficiency up). ici_mult scales collective traffic.
    """

    name: str
    flop_mult: float = 1.0
    byte_mult: float = 1.0
    ici_mult: float = 1.0
    efficiency: float = 0.85   # achievable fraction of roofline


@dataclasses.dataclass(frozen=True)
class KnobSpace:
    freq_scales: Sequence[float] = (1.0, 0.94, 0.88, 0.81, 0.75)
    chip_counts: Sequence[int] = (1, 2, 4, 8)
    impls: Sequence[ImplVariant] = (ImplVariant("default"),)


@dataclasses.dataclass(frozen=True)
class RegionPlan:
    region: str
    freq_scale: float
    chips: int
    impl: str
    time: float
    energy: float

    @property
    def power(self) -> float:
        return self.energy / self.time if self.time else 0.0


@dataclasses.dataclass(frozen=True)
class ProgramPlan:
    plans: tuple[RegionPlan, ...]
    objective: str

    @property
    def time(self) -> float:
        return sum(p.time for p in self.plans)

    @property
    def energy(self) -> float:
        return sum(p.energy for p in self.plans)

    def table(self) -> str:
        hdr = (f"{'region':24s} {'freq':>5s} {'chips':>5s} {'impl':>16s} "
               f"{'t [s]':>9s} {'E [J]':>10s}")
        lines = [hdr, "-" * len(hdr)]
        for p in self.plans:
            lines.append(f"{p.region:24s} {p.freq_scale:5.2f} {p.chips:5d} "
                         f"{p.impl:>16s} {p.time:9.4f} {p.energy:10.2f}")
        lines.append(f"{'PROGRAM':24s} {'':5s} {'':5s} {'':16s} "
                     f"{self.time:9.4f} {self.energy:10.2f}")
        return "\n".join(lines)


_OBJECTIVES = {
    "time": lambda t, e: t,
    "energy": lambda t, e: e,
    "ed": lambda t, e: e * t,
    "ed2": lambda t, e: e * t * t,
}


def evaluate(cost: RegionCost, *, freq_scale: float, chips: int,
             impl: ImplVariant, model: PowerModel,
             tp_comm_frac: float = 0.08) -> tuple[float, float]:
    """(time, energy) for one region under one knob setting.

    Energy counts *all* chips in the submesh (idle chips still burn static
    power — that is what makes concurrency throttling pay off when scaling
    is sublinear, the paper's thread-packing effect). Splitting a region
    over chips adds modeled TP/activation collective traffic
    (``tp_comm_frac`` of its memory bytes scaled by (chips−1)/chips) — the
    sublinearity that was cache contention on the paper's platforms.
    """
    flops = cost.flops * impl.flop_mult * cost.invocations
    hbm = cost.hbm_bytes * impl.byte_mult * cost.invocations
    ici = cost.ici_bytes * impl.ici_mult * cost.invocations
    if chips > 1:
        # Per-chip activation-collective traffic is ~chip-count-invariant
        # while per-chip compute shrinks → regions go collective-bound at
        # high TP width (sublinear scaling; paper's contention analogue).
        ici += tp_comm_frac * hbm * (chips - 1) / chips
    dur, pw, _ = model.region_energy(flops, hbm, ici, freq_scale=freq_scale,
                                     chips=chips, efficiency=impl.efficiency)
    energy = dur * pw * chips
    return dur, energy


def optimize_regions(costs: Sequence[RegionCost], space: KnobSpace,
                     *, objective: str = "energy",
                     model: PowerModel | None = None,
                     impl_space: Mapping[str, Sequence[ImplVariant]] | None = None,
                     baseline_chips: int | None = None,
                     max_slowdown: float | None = None) -> ProgramPlan:
    """Independent per-region knob search (the §7.2 campaign).

    ``impl_space`` optionally restricts/extends implementation variants per
    region name (e.g. only attention regions have a flash variant).
    ``max_slowdown`` bounds each region's time to that multiple of its
    baseline (max-freq, ``baseline_chips``) time — the paper's Table 3
    optima stay within modest slowdowns.
    """
    model = model or PowerModel()
    obj = _OBJECTIVES[objective]
    plans: list[RegionPlan] = []
    for cost in costs:
        impls = (impl_space or {}).get(cost.name, space.impls)
        t_budget = float("inf")
        if max_slowdown is not None:
            bc = baseline_chips or max(space.chip_counts)
            t_base, _ = evaluate(cost, freq_scale=1.0, chips=bc,
                                 impl=impls[0], model=model)
            t_budget = max_slowdown * t_base
        best: RegionPlan | None = None
        for fs, ch, impl in itertools.product(space.freq_scales,
                                              space.chip_counts, impls):
            t, e = evaluate(cost, freq_scale=fs, chips=ch, impl=impl,
                            model=model)
            if t > t_budget:
                continue
            if best is None or obj(t, e) < obj(best.time, best.energy):
                best = RegionPlan(cost.name, fs, ch, impl.name, t, e)
        assert best is not None
        plans.append(best)
    return ProgramPlan(tuple(plans), objective)


def baseline_plan(costs: Sequence[RegionCost], *, chips: int,
                  model: PowerModel | None = None,
                  impl: ImplVariant | None = None) -> ProgramPlan:
    """Max-performance baseline: all chips, max frequency, given impl."""
    model = model or PowerModel()
    impl = impl or ImplVariant("default")
    plans = []
    for cost in costs:
        t, e = evaluate(cost, freq_scale=1.0, chips=chips, impl=impl,
                        model=model)
        plans.append(RegionPlan(cost.name, 1.0, chips, impl.name, t, e))
    return ProgramPlan(tuple(plans), "baseline")
