"""Region timelines: the 'ground truth' substrate ALEA samples.

A :class:`Timeline` is a piecewise-constant execution trace — a sequence of
(region_id, duration, power) intervals, exactly Figure 2 of the paper: a
program is a concatenation of basic-block instances whose per-instance
latency varies between iterations.

Two producers:
  * :func:`synthesize` — builds a timeline for a compiled TPU step from
    per-region roofline costs (FLOPs / HBM bytes / ICI bytes, sourced from
    the dry-run's ``cost_analysis`` + HLO collective parsing) through the
    activity power model. Per-instance latency gets multiplicative lognormal
    jitter, reproducing the paper's latency-varies-per-iteration premise.
  * host profiling (``profiler.HostSession``) — records a real timeline of
    region enter/exit timestamps for validation on CPU.

Ground truth per region is the exact integral over intervals — the stand-in
for the paper's direct RAPL measurements (§5 validation protocol).
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from repro.core.power_model import PowerModel

__all__ = ["RegionCost", "Timeline", "synthesize", "ground_truth"]


@dataclasses.dataclass(frozen=True)
class RegionCost:
    """Per-invocation cost of one region of a step (per chip unless noted).

    ``flops``/``hbm_bytes`` are whole-program per-invocation totals that will
    be divided across chips by the caller; ``ici_bytes`` is per-chip link
    traffic (torus collectives keep it ~chip-count invariant).
    """

    name: str
    flops: float
    hbm_bytes: float
    ici_bytes: float = 0.0
    invocations: int = 1    # instances of this region per step (e.g. layers)


@dataclasses.dataclass
class Timeline:
    """Piecewise-constant trace. Arrays share length m (interval count).

    ``rail_powers`` optionally decomposes each interval's scalar power
    into per-domain rails ([m, D], summing to ``powers`` row-wise —
    package vs HBM vs interconnect, cf.
    :data:`repro.core.power_model.POWER_DOMAINS`); ``domains`` names the
    rail axis. Scalar timelines (``rail_powers=None``) behave exactly as
    before — every consumer treats them as D=1 with domain ``"total"``.
    """

    region_ids: np.ndarray   # int32 [m]
    durations: np.ndarray    # float64 [m] seconds
    powers: np.ndarray       # float64 [m] watts (per-chip)
    names: tuple[str, ...]   # region id → name
    rail_powers: np.ndarray | None = None   # float64 [m, D] or None
    domains: tuple[str, ...] | None = None  # rail axis names (None → scalar)

    def __post_init__(self):
        # Own copies, frozen: the lazy cumsum caches below assume the
        # interval arrays never change after construction, so in-place
        # mutation must fail loudly rather than silently serve stale
        # t_exec/region_at/energy_integral.
        self.region_ids = np.array(self.region_ids, dtype=np.int32)
        self.durations = np.array(self.durations, dtype=np.float64)
        self.powers = np.array(self.powers, dtype=np.float64)
        for arr in (self.region_ids, self.durations, self.powers):
            arr.flags.writeable = False
        if not (len(self.region_ids) == len(self.durations) == len(self.powers)):
            raise ValueError("timeline arrays must share length")
        if np.any(self.durations < 0):
            raise ValueError("negative durations")
        if (self.rail_powers is None) != (self.domains is None):
            raise ValueError("rail_powers and domains must be set together")
        if self.rail_powers is not None:
            self.rail_powers = np.array(self.rail_powers, dtype=np.float64)
            self.rail_powers.flags.writeable = False
            self.domains = tuple(self.domains)
            if self.rail_powers.shape != (len(self.powers),
                                          len(self.domains)):
                raise ValueError(
                    f"rail_powers shape {self.rail_powers.shape} != "
                    f"(m={len(self.powers)}, D={len(self.domains)})")
        # Lazy caches: region_at/power_at are called once per sample chunk,
        # so recomputing an O(m) prefix sum per call dominates long runs.
        self._ends_cache: np.ndarray | None = None
        self._eint_cache: np.ndarray | None = None
        self._rail_eint_cache: np.ndarray | None = None

    @property
    def num_domains(self) -> int:
        return 1 if self.domains is None else len(self.domains)

    @property
    def domain_names(self) -> tuple[str, ...]:
        """Rail axis names; scalar timelines report the one ``"total"``."""
        return ("total",) if self.domains is None else self.domains

    def rails(self) -> np.ndarray:
        """Per-domain interval powers [m, D] (scalar → [m, 1] view)."""
        if self.rail_powers is not None:
            return self.rail_powers
        return self.powers[:, None]

    @property
    def t_exec(self) -> float:
        return float(self.ends[-1]) if len(self.durations) else 0.0

    @property
    def starts(self) -> np.ndarray:
        return np.concatenate([[0.0], self.ends[:-1]])

    @property
    def ends(self) -> np.ndarray:
        if self._ends_cache is None:
            self._ends_cache = np.cumsum(self.durations)
        return self._ends_cache

    def energy_integral(self) -> np.ndarray:
        """Cumulative energy E(t) at interval ends (for sensor emulation)."""
        if self._eint_cache is None:
            self._eint_cache = np.cumsum(self.durations * self.powers)
        return self._eint_cache

    def rail_energy_integral(self) -> np.ndarray:
        """Per-domain cumulative energy at interval ends, [m, D].

        Scalar timelines return ``energy_integral()[:, None]`` so the
        D=1 column is bit-identical to the scalar integral (the
        compatibility contract every multi-channel sensor leans on).
        """
        if self._rail_eint_cache is None:
            if self.rail_powers is None:
                self._rail_eint_cache = self.energy_integral()[:, None]
            else:
                self._rail_eint_cache = np.cumsum(
                    self.durations[:, None] * self.rail_powers, axis=0)
        return self._rail_eint_cache

    def region_at(self, times: np.ndarray) -> np.ndarray:
        """Region id executing at each time point (vectorized PC sampling)."""
        idx = np.searchsorted(self.ends, np.asarray(times), side="right")
        idx = np.clip(idx, 0, len(self.region_ids) - 1)
        return self.region_ids[idx]

    def power_at(self, times: np.ndarray) -> np.ndarray:
        idx = np.searchsorted(self.ends, np.asarray(times), side="right")
        idx = np.clip(idx, 0, len(self.powers) - 1)
        return self.powers[idx]

    def tile(self, reps: int) -> "Timeline":
        """Concatenate ``reps`` identical steps (multi-step profiled run)."""
        return Timeline(np.tile(self.region_ids, reps),
                        np.tile(self.durations, reps),
                        np.tile(self.powers, reps), self.names,
                        rail_powers=None if self.rail_powers is None
                        else np.tile(self.rail_powers, (reps, 1)),
                        domains=self.domains)

    def to_device(self):
        """Upload as a single-worker :class:`DeviceTimeline` substrate.

        Entry to the fused device-resident sampling pipeline
        (:mod:`repro.core.device_pipeline`): interval ends, the cumulative
        energy integral, powers, and region ids become device arrays so an
        arbitrarily long sampling run never touches these host arrays
        again. Imported lazily so numpy-only consumers never pay for jax.
        """
        from repro.core.device_pipeline import DeviceTimeline
        return DeviceTimeline.from_timelines([self])


def ground_truth(tl: Timeline) -> dict[str, dict[str, float]]:
    """Exact per-region time/energy/power (the 'direct measurement').

    Vectorized: one weighted bincount per statistic instead of a
    per-region boolean-mask pass over the interval arrays.
    """
    minlen = int(tl.region_ids.max()) + 1 if len(tl.region_ids) else 0
    t = np.bincount(tl.region_ids, weights=tl.durations, minlength=minlen)
    e = np.bincount(tl.region_ids, weights=tl.durations * tl.powers,
                    minlength=minlen)
    e_rails = None
    if tl.rail_powers is not None:
        e_rails = np.stack(
            [np.bincount(tl.region_ids,
                         weights=tl.durations * tl.rail_powers[:, d],
                         minlength=minlen)
             for d in range(len(tl.domains))], axis=1)
    present = np.bincount(tl.region_ids, minlength=minlen) > 0
    out = {}
    for rid in np.flatnonzero(present):
        row = {"time": float(t[rid]), "energy": float(e[rid]),
               "power": float(e[rid] / t[rid]) if t[rid] > 0 else 0.0}
        if e_rails is not None:
            row["energy_rails"] = {d: float(e_rails[rid, j])
                                   for j, d in enumerate(tl.domains)}
        out[tl.names[rid]] = row
    return out


def synthesize(costs: Sequence[RegionCost], *, steps: int = 1,
               chips: int = 1, model: PowerModel | None = None,
               freq_scale: float = 1.0, latency_noise: float = 0.08,
               power_noise: float = 0.02, efficiency: float = 0.85,
               seed: int = 0, domains: bool = False) -> Timeline:
    """Synthesize a device timeline from per-region roofline costs.

    Each step emits every region's invocations in order; per-instance
    duration is the roofline duration × lognormal(σ=latency_noise) jitter
    (paper Fig. 2: latency varies between iterations, e.g. with the memory
    level serving each load); per-instance power adds Gaussian sensor-scale
    noise on top of the activity model.

    ``domains=True`` additionally carries the power model's per-rail
    decomposition (:meth:`PowerModel.power_rails`) on every interval.
    The scalar ``powers`` stream is computed identically either way —
    same RNG consumption, same values — so ``domains=True`` only *adds*
    information; each instance's rails are scaled uniformly by its noise
    factor so they sum to the scalar power.
    """
    model = model or PowerModel()
    rng = np.random.default_rng(seed)
    names = tuple(c.name for c in costs)
    dom_names = model.domains if domains else None

    ids, durs, pows, rails = [], [], [], []
    for step in range(steps):
        for rid, c in enumerate(costs):
            base = model.region_duration(c.flops, c.hbm_bytes, c.ici_bytes,
                                         freq_scale=freq_scale, chips=chips,
                                         efficiency=efficiency)
            jit = rng.lognormal(mean=0.0, sigma=latency_noise,
                                size=c.invocations)
            d = base * jit
            u = model.utilizations(c.flops / chips, c.hbm_bytes / chips,
                                   c.ici_bytes, base, freq_scale)
            p = float(model.power(*u, freq_scale=freq_scale))
            pn = p * (1.0 + power_noise * rng.standard_normal(c.invocations))
            pn = np.maximum(pn, 1.0)
            ids.append(np.full(c.invocations, rid, dtype=np.int32))
            durs.append(d)
            pows.append(pn)
            if domains:
                r = model.power_rails(*u, freq_scale=freq_scale)
                rails.append(r[None, :] * (pn / r.sum())[:, None])
    return Timeline(np.concatenate(ids), np.concatenate(durs),
                    np.concatenate(pows), names,
                    rail_powers=np.concatenate(rails) if domains else None,
                    domains=dom_names)
