"""EnergyProfiler: one-pass sampling orchestration (paper Fig. 1, §4.8).

Usage, timeline mode (TPU-target; timelines synthesized from dry-run costs):

    prof = EnergyProfiler(period=10e-3)
    est = prof.profile_timeline(timeline, sensor="rapl")
    print(AttributionReport(est).table())

Usage, host mode (real control thread on this machine):

    prof = EnergyProfiler(period=2e-3)
    with prof.host_session() as session:
        ... run python/jit code using regions.region(...) ...
    est = session.estimates()
"""

from __future__ import annotations

import contextlib

import numpy as np

from repro.core import regions as regions_mod
from repro.core.attribution import AttributionReport
from repro.core.estimator import (AggregateFn, EstimateSet,
                                  estimate_combinations, estimate_regions)
from repro.core.sampler import (HostSampler, RegionMarker, SampleStream,
                                iter_multiworker_chunks, iter_sample_chunks,
                                sample_timeline, sample_timeline_multiworker)
from repro.core.streaming import (StreamingAggregator,
                                  StreamingCombinationAggregator)
from repro.core.sensors import (Ina231TraceSensor, InstantTraceSensor,
                                RaplTraceSensor, available_host_sensor)
from repro.core.timeline import Timeline

__all__ = ["EnergyProfiler", "HostSession"]

_SENSORS = {
    "rapl": RaplTraceSensor,
    "ina231": Ina231TraceSensor,
    "instant": InstantTraceSensor,
}


class HostSession:
    """A live host-mode profiling pass.

    ``sensor`` defaults to the best scalar sensor the environment
    permits; passing a :class:`~repro.core.sensors.HostSensorBank` makes
    the session multi-rail — the sampler drains [n, D] power matrices
    and :meth:`estimates` carries per-domain columns, exactly like the
    timeline paths.
    """

    def __init__(self, profiler: "EnergyProfiler", jit_marking: bool,
                 sensor=None):
        self._prof = profiler
        self.marker = RegionMarker()
        sensor = available_host_sensor() if sensor is None else sensor
        min_period = (sensor.effective_min_period()
                      if hasattr(sensor, "effective_min_period")
                      else getattr(sensor, "min_period", 0.0))
        if profiler.period < min_period:
            raise ValueError(f"sampling period {profiler.period} below the "
                             f"sensor bank's floor {min_period}")
        self.sampler = HostSampler(
            self.marker, sensor,
            period=profiler.period, jitter=profiler.jitter,
            seed=profiler.seed)
        self._ctx = None
        self._jit_marking = jit_marking

    def __enter__(self) -> "HostSession":
        self._ctx = contextlib.ExitStack()
        self._ctx.enter_context(
            regions_mod.profiling_session(self.marker,
                                          jit_marking=self._jit_marking))
        self._ctx.enter_context(self.sampler)
        return self

    def __exit__(self, *exc) -> None:
        assert self._ctx is not None
        self._ctx.close()

    def stream(self) -> SampleStream:
        return self.sampler.stream()

    def estimates(self, alpha: float = 0.05) -> EstimateSet:
        s = self.stream()
        names = regions_mod.registry.names
        if s.powers.ndim == 2:
            # Banked sensor: aggregate the [n, D] matrix so the estimate
            # set carries per-rail columns (domain_table/domain_csv).
            hi = int(s.region_ids.max()) + 1 if len(s.region_ids) else 0
            agg = StreamingAggregator(max(len(names), hi, 1),
                                      domains=self.sampler.domains)
            if len(s.region_ids):
                agg.update(s.region_ids, s.powers)
            return agg.estimates(s.t_exec, names, alpha=alpha)
        return estimate_regions(s.region_ids, s.powers, s.t_exec,
                                names, alpha=alpha)


class EnergyProfiler:
    """Fine-grain energy profiler with systematic sampling."""

    def __init__(self, *, period: float = 10e-3, jitter: float = 200e-6,
                 alpha: float = 0.05, seed: int = 0):
        if period <= 0:
            raise ValueError("period must be positive")
        self.period = period
        self.jitter = jitter
        self.alpha = alpha
        self.seed = seed

    # -- timeline (device) mode ---------------------------------------------
    def profile_timeline(self, tl: Timeline, *, sensor: str = "rapl",
                         overhead_per_sample: float = 0.0,
                         seed: int | None = None) -> EstimateSet:
        sens = _SENSORS[sensor](tl)
        stream = sample_timeline(
            tl, sens, period=self.period, jitter=self.jitter,
            overhead_per_sample=overhead_per_sample,
            seed=self.seed if seed is None else seed)
        return estimate_regions(stream.region_ids, stream.powers,
                                stream.t_exec, tl.names, alpha=self.alpha)

    def profile_multiworker(self, timelines: list[Timeline], *,
                            sensor: str = "rapl", seed: int | None = None):
        """§4.4: combination-level attribution across concurrent workers."""
        stream = sample_timeline_multiworker(
            timelines, lambda tl: _SENSORS[sensor](tl),
            period=self.period, jitter=self.jitter,
            seed=self.seed if seed is None else seed)
        names = timelines[0].names
        return estimate_combinations(stream.region_ids, stream.powers,
                                     stream.t_exec, names, alpha=self.alpha)

    # -- streaming (fleet-scale) mode ------------------------------------------
    def _resolve_pipeline(self, pipeline: str, aggregate_fn) -> bool:
        """True → fused device pipeline; False → host-numpy chunk loop.

        ``auto`` prefers the device pipeline whenever JAX is importable
        and no explicit per-chunk ``aggregate_fn`` was plugged in (a
        custom kernel plug implies the host chunk seam), falling back to
        the host path when the device path's preconditions don't hold
        (no jax, or jitter > period breaks its monotone sample clock).
        """
        if pipeline not in ("auto", "device", "host"):
            raise ValueError(f"pipeline must be auto|device|host; "
                             f"got {pipeline!r}")
        if pipeline == "host":
            return False
        if pipeline == "device" and aggregate_fn is not None:
            raise ValueError(
                "aggregate_fn plugs the host chunk seam and would be "
                "silently ignored by the device pipeline; use "
                "pipeline=\"host\" (or drop aggregate_fn)")
        if pipeline == "auto" and (aggregate_fn is not None
                                   or self.jitter > self.period):
            return False
        try:
            import repro.core.device_pipeline  # noqa: F401
        except ImportError:
            if pipeline == "device":
                raise
            return False
        return True

    def profile_timeline_streaming(self, tl: Timeline, *,
                                   sensor: str = "rapl",
                                   chunk_size: int = 65536,
                                   overhead_per_sample: float = 0.0,
                                   aggregate_fn: AggregateFn | None = None,
                                   seed: int | None = None,
                                   pipeline: str = "auto") -> EstimateSet:
        """Constant-memory profiling: chunked sampling → StreamingAggregator.

        Equivalent estimates to :meth:`profile_timeline` (different jitter
        draws for the same seed) while holding O(chunk + R) sample state —
        the path for runs long enough that the stream won't fit in memory.

        ``pipeline`` selects the backend: ``"device"`` runs the fused
        device-resident pipeline (:mod:`repro.core.device_pipeline`) —
        sample generation, region lookup, sensor emulation and the
        attribution reduction in one jitted scan with a donated carry, no
        per-chunk host transfers; ``"host"`` keeps the numpy reference
        loop; ``"auto"`` (default) uses the device pipeline when JAX is
        the substrate. ``aggregate_fn`` plugs a kernel into the *host*
        chunk seam (and so implies the host path under ``auto``).
        """
        use_seed = self.seed if seed is None else seed
        if self._resolve_pipeline(pipeline, aggregate_fn):
            from repro.core import device_pipeline as dp
            res = dp.run_region_pipeline(
                tl.to_device(),
                _SENSORS[sensor].make_spec(domains=tl.domain_names),
                period=self.period, jitter=self.jitter, seed=use_seed,
                chunk_size=chunk_size,
                overhead_per_sample=overhead_per_sample)
            agg = StreamingAggregator.from_statistics(
                res.counts,
                res.psum if tl.num_domains == 1 else np.concatenate(
                    [res.rail_psum, res.psum[:, None]], axis=1),
                res.psumsq if tl.num_domains == 1 else np.concatenate(
                    [res.rail_psumsq, res.psumsq[:, None]], axis=1),
                domains=tl.domain_names)
            return agg.estimates(res.t_exec, tl.names, alpha=self.alpha)
        sens = _SENSORS[sensor](tl)
        agg = StreamingAggregator(len(tl.names), aggregate_fn=aggregate_fn,
                                  domains=tl.domain_names)
        n = 0
        for rids, pows in iter_sample_chunks(
                tl, sens, period=self.period, jitter=self.jitter,
                overhead_per_sample=overhead_per_sample,
                seed=use_seed, chunk_size=chunk_size):
            agg.update(rids, pows)
            n += len(rids)
        t_exec = tl.t_exec + n * overhead_per_sample
        return agg.estimates(t_exec, tl.names, alpha=self.alpha)

    def profile_multiworker_streaming(self, timelines: list[Timeline], *,
                                      sensor: str = "rapl",
                                      chunk_size: int = 65536,
                                      aggregate_fn: AggregateFn | None = None,
                                      exchange=None,
                                      seed: int | None = None,
                                      pipeline: str = "auto"):
        """§4.4 combination attribution without materializing the stream.

        Chunked multi-worker sampling feeds a
        StreamingCombinationAggregator (incremental combination interning),
        so fleet-scale combination spaces (10⁴–10⁵) stay bounded by
        O(chunk + distinct combinations). With ``pipeline="device"``
        (the ``auto`` default when JAX is the substrate) the whole chunk
        loop is the fused device pipeline: ``vmap`` over the batched
        [W, m] timeline replaces the per-chunk Python loop over workers,
        and chunks whose combinations are already in the device-resident
        key table fold without any host transfer.

        ``exchange`` selects the cross-host shard-exchange strategy for
        the final reduction (:mod:`repro.core.exchange`): a
        ``CollectiveExchange`` all-reduces this host's aggregator over a
        mesh axis, a ``CheckpointExchange`` spills it durably and merges
        every published host shard — combination ids are deduped lazily
        at merge in both cases. ``None`` keeps the single-host result.

        Restart semantics: sampling here is deterministic in ``seed``,
        so a restarted host re-produces its complete shard and the final
        spill republishes LATEST idempotently — the previous spill is
        deliberately NOT merged in (that would double-count every
        sample). Under the checkpoint exchange's default delta mode the
        idempotent republish is itself incremental: the regenerated
        shard matches the restored chain row for row, so the new epoch
        is an empty delta and gathers stay bit-exact. Incremental
        resume-from-spill is for accumulating consumers
        (``PhaseEnergyAccountant``, direct ``restore_shard``).
        """
        use_seed = self.seed if seed is None else seed
        if self._resolve_pipeline(pipeline, aggregate_fn):
            from repro.core import device_pipeline as dp
            dtl = dp.DeviceTimeline.from_timelines(timelines)
            agg, _n = dp.run_combo_pipeline(
                dtl, _SENSORS[sensor].make_spec(domains=dtl.domains),
                period=self.period, jitter=self.jitter, seed=use_seed,
                chunk_size=chunk_size)
        else:
            agg = StreamingCombinationAggregator(
                aggregate_fn=aggregate_fn,
                domains=timelines[0].domain_names)
            agg.update_stream(iter_multiworker_chunks(
                timelines, lambda tl: _SENSORS[sensor](tl),
                period=self.period, jitter=self.jitter,
                seed=use_seed, chunk_size=chunk_size))
        if exchange is not None:
            agg = exchange.reduce(agg)
        t_end = min(tl.t_exec for tl in timelines)
        return agg.estimates(t_end, timelines[0].names, alpha=self.alpha)

    # -- host (this machine) mode --------------------------------------------
    def host_session(self, *, jit_marking: bool = False,
                     sensor=None) -> HostSession:
        """A live session on this machine. ``sensor`` accepts any scalar
        host sensor or a :class:`~repro.core.sensors.HostSensorBank`
        (per-rail host profiling, with the bank's failover semantics)."""
        return HostSession(self, jit_marking, sensor=sensor)

    # -- convenience -----------------------------------------------------------
    def report(self, est: EstimateSet) -> AttributionReport:
        return AttributionReport(est)
