"""Activity-based TPU power model (+ DVFS / concurrency-throttling curves).

ALEA's platforms expose calibrated sensors (RAPL, INA231). A TPU pod exposes
coarse board telemetry; for the CPU-only container we *model* chip power from
the same activity signals the paper found dominant (§6: power tracks
memory-access intensity far more than instruction mix):

    P(chip) = P_idle
            + e_flop · (achieved FLOP/s / peak FLOP/s)        (MXU activity)
            + e_mem  · (achieved HBM B/s / peak HBM B/s)       (HBM activity)
            + e_ici  · (achieved ICI B/s / peak ICI B/s)       (link activity)

The utilization denominators are published TPU v5e peaks. The energy
coefficients are *calibration parameters* exactly as in the paper's
per-platform setup — centralize them here so a real deployment substitutes
measured values.

DVFS model (§7 analogue): dynamic power ∝ f·V² with V ∝ f → P_dyn ∝ s³ for
frequency scale s; compute-bound time ∝ 1/s, memory/ICI-bound time
unaffected. This reproduces the paper's finding that most regions are most
energy-efficient slightly below maximum frequency, with the optimum
depending on each region's arithmetic intensity.
"""

from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["HardwareSpec", "PowerModelParams", "PowerModel", "TPU_V5E",
           "POWER_DOMAINS"]

# The power-rail domain axis: the decomposition the activity model already
# computes internally (per-resource utilization terms) before summing to
# chip power. JetsonLEAP-style instruments measure these rails separately;
# threading them end-to-end gives per-block per-domain attribution.
#   package — static/leakage + MXU dynamic power (the PKG-rail analogue)
#   hbm     — HBM/DRAM dynamic power (the DRAM-rail analogue)
#   ici     — interconnect link power
POWER_DOMAINS = ("package", "hbm", "ici")


@dataclasses.dataclass(frozen=True)
class HardwareSpec:
    """Per-chip peaks used for both roofline terms and power utilization."""

    name: str
    peak_flops_bf16: float      # FLOP/s
    hbm_bandwidth: float        # B/s
    ici_bandwidth_per_link: float  # B/s (one direction)
    ici_links: int              # links per chip on a 2D torus
    vmem_bytes: int             # usable VMEM for Pallas BlockSpec sizing
    hbm_bytes: int              # HBM capacity per chip


TPU_V5E = HardwareSpec(
    name="tpu-v5e",
    peak_flops_bf16=197e12,
    hbm_bandwidth=819e9,
    ici_bandwidth_per_link=50e9,
    ici_links=4,
    vmem_bytes=16 * 1024 * 1024,
    hbm_bytes=16 * 1024**3,
)


@dataclasses.dataclass(frozen=True)
class PowerModelParams:
    """Calibration constants [W]. Modeled values for v5e-class chips."""

    p_idle: float = 70.0        # static + leakage at max frequency
    e_flop: float = 90.0        # marginal power at 100% MXU utilization
    e_mem: float = 55.0         # marginal power at 100% HBM utilization
    e_ici: float = 18.0         # marginal power at 100% ICI utilization
    # Contention (paper §6.2): shared-resource pressure raises power
    # superlinearly when multiple workers are memory-intensive at once.
    contention_coeff: float = 0.15
    # Fraction of static power that scales with voltage (DVFS leakage model).
    static_freq_fraction: float = 0.35


class PowerModel:
    """Maps region activity (utilizations) to chip power."""

    def __init__(self, params: PowerModelParams | None = None,
                 hw: HardwareSpec = TPU_V5E):
        self.params = params or PowerModelParams()
        self.hw = hw

    # -- utilization helpers -------------------------------------------------
    def utilizations(self, flops: float, hbm_bytes: float, ici_bytes: float,
                     duration_s: float, freq_scale: float = 1.0
                     ) -> tuple[float, float, float]:
        """Achieved-rate / peak-rate for a region of known cost & duration."""
        if duration_s <= 0:
            return (0.0, 0.0, 0.0)
        peak_f = self.hw.peak_flops_bf16 * freq_scale
        u_f = min(flops / duration_s / peak_f, 1.0)
        u_m = min(hbm_bytes / duration_s / self.hw.hbm_bandwidth, 1.0)
        u_i = min(
            ici_bytes / duration_s
            / (self.hw.ici_bandwidth_per_link * self.hw.ici_links), 1.0)
        return (u_f, u_m, u_i)

    def power(self, u_flop, u_mem, u_ici, *, freq_scale: float = 1.0,
              mem_contention: float = 0.0):
        """Chip power [W] at the given utilizations.

        Args:
          freq_scale: DVFS frequency scale s ∈ (0, 1]; dynamic ∝ s³.
          mem_contention: extra fractional HBM pressure from co-running
            workers (0 = standalone), paper §6.2's cache-contention analogue.
        """
        p = self.params
        s3 = freq_scale ** 3
        static = p.p_idle * ((1 - p.static_freq_fraction)
                             + p.static_freq_fraction * freq_scale**2)
        dyn = (p.e_flop * np.asarray(u_flop) * s3
               + p.e_mem * np.asarray(u_mem)
               * (1.0 + p.contention_coeff * mem_contention)
               + p.e_ici * np.asarray(u_ici))
        return static + dyn

    @property
    def domains(self) -> tuple[str, ...]:
        """Power-rail domain names, aligned with :meth:`power_rails`."""
        return POWER_DOMAINS

    def power_rails(self, u_flop, u_mem, u_ici, *, freq_scale: float = 1.0,
                    mem_contention: float = 0.0) -> np.ndarray:
        """Per-rail chip power [..., D] — the decomposition behind
        :meth:`power`.

        ``power_rails(...).sum(-1)`` equals :meth:`power` up to float64
        association (the rails are the model's own additive terms; static
        power rides on the package rail, as a real PKG counter reports it).
        """
        p = self.params
        s3 = freq_scale ** 3
        static = p.p_idle * ((1 - p.static_freq_fraction)
                             + p.static_freq_fraction * freq_scale**2)
        package = static + p.e_flop * np.asarray(u_flop, np.float64) * s3
        hbm = (p.e_mem * np.asarray(u_mem, np.float64)
               * (1.0 + p.contention_coeff * mem_contention))
        ici = p.e_ici * np.asarray(u_ici, np.float64)
        return np.stack(np.broadcast_arrays(package, hbm, ici), axis=-1)

    # -- region-level durations under DVFS ----------------------------------
    def region_duration(self, flops: float, hbm_bytes: float, ici_bytes: float,
                        *, freq_scale: float = 1.0, chips: int = 1,
                        efficiency: float = 0.85) -> float:
        """Roofline duration of a region spread over ``chips`` chips.

        max(compute, memory, collective) with compute scaled by DVFS. The
        collective term uses per-chip link bandwidth (ring/torus collectives
        keep per-chip traffic ~constant, so ici_bytes is per-chip already).
        """
        t_f = flops / chips / (self.hw.peak_flops_bf16 * freq_scale)
        t_m = hbm_bytes / chips / self.hw.hbm_bandwidth
        t_i = ici_bytes / (self.hw.ici_bandwidth_per_link * self.hw.ici_links)
        return max(t_f, t_m, t_i) / efficiency

    def region_energy(self, flops: float, hbm_bytes: float, ici_bytes: float,
                      *, freq_scale: float = 1.0, chips: int = 1,
                      efficiency: float = 0.85) -> tuple[float, float, float]:
        """(duration, chip_power, total_energy) for a region config."""
        dur = self.region_duration(flops, hbm_bytes, ici_bytes,
                                   freq_scale=freq_scale, chips=chips,
                                   efficiency=efficiency)
        u = self.utilizations(flops / chips, hbm_bytes / chips, ici_bytes,
                              dur, freq_scale)
        pw = float(self.power(*u, freq_scale=freq_scale))
        return dur, pw, dur * pw * chips
