"""Region registry and markers — the TPU analogue of basic blocks.

A *region* is a named sub-computation of a step (``attn_qkv``, ``moe_dispatch``,
``allreduce_grads``...). Regions are declared where the model is built:

    with regions.region("attn_score"):
        scores = ...

This does three things:
  1. wraps the computation in ``jax.named_scope`` so the region name survives
     into HLO metadata (offline sample→region mapping, like PC→block);
  2. when a profiling session is active, updates the shared
     :class:`~repro.core.sampler.RegionMarker` so the host control thread can
     sample the currently-executing region — inside jit this is an
     ``io_callback`` that stores one int (the §4.8 near-zero instrumentation);
  3. registers the region (stable id assignment) for reports.

When no session is active the context manager is a plain ``named_scope`` —
zero runtime cost in production steps.
"""

from __future__ import annotations

import contextlib
import threading
from typing import Iterator

import jax
import numpy as np

from repro.core.sampler import RegionMarker

__all__ = ["RegionRegistry", "region", "registry", "profiling_session",
           "mark_in_jit"]


class RegionRegistry:
    """Process-wide region-name ↔ id mapping (thread-safe)."""

    def __init__(self):
        self._lock = threading.Lock()
        self._name_to_id: dict[str, int] = {"<other>": 0}
        self._names: list[str] = ["<other>"]

    def intern(self, name: str) -> int:
        with self._lock:
            rid = self._name_to_id.get(name)
            if rid is None:
                rid = len(self._names)
                self._name_to_id[name] = rid
                self._names.append(name)
            return rid

    @property
    def names(self) -> tuple[str, ...]:
        with self._lock:
            return tuple(self._names)

    def name_of(self, rid: int) -> str:
        return self._names[rid]

    def reset(self) -> None:
        with self._lock:
            self._name_to_id = {"<other>": 0}
            self._names = ["<other>"]


registry = RegionRegistry()

# Active profiling marker (None ⇒ markers compile away).
_active_marker: RegionMarker | None = None
_in_jit_marking = False


@contextlib.contextmanager
def profiling_session(marker: RegionMarker, *, jit_marking: bool = False
                      ) -> Iterator[None]:
    """Activates host-mode marking. ``jit_marking`` also emits io_callback
    marker stores inside traced code (costs one host callback per region
    entry; only for host-mode validation runs, never production)."""
    global _active_marker, _in_jit_marking
    prev, prev_jit = _active_marker, _in_jit_marking
    _active_marker, _in_jit_marking = marker, jit_marking
    try:
        yield
    finally:
        _active_marker, _in_jit_marking = prev, prev_jit


def _store_marker(rid_arr) -> None:
    m = _active_marker
    if m is not None:
        m.set(int(rid_arr))


def mark_in_jit(name: str, dep=None):
    """Emit an in-graph marker store (validation runs only). Returns ``dep``
    unchanged so callers can thread it for ordering."""
    rid = registry.intern(name)
    if _active_marker is not None and _in_jit_marking:
        jax.experimental.io_callback(_store_marker, None,
                                     np.int32(rid), ordered=True)
    return dep


_region_stack = threading.local()


@contextlib.contextmanager
def region(name: str) -> Iterator[int]:
    """Declare a region. Cheap always; marker store only inside a session.

    Nested regions restore the *parent* region id on exit (a stack), so
    host time spent inside an outer region but after an inner one — e.g.
    XLA compilation following tracing — is attributed to the outer region,
    like a PC returning to the caller's basic block.
    """
    rid = registry.intern(name)
    m = _active_marker
    if m is not None and not _in_jit_marking:
        stack = getattr(_region_stack, "s", None)
        if stack is None:
            stack = _region_stack.s = [0]
        stack.append(rid)
        m.set(rid)
    with jax.named_scope(name):
        yield rid
    if m is not None and not _in_jit_marking:
        stack = _region_stack.s
        stack.pop()
        m.set(stack[-1])
