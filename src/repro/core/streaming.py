"""Streaming fleet-scale sample aggregation (constant extra memory).

ALEA's accuracy comes from sample volume: a fleet sampling at the paper's
~10 ms period (let alone PowerSensor3-class multi-kHz sensors) produces
billions of (region_id, power) samples — far more than the one-shot
``np.bincount`` path in :mod:`repro.core.estimator` can hold in memory.
This module makes the estimator pipeline *streaming*:

* :class:`StreamingAggregator` folds sample chunks of any size into the
  per-region sufficient statistics (counts, Σpow, Σpow²) behind the same
  pluggable ``AggregateFn`` seam the one-shot path uses, so the Pallas
  ``kernels/sample_attr`` kernel (region-tiled, chunked) drops in per
  block. ``merge()`` reduces shards from multiple hosts — the statistics
  are associative+commutative, so any reduction tree is exact.

* :class:`CombinationInterner` replaces ``encode_combinations``'s
  full-matrix ``np.unique(axis=0)`` with incremental hash-interning of
  per-worker region vectors: each chunk is deduplicated locally (sort
  bounded by chunk size) and its unique rows interned into a dict, so the
  multi-worker path runs in one pass with O(chunk + distinct combos)
  memory and no re-sort of previously seen data.

* :class:`StreamingCombinationAggregator` composes the two for §4.4
  combination-level attribution over chunked multi-worker streams.

Statistics carry an optional power-rail domain axis: a ``domains``
axis of D rails stores ``[R, C]`` channel matrices (the rails plus, for
D > 1, the total-power channel — :func:`channels_for`), with the scalar
``psum``/``psumsq`` views unchanged for single-domain streams. The
aggregators also maintain generation-stamped touched-row tracking the
delta spiller (:class:`repro.core.exchange.ShardSpiller`) diffs against
(:meth:`StreamingAggregator.rows_touched_since` — non-destructive, so
any number of spillers consume one aggregator independently).

Peak extra memory is O(chunk + R·C) instead of O(n); see
``benchmarks/aggregation.py`` for the throughput trajectory.
"""

from __future__ import annotations

from typing import Iterable, Sequence

import numpy as np

from repro.core import estimator as estimator_mod
from repro.core.estimator import (AggregateFn, EstimateSet,
                                  combination_names_from_matrix,
                                  estimates_from_statistics)

__all__ = [
    "DEFAULT_CHUNK",
    "channels_for",
    "StreamingAggregator",
    "CombinationInterner",
    "StreamingCombinationAggregator",
    "stream_estimate",
]

DEFAULT_CHUNK = 65536


def _as_channels(arr, c: int) -> np.ndarray:
    """Normalize pre-aggregated Σ statistics to the [R, C] channel layout
    (1-D arrays are the single-channel scalar form)."""
    arr = np.asarray(arr, dtype=np.float64)
    if arr.ndim == 1:
        if c != 1:
            raise ValueError(
                f"1-D statistics for a {c}-channel aggregator; pass the "
                f"[rows, {c}] channel matrix")
        return arr[:, None]
    if arr.ndim == 2 and arr.shape[1] == c:
        return arr
    raise ValueError(f"statistics shape {arr.shape} does not match "
                     f"{c} channels")


def channels_for(domains: Sequence[str] | int) -> int:
    """Statistic channels for a domain axis (names or rail count): the
    rails, plus a dedicated total-power channel when D > 1 (Σpow² of the
    total is not derivable from per-rail Σpow² — squares don't sum
    across rails — and the estimator's power CI needs it). D = 1 is the
    pre-rail scalar layout: the single rail *is* the total.

    This is THE channel-layout rule — the wire schema
    (:mod:`repro.core.exchange`), the device-pipeline carries and the
    estimator split all derive from it; change it nowhere else.
    """
    d = domains if isinstance(domains, int) else len(domains)
    return d + (1 if d > 1 else 0)


class StreamingAggregator:
    """Constant-memory accumulator of per-region sample statistics.

    Consumes (region_ids, powers) chunks of any size via :meth:`update`;
    holds a [R] ``counts`` int64 accumulator plus [R, C] ``chan_psum`` /
    ``chan_psumsq`` f64 channel accumulators, where the channels are the
    power-rail ``domains`` plus — when multi-domain — the total (see
    :func:`channels_for`). The default single-domain aggregator stores
    exactly the pre-rail (counts, Σpow, Σpow²) triple; ``psum``/``psumsq``
    remain its scalar-total views. ``aggregate_fn`` is the per-chunk
    reducer — defaults to the numpy reference, swap in
    ``kernels.sample_attr.ops.chunked_aggregate_fn`` for the Pallas path
    (applied per channel). :meth:`merge` combines shards (multi-host
    reduction; domain axes must match).

    The aggregator also maintains generation-stamped *touched-row
    tracking* (:meth:`rows_touched_since` / :meth:`touch_generation`):
    every row whose statistics may have changed since a consumer's
    watermark. :class:`repro.core.exchange.ShardSpiller` diffs against
    it instead of deep-copying the full packed shard each publish,
    dropping the O(rows) per-epoch snapshot — and because reads are
    non-destructive, independent spillers can publish one aggregator
    to different destinations without corrupting each other's chains.
    """

    def __init__(self, num_regions: int, *,
                 aggregate_fn: AggregateFn | None = None,
                 domains: Sequence[str] = ("total",)):
        if num_regions < 0:
            raise ValueError(f"num_regions must be >= 0; got {num_regions}")
        self._agg = aggregate_fn or estimator_mod.aggregate_samples_np
        self.domains = tuple(domains)
        if not self.domains:
            raise ValueError("domains must name at least one rail")
        c = channels_for(self.domains)
        self.counts = np.zeros(num_regions, dtype=np.int64)
        self.chan_psum = np.zeros((num_regions, c), dtype=np.float64)
        self.chan_psumsq = np.zeros((num_regions, c), dtype=np.float64)
        # Touched-row tracking, generation-based: every mutation stamps
        # its rows with the current generation, and each consumer (a
        # ShardSpiller) remembers the generation it last published —
        # so multiple independent consumers of one aggregator never
        # steal each other's change sets, and a failed publish needs no
        # repair (the consumer simply doesn't advance).
        self._touch_gen = np.zeros(num_regions, dtype=np.int64)
        self._gen = 1

    @classmethod
    def from_statistics(cls, counts, psum, psumsq, *,
                        aggregate_fn: AggregateFn | None = None,
                        domains: Sequence[str] = ("total",)
                        ) -> "StreamingAggregator":
        """Wrap pre-aggregated sufficient statistics in an aggregator.

        Entry point for the fused device pipeline
        (:mod:`repro.core.device_pipeline`): its final carry lands here so
        merge/exchange/estimates compose identically to host-folded runs.
        ``psum``/``psumsq`` are either 1-D [R] (single-domain) or [R, C]
        channel matrices matching ``domains``.
        """
        counts = np.asarray(counts, dtype=np.int64)
        agg = cls(len(counts), aggregate_fn=aggregate_fn, domains=domains)
        agg.counts += counts
        agg.chan_psum += _as_channels(psum, agg.num_channels)
        agg.chan_psumsq += _as_channels(psumsq, agg.num_channels)
        agg._mark_touched((agg.counts != 0) | agg.chan_psum.any(axis=1)
                          | agg.chan_psumsq.any(axis=1))
        return agg

    @property
    def num_regions(self) -> int:
        return len(self.counts)

    @property
    def num_domains(self) -> int:
        return len(self.domains)

    @property
    def num_channels(self) -> int:
        return self.chan_psum.shape[1]

    @property
    def n_total(self) -> int:
        return int(self.counts.sum())

    # The scalar (total-power) statistics stay first-class: views of the
    # last channel, which at D = 1 is also the only rail — existing
    # consumers of (counts, psum, psumsq) are unaffected by the rails.
    @property
    def psum(self) -> np.ndarray:
        return self.chan_psum[:, -1]

    @psum.setter
    def psum(self, value) -> None:
        self.chan_psum[:, -1] = value

    @property
    def psumsq(self) -> np.ndarray:
        return self.chan_psumsq[:, -1]

    @psumsq.setter
    def psumsq(self, value) -> None:
        self.chan_psumsq[:, -1] = value

    @property
    def rail_psum(self) -> np.ndarray:
        """Per-domain Σpow [R, D] (at D = 1, identical to ``psum``)."""
        return self.chan_psum[:, :self.num_domains]

    @property
    def rail_psumsq(self) -> np.ndarray:
        return self.chan_psumsq[:, :self.num_domains]

    def grow(self, num_regions: int) -> None:
        """Widen the accumulators (new regions observed mid-stream)."""
        extra = num_regions - self.num_regions
        if extra < 0:
            raise ValueError("cannot shrink a StreamingAggregator")
        if extra:
            c = self.num_channels
            self.counts = np.concatenate(
                [self.counts, np.zeros(extra, np.int64)])
            self.chan_psum = np.concatenate(
                [self.chan_psum, np.zeros((extra, c), np.float64)])
            self.chan_psumsq = np.concatenate(
                [self.chan_psumsq, np.zeros((extra, c), np.float64)])
            self._touch_gen = np.concatenate(
                [self._touch_gen, np.zeros(extra, np.int64)])

    def _channels_of(self, powers: np.ndarray) -> np.ndarray:
        """Normalize a powers chunk to the [n, C] channel matrix."""
        powers = np.asarray(powers, dtype=np.float64)
        d, c = self.num_domains, self.num_channels
        if powers.ndim == 1:
            if d > 1:
                raise ValueError(
                    f"scalar powers for a {d}-domain aggregator; pass "
                    f"[n, {d}] per-rail readings")
            return powers[:, None]
        if powers.ndim != 2 or powers.shape[1] not in (d, c):
            raise ValueError(
                f"powers shape {powers.shape} matches neither [n, D={d}] "
                f"rails nor [n, C={c}] channels")
        if powers.shape[1] == c:
            return powers
        return np.concatenate(
            [powers, powers.sum(axis=1, keepdims=True)], axis=1)

    def update(self, region_ids: np.ndarray,
               powers: np.ndarray) -> "StreamingAggregator":
        """Fold one chunk into the accumulators. Returns self (chainable).

        ``powers`` is [n] (single-domain), [n, D] per-rail readings, or a
        precomputed [n, C] channel matrix.
        """
        region_ids = np.asarray(region_ids)
        if len(region_ids) == 0:
            return self
        chan = self._channels_of(powers)
        c = self.num_channels
        if c == 1 or self._agg is not estimator_mod.aggregate_samples_np:
            # Single channel, or a plugged kernel (which fuses counts
            # with its sums on device): one aggregate_fn call per
            # channel, counts taken from the first.
            for j in range(c):
                cc, s, sq = self._agg(region_ids, chan[:, j],
                                      self.num_regions)
                if j == 0:
                    self.counts += np.asarray(cc, dtype=np.int64)
                self.chan_psum[:, j] += np.asarray(s, dtype=np.float64)
                self.chan_psumsq[:, j] += np.asarray(sq, dtype=np.float64)
        else:
            # Default numpy reducer, multi-channel: share one index pass
            # — counts once, then a weighted bincount per channel
            # (aggregate_samples_np would recompute counts C times).
            r = self.num_regions
            self.counts += np.bincount(region_ids,
                                       minlength=r).astype(np.int64)
            for j in range(c):
                w = chan[:, j]
                self.chan_psum[:, j] += np.bincount(region_ids, weights=w,
                                                    minlength=r)
                self.chan_psumsq[:, j] += np.bincount(
                    region_ids, weights=w * w, minlength=r)
        self._touch_gen[region_ids] = self._gen
        return self

    def update_stream(self, chunks: Iterable[tuple[np.ndarray, np.ndarray]]
                      ) -> "StreamingAggregator":
        """Drain an iterator of (region_ids, powers) chunks."""
        for rids, pows in chunks:
            self.update(rids, pows)
        return self

    def merge(self, other: "StreamingAggregator") -> "StreamingAggregator":
        """Fold another shard's statistics into this one (associative)."""
        if other.domains != self.domains:
            raise ValueError(f"domain axis mismatch at merge: "
                             f"{other.domains} != {self.domains}")
        if other.num_regions > self.num_regions:
            self.grow(other.num_regions)
        r = other.num_regions
        self.counts[:r] += other.counts
        self.chan_psum[:r] += other.chan_psum
        self.chan_psumsq[:r] += other.chan_psumsq
        touched = np.zeros(self.num_regions, bool)
        touched[:r] = ((other.counts != 0)
                       | other.chan_psum.any(axis=1)
                       | other.chan_psumsq.any(axis=1))
        self._mark_touched(touched)
        return self

    def statistics(self) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """(counts, Σpow, Σpow²) — copies, safe to hand across hosts."""
        return self.counts.copy(), self.psum.copy(), self.psumsq.copy()

    def channel_statistics(self) -> tuple[np.ndarray, np.ndarray,
                                          np.ndarray]:
        """(counts [R], Σpow [R, C], Σpow² [R, C]) — copies."""
        return (self.counts.copy(), self.chan_psum.copy(),
                self.chan_psumsq.copy())

    def _mark_touched(self, mask: np.ndarray) -> None:
        self._touch_gen[mask] = self._gen

    def touch_generation(self) -> int:
        """Snapshot the touch clock: rows mutated *after* this call
        stamp a strictly greater generation. A consumer publishes the
        rows of :meth:`rows_touched_since`, then stores this snapshot
        as its new watermark only once the publish is durable — a
        failed publish simply doesn't advance, so nothing is lost."""
        g = self._gen
        self._gen = g + 1
        return g

    def rows_touched_since(self, gen: int) -> np.ndarray:
        """Rows touched after watermark ``gen`` (sorted indices) — a
        superset of the rows whose values actually changed, which is
        all the delta-spill contract needs. Non-destructive: any number
        of consumers read independently with their own watermarks."""
        return np.flatnonzero(self._touch_gen > gen)

    def estimates(self, t_exec: float, names: Sequence[str], *,
                  alpha: float = 0.05, drop_empty: bool = True,
                  coverage=None) -> EstimateSet:
        """Finalize into an EstimateSet (vectorized Eq. 4-16).

        ``coverage`` attaches degraded-gather provenance (see
        ``exchange.GatherResult``) so reports disclose partial fleets.
        """
        d = self.num_domains
        return estimates_from_statistics(
            self.counts, self.psum, self.psumsq, t_exec, names, alpha=alpha,
            drop_empty=drop_empty,
            rail_psum=self.rail_psum if d > 1 else None,
            rail_psumsq=self.rail_psumsq if d > 1 else None,
            domains=self.domains if d > 1 else None, coverage=coverage)


class CombinationInterner:
    """Incremental hash-interning of per-sample worker region vectors.

    Each :meth:`encode` call deduplicates its chunk locally (``np.unique``
    over chunk rows only — the sort is bounded by chunk size) and interns
    the chunk's unique rows into a persistent dict keyed by row bytes.
    Combination ids are assigned in first-appearance order, so ids are
    stream-order dependent but the (id → tuple) table always maps every
    sample to the same combination tuple as the one-shot path.
    """

    def __init__(self):
        self._table: dict[bytes, int] = {}
        self._combos: list[tuple[int, ...]] = []
        self._width: int | None = None

    def __len__(self) -> int:
        return len(self._combos)

    @property
    def combos(self) -> list[tuple[int, ...]]:
        """Combination tuples indexed by combination id."""
        return list(self._combos)

    def combo_matrix(self) -> np.ndarray:
        """The key table as an int64 [k, width] matrix (shard wire format).

        This is what a shard serializes: its *local* id space is the row
        order, and receivers dedupe lazily by interning the rows into
        their own table (:meth:`intern_rows`) at merge time.
        """
        w = self._width if self._width is not None else 0
        if not self._combos:
            return np.empty((0, w), dtype=np.int64)
        return np.asarray(self._combos, dtype=np.int64)

    def intern_rows(self, mat: np.ndarray) -> np.ndarray:
        """Intern each row of an int64 [k, width] matrix; returns ids [k].

        The lazy cross-shard dedup primitive: another shard's key table
        maps local id ``i`` → union id ``intern_rows(table)[i]``. Rows are
        hashed as contiguous bytes (no per-row tuple boxing on re-intern).
        """
        mat = np.ascontiguousarray(np.asarray(mat), dtype=np.int64)
        if mat.ndim != 2:
            raise ValueError(f"expected [k, workers]; got shape {mat.shape}")
        if len(mat):
            if self._width is None:
                self._width = mat.shape[1]
            elif mat.shape[1] != self._width:
                raise ValueError(f"worker count mismatch at merge: "
                                 f"{mat.shape[1]} != {self._width}")
        table = self._table
        combos = self._combos
        ids = np.empty(len(mat), dtype=np.int64)
        for k in range(len(mat)):
            key = mat[k].tobytes()
            cid = table.get(key)
            if cid is None:
                cid = len(combos)
                table[key] = cid
                combos.append(tuple(int(v) for v in mat[k]))
            ids[k] = cid
        return ids

    def intern(self, combo: tuple[int, ...]) -> int:
        """Intern a single combination tuple; returns its id."""
        key = np.asarray(combo, dtype=np.int64).tobytes()
        cid = self._table.get(key)
        if cid is None:
            cid = len(self._combos)
            self._table[key] = cid
            self._combos.append(tuple(int(v) for v in combo))
        return cid

    def encode(self, region_id_matrix: np.ndarray) -> np.ndarray:
        """Map one chunk [c, workers] of region-id vectors to comb ids [c]."""
        mat = np.ascontiguousarray(np.asarray(region_id_matrix),
                                   dtype=np.int64)
        if mat.ndim != 2:
            raise ValueError(f"expected [n, workers]; got shape {mat.shape}")
        if self._width is None:
            self._width = mat.shape[1]
        elif mat.shape[1] != self._width:
            raise ValueError(f"worker count changed mid-stream: "
                             f"{mat.shape[1]} != {self._width}")
        if len(mat) == 0:
            return np.empty(0, dtype=np.int64)
        uniq, inverse = np.unique(mat, axis=0, return_inverse=True)
        # Hash the contiguous row bytes directly; the tuple form is only
        # materialized on first insertion (steady state re-interns cost a
        # dict lookup per distinct row, no boxing).
        local_to_global = self.intern_rows(uniq)
        return local_to_global[inverse.reshape(-1)]


class StreamingCombinationAggregator:
    """§4.4 combination attribution over chunked multi-worker streams.

    Composes a :class:`CombinationInterner` (growing combination id space)
    with a :class:`StreamingAggregator` that widens as new combinations
    appear. ``merge()`` remaps the other shard's combination ids through
    this shard's interner, so multi-host reductions agree with a single
    stream over the concatenated data.
    """

    def __init__(self, *, aggregate_fn: AggregateFn | None = None,
                 domains: Sequence[str] = ("total",)):
        self.interner = CombinationInterner()
        self.agg = StreamingAggregator(0, aggregate_fn=aggregate_fn,
                                       domains=domains)

    @classmethod
    def from_table(cls, combo_matrix: np.ndarray, counts: np.ndarray,
                   psum: np.ndarray, psumsq: np.ndarray, *,
                   aggregate_fn: AggregateFn | None = None,
                   domains: Sequence[str] = ("total",)
                   ) -> "StreamingCombinationAggregator":
        """Build from a key table + statistics (device-pipeline results,
        deserialized shards): ids are assigned in the table's row order,
        so a table in interner order round-trips exactly. ``psum``/
        ``psumsq`` are 1-D (single-domain) or [k, C] channel matrices."""
        agg = cls(aggregate_fn=aggregate_fn, domains=domains)
        agg.merge_table(combo_matrix, counts, psum, psumsq)
        return agg

    @property
    def n_total(self) -> int:
        return self.agg.n_total

    @property
    def domains(self) -> tuple[str, ...]:
        return self.agg.domains

    def touch_generation(self) -> int:
        """Delegates the spiller's touched-row contract to the inner
        statistics aggregator (combination rows only ever append)."""
        return self.agg.touch_generation()

    def rows_touched_since(self, gen: int) -> np.ndarray:
        return self.agg.rows_touched_since(gen)

    def update(self, region_id_matrix: np.ndarray,
               powers: np.ndarray) -> "StreamingCombinationAggregator":
        cids = self.interner.encode(region_id_matrix)
        if len(self.interner) > self.agg.num_regions:
            self.agg.grow(len(self.interner))
        self.agg.update(cids, powers)
        return self

    def update_stream(self, chunks: Iterable[tuple[np.ndarray, np.ndarray]]
                      ) -> "StreamingCombinationAggregator":
        for mat, pows in chunks:
            self.update(mat, pows)
        return self

    def merge_table(self, combo_matrix: np.ndarray, counts: np.ndarray,
                    psum: np.ndarray, psumsq: np.ndarray
                    ) -> "StreamingCombinationAggregator":
        """Fold a shard given by its raw key table + statistics.

        The cross-host merge primitive (lazy id dedup): ``combo_matrix``
        is the shard's local id space in row order, so its local id ``i``
        remaps to ``intern_rows(combo_matrix)[i]`` in the union space.
        Entry point for deserialized shards (:mod:`repro.core.exchange`);
        :meth:`merge` routes through it. Unseen rows are appended in the
        shard's local order, so any left-to-right reduction tree assigns
        the same union ids as one aggregator fed the concatenated stream.
        """
        remap = self.interner.intern_rows(combo_matrix)
        if len(self.interner) > self.agg.num_regions:
            self.agg.grow(len(self.interner))
        if len(remap):
            c = self.agg.num_channels
            np.add.at(self.agg.counts, remap, np.asarray(counts, np.int64))
            np.add.at(self.agg.chan_psum, remap, _as_channels(psum, c))
            np.add.at(self.agg.chan_psumsq, remap, _as_channels(psumsq, c))
            self.agg._touch_gen[remap] = self.agg._gen
        return self

    def merge(self, other: "StreamingCombinationAggregator"
              ) -> "StreamingCombinationAggregator":
        if other.domains != self.domains:
            raise ValueError(f"domain axis mismatch at merge: "
                             f"{other.domains} != {self.domains}")
        return self.merge_table(other.interner.combo_matrix(),
                                other.agg.counts, other.agg.chan_psum,
                                other.agg.chan_psumsq)

    def estimates(self, t_exec: float, names: Sequence[str], *,
                  alpha: float = 0.05, coverage=None
                  ) -> tuple[EstimateSet, list[tuple[int, ...]]]:
        """Finalize into (combination EstimateSet, combination tuples)."""
        comb_names = combination_names_from_matrix(
            self.interner.combo_matrix(), names)
        est = self.agg.estimates(t_exec, comb_names, alpha=alpha,
                                 coverage=coverage)
        return est, self.interner.combos


def stream_estimate(chunks: Iterable[tuple[np.ndarray, np.ndarray]],
                    t_exec: float, names: Sequence[str], *,
                    alpha: float = 0.05,
                    aggregate_fn: AggregateFn | None = None) -> EstimateSet:
    """One-call streaming estimation: fold chunks, then build estimates."""
    agg = StreamingAggregator(len(names), aggregate_fn=aggregate_fn)
    agg.update_stream(chunks)
    return agg.estimates(t_exec, names, alpha=alpha)
