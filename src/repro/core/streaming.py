"""Streaming fleet-scale sample aggregation (constant extra memory).

ALEA's accuracy comes from sample volume: a fleet sampling at the paper's
~10 ms period (let alone PowerSensor3-class multi-kHz sensors) produces
billions of (region_id, power) samples — far more than the one-shot
``np.bincount`` path in :mod:`repro.core.estimator` can hold in memory.
This module makes the estimator pipeline *streaming*:

* :class:`StreamingAggregator` folds sample chunks of any size into the
  per-region sufficient statistics (counts, Σpow, Σpow²) behind the same
  pluggable ``AggregateFn`` seam the one-shot path uses, so the Pallas
  ``kernels/sample_attr`` kernel (region-tiled, chunked) drops in per
  block. ``merge()`` reduces shards from multiple hosts — the statistics
  are associative+commutative, so any reduction tree is exact.

* :class:`CombinationInterner` replaces ``encode_combinations``'s
  full-matrix ``np.unique(axis=0)`` with incremental hash-interning of
  per-worker region vectors: each chunk is deduplicated locally (sort
  bounded by chunk size) and its unique rows interned into a dict, so the
  multi-worker path runs in one pass with O(chunk + distinct combos)
  memory and no re-sort of previously seen data.

* :class:`StreamingCombinationAggregator` composes the two for §4.4
  combination-level attribution over chunked multi-worker streams.

Peak extra memory is O(chunk + R) instead of O(n); see
``benchmarks/aggregation.py`` for the throughput trajectory.
"""

from __future__ import annotations

from typing import Iterable, Sequence

import numpy as np

from repro.core import estimator as estimator_mod
from repro.core.estimator import (AggregateFn, EstimateSet,
                                  combination_names_from_matrix,
                                  estimates_from_statistics)

__all__ = [
    "DEFAULT_CHUNK",
    "StreamingAggregator",
    "CombinationInterner",
    "StreamingCombinationAggregator",
    "stream_estimate",
]

DEFAULT_CHUNK = 65536


class StreamingAggregator:
    """Constant-memory accumulator of per-region sample statistics.

    Consumes (region_ids, powers) chunks of any size via :meth:`update`;
    holds exactly three [R] accumulators (counts int64, Σpow f64, Σpow² f64).
    ``aggregate_fn`` is the per-chunk reducer — defaults to the numpy
    reference, swap in ``kernels.sample_attr.ops.chunked_aggregate_fn`` for
    the Pallas path. :meth:`merge` combines shards (multi-host reduction).
    """

    def __init__(self, num_regions: int, *,
                 aggregate_fn: AggregateFn | None = None):
        if num_regions < 0:
            raise ValueError(f"num_regions must be >= 0; got {num_regions}")
        self._agg = aggregate_fn or estimator_mod.aggregate_samples_np
        self.counts = np.zeros(num_regions, dtype=np.int64)
        self.psum = np.zeros(num_regions, dtype=np.float64)
        self.psumsq = np.zeros(num_regions, dtype=np.float64)

    @classmethod
    def from_statistics(cls, counts, psum, psumsq, *,
                        aggregate_fn: AggregateFn | None = None
                        ) -> "StreamingAggregator":
        """Wrap pre-aggregated sufficient statistics in an aggregator.

        Entry point for the fused device pipeline
        (:mod:`repro.core.device_pipeline`): its final carry lands here so
        merge/exchange/estimates compose identically to host-folded runs.
        """
        counts = np.asarray(counts, dtype=np.int64)
        agg = cls(len(counts), aggregate_fn=aggregate_fn)
        agg.counts += counts
        agg.psum += np.asarray(psum, dtype=np.float64)
        agg.psumsq += np.asarray(psumsq, dtype=np.float64)
        return agg

    @property
    def num_regions(self) -> int:
        return len(self.counts)

    @property
    def n_total(self) -> int:
        return int(self.counts.sum())

    def grow(self, num_regions: int) -> None:
        """Widen the accumulators (new regions observed mid-stream)."""
        extra = num_regions - self.num_regions
        if extra < 0:
            raise ValueError("cannot shrink a StreamingAggregator")
        if extra:
            self.counts = np.concatenate(
                [self.counts, np.zeros(extra, np.int64)])
            self.psum = np.concatenate(
                [self.psum, np.zeros(extra, np.float64)])
            self.psumsq = np.concatenate(
                [self.psumsq, np.zeros(extra, np.float64)])

    def update(self, region_ids: np.ndarray,
               powers: np.ndarray) -> "StreamingAggregator":
        """Fold one chunk into the accumulators. Returns self (chainable)."""
        region_ids = np.asarray(region_ids)
        powers = np.asarray(powers)
        if len(region_ids) == 0:
            return self
        c, s, sq = self._agg(region_ids, powers, self.num_regions)
        self.counts += np.asarray(c, dtype=np.int64)
        self.psum += np.asarray(s, dtype=np.float64)
        self.psumsq += np.asarray(sq, dtype=np.float64)
        return self

    def update_stream(self, chunks: Iterable[tuple[np.ndarray, np.ndarray]]
                      ) -> "StreamingAggregator":
        """Drain an iterator of (region_ids, powers) chunks."""
        for rids, pows in chunks:
            self.update(rids, pows)
        return self

    def merge(self, other: "StreamingAggregator") -> "StreamingAggregator":
        """Fold another shard's statistics into this one (associative)."""
        if other.num_regions > self.num_regions:
            self.grow(other.num_regions)
        r = other.num_regions
        self.counts[:r] += other.counts
        self.psum[:r] += other.psum
        self.psumsq[:r] += other.psumsq
        return self

    def statistics(self) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """(counts, Σpow, Σpow²) — copies, safe to hand across hosts."""
        return self.counts.copy(), self.psum.copy(), self.psumsq.copy()

    def estimates(self, t_exec: float, names: Sequence[str], *,
                  alpha: float = 0.05, drop_empty: bool = True) -> EstimateSet:
        """Finalize into an EstimateSet (vectorized Eq. 4-16)."""
        return estimates_from_statistics(self.counts, self.psum, self.psumsq,
                                         t_exec, names, alpha=alpha,
                                         drop_empty=drop_empty)


class CombinationInterner:
    """Incremental hash-interning of per-sample worker region vectors.

    Each :meth:`encode` call deduplicates its chunk locally (``np.unique``
    over chunk rows only — the sort is bounded by chunk size) and interns
    the chunk's unique rows into a persistent dict keyed by row bytes.
    Combination ids are assigned in first-appearance order, so ids are
    stream-order dependent but the (id → tuple) table always maps every
    sample to the same combination tuple as the one-shot path.
    """

    def __init__(self):
        self._table: dict[bytes, int] = {}
        self._combos: list[tuple[int, ...]] = []
        self._width: int | None = None

    def __len__(self) -> int:
        return len(self._combos)

    @property
    def combos(self) -> list[tuple[int, ...]]:
        """Combination tuples indexed by combination id."""
        return list(self._combos)

    def combo_matrix(self) -> np.ndarray:
        """The key table as an int64 [k, width] matrix (shard wire format).

        This is what a shard serializes: its *local* id space is the row
        order, and receivers dedupe lazily by interning the rows into
        their own table (:meth:`intern_rows`) at merge time.
        """
        w = self._width if self._width is not None else 0
        if not self._combos:
            return np.empty((0, w), dtype=np.int64)
        return np.asarray(self._combos, dtype=np.int64)

    def intern_rows(self, mat: np.ndarray) -> np.ndarray:
        """Intern each row of an int64 [k, width] matrix; returns ids [k].

        The lazy cross-shard dedup primitive: another shard's key table
        maps local id ``i`` → union id ``intern_rows(table)[i]``. Rows are
        hashed as contiguous bytes (no per-row tuple boxing on re-intern).
        """
        mat = np.ascontiguousarray(np.asarray(mat), dtype=np.int64)
        if mat.ndim != 2:
            raise ValueError(f"expected [k, workers]; got shape {mat.shape}")
        if len(mat):
            if self._width is None:
                self._width = mat.shape[1]
            elif mat.shape[1] != self._width:
                raise ValueError(f"worker count mismatch at merge: "
                                 f"{mat.shape[1]} != {self._width}")
        table = self._table
        combos = self._combos
        ids = np.empty(len(mat), dtype=np.int64)
        for k in range(len(mat)):
            key = mat[k].tobytes()
            cid = table.get(key)
            if cid is None:
                cid = len(combos)
                table[key] = cid
                combos.append(tuple(int(v) for v in mat[k]))
            ids[k] = cid
        return ids

    def intern(self, combo: tuple[int, ...]) -> int:
        """Intern a single combination tuple; returns its id."""
        key = np.asarray(combo, dtype=np.int64).tobytes()
        cid = self._table.get(key)
        if cid is None:
            cid = len(self._combos)
            self._table[key] = cid
            self._combos.append(tuple(int(v) for v in combo))
        return cid

    def encode(self, region_id_matrix: np.ndarray) -> np.ndarray:
        """Map one chunk [c, workers] of region-id vectors to comb ids [c]."""
        mat = np.ascontiguousarray(np.asarray(region_id_matrix),
                                   dtype=np.int64)
        if mat.ndim != 2:
            raise ValueError(f"expected [n, workers]; got shape {mat.shape}")
        if self._width is None:
            self._width = mat.shape[1]
        elif mat.shape[1] != self._width:
            raise ValueError(f"worker count changed mid-stream: "
                             f"{mat.shape[1]} != {self._width}")
        if len(mat) == 0:
            return np.empty(0, dtype=np.int64)
        uniq, inverse = np.unique(mat, axis=0, return_inverse=True)
        # Hash the contiguous row bytes directly; the tuple form is only
        # materialized on first insertion (steady state re-interns cost a
        # dict lookup per distinct row, no boxing).
        local_to_global = self.intern_rows(uniq)
        return local_to_global[inverse.reshape(-1)]


class StreamingCombinationAggregator:
    """§4.4 combination attribution over chunked multi-worker streams.

    Composes a :class:`CombinationInterner` (growing combination id space)
    with a :class:`StreamingAggregator` that widens as new combinations
    appear. ``merge()`` remaps the other shard's combination ids through
    this shard's interner, so multi-host reductions agree with a single
    stream over the concatenated data.
    """

    def __init__(self, *, aggregate_fn: AggregateFn | None = None):
        self.interner = CombinationInterner()
        self.agg = StreamingAggregator(0, aggregate_fn=aggregate_fn)

    @classmethod
    def from_table(cls, combo_matrix: np.ndarray, counts: np.ndarray,
                   psum: np.ndarray, psumsq: np.ndarray, *,
                   aggregate_fn: AggregateFn | None = None
                   ) -> "StreamingCombinationAggregator":
        """Build from a key table + statistics (device-pipeline results,
        deserialized shards): ids are assigned in the table's row order,
        so a table in interner order round-trips exactly."""
        agg = cls(aggregate_fn=aggregate_fn)
        agg.merge_table(combo_matrix, counts, psum, psumsq)
        return agg

    @property
    def n_total(self) -> int:
        return self.agg.n_total

    def update(self, region_id_matrix: np.ndarray,
               powers: np.ndarray) -> "StreamingCombinationAggregator":
        cids = self.interner.encode(region_id_matrix)
        if len(self.interner) > self.agg.num_regions:
            self.agg.grow(len(self.interner))
        self.agg.update(cids, powers)
        return self

    def update_stream(self, chunks: Iterable[tuple[np.ndarray, np.ndarray]]
                      ) -> "StreamingCombinationAggregator":
        for mat, pows in chunks:
            self.update(mat, pows)
        return self

    def merge_table(self, combo_matrix: np.ndarray, counts: np.ndarray,
                    psum: np.ndarray, psumsq: np.ndarray
                    ) -> "StreamingCombinationAggregator":
        """Fold a shard given by its raw key table + statistics.

        The cross-host merge primitive (lazy id dedup): ``combo_matrix``
        is the shard's local id space in row order, so its local id ``i``
        remaps to ``intern_rows(combo_matrix)[i]`` in the union space.
        Entry point for deserialized shards (:mod:`repro.core.exchange`);
        :meth:`merge` routes through it. Unseen rows are appended in the
        shard's local order, so any left-to-right reduction tree assigns
        the same union ids as one aggregator fed the concatenated stream.
        """
        remap = self.interner.intern_rows(combo_matrix)
        if len(self.interner) > self.agg.num_regions:
            self.agg.grow(len(self.interner))
        if len(remap):
            np.add.at(self.agg.counts, remap, np.asarray(counts, np.int64))
            np.add.at(self.agg.psum, remap, np.asarray(psum, np.float64))
            np.add.at(self.agg.psumsq, remap,
                      np.asarray(psumsq, np.float64))
        return self

    def merge(self, other: "StreamingCombinationAggregator"
              ) -> "StreamingCombinationAggregator":
        return self.merge_table(other.interner.combo_matrix(),
                                other.agg.counts, other.agg.psum,
                                other.agg.psumsq)

    def estimates(self, t_exec: float, names: Sequence[str], *,
                  alpha: float = 0.05
                  ) -> tuple[EstimateSet, list[tuple[int, ...]]]:
        """Finalize into (combination EstimateSet, combination tuples)."""
        comb_names = combination_names_from_matrix(
            self.interner.combo_matrix(), names)
        est = self.agg.estimates(t_exec, comb_names, alpha=alpha)
        return est, self.interner.combos


def stream_estimate(chunks: Iterable[tuple[np.ndarray, np.ndarray]],
                    t_exec: float, names: Sequence[str], *,
                    alpha: float = 0.05,
                    aggregate_fn: AggregateFn | None = None) -> EstimateSet:
    """One-call streaming estimation: fold chunks, then build estimates."""
    agg = StreamingAggregator(len(names), aggregate_fn=aggregate_fn)
    agg.update_stream(chunks)
    return agg.estimates(t_exec, names, alpha=alpha)
