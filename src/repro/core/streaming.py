"""Streaming fleet-scale sample aggregation (constant extra memory).

ALEA's accuracy comes from sample volume: a fleet sampling at the paper's
~10 ms period (let alone PowerSensor3-class multi-kHz sensors) produces
billions of (region_id, power) samples — far more than the one-shot
``np.bincount`` path in :mod:`repro.core.estimator` can hold in memory.
This module makes the estimator pipeline *streaming*:

* :class:`StreamingAggregator` folds sample chunks of any size into the
  per-region sufficient statistics (counts, Σpow, Σpow²) behind the same
  pluggable ``AggregateFn`` seam the one-shot path uses, so the Pallas
  ``kernels/sample_attr`` kernel (region-tiled, chunked) drops in per
  block. ``merge()`` reduces shards from multiple hosts — the statistics
  are associative+commutative, so any reduction tree is exact.

* :class:`CombinationInterner` replaces ``encode_combinations``'s
  full-matrix ``np.unique(axis=0)`` with incremental hash-interning of
  per-worker region vectors: each chunk is deduplicated locally (sort
  bounded by chunk size) and its unique rows interned into a dict, so the
  multi-worker path runs in one pass with O(chunk + distinct combos)
  memory and no re-sort of previously seen data.

* :class:`StreamingCombinationAggregator` composes the two for §4.4
  combination-level attribution over chunked multi-worker streams.

Statistics carry an optional power-rail domain axis: a ``domains``
axis of D rails stores ``[R, C]`` channel matrices (the rails plus, for
D > 1, the total-power channel — :func:`channels_for`), with the scalar
``psum``/``psumsq`` views unchanged for single-domain streams. The
aggregators also maintain generation-stamped touched-row tracking the
delta spiller (:class:`repro.core.exchange.ShardSpiller`) diffs against
(:meth:`StreamingAggregator.rows_touched_since` — non-destructive, so
any number of spillers consume one aggregator independently).

Peak extra memory is O(chunk + R·C) instead of O(n); see
``benchmarks/aggregation.py`` for the throughput trajectory.
"""

from __future__ import annotations

from typing import Iterable, Sequence

import numpy as np

from repro.core import estimator as estimator_mod
from repro.core import sketch as sketch_mod
from repro.core.estimator import (AggregateFn, EstimateSet,
                                  combination_names_from_matrix,
                                  estimates_from_statistics)
from repro.core.faults import SketchConfigError
from repro.core.sketch import HashRange, combo_hashes

__all__ = [
    "DEFAULT_CHUNK",
    "channels_for",
    "StreamingAggregator",
    "CombinationInterner",
    "StreamingCombinationAggregator",
    "stream_estimate",
]

DEFAULT_CHUNK = 65536

_I64MAX = np.iinfo(np.int64).max


def _as_hash_range(hr) -> HashRange | None:
    """Normalize a hash-range argument (HashRange, (lo, hi) pair, None)."""
    if hr is None or isinstance(hr, HashRange):
        return hr
    lo, hi = hr
    return HashRange(int(lo), int(hi))


def _as_channels(arr, c: int) -> np.ndarray:
    """Normalize pre-aggregated Σ statistics to the [R, C] channel layout
    (1-D arrays are the single-channel scalar form)."""
    arr = np.asarray(arr, dtype=np.float64)
    if arr.ndim == 1:
        if c != 1:
            raise ValueError(
                f"1-D statistics for a {c}-channel aggregator; pass the "
                f"[rows, {c}] channel matrix")
        return arr[:, None]
    if arr.ndim == 2 and arr.shape[1] == c:
        return arr
    raise ValueError(f"statistics shape {arr.shape} does not match "
                     f"{c} channels")


def channels_for(domains: Sequence[str] | int) -> int:
    """Statistic channels for a domain axis (names or rail count): the
    rails, plus a dedicated total-power channel when D > 1 (Σpow² of the
    total is not derivable from per-rail Σpow² — squares don't sum
    across rails — and the estimator's power CI needs it). D = 1 is the
    pre-rail scalar layout: the single rail *is* the total.

    This is THE channel-layout rule — the wire schema
    (:mod:`repro.core.exchange`), the device-pipeline carries and the
    estimator split all derive from it; change it nowhere else.
    """
    d = domains if isinstance(domains, int) else len(domains)
    return d + (1 if d > 1 else 0)


class StreamingAggregator:
    """Constant-memory accumulator of per-region sample statistics.

    Consumes (region_ids, powers) chunks of any size via :meth:`update`;
    holds a [R] ``counts`` int64 accumulator plus [R, C] ``chan_psum`` /
    ``chan_psumsq`` f64 channel accumulators, where the channels are the
    power-rail ``domains`` plus — when multi-domain — the total (see
    :func:`channels_for`). The default single-domain aggregator stores
    exactly the pre-rail (counts, Σpow, Σpow²) triple; ``psum``/``psumsq``
    remain its scalar-total views. ``aggregate_fn`` is the per-chunk
    reducer — defaults to the numpy reference, swap in
    ``kernels.sample_attr.ops.chunked_aggregate_fn`` for the Pallas path
    (applied per channel). :meth:`merge` combines shards (multi-host
    reduction; domain axes must match).

    The aggregator also maintains generation-stamped *touched-row
    tracking* (:meth:`rows_touched_since` / :meth:`touch_generation`):
    every row whose statistics may have changed since a consumer's
    watermark. :class:`repro.core.exchange.ShardSpiller` diffs against
    it instead of deep-copying the full packed shard each publish,
    dropping the O(rows) per-epoch snapshot — and because reads are
    non-destructive, independent spillers can publish one aggregator
    to different destinations without corrupting each other's chains.
    """

    def __init__(self, num_regions: int, *,
                 aggregate_fn: AggregateFn | None = None,
                 domains: Sequence[str] = ("total",)):
        if num_regions < 0:
            raise ValueError(f"num_regions must be >= 0; got {num_regions}")
        self._agg = aggregate_fn or estimator_mod.aggregate_samples_np
        self.domains = tuple(domains)
        if not self.domains:
            raise ValueError("domains must name at least one rail")
        c = channels_for(self.domains)
        self.counts = np.zeros(num_regions, dtype=np.int64)
        self.chan_psum = np.zeros((num_regions, c), dtype=np.float64)
        self.chan_psumsq = np.zeros((num_regions, c), dtype=np.float64)
        # Touched-row tracking, generation-based: every mutation stamps
        # its rows with the current generation, and each consumer (a
        # ShardSpiller) remembers the generation it last published —
        # so multiple independent consumers of one aggregator never
        # steal each other's change sets, and a failed publish needs no
        # repair (the consumer simply doesn't advance).
        self._touch_gen = np.zeros(num_regions, dtype=np.int64)
        self._gen = 1

    @classmethod
    def from_statistics(cls, counts, psum, psumsq, *,
                        aggregate_fn: AggregateFn | None = None,
                        domains: Sequence[str] = ("total",)
                        ) -> "StreamingAggregator":
        """Wrap pre-aggregated sufficient statistics in an aggregator.

        Entry point for the fused device pipeline
        (:mod:`repro.core.device_pipeline`): its final carry lands here so
        merge/exchange/estimates compose identically to host-folded runs.
        ``psum``/``psumsq`` are either 1-D [R] (single-domain) or [R, C]
        channel matrices matching ``domains``.
        """
        counts = np.asarray(counts, dtype=np.int64)
        agg = cls(len(counts), aggregate_fn=aggregate_fn, domains=domains)
        agg.counts += counts
        agg.chan_psum += _as_channels(psum, agg.num_channels)
        agg.chan_psumsq += _as_channels(psumsq, agg.num_channels)
        agg._mark_touched((agg.counts != 0) | agg.chan_psum.any(axis=1)
                          | agg.chan_psumsq.any(axis=1))
        return agg

    @property
    def num_regions(self) -> int:
        return len(self.counts)

    @property
    def num_domains(self) -> int:
        return len(self.domains)

    @property
    def num_channels(self) -> int:
        return self.chan_psum.shape[1]

    @property
    def n_total(self) -> int:
        return int(self.counts.sum())

    # The scalar (total-power) statistics stay first-class: views of the
    # last channel, which at D = 1 is also the only rail — existing
    # consumers of (counts, psum, psumsq) are unaffected by the rails.
    @property
    def psum(self) -> np.ndarray:
        return self.chan_psum[:, -1]

    @psum.setter
    def psum(self, value) -> None:
        self.chan_psum[:, -1] = value

    @property
    def psumsq(self) -> np.ndarray:
        return self.chan_psumsq[:, -1]

    @psumsq.setter
    def psumsq(self, value) -> None:
        self.chan_psumsq[:, -1] = value

    @property
    def rail_psum(self) -> np.ndarray:
        """Per-domain Σpow [R, D] (at D = 1, identical to ``psum``)."""
        return self.chan_psum[:, :self.num_domains]

    @property
    def rail_psumsq(self) -> np.ndarray:
        return self.chan_psumsq[:, :self.num_domains]

    def grow(self, num_regions: int) -> None:
        """Widen the accumulators (new regions observed mid-stream)."""
        extra = num_regions - self.num_regions
        if extra < 0:
            raise ValueError("cannot shrink a StreamingAggregator")
        if extra:
            c = self.num_channels
            self.counts = np.concatenate(
                [self.counts, np.zeros(extra, np.int64)])
            self.chan_psum = np.concatenate(
                [self.chan_psum, np.zeros((extra, c), np.float64)])
            self.chan_psumsq = np.concatenate(
                [self.chan_psumsq, np.zeros((extra, c), np.float64)])
            self._touch_gen = np.concatenate(
                [self._touch_gen, np.zeros(extra, np.int64)])

    def _channels_of(self, powers: np.ndarray) -> np.ndarray:
        """Normalize a powers chunk to the [n, C] channel matrix."""
        powers = np.asarray(powers, dtype=np.float64)
        d, c = self.num_domains, self.num_channels
        if powers.ndim == 1:
            if d > 1:
                raise ValueError(
                    f"scalar powers for a {d}-domain aggregator; pass "
                    f"[n, {d}] per-rail readings")
            return powers[:, None]
        if powers.ndim != 2 or powers.shape[1] not in (d, c):
            raise ValueError(
                f"powers shape {powers.shape} matches neither [n, D={d}] "
                f"rails nor [n, C={c}] channels")
        if powers.shape[1] == c:
            return powers
        return np.concatenate(
            [powers, powers.sum(axis=1, keepdims=True)], axis=1)

    def update(self, region_ids: np.ndarray,
               powers: np.ndarray) -> "StreamingAggregator":
        """Fold one chunk into the accumulators. Returns self (chainable).

        ``powers`` is [n] (single-domain), [n, D] per-rail readings, or a
        precomputed [n, C] channel matrix.
        """
        region_ids = np.asarray(region_ids)
        if len(region_ids) == 0:
            return self
        chan = self._channels_of(powers)
        c = self.num_channels
        if c == 1 or self._agg is not estimator_mod.aggregate_samples_np:
            # Single channel, or a plugged kernel (which fuses counts
            # with its sums on device): one aggregate_fn call per
            # channel, counts taken from the first.
            for j in range(c):
                cc, s, sq = self._agg(region_ids, chan[:, j],
                                      self.num_regions)
                if j == 0:
                    self.counts += np.asarray(cc, dtype=np.int64)
                self.chan_psum[:, j] += np.asarray(s, dtype=np.float64)
                self.chan_psumsq[:, j] += np.asarray(sq, dtype=np.float64)
        else:
            # Default numpy reducer, multi-channel: share one index pass
            # — counts once, then a weighted bincount per channel
            # (aggregate_samples_np would recompute counts C times).
            r = self.num_regions
            self.counts += np.bincount(region_ids,
                                       minlength=r).astype(np.int64)
            for j in range(c):
                w = chan[:, j]
                self.chan_psum[:, j] += np.bincount(region_ids, weights=w,
                                                    minlength=r)
                self.chan_psumsq[:, j] += np.bincount(
                    region_ids, weights=w * w, minlength=r)
        self._touch_gen[region_ids] = self._gen
        return self

    def update_stream(self, chunks: Iterable[tuple[np.ndarray, np.ndarray]]
                      ) -> "StreamingAggregator":
        """Drain an iterator of (region_ids, powers) chunks."""
        for rids, pows in chunks:
            self.update(rids, pows)
        return self

    def merge(self, other: "StreamingAggregator") -> "StreamingAggregator":
        """Fold another shard's statistics into this one (associative)."""
        if other.domains != self.domains:
            raise ValueError(f"domain axis mismatch at merge: "
                             f"{other.domains} != {self.domains}")
        if other.num_regions > self.num_regions:
            self.grow(other.num_regions)
        r = other.num_regions
        self.counts[:r] += other.counts
        self.chan_psum[:r] += other.chan_psum
        self.chan_psumsq[:r] += other.chan_psumsq
        touched = np.zeros(self.num_regions, bool)
        touched[:r] = ((other.counts != 0)
                       | other.chan_psum.any(axis=1)
                       | other.chan_psumsq.any(axis=1))
        self._mark_touched(touched)
        return self

    def statistics(self) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """(counts, Σpow, Σpow²) — copies, safe to hand across hosts."""
        return self.counts.copy(), self.psum.copy(), self.psumsq.copy()

    def channel_statistics(self) -> tuple[np.ndarray, np.ndarray,
                                          np.ndarray]:
        """(counts [R], Σpow [R, C], Σpow² [R, C]) — copies."""
        return (self.counts.copy(), self.chan_psum.copy(),
                self.chan_psumsq.copy())

    def _mark_touched(self, mask: np.ndarray) -> None:
        self._touch_gen[mask] = self._gen

    def touch_generation(self) -> int:
        """Snapshot the touch clock: rows mutated *after* this call
        stamp a strictly greater generation. A consumer publishes the
        rows of :meth:`rows_touched_since`, then stores this snapshot
        as its new watermark only once the publish is durable — a
        failed publish simply doesn't advance, so nothing is lost."""
        g = self._gen
        self._gen = g + 1
        return g

    def rows_touched_since(self, gen: int) -> np.ndarray:
        """Rows touched after watermark ``gen`` (sorted indices) — a
        superset of the rows whose values actually changed, which is
        all the delta-spill contract needs. Non-destructive: any number
        of consumers read independently with their own watermarks."""
        return np.flatnonzero(self._touch_gen > gen)

    def estimates(self, t_exec: float, names: Sequence[str], *,
                  alpha: float = 0.05, drop_empty: bool = True,
                  coverage=None, tail=None) -> EstimateSet:
        """Finalize into an EstimateSet (vectorized Eq. 4-16).

        ``coverage`` attaches degraded-gather provenance (see
        ``exchange.GatherResult``) so reports disclose partial fleets;
        ``tail`` attaches bounded-mode fold disclosure (the combination
        aggregator passes its :meth:`tail_info`).
        """
        d = self.num_domains
        return estimates_from_statistics(
            self.counts, self.psum, self.psumsq, t_exec, names, alpha=alpha,
            drop_empty=drop_empty,
            rail_psum=self.rail_psum if d > 1 else None,
            rail_psumsq=self.rail_psumsq if d > 1 else None,
            domains=self.domains if d > 1 else None, coverage=coverage,
            tail=tail)


class CombinationInterner:
    """Incremental hash-interning of per-sample worker region vectors.

    Each :meth:`encode` call deduplicates its chunk locally (``np.unique``
    over chunk rows only — the sort is bounded by chunk size) and interns
    the chunk's unique rows into a persistent dict keyed by row bytes.
    Combination ids are assigned in first-appearance order, so ids are
    stream-order dependent but the (id → tuple) table always maps every
    sample to the same combination tuple as the one-shot path.

    The interner also keeps first-class *pressure counters* so operators
    can see when the exact path is about to blow up: ``distinct`` (live
    table size), ``intern_misses`` (total insert-on-miss events — in
    exact mode equal to ``distinct``, diverging once bounded mode
    recycles slots) and ``growth_events`` (crossings of the next
    power-of-two capacity — a proxy for device-table recompiles, which
    grow the packed key table by doubling). They flow to
    ``EstimateSet.coverage`` and ``ServeReport.coverage()``.
    """

    def __init__(self):
        self._table: dict[bytes, int] = {}
        self._combos: list[tuple[int, ...]] = []
        self._width: int | None = None
        self.intern_misses = 0
        self.growth_events = 0
        self._pow2_cap = 0

    def __len__(self) -> int:
        return len(self._combos)

    @property
    def distinct(self) -> int:
        """Live table size (id-space width), a pressure counter."""
        return len(self._combos)

    def _note_miss(self) -> None:
        self.intern_misses += 1
        while len(self._combos) > self._pow2_cap:
            self._pow2_cap = max(1, self._pow2_cap * 2)
            self.growth_events += 1

    @property
    def combos(self) -> list[tuple[int, ...]]:
        """Combination tuples indexed by combination id."""
        return list(self._combos)

    def combo_matrix(self) -> np.ndarray:
        """The key table as an int64 [k, width] matrix (shard wire format).

        This is what a shard serializes: its *local* id space is the row
        order, and receivers dedupe lazily by interning the rows into
        their own table (:meth:`intern_rows`) at merge time.
        """
        w = self._width if self._width is not None else 0
        if not self._combos:
            return np.empty((0, w), dtype=np.int64)
        return np.asarray(self._combos, dtype=np.int64)

    def intern_rows(self, mat: np.ndarray) -> np.ndarray:
        """Intern each row of an int64 [k, width] matrix; returns ids [k].

        The lazy cross-shard dedup primitive: another shard's key table
        maps local id ``i`` → union id ``intern_rows(table)[i]``. Rows are
        hashed as contiguous bytes (no per-row tuple boxing on re-intern).
        """
        mat = np.ascontiguousarray(np.asarray(mat), dtype=np.int64)
        if mat.ndim != 2:
            raise ValueError(f"expected [k, workers]; got shape {mat.shape}")
        if len(mat):
            if self._width is None:
                self._width = mat.shape[1]
            elif mat.shape[1] != self._width:
                raise ValueError(f"worker count mismatch at merge: "
                                 f"{mat.shape[1]} != {self._width}")
        table = self._table
        combos = self._combos
        ids = np.empty(len(mat), dtype=np.int64)
        for k in range(len(mat)):
            key = mat[k].tobytes()
            cid = table.get(key)
            if cid is None:
                cid = len(combos)
                table[key] = cid
                combos.append(tuple(int(v) for v in mat[k]))
                self._note_miss()
            ids[k] = cid
        return ids

    def intern(self, combo: tuple[int, ...]) -> int:
        """Intern a single combination tuple; returns its id."""
        key = np.asarray(combo, dtype=np.int64).tobytes()
        cid = self._table.get(key)
        if cid is None:
            cid = len(self._combos)
            self._table[key] = cid
            self._combos.append(tuple(int(v) for v in combo))
            if self._width is None:
                self._width = len(self._combos[-1])
            self._note_miss()
        return cid

    def find_row(self, row: np.ndarray) -> int | None:
        """Id of an int64 combination row, or None if never interned."""
        key = np.ascontiguousarray(row, dtype=np.int64).tobytes()
        return self._table.get(key)

    def replace(self, cid: int, combo: tuple[int, ...]) -> int:
        """Recycle slot ``cid`` for a new combination (bounded-mode
        eviction). The old key is forgotten; the slot keeps its id. The
        caller owns the statistics handoff (fold-then-zero) — this only
        rewrites identity. Counts as an intern miss (the new key missed),
        but not as table growth (the id space is unchanged)."""
        new = tuple(int(v) for v in combo)
        new_key = np.asarray(new, dtype=np.int64).tobytes()
        if new_key in self._table:
            raise ValueError("replacement combination is already interned")
        old_key = np.asarray(self._combos[cid], dtype=np.int64).tobytes()
        del self._table[old_key]
        self._table[new_key] = cid
        self._combos[cid] = new
        self.intern_misses += 1
        return cid

    def encode(self, region_id_matrix: np.ndarray) -> np.ndarray:
        """Map one chunk [c, workers] of region-id vectors to comb ids [c]."""
        mat = np.ascontiguousarray(np.asarray(region_id_matrix),
                                   dtype=np.int64)
        if mat.ndim != 2:
            raise ValueError(f"expected [n, workers]; got shape {mat.shape}")
        if self._width is None:
            self._width = mat.shape[1]
        elif mat.shape[1] != self._width:
            raise ValueError(f"worker count changed mid-stream: "
                             f"{mat.shape[1]} != {self._width}")
        if len(mat) == 0:
            return np.empty(0, dtype=np.int64)
        uniq, inverse = np.unique(mat, axis=0, return_inverse=True)
        # Hash the contiguous row bytes directly; the tuple form is only
        # materialized on first insertion (steady state re-interns cost a
        # dict lookup per distinct row, no boxing).
        local_to_global = self.intern_rows(uniq)
        return local_to_global[inverse.reshape(-1)]


class StreamingCombinationAggregator:
    """§4.4 combination attribution over chunked multi-worker streams.

    Composes a :class:`CombinationInterner` (growing combination id space)
    with a :class:`StreamingAggregator` that widens as new combinations
    appear. ``merge()`` remaps the other shard's combination ids through
    this shard's interner, so multi-host reductions agree with a single
    stream over the concatenated data.

    **Bounded mode** (``k=``): a space-saving-style heavy-hitters tier
    caps the table at ``k`` identified rows plus one ``other`` row per
    region (``(region, -1, ..., -1)`` — :data:`repro.core.sketch.OTHER`).
    A new combination admitted against a full table either evicts the
    lowest-count resident row (when its chunk weight exceeds that count)
    — folding the victim's full (counts, Σpow, Σpow²) triple into its
    region's ``other`` row first, so *per-region totals stay bit-exact*
    and only tail identity coarsens — or folds straight into ``other``.
    All decisions derive from the deterministic fold counters (never wall
    clock), and rows already carrying samples in the current chunk are
    never its eviction victims (their pending weight isn't folded yet).
    With ``k >= distinct`` the policy never fires and the bounded path is
    bit-exact to exact mode (the pinned oracle). Exact mode (``k=None``)
    stays the default and is completely unchanged.

    **Hash-range ownership** (``hash_range=``): the aggregator declares
    the splitmix64 hash interval of combination keys it owns; ingests
    and merges refuse *identified* rows outside it (and merges refuse
    peers declaring a different range), so a per-range shuffle over
    spilled shards can't double-count. Per-region ``other`` rows are
    exempt from ownership: a bounded shard mints them locally at fold
    time, so a sentinel key's own hash is arbitrary — it lives wherever
    its folds happened, and spilling / re-merging a folded sharded
    table must round-trip. See :meth:`filter_range`.
    """

    def __init__(self, *, aggregate_fn: AggregateFn | None = None,
                 domains: Sequence[str] = ("total",),
                 k: int | None = None, hash_range=None):
        if k is not None and k < 1:
            raise ValueError(f"k must be >= 1 (or None for exact); got {k}")
        self.interner = CombinationInterner()
        self.agg = StreamingAggregator(0, aggregate_fn=aggregate_fn,
                                       domains=domains)
        self.k = None if k is None else int(k)
        self.hash_range = _as_hash_range(hash_range)
        self._other_by_region: dict[int, int] = {}
        self._other_rows: set[int] = set()
        self.tail_folds = 0      # fold events (evictions + tail routings)
        self.evictions = 0       # identified rows evicted (slot recycled)
        self._recycles = 0       # identity rewrites (breaks append-only)

    @classmethod
    def from_table(cls, combo_matrix: np.ndarray, counts: np.ndarray,
                   psum: np.ndarray, psumsq: np.ndarray, *,
                   aggregate_fn: AggregateFn | None = None,
                   domains: Sequence[str] = ("total",),
                   k: int | None = None, hash_range=None
                   ) -> "StreamingCombinationAggregator":
        """Build from a key table + statistics (device-pipeline results,
        deserialized shards): ids are assigned in the table's row order,
        so a table in interner order round-trips exactly. ``psum``/
        ``psumsq`` are 1-D (single-domain) or [k, C] channel matrices.
        ``k``/``hash_range`` reconstruct a bounded/sharded table (its
        ``other`` rows are recognized by their sentinel fields)."""
        agg = cls(aggregate_fn=aggregate_fn, domains=domains, k=k,
                  hash_range=hash_range)
        agg.merge_table(combo_matrix, counts, psum, psumsq, k=k,
                        hash_range=hash_range)
        return agg

    @property
    def n_total(self) -> int:
        return self.agg.n_total

    @property
    def domains(self) -> tuple[str, ...]:
        return self.agg.domains

    @property
    def other_rows(self) -> int:
        """Number of per-region ``other`` (tail bucket) rows."""
        return len(self._other_rows)

    @property
    def resident(self) -> int:
        """Identified (non-``other``) rows currently holding identity."""
        return len(self.interner) - len(self._other_rows)

    @property
    def append_only(self) -> bool:
        """True while no slot has ever been recycled — the structural
        precondition for the spiller's cheap touched-row delta path.
        Once an eviction (or a :meth:`shrink_k` rebuild) rewrites row
        identity, dirty-row deltas would silently misattribute recycled
        slots, so the spiller must fall back to exact diffing."""
        return self._recycles == 0

    def touch_generation(self) -> int:
        """Delegates the spiller's touched-row contract to the inner
        statistics aggregator. Only valid for structural-append-only
        histories — consumers must check :attr:`append_only` (bounded
        mode recycles slots on eviction, rewriting row identity)."""
        return self.agg.touch_generation()

    def rows_touched_since(self, gen: int) -> np.ndarray:
        return self.agg.rows_touched_since(gen)

    # -- bounded-mode internals ----------------------------------------------

    def _sync_rows(self) -> None:
        if len(self.interner) > self.agg.num_regions:
            self.agg.grow(len(self.interner))

    def _other_id(self, region: int) -> int:
        """Id of ``region``'s tail bucket row, interning it on demand."""
        oid = self._other_by_region.get(region)
        if oid is None:
            width = self.interner._width
            oid = self.interner.intern(sketch_mod.other_row(region, width))
            self._other_by_region[region] = oid
            self._other_rows.add(oid)
            self._sync_rows()
        return oid

    def _register_other(self, cid: int, region: int) -> None:
        """Record an already-interned sentinel row as a tail bucket."""
        self._other_by_region.setdefault(region, cid)
        self._other_rows.add(cid)

    def _fold_stats(self, src: int, dst: int) -> None:
        """Move row ``src``'s full statistics triple onto ``dst`` and zero
        ``src`` — addition, so totals are preserved exactly."""
        a = self.agg
        a.counts[dst] += a.counts[src]
        a.chan_psum[dst] += a.chan_psum[src]
        a.chan_psumsq[dst] += a.chan_psumsq[src]
        a.counts[src] = 0
        a.chan_psum[src] = 0.0
        a.chan_psumsq[src] = 0.0
        a._touch_gen[src] = a._gen
        a._touch_gen[dst] = a._gen

    def _find_victim(self, protected: set[int]) -> tuple[int, int]:
        """Lowest-count evictable row (ties → lowest id): never an
        ``other`` row, never a row carrying unfolded weight from the
        chunk in flight (``protected`` — its count is not current yet).
        Returns (id, effective count); the count is ``_I64MAX`` when
        nothing is evictable."""
        n = len(self.interner)
        eff = self.agg.counts[:n].copy()
        masked = self._other_rows | protected
        if masked:
            eff[np.fromiter(masked, np.int64, len(masked))] = _I64MAX
        vid = int(np.argmin(eff))
        return vid, int(eff[vid])

    def _admit_or_fold(self, row: np.ndarray, weight: int,
                       protected: set[int],
                       exhausted: list[bool],
                       floor: list[int]) -> int:
        """Admission decision for one *new* combination carrying
        ``weight`` samples: intern while room, else evict the min-count
        resident (when ``weight`` beats it) or fold into the region's
        ``other`` row. Deterministic — counts and ids only.

        ``exhausted`` and ``floor`` are single-cell scan caches scoped
        to ONE ingest call: both are only valid while the masked
        (chunk-protected) set keeps growing, so a fresh ``[False]`` /
        ``[0]`` pair must be passed per update()/merge_table(). A
        cached floor that outlived its chunk would mask rows protected
        *then* but evictable *now*, permanently inflating the admission
        bar past the true minimum."""
        if self.resident < self.k:
            cid = self.interner.intern(tuple(int(v) for v in row))
            self._sync_rows()
            protected.add(cid)
            return cid
        if weight > floor[0] and not exhausted[0]:
            vid, vcount = self._find_victim(protected)
            if vcount != _I64MAX:
                # Within this ingest, counts only grow and the masked
                # set only widens, so the scanned min stays a valid
                # lower bound — later light arrivals skip the scan.
                floor[0] = vcount
            else:
                # Every resident is masked (chunk-protected or an
                # ``other`` row). The masked set only grows within a
                # chunk, so no victim can appear before the next chunk:
                # skip further scans instead of re-walking the table
                # for every tail arrival.
                exhausted[0] = True
            if weight > vcount:
                # The victim folds into *its own* region's tail bucket
                # (not the arriving row's — regions must never bleed).
                oid = self._other_id(int(self.interner._combos[vid][0]))
                self._fold_stats(vid, oid)
                self.interner.replace(vid, tuple(int(v) for v in row))
                self._recycles += 1
                self.evictions += 1
                self.tail_folds += 1
                protected.add(vid)
                return vid
        self.tail_folds += 1
        return self._other_id(int(row[0]))

    def _check_owned(self, mat: np.ndarray, verb: str) -> None:
        """Refuse identified rows whose key hash falls outside the owned
        range — a live sharded aggregator fails at the mis-routed ingest
        or merge, never by silently accumulating unowned keys that only
        surface at a downstream merge/restore. Sentinel (``other``) rows
        are exempt: folds mint them locally, wherever eviction happens,
        so their placement derives from the fold site, not their hash."""
        if self.hash_range is None or len(mat) == 0:
            return
        ident = ~sketch_mod.is_other_rows(mat)
        if ident.any() and not self.hash_range.owns(
                combo_hashes(mat[ident])).all():
            kind = "shuffle" if verb == "merge" else "ingest"
            raise SketchConfigError(
                f"{verb} offers combination rows outside this "
                f"aggregator's owned hash range "
                f"{self.hash_range.as_tuple()}; mis-routed {kind} — "
                f"route rows to their range owner first")

    # -- ingest ---------------------------------------------------------------

    def update(self, region_id_matrix: np.ndarray,
               powers: np.ndarray) -> "StreamingCombinationAggregator":
        if self.k is None:
            if self.hash_range is not None:
                m = np.ascontiguousarray(np.asarray(region_id_matrix),
                                         dtype=np.int64)
                if m.ndim == 2:
                    self._check_owned(m, "update")
            cids = self.interner.encode(region_id_matrix)
            self._sync_rows()
            self.agg.update(cids, powers)
            return self
        mat = np.ascontiguousarray(np.asarray(region_id_matrix),
                                   dtype=np.int64)
        if mat.ndim != 2:
            raise ValueError(f"expected [n, workers]; got shape {mat.shape}")
        if len(mat) and mat.shape[1] < 2:
            raise SketchConfigError(
                "bounded combination tables need width >= 2 (the region "
                "axis plus at least one folded axis); at width 1 use the "
                "plain StreamingAggregator")
        if len(mat) == 0:
            return self
        if self.interner._width is None:
            self.interner._width = mat.shape[1]
        elif mat.shape[1] != self.interner._width:
            raise ValueError(f"worker count changed mid-stream: "
                             f"{mat.shape[1]} != {self.interner._width}")
        uniq, inverse = np.unique(mat, axis=0, return_inverse=True)
        self._check_owned(uniq, "update")
        weights = np.bincount(inverse.reshape(-1), minlength=len(uniq))
        ids = np.empty(len(uniq), dtype=np.int64)
        protected: set[int] = set()
        exhausted = [False]
        floor = [0]
        missing: list[int] = []
        for i in range(len(uniq)):
            cid = self.interner.find_row(uniq[i])
            if cid is None:
                missing.append(i)
            else:
                ids[i] = cid
                protected.add(cid)
        for i in missing:
            ids[i] = self._admit_or_fold(uniq[i], int(weights[i]),
                                         protected, exhausted, floor)
        self._sync_rows()
        self.agg.update(ids[inverse.reshape(-1)], powers)
        return self

    def update_stream(self, chunks: Iterable[tuple[np.ndarray, np.ndarray]]
                      ) -> "StreamingCombinationAggregator":
        for mat, pows in chunks:
            self.update(mat, pows)
        return self

    # -- merge ----------------------------------------------------------------

    def merge_table(self, combo_matrix: np.ndarray, counts: np.ndarray,
                    psum: np.ndarray, psumsq: np.ndarray, *,
                    k: int | None = None, hash_range=None
                    ) -> "StreamingCombinationAggregator":
        """Fold a shard given by its raw key table + statistics.

        The cross-host merge primitive (lazy id dedup): ``combo_matrix``
        is the shard's local id space in row order, so its local id ``i``
        remaps to ``intern_rows(combo_matrix)[i]`` in the union space.
        Entry point for deserialized shards (:mod:`repro.core.exchange`);
        :meth:`merge` routes through it. Unseen rows are appended in the
        shard's local order, so any left-to-right reduction tree assigns
        the same union ids as one aggregator fed the concatenated stream.

        ``k``/``hash_range`` declare the *source* table's bounded-state
        config. Mismatched configs refuse with
        :class:`~repro.core.faults.SketchConfigError` (typed, never a
        silent union): a source k differing from this aggregator's, a
        sentinel (``other``) row offered to an exact table, a declared
        hash range contradicting this aggregator's, or *identified*
        rows hashing outside this aggregator's owned range. Sentinel
        rows are exempt from the ownership check — a bounded shard
        folds its tail locally, so its own (legitimately produced)
        table carries ``other`` keys whose hashes land anywhere in the
        space; spill/restore and peer merges must accept them. In
        bounded mode, source rows route through the same admission
        policy as live samples and source ``other`` rows fold into the
        matching local tail buckets.
        """
        mat = np.ascontiguousarray(np.asarray(combo_matrix), dtype=np.int64)
        if mat.ndim != 2:
            raise ValueError(f"expected [k, workers]; got shape {mat.shape}")
        src_k = None if k is None else int(k)
        if src_k != self.k:
            raise SketchConfigError(
                f"combination-table k mismatch at merge: source "
                f"k={src_k} vs destination k={self.k}; bounded and exact "
                f"tails cannot be blended — rebuild one side first")
        src_hr = _as_hash_range(hash_range)
        if (src_hr is not None and self.hash_range is not None
                and src_hr != self.hash_range):
            raise SketchConfigError(
                f"hash-range ownership mismatch at merge: source "
                f"{src_hr.as_tuple()} vs destination "
                f"{self.hash_range.as_tuple()}")
        sentinel = sketch_mod.is_other_rows(mat)
        self._check_owned(mat, "merge")
        if sentinel.any() and self.k is None:
            raise SketchConfigError(
                "bounded (top-k + 'other') rows cannot merge into an "
                "exact aggregator; construct the destination with the "
                "matching k")
        if self.k is None:
            # Exact fast path — unchanged from pre-bounded behavior.
            remap = self.interner.intern_rows(mat)
            self._sync_rows()
            if len(remap):
                c = self.agg.num_channels
                np.add.at(self.agg.counts, remap,
                          np.asarray(counts, np.int64))
                np.add.at(self.agg.chan_psum, remap, _as_channels(psum, c))
                np.add.at(self.agg.chan_psumsq, remap,
                          _as_channels(psumsq, c))
                self.agg._touch_gen[remap] = self.agg._gen
            return self
        if len(mat) and mat.shape[1] < 2:
            raise SketchConfigError(
                "bounded combination tables need width >= 2")
        if len(mat) == 0:
            return self
        if self.interner._width is None:
            self.interner._width = mat.shape[1]
        c = self.agg.num_channels
        cnt = np.asarray(counts, dtype=np.int64).reshape(-1)
        ps = _as_channels(psum, c)
        psq = _as_channels(psumsq, c)
        protected: set[int] = set()
        exhausted = [False]
        floor = [0]
        a = self.agg
        for i in range(len(mat)):
            row = mat[i]
            if sentinel[i]:
                tid = self._other_id(int(row[0]))
            else:
                cid = self.interner.find_row(row)
                if cid is None:
                    tid = self._admit_or_fold(row, int(cnt[i]),
                                              protected, exhausted,
                                              floor)
                else:
                    tid = cid
                    protected.add(cid)
            self._sync_rows()
            a.counts[tid] += cnt[i]
            a.chan_psum[tid] += ps[i]
            a.chan_psumsq[tid] += psq[i]
            a._touch_gen[tid] = a._gen
        return self

    def merge(self, other: "StreamingCombinationAggregator"
              ) -> "StreamingCombinationAggregator":
        if other.domains != self.domains:
            raise ValueError(f"domain axis mismatch at merge: "
                             f"{other.domains} != {self.domains}")
        self.merge_table(other.interner.combo_matrix(),
                         other.agg.counts, other.agg.chan_psum,
                         other.agg.chan_psumsq, k=other.k,
                         hash_range=other.hash_range)
        # Tail provenance rides along: folds that happened at the source
        # stay disclosed after the reduction.
        self.tail_folds += other.tail_folds
        self.evictions += other.evictions
        return self

    # -- bounded-state surface -------------------------------------------------

    def shrink_k(self, k: int) -> None:
        """Lower the heavy-hitters capacity in place (overload response:
        the serve ladder's ``degraded`` rung calls this). Never widens —
        eviction is irreversible, so a larger k would only misreport the
        already-folded tail. When the current resident set exceeds the
        new k, the lowest-count rows (ties → lowest id) fold into their
        regions' ``other`` buckets; per-region totals are preserved
        exactly. Works from exact mode too (adopts bounded mode)."""
        k = int(k)
        if k < 1:
            raise ValueError(f"k must be >= 1; got {k}")
        if self.k is not None and k >= self.k:
            return
        if self.resident <= k:
            self.k = k
            return
        n = len(self.interner)
        counts = self.agg.counts[:n]
        # Keep the k highest-count identified rows (other rows keep
        # their slots for free — they are the fold destinations);
        # lexsort's last key is primary, so sort by (-count, id).
        ident = np.asarray([cid for cid in range(n)
                            if cid not in self._other_rows], np.int64)
        order = ident[np.lexsort((ident, -counts[ident]))]
        keep = set(int(v) for v in order[:k])
        folded = [int(v) for v in order[k:]]
        self.k = k
        for cid in folded:
            oid = self._other_id(int(self.interner._combos[cid][0]))
            self._fold_stats(cid, oid)
        # Rewrite identity of the folded slots is impossible in place
        # (their keys must leave the table so future arrivals re-enter
        # admission); rebuild the table without them.
        self._rebuild_without(set(folded))
        self._recycles += len(folded)
        self.evictions += len(folded)
        self.tail_folds += len(folded)

    def _rebuild_without(self, drop: set[int]) -> None:
        """Re-intern every kept row (original id order) into a fresh
        table, remapping statistics; dropped rows must already be zeroed."""
        old = self.interner
        n = len(old)
        keep_ids = [cid for cid in range(n) if cid not in drop]
        fresh = CombinationInterner()
        fresh._width = old._width
        fresh._pow2_cap = old._pow2_cap
        other_by_region: dict[int, int] = {}
        other_rows: set[int] = set()
        for cid in keep_ids:
            nid = fresh.intern(old._combos[cid])
            if cid in self._other_rows:
                other_rows.add(nid)
                other_by_region[int(old._combos[cid][0])] = nid
        # Pressure counters describe the stream's history, not the
        # rebuild — carry them over verbatim.
        fresh.intern_misses = old.intern_misses
        fresh.growth_events = old.growth_events
        a = self.agg
        sel = np.asarray(keep_ids, dtype=np.int64)
        rebuilt = StreamingAggregator(len(keep_ids), aggregate_fn=a._agg,
                                      domains=a.domains)
        rebuilt.counts += a.counts[sel]
        rebuilt.chan_psum += a.chan_psum[sel]
        rebuilt.chan_psumsq += a.chan_psumsq[sel]
        rebuilt._touch_gen[:] = a._touch_gen[sel]
        rebuilt._gen = a._gen
        self.interner = fresh
        self.agg = rebuilt
        self._other_by_region = other_by_region
        self._other_rows = other_rows

    def filter_range(self, hash_range) -> "StreamingCombinationAggregator":
        """Project this table onto a hash range: a new aggregator (same
        k / domains, owning ``hash_range``) holding exactly the rows —
        identified and ``other`` alike — whose key hashes fall inside
        it. The per-range shuffle primitive: ``split(n)`` ranges'
        projections partition the table, so merging each range on its
        owner host and unioning the results never double-counts."""
        hr = _as_hash_range(hash_range)
        if hr is None:
            raise ValueError("filter_range needs a hash range")
        out = StreamingCombinationAggregator(
            aggregate_fn=self.agg._agg, domains=self.domains, k=self.k,
            hash_range=hr)
        mat = self.interner.combo_matrix()
        if len(mat) == 0:
            return out
        keep = hr.owns(combo_hashes(mat))
        n = len(mat)
        out.merge_table(mat[keep], self.agg.counts[:n][keep],
                        self.agg.chan_psum[:n][keep],
                        self.agg.chan_psumsq[:n][keep], k=self.k,
                        hash_range=hr)
        return out

    def interner_pressure(self) -> dict:
        """First-class pressure counters for operators: how close the
        exact path is to blowing up, and what bounded mode folded."""
        out = {
            "distinct": self.interner.distinct,
            "intern_misses": self.interner.intern_misses,
            "growth_events": self.interner.growth_events,
        }
        if self.k is not None:
            out.update(k=self.k, resident=self.resident,
                       other_rows=self.other_rows,
                       tail_folds=self.tail_folds,
                       evictions=self.evictions)
        return out

    def tail_info(self) -> dict | None:
        """TAIL disclosure payload (None in exact mode)."""
        if self.k is None:
            return None
        return {"k": self.k, "resident": self.resident,
                "other_rows": self.other_rows,
                "tail_folds": self.tail_folds,
                "evictions": self.evictions}

    def estimates(self, t_exec: float, names: Sequence[str], *,
                  alpha: float = 0.05, coverage=None
                  ) -> tuple[EstimateSet, list[tuple[int, ...]]]:
        """Finalize into (combination EstimateSet, combination tuples).

        Bounded tables disclose themselves: ``EstimateSet.tail`` carries
        the fold counters (the report's ``TAIL`` line) and the coverage
        mapping gains an ``"interner"`` pressure block. Exact tables
        with no gather coverage keep ``coverage=None`` — byte-identical
        to pre-bounded output."""
        comb_names = combination_names_from_matrix(
            self.interner.combo_matrix(), names)
        cov = coverage
        if cov is not None:
            cov = dict(cov)
            cov["interner"] = self.interner_pressure()
        elif self.k is not None:
            cov = {"complete": True, "interner": self.interner_pressure()}
        est = self.agg.estimates(t_exec, comb_names, alpha=alpha,
                                 coverage=cov, tail=self.tail_info())
        return est, self.interner.combos


def stream_estimate(chunks: Iterable[tuple[np.ndarray, np.ndarray]],
                    t_exec: float, names: Sequence[str], *,
                    alpha: float = 0.05,
                    aggregate_fn: AggregateFn | None = None) -> EstimateSet:
    """One-call streaming estimation: fold chunks, then build estimates."""
    agg = StreamingAggregator(len(names), aggregate_fn=aggregate_fn)
    agg.update_stream(chunks)
    return agg.estimates(t_exec, names, alpha=alpha)
