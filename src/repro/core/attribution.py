"""Attribution reports: per-region energy tables + validation vs ground truth."""

from __future__ import annotations

import dataclasses
from typing import Mapping

import numpy as np

from repro.core.estimator import EstimateSet

__all__ = ["AttributionReport", "ValidationResult", "validate"]


@dataclasses.dataclass(frozen=True)
class AttributionReport:
    """Human/CSV rendering of an EstimateSet."""

    estimates: EstimateSet

    def _coverage_lines(self) -> list[str]:
        """Degraded-gather disclosure: statistics merged from a partial
        fleet must say so in every human rendering (the numbers alone
        look identical to a complete gather's)."""
        cov = self.estimates.coverage
        if not cov or cov.get("complete"):
            return []
        return [f"COVERAGE (partial fleet): {cov.get('summary', cov)}"]

    def _tail_lines(self) -> list[str]:
        """Bounded-state disclosure (mirrors COVERAGE): a top-k + 'other'
        combination table coarsened tail identity, and every human
        rendering must say so — per-region totals stay exact, but the
        per-combination rows no longer enumerate the full key space."""
        tail = self.estimates.tail
        if not tail:
            return []
        return [f"TAIL (bounded combinations, k={tail.get('k')}): "
                f"{tail.get('tail_folds', 0)} fold event(s), "
                f"{tail.get('evictions', 0)} eviction(s) into "
                f"{tail.get('other_rows', 0)} per-region 'other' row(s); "
                f"per-region totals exact, tail identity coarsened"]

    def table(self, top: int | None = None) -> str:
        rows = sorted(self.estimates.regions, key=lambda r: -r.e_hat)
        if top:
            rows = rows[:top]
        hdr = (f"{'region':28s} {'n':>8s} {'t̂ [s]':>10s} {'t CI±':>8s} "
               f"{'p̂ow [W]':>9s} {'ê [J]':>11s} {'e CI':>21s}")
        lines = [hdr, "-" * len(hdr)]
        for r in rows:
            ci = f"[{r.e_lo:9.2f},{r.e_hi:9.2f}]"
            lines.append(
                f"{r.name:28s} {r.n_samples:8d} {r.t_hat:10.4f} "
                f"{r.t_ci_halfwidth:8.4f} {r.pow_hat:9.2f} {r.e_hat:11.2f} "
                f"{ci:>21s}")
        lines.append(f"{'TOTAL':28s} {self.estimates.n_total:8d} "
                     f"{self.estimates.total_time:10.4f} {'':8s} {'':9s} "
                     f"{self.estimates.total_energy:11.2f}")
        lines.extend(self._coverage_lines())
        lines.extend(self._tail_lines())
        return "\n".join(lines)

    def csv(self) -> str:
        lines = ["region,n,t_hat,t_lo,t_hi,pow_hat,pow_lo,pow_hi,e_hat,e_lo,e_hi"]
        for r in self.estimates.regions:
            lines.append(f"{r.name},{r.n_samples},{r.t_hat:.6g},{r.t_lo:.6g},"
                         f"{r.t_hi:.6g},{r.pow_hat:.6g},{r.pow_lo:.6g},"
                         f"{r.pow_hi:.6g},{r.e_hat:.6g},{r.e_lo:.6g},{r.e_hi:.6g}")
        return "\n".join(lines)

    def domain_table(self, top: int | None = None) -> str:
        """Per-block × per-domain energy breakdown (multi-rail runs).

        The §6 compute-vs-memory question answered directly: each row
        shows a region's energy split across measured power rails plus
        the share of its energy on each — no indirect memory_power
        inference needed.
        """
        tbl = self.estimates.table
        if tbl.domains is None:
            raise ValueError(
                "single-rail estimates have no domain breakdown; profile "
                "with a multi-domain timeline/sensor bank")
        order = np.argsort(-tbl.e_hat, kind="stable")
        if top:
            order = order[:top]
        hdr = f"{'region':28s} {'ê [J]':>11s}"
        for d in tbl.domains:
            hdr += f" {'ê_' + d + ' [J]':>14s} {'%':>5s}"
        lines = [hdr, "-" * len(hdr)]
        for i in order:
            i = int(i)
            row = f"{tbl.names[i]:28s} {tbl.e_hat[i]:11.2f}"
            for j in range(len(tbl.domains)):
                share = (tbl.e_rails[i, j] / tbl.e_hat[i] * 100.0
                         if tbl.e_hat[i] > 0 else 0.0)
                row += f" {tbl.e_rails[i, j]:14.2f} {share:5.1f}"
            lines.append(row)
        totals = self.estimates.energy_by_domain()
        tot = f"{'TOTAL':28s} {self.estimates.total_energy:11.2f}"
        te = self.estimates.total_energy
        for d in tbl.domains:
            share = totals[d] / te * 100.0 if te > 0 else 0.0
            tot += f" {totals[d]:14.2f} {share:5.1f}"
        lines.append(tot)
        lines.extend(self._coverage_lines())
        lines.extend(self._tail_lines())
        return "\n".join(lines)

    def domain_csv(self) -> str:
        """CSV of the per-block × per-domain energy decomposition."""
        tbl = self.estimates.table
        if tbl.domains is None:
            raise ValueError("single-rail estimates have no domain "
                             "breakdown")
        cols = []
        for d in tbl.domains:
            cols += [f"pow_{d}", f"e_{d}", f"e_{d}_lo", f"e_{d}_hi"]
        lines = ["region,n,e_hat," + ",".join(cols)]
        for i in range(len(tbl)):
            vals = []
            for j in range(len(tbl.domains)):
                vals += [f"{tbl.pow_rails[i, j]:.6g}",
                         f"{tbl.e_rails[i, j]:.6g}",
                         f"{tbl.e_rails_lo[i, j]:.6g}",
                         f"{tbl.e_rails_hi[i, j]:.6g}"]
            lines.append(f"{tbl.names[i]},{int(tbl.n_samples[i])},"
                         f"{tbl.e_hat[i]:.6g}," + ",".join(vals))
        return "\n".join(lines)


@dataclasses.dataclass(frozen=True)
class ValidationResult:
    """Paper-§5-style accuracy summary vs direct measurements."""

    per_region_time_err: Mapping[str, float]   # |t̂−t|/t
    per_region_energy_err: Mapping[str, float]
    mean_time_err: float
    mean_energy_err: float
    whole_time_err: float
    whole_energy_err: float
    ci_time_coverage: float     # fraction of regions whose CI contains truth
    ci_energy_coverage: float
    measured_time_fraction: float = 1.0   # paper's "81% of execution time"

    def summary(self) -> str:
        return (f"mean err: time {self.mean_time_err*100:.2f}% "
                f"energy {self.mean_energy_err*100:.2f}% | whole-program: "
                f"time {self.whole_time_err*100:.2f}% "
                f"energy {self.whole_energy_err*100:.2f}% | CI coverage: "
                f"time {self.ci_time_coverage*100:.0f}% "
                f"energy {self.ci_energy_coverage*100:.0f}% | "
                f"measured {self.measured_time_fraction*100:.0f}% of time")


def validate(est: EstimateSet, truth: Mapping[str, Mapping[str, float]],
             *, min_time_fraction: float = 0.002,
             spans: Mapping[str, float] | None = None,
             min_span: float = 0.0) -> ValidationResult:
    """Compare estimates to exact ground truth (direct-measurement analogue).

    Following the paper's §5 protocol, per-region errors are computed only
    over regions that direct measurement could resolve: contiguous
    execution span (one invocation run of the region — the 'enclosing
    loop') at least ``min_span`` (the sampling period), and at least
    ``min_time_fraction`` of total time. Excluded regions still count
    toward whole-program error. ``measured_time_fraction`` reports how
    much execution time the validated regions cover (the paper: 81%).
    """
    t_errs: dict[str, float] = {}
    e_errs: dict[str, float] = {}
    cov_t: list[bool] = []
    cov_e: list[bool] = []
    total_t = sum(v["time"] for v in truth.values())
    total_e = sum(v["energy"] for v in truth.values())
    by_name = est.by_name()
    measured_t = 0.0
    for name, gt in truth.items():
        r = by_name.get(name)
        if r is None or gt["time"] < min_time_fraction * total_t:
            continue
        if spans is not None and spans.get(name, 0.0) < min_span:
            continue
        measured_t += gt["time"]
        t_errs[name] = abs(r.t_hat - gt["time"]) / gt["time"]
        e_errs[name] = abs(r.e_hat - gt["energy"]) / max(gt["energy"], 1e-12)
        if r.ci_valid:
            cov_t.append(r.t_lo <= gt["time"] <= r.t_hi)
            cov_e.append(r.e_lo <= gt["energy"] <= r.e_hi)
    est_total_t = sum(r.t_hat for r in est.regions)
    est_total_e = sum(r.e_hat for r in est.regions)
    return ValidationResult(
        per_region_time_err=t_errs,
        per_region_energy_err=e_errs,
        mean_time_err=float(np.mean(list(t_errs.values()))) if t_errs else 0.0,
        mean_energy_err=float(np.mean(list(e_errs.values()))) if e_errs else 0.0,
        whole_time_err=abs(est_total_t - total_t) / total_t,
        whole_energy_err=abs(est_total_e - total_e) / total_e,
        ci_time_coverage=float(np.mean(cov_t)) if cov_t else 1.0,
        ci_energy_coverage=float(np.mean(cov_e)) if cov_e else 1.0,
        measured_time_fraction=measured_t / total_t if total_t else 0.0,
    )
