"""Power sensors with realistic sampling semantics (paper §3, §4.5).

Two families:

* **Trace sensors** read a synthesized :class:`~repro.core.timeline.Timeline`
  with the *exact semantics of the paper's instruments*:

  - :class:`RaplTraceSensor` — integrating energy counter (Sandy Bridge
    RAPL): a sample at time t returns (E(t) − E(t_prev)) / (t − t_prev);
    counter contents update only every ``update_period`` (1 ms on SNB).
  - :class:`Ina231TraceSensor` — window-averaging power meter (Exynos
    INA231): a sample returns mean power over [t − window, t]; minimum
    feasible window 280 µs in the paper.
  - :class:`InstantTraceSensor` — oracle P(t) (for unit tests).

* **Host sensors** read the real machine while host-mode profiling runs:

  - :class:`RaplSensor` — Linux powercap energy_uj, when readable.
  - :class:`ProcessActivitySensor` — fallback for unprivileged containers:
    models power from process CPU utilization (idle + dynamic·util),
    keeping the host demo self-contained.

All sensors expose ``read(t) -> watts`` plus ``min_period`` so the profiler
can refuse sampling faster than the instrument supports.
"""

from __future__ import annotations

import dataclasses
import functools
import os
import time
from typing import Mapping, Sequence

import numpy as np

from repro.core import faults as faults_mod
from repro.core.faults import declare_site
from repro.core.timeline import Timeline

# Injection seam this module owns (see faults.FAULT_SITES): per-rail
# dropout masks applied by the trace-sensor banks.
_SITE_TRACE_BANK = declare_site("sensors.trace_bank")

__all__ = [
    "SensorSpec", "DEFAULT_IDLE_POWER", "idle_channel",
    "InstantTraceSensor", "RaplTraceSensor", "Ina231TraceSensor",
    "FailoverTraceBank",
    "RaplSensor", "ProcessActivitySensor", "available_host_sensor",
    "HostSensorBank",
]

# Near-idle package power blended into suspended-sample readings (§4.7);
# shared by the host sampler and the device pipeline so both overhead
# models emulate the same machine.
DEFAULT_IDLE_POWER = 70.0


def idle_channel(domains: "tuple[str, ...]") -> int:
    """Rail index that absorbs §4.7 suspension idle power.

    A suspended chip burns near-idle power in the *package*, not on
    HBM/ICI rails — so the blend targets the rail named ``"package"``
    wherever it sits in the domain axis, falling back to channel 0 for
    axes without one (including the scalar ``("total",)``). Shared by
    the device pipeline, the numpy oracle and the host sampler so every
    overhead model emulates the same machine.
    """
    try:
        return domains.index("package")
    # audit: allow(no-silent-except) documented fallback: axes without a
    # "package" rail blend idle power into channel 0 by contract
    except ValueError:
        return 0


@dataclasses.dataclass(frozen=True)
class SensorSpec:
    """Declarative trace-sensor semantics (hashable jit-cache key).

    The device-resident pipeline (:mod:`repro.core.device_pipeline`)
    re-implements each trace sensor as a *pure function* of the timeline's
    cumulative energy integral; this spec carries the parameters of that
    function without binding to a host-side Timeline. ``kind`` selects the
    emulation: ``instant`` (oracle P(t)), ``rapl`` (energy counter
    differenced between consecutive samples, quantized to
    ``update_period``), ``ina231`` (mean power over ``[t - window, t]``).

    A spec is a *bank* of synchronized channels, one per power-rail
    domain (RAPL exposes PKG and DRAM counters, PowerSensor3-class
    instruments several rails): ``domains`` names the channels and every
    channel applies the same kind/period semantics to its own rail's
    energy integral. ``min_periods`` optionally carries per-channel
    instrument floors (a DRAM counter can refresh slower than PKG);
    :meth:`effective_min_period` is the bank's binding constraint. The
    default single-channel ``("total",)`` spec is exactly the old scalar
    sensor.
    """

    kind: str                    # "instant" | "rapl" | "ina231"
    update_period: float = 0.0   # rapl counter quantum [s]
    window: float = 0.0          # ina231 averaging window [s]
    min_period: float = 0.0      # instrument's fastest supported period [s]
    domains: tuple[str, ...] = ("total",)   # channel (rail) names
    min_periods: tuple[float, ...] = ()     # optional per-channel floors

    def __post_init__(self):
        if self.min_periods and len(self.min_periods) != len(self.domains):
            raise ValueError(
                f"min_periods has {len(self.min_periods)} entries for "
                f"{len(self.domains)} domains")

    @property
    def num_domains(self) -> int:
        return len(self.domains)

    def effective_min_period(self) -> float:
        """Fastest period every channel of the bank supports."""
        return max((self.min_period, *self.min_periods))


class _TraceSensorBase:
    """Common precomputation for trace sensors.

    Multi-channel support: ``domains`` mirrors the timeline's rail axis
    and ``_energy_rails_at`` is the per-rail twin of ``_energy_at`` —
    for scalar (D=1) timelines its single column is bit-identical to the
    scalar integral, which is what keeps the multi-channel code paths
    output-compatible with the pre-rail sensors.
    """

    def __init__(self, timeline: Timeline):
        self.tl = timeline
        self.domains = timeline.domain_names
        self._ends = timeline.ends
        self._E = np.concatenate([[0.0], timeline.energy_integral()])
        self._bounds = np.concatenate([[0.0], self._ends])

    @functools.cached_property
    def _ER(self) -> np.ndarray:
        # Built on first read_rails/_energy_rails_at use only: scalar
        # consumers of read/read_many never pay the O(m·D) table.
        return np.concatenate([np.zeros((1, self.tl.num_domains)),
                               self.tl.rail_energy_integral()])

    def _energy_at(self, t: np.ndarray) -> np.ndarray:
        """Exact cumulative energy E(t) for piecewise-constant power."""
        t = np.clip(np.asarray(t, dtype=np.float64), 0.0, self._bounds[-1])
        idx = np.searchsorted(self._bounds, t, side="right") - 1
        idx = np.clip(idx, 0, len(self.tl.powers) - 1)
        return self._E[idx] + (t - self._bounds[idx]) * self.tl.powers[idx]

    def _energy_rails_at(self, t: np.ndarray) -> np.ndarray:
        """Per-rail cumulative energy E_d(t), [n, D]."""
        t = np.clip(np.asarray(t, dtype=np.float64), 0.0, self._bounds[-1])
        idx = np.searchsorted(self._bounds, t, side="right") - 1
        idx = np.clip(idx, 0, len(self.tl.powers) - 1)
        return (self._ER[idx]
                + (t - self._bounds[idx])[..., None] * self.tl.rails()[idx])


class InstantTraceSensor(_TraceSensorBase):
    min_period = 0.0

    def read(self, t):
        return self.tl.power_at(t)

    def read_rails(self, times: np.ndarray) -> np.ndarray:
        """Per-channel readings [n, D] (oracle rail powers at t)."""
        idx = np.searchsorted(self._ends, np.asarray(times), side="right")
        idx = np.clip(idx, 0, len(self.tl.powers) - 1)
        return self.tl.rails()[idx]

    @classmethod
    def make_spec(cls, *, domains: tuple[str, ...] = ("total",),
                  min_periods: tuple[float, ...] = ()) -> SensorSpec:
        return SensorSpec(kind="instant", domains=tuple(domains),
                          min_periods=tuple(min_periods))

    def spec(self) -> SensorSpec:
        return self.make_spec(domains=self.domains)


class RaplTraceSensor(_TraceSensorBase):
    """Integrating energy counter, differenced between consecutive samples.

    Matches §4.5: 'we measure power ... by dividing the energy consumed
    since the last sample by the length of the sampling period', with the
    counter updating once per ``update_period`` (1 ms on Sandy Bridge).
    """

    DEFAULT_UPDATE_PERIOD = 1e-3    # Sandy Bridge counter refresh (§4.5)

    def __init__(self, timeline: Timeline,
                 update_period: float = DEFAULT_UPDATE_PERIOD):
        super().__init__(timeline)
        self.update_period = update_period
        self.min_period = update_period

    @classmethod
    def make_spec(cls, update_period: float | None = None, *,
                  domains: tuple[str, ...] = ("total",),
                  min_periods: tuple[float, ...] = ()) -> SensorSpec:
        if update_period is None:
            update_period = cls.DEFAULT_UPDATE_PERIOD
        return SensorSpec(kind="rapl", update_period=update_period,
                          min_period=update_period, domains=tuple(domains),
                          min_periods=tuple(min_periods))

    def spec(self) -> SensorSpec:
        return self.make_spec(self.update_period, domains=self.domains)

    def _quantized(self, times: np.ndarray):
        times = np.asarray(times, dtype=np.float64)
        # Counter is quantized to its internal update period. The 1e-6
        # epsilon (in units of the period) keeps exact-boundary sample times
        # from flooring down a whole period due to fp division error.
        tq = np.floor(times / self.update_period + 1e-6) * self.update_period
        prev_t = np.concatenate([[max(tq[0] - self.update_period, 0.0)],
                                 tq[:-1]])
        dt = np.maximum(tq - prev_t, self.update_period)
        return tq, prev_t, dt

    def read_many(self, times: np.ndarray) -> np.ndarray:
        """Vectorized differencing over an increasing sample-time array."""
        tq, prev_t, dt = self._quantized(times)
        return (self._energy_at(tq) - self._energy_at(prev_t)) / dt

    def read_rails(self, times: np.ndarray) -> np.ndarray:
        """Per-channel RAPL differencing [n, D] (PKG/DRAM-style bank)."""
        tq, prev_t, dt = self._quantized(times)
        de = self._energy_rails_at(tq) - self._energy_rails_at(prev_t)
        return de / dt[:, None]


class Ina231TraceSensor(_TraceSensorBase):
    """Window-averaged power meter (TI INA231 semantics, §4.5)."""

    DEFAULT_WINDOW = 280e-6         # minimum feasible INA231 window (§4.5)

    def __init__(self, timeline: Timeline, window: float = DEFAULT_WINDOW):
        super().__init__(timeline)
        self.window = window
        self.min_period = window

    @classmethod
    def make_spec(cls, window: float | None = None, *,
                  domains: tuple[str, ...] = ("total",),
                  min_periods: tuple[float, ...] = ()) -> SensorSpec:
        if window is None:
            window = cls.DEFAULT_WINDOW
        return SensorSpec(kind="ina231", window=window, min_period=window,
                          domains=tuple(domains),
                          min_periods=tuple(min_periods))

    def spec(self) -> SensorSpec:
        return self.make_spec(self.window, domains=self.domains)

    def read(self, t):
        t = np.asarray(t, dtype=np.float64)
        lo = np.maximum(t - self.window, 0.0)
        de = self._energy_at(t) - self._energy_at(lo)
        dt = np.maximum(t - lo, 1e-12)
        return de / dt

    def read_many(self, times: np.ndarray) -> np.ndarray:
        return self.read(times)

    def read_rails(self, times: np.ndarray) -> np.ndarray:
        """Per-channel windowed means [n, D] (multi-rail INA bank)."""
        t = np.asarray(times, dtype=np.float64)
        lo = np.maximum(t - self.window, 0.0)
        de = self._energy_rails_at(t) - self._energy_rails_at(lo)
        return de / np.maximum(t - lo, 1e-12)[:, None]


class FailoverTraceBank:
    """Per-channel failover over a multi-rail trace sensor.

    Production rails fail independently (a DRAM counter stalls while PKG
    keeps reporting), so the bank pairs the primary instrument with an
    optional *fallback* sensor per domain. A dropped-out channel —
    injected by the active :class:`~repro.core.faults.FaultPlan`, or any
    NaN the primary itself reports — is repaired two ways:

    * a fallback exists for the domain → its (typically slower/noisier)
      readings substitute for exactly the dropped entries, and the CIs
      widen through that sensor's own variance;
    * no fallback → the entries stay NaN and the *sampler* voids those
      whole samples (see ``iter_sample_chunks``): fewer samples → larger
      standard error — the CI widens honestly, with no bias toward any
      rail, and nothing about the wire schema changes.

    Period arbitration reuses :meth:`SensorSpec.effective_min_period`:
    the bank's spec carries per-channel floors raised to each fallback's
    ``min_period``, so a session cannot sample faster than the slowest
    instrument that might have to serve a channel.
    """

    def __init__(self, primary,
                 fallbacks: Mapping[str, object] | None = None, *,
                 faults: "faults_mod.FaultPlan | None" = None):
        self.primary = primary
        self.domains = tuple(primary.domains)
        self.fallbacks = dict(fallbacks or {})
        unknown = set(self.fallbacks) - set(self.domains)
        if unknown:
            raise ValueError(f"fallback domains {sorted(unknown)} not in "
                             f"bank domains {self.domains}")
        # Captured once — samplers read from worker threads where the
        # installing context is invisible.
        self._faults = faults_mod.resolve_plan(faults)
        self.failover_reads = {d: 0 for d in self.domains}
        self.masked_samples = 0
        self.min_period = self.spec().effective_min_period()

    def spec(self) -> SensorSpec:
        base = self.primary.spec()
        floors = list(base.min_periods or (base.min_period,) * len(
            self.domains))
        for j, d in enumerate(self.domains):
            fb = self.fallbacks.get(d)
            if fb is not None:
                floors[j] = max(floors[j], getattr(fb, "min_period", 0.0))
        return dataclasses.replace(base, min_periods=tuple(floors))

    def effective_min_period(self) -> float:
        return self.spec().effective_min_period()

    def _fallback_column(self, fb, times: np.ndarray, j: int) -> np.ndarray:
        if hasattr(fb, "read_rails"):
            return np.asarray(fb.read_rails(times),
                              dtype=np.float64)[:, j]
        if hasattr(fb, "read_many"):
            return np.asarray(fb.read_many(times), dtype=np.float64)
        return np.asarray(fb.read(times), dtype=np.float64)

    def read_rails(self, times) -> np.ndarray:
        times = np.asarray(times, dtype=np.float64)
        pows = np.array(self.primary.read_rails(times), dtype=np.float64)
        if self._faults is not None:
            mask = self._faults.dropout_mask(self.domains, times)
            if mask is not None:
                pows[mask] = np.nan
        bad = np.isnan(pows)
        if not bad.any():
            return pows
        for j, d in enumerate(self.domains):
            col = bad[:, j]
            if not col.any():
                continue
            fb = self.fallbacks.get(d)
            if fb is None:
                continue                       # masked; sampler voids rows
            pows[col, j] = self._fallback_column(fb, times[col], j)
            self.failover_reads[d] += int(col.sum())
        self.masked_samples += int(np.isnan(pows).any(axis=1).sum())
        return pows

    def read_many(self, times) -> np.ndarray:
        if len(self.domains) != 1:
            raise ValueError("multi-rail bank: use read_rails")
        return self.read_rails(times)[:, 0]


# ---------------------------------------------------------------------------
# Host (real machine) sensors.
# ---------------------------------------------------------------------------

_RAPL_GLOB = "/sys/class/powercap/intel-rapl:0/energy_uj"


class RaplSensor:
    """Reads the Linux powercap RAPL energy counter (µJ), differenced."""

    min_period = 1e-3

    def __init__(self, path: str = _RAPL_GLOB):
        self.path = path
        self._last: tuple[float, float] | None = None
        with open(path) as f:       # raises if unreadable → caller falls back
            int(f.read())

    def read(self, t: float | None = None) -> float:
        now = time.monotonic() if t is None else t
        with open(self.path) as f:
            uj = int(f.read())
        if self._last is None:
            self._last = (now, uj)
            return 0.0
        t0, uj0 = self._last
        self._last = (now, uj)
        dt = max(now - t0, 1e-9)
        duj = uj - uj0
        if duj < 0:  # counter wrap
            return 0.0
        return duj * 1e-6 / dt


class ProcessActivitySensor:
    """Container-safe fallback: power modeled from process CPU utilization.

    P = p_idle + p_dyn · util, where util is the derivative of process CPU
    time. This keeps host-mode profiling honest (the 'sensor' responds to
    what the program actually does) without privileged counters.
    """

    min_period = 1e-4

    def __init__(self, p_idle: float = 35.0, p_dyn: float = 65.0):
        self.p_idle, self.p_dyn = p_idle, p_dyn
        self._last = (time.monotonic(), time.process_time())

    def read(self, t: float | None = None) -> float:
        now, cpu = time.monotonic(), time.process_time()
        t0, c0 = self._last
        self._last = (now, cpu)
        dt = max(now - t0, 1e-9)
        util = min(max((cpu - c0) / dt, 0.0), os.cpu_count() or 1)
        return self.p_idle + self.p_dyn * util


class HostSensorBank:
    """Synchronized multi-channel host sensor (one rail per domain).

    Wraps named scalar host sensors into one instrument whose ``read``
    returns a ``[D]`` vector — the host-mode analogue of a multi-channel
    :class:`SensorSpec` bank (e.g. RAPL PKG + DRAM powercap zones read
    back-to-back). ``min_period`` is the slowest member's floor: the bank
    samples no faster than its most constrained channel.

    ``fallbacks`` maps domain names to substitute sensors: the first
    time a channel's sensor raises (or returns a non-finite reading),
    the bank fails over to the substitute *permanently* (a dead powercap
    zone does not resurrect mid-session; sticky failover also keeps the
    channel's readings from interleaving two instruments' calibrations)
    and counts the event in ``failover_events``. A channel with no
    fallback reads NaN from then on — the sampler drops those samples
    (counted) so the CIs widen instead of silently averaging zeros.
    """

    def __init__(self, channels: Sequence[tuple[str, object]],
                 fallbacks: Mapping[str, object] | None = None):
        if not channels:
            raise ValueError("sensor bank needs at least one channel")
        self.domains = tuple(name for name, _ in channels)
        if len(set(self.domains)) != len(self.domains):
            raise ValueError(f"duplicate domain names: {self.domains}")
        self._sensors = [s for _, s in channels]
        self._fallbacks = dict(fallbacks or {})
        unknown = set(self._fallbacks) - set(self.domains)
        if unknown:
            raise ValueError(f"fallback domains {sorted(unknown)} not in "
                             f"bank domains {self.domains}")
        self._dead = [False] * len(self._sensors)
        self.failover_events: dict[str, int] = {}
        self.min_period = max(getattr(s, "min_period", 0.0)
                              for s in self._sensors)

    def effective_min_period(self) -> float:
        """Slowest floor across members *and* their potential fallbacks
        (same arbitration as :meth:`SensorSpec.effective_min_period`)."""
        return max(self.min_period,
                   *(getattr(s, "min_period", 0.0)
                     for s in self._fallbacks.values()), 0.0)

    def _fail_over(self, j: int) -> None:
        d = self.domains[j]
        self.failover_events[d] = self.failover_events.get(d, 0) + 1
        fb = self._fallbacks.pop(d, None)
        if fb is not None:
            self._sensors[j] = fb
        else:
            self._dead[j] = True

    def read(self, t: float | None = None) -> np.ndarray:
        out = np.empty(len(self._sensors), dtype=np.float64)
        for j, s in enumerate(self._sensors):
            if self._dead[j]:
                out[j] = np.nan
                continue
            try:
                v = float(s.read(t))
            except Exception:
                self._fail_over(j)
                s = self._sensors[j]
                if self._dead[j]:
                    out[j] = np.nan
                    continue
                v = float(s.read(t))
            if not np.isfinite(v):
                self._fail_over(j)
                v = (float(self._sensors[j].read(t))
                     if not self._dead[j] else np.nan)
            out[j] = v
        return out


def available_host_sensor():
    """Best host sensor the environment permits."""
    try:
        return RaplSensor()
    except Exception:
        return ProcessActivitySensor()
