"""Power sensors with realistic sampling semantics (paper §3, §4.5).

Two families:

* **Trace sensors** read a synthesized :class:`~repro.core.timeline.Timeline`
  with the *exact semantics of the paper's instruments*:

  - :class:`RaplTraceSensor` — integrating energy counter (Sandy Bridge
    RAPL): a sample at time t returns (E(t) − E(t_prev)) / (t − t_prev);
    counter contents update only every ``update_period`` (1 ms on SNB).
  - :class:`Ina231TraceSensor` — window-averaging power meter (Exynos
    INA231): a sample returns mean power over [t − window, t]; minimum
    feasible window 280 µs in the paper.
  - :class:`InstantTraceSensor` — oracle P(t) (for unit tests).

* **Host sensors** read the real machine while host-mode profiling runs:

  - :class:`RaplSensor` — Linux powercap energy_uj, when readable.
  - :class:`ProcessActivitySensor` — fallback for unprivileged containers:
    models power from process CPU utilization (idle + dynamic·util),
    keeping the host demo self-contained.

All sensors expose ``read(t) -> watts`` plus ``min_period`` so the profiler
can refuse sampling faster than the instrument supports.
"""

from __future__ import annotations

import dataclasses
import os
import time

import numpy as np

from repro.core.timeline import Timeline

__all__ = [
    "SensorSpec", "DEFAULT_IDLE_POWER",
    "InstantTraceSensor", "RaplTraceSensor", "Ina231TraceSensor",
    "RaplSensor", "ProcessActivitySensor", "available_host_sensor",
]

# Near-idle package power blended into suspended-sample readings (§4.7);
# shared by the host sampler and the device pipeline so both overhead
# models emulate the same machine.
DEFAULT_IDLE_POWER = 70.0


@dataclasses.dataclass(frozen=True)
class SensorSpec:
    """Declarative trace-sensor semantics (hashable jit-cache key).

    The device-resident pipeline (:mod:`repro.core.device_pipeline`)
    re-implements each trace sensor as a *pure function* of the timeline's
    cumulative energy integral; this spec carries the parameters of that
    function without binding to a host-side Timeline. ``kind`` selects the
    emulation: ``instant`` (oracle P(t)), ``rapl`` (energy counter
    differenced between consecutive samples, quantized to
    ``update_period``), ``ina231`` (mean power over ``[t - window, t]``).
    """

    kind: str                    # "instant" | "rapl" | "ina231"
    update_period: float = 0.0   # rapl counter quantum [s]
    window: float = 0.0          # ina231 averaging window [s]
    min_period: float = 0.0      # instrument's fastest supported period [s]


class _TraceSensorBase:
    """Common precomputation for trace sensors."""

    def __init__(self, timeline: Timeline):
        self.tl = timeline
        self._ends = timeline.ends
        self._E = np.concatenate([[0.0], timeline.energy_integral()])
        self._bounds = np.concatenate([[0.0], self._ends])

    def _energy_at(self, t: np.ndarray) -> np.ndarray:
        """Exact cumulative energy E(t) for piecewise-constant power."""
        t = np.clip(np.asarray(t, dtype=np.float64), 0.0, self._bounds[-1])
        idx = np.searchsorted(self._bounds, t, side="right") - 1
        idx = np.clip(idx, 0, len(self.tl.powers) - 1)
        return self._E[idx] + (t - self._bounds[idx]) * self.tl.powers[idx]


class InstantTraceSensor(_TraceSensorBase):
    min_period = 0.0

    def read(self, t):
        return self.tl.power_at(t)

    @classmethod
    def make_spec(cls) -> SensorSpec:
        return SensorSpec(kind="instant")

    def spec(self) -> SensorSpec:
        return self.make_spec()


class RaplTraceSensor(_TraceSensorBase):
    """Integrating energy counter, differenced between consecutive samples.

    Matches §4.5: 'we measure power ... by dividing the energy consumed
    since the last sample by the length of the sampling period', with the
    counter updating once per ``update_period`` (1 ms on Sandy Bridge).
    """

    DEFAULT_UPDATE_PERIOD = 1e-3    # Sandy Bridge counter refresh (§4.5)

    def __init__(self, timeline: Timeline,
                 update_period: float = DEFAULT_UPDATE_PERIOD):
        super().__init__(timeline)
        self.update_period = update_period
        self.min_period = update_period

    @classmethod
    def make_spec(cls, update_period: float | None = None) -> SensorSpec:
        if update_period is None:
            update_period = cls.DEFAULT_UPDATE_PERIOD
        return SensorSpec(kind="rapl", update_period=update_period,
                          min_period=update_period)

    def spec(self) -> SensorSpec:
        return self.make_spec(self.update_period)

    def read_many(self, times: np.ndarray) -> np.ndarray:
        """Vectorized differencing over an increasing sample-time array."""
        times = np.asarray(times, dtype=np.float64)
        # Counter is quantized to its internal update period. The 1e-6
        # epsilon (in units of the period) keeps exact-boundary sample times
        # from flooring down a whole period due to fp division error.
        tq = np.floor(times / self.update_period + 1e-6) * self.update_period
        e = self._energy_at(tq)
        prev_t = np.concatenate([[max(tq[0] - self.update_period, 0.0)],
                                 tq[:-1]])
        prev_e = self._energy_at(prev_t)
        dt = np.maximum(tq - prev_t, self.update_period)
        return (e - prev_e) / dt


class Ina231TraceSensor(_TraceSensorBase):
    """Window-averaged power meter (TI INA231 semantics, §4.5)."""

    DEFAULT_WINDOW = 280e-6         # minimum feasible INA231 window (§4.5)

    def __init__(self, timeline: Timeline, window: float = DEFAULT_WINDOW):
        super().__init__(timeline)
        self.window = window
        self.min_period = window

    @classmethod
    def make_spec(cls, window: float | None = None) -> SensorSpec:
        if window is None:
            window = cls.DEFAULT_WINDOW
        return SensorSpec(kind="ina231", window=window, min_period=window)

    def spec(self) -> SensorSpec:
        return self.make_spec(self.window)

    def read(self, t):
        t = np.asarray(t, dtype=np.float64)
        lo = np.maximum(t - self.window, 0.0)
        de = self._energy_at(t) - self._energy_at(lo)
        dt = np.maximum(t - lo, 1e-12)
        return de / dt

    def read_many(self, times: np.ndarray) -> np.ndarray:
        return self.read(times)


# ---------------------------------------------------------------------------
# Host (real machine) sensors.
# ---------------------------------------------------------------------------

_RAPL_GLOB = "/sys/class/powercap/intel-rapl:0/energy_uj"


class RaplSensor:
    """Reads the Linux powercap RAPL energy counter (µJ), differenced."""

    min_period = 1e-3

    def __init__(self, path: str = _RAPL_GLOB):
        self.path = path
        self._last: tuple[float, float] | None = None
        with open(path) as f:       # raises if unreadable → caller falls back
            int(f.read())

    def read(self, t: float | None = None) -> float:
        now = time.monotonic() if t is None else t
        with open(self.path) as f:
            uj = int(f.read())
        if self._last is None:
            self._last = (now, uj)
            return 0.0
        t0, uj0 = self._last
        self._last = (now, uj)
        dt = max(now - t0, 1e-9)
        duj = uj - uj0
        if duj < 0:  # counter wrap
            return 0.0
        return duj * 1e-6 / dt


class ProcessActivitySensor:
    """Container-safe fallback: power modeled from process CPU utilization.

    P = p_idle + p_dyn · util, where util is the derivative of process CPU
    time. This keeps host-mode profiling honest (the 'sensor' responds to
    what the program actually does) without privileged counters.
    """

    min_period = 1e-4

    def __init__(self, p_idle: float = 35.0, p_dyn: float = 65.0):
        self.p_idle, self.p_dyn = p_idle, p_dyn
        self._last = (time.monotonic(), time.process_time())

    def read(self, t: float | None = None) -> float:
        now, cpu = time.monotonic(), time.process_time()
        t0, c0 = self._last
        self._last = (now, cpu)
        dt = max(now - t0, 1e-9)
        util = min(max((cpu - c0) / dt, 0.0), os.cpu_count() or 1)
        return self.p_idle + self.p_dyn * util


def available_host_sensor():
    """Best host sensor the environment permits."""
    try:
        return RaplSensor()
    except Exception:
        return ProcessActivitySensor()
