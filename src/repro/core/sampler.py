"""Systematic sampling (paper §4.6-§4.8).

Two samplers:

* :func:`sample_timeline` — vectorized systematic sampling of a synthesized
  :class:`Timeline`: sample times start at U(0, T) and advance by T plus a
  random timer-jitter term (the paper observes up to hundreds of µs of
  natural jitter, which is what makes systematic sampling safe against
  periodic aliasing — §4.6). Optional per-sample *suspension overhead*
  models ptrace-style stop-the-world reads (§4.7/§4.8): each sample
  stretches the interval it lands in by ``overhead_per_sample`` seconds of
  near-idle execution, biasing measured t_exec exactly as in Figures 4/5.

* :class:`HostSampler` — a real control thread (the §4.8 'separate control
  process'): the profiled program only updates a shared region marker; the
  thread samples (marker, sensor) pairs at the configured period without
  suspending the program.
"""

from __future__ import annotations

import dataclasses
import sys
import threading
import time
from typing import Callable

import numpy as np

from repro.core.faults import declare_site
from repro.core.sensors import DEFAULT_IDLE_POWER, idle_channel
from repro.core.timeline import Timeline

__all__ = ["SampleStream", "sample_timeline", "iter_sample_chunks",
           "iter_multiworker_chunks", "sample_timeline_multiworker",
           "HostSampler", "RegionMarker", "SampleBuffer"]

# Injection seam this module owns (see faults.FAULT_SITES): the
# HostSampler control thread (sampler_fail_after thread death).
_SITE_SAMPLER_LOOP = declare_site("sampler.loop")


@dataclasses.dataclass
class SampleStream:
    """Output of one profiling pass."""

    region_ids: np.ndarray   # [n] (or [n, workers] for multi-worker runs)
    powers: np.ndarray       # [n]
    t_exec: float            # measured wall time of the profiled run
    n: int
    overhead_time: float = 0.0   # systematic-error component (for reporting)


def _sample_times(t_end: float, period: float, jitter: float,
                  rng: np.random.Generator) -> np.ndarray:
    """Systematic sample times with uniform timer jitter, first at U(0,T)."""
    n_max = int(t_end / period) + 2
    deltas = period + rng.uniform(0.0, jitter, size=n_max)
    t = rng.uniform(0.0, period) + np.cumsum(deltas) - deltas[0]
    return t[t < t_end]


def sample_timeline(tl: Timeline, sensor, *, period: float,
                    jitter: float = 200e-6, overhead_per_sample: float = 0.0,
                    idle_power: float = DEFAULT_IDLE_POWER, seed: int = 0,
                    deliberate_alias: bool = False) -> SampleStream:
    """One-pass systematic sampling of a synthesized timeline.

    Args:
      sensor: a trace sensor over ``tl`` (``read_many``/``read``).
      period: sampling period T [s] (paper default 10 ms).
      jitter: uniform upper bound of per-sample timer jitter [s]. Set to 0
        together with ``deliberate_alias`` in tests to *demonstrate* the
        aliasing pathology of exact systematic sampling.
      overhead_per_sample: suspension cost per sample [s]; models the
        ptrace-style control-process read. The profiled run's measured
        t_exec inflates by n·overhead and sampled power during suspension
        windows is near idle, producing the systematic error term of §4.7.
    """
    rng = np.random.default_rng(seed)
    if period < getattr(sensor, "min_period", 0.0):
        raise ValueError(
            f"sampling period {period} below sensor minimum "
            f"{sensor.min_period}")
    if deliberate_alias:
        jitter = 0.0
    times = _sample_times(tl.t_exec, period, jitter, rng)
    n = len(times)
    if n == 0:
        raise ValueError("run too short for sampling period")
    rids = tl.region_at(times)
    if hasattr(sensor, "read_many"):
        pows = np.asarray(sensor.read_many(times), dtype=np.float64)
    else:
        pows = np.asarray(sensor.read(times), dtype=np.float64)

    overhead_time = n * overhead_per_sample
    t_exec_measured = tl.t_exec + overhead_time
    if overhead_per_sample > 0.0:
        # During suspension the program makes no progress but the package
        # still burns near-idle power; RAPL-style differencing mixes that
        # into the sample. Blend proportionally to overhead per period.
        frac = min(overhead_per_sample / period, 1.0)
        pows = (1.0 - frac) * pows + frac * idle_power
    return SampleStream(region_ids=rids, powers=pows,
                        t_exec=t_exec_measured, n=n,
                        overhead_time=overhead_time)


class _ChunkedTimes:
    """Systematic sample-time generator emitting bounded chunks.

    Same process as :func:`_sample_times` (first sample at U(0, T), then
    advance by T + U(0, jitter)) but drawn ``chunk_size`` deltas at a time,
    so an arbitrarily long run needs O(chunk) memory for times.
    """

    def __init__(self, t_end: float, period: float, jitter: float,
                 rng: np.random.Generator, chunk_size: int):
        self._t_end = t_end
        self._period = period
        self._jitter = jitter
        self._rng = rng
        self._chunk = chunk_size
        self._next_t = float(rng.uniform(0.0, period))

    def __iter__(self):
        while self._next_t < self._t_end:
            deltas = self._period + self._rng.uniform(
                0.0, self._jitter, size=self._chunk)
            times = self._next_t + np.concatenate(
                [[0.0], np.cumsum(deltas[:-1])])
            self._next_t = float(times[-1] + deltas[-1])
            times = times[times < self._t_end]
            if len(times):
                yield times


def iter_sample_chunks(tl: Timeline, sensor, *, period: float,
                       jitter: float = 200e-6,
                       overhead_per_sample: float = 0.0,
                       idle_power: float = DEFAULT_IDLE_POWER, seed: int = 0,
                       chunk_size: int = 65536):
    """Streaming counterpart of :func:`sample_timeline`.

    Yields (region_ids, powers) chunks of ≤ ``chunk_size`` samples without
    ever materializing the full stream — feed to
    ``streaming.StreamingAggregator``. Draws a different (statistically
    equivalent) jitter sequence than the one-shot path for the same seed.
    """
    rng = np.random.default_rng(seed)
    if period < getattr(sensor, "min_period", 0.0):
        raise ValueError(f"sampling period {period} below sensor minimum "
                         f"{sensor.min_period}")
    frac = min(overhead_per_sample / period, 1.0) if overhead_per_sample > 0.0 \
        else 0.0
    # Multi-rail timelines read the sensor's whole channel bank — chunks
    # are then ([c], [c, D]) and the consuming aggregator keeps the
    # per-domain decomposition. Scalar timelines keep the 1-D contract.
    rails = tl.num_domains > 1 and hasattr(sensor, "read_rails")
    for times in _ChunkedTimes(tl.t_exec, period, jitter, rng, chunk_size):
        rids = tl.region_at(times)
        if rails:
            pows = np.asarray(sensor.read_rails(times), dtype=np.float64)
            bad = np.isnan(pows).any(axis=1)
            if bad.any():
                # A masked sensor channel (failover with no substitute,
                # cf. sensors.FailoverTraceBank) voids the whole sample:
                # dropping the row shrinks n — the CI widens honestly —
                # whereas imputing any value would bias that rail.
                keep = ~bad
                rids, pows = rids[keep], pows[keep]
                if not len(rids):
                    continue
        elif hasattr(sensor, "read_many"):
            pows = np.asarray(sensor.read_many(times), dtype=np.float64)
        else:
            pows = np.asarray(sensor.read(times), dtype=np.float64)
        if frac:
            pows = (1.0 - frac) * pows
            if pows.ndim == 2:
                # Suspension idle power lands on the package rail
                # (located by name), mirroring the device pipeline.
                pows[:, idle_channel(tl.domain_names)] += frac * idle_power
            else:
                pows = pows + frac * idle_power
        yield rids, pows


def iter_multiworker_chunks(timelines: list[Timeline], sensor_fn, *,
                            period: float, jitter: float = 200e-6,
                            seed: int = 0, chunk_size: int = 65536):
    """Streaming counterpart of :func:`sample_timeline_multiworker`.

    Yields ([c, workers] region-id matrices, [c] summed powers) chunks —
    feed to ``streaming.StreamingCombinationAggregator``.
    """
    rng = np.random.default_rng(seed)
    t_end = min(tl.t_exec for tl in timelines)
    sensors = [sensor_fn(tl) for tl in timelines]
    rails = (all(tl.num_domains > 1 for tl in timelines)
             and all(hasattr(s, "read_rails") for s in sensors))
    for times in _ChunkedTimes(t_end, period, jitter, rng, chunk_size):
        rid_mat = np.stack([tl.region_at(times) for tl in timelines], axis=1)
        if rails:
            total_power = sum(np.asarray(s.read_rails(times))
                              for s in sensors)
        else:
            total_power = sum(np.asarray(s.read_many(times)
                                         if hasattr(s, "read_many")
                                         else s.read(times))
                              for s in sensors)
        yield rid_mat, total_power


def sample_timeline_multiworker(timelines: list[Timeline], sensor_fn,
                                *, period: float, jitter: float = 200e-6,
                                seed: int = 0) -> SampleStream:
    """Sample W concurrent worker timelines simultaneously (§4.4).

    Each sample is a vector of region ids — one per worker — plus one shared
    package power reading (sum of per-worker powers + contention handled by
    the caller's power model when the timelines were synthesized).
    """
    rng = np.random.default_rng(seed)
    t_end = min(tl.t_exec for tl in timelines)
    times = _sample_times(t_end, period, jitter, rng)
    rid_mat = np.stack([tl.region_at(times) for tl in timelines], axis=1)
    total_power = sum(np.asarray(sensor_fn(tl).read_many(times)
                                 if hasattr(sensor_fn(tl), "read_many")
                                 else sensor_fn(tl).read(times))
                      for tl in timelines)
    return SampleStream(region_ids=rid_mat, powers=total_power,
                        t_exec=t_end, n=len(times))


# ---------------------------------------------------------------------------
# Host-mode control thread.
# ---------------------------------------------------------------------------


class RegionMarker:
    """Shared 'program counter' cell: region code writes, sampler reads.

    Reads/writes of a Python int are atomic under the GIL, so the profiled
    program's only instrumentation cost is one attribute store per region
    entry — the §4.8 design point (no sampling code on the critical path).
    """

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0

    def set(self, region_id: int) -> None:
        self.value = region_id


class SampleBuffer:
    """Growable preallocated (region_id, power) buffer.

    The control thread's hot path is two array stores + an index bump —
    no per-sample Python object boxing or list resizing (paper's ~1%
    overhead budget, §4.8). Capacity doubles when full (amortized O(1)).
    ``drain()`` empties the buffer, so streaming consumers that drain
    periodically hold O(drain chunk) state — capacity is bounded by the
    largest inter-drain burst, not run length. The lock is uncontended
    except at drain points (≪ the ≥1 ms sampling period).

    ``channels > 1`` stores one power vector per sample (multi-rail host
    sensor banks, :class:`repro.core.sensors.HostSensorBank`); drains
    then yield [n, channels] power matrices instead of [n] vectors.

    ``max_capacity`` bounds growth: once the buffer holds that many
    undrained samples, further appends are *dropped and counted*
    (:attr:`overruns`) instead of growing without bound — a consumer
    stalled for longer than the burst budget (e.g. a long prefill loop
    that never drains) loses the newest samples, never corrupts the
    stream, and the loss is observable. ``None`` (default) keeps the
    unbounded doubling behavior.
    """

    def __init__(self, capacity: int = 4096, channels: int = 1,
                 max_capacity: int | None = None):
        if channels < 1:
            raise ValueError(f"channels must be >= 1; got {channels}")
        if max_capacity is not None and max_capacity < 1:
            raise ValueError(
                f"max_capacity must be >= 1; got {max_capacity}")
        self.channels = channels
        cap = max(capacity, 16)
        if max_capacity is not None:
            cap = min(cap, max_capacity)
        self.max_capacity = max_capacity
        self._rids = np.empty(cap, dtype=np.int32)
        self._pows = np.empty((cap, channels), dtype=np.float64)
        self._n = 0
        self.overruns = 0
        self._lock = threading.Lock()

    def __len__(self) -> int:
        return self._n

    def append(self, rid: int, power) -> None:
        with self._lock:
            n = self._n
            if n == len(self._rids):
                if (self.max_capacity is not None
                        and n >= self.max_capacity):
                    self.overruns += 1
                    return
                grow = len(self._rids)
                if self.max_capacity is not None:
                    grow = min(grow, self.max_capacity - n)
                self._rids = np.concatenate(
                    [self._rids, np.empty(grow, dtype=self._rids.dtype)])
                self._pows = np.concatenate(
                    [self._pows,
                     np.empty((grow, self.channels),
                              dtype=self._pows.dtype)])
            self._rids[n] = rid
            self._pows[n] = power      # scalar broadcasts; vector stores
            self._n = n + 1

    def _pow_slice(self, n: int) -> np.ndarray:
        p = self._pows[:n]
        return p[:, 0].copy() if self.channels == 1 else p.copy()

    def view(self) -> tuple[np.ndarray, np.ndarray]:
        """All undrained samples (copies); does not advance the cursor."""
        with self._lock:
            return self._rids[:self._n].copy(), self._pow_slice(self._n)

    def drain(self) -> tuple[np.ndarray, np.ndarray]:
        """All undrained samples (copies); empties the buffer."""
        with self._lock:
            n = self._n
            out = self._rids[:n].copy(), self._pow_slice(n)
            self._n = 0
            return out


class HostSampler:
    """Control thread sampling (marker, sensor) at a jittered period.

    Failure semantics: the control thread runs as a daemon, so an
    exception inside it (a sensor read blowing up mid-session) would
    otherwise kill the thread silently and every later ``drain()`` would
    return empty forever — zero-sample estimates indistinguishable from
    a genuinely idle program. The loop therefore captures the exception
    and re-raises it on the *caller's* thread at the next ``drain()`` /
    ``stream()`` / session exit. Non-finite readings (a masked channel
    of a failing :class:`~repro.core.sensors.HostSensorBank`) are not
    errors: the sample is skipped and counted in ``dropped_samples``.
    """

    def __init__(self, marker: RegionMarker, sensor, *, period: float,
                 jitter: float = 200e-6, seed: int = 0,
                 buffer_capacity: int | None = None,
                 faults: "object | None" = None):
        from repro.core import faults as faults_mod
        self.marker = marker
        self.sensor = sensor
        # A banked sensor (``.domains``) reads one vector per sample; the
        # buffer stores it per channel and drains [n, D] power matrices.
        self.domains = tuple(getattr(sensor, "domains", ("total",)))
        self.period = period
        self.jitter = jitter
        self._rng = np.random.default_rng(seed)
        self._stop = threading.Event()
        self._thread: threading.Thread | None = None
        self._buf = SampleBuffer(channels=len(self.domains),
                                 max_capacity=buffer_capacity)
        self._t0 = 0.0
        self._t1 = 0.0
        # Captured at construction: contextvars set by the caller are
        # invisible inside the control thread.
        self._faults = faults_mod.resolve_plan(faults)
        self._failure: BaseException | None = None
        self.dropped_samples = 0

    def _loop(self) -> None:
        try:
            self._loop_body()
        except BaseException as e:          # noqa: BLE001 — re-raised at drain
            self._failure = e

    def _loop_body(self) -> None:
        read = self.sensor.read
        append = self._buf.append
        marker = self.marker
        uniform = self._rng.uniform
        plan = self._faults
        taken = 0
        # Schedule against absolute deadlines: sleeping a fixed period
        # *after* read()/append() return would stretch the effective
        # period by the read cost every sample (systematic drift above
        # the configured rate). If a read overruns its deadline entirely,
        # rebase instead of bursting to catch up.
        scalar = not hasattr(self.sensor, "domains")
        next_t = time.monotonic()
        while not self._stop.is_set():
            if plan is not None and plan.sampler_should_fail(taken):
                raise RuntimeError(
                    f"injected sampler-thread fault after {taken} samples")
            v = float(read()) if scalar else read()
            taken += 1
            finite = np.isfinite(v) if scalar else bool(np.isfinite(v).all())
            if finite:
                append(marker.value, v)
            else:
                self.dropped_samples += 1
            next_t += self.period + float(uniform(0, self.jitter))
            delay = next_t - time.monotonic()
            if delay > 0:
                time.sleep(delay)
            else:
                next_t = time.monotonic()

    def _raise_failure(self) -> None:
        if self._failure is not None:
            failure, self._failure = self._failure, None
            raise failure

    def __enter__(self) -> "HostSampler":
        # CPython's default 5 ms GIL switch interval would let a CPU-bound
        # profiled region starve the control thread (the ptrace analogue
        # never has this problem since it runs in another process). Tighten
        # it for the session; restored on exit.
        self._old_switch = sys.getswitchinterval()
        sys.setswitchinterval(min(self._old_switch, self.period / 4.0))
        self._t0 = time.monotonic()
        self._thread = threading.Thread(target=self._loop, daemon=True,
                                        name="alea-control")
        self._thread.start()
        return self

    def __exit__(self, *exc) -> None:
        self._t1 = time.monotonic()
        self._stop.set()
        assert self._thread is not None
        self._thread.join(timeout=5.0)
        sys.setswitchinterval(self._old_switch)
        # Surface a control-thread death even from sessions that never
        # drain — but never mask an exception already unwinding the body.
        if not any(exc):
            self._raise_failure()

    def drain(self) -> tuple[np.ndarray, np.ndarray]:
        """New (region_ids, powers) since the last drain (streaming use).

        Empties the buffer — a session either drains periodically into a
        streaming aggregator or collects everything for :meth:`stream`;
        after any drain, ``stream()`` only covers the undrained tail.

        Raises the control thread's captured exception, if it died since
        the last call (each failure is raised exactly once).
        """
        self._raise_failure()
        return self._buf.drain()

    @property
    def buffer_overruns(self) -> int:
        """Samples dropped because the bounded buffer was full at append
        time (see :class:`SampleBuffer`). Always 0 when unbounded."""
        return self._buf.overruns

    @property
    def elapsed(self) -> float:
        """Session wall time so far (final once the sampler exits)."""
        end = self._t1 if self._t1 > self._t0 else time.monotonic()
        return end - self._t0

    def stream(self) -> SampleStream:
        self._raise_failure()
        if not len(self._buf):
            raise RuntimeError("no samples collected")
        rids, pows = self._buf.view()
        return SampleStream(region_ids=rids, powers=pows,
                            t_exec=self._t1 - self._t0, n=len(rids))
