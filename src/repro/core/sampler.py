"""Systematic sampling (paper §4.6-§4.8).

Two samplers:

* :func:`sample_timeline` — vectorized systematic sampling of a synthesized
  :class:`Timeline`: sample times start at U(0, T) and advance by T plus a
  random timer-jitter term (the paper observes up to hundreds of µs of
  natural jitter, which is what makes systematic sampling safe against
  periodic aliasing — §4.6). Optional per-sample *suspension overhead*
  models ptrace-style stop-the-world reads (§4.7/§4.8): each sample
  stretches the interval it lands in by ``overhead_per_sample`` seconds of
  near-idle execution, biasing measured t_exec exactly as in Figures 4/5.

* :class:`HostSampler` — a real control thread (the §4.8 'separate control
  process'): the profiled program only updates a shared region marker; the
  thread samples (marker, sensor) pairs at the configured period without
  suspending the program.
"""

from __future__ import annotations

import dataclasses
import sys
import threading
import time
from typing import Callable

import numpy as np

from repro.core.timeline import Timeline

__all__ = ["SampleStream", "sample_timeline", "HostSampler", "RegionMarker"]


@dataclasses.dataclass
class SampleStream:
    """Output of one profiling pass."""

    region_ids: np.ndarray   # [n] (or [n, workers] for multi-worker runs)
    powers: np.ndarray       # [n]
    t_exec: float            # measured wall time of the profiled run
    n: int
    overhead_time: float = 0.0   # systematic-error component (for reporting)


def _sample_times(t_end: float, period: float, jitter: float,
                  rng: np.random.Generator) -> np.ndarray:
    """Systematic sample times with uniform timer jitter, first at U(0,T)."""
    n_max = int(t_end / period) + 2
    deltas = period + rng.uniform(0.0, jitter, size=n_max)
    t = rng.uniform(0.0, period) + np.cumsum(deltas) - deltas[0]
    return t[t < t_end]


def sample_timeline(tl: Timeline, sensor, *, period: float,
                    jitter: float = 200e-6, overhead_per_sample: float = 0.0,
                    idle_power: float = 70.0, seed: int = 0,
                    deliberate_alias: bool = False) -> SampleStream:
    """One-pass systematic sampling of a synthesized timeline.

    Args:
      sensor: a trace sensor over ``tl`` (``read_many``/``read``).
      period: sampling period T [s] (paper default 10 ms).
      jitter: uniform upper bound of per-sample timer jitter [s]. Set to 0
        together with ``deliberate_alias`` in tests to *demonstrate* the
        aliasing pathology of exact systematic sampling.
      overhead_per_sample: suspension cost per sample [s]; models the
        ptrace-style control-process read. The profiled run's measured
        t_exec inflates by n·overhead and sampled power during suspension
        windows is near idle, producing the systematic error term of §4.7.
    """
    rng = np.random.default_rng(seed)
    if period < getattr(sensor, "min_period", 0.0):
        raise ValueError(
            f"sampling period {period} below sensor minimum "
            f"{sensor.min_period}")
    if deliberate_alias:
        jitter = 0.0
    times = _sample_times(tl.t_exec, period, jitter, rng)
    n = len(times)
    if n == 0:
        raise ValueError("run too short for sampling period")
    rids = tl.region_at(times)
    if hasattr(sensor, "read_many"):
        pows = np.asarray(sensor.read_many(times), dtype=np.float64)
    else:
        pows = np.asarray(sensor.read(times), dtype=np.float64)

    overhead_time = n * overhead_per_sample
    t_exec_measured = tl.t_exec + overhead_time
    if overhead_per_sample > 0.0:
        # During suspension the program makes no progress but the package
        # still burns near-idle power; RAPL-style differencing mixes that
        # into the sample. Blend proportionally to overhead per period.
        frac = min(overhead_per_sample / period, 1.0)
        pows = (1.0 - frac) * pows + frac * idle_power
    return SampleStream(region_ids=rids, powers=pows,
                        t_exec=t_exec_measured, n=n,
                        overhead_time=overhead_time)


def sample_timeline_multiworker(timelines: list[Timeline], sensor_fn,
                                *, period: float, jitter: float = 200e-6,
                                seed: int = 0) -> SampleStream:
    """Sample W concurrent worker timelines simultaneously (§4.4).

    Each sample is a vector of region ids — one per worker — plus one shared
    package power reading (sum of per-worker powers + contention handled by
    the caller's power model when the timelines were synthesized).
    """
    rng = np.random.default_rng(seed)
    t_end = min(tl.t_exec for tl in timelines)
    times = _sample_times(t_end, period, jitter, rng)
    rid_mat = np.stack([tl.region_at(times) for tl in timelines], axis=1)
    total_power = sum(np.asarray(sensor_fn(tl).read_many(times)
                                 if hasattr(sensor_fn(tl), "read_many")
                                 else sensor_fn(tl).read(times))
                      for tl in timelines)
    return SampleStream(region_ids=rid_mat, powers=total_power,
                        t_exec=t_end, n=len(times))


# ---------------------------------------------------------------------------
# Host-mode control thread.
# ---------------------------------------------------------------------------


class RegionMarker:
    """Shared 'program counter' cell: region code writes, sampler reads.

    Reads/writes of a Python int are atomic under the GIL, so the profiled
    program's only instrumentation cost is one attribute store per region
    entry — the §4.8 design point (no sampling code on the critical path).
    """

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0

    def set(self, region_id: int) -> None:
        self.value = region_id


class HostSampler:
    """Control thread sampling (marker, sensor) at a jittered period."""

    def __init__(self, marker: RegionMarker, sensor, *, period: float,
                 jitter: float = 200e-6, seed: int = 0):
        self.marker = marker
        self.sensor = sensor
        self.period = period
        self.jitter = jitter
        self._rng = np.random.default_rng(seed)
        self._stop = threading.Event()
        self._thread: threading.Thread | None = None
        self._rids: list[int] = []
        self._pows: list[float] = []
        self._t0 = 0.0
        self._t1 = 0.0

    def _loop(self) -> None:
        read = self.sensor.read
        while not self._stop.is_set():
            self._rids.append(self.marker.value)
            self._pows.append(float(read()))
            time.sleep(self.period + float(self._rng.uniform(0, self.jitter)))

    def __enter__(self) -> "HostSampler":
        # CPython's default 5 ms GIL switch interval would let a CPU-bound
        # profiled region starve the control thread (the ptrace analogue
        # never has this problem since it runs in another process). Tighten
        # it for the session; restored on exit.
        self._old_switch = sys.getswitchinterval()
        sys.setswitchinterval(min(self._old_switch, self.period / 4.0))
        self._t0 = time.monotonic()
        self._thread = threading.Thread(target=self._loop, daemon=True,
                                        name="alea-control")
        self._thread.start()
        return self

    def __exit__(self, *exc) -> None:
        self._t1 = time.monotonic()
        self._stop.set()
        assert self._thread is not None
        self._thread.join(timeout=5.0)
        sys.setswitchinterval(self._old_switch)

    def stream(self) -> SampleStream:
        if not self._rids:
            raise RuntimeError("no samples collected")
        return SampleStream(region_ids=np.asarray(self._rids, dtype=np.int32),
                            powers=np.asarray(self._pows, dtype=np.float64),
                            t_exec=self._t1 - self._t0, n=len(self._rids))
