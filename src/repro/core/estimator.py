"""ALEA probabilistic estimators (paper §4.1-§4.4, Eqs. 2-16).

The estimator consumes a stream of simultaneous (region_id, power) samples
taken at a systematic period and produces, per region (paper: basic block):

  - execution-time estimate   t̂ = (n_bb / n) · t_exec          (Eq. 5)
  - mean-power estimate       p̂ow = mean(power samples of bb)  (Eq. 6)
  - energy estimate           ê = p̂ow · t̂                      (Eq. 7)
  - Wald confidence interval on the time proportion (Eqs. 8-10)
  - normal confidence interval on power (Eqs. 12-15)
  - product confidence interval on energy (Eq. 16)

Multi-worker profiling (§4.4) attributes time/energy to *combinations* of
regions sampled simultaneously across workers (threads in the paper; chips
or hosts here), because shared-resource contention makes per-worker
apportioning unsound.

Everything is vectorized end to end: the aggregation hot spot (counts /
power sums / power sums-of-squares per region) is pluggable so the Pallas
``kernels.sample_attr`` kernel can take over on TPU for fleet-scale sample
streams, and estimate construction itself is pure numpy column math over an
:class:`EstimateTable` — :class:`RegionEstimate` rows are lazy views, so
10⁴–10⁵ multi-worker combinations cost array ops, not Python-loop time.

Two consumption modes share this module's math:

  * one-shot — :func:`estimate_regions` over in-memory arrays (this file);
  * streaming — :class:`repro.core.streaming.StreamingAggregator` folds
    sample *chunks* into (counts, Σpow, Σpow²) accumulators behind the same
    ``AggregateFn`` seam and calls :func:`estimates_from_statistics` at the
    end, so fleet-scale runs never materialize the full stream.
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import Callable, Mapping, Sequence

import numpy as np

__all__ = [
    "AggregateFn",
    "RegionEstimate",
    "EstimateTable",
    "EstimateSet",
    "aggregate_samples_np",
    "estimate_regions",
    "estimates_from_statistics",
    "estimate_combinations",
    "z_quantile",
]


def z_quantile(alpha: float) -> float:
    """``z_{alpha/2}``: the 1 - alpha/2 percentile of the standard normal.

    Uses the Acklam inverse-CDF approximation (|rel err| < 1.15e-9); avoids a
    scipy dependency and is exact enough for CI construction.
    """
    p = 1.0 - alpha / 2.0
    if not 0.0 < p < 1.0:
        raise ValueError(f"alpha must be in (0, 2); got alpha={alpha}")
    # Acklam's algorithm.
    a = (-3.969683028665376e+01, 2.209460984245205e+02, -2.759285104469687e+02,
         1.383577518672690e+02, -3.066479806614716e+01, 2.506628277459239e+00)
    b = (-5.447609879822406e+01, 1.615858368580409e+02, -1.556989798598866e+02,
         6.680131188771972e+01, -1.328068155288572e+01)
    c = (-7.784894002430293e-03, -3.223964580411365e-01, -2.400758277161838e+00,
         -2.549732539343734e+00, 4.374664141464968e+00, 2.938163982698783e+00)
    d = (7.784695709041462e-03, 3.224671290700398e-01, 2.445134137142996e+00,
         3.754408661907416e+00)
    plow, phigh = 0.02425, 1 - 0.02425
    if p < plow:
        q = math.sqrt(-2 * math.log(p))
        return (((((c[0] * q + c[1]) * q + c[2]) * q + c[3]) * q + c[4]) * q + c[5]) / \
               ((((d[0] * q + d[1]) * q + d[2]) * q + d[3]) * q + 1)
    if p <= phigh:
        q = p - 0.5
        r = q * q
        return (((((a[0] * r + a[1]) * r + a[2]) * r + a[3]) * r + a[4]) * r + a[5]) * q / \
               (((((b[0] * r + b[1]) * r + b[2]) * r + b[3]) * r + b[4]) * r + 1)
    q = math.sqrt(-2 * math.log(1 - p))
    return -(((((c[0] * q + c[1]) * q + c[2]) * q + c[3]) * q + c[4]) * q + c[5]) / \
           ((((d[0] * q + d[1]) * q + d[2]) * q + d[3]) * q + 1)


@dataclasses.dataclass(frozen=True)
class RegionEstimate:
    """Per-region (or per-combination) ALEA estimates with CIs.

    ``pow_rails``/``e_rails`` carry the per-domain decomposition (aligned
    with ``domains``) when the profiling run measured multiple power
    rails; single-rail runs leave them ``None`` — nothing else changes.
    """

    region_id: int
    name: str
    n_samples: int            # n_bb
    p_hat: float              # Eq. 4
    t_hat: float              # Eq. 5  [s]
    t_lo: float               # Eq. 11 lower
    t_hi: float               # Eq. 11 upper
    pow_hat: float            # Eq. 6  [W]
    pow_lo: float             # Eq. 13
    pow_hi: float             # Eq. 12
    e_hat: float              # Eq. 7  [J]
    e_lo: float               # Eq. 16 lower
    e_hi: float               # Eq. 16 upper
    ci_valid: bool            # Wald validity: n·p̂>5 and n·(1-p̂)>5 (§4.3)
    domains: tuple[str, ...] | None = None
    pow_rails: tuple[float, ...] | None = None   # Eq. 6 per rail [W]
    e_rails: tuple[float, ...] | None = None     # Eq. 7 per rail [J]

    @property
    def t_ci_halfwidth(self) -> float:
        return 0.5 * (self.t_hi - self.t_lo)

    def energy_by_domain(self) -> Mapping[str, float]:
        """Per-domain energy split of this region (empty if single-rail)."""
        if self.domains is None:
            return {}
        return dict(zip(self.domains, self.e_rails))


@dataclasses.dataclass(frozen=True)
class EstimateTable:
    """Columnar per-region estimates (one numpy array per statistic).

    The vectorized ``_build_estimates`` produces this directly; it is the
    storage format for fleet-scale runs where the combination table reaches
    10⁴–10⁵ rows. :class:`RegionEstimate` objects are materialized lazily
    per row via :meth:`row` / :meth:`rows`.
    """

    region_ids: np.ndarray    # int64 [k]
    names: tuple[str, ...]    # len k (aligned with rows, not global ids)
    n_samples: np.ndarray     # int64 [k]
    p_hat: np.ndarray         # float64 [k]
    t_hat: np.ndarray
    t_lo: np.ndarray
    t_hi: np.ndarray
    pow_hat: np.ndarray
    pow_lo: np.ndarray
    pow_hi: np.ndarray
    e_hat: np.ndarray
    e_lo: np.ndarray
    e_hi: np.ndarray
    ci_valid: np.ndarray      # bool [k]
    # Per-domain decomposition (multi-rail runs only; None otherwise).
    domains: tuple[str, ...] | None = None
    pow_rails: np.ndarray | None = None      # float64 [k, D]
    pow_rails_lo: np.ndarray | None = None   # per-rail power CI (Eq. 12-14)
    pow_rails_hi: np.ndarray | None = None
    e_rails: np.ndarray | None = None        # float64 [k, D]
    e_rails_lo: np.ndarray | None = None     # per-rail Eq. 16 product CI
    e_rails_hi: np.ndarray | None = None

    def __len__(self) -> int:
        return len(self.region_ids)

    def row(self, i: int) -> RegionEstimate:
        """Materialize one row as a RegionEstimate view."""
        rails = {}
        if self.domains is not None:
            rails = dict(
                domains=self.domains,
                pow_rails=tuple(float(x) for x in self.pow_rails[i]),
                e_rails=tuple(float(x) for x in self.e_rails[i]))
        return RegionEstimate(
            region_id=int(self.region_ids[i]), name=self.names[i],
            n_samples=int(self.n_samples[i]), p_hat=float(self.p_hat[i]),
            t_hat=float(self.t_hat[i]), t_lo=float(self.t_lo[i]),
            t_hi=float(self.t_hi[i]), pow_hat=float(self.pow_hat[i]),
            pow_lo=float(self.pow_lo[i]), pow_hi=float(self.pow_hi[i]),
            e_hat=float(self.e_hat[i]), e_lo=float(self.e_lo[i]),
            e_hi=float(self.e_hi[i]), ci_valid=bool(self.ci_valid[i]),
            **rails)

    def rows(self) -> tuple[RegionEstimate, ...]:
        return tuple(self.row(i) for i in range(len(self)))

    @classmethod
    def from_rows(cls, rows: Sequence[RegionEstimate]) -> "EstimateTable":
        def col(attr, dtype):
            return np.array([getattr(r, attr) for r in rows], dtype=dtype)
        return cls(
            region_ids=col("region_id", np.int64),
            names=tuple(r.name for r in rows),
            n_samples=col("n_samples", np.int64),
            p_hat=col("p_hat", np.float64), t_hat=col("t_hat", np.float64),
            t_lo=col("t_lo", np.float64), t_hi=col("t_hi", np.float64),
            pow_hat=col("pow_hat", np.float64),
            pow_lo=col("pow_lo", np.float64),
            pow_hi=col("pow_hi", np.float64),
            e_hat=col("e_hat", np.float64), e_lo=col("e_lo", np.float64),
            e_hi=col("e_hi", np.float64), ci_valid=col("ci_valid", bool))


@dataclasses.dataclass(frozen=True)
class EstimateSet:
    """All region estimates from one profiling pass.

    Backed by a columnar :class:`EstimateTable`; ``regions`` is a lazily
    cached tuple of per-row views, so existing consumers keep iterating
    RegionEstimate objects while array consumers read ``table`` columns.
    """

    table: EstimateTable
    n_total: int
    t_exec: float
    alpha: float
    # Fleet-coverage provenance of a degraded gather (the
    # ``GatherResult.coverage()`` dict of :mod:`repro.core.exchange`):
    # which hosts merged at which epoch, which were missing / stale /
    # quarantined. None means the statistics were not fleet-gathered or
    # the gather was strict (all-or-nothing), i.e. coverage is total.
    coverage: Mapping | None = None
    # Bounded-state (heavy-hitters) disclosure: when the combination
    # table ran with a top-k + per-region ``other`` tier, this carries
    # the fold counters ({"k", "resident", "other_rows", "tail_folds",
    # "evictions"}) that back the report's TAIL line. None for exact
    # tables — per-row identity is complete.
    tail: Mapping | None = None

    @classmethod
    def from_regions(cls, regions: Sequence[RegionEstimate], n_total: int,
                     t_exec: float, alpha: float) -> "EstimateSet":
        return cls(table=EstimateTable.from_rows(tuple(regions)),
                   n_total=n_total, t_exec=t_exec, alpha=alpha)

    @property
    def complete_coverage(self) -> bool:
        """False only when attached gather provenance says hosts are
        missing, stale or quarantined."""
        return self.coverage is None or bool(self.coverage.get("complete"))

    @functools.cached_property
    def regions(self) -> tuple[RegionEstimate, ...]:
        return self.table.rows()

    def by_name(self) -> Mapping[str, RegionEstimate]:
        return {r.name: r for r in self.regions}

    @property
    def total_energy(self) -> float:
        return float(self.table.e_hat.sum())

    @property
    def total_time(self) -> float:
        return float(self.table.t_hat.sum())

    @property
    def domains(self) -> tuple[str, ...] | None:
        """Power-rail domain names of a multi-rail run, else None."""
        return self.table.domains

    def energy_by_domain(self) -> Mapping[str, float]:
        """Whole-run energy per power rail (empty for single-rail runs)."""
        if self.table.domains is None:
            return {}
        return {d: float(self.table.e_rails[:, j].sum())
                for j, d in enumerate(self.table.domains)}

    def dominant(self, k: int = 1) -> tuple[RegionEstimate, ...]:
        """Top-k regions by estimated energy (hotspot analysis, §7.1)."""
        idx = np.argsort(-self.table.e_hat, kind="stable")[:k]
        return tuple(self.table.row(int(i)) for i in idx)


AggregateFn = Callable[[np.ndarray, np.ndarray, int],
                       tuple[np.ndarray, np.ndarray, np.ndarray]]


def aggregate_samples_np(region_ids: np.ndarray, powers: np.ndarray,
                         num_regions: int) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Reference aggregation: per-region sample counts, Σpow, Σpow².

    This is the tool's aggregation hot spot (one entry per sample; fleets
    collect billions). ``kernels/sample_attr`` provides the tiled Pallas
    equivalent; both must match this exactly.
    """
    region_ids = np.asarray(region_ids)
    powers = np.asarray(powers, dtype=np.float64)
    counts = np.bincount(region_ids, minlength=num_regions).astype(np.int64)
    psum = np.bincount(region_ids, weights=powers, minlength=num_regions)
    psumsq = np.bincount(region_ids, weights=powers * powers, minlength=num_regions)
    return counts, psum, psumsq


def _build_estimates(counts: np.ndarray, psum: np.ndarray, psumsq: np.ndarray,
                     names: Sequence[str], t_exec: float, alpha: float,
                     drop_empty: bool, rail_psum: np.ndarray | None = None,
                     rail_psumsq: np.ndarray | None = None,
                     domains: Sequence[str] | None = None,
                     coverage: Mapping | None = None,
                     tail: Mapping | None = None) -> EstimateSet:
    """Vectorized Eq. 4-16 over the per-region sufficient statistics.

    Pure numpy column math — no per-region Python loop — so multi-worker
    runs with 10⁴–10⁵ combinations build in array time. Returns an
    EstimateSet backed by a columnar EstimateTable. ``rail_psum``/
    ``rail_psumsq`` [R, D] extend the table with the per-domain
    decomposition: the same Eq. 6/7/12-16 column math applies per rail
    (the time proportion — and so the Wald interval — is shared, since
    all rails ride one sample clock).
    """
    counts = np.asarray(counts, dtype=np.int64)
    psum = np.asarray(psum, dtype=np.float64)
    psumsq = np.asarray(psumsq, dtype=np.float64)
    n = int(counts.sum())
    if n == 0:
        raise ValueError("no samples collected; cannot estimate")
    z = z_quantile(alpha)

    rids = np.arange(len(counts), dtype=np.int64)
    if drop_empty:
        keep = counts > 0
        rids, counts = rids[keep], counts[keep]
        psum, psumsq = psum[keep], psumsq[keep]
        if rail_psum is not None:
            rail_psum = rail_psum[keep]
            rail_psumsq = rail_psumsq[keep]

    p_hat = counts / n
    # Eq. 8/9: Wald interval on the Bernoulli proportion.
    se_p = np.sqrt(np.maximum(p_hat * (1.0 - p_hat), 0.0) / n)
    p_lo = np.maximum(p_hat - z * se_p, 0.0)
    p_hi = np.minimum(p_hat + z * se_p, 1.0)
    t_hat = p_hat * t_exec

    def power_ci(s, sq, cnt):
        """Eq. 6 and 12-14 column math (shared by total and rails)."""
        nz = cnt > 0
        hat = np.divide(s, cnt, out=np.zeros_like(s), where=nz)
        gt1 = cnt > 1
        var = np.divide(sq - cnt * hat * hat, np.maximum(cnt - 1, 1),
                        out=np.zeros_like(s), where=gt1)
        se = np.sqrt(np.maximum(var, 0.0) / np.maximum(cnt, 1))
        return hat, hat - z * se, hat + z * se

    pow_hat, pow_lo, pow_hi = power_ci(psum, psumsq,
                                       counts.astype(np.float64))
    e_hat = pow_hat * t_hat                      # Eq. 7
    rails = {}
    if rail_psum is not None:
        cnt_d = counts.astype(np.float64)[:, None]
        pr_hat, pr_lo, pr_hi = power_ci(
            np.asarray(rail_psum, np.float64),
            np.asarray(rail_psumsq, np.float64), cnt_d)
        rails = dict(
            domains=tuple(domains),
            pow_rails=pr_hat, pow_rails_lo=pr_lo, pow_rails_hi=pr_hi,
            e_rails=pr_hat * t_hat[:, None],
            e_rails_lo=(p_lo * t_exec)[:, None] * pr_lo,   # Eq. 16 per rail
            e_rails_hi=(p_hi * t_exec)[:, None] * pr_hi)
    n_names = len(names)
    table = EstimateTable(
        region_ids=rids,
        names=tuple(names[r] if r < n_names else f"region_{r}"
                    for r in rids),
        n_samples=counts,
        p_hat=p_hat,
        t_hat=t_hat,
        t_lo=p_lo * t_exec,
        t_hi=p_hi * t_exec,
        pow_hat=pow_hat,
        pow_lo=pow_lo,
        pow_hi=pow_hi,
        e_hat=e_hat,
        e_lo=p_lo * t_exec * pow_lo,             # Eq. 16
        e_hi=p_hi * t_exec * pow_hi,
        ci_valid=(n * p_hat > 5.0) & (n * (1.0 - p_hat) > 5.0),
        **rails,
    )
    return EstimateSet(table=table, n_total=n, t_exec=float(t_exec),
                       alpha=alpha, coverage=coverage, tail=tail)


def estimates_from_statistics(counts: np.ndarray, psum: np.ndarray,
                              psumsq: np.ndarray, t_exec: float,
                              names: Sequence[str], *, alpha: float = 0.05,
                              drop_empty: bool = True,
                              rail_psum: np.ndarray | None = None,
                              rail_psumsq: np.ndarray | None = None,
                              domains: Sequence[str] | None = None,
                              coverage: Mapping | None = None,
                              tail: Mapping | None = None
                              ) -> EstimateSet:
    """Build estimates directly from pre-aggregated sufficient statistics.

    Entry point for the streaming path: a
    :class:`repro.core.streaming.StreamingAggregator` (or any multi-host
    shard reduction) hands its merged (counts, Σpow, Σpow²) here without
    ever materializing the raw sample stream. ``rail_psum``/``rail_psumsq``
    + ``domains`` add the per-domain columns for multi-rail runs;
    ``coverage`` attaches a degraded gather's provenance so reports can
    disclose partial fleets.
    """
    if not (rail_psum is None) == (rail_psumsq is None) == (domains is None):
        raise ValueError("rail_psum, rail_psumsq and domains must be "
                         "passed together")
    return _build_estimates(np.asarray(counts), np.asarray(psum),
                            np.asarray(psumsq), list(names), t_exec, alpha,
                            drop_empty,
                            rail_psum=None if rail_psum is None
                            else np.asarray(rail_psum),
                            rail_psumsq=None if rail_psumsq is None
                            else np.asarray(rail_psumsq), domains=domains,
                            coverage=coverage, tail=tail)


def estimate_regions(region_ids: np.ndarray, powers: np.ndarray,
                     t_exec: float, names: Sequence[str],
                     *, alpha: float = 0.05, drop_empty: bool = True,
                     aggregate_fn: AggregateFn | None = None) -> EstimateSet:
    """One-pass ALEA estimation over a (region_id, power) sample stream.

    Args:
      region_ids: int array [n] of sampled region ids (PC → basic block map).
      powers: float array [n] of simultaneous sensor readings [W].
      t_exec: measured total execution time [s] of the profiled run.
      names: region id → human name.
      alpha: 1 - confidence level (paper uses 95% → alpha=0.05).
      aggregate_fn: optional replacement aggregation (e.g. Pallas kernel op).
    """
    num_regions = len(names)
    agg = aggregate_fn or aggregate_samples_np
    counts, psum, psumsq = (np.asarray(x) for x in
                            agg(np.asarray(region_ids), np.asarray(powers),
                                num_regions))
    return _build_estimates(counts, psum, psumsq, list(names), t_exec, alpha,
                            drop_empty)


def encode_combinations(region_id_matrix: np.ndarray
                        ) -> tuple[np.ndarray, list[tuple[int, ...]]]:
    """Map per-sample region-id vectors (one per worker) to combination ids.

    Paper §4.4 / Eq. 19: ``comb = (bb_thread_1, ..., bb_thread_l)``.

    One-shot variant: sorts the full matrix via ``np.unique`` (combos come
    out in lexicographic order). For chunked streams use
    :class:`repro.core.streaming.CombinationInterner`, which interns rows
    incrementally in first-appearance order with O(chunk + distinct) memory.

    Args:
      region_id_matrix: int array [n, workers].
    Returns:
      (comb_ids [n], list of combination tuples indexed by comb id).
    """
    mat = np.asarray(region_id_matrix)
    if mat.ndim != 2:
        raise ValueError(f"expected [n, workers]; got shape {mat.shape}")
    uniq, inverse = np.unique(mat, axis=0, return_inverse=True)
    combos = [tuple(int(v) for v in row) for row in uniq]
    return inverse.astype(np.int64), combos


def _combo_field_name(r: int, names: Sequence[str], n_names: int) -> str:
    """One combination field → display name. Negative ids are the
    bounded-mode tail sentinel (``sketch.OTHER``): render ``other``, never
    ``names[-1]`` (Python's end-indexing would silently alias the last
    region)."""
    if r < 0:
        return "other"
    return names[r] if r < n_names else f"r{r}"


def combination_names(combos: Sequence[tuple[int, ...]],
                      names: Sequence[str]) -> list[str]:
    """Human names for combination tuples (shared by one-shot + streaming)."""
    n_names = len(names)
    return ["+".join(_combo_field_name(r, names, n_names) for r in c)
            for c in combos]


def combination_names_from_matrix(combo_matrix: np.ndarray,
                                  names: Sequence[str]) -> list[str]:
    """Human names for a serialized combination key table [k, workers].

    The exchange wire format (:mod:`repro.core.exchange`) carries
    combination id spaces as int64 matrices rather than tuple lists; a
    merged table is named directly from the matrix so finalization never
    reconstructs Python tuples.
    """
    mat = np.asarray(combo_matrix)
    if mat.ndim != 2:
        raise ValueError(f"expected [k, workers]; got shape {mat.shape}")
    n_names = len(names)
    return ["+".join(_combo_field_name(r, names, n_names) for r in row)
            for row in mat.tolist()]


def estimate_combinations(region_id_matrix: np.ndarray, powers: np.ndarray,
                          t_exec: float, names: Sequence[str],
                          *, alpha: float = 0.05) -> tuple[EstimateSet, list[tuple[int, ...]]]:
    """Multi-worker estimation over region combinations (Eqs. 17-19)."""
    comb_ids, combos = encode_combinations(region_id_matrix)
    est = estimate_regions(comb_ids, powers, t_exec,
                           combination_names(combos, names), alpha=alpha)
    return est, combos


def marginalize_worker(est: EstimateSet, combos: list[tuple[int, ...]],
                       names: Sequence[str]) -> EstimateSet:
    """Collapse combination estimates back to per-region marginals.

    A region's marginal time is the sum over combinations containing it;
    its power is the time-weighted mean of combination powers. Useful for
    hotspot ranking while the combination table retains contention detail.
    """
    by_comb = {c: r for c, r in zip(combos, est.regions)}
    num_regions = len(names)
    t = np.zeros(num_regions)
    e = np.zeros(num_regions)
    ns = np.zeros(num_regions, dtype=np.int64)
    for c, r in by_comb.items():
        for rid in set(c):
            t[rid] += r.t_hat
            e[rid] += r.e_hat
            ns[rid] += r.n_samples
    out = []
    for rid in range(num_regions):
        if ns[rid] == 0:
            continue
        pw = e[rid] / t[rid] if t[rid] > 0 else 0.0
        out.append(RegionEstimate(
            region_id=rid, name=names[rid], n_samples=int(ns[rid]),
            p_hat=t[rid] / est.t_exec if est.t_exec else 0.0,
            t_hat=float(t[rid]), t_lo=float("nan"), t_hi=float("nan"),
            pow_hat=float(pw), pow_lo=float("nan"), pow_hi=float("nan"),
            e_hat=float(e[rid]), e_lo=float("nan"), e_hi=float("nan"),
            ci_valid=False))
    return EstimateSet.from_regions(out, n_total=est.n_total,
                                    t_exec=est.t_exec, alpha=est.alpha)
