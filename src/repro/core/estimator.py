"""ALEA probabilistic estimators (paper §4.1-§4.4, Eqs. 2-16).

The estimator consumes a stream of simultaneous (region_id, power) samples
taken at a systematic period and produces, per region (paper: basic block):

  - execution-time estimate   t̂ = (n_bb / n) · t_exec          (Eq. 5)
  - mean-power estimate       p̂ow = mean(power samples of bb)  (Eq. 6)
  - energy estimate           ê = p̂ow · t̂                      (Eq. 7)
  - Wald confidence interval on the time proportion (Eqs. 8-10)
  - normal confidence interval on power (Eqs. 12-15)
  - product confidence interval on energy (Eq. 16)

Multi-worker profiling (§4.4) attributes time/energy to *combinations* of
regions sampled simultaneously across workers (threads in the paper; chips
or hosts here), because shared-resource contention makes per-worker
apportioning unsound.

Everything is vectorized; the aggregation hot spot (counts / power sums /
power sums-of-squares per region) is pluggable so the Pallas
``kernels.sample_attr`` kernel can take over on TPU for fleet-scale sample
streams.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable, Mapping, Sequence

import numpy as np

__all__ = [
    "RegionEstimate",
    "EstimateSet",
    "aggregate_samples_np",
    "estimate_regions",
    "estimate_combinations",
    "z_quantile",
]


def z_quantile(alpha: float) -> float:
    """``z_{alpha/2}``: the 1 - alpha/2 percentile of the standard normal.

    Uses the Acklam inverse-CDF approximation (|rel err| < 1.15e-9); avoids a
    scipy dependency and is exact enough for CI construction.
    """
    p = 1.0 - alpha / 2.0
    if not 0.0 < p < 1.0:
        raise ValueError(f"alpha must be in (0, 2); got alpha={alpha}")
    # Acklam's algorithm.
    a = (-3.969683028665376e+01, 2.209460984245205e+02, -2.759285104469687e+02,
         1.383577518672690e+02, -3.066479806614716e+01, 2.506628277459239e+00)
    b = (-5.447609879822406e+01, 1.615858368580409e+02, -1.556989798598866e+02,
         6.680131188771972e+01, -1.328068155288572e+01)
    c = (-7.784894002430293e-03, -3.223964580411365e-01, -2.400758277161838e+00,
         -2.549732539343734e+00, 4.374664141464968e+00, 2.938163982698783e+00)
    d = (7.784695709041462e-03, 3.224671290700398e-01, 2.445134137142996e+00,
         3.754408661907416e+00)
    plow, phigh = 0.02425, 1 - 0.02425
    if p < plow:
        q = math.sqrt(-2 * math.log(p))
        return (((((c[0] * q + c[1]) * q + c[2]) * q + c[3]) * q + c[4]) * q + c[5]) / \
               ((((d[0] * q + d[1]) * q + d[2]) * q + d[3]) * q + 1)
    if p <= phigh:
        q = p - 0.5
        r = q * q
        return (((((a[0] * r + a[1]) * r + a[2]) * r + a[3]) * r + a[4]) * r + a[5]) * q / \
               (((((b[0] * r + b[1]) * r + b[2]) * r + b[3]) * r + b[4]) * r + 1)
    q = math.sqrt(-2 * math.log(1 - p))
    return -(((((c[0] * q + c[1]) * q + c[2]) * q + c[3]) * q + c[4]) * q + c[5]) / \
           ((((d[0] * q + d[1]) * q + d[2]) * q + d[3]) * q + 1)


@dataclasses.dataclass(frozen=True)
class RegionEstimate:
    """Per-region (or per-combination) ALEA estimates with CIs."""

    region_id: int
    name: str
    n_samples: int            # n_bb
    p_hat: float              # Eq. 4
    t_hat: float              # Eq. 5  [s]
    t_lo: float               # Eq. 11 lower
    t_hi: float               # Eq. 11 upper
    pow_hat: float            # Eq. 6  [W]
    pow_lo: float             # Eq. 13
    pow_hi: float             # Eq. 12
    e_hat: float              # Eq. 7  [J]
    e_lo: float               # Eq. 16 lower
    e_hi: float               # Eq. 16 upper
    ci_valid: bool            # Wald validity: n·p̂>5 and n·(1-p̂)>5 (§4.3)

    @property
    def t_ci_halfwidth(self) -> float:
        return 0.5 * (self.t_hi - self.t_lo)


@dataclasses.dataclass(frozen=True)
class EstimateSet:
    """All region estimates from one profiling pass."""

    regions: tuple[RegionEstimate, ...]
    n_total: int
    t_exec: float
    alpha: float

    def by_name(self) -> Mapping[str, RegionEstimate]:
        return {r.name: r for r in self.regions}

    @property
    def total_energy(self) -> float:
        return float(sum(r.e_hat for r in self.regions))

    @property
    def total_time(self) -> float:
        return float(sum(r.t_hat for r in self.regions))

    def dominant(self, k: int = 1) -> tuple[RegionEstimate, ...]:
        """Top-k regions by estimated energy (hotspot analysis, §7.1)."""
        return tuple(sorted(self.regions, key=lambda r: -r.e_hat)[:k])


AggregateFn = Callable[[np.ndarray, np.ndarray, int],
                       tuple[np.ndarray, np.ndarray, np.ndarray]]


def aggregate_samples_np(region_ids: np.ndarray, powers: np.ndarray,
                         num_regions: int) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Reference aggregation: per-region sample counts, Σpow, Σpow².

    This is the tool's aggregation hot spot (one entry per sample; fleets
    collect billions). ``kernels/sample_attr`` provides the tiled Pallas
    equivalent; both must match this exactly.
    """
    region_ids = np.asarray(region_ids)
    powers = np.asarray(powers, dtype=np.float64)
    counts = np.bincount(region_ids, minlength=num_regions).astype(np.int64)
    psum = np.bincount(region_ids, weights=powers, minlength=num_regions)
    psumsq = np.bincount(region_ids, weights=powers * powers, minlength=num_regions)
    return counts, psum, psumsq


def _build_estimates(counts: np.ndarray, psum: np.ndarray, psumsq: np.ndarray,
                     names: Sequence[str], t_exec: float, alpha: float,
                     drop_empty: bool) -> EstimateSet:
    n = int(counts.sum())
    if n == 0:
        raise ValueError("no samples collected; cannot estimate")
    z = z_quantile(alpha)
    out: list[RegionEstimate] = []
    for rid in range(len(counts)):
        n_bb = int(counts[rid])
        if n_bb == 0 and drop_empty:
            continue
        p_hat = n_bb / n
        # Eq. 8/9: Wald interval on the Bernoulli proportion.
        se_p = math.sqrt(max(p_hat * (1.0 - p_hat), 0.0) / n)
        p_lo = max(p_hat - z * se_p, 0.0)
        p_hi = min(p_hat + z * se_p, 1.0)
        t_hat = p_hat * t_exec
        # Eq. 6 and 12-14: mean power and its normal CI.
        if n_bb > 0:
            pow_hat = psum[rid] / n_bb
        else:
            pow_hat = 0.0
        if n_bb > 1:
            var = (psumsq[rid] - n_bb * pow_hat * pow_hat) / (n_bb - 1)
            s = math.sqrt(max(var, 0.0))
            se_pow = s / math.sqrt(n_bb)
        else:
            se_pow = 0.0
        pow_lo = pow_hat - z * se_pow
        pow_hi = pow_hat + z * se_pow
        e_hat = pow_hat * t_hat  # Eq. 7
        out.append(RegionEstimate(
            region_id=rid,
            name=names[rid] if rid < len(names) else f"region_{rid}",
            n_samples=n_bb,
            p_hat=p_hat,
            t_hat=t_hat,
            t_lo=p_lo * t_exec,
            t_hi=p_hi * t_exec,
            pow_hat=float(pow_hat),
            pow_lo=float(pow_lo),
            pow_hi=float(pow_hi),
            e_hat=float(e_hat),
            e_lo=float(p_lo * t_exec * pow_lo),   # Eq. 16
            e_hi=float(p_hi * t_exec * pow_hi),
            ci_valid=(n * p_hat > 5.0) and (n * (1.0 - p_hat) > 5.0),
        ))
    return EstimateSet(regions=tuple(out), n_total=n, t_exec=float(t_exec),
                       alpha=alpha)


def estimate_regions(region_ids: np.ndarray, powers: np.ndarray,
                     t_exec: float, names: Sequence[str],
                     *, alpha: float = 0.05, drop_empty: bool = True,
                     aggregate_fn: AggregateFn | None = None) -> EstimateSet:
    """One-pass ALEA estimation over a (region_id, power) sample stream.

    Args:
      region_ids: int array [n] of sampled region ids (PC → basic block map).
      powers: float array [n] of simultaneous sensor readings [W].
      t_exec: measured total execution time [s] of the profiled run.
      names: region id → human name.
      alpha: 1 - confidence level (paper uses 95% → alpha=0.05).
      aggregate_fn: optional replacement aggregation (e.g. Pallas kernel op).
    """
    num_regions = len(names)
    agg = aggregate_fn or aggregate_samples_np
    counts, psum, psumsq = (np.asarray(x) for x in
                            agg(np.asarray(region_ids), np.asarray(powers),
                                num_regions))
    return _build_estimates(counts, psum, psumsq, list(names), t_exec, alpha,
                            drop_empty)


def encode_combinations(region_id_matrix: np.ndarray
                        ) -> tuple[np.ndarray, list[tuple[int, ...]]]:
    """Map per-sample region-id vectors (one per worker) to combination ids.

    Paper §4.4 / Eq. 19: ``comb = (bb_thread_1, ..., bb_thread_l)``.

    Args:
      region_id_matrix: int array [n, workers].
    Returns:
      (comb_ids [n], list of combination tuples indexed by comb id).
    """
    mat = np.asarray(region_id_matrix)
    if mat.ndim != 2:
        raise ValueError(f"expected [n, workers]; got shape {mat.shape}")
    uniq, inverse = np.unique(mat, axis=0, return_inverse=True)
    combos = [tuple(int(v) for v in row) for row in uniq]
    return inverse.astype(np.int64), combos


def estimate_combinations(region_id_matrix: np.ndarray, powers: np.ndarray,
                          t_exec: float, names: Sequence[str],
                          *, alpha: float = 0.05) -> tuple[EstimateSet, list[tuple[int, ...]]]:
    """Multi-worker estimation over region combinations (Eqs. 17-19)."""
    comb_ids, combos = encode_combinations(region_id_matrix)
    comb_names = ["+".join(names[r] if r < len(names) else f"r{r}" for r in c)
                  for c in combos]
    est = estimate_regions(comb_ids, powers, t_exec, comb_names, alpha=alpha)
    return est, combos


def marginalize_worker(est: EstimateSet, combos: list[tuple[int, ...]],
                       names: Sequence[str]) -> EstimateSet:
    """Collapse combination estimates back to per-region marginals.

    A region's marginal time is the sum over combinations containing it;
    its power is the time-weighted mean of combination powers. Useful for
    hotspot ranking while the combination table retains contention detail.
    """
    by_comb = {c: r for c, r in zip(combos, est.regions)}
    num_regions = len(names)
    t = np.zeros(num_regions)
    e = np.zeros(num_regions)
    ns = np.zeros(num_regions, dtype=np.int64)
    for c, r in by_comb.items():
        for rid in set(c):
            t[rid] += r.t_hat
            e[rid] += r.e_hat
            ns[rid] += r.n_samples
    out = []
    for rid in range(num_regions):
        if ns[rid] == 0:
            continue
        pw = e[rid] / t[rid] if t[rid] > 0 else 0.0
        out.append(RegionEstimate(
            region_id=rid, name=names[rid], n_samples=int(ns[rid]),
            p_hat=t[rid] / est.t_exec if est.t_exec else 0.0,
            t_hat=float(t[rid]), t_lo=float("nan"), t_hi=float("nan"),
            pow_hat=float(pw), pow_lo=float("nan"), pow_hi=float("nan"),
            e_hat=float(e[rid]), e_lo=float("nan"), e_hi=float("nan"),
            ci_valid=False))
    return EstimateSet(regions=tuple(out), n_total=est.n_total,
                       t_exec=est.t_exec, alpha=est.alpha)
