"""Cross-host shard exchange for the streaming aggregation engine.

ALEA's estimator is multi-worker by design (§4.4): per-region sample
shards collected on each host must be reduced into one set of sufficient
statistics (counts, Σpow, Σpow²) — and, for combination attribution, one
deduplicated combination id space — before confidence intervals are
valid. :mod:`repro.core.streaming` gives the in-process ``merge()``; this
module moves it across hosts, two ways:

* **Collective path** — :func:`collective_reduce`. Each host serializes
  its aggregator into fixed-shape arrays (:func:`pack_shard`) and the
  statistics are all-reduced via ``jax.lax.psum`` over a 1-D mesh axis
  (``launch.mesh.make_exchange_mesh``). Combination shards cannot be
  summed (ids are host-local), so their key tables + statistics are
  ``all_gather``-ed instead and every host folds the same ordered union
  merge — deterministic and identical on all hosts. Interpret-friendly:
  runs eagerly under ``shard_map`` on CPU test meshes.

* **Checkpointed path** — :func:`spill_shard` / :func:`gather_shards`.
  Each host atomically spills its shard using the manifest+CRC+rename
  protocol of :mod:`repro.checkpoint.ckpt` (``write_manifest_dir``), so
  hosts can die and rejoin: a crashed spill leaves only an ignored
  ``.tmp-`` directory, a restarted host resumes from its own LATEST
  (:func:`restore_shard`), and the reader merges whatever shards are
  published. Restore is a left-to-right binary reduction tree::

      host_0   host_1   host_2   host_3     (published shards, id order)
         \\       /         \\       /
          m_01               m_23           round 1: pairwise merge()
              \\             /
               \\           /
                m_0123                      round 2 → merged aggregator

  ``merge`` appends a shard's unseen combination rows in the shard's
  local first-appearance order, so *any* order-preserving tree assigns
  the same union ids as a single aggregator fed the concatenated stream
  — id assignment is reduction-shape independent.

Shard manifest schema v2 (see ROADMAP "exchange formats"): arrays
``counts`` int64[cap], ``psum``/``psumsq`` float64 — 1-D [cap] for
single-domain shards (byte-identical to the schema-v1 layout) or
[cap, C] channel matrices for multi-domain shards (the power-rail
``domains`` plus the total channel, cf.
:func:`repro.core.streaming.channels_for`) — and, for combination
shards, ``combos`` int64[cap, width]; manifest ``meta`` keys ``kind``
("region"|"combination"), ``host_id``, ``epoch``, ``n_rows`` (valid
prefix — rows past it are padding for fixed-shape collectives),
``schema_version`` (2) and ``domains`` (the rail axis). Readers accept
legacy v1 epochs (no ``domains`` key, 1-D statistics) transparently —
they normalize to the single-domain in-memory form — so pre-rail spill
directories keep gathering, including mixed with v2 delta-publishing
hosts; merges refuse mismatched domain axes loudly.

Schema v3 extends v2 for *bounded-state* combination shards
(:mod:`repro.core.sketch`): meta keys ``k`` (heavy-hitters capacity),
``hash_range`` (``[lo, hi)`` splitmix64 ownership interval) and
``other_rows`` (count of per-region tail-bucket sentinel rows in the
valid prefix) ride along, and ``schema_version`` becomes 3. The v3 keys
are emitted **only when non-default** — exact, unsharded shards stay
byte-identical v2, so pre-bounded readers and golden spill fixtures are
unaffected. Readers normalize v1/v2 epochs to ``(k=None,
hash_range=None)`` transparently; merging shards whose bounded configs
differ refuses with a typed
:class:`~repro.core.faults.SketchConfigError` (mixed-axis discipline,
same as the domain axis), and delta chains refuse config drift
mid-chain.

**Incremental (delta) spills.** Republishing the full shard every epoch
costs O(rows) bandwidth per epoch — O(run length · rows) per host over a
long-running serving fleet. :class:`ShardSpiller` instead publishes a
full *base* epoch, then per-epoch :class:`ShardDelta` records holding
only the rows that changed (sufficient-statistic rows mutate in place
and new combination rows append monotonically, so an epoch's difference
is a row-sparse overlay plus a combo-row suffix). Every
``compact_every``-th publish it *compacts*: rewrites a fresh full base
and garbage-collects the now-unreachable epoch dirs, keeping the host
directory O(compact window). Readers (:class:`DeltaChain`, used by
:func:`restore_shard` and so :func:`gather_shards`) walk LATEST's
``delta_of`` back-pointers to the base and fold ``base + Σ deltas`` into
a :class:`PackedShard` — hosts publishing full shards and hosts
publishing deltas mix freely under one gather. Changed rows store their
*replacement* values, not arithmetic differences: int64 differencing
would round-trip, but float64 ``prev + (cur - prev)`` does not, and the
gather must stay bit-exact against the full-spill path. A crash between
a delta publish and its compaction is safe: LATEST still names a valid
chain, and compaction GC runs only after the fresh base is durable.

Delta manifest schema: arrays ``idx`` int64[k] (changed-row indices),
``counts`` int64[k] / ``psum``/``psumsq`` float64[k] (replacement values
at those rows) and, for combination shards, ``combos_new``
int64[n_rows - prev_rows, width] (appended key rows); meta adds
``delta_of`` (the epoch this delta builds on), ``base_epoch`` (the chain
base, for validation), and ``prev_rows``.
"""

from __future__ import annotations

import dataclasses
import os
import re
import shutil
import time
import weakref
from typing import Callable, Mapping, Sequence

import numpy as np

from repro.checkpoint import ckpt
from repro.core import faults as faults_mod
from repro.core import sketch as sketch_mod
from repro.core.estimator import AggregateFn
from repro.core.faults import (CorruptShardError, DeltaMismatchError,
                               InjectedCrash, MissingArtifactError,
                               QuorumError, SketchConfigError, SpillError,
                               StaleShardError, TornWriteError, declare_site)
from repro.core.streaming import (StreamingAggregator,
                                  StreamingCombinationAggregator,
                                  channels_for)

__all__ = [
    "PackedShard", "pack_shard", "unpack_shard",
    "collective_reduce", "spill_shard", "restore_shard",
    "read_shard_meta", "gather_shards", "list_spilled_hosts",
    "tree_reduce", "CollectiveExchange", "CheckpointExchange",
    "ShardDelta", "compute_shard_delta", "apply_shard_delta",
    "spill_shard_delta", "DeltaChain", "ShardSpiller",
    "QuorumPolicy", "HostReport", "GatherResult",
]

# \d+ not \d{4}: the :04d dir format zero-pads but never truncates, so
# host ids >= 10000 still publish (and must still gather).
_HOST_DIR_RE = re.compile(r"^host_(\d+)$")

KIND_REGION = "region"
KIND_COMBINATION = "combination"


# -- wire format ---------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PackedShard:
    """One host's aggregator state as fixed-shape arrays.

    ``n_rows`` is the valid prefix; rows past it are zero padding so
    shards from hosts with different region/combination counts still
    stack into one mesh-reducible array. ``combos`` is the host-local
    combination key table (None for plain region shards) — receivers
    dedupe it lazily at merge via ``CombinationInterner.intern_rows``.

    Schema v2: ``psum``/``psumsq`` carry the channel axis ``[cap, C]``
    (``domains`` rails plus, for D > 1, the total channel — see
    :func:`repro.core.streaming.channels_for`). Single-domain shards
    have C = 1, and serialize 1-D exactly like schema v1 — readers
    normalize either layout into this in-memory form.

    Schema v3 (bounded-state combination shards): ``k`` is the source
    aggregator's heavy-hitters capacity and ``hash_range`` its ``[lo,
    hi)`` splitmix64 ownership interval (``None``/``None`` = exact,
    unsharded — the v1/v2 reading). The config is part of shard
    identity: merges across differing configs refuse with
    :class:`~repro.core.faults.SketchConfigError` rather than silently
    blending incompatible tails. ``tail_folds``/``evictions`` carry the
    source's cumulative fold provenance — without them a restored host
    would render a TAIL disclosure claiming zero folds while its table
    holds ``other`` rows.
    """

    counts: np.ndarray            # int64 [cap]
    psum: np.ndarray              # float64 [cap, C]
    psumsq: np.ndarray            # float64 [cap, C]
    n_rows: int
    combos: np.ndarray | None = None   # int64 [cap, width] or None
    domains: tuple[str, ...] = ("total",)
    k: int | None = None               # heavy-hitters capacity (None = exact)
    hash_range: tuple[int, int] | None = None   # [lo, hi) ownership
    tail_folds: int = 0                # cumulative fold events at pack time
    evictions: int = 0                 # cumulative evictions at pack time

    def __post_init__(self):
        # 1-D statistics are the scalar (v1-layout) form; normalize to
        # the one-channel matrix so every consumer sees [cap, C].
        if self.psum.ndim == 1:
            object.__setattr__(self, "psum", self.psum[:, None])
        if self.psumsq.ndim == 1:
            object.__setattr__(self, "psumsq", self.psumsq[:, None])
        c = channels_for(self.domains)
        if self.psum.shape[1] != c or self.psumsq.shape[1] != c:
            raise ValueError(
                f"shard has {self.psum.shape[1]} channels; domain axis "
                f"{self.domains} requires {c}")

    @property
    def kind(self) -> str:
        return KIND_REGION if self.combos is None else KIND_COMBINATION

    @property
    def capacity(self) -> int:
        return len(self.counts)

    @property
    def num_channels(self) -> int:
        return self.psum.shape[1]

    @property
    def other_rows(self) -> int:
        """Tail-bucket sentinel rows in the valid prefix (0 for region
        shards and exact combination shards)."""
        if self.combos is None or self.n_rows == 0:
            return 0
        return int(sketch_mod.is_other_rows(
            self.combos[:self.n_rows]).sum())

    @property
    def bounded(self) -> bool:
        return self.k is not None or self.hash_range is not None


def _pad(arr: np.ndarray, cap: int) -> np.ndarray:
    if len(arr) > cap:
        raise ValueError(f"shard has {len(arr)} rows > capacity {cap}")
    if len(arr) == cap:
        return arr
    pad = [(0, cap - len(arr))] + [(0, 0)] * (arr.ndim - 1)
    return np.pad(arr, pad)


def pack_shard(agg: StreamingAggregator | StreamingCombinationAggregator,
               capacity: int | None = None) -> PackedShard:
    """Serialize an aggregator into a :class:`PackedShard`.

    ``capacity`` pads the row dimension to a fixed size; collectives need
    every participating host to pass the same value.
    """
    if isinstance(agg, StreamingCombinationAggregator):
        combos = agg.interner.combo_matrix()
        n_rows = len(combos)
        cap = n_rows if capacity is None else capacity
        hr = agg.hash_range
        return PackedShard(
            counts=_pad(agg.agg.counts[:n_rows], cap),
            psum=_pad(agg.agg.chan_psum[:n_rows], cap),
            psumsq=_pad(agg.agg.chan_psumsq[:n_rows], cap),
            n_rows=n_rows, combos=_pad(combos, cap),
            domains=agg.domains, k=agg.k,
            hash_range=None if hr is None else hr.as_tuple(),
            tail_folds=agg.tail_folds, evictions=agg.evictions)
    n_rows = agg.num_regions
    cap = n_rows if capacity is None else capacity
    return PackedShard(counts=_pad(agg.counts, cap),
                       psum=_pad(agg.chan_psum, cap),
                       psumsq=_pad(agg.chan_psumsq, cap), n_rows=n_rows,
                       domains=agg.domains)


def unpack_shard(shard: PackedShard, *,
                 aggregate_fn: AggregateFn | None = None
                 ) -> StreamingAggregator | StreamingCombinationAggregator:
    """Reconstruct a live aggregator from a packed shard."""
    k = shard.n_rows
    if shard.combos is None:
        return StreamingAggregator.from_statistics(
            shard.counts[:k], shard.psum[:k], shard.psumsq[:k],
            aggregate_fn=aggregate_fn, domains=shard.domains)
    cagg = StreamingCombinationAggregator(aggregate_fn=aggregate_fn,
                                          domains=shard.domains,
                                          k=shard.k,
                                          hash_range=shard.hash_range)
    cagg.merge_table(shard.combos[:k], shard.counts[:k],
                     shard.psum[:k], shard.psumsq[:k],
                     k=shard.k, hash_range=shard.hash_range)
    # Reconstruction never folds (resident <= k by construction), so the
    # packed provenance restores exactly — not additively.
    cagg.tail_folds = shard.tail_folds
    cagg.evictions = shard.evictions
    return cagg


def _merge_shard_into(agg, shard: PackedShard):
    """Fold a packed shard into a live aggregator (kinds must match)."""
    k = shard.n_rows
    if isinstance(agg, StreamingCombinationAggregator):
        if shard.combos is None:
            raise ValueError("cannot merge a region shard into a "
                             "combination aggregator")
        agg.merge_table(shard.combos[:k], shard.counts[:k],
                        shard.psum[:k], shard.psumsq[:k],
                        k=shard.k, hash_range=shard.hash_range)
        # Same tail provenance accounting as merge(): the source's fold
        # history rides along with its statistics.
        agg.tail_folds += shard.tail_folds
        agg.evictions += shard.evictions
        return agg
    if shard.combos is not None:
        raise ValueError("cannot merge a combination shard into a region "
                         "aggregator")
    other = unpack_shard(shard)
    return agg.merge(other)


# -- collective path -----------------------------------------------------------

def _stack_global(mesh, axis: str, rows: Sequence[np.ndarray]):
    """Stack per-position rows into the [H, ...] global array for a mesh.

    Single-process (CI): plain np.stack — ``rows`` holds every position.
    Multi-process (production): each process passes only its local row(s)
    and the global array is assembled from process-local data.
    """
    import jax
    stacked = np.stack(rows)
    if jax.process_count() == 1:
        return stacked
    from jax.sharding import NamedSharding, PartitionSpec as P
    sharding = NamedSharding(mesh, P(axis))
    return jax.make_array_from_process_local_data(sharding, stacked)


def region_allreduce_fn(axis: str):
    """Per-shard body of the region all-reduce collective.

    Module-level (rather than a closure inside :func:`collective_reduce`)
    so the jaxpr auditor can trace exactly the computation that runs
    under ``shard_map`` — see ``repro.analysis.jaxpr_audit``.
    """
    import jax

    def _allreduce(c, s, q):
        return (jax.lax.psum(c, axis).sum(0),
                jax.lax.psum(s, axis).sum(0),
                jax.lax.psum(q, axis).sum(0))
    return _allreduce


def combo_allgather_fn(axis: str):
    """Per-shard body of the combination-table all-gather collective
    (module-level for the same auditability reason as
    :func:`region_allreduce_fn`)."""
    import jax

    def _gather(*arrs):
        return tuple(jax.lax.all_gather(a, axis, axis=0, tiled=True)
                     for a in arrs)
    return _gather


def collective_reduce(shards: Sequence[StreamingAggregator |
                                       StreamingCombinationAggregator],
                      *, mesh=None, axis: str = "hosts",
                      capacity: int | None = None, width: int | None = None,
                      aggregate_fn: AggregateFn | None = None):
    """All-reduce aggregator shards over a mesh axis; returns the merge.

    ``shards`` holds one aggregator per position of the mesh axis this
    process owns — in production each host passes ``[its local shard]``
    against a multi-host mesh; in single-process tests pass all H shards
    against an H-device mesh. Plain region shards reduce with one
    ``lax.psum``; combination shards ``all_gather`` (tables are
    host-local id spaces, not summable) and every host folds the same
    ordered union merge, so results are identical everywhere.
    """
    from jax.experimental import enable_x64
    from jax.sharding import PartitionSpec as P
    from functools import partial

    from repro.compat import shard_map
    from repro.launch.mesh import make_exchange_mesh

    if not shards:
        raise ValueError("no shards to reduce")
    if mesh is None:
        mesh = make_exchange_mesh(len(shards), axis=axis)
    n_hosts = mesh.shape[axis]
    if capacity is None:
        if isinstance(shards[0], StreamingCombinationAggregator):
            capacity = max(len(s.interner) for s in shards)
        else:
            capacity = max(s.num_regions for s in shards)
    packed = [pack_shard(s, capacity) for s in shards]
    kinds = {p.kind for p in packed}
    if len(kinds) != 1:
        raise ValueError(f"mixed shard kinds: {sorted(kinds)}")
    domain_axes = {p.domains for p in packed}
    if len(domain_axes) != 1:
        raise ValueError(f"mixed shard domain axes: {sorted(domain_axes)}")
    domains = domain_axes.pop()
    if KIND_COMBINATION in kinds:
        # A host that saw no traffic has a width-0 key table; its combos
        # must still stack to the fleet's fixed [cap, width] shape (its
        # n_rows=0 keeps the zero rows out of the merge). Multi-process
        # fleets pass ``width`` explicitly (worker count is static).
        widths = {p.combos.shape[1] for p in packed if p.combos.shape[1]}
        if width is not None:
            widths.add(width)
        if len(widths) > 1:
            raise ValueError(f"worker-count mismatch across shards: "
                             f"{sorted(widths)}")
        w = widths.pop() if widths else 0
        packed = [p if p.combos.shape[1] == w else dataclasses.replace(
                      p, combos=np.zeros((p.capacity, w), np.int64))
                  for p in packed]
        # Bounded-state config is part of shard identity (like the
        # domain axis). Local shards must agree; remote hosts are
        # assumed uniform (collectives carry arrays, not manifests).
        configs = {(p.k, p.hash_range) for p in packed}
        if len(configs) > 1:
            raise SketchConfigError(
                f"mixed bounded-state configs across collective shards: "
                f"{sorted(configs, key=repr)}")
        combo_k, combo_hr = configs.pop()
    smap = partial(shard_map, mesh=mesh, in_specs=P(axis), out_specs=P(),
                   check_vma=False)

    # jax's default 32-bit mode would truncate int64 counts and round
    # float64 sums; the exchange is bit-exact only under x64.
    with enable_x64():
        if KIND_REGION in kinds:
            counts = _stack_global(mesh, axis, [p.counts for p in packed])
            psum = _stack_global(mesh, axis, [p.psum for p in packed])
            psumsq = _stack_global(mesh, axis, [p.psumsq for p in packed])

            c, s, q = smap(region_allreduce_fn(axis))(counts, psum, psumsq)
            # Remote hosts may populate rows past any local shard's
            # n_rows; the merged statistics span the full capacity.
            return unpack_shard(
                PackedShard(counts=np.asarray(c), psum=np.asarray(s),
                            psumsq=np.asarray(q), n_rows=capacity,
                            domains=domains),
                aggregate_fn=aggregate_fn)

        combos = _stack_global(mesh, axis, [p.combos for p in packed])
        counts = _stack_global(mesh, axis, [p.counts for p in packed])
        psum = _stack_global(mesh, axis, [p.psum for p in packed])
        psumsq = _stack_global(mesh, axis, [p.psumsq for p in packed])
        n_rows = _stack_global(
            mesh, axis,
            [np.asarray([p.n_rows], np.int64) for p in packed])

        g = smap(combo_allgather_fn(axis))(combos, counts, psum, psumsq,
                                           n_rows)
        g_combos, g_counts, g_psum, g_psumsq, g_rows = map(np.asarray, g)
        merged = StreamingCombinationAggregator(aggregate_fn=aggregate_fn,
                                                domains=domains,
                                                k=combo_k,
                                                hash_range=combo_hr)
        for h in range(n_hosts):
            k = int(g_rows[h, 0])
            merged.merge_table(g_combos[h, :k], g_counts[h, :k],
                               g_psum[h, :k], g_psumsq[h, :k],
                               k=combo_k, hash_range=combo_hr)
        return merged


# -- checkpointed path ---------------------------------------------------------

_EPOCH_DIR_RE = re.compile(r"^epoch_(\d+)$")


def _host_dir(path: str, host_id: int) -> str:
    return os.path.join(path, f"host_{host_id:04d}")


def _epoch_dir(hd: str, epoch: int) -> str:
    return os.path.join(hd, f"epoch_{epoch:09d}")


def _wire_stats(arr: np.ndarray) -> np.ndarray:
    """[cap, C] channel matrix → wire layout: single-channel shards write
    the 1-D array schema v1 wrote (same data bytes; v1 readers could even
    consume them), multi-channel shards write [cap, C]."""
    return arr[:, 0] if arr.shape[1] == 1 else arr


def _unwire_stats(arr: np.ndarray, domains: tuple[str, ...]) -> np.ndarray:
    """Wire layout → [cap, C]: v1 shards (and v2 single-domain shards)
    store 1-D arrays; reshape to the one-channel matrix."""
    arr = np.asarray(arr, np.float64)
    if arr.ndim == 1:
        arr = arr[:, None]
    c = channels_for(domains)
    if arr.shape[1] != c:
        raise CorruptShardError(
            f"shard statistics have {arr.shape[1]} channels; "
            f"domain axis {domains} requires {c}")
    return arr


def _meta_domains(manifest: dict) -> tuple[str, ...]:
    """Domain axis of an epoch dir; schema v1 manifests (no ``domains``
    key) are single-domain by construction."""
    return tuple(manifest.get("domains", ("total",)))


def _meta_bounds(manifest: dict
                 ) -> tuple[int | None, tuple[int, int] | None, int, int]:
    """``(k, hash_range, tail_folds, evictions)`` of an epoch dir; v1/v2
    manifests (no bounded keys) normalize to the exact, unsharded
    config with zero fold provenance."""
    k = manifest.get("k")
    hr = manifest.get("hash_range")
    return (None if k is None else int(k),
            None if hr is None else (int(hr[0]), int(hr[1])),
            int(manifest.get("tail_folds", 0)),
            int(manifest.get("evictions", 0)))


def _bounds_meta(meta: dict, k: int | None,
                 hash_range: tuple[int, int] | None,
                 tail_folds: int = 0, evictions: int = 0) -> dict:
    """Stamp bounded-state keys onto a manifest meta dict — only when
    non-default, so exact unsharded epochs stay byte-identical schema
    v2 (pre-bounded readers and golden spill fixtures unaffected)."""
    if k is None and hash_range is None:
        return meta
    meta["schema_version"] = 3
    if k is not None:
        meta["k"] = int(k)
    if hash_range is not None:
        meta["hash_range"] = [int(hash_range[0]), int(hash_range[1])]
    meta["tail_folds"] = int(tail_folds)
    meta["evictions"] = int(evictions)
    return meta


def _spill_packed(path: str, host_id: int, epoch: int, shard: PackedShard,
                  *, extra_meta: dict | None = None) -> str:
    hd = _host_dir(path, host_id)
    os.makedirs(hd, exist_ok=True)
    arrays = [shard.counts, _wire_stats(shard.psum),
              _wire_stats(shard.psumsq)]
    meta = {"kind": shard.kind, "host_id": host_id, "epoch": epoch,
            "n_rows": shard.n_rows,
            "schema": ["counts", "psum", "psumsq"],
            "schema_version": 2, "domains": list(shard.domains)}
    _bounds_meta(meta, shard.k, shard.hash_range,
                 shard.tail_folds, shard.evictions)
    if shard.bounded:
        meta["other_rows"] = shard.other_rows
    if extra_meta:
        meta["extra"] = dict(extra_meta)
    if shard.combos is not None:
        arrays.append(shard.combos)
        meta["schema"] = meta["schema"] + ["combos"]
        meta["width"] = int(shard.combos.shape[1])
    final = _epoch_dir(hd, epoch)
    ckpt.write_manifest_dir(final, arrays, meta=meta)
    ckpt.publish_latest(hd, epoch)
    return final


def spill_shard(path: str, host_id: int, epoch: int,
                agg: StreamingAggregator | StreamingCombinationAggregator,
                *, extra_meta: dict | None = None) -> str:
    """Atomically publish one host's full shard at ``epoch``.

    Reuses the checkpoint manifest+CRC+rename protocol: a shard is never
    half-visible, and per-host ``LATEST`` is only advanced after the
    epoch directory is durable. ``extra_meta`` (JSON-serializable) rides
    along under the manifest's ``"extra"`` key — callers stash run-scope
    state a restarted host needs (e.g. elapsed wall time). Returns the
    published directory. For per-epoch publishing use a
    :class:`ShardSpiller`, which spills incremental deltas instead of
    rewriting the full shard every time.
    """
    return _spill_packed(path, host_id, epoch, pack_shard(agg),
                         extra_meta=extra_meta)


def _load_shard(hd: str, epoch: int) -> PackedShard:
    """Load one *full* epoch dir (no chain resolution).

    Accepts all wire schemas: v1 (1-D psum/psumsq, no ``domains`` meta),
    v2 ([cap, C] channel matrices + ``domains``) and v3 (bounded-state
    ``k``/``hash_range`` keys) normalize into the same in-memory
    :class:`PackedShard`.
    """
    d = _epoch_dir(hd, epoch)
    arrays, manifest = ckpt.read_manifest_dir(d)
    try:
        named = dict(zip(manifest["schema"], arrays))
        domains = _meta_domains(manifest)
        k, hash_range, tail_folds, evictions = _meta_bounds(manifest)
        return PackedShard(counts=named["counts"].astype(np.int64),
                           psum=_unwire_stats(named["psum"], domains),
                           psumsq=_unwire_stats(named["psumsq"], domains),
                           n_rows=int(manifest["n_rows"]),
                           combos=named.get("combos"), domains=domains,
                           k=k, hash_range=hash_range,
                           tail_folds=tail_folds, evictions=evictions)
    except (KeyError, TypeError, ValueError, IndexError) as e:
        # The leaves CRC'd clean but the manifest decoded to the wrong
        # structure (a bit flip inside a JSON string still parses):
        # corrupt, not a programming error.
        raise CorruptShardError(f"malformed shard manifest in {d}: "
                                f"{e!r}") from e


def restore_shard(path: str, host_id: int, *,
                  aggregate_fn: AggregateFn | None = None,
                  min_epoch: int | None = None):
    """(aggregator, epoch) from a host's LATEST spill, or None if absent.

    A restarted host calls this to resume accumulating from its last
    durable state instead of re-sampling from zero. If LATEST names a
    delta epoch, the full chain ``base + Σ deltas`` is folded
    transparently (:class:`DeltaChain`), so full-spilling and
    delta-spilling hosts are indistinguishable to readers.

    ``min_epoch`` makes the read strict about recency: a host whose
    LATEST is behind it raises :class:`StaleShardError` instead of
    silently handing back old statistics.

    Concurrent-compaction race: the writer may publish a fresh base and
    GC the chain this reader just resolved from a now-stale LATEST. The
    fold then fails mid-walk — re-reading LATEST finds the new (full)
    base, so a couple of retries make the read lock-free. Failures that
    persist past the retries surface as typed
    :class:`~repro.core.faults.SpillError` subclasses.
    """
    hd = _host_dir(path, host_id)
    last_err = None
    for _attempt in range(3):
        epoch = ckpt.latest_step(hd)
        if epoch is None:
            return None
        if min_epoch is not None and epoch < min_epoch:
            raise StaleShardError(
                f"host {host_id} LATEST epoch {epoch} is behind the "
                f"required watermark {min_epoch}")
        try:
            shard = DeltaChain(hd, epoch).fold()
        except IOError as e:
            last_err = e
            continue
        return unpack_shard(shard, aggregate_fn=aggregate_fn), epoch
    raise last_err


def read_shard_meta(path: str, host_id: int) -> dict | None:
    """Manifest of a host's LATEST shard (no array I/O), or None.

    Includes the caller's ``extra`` dict from :func:`spill_shard`.
    """
    hd = _host_dir(path, host_id)
    epoch = ckpt.latest_step(hd)
    if epoch is None:
        return None
    return ckpt.read_manifest_meta(_epoch_dir(hd, epoch))


def list_spilled_hosts(path: str) -> list[int]:
    """Host ids with at least one published (LATEST-named) shard.

    ``.tmp-`` directories from crashed writers are never inspected.
    """
    if not os.path.isdir(path):
        return []
    out = []
    for name in os.listdir(path):
        m = _HOST_DIR_RE.match(name)
        if m and ckpt.latest_step(os.path.join(path, name)) is not None:
            out.append(int(m.group(1)))
    # Numeric, not lexicographic: host_10000 must sort after host_9999
    # (id order is what makes merged combination ids deterministic).
    return sorted(out)


def tree_reduce(aggs: Sequence):
    """Merge aggregators by an order-preserving binary reduction tree.

    The order preservation is correctness-critical (see module
    docstring): it is what makes merged combination id assignment match
    a single pass over the concatenated stream, for any tree shape.
    """
    aggs = list(aggs)
    if not aggs:
        raise ValueError("nothing to reduce")
    while len(aggs) > 1:
        nxt = [aggs[i].merge(aggs[i + 1])
               for i in range(0, len(aggs) - 1, 2)]
        if len(aggs) % 2:
            nxt.append(aggs[-1])
        aggs = nxt
    return aggs[0]


def gather_shards(path: str, *, aggregate_fn: AggregateFn | None = None,
                  quorum: "QuorumPolicy | None" = None,
                  hash_range=None):
    """Merge every published host shard under ``path`` (reduction tree).

    Hosts are taken in id order and merged by :func:`tree_reduce`, so
    combination ids match a single-host pass over the concatenated
    stream regardless of host count.

    Without ``quorum`` this is the strict, all-or-nothing gather: any
    unreadable host raises (typed — see :mod:`repro.core.faults`) and
    the return value is the merged aggregator. With a
    :class:`QuorumPolicy` the gather degrades instead of failing:
    per-host bounded retries with exponential backoff, corrupt epoch
    tails folded back to the last durable prefix, and a
    :class:`GatherResult` return value carrying full provenance — which
    hosts merged at which effective epoch, which were missing, stale or
    quarantined — so downstream reports disclose coverage instead of
    overstating it.

    ``hash_range`` turns the gather into one shard of a per-range
    shuffle: each restored combination aggregator is projected onto the
    range (:meth:`~repro.core.streaming.StreamingCombinationAggregator.
    filter_range`) before the reduction tree, so a caller owning range
    ``i`` of :meth:`HashRange.split(n) <repro.core.sketch.HashRange.
    split>` folds only its keys and no host ever materializes the union
    table. The ``n`` range-gathers partition every (combination, stats)
    row of the fleet exactly once — same delta-spill + quorum machinery,
    O(union / n) memory per owner. Region shards have no key hash to
    shard by, so combining them with ``hash_range`` raises.
    """
    if quorum is not None:
        return _quorum_gather(path, quorum, aggregate_fn,
                              hash_range=hash_range)
    hosts = list_spilled_hosts(path)
    # Strict mode must not silently shrink the fleet: a host whose LATEST
    # file exists but doesn't parse is corrupt, not "never published"
    # (``list_spilled_hosts`` can't tell the two apart — it hides both).
    for h in _list_host_dirs(path):
        hd = _host_dir(path, h)
        if (h not in hosts
                and os.path.exists(os.path.join(hd, "LATEST"))):
            raise CorruptShardError(f"unreadable LATEST under {hd}")
    if not hosts:
        raise MissingArtifactError(f"no published shards under {path}")
    aggs = []
    for h in hosts:
        restored = restore_shard(path, h, aggregate_fn=aggregate_fn)
        assert restored is not None       # list_spilled_hosts checked LATEST
        aggs.append(_project_range(restored[0], hash_range))
    return tree_reduce(aggs)


def _project_range(agg, hash_range):
    """Project a restored aggregator onto a gather's owned hash range
    (identity when no range is requested)."""
    if hash_range is None:
        return agg
    if not isinstance(agg, StreamingCombinationAggregator):
        raise SketchConfigError(
            "hash-range gather needs combination shards: region rows "
            "have no combination key to hash")
    return agg.filter_range(hash_range)


# -- quorum (degraded-mode) gather ---------------------------------------------

@dataclasses.dataclass(frozen=True)
class QuorumPolicy:
    """How a degraded gather trades completeness for availability.

    Attributes
    ----------
    expected_hosts: the fleet roster. ``None`` means "whatever host
        directories exist on disk" — note that a host which crashed
        before its *first* publish is invisible then, so production
        gathers should pass the roster explicitly.
    min_hosts:     merged-host count below which the gather raises
        :class:`QuorumError` rather than return statistics too partial
        to act on.
    min_epoch:     recency watermark: hosts whose effective epoch falls
        behind it are classified stale (merged but disclosed, or
        excluded when ``drop_stale``).
    watermarks:    per-host monotone epoch watermarks (e.g. the
        ``host_epochs`` of the previous :class:`GatherResult`): a host
        folded back *behind* its own last-seen epoch is flagged stale,
        so coverage can never silently move backwards between gathers.
    retries:       read attempts per host before accepting a degraded
        fold or quarantining.
    backoff:       initial inter-attempt sleep, doubled each retry
        (0 disables sleeping — tests).
    sleep_fn:      how the inter-attempt backoff actually waits; defaults
        to ``time.sleep``. Chaos tests exercising the retry ladder pass
        a recording stub so a multi-retry scenario replays instantly and
        deterministically instead of burning real wall-clock time. Only
        ever called *between* attempts — never after the final failed
        one (there is nothing left to wait for).
    drop_stale:    exclude stale hosts from the merge entirely instead
        of merging-and-disclosing.
    """
    expected_hosts: tuple[int, ...] | None = None
    min_hosts: int = 1
    min_epoch: int | None = None
    watermarks: Mapping[int, int] | None = None
    retries: int = 3
    backoff: float = 0.05
    sleep_fn: Callable[[float], None] = time.sleep
    drop_stale: bool = False


@dataclasses.dataclass(frozen=True)
class HostReport:
    """Per-host provenance of one quorum gather.

    ``status`` is one of:

    * ``"merged"``       — full chain folded at the host's LATEST epoch.
    * ``"degraded"``     — a corrupt/torn tail was quarantined; the host
      merged at an earlier durable epoch (``quarantined_epochs`` lists
      the rolled-back tail).
    * ``"stale"``        — durable state is behind the policy watermark
      (merged unless ``drop_stale``).
    * ``"missing"``      — expected host never published.
    * ``"quarantined"``  — host present but nothing durable was readable;
      excluded from the merge.
    """
    host_id: int
    status: str
    epoch: int | None = None             # effective (merged) epoch
    requested_epoch: int | None = None   # LATEST at gather time
    quarantined_epochs: tuple[int, ...] = ()
    error: str | None = None
    attempts: int = 1

    @property
    def merged(self) -> bool:
        return self.epoch is not None


@dataclasses.dataclass(frozen=True)
class GatherResult:
    """A degraded-mode gather: merged statistics + full provenance."""
    agg: object
    hosts: tuple[HostReport, ...]

    @property
    def complete(self) -> bool:
        """True iff every expected host merged its full LATEST chain —
        the condition under which the merge is bit-exact to a fault-free
        gather of the same hosts."""
        return all(r.status == "merged" for r in self.hosts)

    def _by_status(self, *statuses: str) -> tuple[int, ...]:
        return tuple(r.host_id for r in self.hosts if r.status in statuses)

    @property
    def hosts_merged(self) -> tuple[int, ...]:
        return tuple(r.host_id for r in self.hosts if r.merged)

    @property
    def hosts_missing(self) -> tuple[int, ...]:
        return self._by_status("missing")

    @property
    def hosts_stale(self) -> tuple[int, ...]:
        return self._by_status("stale")

    @property
    def hosts_degraded(self) -> tuple[int, ...]:
        return self._by_status("degraded")

    @property
    def hosts_quarantined(self) -> tuple[int, ...]:
        return self._by_status("quarantined")

    @property
    def host_epochs(self) -> dict[int, int]:
        """Effective merged epoch per merged host — feed back as the next
        gather's ``watermarks`` to pin the monotonicity invariant."""
        return {r.host_id: r.epoch for r in self.hosts if r.merged}

    def coverage(self) -> dict:
        """JSON-able provenance dict (the ``EstimateSet.coverage`` payload)."""
        n = len(self.hosts)
        parts = [f"merged {len(self.hosts_merged)}/{n} hosts"]
        for label, ids in (("missing", self.hosts_missing),
                           ("stale", self.hosts_stale),
                           ("degraded", self.hosts_degraded),
                           ("quarantined", self.hosts_quarantined)):
            if ids:
                parts.append(f"{label}: {list(ids)}")
        return {
            "complete": self.complete,
            "hosts_merged": list(self.hosts_merged),
            "hosts_missing": list(self.hosts_missing),
            "hosts_stale": list(self.hosts_stale),
            "hosts_degraded": list(self.hosts_degraded),
            "hosts_quarantined": list(self.hosts_quarantined),
            "host_epochs": {str(h): e for h, e in self.host_epochs.items()},
            "quarantined_epochs": {
                str(r.host_id): list(r.quarantined_epochs)
                for r in self.hosts if r.quarantined_epochs},
            "summary": "; ".join(parts),
        }

    def estimates(self, t_exec: float, names: Sequence[str], *,
                  alpha: float = 0.05):
        """Estimates with the gather's coverage attached (so reports
        disclose partial fleets instead of presenting degraded statistics
        as complete)."""
        return self.agg.estimates(t_exec, names, alpha=alpha,
                                  coverage=self.coverage())


def _list_host_dirs(path: str) -> list[int]:
    """Every host directory, *including* ones with no/unreadable LATEST
    (:func:`list_spilled_hosts` deliberately hides those)."""
    if not os.path.isdir(path):
        return []
    return sorted(int(m.group(1)) for name in os.listdir(path)
                  if (m := _HOST_DIR_RE.match(name)))


def _restore_degraded(path: str, host_id: int, policy: QuorumPolicy,
                      aggregate_fn: AggregateFn | None):
    """One host's best durable state under bounded retries.

    Returns ``(HostReport, PackedShard | None)``. Retries first — a
    failed fold may be the benign concurrent-compaction race — and only
    accepts a degraded (prefix-fold) result once retries are exhausted,
    so transient races never masquerade as corruption in the provenance.
    """
    hd = _host_dir(path, host_id)
    attempts = max(1, policy.retries)
    delay = policy.backoff
    last_err: Exception | None = None
    best: tuple[PackedShard, int, tuple[int, ...], int] | None = None
    for attempt in range(1, attempts + 1):
        if attempt > 1 and delay > 0:
            # Between attempts only: the final failed attempt falls
            # straight through to the degraded/quarantine verdict with
            # no trailing wait.
            policy.sleep_fn(delay)
            delay *= 2
        epoch = ckpt.latest_step(hd)
        if epoch is None:
            if os.path.exists(os.path.join(hd, "LATEST")):
                last_err = CorruptShardError(f"unreadable LATEST under {hd}")
                continue
            return HostReport(host_id, "missing", attempts=attempt,
                              error="never published"), None
        try:
            chain = DeltaChain(hd, epoch)
            shard, effective, failed = chain.fold_partial()
        except IOError as e:
            last_err = e
            continue
        if not failed:
            return (HostReport(host_id, "merged", epoch=effective,
                               requested_epoch=epoch, attempts=attempt),
                    shard)
        best = (shard, effective, failed, epoch)
        last_err = CorruptShardError(
            f"epochs {list(failed)} unreadable under {hd}")
    if best is not None:
        shard, effective, failed, epoch = best
        return (HostReport(host_id, "degraded", epoch=effective,
                           requested_epoch=epoch,
                           quarantined_epochs=failed,
                           error=str(last_err), attempts=attempts),
                shard)
    # Nothing resolvable through LATEST. Fall back to scanning epoch
    # dirs newest-first for any fully durable chain (covers a corrupt
    # LATEST epoch whose *predecessor* base is intact).
    fallback = _scan_last_durable(hd)
    if fallback is not None:
        shard, effective, failed = fallback
        return (HostReport(host_id, "degraded", epoch=effective,
                           requested_epoch=ckpt.latest_step(hd),
                           quarantined_epochs=failed,
                           error=str(last_err), attempts=attempts),
                shard)
    return (HostReport(host_id, "quarantined",
                       requested_epoch=ckpt.latest_step(hd),
                       error=str(last_err) if last_err else "unreadable",
                       attempts=attempts),
            None)


def _scan_last_durable(hd: str):
    """Newest fully-foldable chain among the published epoch dirs, or
    None. Returns ``(shard, effective_epoch, quarantined_epochs)`` where
    the quarantined set is every published epoch above the durable one.
    """
    try:
        names = os.listdir(hd)
    # audit: allow(no-silent-except) absent host dir == no durable state
    except FileNotFoundError:
        return None
    epochs = sorted((int(m.group(1)) for name in names
                     if (m := _EPOCH_DIR_RE.match(name))), reverse=True)
    for i, e in enumerate(epochs):
        try:
            shard = DeltaChain(hd, e).fold()
        # audit: allow(no-silent-except) fold-back scan: the skipped
        # epochs are returned as the quarantined set, not dropped
        except IOError:
            continue
        return shard, e, tuple(sorted(epochs[:i]))
    return None


def _quorum_gather(path: str, policy: QuorumPolicy,
                   aggregate_fn: AggregateFn | None,
                   hash_range=None) -> GatherResult:
    if policy.expected_hosts is not None:
        roster = sorted(set(int(h) for h in policy.expected_hosts))
    else:
        roster = _list_host_dirs(path)
    reports: list[HostReport] = []
    shards: list[PackedShard] = []
    for h in roster:
        rep, shard = _restore_degraded(path, h, policy, aggregate_fn)
        if shard is not None:
            floor = max(policy.min_epoch or 0,
                        (policy.watermarks or {}).get(h, 0))
            if floor and rep.epoch is not None and rep.epoch < floor:
                err = (f"host {h} effective epoch {rep.epoch} is behind "
                       f"the watermark {floor}")
                if policy.drop_stale:
                    rep = dataclasses.replace(rep, status="stale",
                                              epoch=None, error=err)
                    shard = None
                else:
                    rep = dataclasses.replace(rep, status="stale", error=err)
        reports.append(rep)
        if shard is not None:
            shards.append(shard)
    merged_n = sum(1 for r in reports if r.merged)
    if merged_n < policy.min_hosts:
        detail = "; ".join(f"host {r.host_id}: {r.status}"
                           f" ({r.error})" if r.error else
                           f"host {r.host_id}: {r.status}"
                           for r in reports if not r.merged)
        raise QuorumError(
            f"quorum failed under {path}: {merged_n} host(s) merged, "
            f"policy requires {policy.min_hosts} ({detail or 'no hosts'})")
    # Host-id order + the order-preserving reduction tree keep merged
    # combination ids deterministic, exactly as in the strict gather.
    aggs = [_project_range(unpack_shard(s, aggregate_fn=aggregate_fn),
                           hash_range)
            for s in shards]
    return GatherResult(agg=tree_reduce(aggs) if aggs else None,
                        hosts=tuple(reports))


# -- incremental (delta) spills ------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ShardDelta:
    """Row-sparse difference between two published states of one shard.

    ``idx`` lists the rows whose sufficient statistics changed since the
    ``prev_rows``-row predecessor (including all appended rows); the
    parallel ``counts``/``psum``/``psumsq`` arrays hold those rows'
    *replacement* values. Replacement, not arithmetic difference, is what
    keeps a folded chain bit-exact vs. a full spill: int64 differences
    would round-trip, but float64 ``prev + (cur - prev)`` loses ulps.
    ``combos_new`` carries the appended combination key rows
    (``None`` for region shards) — the interner assigns ids in
    first-appearance order and never reorders, so append-only suffices.
    """

    idx: np.ndarray               # int64 [k] changed-row indices
    counts: np.ndarray            # int64 [k] replacement values at idx
    psum: np.ndarray              # float64 [k, C]
    psumsq: np.ndarray            # float64 [k, C]
    n_rows: int                   # rows after applying
    prev_rows: int                # rows in the state this builds on
    combos_new: np.ndarray | None = None   # int64 [n_rows-prev_rows, width]
    domains: tuple[str, ...] = ("total",)
    k: int | None = None               # bounded-state config (must be
    hash_range: tuple[int, int] | None = None   # chain-constant)
    tail_folds: int = 0                # cumulative provenance at this epoch
    evictions: int = 0                 # (latest-wins metadata, not summed)

    def __post_init__(self):
        if self.psum.ndim == 1:
            object.__setattr__(self, "psum", self.psum[:, None])
        if self.psumsq.ndim == 1:
            object.__setattr__(self, "psumsq", self.psumsq[:, None])

    @property
    def kind(self) -> str:
        return KIND_REGION if self.combos_new is None else KIND_COMBINATION


def compute_shard_delta(prev: PackedShard, cur: PackedShard) -> ShardDelta:
    """Row-sparse delta taking ``prev`` to ``cur``.

    Requires append-only evolution: ``cur``'s first ``prev.n_rows``
    combination key rows must equal ``prev``'s (statistics may change
    freely). Raises :class:`~repro.core.faults.DeltaMismatchError`
    (a ``ValueError`` subclass) otherwise — writers fall back to a fresh
    full base in that case.
    """
    if (prev.combos is None) != (cur.combos is None):
        raise DeltaMismatchError("shard kind changed between epochs")
    if prev.domains != cur.domains:
        raise DeltaMismatchError("shard domain axis changed between epochs")
    if prev.k != cur.k or prev.hash_range != cur.hash_range:
        # Config drift (a k-shrink, a resharding) rewrites row identity;
        # a row-sparse overlay can't express it — fresh full base.
        raise DeltaMismatchError(
            f"bounded-state config changed between epochs: "
            f"(k={prev.k}, hash_range={prev.hash_range}) -> "
            f"(k={cur.k}, hash_range={cur.hash_range})")
    n0, n1 = prev.n_rows, cur.n_rows
    if n1 < n0:
        raise DeltaMismatchError(f"shard shrank: {n1} < {n0} rows")
    if cur.combos is not None and n0:
        if prev.combos.shape[1] != cur.combos.shape[1]:
            raise DeltaMismatchError("worker width changed between epochs")
        if not np.array_equal(prev.combos[:n0], cur.combos[:n0]):
            raise DeltaMismatchError(
                "combination key rows are not append-only")
    changed = ((cur.counts[:n0] != prev.counts[:n0])
               | (cur.psum[:n0] != prev.psum[:n0]).any(axis=1)
               | (cur.psumsq[:n0] != prev.psumsq[:n0]).any(axis=1))
    idx = np.concatenate([np.flatnonzero(changed),
                          np.arange(n0, n1)]).astype(np.int64)
    combos_new = None
    if cur.combos is not None:
        combos_new = np.array(cur.combos[n0:n1], dtype=np.int64)
    return ShardDelta(idx=idx,
                      counts=np.asarray(cur.counts, np.int64)[idx],
                      psum=np.asarray(cur.psum, np.float64)[idx],
                      psumsq=np.asarray(cur.psumsq, np.float64)[idx],
                      n_rows=n1, prev_rows=n0, combos_new=combos_new,
                      domains=cur.domains, k=cur.k,
                      hash_range=cur.hash_range,
                      tail_folds=cur.tail_folds, evictions=cur.evictions)


def _grow_1d(arr: np.ndarray, n: int, dtype) -> np.ndarray:
    out = np.zeros(n, dtype)
    out[:len(arr)] = arr
    return out


def _grow_2d(arr: np.ndarray, n: int, dtype) -> np.ndarray:
    out = np.zeros((n, arr.shape[1]), dtype)
    out[:len(arr)] = arr
    return out


def apply_shard_delta(shard: PackedShard, delta: ShardDelta) -> PackedShard:
    """Fold one delta onto a folded shard state (chain-validating)."""
    if delta.prev_rows != shard.n_rows:
        raise CorruptShardError(f"delta chain mismatch: delta builds on "
                                f"{delta.prev_rows} rows, folded state has "
                                f"{shard.n_rows}")
    if (shard.combos is None) != (delta.combos_new is None):
        raise CorruptShardError(f"delta chain mismatch: {delta.kind} delta "
                                f"over a {shard.kind} base")
    if shard.domains != delta.domains:
        raise CorruptShardError(
            f"delta chain mismatch: domain axis {delta.domains} "
            f"delta over a {shard.domains} base")
    if shard.k != delta.k or shard.hash_range != delta.hash_range:
        raise CorruptShardError(
            f"delta chain mismatch: bounded-state config "
            f"(k={delta.k}, hash_range={delta.hash_range}) delta over a "
            f"(k={shard.k}, hash_range={shard.hash_range}) base")
    n1 = delta.n_rows
    if delta.idx.size and int(delta.idx.max()) >= n1:
        # CRC only covers bytes; a structurally corrupt delta must fail
        # with the same diagnostic class as every other malformation
        # (restore_shard's retry loop catches IOError, not IndexError).
        raise CorruptShardError(f"delta row index {int(delta.idx.max())} "
                                f"out of bounds for {n1} rows")
    counts = _grow_1d(shard.counts[:shard.n_rows], n1, np.int64)
    psum = _grow_2d(shard.psum[:shard.n_rows], n1, np.float64)
    psumsq = _grow_2d(shard.psumsq[:shard.n_rows], n1, np.float64)
    counts[delta.idx] = delta.counts
    psum[delta.idx] = delta.psum
    psumsq[delta.idx] = delta.psumsq
    combos = None
    if shard.combos is not None:
        new = delta.combos_new
        if len(new) != n1 - shard.n_rows:
            raise CorruptShardError(
                f"delta appends {len(new)} combo rows; header "
                f"says {n1 - shard.n_rows}")
        if shard.n_rows == 0:
            combos = np.array(new, dtype=np.int64)
        elif len(new) == 0:
            combos = shard.combos[:shard.n_rows]
        else:
            if new.shape[1] != shard.combos.shape[1]:
                raise CorruptShardError("worker width changed mid-chain")
            combos = np.vstack([shard.combos[:shard.n_rows], new])
    return PackedShard(counts=counts, psum=psum, psumsq=psumsq,
                       n_rows=n1, combos=combos, domains=shard.domains,
                       k=shard.k, hash_range=shard.hash_range,
                       tail_folds=delta.tail_folds,
                       evictions=delta.evictions)


def spill_shard_delta(path: str, host_id: int, epoch: int,
                      delta: ShardDelta, *, delta_of: int, base_epoch: int,
                      extra_meta: dict | None = None) -> str:
    """Atomically publish one incremental delta epoch.

    Same manifest+CRC+rename protocol as full spills; the manifest links
    the chain via ``delta_of`` (the epoch this builds on) and
    ``base_epoch`` (the chain's full base, validated by readers).
    """
    hd = _host_dir(path, host_id)
    os.makedirs(hd, exist_ok=True)
    arrays = [delta.idx, delta.counts, _wire_stats(delta.psum),
              _wire_stats(delta.psumsq)]
    meta = {"kind": delta.kind, "host_id": host_id, "epoch": epoch,
            "n_rows": delta.n_rows, "prev_rows": delta.prev_rows,
            "delta_of": int(delta_of), "base_epoch": int(base_epoch),
            "schema": ["idx", "counts", "psum", "psumsq"],
            "schema_version": 2, "domains": list(delta.domains)}
    _bounds_meta(meta, delta.k, delta.hash_range,
                 delta.tail_folds, delta.evictions)
    if extra_meta:
        meta["extra"] = dict(extra_meta)
    if delta.combos_new is not None:
        arrays.append(delta.combos_new)
        meta["schema"] = meta["schema"] + ["combos_new"]
        meta["width"] = int(delta.combos_new.shape[1])
    final = _epoch_dir(hd, epoch)
    ckpt.write_manifest_dir(final, arrays, meta=meta)
    ckpt.publish_latest(hd, epoch)
    return final


def _load_delta(hd: str, epoch: int) -> ShardDelta:
    d = _epoch_dir(hd, epoch)
    arrays, manifest = ckpt.read_manifest_dir(d)
    try:
        named = dict(zip(manifest["schema"], arrays))
        domains = _meta_domains(manifest)
        k, hash_range, tail_folds, evictions = _meta_bounds(manifest)
        return ShardDelta(idx=named["idx"].astype(np.int64),
                          counts=named["counts"].astype(np.int64),
                          psum=_unwire_stats(named["psum"], domains),
                          psumsq=_unwire_stats(named["psumsq"], domains),
                          n_rows=int(manifest["n_rows"]),
                          prev_rows=int(manifest["prev_rows"]),
                          combos_new=named.get("combos_new"),
                          domains=domains, k=k, hash_range=hash_range,
                          tail_folds=tail_folds, evictions=evictions)
    except (KeyError, TypeError, ValueError, IndexError) as e:
        raise CorruptShardError(f"malformed delta manifest in {d}: "
                                f"{e!r}") from e


class DeltaChain:
    """Reader for one host's published epoch chain.

    Walks ``delta_of`` back-pointers from ``epoch`` (normally LATEST)
    down to the full base, validating linkage as it goes: every link
    must exist (a GC'd or never-published epoch breaks the chain), every
    delta must name the same ``base_epoch``, and folding re-checks row
    monotonicity and kind/width consistency. A chain rooted at a full
    epoch of length 1 is the degenerate (pre-delta) format, so readers
    handle both transparently.
    """

    def __init__(self, host_dir: str, epoch: int):
        self.host_dir = host_dir
        self.epoch = epoch
        links: list[tuple[int, dict]] = []
        e, seen = epoch, set()
        while True:
            if e in seen:
                raise CorruptShardError(f"delta chain cycle at epoch {e} "
                                        f"under {host_dir}")
            seen.add(e)
            try:
                meta = ckpt.read_manifest_meta(_epoch_dir(host_dir, e))
            except FileNotFoundError:
                raise TornWriteError(
                    f"broken delta chain under {host_dir}: epoch {e} is "
                    f"missing (garbage-collected or never published)")
            links.append((e, meta))
            if meta.get("delta_of") is None:
                break
            try:
                e = int(meta["delta_of"])
            except (TypeError, ValueError) as err:
                raise CorruptShardError(
                    f"epoch {e} under {host_dir} has an unusable "
                    f"delta_of pointer: {meta.get('delta_of')!r}") from err
        self._links = links[::-1]          # base first, LATEST last
        self.base_epoch = self._links[0][0]
        kinds = {m.get("kind") for _, m in self._links}
        if len(kinds) != 1:
            raise CorruptShardError(
                f"mixed shard kinds in one chain: {sorted(kinds)}")
        for e_, m in self._links[1:]:
            try:
                base_ref = int(m.get("base_epoch", -1))
            except (TypeError, ValueError):
                base_ref = -1
            if base_ref != self.base_epoch:
                raise CorruptShardError(
                    f"delta epoch {e_} names base "
                    f"{m.get('base_epoch')}; chain resolves to "
                    f"{self.base_epoch}")

    @property
    def epochs(self) -> list[int]:
        """Chain epochs, base first."""
        return [e for e, _ in self._links]

    @property
    def latest_meta(self) -> dict:
        return self._links[-1][1]

    def fold(self) -> PackedShard:
        """``base + Σ deltas`` → the full shard state at ``self.epoch``."""
        shard = _load_shard(self.host_dir, self._links[0][0])
        for e, _meta in self._links[1:]:
            shard = apply_shard_delta(shard, _load_delta(self.host_dir, e))
        return shard

    def fold_partial(self) -> tuple[PackedShard, int, tuple[int, ...]]:
        """Best-effort fold: the base plus the longest intact delta prefix.

        Returns ``(shard, effective_epoch, quarantined_epochs)``. Once a
        link fails to load or apply, every later link is quarantined too
        (deltas carry replacement values against the *immediately*
        preceding state — skipping a link and folding on would merge
        rows computed against state the reader never saw, i.e. silent
        corruption; rolling the whole tail back to the last durable
        prefix can only lose recency, never correctness). Raises if the
        base itself is unreadable — there is then nothing durable to
        fall back to and the caller must quarantine the whole host.
        """
        shard = _load_shard(self.host_dir, self._links[0][0])
        effective = self._links[0][0]
        epochs = self.epochs
        for i, (e, _meta) in enumerate(self._links[1:], start=1):
            try:
                shard = apply_shard_delta(shard,
                                          _load_delta(self.host_dir, e))
            except IOError:
                return shard, effective, tuple(epochs[i:])
            effective = e
        return shard, effective, ()


def _copy_shard(s: PackedShard) -> PackedShard:
    """Deep copy — spiller snapshots must not alias live accumulators."""
    return PackedShard(
        counts=np.array(s.counts, np.int64),
        psum=np.array(s.psum, np.float64),
        psumsq=np.array(s.psumsq, np.float64), n_rows=s.n_rows,
        combos=None if s.combos is None else np.array(s.combos, np.int64),
        domains=s.domains, k=s.k, hash_range=s.hash_range,
        tail_folds=s.tail_folds, evictions=s.evictions)


# Injection seam this module owns (see faults.FAULT_SITES): the publish
# step of ShardSpiller.spill — crash-before-publish, silent straggle,
# transient failure.
_SITE_SPILLER_PUBLISH = declare_site("spiller.publish")


class ShardSpiller:
    """Per-host durable publishing engine: incremental spills + compaction.

    ``mode="delta"`` (default) publishes a full base first, then
    row-sparse :class:`ShardDelta` epochs, and every ``compact_every``-th
    publish rewrites a fresh base and garbage-collects the consumed
    chain — steady-state spill bandwidth scales with rows *touched* per
    epoch, and the host directory stays O(compact window) instead of
    O(run length). ``mode="full"`` republishes the whole shard every
    epoch (each publish also GCs the consumed predecessors — unlike the
    bare :func:`spill_shard` free function, which leaves old epochs in
    place). Readers retry around the GC window (see
    :func:`restore_shard`), so neither mode blocks concurrent gathers.

    Changed-row detection is O(rows touched), not O(rows): once a spiller
    has published an aggregator instance, subsequent deltas come from the
    aggregator's generation-stamped touched-row tracking
    (``rows_touched_since`` — a superset of the rows whose values
    changed, stamped as updates/merges land; reads are non-destructive,
    so several spillers can publish one aggregator to different
    destinations, each against its own watermark), so no host-side
    snapshot of the packed shard is retained or diffed. The exact array
    diff (:func:`compute_shard_delta` against the restored chain) is
    used only for the *first* publish of an aggregator instance this
    spiller hasn't tracked (e.g. after a restore) — which keeps a
    restarted deterministic profiler's idempotent republish an *empty*
    delta — and aggregators without touch tracking fall back to the
    per-epoch snapshot diff.

    Construction restores the on-disk chain (if any): ``resumed`` holds
    the folded aggregator, ``resumed_meta`` the LATEST manifest, and
    ``epoch`` the LATEST epoch — a host killed *anywhere* (mid-delta,
    between a delta publish and its compaction, mid-compaction) resumes
    from exactly what readers can see, so nothing is double-counted.
    """

    def __init__(self, path: str, host_id: int = 0, *, mode: str = "delta",
                 compact_every: int = 16,
                 aggregate_fn: AggregateFn | None = None,
                 faults: "faults_mod.FaultPlan | None" = None):
        if mode not in ("full", "delta"):
            raise ValueError(f"unknown spill mode {mode!r}")
        if compact_every < 1:
            raise ValueError(f"compact_every must be >= 1; "
                             f"got {compact_every}")
        self.path = path
        self.host_id = host_id
        # Captured once (explicit arg or the ambient installed plan):
        # spills may run from worker threads, where contextvars set in
        # the test thread are invisible.
        self._faults = faults_mod.resolve_plan(faults)
        self.mode = mode
        self.compact_every = compact_every
        self._hd = _host_dir(path, host_id)
        self.epoch = 0
        self.resumed = None
        self.resumed_meta: dict | None = None
        self.resumed_dir: str | None = None    # LATEST epoch's directory
        self._published = False
        # Exact-diff base for the first publish of an agg instance this
        # spiller hasn't tracked (restored chains); dropped as soon as
        # dirty tracking takes over — never refreshed per epoch.
        self._prev: PackedShard | None = None
        self._prev_rows = 0                    # rows at `epoch`
        # Weakly held tracked-aggregator identity: a weakref (not id())
        # so a recycled address can never make a fresh aggregator pass
        # as tracked, and the spiller never extends the agg's lifetime.
        self._agg_ref = None
        self._seen_gen = 0      # touch-clock watermark of the last publish
        self._base_epoch: int | None = None
        self._since_base = 0
        latest = ckpt.latest_step(self._hd)
        if latest is not None:
            chain = DeltaChain(self._hd, latest)
            self._prev = chain.fold()
            self._prev_rows = self._prev.n_rows
            self._published = True
            self.epoch = latest
            self._base_epoch = chain.base_epoch
            self._since_base = len(chain.epochs) - 1
            self.resumed = unpack_shard(self._prev,
                                        aggregate_fn=aggregate_fn)
            self.resumed_meta = chain.latest_meta
            self.resumed_dir = _epoch_dir(self._hd, latest)

    def _dirty_delta(self, dirty: np.ndarray,
                     cur: PackedShard) -> ShardDelta:
        """Delta from the aggregator's touched-row set (no prev arrays).

        Valid only for the instance this spiller last published (row
        prefix continuity is then structural: statistics rows mutate in
        place and combination keys only append).
        """
        n0, n1 = self._prev_rows, cur.n_rows
        idx = np.concatenate([dirty[dirty < n0],
                              np.arange(n0, n1)]).astype(np.int64)
        combos_new = None
        if cur.combos is not None:
            combos_new = np.array(cur.combos[n0:n1], dtype=np.int64)
        return ShardDelta(idx=idx,
                          counts=np.asarray(cur.counts, np.int64)[idx],
                          psum=np.asarray(cur.psum, np.float64)[idx],
                          psumsq=np.asarray(cur.psumsq, np.float64)[idx],
                          n_rows=n1, prev_rows=n0,
                          combos_new=combos_new, domains=cur.domains,
                          k=cur.k, hash_range=cur.hash_range,
                          tail_folds=cur.tail_folds,
                          evictions=cur.evictions)

    def spill(self, agg, epoch: int, extra_meta: dict | None = None) -> str:
        """Publish ``agg``'s state as ``epoch`` (delta when profitable)."""
        if self._published and epoch <= self.epoch:
            raise ValueError(f"epoch {epoch} already published "
                             f"(LATEST is {self.epoch})")
        plan = self._faults
        if plan is not None:
            # Named fault seam (chaos harness). All three fire *before*
            # any state mutation, so the spiller — like a real crashed
            # or stalled host — leaves durable state and its own
            # bookkeeping exactly as the previous epoch left them.
            if plan.crash_at(self.host_id, epoch):
                raise InjectedCrash(f"host {self.host_id} crashed "
                                    f"publishing epoch {epoch}")
            if plan.spill_fails(self.host_id, epoch):
                raise SpillError(f"injected transient spill failure "
                                 f"(host {self.host_id}, epoch {epoch})")
            if plan.straggles(self.host_id, epoch):
                # Silent stall: the host keeps running but its durable
                # state stops advancing (the stale-shard failure mode).
                return _epoch_dir(self._hd, self.epoch)
        cur = pack_shard(agg)
        # Touch tracking assumes append-only row identity: a bounded
        # aggregator that has evicted (or shrunk) rewrote combo rows in
        # place, and a dirty-index overlay against the *old* identity
        # would silently corrupt the chain. Such aggregators fall back
        # to the exact snapshot diff, which detects rewrites
        # (DeltaMismatchError) and publishes a fresh full base.
        trackable = (hasattr(agg, "rows_touched_since")
                     and getattr(agg, "append_only", True))
        tracked = (trackable and self._agg_ref is not None
                   and self._agg_ref() is agg)
        full = (self.mode == "full" or not self._published
                or self._since_base + 1 >= self.compact_every)
        delta = None
        gen = agg.touch_generation() if trackable else 0
        if not full:
            if tracked and cur.n_rows >= self._prev_rows:
                delta = self._dirty_delta(
                    agg.rows_touched_since(self._seen_gen), cur)
            elif self._prev is not None:
                try:
                    delta = compute_shard_delta(self._prev, cur)
                except ValueError:
                    delta = None
            # Non-append-only evolution (kind/width/domain change,
            # shrink) or an untracked aggregator instance: a delta
            # can't express it — publish a fresh base.
            full = delta is None
        if full:
            out = _spill_packed(self.path, self.host_id, epoch, cur,
                                extra_meta=extra_meta)
            self._gc_consumed(keep=epoch)
            self._base_epoch = epoch
            self._since_base = 0
        else:
            out = spill_shard_delta(self.path, self.host_id, epoch,
                                    delta, delta_of=self.epoch,
                                    base_epoch=self._base_epoch,
                                    extra_meta=extra_meta)
            self._since_base += 1
        # Advance the watermark only now that the epoch is durable: a
        # failed publish above leaves _seen_gen untouched, so every
        # still-unpublished row reappears in the next attempt's delta.
        if trackable:
            # Touch tracking owns change detection from here on: drop
            # the exact-diff base (if any) — it is never refreshed.
            self._agg_ref = weakref.ref(agg)
            self._seen_gen = gen
            self._prev = None
        else:
            self._agg_ref = None
            self._prev = _copy_shard(cur)
        self._prev_rows = cur.n_rows
        self._published = True
        self.epoch = epoch
        return out

    def _gc_consumed(self, keep: int) -> None:
        """Drop epoch dirs made unreachable by the fresh base ``keep``.

        Runs only after ``keep`` is durable and LATEST points at it, so
        a crash mid-GC leaves extra (ignored) dirs, never a broken
        chain. ``.tmp-`` litter from crashed writers doesn't match the
        epoch pattern and is left alone.
        """
        try:
            names = os.listdir(self._hd)
        # audit: allow(no-silent-except) nothing published -> nothing to GC
        except FileNotFoundError:
            return
        for name in names:
            m = _EPOCH_DIR_RE.match(name)
            if m and int(m.group(1)) != keep:
                shutil.rmtree(os.path.join(self._hd, name),
                              ignore_errors=True)


# -- profiler strategies -------------------------------------------------------

class CollectiveExchange:
    """``exchange=`` strategy: all-reduce the final shard over a mesh axis.

    Production: every host constructs the same multi-host mesh and each
    passes its local aggregator; CI: a 1-device mesh exercises the same
    pack → shard_map collective → unpack path.
    """

    def __init__(self, mesh=None, *, axis: str = "hosts",
                 capacity: int | None = None, width: int | None = None,
                 aggregate_fn: AggregateFn | None = None):
        self.mesh = mesh
        self.axis = axis
        self.capacity = capacity
        self.width = width
        self.aggregate_fn = aggregate_fn

    def reduce(self, agg):
        return collective_reduce([agg], mesh=self.mesh, axis=self.axis,
                                 capacity=self.capacity, width=self.width,
                                 aggregate_fn=self.aggregate_fn)


class CheckpointExchange:
    """``exchange=`` strategy: durable spill + gather via shared storage.

    ``spill()`` may be called per epoch for fault tolerance (the serving
    accountant does); ``reduce()`` publishes the final state and merges
    every host's LATEST shard. ``resumed`` exposes the host's previous
    spill (if any) for *accumulating* callers that replay only the work
    after it; deterministic re-runs (the profiler) must ignore it — they
    regenerate the full shard and republish LATEST idempotently (in
    delta mode, the republish is an empty delta epoch: the regenerated
    state matches the restored chain row for row).

    ``mode="delta"`` (default) publishes incremental epochs with
    compaction every ``compact_every`` publishes; ``mode="full"``
    rewrites the whole shard each epoch (see :class:`ShardSpiller`).
    """

    def __init__(self, path: str, host_id: int = 0, *,
                 aggregate_fn: AggregateFn | None = None,
                 mode: str = "delta", compact_every: int = 16,
                 quorum: QuorumPolicy | None = None,
                 faults: "faults_mod.FaultPlan | None" = None):
        self.path = path
        self.host_id = host_id
        self.aggregate_fn = aggregate_fn
        self.quorum = quorum
        self._spiller = ShardSpiller(path, host_id, mode=mode,
                                     compact_every=compact_every,
                                     aggregate_fn=aggregate_fn,
                                     faults=faults)
        self.resumed = self._spiller.resumed
        self.epoch = self._spiller.epoch

    def spill(self, agg) -> str:
        self.epoch += 1
        return self._spiller.spill(agg, self.epoch)

    def reduce(self, agg):
        """Publish the final state and merge the fleet's LATEST shards.

        With a ``quorum`` policy the merge degrades instead of failing;
        the merged aggregator is returned (keeping the strategy
        interface) and the full :class:`GatherResult` provenance is kept
        on ``self.last_gather`` for callers that disclose coverage.
        """
        self.spill(agg)
        if self.quorum is not None:
            self.last_gather = gather_shards(self.path,
                                             aggregate_fn=self.aggregate_fn,
                                             quorum=self.quorum)
            return self.last_gather.agg
        return gather_shards(self.path, aggregate_fn=self.aggregate_fn)
