"""Cross-host shard exchange for the streaming aggregation engine.

ALEA's estimator is multi-worker by design (§4.4): per-region sample
shards collected on each host must be reduced into one set of sufficient
statistics (counts, Σpow, Σpow²) — and, for combination attribution, one
deduplicated combination id space — before confidence intervals are
valid. :mod:`repro.core.streaming` gives the in-process ``merge()``; this
module moves it across hosts, two ways:

* **Collective path** — :func:`collective_reduce`. Each host serializes
  its aggregator into fixed-shape arrays (:func:`pack_shard`) and the
  statistics are all-reduced via ``jax.lax.psum`` over a 1-D mesh axis
  (``launch.mesh.make_exchange_mesh``). Combination shards cannot be
  summed (ids are host-local), so their key tables + statistics are
  ``all_gather``-ed instead and every host folds the same ordered union
  merge — deterministic and identical on all hosts. Interpret-friendly:
  runs eagerly under ``shard_map`` on CPU test meshes.

* **Checkpointed path** — :func:`spill_shard` / :func:`gather_shards`.
  Each host atomically spills its shard using the manifest+CRC+rename
  protocol of :mod:`repro.checkpoint.ckpt` (``write_manifest_dir``), so
  hosts can die and rejoin: a crashed spill leaves only an ignored
  ``.tmp-`` directory, a restarted host resumes from its own LATEST
  (:func:`restore_shard`), and the reader merges whatever shards are
  published. Restore is a left-to-right binary reduction tree::

      host_0   host_1   host_2   host_3     (published shards, id order)
         \\       /         \\       /
          m_01               m_23           round 1: pairwise merge()
              \\             /
               \\           /
                m_0123                      round 2 → merged aggregator

  ``merge`` appends a shard's unseen combination rows in the shard's
  local first-appearance order, so *any* order-preserving tree assigns
  the same union ids as a single aggregator fed the concatenated stream
  — id assignment is reduction-shape independent.

Shard manifest schema (see ROADMAP "exchange formats"): arrays
``counts`` int64[cap], ``psum``/``psumsq`` float64[cap] and, for
combination shards, ``combos`` int64[cap, width]; manifest ``meta`` keys
``kind`` ("region"|"combination"), ``host_id``, ``epoch``, ``n_rows``
(valid prefix — rows past it are padding for fixed-shape collectives).
"""

from __future__ import annotations

import dataclasses
import json
import os
import re
from typing import Sequence

import numpy as np

from repro.checkpoint import ckpt
from repro.core.estimator import AggregateFn
from repro.core.streaming import (StreamingAggregator,
                                  StreamingCombinationAggregator)

__all__ = [
    "PackedShard", "pack_shard", "unpack_shard",
    "collective_reduce", "spill_shard", "restore_shard",
    "read_shard_meta", "gather_shards", "list_spilled_hosts",
    "tree_reduce", "CollectiveExchange", "CheckpointExchange",
]

# \d+ not \d{4}: the :04d dir format zero-pads but never truncates, so
# host ids >= 10000 still publish (and must still gather).
_HOST_DIR_RE = re.compile(r"^host_(\d+)$")

KIND_REGION = "region"
KIND_COMBINATION = "combination"


# -- wire format ---------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PackedShard:
    """One host's aggregator state as fixed-shape arrays.

    ``n_rows`` is the valid prefix; rows past it are zero padding so
    shards from hosts with different region/combination counts still
    stack into one mesh-reducible array. ``combos`` is the host-local
    combination key table (None for plain region shards) — receivers
    dedupe it lazily at merge via ``CombinationInterner.intern_rows``.
    """

    counts: np.ndarray            # int64 [cap]
    psum: np.ndarray              # float64 [cap]
    psumsq: np.ndarray            # float64 [cap]
    n_rows: int
    combos: np.ndarray | None = None   # int64 [cap, width] or None

    @property
    def kind(self) -> str:
        return KIND_REGION if self.combos is None else KIND_COMBINATION

    @property
    def capacity(self) -> int:
        return len(self.counts)


def _pad(arr: np.ndarray, cap: int) -> np.ndarray:
    if len(arr) > cap:
        raise ValueError(f"shard has {len(arr)} rows > capacity {cap}")
    if len(arr) == cap:
        return arr
    pad = [(0, cap - len(arr))] + [(0, 0)] * (arr.ndim - 1)
    return np.pad(arr, pad)


def pack_shard(agg: StreamingAggregator | StreamingCombinationAggregator,
               capacity: int | None = None) -> PackedShard:
    """Serialize an aggregator into a :class:`PackedShard`.

    ``capacity`` pads the row dimension to a fixed size; collectives need
    every participating host to pass the same value.
    """
    if isinstance(agg, StreamingCombinationAggregator):
        combos = agg.interner.combo_matrix()
        n_rows = len(combos)
        cap = n_rows if capacity is None else capacity
        return PackedShard(
            counts=_pad(agg.agg.counts[:n_rows], cap),
            psum=_pad(agg.agg.psum[:n_rows], cap),
            psumsq=_pad(agg.agg.psumsq[:n_rows], cap),
            n_rows=n_rows, combos=_pad(combos, cap))
    n_rows = agg.num_regions
    cap = n_rows if capacity is None else capacity
    return PackedShard(counts=_pad(agg.counts, cap),
                       psum=_pad(agg.psum, cap),
                       psumsq=_pad(agg.psumsq, cap), n_rows=n_rows)


def unpack_shard(shard: PackedShard, *,
                 aggregate_fn: AggregateFn | None = None
                 ) -> StreamingAggregator | StreamingCombinationAggregator:
    """Reconstruct a live aggregator from a packed shard."""
    k = shard.n_rows
    if shard.combos is None:
        agg = StreamingAggregator(k, aggregate_fn=aggregate_fn)
        agg.counts += np.asarray(shard.counts[:k], np.int64)
        agg.psum += np.asarray(shard.psum[:k], np.float64)
        agg.psumsq += np.asarray(shard.psumsq[:k], np.float64)
        return agg
    cagg = StreamingCombinationAggregator(aggregate_fn=aggregate_fn)
    cagg.merge_table(shard.combos[:k], shard.counts[:k],
                     shard.psum[:k], shard.psumsq[:k])
    return cagg


def _merge_shard_into(agg, shard: PackedShard):
    """Fold a packed shard into a live aggregator (kinds must match)."""
    k = shard.n_rows
    if isinstance(agg, StreamingCombinationAggregator):
        if shard.combos is None:
            raise ValueError("cannot merge a region shard into a "
                             "combination aggregator")
        return agg.merge_table(shard.combos[:k], shard.counts[:k],
                               shard.psum[:k], shard.psumsq[:k])
    if shard.combos is not None:
        raise ValueError("cannot merge a combination shard into a region "
                         "aggregator")
    other = unpack_shard(shard)
    return agg.merge(other)


# -- collective path -----------------------------------------------------------

def _stack_global(mesh, axis: str, rows: Sequence[np.ndarray]):
    """Stack per-position rows into the [H, ...] global array for a mesh.

    Single-process (CI): plain np.stack — ``rows`` holds every position.
    Multi-process (production): each process passes only its local row(s)
    and the global array is assembled from process-local data.
    """
    import jax
    stacked = np.stack(rows)
    if jax.process_count() == 1:
        return stacked
    from jax.sharding import NamedSharding, PartitionSpec as P
    sharding = NamedSharding(mesh, P(axis))
    return jax.make_array_from_process_local_data(sharding, stacked)


def collective_reduce(shards: Sequence[StreamingAggregator |
                                       StreamingCombinationAggregator],
                      *, mesh=None, axis: str = "hosts",
                      capacity: int | None = None, width: int | None = None,
                      aggregate_fn: AggregateFn | None = None):
    """All-reduce aggregator shards over a mesh axis; returns the merge.

    ``shards`` holds one aggregator per position of the mesh axis this
    process owns — in production each host passes ``[its local shard]``
    against a multi-host mesh; in single-process tests pass all H shards
    against an H-device mesh. Plain region shards reduce with one
    ``lax.psum``; combination shards ``all_gather`` (tables are
    host-local id spaces, not summable) and every host folds the same
    ordered union merge, so results are identical everywhere.
    """
    import jax
    from jax.experimental import enable_x64
    from jax.sharding import PartitionSpec as P
    from functools import partial

    from repro.compat import shard_map
    from repro.launch.mesh import make_exchange_mesh

    if not shards:
        raise ValueError("no shards to reduce")
    if mesh is None:
        mesh = make_exchange_mesh(len(shards), axis=axis)
    n_hosts = mesh.shape[axis]
    if capacity is None:
        if isinstance(shards[0], StreamingCombinationAggregator):
            capacity = max(len(s.interner) for s in shards)
        else:
            capacity = max(s.num_regions for s in shards)
    packed = [pack_shard(s, capacity) for s in shards]
    kinds = {p.kind for p in packed}
    if len(kinds) != 1:
        raise ValueError(f"mixed shard kinds: {sorted(kinds)}")
    if KIND_COMBINATION in kinds:
        # A host that saw no traffic has a width-0 key table; its combos
        # must still stack to the fleet's fixed [cap, width] shape (its
        # n_rows=0 keeps the zero rows out of the merge). Multi-process
        # fleets pass ``width`` explicitly (worker count is static).
        widths = {p.combos.shape[1] for p in packed if p.combos.shape[1]}
        if width is not None:
            widths.add(width)
        if len(widths) > 1:
            raise ValueError(f"worker-count mismatch across shards: "
                             f"{sorted(widths)}")
        w = widths.pop() if widths else 0
        packed = [p if p.combos.shape[1] == w else dataclasses.replace(
                      p, combos=np.zeros((p.capacity, w), np.int64))
                  for p in packed]
    smap = partial(shard_map, mesh=mesh, in_specs=P(axis), out_specs=P(),
                   check_vma=False)

    # jax's default 32-bit mode would truncate int64 counts and round
    # float64 sums; the exchange is bit-exact only under x64.
    with enable_x64():
        if KIND_REGION in kinds:
            counts = _stack_global(mesh, axis, [p.counts for p in packed])
            psum = _stack_global(mesh, axis, [p.psum for p in packed])
            psumsq = _stack_global(mesh, axis, [p.psumsq for p in packed])

            def _allreduce(c, s, q):
                return (jax.lax.psum(c, axis).sum(0),
                        jax.lax.psum(s, axis).sum(0),
                        jax.lax.psum(q, axis).sum(0))

            c, s, q = smap(_allreduce)(counts, psum, psumsq)
            # Remote hosts may populate rows past any local shard's
            # n_rows; the merged statistics span the full capacity.
            return unpack_shard(
                PackedShard(counts=np.asarray(c), psum=np.asarray(s),
                            psumsq=np.asarray(q), n_rows=capacity),
                aggregate_fn=aggregate_fn)

        combos = _stack_global(mesh, axis, [p.combos for p in packed])
        counts = _stack_global(mesh, axis, [p.counts for p in packed])
        psum = _stack_global(mesh, axis, [p.psum for p in packed])
        psumsq = _stack_global(mesh, axis, [p.psumsq for p in packed])
        n_rows = _stack_global(
            mesh, axis,
            [np.asarray([p.n_rows], np.int64) for p in packed])

        def _gather(*arrs):
            return tuple(jax.lax.all_gather(a, axis, axis=0, tiled=True)
                         for a in arrs)

        g = smap(_gather)(combos, counts, psum, psumsq, n_rows)
        g_combos, g_counts, g_psum, g_psumsq, g_rows = map(np.asarray, g)
        merged = StreamingCombinationAggregator(aggregate_fn=aggregate_fn)
        for h in range(n_hosts):
            k = int(g_rows[h, 0])
            merged.merge_table(g_combos[h, :k], g_counts[h, :k],
                               g_psum[h, :k], g_psumsq[h, :k])
        return merged


# -- checkpointed path ---------------------------------------------------------

def _host_dir(path: str, host_id: int) -> str:
    return os.path.join(path, f"host_{host_id:04d}")


def spill_shard(path: str, host_id: int, epoch: int,
                agg: StreamingAggregator | StreamingCombinationAggregator,
                *, extra_meta: dict | None = None) -> str:
    """Atomically publish one host's shard at ``epoch``.

    Reuses the checkpoint manifest+CRC+rename protocol: a shard is never
    half-visible, and per-host ``LATEST`` is only advanced after the
    epoch directory is durable. ``extra_meta`` (JSON-serializable) rides
    along under the manifest's ``"extra"`` key — callers stash run-scope
    state a restarted host needs (e.g. elapsed wall time). Returns the
    published directory.
    """
    hd = _host_dir(path, host_id)
    os.makedirs(hd, exist_ok=True)
    shard = pack_shard(agg)
    arrays = [shard.counts, shard.psum, shard.psumsq]
    meta = {"kind": shard.kind, "host_id": host_id, "epoch": epoch,
            "n_rows": shard.n_rows,
            "schema": ["counts", "psum", "psumsq"]}
    if extra_meta:
        meta["extra"] = dict(extra_meta)
    if shard.combos is not None:
        arrays.append(shard.combos)
        meta["schema"] = meta["schema"] + ["combos"]
        meta["width"] = int(shard.combos.shape[1])
    final = os.path.join(hd, f"epoch_{epoch:09d}")
    ckpt.write_manifest_dir(final, arrays, meta=meta)
    ckpt.publish_latest(hd, epoch)
    return final


def _load_shard(hd: str, epoch: int) -> PackedShard:
    d = os.path.join(hd, f"epoch_{epoch:09d}")
    arrays, manifest = ckpt.read_manifest_dir(d)
    named = dict(zip(manifest["schema"], arrays))
    return PackedShard(counts=named["counts"].astype(np.int64),
                       psum=named["psum"], psumsq=named["psumsq"],
                       n_rows=int(manifest["n_rows"]),
                       combos=named.get("combos"))


def restore_shard(path: str, host_id: int, *,
                  aggregate_fn: AggregateFn | None = None):
    """(aggregator, epoch) from a host's LATEST spill, or None if absent.

    A restarted host calls this to resume accumulating from its last
    durable state instead of re-sampling from zero.
    """
    hd = _host_dir(path, host_id)
    epoch = ckpt.latest_step(hd)
    if epoch is None:
        return None
    shard = _load_shard(hd, epoch)
    return unpack_shard(shard, aggregate_fn=aggregate_fn), epoch


def read_shard_meta(path: str, host_id: int) -> dict | None:
    """Manifest of a host's LATEST shard (no array I/O), or None.

    Includes the caller's ``extra`` dict from :func:`spill_shard`.
    """
    hd = _host_dir(path, host_id)
    epoch = ckpt.latest_step(hd)
    if epoch is None:
        return None
    d = os.path.join(hd, f"epoch_{epoch:09d}")
    with open(os.path.join(d, "manifest.json")) as f:
        return json.load(f)


def list_spilled_hosts(path: str) -> list[int]:
    """Host ids with at least one published (LATEST-named) shard.

    ``.tmp-`` directories from crashed writers are never inspected.
    """
    if not os.path.isdir(path):
        return []
    out = []
    for name in os.listdir(path):
        m = _HOST_DIR_RE.match(name)
        if m and ckpt.latest_step(os.path.join(path, name)) is not None:
            out.append(int(m.group(1)))
    # Numeric, not lexicographic: host_10000 must sort after host_9999
    # (id order is what makes merged combination ids deterministic).
    return sorted(out)


def tree_reduce(aggs: Sequence):
    """Merge aggregators by an order-preserving binary reduction tree.

    The order preservation is correctness-critical (see module
    docstring): it is what makes merged combination id assignment match
    a single pass over the concatenated stream, for any tree shape.
    """
    aggs = list(aggs)
    if not aggs:
        raise ValueError("nothing to reduce")
    while len(aggs) > 1:
        nxt = [aggs[i].merge(aggs[i + 1])
               for i in range(0, len(aggs) - 1, 2)]
        if len(aggs) % 2:
            nxt.append(aggs[-1])
        aggs = nxt
    return aggs[0]


def gather_shards(path: str, *, aggregate_fn: AggregateFn | None = None):
    """Merge every published host shard under ``path`` (reduction tree).

    Hosts are taken in id order and merged by :func:`tree_reduce`, so
    combination ids match a single-host pass over the concatenated
    stream regardless of host count.
    """
    hosts = list_spilled_hosts(path)
    if not hosts:
        raise FileNotFoundError(f"no published shards under {path}")
    aggs = []
    for h in hosts:
        restored = restore_shard(path, h, aggregate_fn=aggregate_fn)
        assert restored is not None       # list_spilled_hosts checked LATEST
        aggs.append(restored[0])
    return tree_reduce(aggs)


# -- profiler strategies -------------------------------------------------------

class CollectiveExchange:
    """``exchange=`` strategy: all-reduce the final shard over a mesh axis.

    Production: every host constructs the same multi-host mesh and each
    passes its local aggregator; CI: a 1-device mesh exercises the same
    pack → shard_map collective → unpack path.
    """

    def __init__(self, mesh=None, *, axis: str = "hosts",
                 capacity: int | None = None, width: int | None = None,
                 aggregate_fn: AggregateFn | None = None):
        self.mesh = mesh
        self.axis = axis
        self.capacity = capacity
        self.width = width
        self.aggregate_fn = aggregate_fn

    def reduce(self, agg):
        return collective_reduce([agg], mesh=self.mesh, axis=self.axis,
                                 capacity=self.capacity, width=self.width,
                                 aggregate_fn=self.aggregate_fn)


class CheckpointExchange:
    """``exchange=`` strategy: durable spill + gather via shared storage.

    ``spill()`` may be called per epoch for fault tolerance (the serving
    accountant does); ``reduce()`` publishes the final state and merges
    every host's LATEST shard. ``resumed`` exposes the host's previous
    spill (if any) for *accumulating* callers that replay only the work
    after it; deterministic re-runs (the profiler) must ignore it — they
    regenerate the full shard and republish LATEST idempotently.
    """

    def __init__(self, path: str, host_id: int = 0, *,
                 aggregate_fn: AggregateFn | None = None):
        self.path = path
        self.host_id = host_id
        self.aggregate_fn = aggregate_fn
        self.epoch = 0
        prev = restore_shard(path, host_id, aggregate_fn=aggregate_fn)
        self.resumed = prev[0] if prev is not None else None
        if prev is not None:
            self.epoch = prev[1]

    def spill(self, agg) -> str:
        self.epoch += 1
        return spill_shard(self.path, self.host_id, self.epoch, agg)

    def reduce(self, agg):
        self.spill(agg)
        return gather_shards(self.path, aggregate_fn=self.aggregate_fn)
