"""Deterministic fault injection + the typed spill-failure hierarchy.

Production fleets fail partially: hosts crash mid-epoch, spill files get
torn or bit-flipped by the storage layer, sensor channels stall, and
background sampler threads die. This module gives the profiling stack a
single, replayable model of those failures:

* A typed error hierarchy rooted at :class:`SpillError` (itself an
  ``IOError`` so existing ``except IOError`` retry loops keep working):
  :class:`CorruptShardError` (bytes present but wrong),
  :class:`TornWriteError` (bytes missing/short) and
  :class:`StaleShardError` (host present but behind the required
  watermark). Tolerance code dispatches on these types instead of
  matching message strings.

* A seeded, frozen :class:`FaultPlan` that injects faults at *named
  seams* — ``ShardSpiller.spill`` (host crashes, silent stragglers,
  transient publish failures, post-publish corruption), ``ckpt`` leaf
  write/read (torn writes, bit flips), ``HostSampler._loop`` (sampler
  thread death) and the trace-sensor bank (per-rail dropouts). Every
  corruption choice (which byte, which bit, how short a truncation) is
  counter-keyed off ``(seed, seam, keys...)`` through a splitmix-style
  mixer — no wall-clock randomness, so a chaos run replays bit-exactly
  and ``FaultPlan()`` (the empty plan) is byte-for-byte a no-op.

Seams accept the plan two ways: explicitly (``faults=`` constructor
parameters on ``ShardSpiller`` / ``HostSampler`` / the sensor banks) or
ambiently via :func:`install` for seams too deep to thread a parameter
through (the ``ckpt`` leaf codec). The ambient plan is a contextvar, so
concurrent tests don't leak plans into each other. Note contextvars do
not propagate into already-running threads: thread-owning seams capture
the active plan at construction time.
"""

from __future__ import annotations

import contextlib
import contextvars
import dataclasses
import re
from typing import Iterator, Sequence

import numpy as np

__all__ = [
    "SpillError", "CorruptShardError", "TornWriteError", "StaleShardError",
    "DeltaMismatchError", "SketchConfigError", "QuorumError",
    "MissingArtifactError",
    "InjectedCrash", "ChannelDropout", "LeafFault", "FaultPlan",
    "install", "active_plan", "resolve_plan",
    "FAULT_SITES", "declare_site", "declared_sites",
]


# -- typed failure hierarchy --------------------------------------------------

class SpillError(IOError):
    """Base for durable-spill failures (subclasses ``IOError`` so the
    pre-existing transient-race retry loops in ``restore_shard`` and the
    gather path catch the typed errors unchanged)."""


class CorruptShardError(SpillError):
    """Published bytes are present but wrong: CRC mismatch, unparseable
    manifest, undecodable leaf. The epoch must be quarantined — its rows
    may never be merged."""


class TornWriteError(SpillError):
    """Published bytes are missing or short: a leaf file truncated or
    deleted after the manifest was published (leaf data files are not
    fsynced — only the manifest is — so a machine crash right after the
    rename can tear them)."""


class StaleShardError(SpillError):
    """A host's latest durable epoch is behind the gather's required
    watermark (straggler, or a corrupt tail folded back past it)."""


class DeltaMismatchError(SpillError, ValueError):
    """Writer-side delta precondition failure: the aggregator did not
    evolve append-only (kind/width/domain change, shrink, rewritten key
    rows), so no delta can express the epoch. Also a ``ValueError`` so
    the spiller's pre-existing fall-back-to-full-base handler catches it
    unchanged."""


class SketchConfigError(SpillError, ValueError):
    """Bounded-attribution configuration mismatch at a merge/gather seam:
    two combination tables disagree on top-k capacity, ``other``-bucket
    layout (sentinel tail rows merged into an exact table), or hash-range
    ownership. Folding them would silently blend incompatible tails, so
    the merge refuses. Also a ``ValueError`` (API-misuse flavor), same
    pattern as :class:`DeltaMismatchError`."""


class QuorumError(SpillError):
    """A quorum gather could not merge the policy's minimum host count."""


class MissingArtifactError(SpillError, FileNotFoundError):
    """No durable artifact was ever published under the requested path
    (no checkpoint step, no shard epoch). Distinct from
    :class:`TornWriteError` — nothing was lost, nothing exists yet. Also
    a ``FileNotFoundError`` so pre-existing absence handlers catch it
    unchanged."""


class InjectedCrash(RuntimeError):
    """Raised by a seam to simulate the process dying at that point.

    Deliberately *not* a :class:`SpillError`: tolerance code must never
    catch it (a real crash isn't catchable); only the chaos harness does.
    """


# -- deterministic counter-keyed randomness -----------------------------------

_MASK64 = (1 << 64) - 1


def _mix64(*words: int) -> int:
    """splitmix64-style avalanche over a word sequence (same construction
    as the sample clock: pure function of its inputs, no global state)."""
    h = 0x9E3779B97F4A7C15
    for w in words:
        h = (h + (w & _MASK64)) & _MASK64
        h ^= h >> 30
        h = (h * 0xBF58476D1CE4E5B9) & _MASK64
        h ^= h >> 27
        h = (h * 0x94D049BB133111EB) & _MASK64
        h ^= h >> 31
    return h


def _key_words(key: str) -> Iterator[int]:
    data = key.encode()
    for i in range(0, len(data), 8):
        yield int.from_bytes(data[i:i + 8], "little")


# -- fault specs --------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ChannelDropout:
    """Rail ``domain`` reads NaN for sample times in ``[t0, t1)``."""
    domain: str
    t0: float
    t1: float


@dataclasses.dataclass(frozen=True)
class LeafFault:
    """Corrupt one durable file at the ckpt leaf codec seam.

    ``match`` is a path substring (e.g. ``"host_0002/epoch_000000005"``
    or a file name like ``"arr_00001"``); every read/write whose path
    contains it is affected. ``kind`` is ``"bitflip"`` (one
    deterministically chosen bit) or ``"truncate"`` (cut to a
    deterministically chosen shorter length). ``stage`` selects whether
    the bytes are corrupted as they are persisted (``"write"`` — models
    storage-layer rot; the manifest CRC still covers the *intended*
    bytes, so readers detect it) or as they are handed to the reader
    (``"read"`` — models a flaky read path).
    """
    match: str
    kind: str = "bitflip"
    stage: str = "write"

    def __post_init__(self):
        if self.kind not in ("bitflip", "truncate"):
            raise ValueError(f"kind must be bitflip|truncate; got {self.kind!r}")
        if self.stage not in ("read", "write"):
            raise ValueError(f"stage must be read|write; got {self.stage!r}")


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """A seeded, replayable set of faults to inject across the fleet.

    The default-constructed plan injects nothing, and every seam is
    written so that the empty plan is byte-for-byte identical to passing
    no plan at all (the fault-free acceptance invariant).

    Attributes
    ----------
    seed:            keys every deterministic corruption choice.
    crashes:         ``(host_id, epoch)`` pairs — ``ShardSpiller.spill``
                     raises :class:`InjectedCrash` *before* publishing
                     that epoch (the host dies with the epoch in flight).
    stragglers:      ``(host_id, after_epoch)`` pairs — spills for epochs
                     beyond ``after_epoch`` silently do nothing (the
                     host keeps running but its durable state goes stale).
    spill_failures:  ``(host_id, epoch)`` pairs — the publish raises a
                     transient :class:`SpillError` (succeeds if retried
                     at a later epoch); exercises bounded-retry queues.
    leaf_faults:     :class:`LeafFault` specs applied at the ckpt codec.
    sampler_fail_after: sample count after which ``HostSampler``'s
                     control thread raises (None → never).
    dropouts:        :class:`ChannelDropout` specs applied by the trace
                     sensor banks.
    serve_crashes:   engine step-clock values at which ``Engine.step``
                     raises :class:`InjectedCrash` *before* mutating any
                     host or device state for that step (the serving
                     process dies between steps; a restore from the last
                     durable snapshot must replay bit-exactly).
    snapshot_failures: engine step-clock values whose serve snapshot
                     publish raises a transient :class:`SpillError`
                     (succeeds if re-attempted at a later step). Torn /
                     corrupt snapshot *bytes* are modeled by
                     ``leaf_faults`` matching the snapshot paths — the
                     snapshot writer shares the ckpt leaf codec.
    admission_faults: submit sequence numbers (0-based, per scheduler)
                     whose admission raises a transient typed admission
                     error — exercises counted-never-silent rejection
                     paths without a real overload.
    """
    seed: int = 0
    crashes: tuple[tuple[int, int], ...] = ()
    stragglers: tuple[tuple[int, int], ...] = ()
    spill_failures: tuple[tuple[int, int], ...] = ()
    leaf_faults: tuple[LeafFault, ...] = ()
    sampler_fail_after: int | None = None
    dropouts: tuple[ChannelDropout, ...] = ()
    serve_crashes: tuple[int, ...] = ()
    snapshot_failures: tuple[int, ...] = ()
    admission_faults: tuple[int, ...] = ()

    # -- spiller seam ---------------------------------------------------------
    def crash_at(self, host_id: int, epoch: int) -> bool:
        return (host_id, epoch) in self.crashes

    def straggles(self, host_id: int, epoch: int) -> bool:
        return any(h == host_id and epoch > after
                   for h, after in self.stragglers)

    def spill_fails(self, host_id: int, epoch: int) -> bool:
        return (host_id, epoch) in self.spill_failures

    # -- ckpt leaf codec seam -------------------------------------------------
    @staticmethod
    def _canon(path: str) -> str:
        """Canonical path for matching/keying: forward slashes, and the
        write protocol's random ``.tmp-<nonce>`` dir suffix stripped so a
        write-stage fault picks the same byte every replay."""
        return re.sub(r"\.tmp-[0-9a-f]+", "", path.replace("\\", "/"))

    def _faults_for(self, path: str, stage: str) -> list[LeafFault]:
        norm = self._canon(path)
        return [f for f in self.leaf_faults
                if f.stage == stage and f.match in norm]

    def corrupt_bytes(self, path: str, data: bytes, stage: str) -> bytes:
        """Apply matching leaf faults to ``data`` for file ``path``.

        Returns ``data`` unchanged (same object) when nothing matches,
        so the no-fault path stays allocation-free and byte-identical.
        """
        for i, fault in enumerate(self._faults_for(path, stage)):
            if not data:
                continue
            h = _mix64(self.seed, i, len(data), *_key_words(fault.match),
                       *_key_words(self._canon(path)))
            if fault.kind == "bitflip":
                bit = h % (len(data) * 8)
                buf = bytearray(data)
                buf[bit // 8] ^= 1 << (bit % 8)
                data = bytes(buf)
            else:  # truncate — always strictly shorter
                data = data[:h % len(data)]
        return data

    # -- sampler seam ---------------------------------------------------------
    def sampler_should_fail(self, samples_taken: int) -> bool:
        return (self.sampler_fail_after is not None
                and samples_taken >= self.sampler_fail_after)

    # -- serving seam ---------------------------------------------------------
    def serve_crash_at(self, step: int) -> bool:
        return step in self.serve_crashes

    def snapshot_fails(self, step: int) -> bool:
        return step in self.snapshot_failures

    def admission_fails(self, seq: int) -> bool:
        return seq in self.admission_faults

    # -- sensor seam ----------------------------------------------------------
    def dropout_mask(self, domains: Sequence[str],
                     times: np.ndarray) -> np.ndarray | None:
        """[n, D] bool mask (True = channel dropped at that sample time),
        or None when no dropout touches these domains (no-fault fast path).
        """
        hits = [d for d in self.dropouts if d.domain in domains]
        if not hits:
            return None
        t = np.asarray(times, dtype=np.float64)
        mask = np.zeros((t.shape[0], len(domains)), dtype=bool)
        col = {name: j for j, name in enumerate(domains)}
        for d in hits:
            mask[:, col[d.domain]] |= (t >= d.t0) & (t < d.t1)
        return mask


# -- ambient plan (deep seams) ------------------------------------------------

_ACTIVE: contextvars.ContextVar[FaultPlan | None] = contextvars.ContextVar(
    "repro_fault_plan", default=None)


@contextlib.contextmanager
def install(plan: FaultPlan):
    """Make ``plan`` the ambient fault plan within the ``with`` block."""
    token = _ACTIVE.set(plan)
    try:
        yield plan
    finally:
        _ACTIVE.reset(token)


def active_plan() -> FaultPlan | None:
    return _ACTIVE.get()


def resolve_plan(explicit: FaultPlan | None) -> FaultPlan | None:
    """Seam-side plan lookup: an explicit ``faults=`` argument wins,
    otherwise fall back to the ambient installed plan (if any)."""
    return explicit if explicit is not None else _ACTIVE.get()


# -- fault-site registry ------------------------------------------------------
#
# Every module that consults a FaultPlan marks itself with a module-level
# ``_SITE = declare_site("...")`` per injection seam. The canonical list
# below is the single source of truth: a seam name that drifts (typo,
# rename, copy-paste duplicate) would silently decouple chaos configs
# from the code they target, so membership and uniqueness are enforced
# both at import time (here) and statically (the ``fault-site-hygiene``
# pass in :mod:`repro.analysis`). Adding a seam means extending this
# tuple in the same change that declares it.

FAULT_SITES: tuple[str, ...] = (
    "spiller.publish",        # ShardSpiller.spill crash/straggle/fail seam
    "ckpt.leaf_write",        # _write_leaf byte corruption (storage rot)
    "ckpt.leaf_read",         # _read_leaf byte corruption (flaky reads)
    "ckpt.manifest_write",    # write_manifest_dir manifest corruption
    "ckpt.manifest_read",     # read_manifest_meta manifest corruption
    "sampler.loop",           # HostSampler control-thread death
    "sensors.trace_bank",     # trace-sensor per-rail dropouts
    "serve.step.crash",       # Engine.step process-death injection
    "serve.snapshot.write",   # serve snapshot publish failures
    "serve.admission",        # scheduler submit-time transient faults
)

_DECLARED: dict[str, str] = {}


def declare_site(name: str, *, module: str | None = None) -> str:
    """Register a fault-injection seam; returns ``name`` for assignment.

    ``module`` defaults to the caller's ``__name__``. Unknown names and
    cross-module duplicates raise at import time; re-declaring from the
    same module (reload, re-import) is idempotent.
    """
    if name not in FAULT_SITES:
        raise ValueError(
            f"unregistered fault site {name!r}; add it to "
            f"faults.FAULT_SITES in the same change")
    if module is None:
        import sys
        frame = sys._getframe(1)
        module = frame.f_globals.get("__name__", "<unknown>")
    prev = _DECLARED.get(name)
    if prev is not None and prev != module:
        raise ValueError(
            f"fault site {name!r} already declared by {prev}; "
            f"duplicate declaration from {module}")
    _DECLARED[name] = module
    return name


def declared_sites() -> dict[str, str]:
    """Snapshot of declared seams: site name -> declaring module."""
    return dict(_DECLARED)
