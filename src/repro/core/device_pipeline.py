"""Device-resident fused sampling→attribution pipeline (ALEA hot path).

The host streaming path (``sampler.iter_sample_chunks`` →
``StreamingAggregator``) bounces every chunk across the host↔device
boundary: numpy ``region_at``/sensor emulation on host, kernel attribution
on device, accumulation back on host — and the multi-worker variant adds
an O(W) Python loop per chunk. This module moves the whole per-chunk loop
onto the device:

* :class:`DeviceTimeline` — the sampling substrate resident on device:
  interval ``ends``, the cumulative energy integral, ``powers`` and
  ``region_ids``, batched ``[W, m]`` (ragged workers padded, per-worker
  valid length carried alongside).

* **Counter-based sample times** — chunk ``k``'s times are a pure function
  of ``(seed, k)``: ``t_i = u0 + i·T + u_i`` with ``u0 ~ U(0, T)``,
  ``u_i ~ U(0, jitter)`` drawn from ``fold_in(key, k+1)`` (threefry), and
  the result quantized to an integer-nanosecond clock. Chunk ``k`` is
  reproducible with no host state — the carry never includes a time
  cursor. (Deviation from the host process: jitter is per-sample rather
  than accumulated — statistically equivalent protection against phase
  locking at realistic jitter, and the price of statelessness.)

* **Fused chunk step** — one jitted fixed-shape step per chunk: time
  generation, vectorized region lookup (``searchsorted(side="right")``
  semantics through a precomputed per-worker grid accelerator, ``vmap``
  over the worker axis), trace-sensor emulation as pure functions of the
  energy integral (RAPL differencing with a one-scalar prev-sample carry,
  INA231 window semantics), and the ``sample_attr`` reduction folding
  into a donated ``(counts, Σpow, Σpow²)`` carry
  (:func:`repro.kernels.sample_attr.ops.make_carry_update`: Pallas one-hot
  matmuls on TPU, XLA scatter-add elsewhere). Chunk padding/masking
  happens *inside* the step (lanes past the profiled horizon scatter out
  of bounds and drop) — no host-side ``np.concatenate`` padding.

* :func:`run_region_pipeline` — single-worker runs execute the whole scan
  in ONE jitted ``fori_loop``: no per-chunk dispatch, no per-chunk host
  transfer; only the final sufficient statistics come back.

* :func:`run_combo_pipeline` — multi-worker (§4.4) combination
  attribution with a device-resident, lexicographically sorted combination
  key table. Chunks whose rows all hit the table fold entirely on device
  (binary search → interner ids → scatter into the donated carry). A
  chunk containing an unseen combination raises a scalar miss flag; only
  then does the host pull that one chunk, intern the new rows
  (:class:`~repro.core.streaming.CombinationInterner` — the id space stays
  host-authoritative because it is dynamic and ordered), rebuild the
  sorted table, and fold the chunk through a fixed-shape device update.
  Steady state (stable combination set) transfers no sample arrays at all.

Everything runs under ``enable_x64`` (cf. :mod:`repro.core.exchange`):
float64 times make device region lookups bit-identical to the numpy
reference, and int64/float64 accumulators keep the statistics exact on
CPU. The numpy reference (:func:`reference_region_pipeline` /
:func:`reference_combo_pipeline`) consumes the same
:func:`chunk_sample_times` and mirrors the sensor math in float64 — the
oracle the equivalence tests pin the fused path against.

**Power-rail domain axis.** Multi-domain timelines (``Timeline.domains``
— package/HBM/ICI rails) thread end to end: the substrate carries
per-rail energy integrals, the sensor bank is vmapped over the domain
axis (one interval lookup serves every rail — they share the clock),
and the carry accumulates a ``[rows, C]`` channel matrix (the D rails
plus a dedicated total channel; Σpow² of the total is not derivable
from per-rail Σpow², see :func:`num_channels`). Scalar timelines keep
1-D statistics through the *verbatim* pre-rail computation graph —
the D=1 bit-exactness contract, pinned by golden-value tests
(``tests/test_domains.py``).
"""

from __future__ import annotations

import dataclasses
import functools
import math

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import enable_x64

from repro.core.sensors import (DEFAULT_IDLE_POWER, SensorSpec,
                                _TraceSensorBase, idle_channel)
from repro.core.sketch import SketchConfigError, other_row
from repro.core.streaming import (CombinationInterner,
                                  StreamingCombinationAggregator,
                                  channels_for)
from repro.core.timeline import Timeline
from repro.kernels.sample_attr.ops import make_carry_update

__all__ = [
    "DeviceTimeline", "PipelineResult", "chunk_sample_times",
    "num_chunks", "num_channels", "run_region_pipeline",
    "run_combo_pipeline", "reference_region_pipeline",
    "reference_combo_pipeline",
]

DEFAULT_CHUNK = 65536
_TABLE_MIN = 64


# ---------------------------------------------------------------------------
# Device timeline substrate.
# ---------------------------------------------------------------------------


_GRID_OVERSAMPLE = 4        # grid cells per interval (amortizes window K)
_GRID_MAX = 1 << 20
# Heavy-tailed durations (one long interval + many micro-intervals) can
# concentrate intervals in one grid cell; past this window the unrolled
# compare loop loses to a plain O(log m) binary search, so grid_k = 0
# (sentinel) routes lookups to jnp.searchsorted instead.
_GRID_K_MAX = 32


@dataclasses.dataclass(frozen=True)
class DeviceTimeline:
    """Device-resident piecewise-constant traces, batched over workers.

    Ragged workers are padded to a common interval count ``M``: ``ends``
    and ``bounds`` pad with ``+inf`` (lookups never land there for
    in-horizon times), value arrays pad with zeros, and ``m_true`` carries
    each worker's valid interval count so lookups clip per worker exactly
    like the host path clips to its own length.

    The power substrate is per-rail: for multi-domain timelines
    ``powers``/``eint`` carry a domain axis ``[W, D, ·]`` (package/HBM/
    ICI rails). Scalar (D=1) timelines keep the flat ``[W, ·]`` layout —
    deliberately: the jitted pipeline branches on the array rank at
    trace time and runs the *identical* pre-rail computation graph for
    scalar substrates, which is what makes D=1 outputs bit-exact (XLA's
    whole-graph fusion reassociates float reductions at the ulp level
    if the same math merely flows through differently-shaped arrays).
    Interval *structure* (ends/bounds/region ids and the grid
    accelerator) never has a domain axis: all rails of a worker share
    one clock, so one interval lookup serves every channel.

    ``grid``/``cell``/``grid_k`` form the lookup accelerator: per worker,
    ``grid[g] = #(ends ≤ g·cell)`` on a uniform time grid, with ``grid_k``
    the maximum interval count of any cell. An interval lookup is then one
    grid gather plus ``grid_k`` *consecutive* compares — exactly
    ``searchsorted(side="right")``, at O(1) instead of O(log m) random
    accesses (the device hot path's dominant cost). Because
    ``bounds = [0, ends...]``, the energy-interpolation index derives from
    the same count: ``#(bounds ≤ t) = 1 + #(ends ≤ t)`` — one structure
    accelerates both lookups.
    """

    ends: jax.Array        # f64 [W, M]   interval end times, +inf padded
    bounds: jax.Array      # f64 [W, M+1] [0, ends...], +inf padded
    eint: jax.Array        # f64 [W, M+1] (D=1) | [W, D, M+1] rail energy
    powers: jax.Array      # f64 [W, M] (D=1) | [W, D, M] rail powers, 0 pad
    region_ids: jax.Array  # i32 [W, M]   region per interval, 0 padded
    m_true: jax.Array      # i32 [W]      valid interval count per worker
    grid: jax.Array        # i32 [W, G+2] #(ends ≤ g·cell) per grid point
    cell: jax.Array        # f64 [W]      grid cell width (span / G)
    grid_k: int            # static: max intervals per grid cell
    t_end: float           # profiled horizon: min worker t_exec
    num_regions: int
    names: tuple[str, ...]
    domains: tuple[str, ...] = ("total",)   # rail axis names

    @property
    def num_workers(self) -> int:
        return self.ends.shape[0]

    @property
    def num_domains(self) -> int:
        return len(self.domains)

    @classmethod
    def from_timelines(cls, timelines: list[Timeline]) -> "DeviceTimeline":
        if not timelines:
            raise ValueError("need at least one timeline")
        names = timelines[0].names
        domains = timelines[0].domain_names
        for tl in timelines:
            if tl.names != names:
                raise ValueError("workers must share a region name space")
            if tl.domain_names != domains:
                raise ValueError(
                    f"workers must share a power-rail domain axis; got "
                    f"{tl.domain_names} vs {domains}")
            if len(tl.region_ids) == 0:
                raise ValueError("empty timeline")
            if tl.t_exec <= 0.0:
                raise ValueError("zero-length timeline")
        W = len(timelines)
        D = len(domains)
        M = max(len(tl.region_ids) for tl in timelines)
        G = int(min(_GRID_OVERSAMPLE * M, _GRID_MAX))
        ends = np.full((W, M), np.inf)
        bounds = np.full((W, M + 1), np.inf)
        # Scalar timelines keep the flat pre-rail layout (see class
        # docstring: the bit-exactness contract hangs on it).
        eint = np.zeros((W, M + 1) if D == 1 else (W, D, M + 1))
        powers = np.zeros((W, M) if D == 1 else (W, D, M))
        rids = np.zeros((W, M), np.int32)
        m_true = np.array([len(tl.region_ids) for tl in timelines], np.int32)
        grid = np.zeros((W, G + 2), np.int32)
        cell = np.zeros(W)
        grid_k = 1
        for w, tl in enumerate(timelines):
            m = int(m_true[w])
            ends[w, :m] = tl.ends
            bounds[w, 0] = 0.0
            bounds[w, 1:m + 1] = tl.ends
            if D == 1:
                eint[w, 1:m + 1] = tl.energy_integral()
                powers[w, :m] = tl.powers
            else:
                eint[w, :, 1:m + 1] = tl.rail_energy_integral().T
                powers[w, :, :m] = tl.rails().T
            rids[w, :m] = tl.region_ids
            cell[w] = tl.t_exec / G
            # Same f64 products the device guard computes (g · cell), so
            # grid[g] is exact for the comparisons the lookup performs.
            pts = np.arange(G + 2, dtype=np.float64) * cell[w]
            grid[w] = np.searchsorted(tl.ends, pts, side="right")
            grid_k = max(grid_k, int(np.diff(grid[w]).max()))
        if grid_k > _GRID_K_MAX:
            grid_k = 0      # searchsorted fallback (see _count_le)
        with enable_x64():
            return cls(ends=jnp.asarray(ends), bounds=jnp.asarray(bounds),
                       eint=jnp.asarray(eint), powers=jnp.asarray(powers),
                       region_ids=jnp.asarray(rids),
                       m_true=jnp.asarray(m_true),
                       grid=jnp.asarray(grid), cell=jnp.asarray(cell),
                       grid_k=grid_k,
                       t_end=float(min(tl.t_exec for tl in timelines)),
                       num_regions=len(names), names=names,
                       domains=domains)

    def arrays(self):
        return (self.ends, self.bounds, self.eint, self.powers,
                self.region_ids, self.m_true, self.grid, self.cell)


@dataclasses.dataclass(frozen=True)
class PipelineResult:
    """Final sufficient statistics of one fused run (host numpy).

    ``psum``/``psumsq`` are the scalar (rail-summed) statistics — for
    D=1 runs the single rail itself, bit-identical to the pre-rail
    pipeline. ``rail_psum``/``rail_psumsq`` carry the per-domain
    decomposition ``[R, D]`` aligned with ``domains``.
    """

    counts: np.ndarray     # int64 [R]
    psum: np.ndarray       # float64 [R]
    psumsq: np.ndarray     # float64 [R]
    n: int                 # total valid samples
    t_exec: float          # measured horizon incl. suspension overhead
    rail_psum: np.ndarray | None = None     # float64 [R, D]
    rail_psumsq: np.ndarray | None = None   # float64 [R, D]
    domains: tuple[str, ...] = ("total",)


def num_channels(num_domains: int) -> int:
    """Statistic channels for a D-rail run — delegates to the one
    channel-layout rule (:func:`repro.core.streaming.channels_for`):
    the rails plus, when D > 1, a dedicated total-power channel (Σpow²
    of the total is not derivable from per-rail Σpow²). At D = 1 the
    single rail is the total, bit-identical to the pre-rail carry."""
    return channels_for(num_domains)


def _result_from_channels(counts, chan_psum, chan_psumsq, n, t_exec,
                          domains) -> PipelineResult:
    """Split a channel carry into (rail, scalar-total) statistics.

    Accepts the scalar-path 1-D carry (D = 1) or the [R, C] channel
    carry; the last channel is the total (at D = 1 it is also the only
    rail), so ``psum``/``psumsq`` are exactly the scalar accumulators."""
    chan_psum = np.asarray(chan_psum, np.float64)
    chan_psumsq = np.asarray(chan_psumsq, np.float64)
    if chan_psum.ndim == 1:
        chan_psum = chan_psum[:, None]
        chan_psumsq = chan_psumsq[:, None]
    d = len(domains)
    return PipelineResult(counts=np.asarray(counts, np.int64),
                          psum=chan_psum[:, -1], psumsq=chan_psumsq[:, -1],
                          n=n, t_exec=t_exec,
                          rail_psum=chan_psum[:, :d],
                          rail_psumsq=chan_psumsq[:, :d],
                          domains=tuple(domains))


# ---------------------------------------------------------------------------
# Counter-based sample times (the chunk-step contract's time source).
# ---------------------------------------------------------------------------


def _raw_chunk_times(root, k, c: int, period, jitter):
    """Chunk ``k``'s sample times: pure function of (key, k).

    ``t_i = u0 + i·T + u_i`` on an integer-nanosecond clock. The ns
    quantization is part of the contract: it models a real timer's
    resolution and pins the float64 value exactly, so the numpy reference
    recovers identical region lookups.
    """
    dt = period.dtype
    u0 = jax.random.uniform(jax.random.fold_in(root, 0), (), dt, 0.0, period)
    u = jax.random.uniform(jax.random.fold_in(root, k + 1), (c,), dt,
                           0.0, jitter)
    # k arrives as int32 (fori_loop index); widen BEFORE k·c so sample
    # indices past 2^31 (long runs at small chunk sizes) don't wrap.
    i = jnp.asarray(k, jnp.int64) * c + jnp.arange(c)
    t = u0 + i.astype(dt) * period + u
    return jnp.floor(t * 1e9 + 0.5) * 1e-9


@functools.partial(jax.jit, static_argnames=("chunk_size",))
def chunk_sample_times(root, k, period, jitter, *, chunk_size: int):
    """Public (jitted) form of the time contract — the reference oracle
    consumes exactly these times, so time generation is shared, not
    re-derived, between the fused path and its numpy mirror."""
    return _raw_chunk_times(root, k, chunk_size, period, jitter)


def num_chunks(t_end: float, period: float, chunk_size: int) -> int:
    """Chunks needed to cover the horizon: ``t_i ≥ i·T`` guarantees every
    sample of chunk ``k ≥ ceil(t_end/(c·T))`` lands past ``t_end``."""
    return max(int(math.ceil(t_end / (chunk_size * period))), 1)


# ---------------------------------------------------------------------------
# Device lookups + trace-sensor emulation (pure functions of the integral).
# ---------------------------------------------------------------------------


def _count_le(ends_w, grid_w, cell_w, t, k_max: int):
    """``#(ends ≤ t)`` per sample — ``searchsorted(side="right")``, but
    through the precomputed grid: locate the cell (with exact-comparison
    guards against division rounding), start from its prefix count, and
    add at most ``k_max`` consecutive compares. All comparisons are exact,
    so this is bit-equal to the numpy reference's searchsorted.
    ``k_max = 0`` means the timeline's durations were too heavy-tailed
    for a bounded window (see ``_GRID_K_MAX``) — use the real binary
    search (same result, O(log m))."""
    if k_max == 0:
        return jnp.searchsorted(ends_w, t, side="right").astype(jnp.int32)
    G = grid_w.shape[0] - 2
    g = jnp.floor(t / cell_w).astype(jnp.int32)
    g = g - (g * cell_w > t)
    g = g + ((g + 1) * cell_w <= t)
    g = jnp.clip(g, 0, G)
    lo = grid_w[g]
    M = ends_w.shape[0]
    cnt = lo
    for j in range(k_max):
        pos = lo + j
        cnt = cnt + ((pos < M)
                     & (ends_w[jnp.minimum(pos, M - 1)] <= t))
    return cnt


def _energy_at_cnt(bounds_w, eint_w, powers_w, m_w, x, cnt):
    """Exact E(x) for piecewise-constant power (device twin of
    ``sensors._TraceSensorBase._energy_at``) given ``cnt = #(ends ≤ x)``;
    ``bounds = [0, ends...]`` makes the bounds index ``clip(cnt)``."""
    idx = jnp.clip(cnt, 0, m_w - 1)
    return eint_w[idx] + (x - bounds_w[idx]) * powers_w[idx]


def _sensor_powers(spec: SensorSpec, arrs, t, cnt_t, valid, prev,
                   k_max: int):
    """Per-worker sensor readings + updated RAPL prev-sample carry.

    Scalar substrates (``powers`` [W, M]) return [W, c] — the verbatim
    pre-rail computation graph, which is what keeps D=1 outputs
    bit-identical. Multi-rail substrates (``powers`` [W, D, M]) return
    [W, D, c]: the sensor bank is vmapped over the domain axis — every
    rail applies the same instrument semantics to its own energy
    integral, sharing the worker's interval lookup (``cnt_t`` [W, c]:
    rails share the clock and the interval structure, so one count
    serves all channels). ``prev`` is a single f64 scalar (< 0 means
    "no sample taken yet"): all workers and rails share the sample
    clock, so the RAPL differencing chain has one prev time regardless
    of W or D.
    """
    ends, bounds, eint, powers, rids, m_true, grid, cell = arrs
    scalar = powers.ndim == 2
    count = jax.vmap(_count_le, in_axes=(0, 0, 0, None, None))
    if scalar:
        e_at = jax.vmap(_energy_at_cnt, in_axes=(0, 0, 0, 0, None, 0))
    else:
        # Inner vmap batches the domain axis of eint/powers (bounds,
        # valid length and the sample count are per worker, shared by
        # its rails); outer vmap batches workers.
        e_at_d = jax.vmap(_energy_at_cnt,
                          in_axes=(None, 0, 0, None, None, None))
        e_at = jax.vmap(e_at_d, in_axes=(0, 0, 0, 0, None, 0))
    if spec.kind == "instant":
        if scalar:
            def one(p_w, m_w, cnt_w):
                return p_w[jnp.clip(cnt_w, 0, m_w - 1)]
        else:
            def one(p_w, m_w, cnt_w):
                return p_w[:, jnp.clip(cnt_w, 0, m_w - 1)]
        return jax.vmap(one)(powers, m_true, cnt_t), prev
    if spec.kind == "rapl":
        up = spec.update_period
        tq = jnp.floor(t / up + 1e-6) * up
        # The prev chain is tq shifted by one sample, so E(prev) is e_q
        # shifted by one lane — one energy pass instead of two; only the
        # chain head (carry prev, or tq[0] - up on the very first sample)
        # needs its own tiny lookup.
        prev0 = jnp.where(prev < 0.0, jnp.maximum(tq[0] - up, 0.0), prev)
        e_q = e_at(bounds, eint, powers, m_true, tq,
                   count(ends, grid, cell, tq, k_max))
        e_p0 = e_at(bounds, eint, powers, m_true, prev0[None],
                    count(ends, grid, cell, prev0[None], k_max))
        e_prev = jnp.concatenate([e_p0, e_q[..., :-1]], axis=-1)
        prev_vec = jnp.concatenate([prev0[None], tq[:-1]])
        dt = jnp.maximum(tq - prev_vec, up)
        new_prev = jnp.max(jnp.where(valid, tq, -jnp.inf))
        new_prev = jnp.where(jnp.any(valid), new_prev, prev)
        return (e_q - e_prev) / dt, new_prev
    if spec.kind == "ina231":
        lo = jnp.maximum(t - spec.window, 0.0)
        e_t = e_at(bounds, eint, powers, m_true, t, cnt_t)
        e_lo = e_at(bounds, eint, powers, m_true, lo,
                    count(ends, grid, cell, lo, k_max))
        return (e_t - e_lo) / jnp.maximum(t - lo, 1e-12), prev
    raise ValueError(f"unknown trace sensor kind: {spec.kind!r}")


def _chunk_samples(arrs, spec: SensorSpec, root, k, c: int, period, jitter,
                   t_end, prev, k_max: int):
    """One fused chunk: times → region ids [W, c] → channel powers.

    Scalar substrates produce the summed power [c] (the pre-rail graph);
    multi-rail substrates produce the [C, c] channel matrix — the
    worker-summed rails plus the total (see :func:`num_channels`).
    Masking happens here, in the kernel's input domain: lanes past the
    horizon are flagged invalid and their times clipped to ``t_end`` so
    the sensor math stays finite (they contribute nothing downstream).
    """
    ends, bounds, eint, powers, rids, m_true, grid, cell = arrs
    t_raw = _raw_chunk_times(root, k, c, period, jitter)
    valid = t_raw < t_end
    t = jnp.minimum(t_raw, t_end)
    cnt_t = jax.vmap(_count_le, in_axes=(0, 0, 0, None, None))(
        ends, grid, cell, t, k_max)

    def lookup(r_w, m_w, cnt_w):
        return r_w[jnp.clip(cnt_w, 0, m_w - 1)]
    rid_mat = jax.vmap(lookup)(rids, m_true, cnt_t)
    pows, prev = _sensor_powers(spec, arrs, t, cnt_t, valid, prev, k_max)
    chan = pows.sum(axis=0)                  # [c] scalar | [D, c] rails
    if chan.ndim == 2:
        chan = jnp.concatenate([chan, chan.sum(axis=0, keepdims=True)])
    return rid_mat, chan, valid, prev


def _check_sampling_args(spec: SensorSpec, period: float, jitter: float):
    if period < spec.effective_min_period():
        raise ValueError(f"sampling period {period} below sensor minimum "
                         f"{spec.effective_min_period()}")
    if jitter > period:
        raise ValueError(
            f"device pipeline requires jitter <= period for a monotone "
            f"sample clock (RAPL differencing); got jitter={jitter}, "
            f"period={period}")


def _check_spec_domains(spec: SensorSpec, dtl: "DeviceTimeline"):
    """The sensor bank must have one channel per timeline rail."""
    if spec.num_domains != dtl.num_domains:
        raise ValueError(
            f"sensor bank has {spec.num_domains} channel(s) "
            f"{spec.domains} but the timeline carries "
            f"{dtl.num_domains} power rail(s) {dtl.domains}")


# ---------------------------------------------------------------------------
# Single-worker region pipeline: whole run in one jitted scan.
# ---------------------------------------------------------------------------


def _blend_idle(chan, frac, idle_power, idle_ch: int):
    """§4.7 suspension overhead: blend toward idle proportionally to the
    per-period suspension fraction (frac = 0 → identity). On the scalar
    graph this is the pre-rail formula verbatim; on the channel matrix
    the idle power lands on the package rail (``idle_ch``, located by
    name via :func:`repro.core.sensors.idle_channel` — a suspended chip
    burns near-idle power in the package, not on HBM/ICI rails) and on
    the total channel so the scalar statistics see the same blend as
    before."""
    if chan.ndim == 1:
        return (1.0 - frac) * chan + frac * idle_power
    chan = (1.0 - frac) * chan
    chan = chan.at[idle_ch].add(frac * idle_power)
    return chan.at[-1].add(frac * idle_power)


@functools.lru_cache(maxsize=None)
def _region_run_fn(chunk_size: int, spec: SensorSpec, num_regions: int,
                   use_pallas: bool, grid_k: int):
    update = make_carry_update(num_regions, use_pallas=use_pallas)
    n_chan = num_channels(spec.num_domains)
    idle_ch = idle_channel(spec.domains)

    def run(ends, bounds, eint, powers, rids, m_true, grid, cell, root,
            period, jitter, t_end, frac, idle_power, n_chunks):
        arrs = (ends, bounds, eint, powers, rids, m_true, grid, cell)

        def body(k, carry):
            counts, psum, psumsq, n, prev = carry
            rid_mat, chan, valid, prev = _chunk_samples(
                arrs, spec, root, k, chunk_size, period, jitter, t_end,
                prev, grid_k)
            chan = _blend_idle(chan, frac, idle_power, idle_ch)
            counts, psum, psumsq = update(counts, psum, psumsq,
                                          rid_mat[0], chan, valid)
            return (counts, psum, psumsq, n + jnp.sum(valid), prev)

        stat_shape = (num_regions,) if n_chan == 1 \
            else (num_regions, n_chan)
        carry0 = (jnp.zeros(num_regions, jnp.int64),
                  jnp.zeros(stat_shape, jnp.float64),
                  jnp.zeros(stat_shape, jnp.float64),
                  jnp.zeros((), jnp.int64),
                  -jnp.ones((), jnp.float64))
        counts, psum, psumsq, n, _ = lax.fori_loop(0, n_chunks, body, carry0)
        return counts, psum, psumsq, n

    return jax.jit(run)


def run_region_pipeline(dtl: DeviceTimeline, spec: SensorSpec, *,
                        period: float, jitter: float = 200e-6, seed: int = 0,
                        chunk_size: int = DEFAULT_CHUNK,
                        overhead_per_sample: float = 0.0,
                        idle_power: float = DEFAULT_IDLE_POWER,
                        use_pallas: bool | None = None) -> PipelineResult:
    """Fused single-worker profiling run, entirely on device.

    One jitted call scans every chunk through the fused step and folds
    into the (counts, Σpow, Σpow²) carry; only the final [R] statistics
    are transferred back. Statistically equivalent to
    ``sampler.iter_sample_chunks`` + ``StreamingAggregator`` (different
    but equally valid jitter process for the same seed);
    :func:`reference_region_pipeline` is the exact numpy mirror.
    """
    _check_sampling_args(spec, period, jitter)
    _check_spec_domains(spec, dtl)
    if dtl.num_workers != 1:
        raise ValueError(f"region pipeline is single-worker; got "
                         f"W={dtl.num_workers} (use run_combo_pipeline)")
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    frac = min(overhead_per_sample / period, 1.0) \
        if overhead_per_sample > 0.0 else 0.0
    with enable_x64():
        k_chunks = num_chunks(dtl.t_end, period, chunk_size)
        fn = _region_run_fn(chunk_size, spec, dtl.num_regions,
                            bool(use_pallas), dtl.grid_k)
        counts, psum, psumsq, n = fn(
            *dtl.arrays(), jax.random.PRNGKey(seed),
            jnp.float64(period), jnp.float64(jitter),
            jnp.float64(dtl.t_end), jnp.float64(frac),
            jnp.float64(idle_power), jnp.int32(k_chunks))
        n = int(n)
    if n == 0:
        raise ValueError("run too short for sampling period")
    return _result_from_channels(counts, psum, psumsq, n,
                                 dtl.t_end + n * overhead_per_sample,
                                 dtl.domains)


# ---------------------------------------------------------------------------
# Multi-worker combination pipeline: device table + host interner fallback.
# ---------------------------------------------------------------------------


def _lex_less(a, b):
    """Row-wise lexicographic a < b for [c, n_words] key matrices.

    Cascaded column compare (2 compares + 2 logic ops per word) — the
    word count is small (≤ ⌈W·bits/62⌉), so this beats a first-mismatch
    gather."""
    less = jnp.zeros(a.shape[0], bool)
    eq = jnp.ones(a.shape[0], bool)
    for col in range(a.shape[1]):
        ac, bc = a[:, col], b[:, col]
        less = less | (eq & (ac < bc))
        eq = eq & (ac == bc)
    return less


def _lex_search(table, n_rows, rows):
    """Vectorized lower-bound binary search of ``rows`` [c, W] in the
    lex-sorted ``table`` [cap, W] (first ``n_rows`` rows valid)."""
    cap = table.shape[0]
    c = rows.shape[0]
    lo = jnp.zeros(c, jnp.int32)
    hi = jnp.full(c, n_rows, jnp.int32)
    for _ in range(int(cap).bit_length()):
        active = lo < hi
        mid = (lo + hi) // 2
        less = active & _lex_less(table[mid], rows)
        lo = jnp.where(less, mid + 1, lo)
        hi = jnp.where(active & ~less, mid, hi)
    pos = jnp.clip(lo, 0, cap - 1)
    found = (lo < n_rows) & (table[pos] == rows).all(axis=1)
    return pos, found


def _pack_spec(num_regions: int, width: int) -> tuple[int, int, int]:
    """(bits per region id, ids per word, words per row) for packing
    worker-region rows into int64 key words: always fewer columns than
    the raw [W] row, one scalar word whenever ``W·bits ≤ 62`` (≤ 62 so a
    real key never collides with the int64-max table padding)."""
    bits = max((num_regions - 1).bit_length(), 1)
    per = max(62 // bits, 1)
    n_words = -(-width // per)
    return bits, per, n_words


def _pack_rows_np(mat: np.ndarray, pack: tuple[int, int, int]) -> np.ndarray:
    bits, per, n_words = pack
    w = mat.shape[1]
    out = np.zeros((len(mat), n_words), np.int64)
    for j in range(n_words):
        cols = mat[:, j * per:min((j + 1) * per, w)].astype(np.int64)
        shifts = np.arange(cols.shape[1], dtype=np.int64) * bits
        out[:, j] = (cols << shifts[None, :]).sum(axis=1)
    return out


def _pack_rows(rid_mat, pack: tuple[int, int, int]):
    """[W, c] device region-id matrix → [c, n_words] int64 key words."""
    bits, per, n_words = pack
    w = rid_mat.shape[0]
    words = []
    for j in range(n_words):
        cols = rid_mat[j * per:min((j + 1) * per, w)].astype(jnp.int64)
        shifts = jnp.arange(cols.shape[0], dtype=jnp.int64) * bits
        words.append((cols << shifts[:, None]).sum(axis=0))
    return jnp.stack(words, axis=1)


@functools.lru_cache(maxsize=None)
def _combo_step_fn(chunk_size: int, spec: SensorSpec, grid_k: int,
                   pack: tuple[int, int, int]):
    def step(carry, table, table_ids, n_rows, ends, bounds, eint, powers,
             rids, m_true, grid, cell, root, k, period, jitter, t_end):
        counts, psum, psumsq, n, prev = carry
        prev_in = prev      # pre-chunk sensor state, for miss replay
        arrs = (ends, bounds, eint, powers, rids, m_true, grid, cell)
        rid_mat, chan, valid, prev = _chunk_samples(
            arrs, spec, root, k, chunk_size, period, jitter, t_end, prev,
            grid_k)
        cap = counts.shape[0]
        keys = _pack_rows(rid_mat, pack)
        if pack[2] == 1:
            # One int64 key per sample → scalar binary search.
            flat = keys[:, 0]
            pos = jnp.searchsorted(table[:, 0], flat, side="left")
            pos = jnp.minimum(pos, table.shape[0] - 1).astype(jnp.int32)
            found = (pos < n_rows) & (table[pos, 0] == flat)
        else:
            pos, found = _lex_search(table, n_rows, keys)
        # Any in-horizon row missing from the table aborts the on-device
        # fold for the WHOLE chunk — the host interns it and re-folds, so
        # no sample is ever half-counted.
        any_miss = jnp.any(valid & ~found)
        fold = valid & found & ~any_miss
        idx = jnp.where(fold, table_ids[pos], cap)
        counts = counts.at[idx].add(jnp.ones((), counts.dtype), mode="drop")
        if psum.ndim == 1:      # scalar substrate: the pre-rail graph
            psum = psum.at[idx].add(chan, mode="drop")
            psumsq = psumsq.at[idx].add(chan * chan, mode="drop")
        else:
            psum = psum.at[idx].add(chan.T, mode="drop")
            psumsq = psumsq.at[idx].add((chan * chan).T, mode="drop")
        carry = (counts, psum, psumsq, n + jnp.sum(fold), prev)
        return carry, any_miss, prev_in

    return jax.jit(step, donate_argnums=(0,))


@functools.lru_cache(maxsize=None)
def _chunk_recompute_fn(chunk_size: int, spec: SensorSpec, grid_k: int):
    """Miss-path sample recomputation: identical to the step's internal
    chunk (purely counter-based, so replaying chunk k is exact) — keeps
    sample arrays out of the steady-state step's outputs entirely."""
    def recompute(ends, bounds, eint, powers, rids, m_true, grid, cell,
                  root, k, period, jitter, t_end, prev):
        arrs = (ends, bounds, eint, powers, rids, m_true, grid, cell)
        rid_mat, chan, valid, _ = _chunk_samples(
            arrs, spec, root, k, chunk_size, period, jitter, t_end, prev,
            grid_k)
        return rid_mat, chan, valid
    return jax.jit(recompute)


def _combo_fold(carry, idx, pows, valid):
    """Fixed-shape host-assisted fold for miss chunks: encoded combination
    ids (padded with the out-of-bounds cap index) scatter into the donated
    carry exactly like the on-device path would have. ``pows`` is [c]
    (scalar substrate) or the [C, c] channel matrix."""
    counts, psum, psumsq, n, prev = carry
    counts = counts.at[idx].add(jnp.ones((), counts.dtype), mode="drop")
    if psum.ndim == 1:
        psum = psum.at[idx].add(pows, mode="drop")
        psumsq = psumsq.at[idx].add(pows * pows, mode="drop")
    else:
        psum = psum.at[idx].add(pows.T, mode="drop")
        psumsq = psumsq.at[idx].add((pows * pows).T, mode="drop")
    return (counts, psum, psumsq, n + jnp.sum(valid), prev)


_combo_fold_jit = jax.jit(_combo_fold, donate_argnums=(0,))


def _build_table(interner: CombinationInterner, cap: int, width: int,
                 pack: tuple[int, int, int]):
    """Lex-sorted packed-key table [cap, n_words] int64 (int64-max
    padded) + sorted-position → interner id map."""
    mat = interner.combo_matrix()
    k = len(mat)
    ids = np.zeros(cap, np.int64)
    bits, per, n_words = pack
    table = np.full((cap, n_words), np.iinfo(np.int64).max, np.int64)
    if k:
        keys = _pack_rows_np(mat, pack)
        order = np.lexsort(keys.T[::-1])
        table[:k] = keys[order]
        ids[:k] = order
    with enable_x64():
        return jnp.asarray(table), jnp.asarray(ids), jnp.int32(k)


def run_combo_pipeline(dtl: DeviceTimeline, spec: SensorSpec, *,
                       period: float, jitter: float = 200e-6, seed: int = 0,
                       chunk_size: int = DEFAULT_CHUNK,
                       max_combinations: int | None = None,
                       stats: dict | None = None
                       ) -> tuple[StreamingCombinationAggregator, int]:
    """Fused multi-worker (§4.4) combination attribution.

    Steady state is fully device-resident: the jitted chunk step looks
    every sample's worker-region row up in the device-side lex-sorted
    combination table and scatters into the donated carry; only a scalar
    miss flag is read back per chunk. Chunks that surface a new
    combination fall back to the host interner (the combination id space
    is dynamic and first-appearance-ordered — host-authoritative), after
    which the rebuilt table is re-uploaded; with a stable combination set
    that happens O(distinct combos / chunk) times total.

    ``max_combinations`` bounds the attribution state (heavy-hitters
    tier, see :mod:`repro.core.sketch`): the miss path *admits* new
    combinations while fewer than ``max_combinations`` identified rows
    exist, and *folds* later arrivals into their region's ``other``
    sentinel row — the device table, carry and final aggregator stay
    O(max_combinations + regions) instead of growing with the distinct
    count. Per-region sample counts stay exact; tail identity coarsens.
    Folded (non-admitted) combinations never enter the device table, so
    chunks carrying tail traffic keep taking the host fold path —
    bounded memory trades away the tail's zero-transfer steady state,
    never correctness. With ``max_combinations >= distinct`` nothing
    folds and the result is bit-exact to the unbounded run.

    Returns ``(aggregator, n_samples)`` — the aggregator is a regular
    :class:`StreamingCombinationAggregator`, so merge/exchange/estimates
    compose exactly as with the host path. ``stats``, if given, records
    ``chunks`` and ``miss_chunks`` (host-fallback count — the
    steady-state zero-transfer claim is ``miss_chunks ≪ chunks``) plus,
    in bounded mode, ``tail_folds``.
    """
    _check_sampling_args(spec, period, jitter)
    _check_spec_domains(spec, dtl)
    W = dtl.num_workers
    if max_combinations is not None:
        if max_combinations < 1:
            raise ValueError(f"max_combinations must be >= 1; "
                             f"got {max_combinations}")
        if W < 2:
            raise SketchConfigError(
                "bounded combination attribution needs >= 2 workers (the "
                "region axis plus at least one folded axis); at W=1 use "
                "the region pipeline")
    miss_chunks = 0
    tail_folds = 0
    other_by_region: dict[int, int] = {}
    n_chan = num_channels(dtl.num_domains)
    pack = _pack_spec(dtl.num_regions, W)
    interner = CombinationInterner()
    with enable_x64():
        step = _combo_step_fn(chunk_size, spec, dtl.grid_k, pack)
        cap = _TABLE_MIN
        stat_shape = (cap,) if n_chan == 1 else (cap, n_chan)
        table, table_ids, n_rows = _build_table(interner, cap, W, pack)
        carry = (jnp.zeros(cap, jnp.int64),
                 jnp.zeros(stat_shape, jnp.float64),
                 jnp.zeros(stat_shape, jnp.float64),
                 jnp.zeros((), jnp.int64),
                 -jnp.ones((), jnp.float64))
        root = jax.random.PRNGKey(seed)
        period_j = jnp.float64(period)
        jitter_j = jnp.float64(jitter)
        t_end_j = jnp.float64(dtl.t_end)
        k_chunks = num_chunks(dtl.t_end, period, chunk_size)
        for k in range(k_chunks):
            carry, miss, prev_in = step(
                carry, table, table_ids, n_rows, *dtl.arrays(), root,
                jnp.int32(k), period_j, jitter_j, t_end_j)
            if not bool(miss):
                continue
            # Miss path: replay this one chunk (counter-based times make
            # the replay exact), intern the new rows, rebuild, re-fold.
            miss_chunks += 1
            rid_dev, total_dev, valid_dev = _chunk_recompute_fn(
                chunk_size, spec, dtl.grid_k)(
                    *dtl.arrays(), root, jnp.int32(k), period_j, jitter_j,
                    t_end_j, prev_in)
            valid = np.asarray(valid_dev)
            rows = np.asarray(rid_dev).T[valid]
            if max_combinations is None:
                cids = interner.encode(rows.astype(np.int64))
            else:
                # Admit-or-fold (bounded tier): intern new rows while
                # fewer than max_combinations identified rows exist;
                # later arrivals fold into their region's `other`
                # sentinel row, so the table/carry stop growing. Folded
                # keys stay out of the device table — their traffic
                # keeps re-missing — but each miss lands here and folds
                # exactly once per sample, so nothing is lost.
                uniq, inverse = np.unique(rows.astype(np.int64), axis=0,
                                          return_inverse=True)
                uids = np.empty(len(uniq), np.int64)
                for i in range(len(uniq)):
                    key = tuple(int(v) for v in uniq[i])
                    cid = interner.find_row(uniq[i])
                    if cid is None:
                        resident = len(interner) - len(other_by_region)
                        if resident < max_combinations:
                            cid = interner.intern(key)
                        else:
                            region = key[0]
                            cid = other_by_region.get(region)
                            if cid is None:
                                cid = interner.intern(other_row(region, W))
                                other_by_region[region] = cid
                            tail_folds += int(np.sum(inverse == i))
                    uids[i] = cid
                cids = uids[inverse.reshape(-1)]
            if len(interner) > cap:
                new_cap = 1 << (len(interner) - 1).bit_length()
                pad = new_cap - cap
                pad_stat = (pad,) if n_chan == 1 else (pad, n_chan)
                counts, psum, psumsq, n, prev = carry
                carry = (jnp.concatenate([counts,
                                          jnp.zeros(pad, counts.dtype)]),
                         jnp.concatenate([psum,
                                          jnp.zeros(pad_stat,
                                                    psum.dtype)]),
                         jnp.concatenate([psumsq,
                                          jnp.zeros(pad_stat,
                                                    psumsq.dtype)]),
                         n, prev)
                cap = new_cap
            table, table_ids, n_rows = _build_table(interner, cap, W, pack)
            idx = np.full(chunk_size, cap, np.int64)
            idx[valid] = cids
            carry = _combo_fold_jit(carry, jnp.asarray(idx), total_dev,
                                    valid_dev)
        counts, psum, psumsq, n, _ = carry
        k_combos = len(interner)
        n = int(n)
        counts = np.asarray(counts, np.int64)[:k_combos]
        psum = np.asarray(psum, np.float64)[:k_combos]
        psumsq = np.asarray(psumsq, np.float64)[:k_combos]
    if stats is not None:
        stats["chunks"] = k_chunks
        stats["miss_chunks"] = miss_chunks
        if max_combinations is not None:
            stats["tail_folds"] = tail_folds
    if n == 0:
        raise ValueError("run too short for sampling period")
    agg = StreamingCombinationAggregator.from_table(
        interner.combo_matrix(), counts, psum, psumsq,
        domains=dtl.domains, k=max_combinations)
    if max_combinations is not None:
        # from_table re-counts nothing; carry the pipeline's fold
        # provenance so tail_info() discloses what happened on device.
        agg.tail_folds += tail_folds
    return agg, n


# ---------------------------------------------------------------------------
# Numpy reference oracle (same sample clock, float64 host math).
# ---------------------------------------------------------------------------


def _ref_times(seed: int, k: int, period: float, jitter: float,
               chunk_size: int) -> np.ndarray:
    with enable_x64():
        t = chunk_sample_times(jax.random.PRNGKey(seed), jnp.int32(k),
                               jnp.float64(period), jnp.float64(jitter),
                               chunk_size=chunk_size)
        return np.asarray(t, np.float64)


def _ref_reader(spec: SensorSpec, tl: Timeline):
    """Per-run chunk reader ``(t, valid, prev) -> (rails [n, D], new_prev)``.

    Sensors/precomputations are built once per run (not per chunk); the
    RAPL prev-sample state is carried by the caller because it crosses
    chunk boundaries. The instant/INA231 branches reuse the real trace
    sensors' ``read_rails`` (stateless semantics) so the oracle can't
    drift from the instrument model. For scalar (D=1) timelines the
    single rail column is bit-identical to the old scalar reader.
    """
    if spec.kind == "instant":
        from repro.core.sensors import InstantTraceSensor
        sens = InstantTraceSensor(tl)
        return lambda t, valid, prev: (sens.read_rails(t), prev)
    if spec.kind == "rapl":
        base = _TraceSensorBase(tl)
        up = spec.update_period

        def read(t, valid, prev):
            tq = np.floor(t / up + 1e-6) * up
            prev_vec = np.concatenate([[prev], tq[:-1]])
            prev_vec = np.where(prev_vec < 0.0, np.maximum(tq - up, 0.0),
                                prev_vec)
            dt = np.maximum(tq - prev_vec, up)
            p = (base._energy_rails_at(tq)
                 - base._energy_rails_at(prev_vec)) / dt[:, None]
            new_prev = float(tq[valid][-1]) if valid.any() else prev
            return p, new_prev
        return read
    if spec.kind == "ina231":
        from repro.core.sensors import Ina231TraceSensor
        sens = Ina231TraceSensor(tl, window=spec.window)
        return lambda t, valid, prev: (sens.read_rails(t), prev)
    raise ValueError(f"unknown trace sensor kind: {spec.kind!r}")


def _ref_channels(rails: np.ndarray) -> np.ndarray:
    """[n, D] rails → [n, C] channels (total appended when D > 1)."""
    if rails.shape[1] == 1:
        return rails
    return np.concatenate([rails, rails.sum(axis=1, keepdims=True)], axis=1)


def reference_region_pipeline(tl: Timeline, spec: SensorSpec, *,
                              period: float, jitter: float = 200e-6,
                              seed: int = 0,
                              chunk_size: int = DEFAULT_CHUNK,
                              overhead_per_sample: float = 0.0,
                              idle_power: float = DEFAULT_IDLE_POWER) -> PipelineResult:
    """Numpy mirror of :func:`run_region_pipeline` (the oracle).

    Same counter-based times (shared :func:`chunk_sample_times`), host
    ``searchsorted`` lookups, float64 sensor math, ``np.bincount``
    reduction. Counts must match the fused path bit-exactly; sums agree
    to float64 elementwise-rounding differences.
    """
    _check_sampling_args(spec, period, jitter)
    if spec.num_domains != tl.num_domains:
        raise ValueError(
            f"sensor bank has {spec.num_domains} channel(s) but the "
            f"timeline carries {tl.num_domains} power rail(s)")
    R = len(tl.names)
    C = num_channels(tl.num_domains)
    idle_ch = idle_channel(tl.domain_names)
    reader = _ref_reader(spec, tl)
    frac = min(overhead_per_sample / period, 1.0) \
        if overhead_per_sample > 0.0 else 0.0
    counts = np.zeros(R, np.int64)
    psum = np.zeros((R, C), np.float64)
    psumsq = np.zeros((R, C), np.float64)
    prev = -1.0
    t_end = tl.t_exec
    n = 0
    for k in range(num_chunks(t_end, period, chunk_size)):
        t_raw = _ref_times(seed, k, period, jitter, chunk_size)
        valid = t_raw < t_end
        t = np.minimum(t_raw, t_end)
        rids = tl.region_at(t)
        rails, prev = reader(t, valid, prev)
        chan = (1.0 - frac) * _ref_channels(rails)
        chan[:, idle_ch] += frac * idle_power
        if C > 1:
            chan[:, -1] += frac * idle_power
        rv, pv = rids[valid], chan[valid]
        counts += np.bincount(rv, minlength=R).astype(np.int64)
        for j in range(C):
            psum[:, j] += np.bincount(rv, weights=pv[:, j], minlength=R)
            psumsq[:, j] += np.bincount(rv, weights=pv[:, j] * pv[:, j],
                                        minlength=R)
        n += int(valid.sum())
    if n == 0:
        raise ValueError("run too short for sampling period")
    return _result_from_channels(counts, psum, psumsq, n,
                                 t_end + n * overhead_per_sample,
                                 tl.domain_names)


def reference_combo_pipeline(timelines: list[Timeline], spec_fn, *,
                             period: float, jitter: float = 200e-6,
                             seed: int = 0,
                             chunk_size: int = DEFAULT_CHUNK
                             ) -> tuple[StreamingCombinationAggregator, int]:
    """Numpy mirror of :func:`run_combo_pipeline`.

    ``spec_fn`` maps a timeline to its :class:`SensorSpec` (matching the
    device path's one-spec-for-all, pass ``lambda tl: spec``). Chunks are
    interned through a host :class:`CombinationInterner` exactly as the
    device path's miss fallback does, so combination ids line up 1:1.
    """
    specs = [spec_fn(tl) for tl in timelines]
    for s, tl in zip(specs, timelines):
        _check_sampling_args(s, period, jitter)
        if s.num_domains != tl.num_domains:
            raise ValueError("sensor bank / timeline rail count mismatch")
    domains = timelines[0].domain_names
    if any(tl.domain_names != domains for tl in timelines):
        raise ValueError("workers must share a power-rail domain axis")
    readers = [_ref_reader(s, tl) for s, tl in zip(specs, timelines)]
    t_end = min(tl.t_exec for tl in timelines)
    agg = StreamingCombinationAggregator(domains=domains)
    prev = -1.0
    n = 0
    for k in range(num_chunks(t_end, period, chunk_size)):
        t_raw = _ref_times(seed, k, period, jitter, chunk_size)
        valid = t_raw < t_end
        t = np.minimum(t_raw, t_end)
        rid_mat = np.stack([tl.region_at(t) for tl in timelines], axis=1)
        rails = np.zeros((len(t), len(domains)), np.float64)
        new_prev = prev
        for reader in readers:
            p, new_prev = reader(t, valid, prev)
            rails += p
        prev = new_prev
        pv = rails[valid]
        agg.update(rid_mat[valid].astype(np.int64),
                   pv[:, 0] if len(domains) == 1 else pv)
        n += int(valid.sum())
    if n == 0:
        raise ValueError("run too short for sampling period")
    return agg, n
