"""Bounded-state combination attribution: hashing + ownership primitives.

ALEA's §4.4 multi-worker attribution keys sufficient statistics by
*combination* rows (region, worker, request, ...). The exact
:class:`~repro.core.streaming.CombinationInterner` is O(distinct) host
memory with O(log R) device recompiles — the unbounded-overhead failure
mode the RAPL cost study warns profilers against. This module holds the
two primitives that bound it, shared by the streaming layer, the device
pipeline and the exchange layer:

* **Heavy-hitters tail sentinel** (:data:`OTHER`): a bounded aggregator
  keeps at most ``k`` identified combination rows per table plus one
  ``other`` row per region, ``(region, -1, ..., -1)``. Evicting a row
  folds its full (counts, Σpow, Σpow²) triple — all C channels — into
  its region's ``other`` row, so *per-region totals stay bit-exact* and
  only tail identity coarsens. Sentinel rows pack safely into the
  device-resident int64-key table: any ``-1`` field drives that packed
  word negative, while real rows (fields in ``[0, 2^bits)``) and the
  int64-max padding rows are non-negative, so sentinel keys can never
  collide with either.

* **Hash-range ownership** (:func:`combo_hashes`, :class:`HashRange`):
  combination-key ownership is partitioned across hosts by splitmix64
  hash range so no host holds the union table. The hash is the same
  avalanche construction as the sample clock and the fault mixer
  (:func:`repro.core.faults._mix64`), vectorized over rows — a pure
  function of the combination tuple, so every host agrees on ownership
  without coordination.

Everything here is a pure function of its inputs — no wall clock, no
global state. The module is a member of ``DETERMINISM_CRITICAL_MODULES``
(the ``no-wallclock`` AST pass include list): eviction order in the
streaming layer derives from the deterministic fold counters, and the
hash used for sharding must replay bit-exactly across hosts and restarts.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.faults import SketchConfigError

__all__ = [
    "OTHER", "SketchConfigError",
    "mix64", "combo_hashes",
    "HashRange", "is_other_rows", "other_row",
]


# Sentinel filling every non-region field of a tail bucket row. The region
# axis (combination column 0) keeps its real id — that is what makes the
# per-region totals contract exact.
OTHER: int = -1

_U64 = np.uint64
_SEED = _U64(0x9E3779B97F4A7C15)
_M1 = _U64(0xBF58476D1CE4E5B9)
_M2 = _U64(0x94D049BB133111EB)
_S30, _S27, _S31 = _U64(30), _U64(27), _U64(31)


def mix64(h: np.ndarray, w: np.ndarray) -> np.ndarray:
    """One splitmix64 absorb+avalanche round, vectorized: ``mix(h + w)``.

    Matches :func:`repro.core.faults._mix64`'s per-word step exactly
    (uint64 wrap-around is the ``& MASK64`` of the scalar version), so
    host-side scalar keys and vectorized row hashes agree bit-for-bit.
    """
    h = (h + w).astype(_U64, copy=False)
    h ^= h >> _S30
    h *= _M1
    h ^= h >> _S27
    h *= _M2
    h ^= h >> _S31
    return h


def combo_hashes(mat: np.ndarray) -> np.ndarray:
    """splitmix64 hash of each combination row: ``[k, W] int -> [k] uint64``.

    Absorbs the row's fields in column order starting from the golden
    seed — the same word-sequence construction as ``faults._mix64``, so
    ``combo_hashes(row[None])[0] == _mix64(*row)`` for any row. Negative
    fields (the :data:`OTHER` sentinel) absorb as their two's-complement
    uint64 image, exactly like the scalar mixer's ``w & MASK64``.
    """
    mat = np.ascontiguousarray(np.asarray(mat, dtype=np.int64))
    if mat.ndim == 1:
        mat = mat[:, None]
    h = np.full(mat.shape[0], _SEED, dtype=_U64)
    with np.errstate(over="ignore"):
        for j in range(mat.shape[1]):
            h = mix64(h, mat[:, j].view(_U64))
    return h


def other_row(region: int, width: int) -> tuple[int, ...]:
    """The tail bucket combination row for ``region`` at table ``width``."""
    if width < 2:
        raise SketchConfigError(
            "bounded combination tables need width >= 2: at width 1 the "
            "region axis is the whole key, so a per-region 'other' bucket "
            "degenerates to the row it would fold")
    return (int(region),) + (OTHER,) * (width - 1)


def is_other_rows(mat: np.ndarray) -> np.ndarray:
    """[k] bool mask of tail bucket rows (any field carries the sentinel)."""
    mat = np.asarray(mat)
    if mat.ndim == 1:
        mat = mat[:, None]
    if mat.shape[0] == 0:
        return np.zeros(0, dtype=bool)
    return (mat < 0).any(axis=1)


@dataclasses.dataclass(frozen=True)
class HashRange:
    """Half-open uint64 hash interval ``[lo, hi)`` owning combination keys.

    ``hi`` may be ``2**64`` (exclusive upper bound of the full space).
    Ranges are plain value objects: equality is ownership equality, and
    the wire schema (v3) carries them as ``[lo, hi]`` integer pairs.
    """
    lo: int
    hi: int

    def __post_init__(self):
        if not (0 <= self.lo < self.hi <= 1 << 64):
            raise ValueError(
                f"hash range must satisfy 0 <= lo < hi <= 2**64; "
                f"got [{self.lo}, {self.hi})")

    @classmethod
    def full(cls) -> "HashRange":
        return cls(0, 1 << 64)

    @classmethod
    def split(cls, n: int) -> tuple["HashRange", ...]:
        """Partition the uint64 hash space into ``n`` contiguous ranges
        (a deterministic, coordination-free shard map: range ``i`` of
        ``n`` is the same on every host)."""
        if n < 1:
            raise ValueError(f"need at least one range; got n={n}")
        bounds = [(i << 64) // n for i in range(n + 1)]
        return tuple(cls(bounds[i], bounds[i + 1]) for i in range(n))

    def owns(self, hashes: np.ndarray) -> np.ndarray:
        """[k] bool mask of hashes inside ``[lo, hi)``."""
        h = np.asarray(hashes, dtype=_U64)
        # hi == 2**64 doesn't fit in uint64; compare inclusively on hi-1.
        return (h >= _U64(self.lo)) & (h <= _U64(self.hi - 1))

    def owns_row(self, combo) -> bool:
        return bool(self.owns(combo_hashes(np.asarray(combo)[None, :]))[0])

    def as_tuple(self) -> tuple[int, int]:
        return (self.lo, self.hi)
