"""ALEA core: fine-grain energy profiling with region (basic-block) sampling.

The paper's primary contribution, adapted TPU-native: systematic sampling of
(currently-executing region, power sensor reading) pairs + a probabilistic
model that attributes execution time and energy to regions far finer than
the sensor's sampling period.
"""

from repro.core.attribution import AttributionReport, ValidationResult, validate
from repro.core.energy_opt import (ImplVariant, KnobSpace, ProgramPlan,
                                   RegionPlan, baseline_plan, optimize_regions)
from repro.core.estimator import (AggregateFn, EstimateSet, EstimateTable,
                                  RegionEstimate, aggregate_samples_np,
                                  estimate_combinations, estimate_regions,
                                  estimates_from_statistics, z_quantile)
from repro.core.exchange import (CheckpointExchange, CollectiveExchange,
                                 PackedShard, collective_reduce,
                                 gather_shards, pack_shard, restore_shard,
                                 spill_shard, unpack_shard)
from repro.core.power_model import (TPU_V5E, HardwareSpec, PowerModel,
                                    PowerModelParams)
from repro.core.profiler import EnergyProfiler, HostSession
from repro.core.regions import profiling_session, region, registry
from repro.core.sampler import (HostSampler, RegionMarker, SampleBuffer,
                                SampleStream, iter_multiworker_chunks,
                                iter_sample_chunks, sample_timeline)
from repro.core.streaming import (CombinationInterner, StreamingAggregator,
                                  StreamingCombinationAggregator,
                                  stream_estimate)
from repro.core.timeline import RegionCost, Timeline, ground_truth, synthesize

__all__ = [
    "AttributionReport", "ValidationResult", "validate",
    "ImplVariant", "KnobSpace", "ProgramPlan", "RegionPlan",
    "baseline_plan", "optimize_regions",
    "AggregateFn", "EstimateSet", "EstimateTable", "RegionEstimate",
    "aggregate_samples_np", "estimate_combinations", "estimate_regions",
    "estimates_from_statistics", "z_quantile",
    "CheckpointExchange", "CollectiveExchange", "PackedShard",
    "collective_reduce", "gather_shards", "pack_shard", "restore_shard",
    "spill_shard", "unpack_shard",
    "CombinationInterner", "StreamingAggregator",
    "StreamingCombinationAggregator", "stream_estimate",
    "TPU_V5E", "HardwareSpec", "PowerModel", "PowerModelParams",
    "EnergyProfiler", "HostSession",
    "profiling_session", "region", "registry",
    "HostSampler", "RegionMarker", "SampleBuffer", "SampleStream",
    "iter_multiworker_chunks", "iter_sample_chunks", "sample_timeline",
    "RegionCost", "Timeline", "ground_truth", "synthesize",
]
