"""AdamW + LR schedules + global-norm clipping, from scratch (no optax).

States are plain pytrees; update is fully jit/pjit-compatible. Master
weights stay fp32; gradients may arrive bf16 (cast up inside).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

Params = Any

__all__ = ["AdamWConfig", "adamw_init", "adamw_update", "global_norm",
           "clip_by_global_norm", "cosine_schedule", "linear_warmup"]


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def adamw_init(params: Params) -> dict:
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32),
                         params)
    import copy
    return {"mu": zeros,
            "nu": jax.tree.map(jnp.zeros_like, zeros),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree: Params) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(grads: Params, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), norm


def cosine_schedule(cfg: AdamWConfig) -> Callable[[jax.Array], jax.Array]:
    def sched(step):
        step = step.astype(jnp.float32)
        warm = step / jnp.maximum(cfg.warmup_steps, 1)
        decay_frac = jnp.clip(
            (step - cfg.warmup_steps)
            / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
        cos = 0.5 * (1 + jnp.cos(jnp.pi * decay_frac))
        mult = jnp.where(step < cfg.warmup_steps, warm,
                         cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)
        return cfg.lr * mult
    return sched


def linear_warmup(cfg: AdamWConfig) -> Callable[[jax.Array], jax.Array]:
    def sched(step):
        return cfg.lr * jnp.minimum(step.astype(jnp.float32)
                                    / max(cfg.warmup_steps, 1), 1.0)
    return sched


def _is_matrix(path: tuple, leaf: jax.Array) -> bool:
    """Weight decay applies to matrices, not norms/biases/1-d params."""
    return leaf.ndim >= 2


def adamw_update(cfg: AdamWConfig, params: Params, grads: Params,
                 state: dict, *, schedule=None):
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    sched = schedule or cosine_schedule(cfg)
    step = state["step"] + 1
    lr = sched(step)
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32)
        mu = b1 * mu + (1 - b1) * g
        nu = b2 * nu + (1 - b2) * g * g
        mhat = mu / bc1
        vhat = nu / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), mu, nu

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_mu = jax.tree.leaves(state["mu"])
    flat_nu = jax.tree.leaves(state["nu"])
    new_p, new_mu, new_nu = [], [], []
    for p, g, mu, nu in zip(flat_p, flat_g, flat_mu, flat_nu):
        a, b, c = upd(p, g, mu, nu)
        new_p.append(a); new_mu.append(b); new_nu.append(c)
    metrics = {"grad_norm": gnorm, "lr": lr}
    return (jax.tree.unflatten(tdef, new_p),
            {"mu": jax.tree.unflatten(tdef, new_mu),
             "nu": jax.tree.unflatten(tdef, new_nu),
             "step": step},
            metrics)
