"""int8 gradient compression with error feedback (distributed-optimization
trick for scale-out DP).

Quantize each gradient leaf to int8 with a per-leaf fp32 scale before the
cross-replica all-reduce, keep the quantization residual locally, and add
it back into the next step's gradient (error feedback), which preserves
convergence (1-bit Adam / EF-SGD literature). Compression runs *inside*
the pjit'd train step, so the all-reduce moves ~4x fewer bytes over DP
links — visible in the dry-run's collective byte count.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["compress_init", "compress_decompress", "quantize_int8",
           "dequantize_int8"]


def quantize_int8(x: jax.Array):
    """Symmetric per-tensor int8. Returns (q, scale)."""
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf))
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_init(params) -> dict:
    """Error-feedback residual buffers (fp32, zero-init)."""
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress_decompress(grads, residuals):
    """Simulate the quantize→(all-reduce)→dequantize path with error
    feedback. Under pjit the all-reduce is implicit (grads are averaged by
    the sharded loss); we apply EF around the quantization so the *numeric*
    effect matches the wire-compressed run. Returns (new_grads, new_residuals).
    """
    def one(g, r):
        gf = g.astype(jnp.float32) + r
        q, scale = quantize_int8(gf)
        deq = dequantize_int8(q, scale)
        return deq, gf - deq

    flat_g, tdef = jax.tree.flatten(grads)
    flat_r = jax.tree.leaves(residuals)
    outs = [one(g, r) for g, r in zip(flat_g, flat_r)]
    new_g = jax.tree.unflatten(tdef, [o[0] for o in outs])
    new_r = jax.tree.unflatten(tdef, [o[1] for o in outs])
    return new_g, new_r
