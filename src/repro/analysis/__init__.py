"""Contract auditor: static enforcement of the repo's invariants.

Two layers (see ``README.md`` in this package and ROADMAP → "Static
contracts"):

* **Layer 1** — AST passes over ``src/repro`` (:mod:`.passes`):
  determinism hygiene, typed spill errors, no silent excepts,
  fault-site registry discipline, scoped ``enable_x64``. Pre-existing
  findings are pinned in ``baseline.json``; only new ones fail.
* **Layer 2** — jaxpr audits of the jitted hot paths
  (:mod:`.jaxpr_audit`): f64-op inventory ratcheted by
  ``x64_budget.json``, donation-aliasing verification, host-callback
  detection.

Entry point: ``python -m repro.analysis [--check|--report|--update-baseline]``
(wired into ``scripts/lint.sh`` and the CI ``analysis`` job).
"""

from __future__ import annotations

import dataclasses
import os

from repro.analysis import baseline as baseline_mod
from repro.analysis.passes import (ContractPass, FileUnit, Finding,
                                   PASS_REGISTRY, all_passes, parse_unit,
                                   run_passes)

__all__ = [
    "Finding", "FileUnit", "ContractPass", "PASS_REGISTRY",
    "all_passes", "parse_unit", "run_passes",
    "REPO_ROOT", "SCAN_ROOT", "BASELINE_PATH", "BUDGET_PATH",
    "scan_repo", "AuditResult", "run_audit",
]

_PKG_DIR = os.path.dirname(os.path.abspath(__file__))
REPO_ROOT = os.path.dirname(os.path.dirname(os.path.dirname(_PKG_DIR)))
SCAN_ROOT = os.path.join(REPO_ROOT, "src", "repro")
BASELINE_PATH = os.path.join(_PKG_DIR, "baseline.json")
BUDGET_PATH = os.path.join(_PKG_DIR, "x64_budget.json")


def scan_repo(scan_root: str | None = None) -> list[FileUnit]:
    """Parse every ``.py`` under the scan root (default: ``src/repro``)."""
    root = scan_root or SCAN_ROOT
    units: list[FileUnit] = []
    for dirpath, dirnames, filenames in os.walk(root):
        dirnames[:] = sorted(d for d in dirnames
                             if d not in ("__pycache__",))
        for fname in sorted(filenames):
            if not fname.endswith(".py"):
                continue
            full = os.path.join(dirpath, fname)
            modpath = os.path.relpath(full, root).replace(os.sep, "/")
            display = os.path.relpath(full, REPO_ROOT).replace(os.sep, "/")
            with open(full) as f:
                source = f.read()
            units.append(parse_unit(display, modpath, source))
    return units


@dataclasses.dataclass
class AuditResult:
    """Everything one full audit run produced, pre-ratchet-checked."""
    findings: list                   # all layer-1 findings (pre-baseline)
    ratchet: "baseline_mod.RatchetResult"
    reports: list                    # layer-2 PathReports ([] if skipped)
    budget_violations: list          # layer-2 ratchet failures

    @property
    def ok(self) -> bool:
        return self.ratchet.ok and not self.budget_violations


def run_audit(*, jaxpr: bool = True,
              baseline_path: str | None = None,
              budget_path: str | None = None) -> AuditResult:
    """One full audit: scan + passes + baseline check (+ jaxpr budgets)."""
    units = scan_repo()
    findings = run_passes(units)
    ratchet = baseline_mod.check_findings(
        findings, baseline_mod.load_counts(baseline_path or BASELINE_PATH))
    reports: list = []
    violations: list = []
    if jaxpr:
        from repro.analysis.jaxpr_audit import audit_hot_paths
        reports = audit_hot_paths()
        violations = baseline_mod.check_budget(
            reports, baseline_mod.load_budget(budget_path or BUDGET_PATH))
    return AuditResult(findings=findings, ratchet=ratchet,
                       reports=reports, budget_violations=violations)
