"""CLI for the contract auditor.

Modes (mutually exclusive; ``--check`` is the default):

* ``--check``           fail (exit 1) on any finding not absorbed by
                        ``baseline.json`` or any hot-path metric over
                        its ``x64_budget.json`` budget.
* ``--report``          print everything — baselined findings included,
                        per-path f64 inventories — and exit 0.
* ``--update-baseline`` regenerate both baseline files from the current
                        tree. Refuses to *raise* a committed f64 budget
                        unless ``--allow-increase`` is also given.

``--no-jaxpr`` skips layer 2 (no jax import, no tracing) for fast
lint-loop iterations on the AST passes alone.
"""

from __future__ import annotations

import argparse
import sys

from repro.analysis import (BASELINE_PATH, BUDGET_PATH, run_audit,
                            run_passes, scan_repo)
from repro.analysis import baseline as baseline_mod


def _print_findings(findings, label: str) -> None:
    if not findings:
        return
    print(f"-- {label} ({len(findings)}) --")
    for f in findings:
        print(f"  {f.render()}")


def _cmd_check(args) -> int:
    result = run_audit(jaxpr=not args.no_jaxpr)
    _print_findings(result.ratchet.new, "new contract findings")
    for v in result.budget_violations:
        print(f"  [jaxpr] {v.render()}")
    if result.ratchet.stale_keys:
        print(f"note: {len(result.ratchet.stale_keys)} baseline entries "
              f"are stale (fixed findings) — run --update-baseline to "
              f"shrink the pin file")
    if not result.ok:
        n = len(result.ratchet.new) + len(result.budget_violations)
        print(f"contract audit FAILED: {n} violation(s)")
        return 1
    n_base = len(result.ratchet.baselined)
    suffix = f" ({n_base} baselined)" if n_base else ""
    print(f"contract audit OK: {len(result.findings)} finding(s) "
          f"absorbed{suffix}, "
          f"{len(result.reports)} hot path(s) within budget")
    return 0


def _cmd_report(args) -> int:
    result = run_audit(jaxpr=not args.no_jaxpr)
    _print_findings(result.ratchet.new, "new contract findings")
    _print_findings(result.ratchet.baselined, "baselined findings")
    if result.ratchet.stale_keys:
        print(f"-- stale baseline keys ({len(result.ratchet.stale_keys)}) --")
        for k in result.ratchet.stale_keys:
            print(f"  {k}")
    if result.reports:
        print("-- hot-path audit --")
        for r in result.reports:
            print(f"  {r.render()}")
    for v in result.budget_violations:
        print(f"  [jaxpr] {v.render()}")
    return 0


def _cmd_update(args) -> int:
    units = scan_repo()
    findings = run_passes(units)
    baseline_mod.save_counts(baseline_mod.finding_counts(findings),
                             BASELINE_PATH)
    print(f"wrote {BASELINE_PATH} ({len(findings)} finding(s) pinned)")
    if not args.no_jaxpr:
        from repro.analysis.jaxpr_audit import audit_hot_paths
        reports = audit_hot_paths()
        try:
            merged = baseline_mod.merge_budget(
                reports, baseline_mod.load_budget(BUDGET_PATH),
                allow_increase=args.allow_increase)
        except ValueError as e:
            print(f"refusing to update x64 budget: {e}")
            return 1
        baseline_mod.save_budget(merged, BUDGET_PATH)
        print(f"wrote {BUDGET_PATH} ({len(reports)} hot path(s))")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="contract auditor: AST passes + jaxpr hot-path audits")
    mode = ap.add_mutually_exclusive_group()
    mode.add_argument("--check", action="store_true",
                      help="fail on new findings / budget overruns "
                           "(default)")
    mode.add_argument("--report", action="store_true",
                      help="print the full audit, never fail")
    mode.add_argument("--update-baseline", action="store_true",
                      help="regenerate baseline.json + x64_budget.json")
    ap.add_argument("--allow-increase", action="store_true",
                    help="with --update-baseline: permit a committed f64 "
                         "budget to grow")
    ap.add_argument("--no-jaxpr", action="store_true",
                    help="layer 1 only (skip hot-path tracing)")
    args = ap.parse_args(argv)
    if args.update_baseline:
        return _cmd_update(args)
    if args.report:
        return _cmd_report(args)
    return _cmd_check(args)


if __name__ == "__main__":
    sys.exit(main())
