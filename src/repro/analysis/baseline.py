"""Ratcheted baselines for both auditor layers.

Layer 1 (:mod:`repro.analysis.passes`) pins pre-existing findings in
``baseline.json``: a count per finding *key* (``pass:path:ident`` —
deliberately line-free, so unrelated edits that shift code don't churn
the file). A check fails only on findings in excess of the pinned count;
keys whose findings were fixed are reported as stale so the baseline
shrinks over time.

Layer 2 (:mod:`repro.analysis.jaxpr_audit`) pins per-hot-path metric
counts in ``x64_budget.json`` (f64 ops, widenings, host callbacks, and
the donation-aliasing contract). Metrics are a one-way ratchet: a check
fails when any count *exceeds* its budget, and ``--update-baseline``
refuses to raise a committed f64 budget unless forced
(``allow_increase``) — the ROADMAP item-2 mechanism for driving the
fused chunk step x64-free without regressions sneaking back in.
"""

from __future__ import annotations

import dataclasses
import json
import os
from typing import Mapping, Sequence

from repro.analysis.passes import Finding

__all__ = [
    "load_counts", "save_counts", "finding_counts", "RatchetResult",
    "check_findings", "load_budget", "save_budget", "BudgetViolation",
    "check_budget", "merge_budget",
]


# -- layer 1: finding-count baseline ------------------------------------------

def load_counts(path: str) -> dict[str, int]:
    """Baseline key -> pinned count; missing file means empty baseline."""
    if not os.path.exists(path):
        return {}
    with open(path) as f:
        data = json.load(f)
    counts = data.get("counts", {}) if isinstance(data, dict) else {}
    return {str(k): int(v) for k, v in counts.items()}


def save_counts(counts: Mapping[str, int], path: str) -> None:
    payload = {
        "_comment": ("Pinned pre-existing contract findings "
                     "(repro.analysis layer 1). Regenerate with "
                     "`python -m repro.analysis --update-baseline`; "
                     "counts should only shrink."),
        "counts": {k: counts[k] for k in sorted(counts)},
    }
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=False)
        f.write("\n")


def finding_counts(findings: Sequence[Finding]) -> dict[str, int]:
    counts: dict[str, int] = {}
    for f in findings:
        counts[f.key] = counts.get(f.key, 0) + 1
    return counts


@dataclasses.dataclass
class RatchetResult:
    new: list[Finding]           # findings in excess of the baseline
    baselined: list[Finding]     # findings absorbed by the baseline
    stale_keys: list[str]        # baseline keys with no current finding

    @property
    def ok(self) -> bool:
        return not self.new


def check_findings(findings: Sequence[Finding],
                   baseline: Mapping[str, int]) -> RatchetResult:
    """Split findings into new vs baselined; report stale baseline keys.

    Within one key, the *first* ``baseline[key]`` findings (source
    order) are absorbed — which ones is arbitrary but stable, and the
    failure message always shows concrete file:line rows.
    """
    by_key: dict[str, list[Finding]] = {}
    for f in findings:
        by_key.setdefault(f.key, []).append(f)
    new: list[Finding] = []
    baselined: list[Finding] = []
    for key, group in by_key.items():
        allowed = int(baseline.get(key, 0))
        baselined.extend(group[:allowed])
        new.extend(group[allowed:])
    stale = [k for k in baseline if len(by_key.get(k, ())) < baseline[k]]
    new.sort(key=lambda f: (f.path, f.line, f.pass_name))
    return RatchetResult(new=new, baselined=baselined,
                         stale_keys=sorted(stale))


# -- layer 2: per-path metric budget ------------------------------------------

# Metrics that ratchet (current must be <= budget). Donation is checked
# absolutely by the auditor itself — an unaliased donated arg is a bug
# at any count, not a budget line.
RATCHET_METRICS = ("f64_ops", "f64_widenings", "host_callbacks")


def load_budget(path: str) -> dict[str, dict]:
    if not os.path.exists(path):
        return {}
    with open(path) as f:
        data = json.load(f)
    paths = data.get("paths", {}) if isinstance(data, dict) else {}
    return {str(k): dict(v) for k, v in paths.items()}


def save_budget(paths: Mapping[str, dict], path: str) -> None:
    payload = {
        "_comment": ("Committed per-hot-path budgets (repro.analysis "
                     "layer 2): f64 op counts may only go down "
                     "(ROADMAP item 2 ratchet). Regenerate with "
                     "`python -m repro.analysis --update-baseline`."),
        "paths": {k: paths[k] for k in sorted(paths)},
    }
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=False)
        f.write("\n")


@dataclasses.dataclass(frozen=True)
class BudgetViolation:
    path_name: str
    message: str

    def render(self) -> str:
        return f"{self.path_name}: {self.message}"


def check_budget(reports: Sequence, budget: Mapping[str, dict]
                 ) -> list[BudgetViolation]:
    """Compare :class:`jaxpr_audit.PathReport` rows against the budget."""
    out: list[BudgetViolation] = []
    for r in reports:
        entry = budget.get(r.name)
        if entry is None:
            out.append(BudgetViolation(
                r.name,
                "hot path not in x64_budget.json — run "
                "`python -m repro.analysis --update-baseline`"))
            continue
        for metric in RATCHET_METRICS:
            cur = int(getattr(r, metric))
            cap = int(entry.get(metric, 0))
            if cur > cap:
                out.append(BudgetViolation(
                    r.name,
                    f"{metric} grew: {cur} > budget {cap} "
                    f"(the ratchet only goes down)"))
        if r.donated_expected and r.donated_aliased < r.donated_expected:
            out.append(BudgetViolation(
                r.name,
                f"donation broken: {r.donated_aliased}/"
                f"{r.donated_expected} donated args aliased to outputs"))
    return out


def merge_budget(reports: Sequence, existing: Mapping[str, dict], *,
                 allow_increase: bool = False) -> dict[str, dict]:
    """New budget file contents from fresh reports.

    Raises ``ValueError`` on an attempt to raise a committed f64 count
    without ``allow_increase`` — updating the baseline must not be a
    back door around the ratchet.
    """
    out: dict[str, dict] = {}
    for r in reports:
        entry = {
            "f64_ops": int(r.f64_ops),
            "f64_widenings": int(r.f64_widenings),
            "host_callbacks": int(r.host_callbacks),
            "donated_expected": int(r.donated_expected),
            "donated_aliased": int(r.donated_aliased),
        }
        prev = existing.get(r.name)
        if prev is not None and not allow_increase:
            for metric in RATCHET_METRICS:
                if entry[metric] > int(prev.get(metric, 0)):
                    raise ValueError(
                        f"{r.name}: refusing to raise {metric} budget "
                        f"{prev.get(metric, 0)} -> {entry[metric]} "
                        f"(pass allow_increase to force)")
        out[r.name] = entry
    return out
