"""Layer 2 of the contract auditor: static walks over hot-path jaxprs.

The paper's ~1% overhead cap (and the RAPL-overhead study in PAPERS.md)
dies by a thousand cuts that unit tests don't see: an f64 op sneaking
into the serve decode step, a donated carry that silently stops
aliasing (doubling peak memory per step), a stray ``debug.print`` or
``pure_callback`` forcing a host sync per chunk. This module traces the
jitted hot paths *without running them* and reports, per path:

* an **f64-op inventory** (every equation producing a float64 output,
  by primitive) — ratcheted against ``x64_budget.json``: counts may
  only go down (ROADMAP item 2: drive the fused chunk step x64-free);
* **donation verification** — each ``donate_argnums`` entry must appear
  as an input-output alias (``tf.aliasing_output``) in the lowered
  StableHLO, otherwise the donation is a no-op and the step allocates
  a second carry;
* **host-callback / transfer detection** — callback primitives and
  implicit ``convert_element_type`` widenings to f64.

Audited paths: the device-pipeline region run and fused combo chunk
step at D=1 and D=3 (scalar vs multi-rail substrate), the miss-path
admit-or-fold scatter (``_combo_fold`` — the step bounded runs lean on
whenever the heavy-hitters tier folds tail combinations, so its carry
donation and f64 inventory are ratcheted like the steady-state step's),
the serve decode step for each KV-cache family (dense / MoE /
recurrent / hybrid), and the exchange collectives (psum all-reduce,
combination all-gather).
Path construction is shape-only where params would be large
(``jax.eval_shape``); nothing here compiles or executes device code
beyond tracing/lowering.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Callable, Iterable, Iterator, Sequence

import numpy as np

__all__ = [
    "JaxprStats", "PathReport", "iter_eqns", "audit_jaxpr",
    "count_aliased_outputs", "donation_of_jitted", "jit_cache_size",
    "HOT_PATH_BUILDERS", "audit_hot_paths",
]


# -- jaxpr walking ------------------------------------------------------------

def _as_open_jaxpr(j):
    """Accept ClosedJaxpr / Jaxpr / make_jaxpr output, duck-typed so we
    don't pin a jax.core layout."""
    inner = getattr(j, "jaxpr", None)
    return inner if inner is not None else j


def _sub_jaxprs(eqn) -> Iterator:
    for v in eqn.params.values():
        items = v if isinstance(v, (tuple, list)) else (v,)
        for item in items:
            if hasattr(item, "eqns"):                 # open Jaxpr
                yield item
            elif hasattr(item, "jaxpr") and hasattr(
                    getattr(item, "jaxpr"), "eqns"):  # ClosedJaxpr
                yield item.jaxpr


def iter_eqns(jaxpr) -> Iterator:
    """All equations of a (closed) jaxpr, recursing into call/control-flow
    sub-jaxprs (pjit, scan, while, cond branches, custom_jvp, ...)."""
    jaxpr = _as_open_jaxpr(jaxpr)
    for eqn in jaxpr.eqns:
        yield eqn
        for sub in _sub_jaxprs(eqn):
            yield from iter_eqns(sub)


_CALLBACK_PRIMS = frozenset({
    "pure_callback", "io_callback", "debug_callback", "callback",
    "host_callback_call", "outside_call", "infeed", "outfeed",
    "debug_print",
})


def _is_callback_prim(name: str) -> bool:
    return name in _CALLBACK_PRIMS or "callback" in name


def _np_dtype(dt) -> np.dtype | None:
    try:
        return np.dtype(dt)
    except TypeError:
        return None      # extended dtype (PRNG key) — never float64


def _out_dtypes(eqn) -> Iterator[np.dtype]:
    for v in eqn.outvars:
        dt = _np_dtype(getattr(getattr(v, "aval", None), "dtype", None))
        if dt is not None:
            yield dt


def _in_dtypes(eqn) -> Iterator[np.dtype]:
    for v in eqn.invars:
        dt = _np_dtype(getattr(getattr(v, "aval", None), "dtype", None))
        if dt is not None:
            yield dt


@dataclasses.dataclass
class JaxprStats:
    """Static inventory of one traced computation."""
    eqn_count: int = 0
    f64_by_prim: dict = dataclasses.field(default_factory=dict)
    f64_widenings: int = 0
    callback_prims: list = dataclasses.field(default_factory=list)

    @property
    def f64_ops(self) -> int:
        return sum(self.f64_by_prim.values())

    @property
    def host_callbacks(self) -> int:
        return len(self.callback_prims)


_F64 = np.dtype(np.float64)


def audit_jaxpr(jaxpr) -> JaxprStats:
    """Walk every equation (recursively) and tally the inventory.

    An equation counts toward the f64 inventory when any output is
    float64. A ``convert_element_type`` whose output is float64 but
    whose input is not counts as a widening — the signature of an
    implicit promotion (weak-type contagion, a stray python float) as
    opposed to deliberate f64 arithmetic.
    """
    stats = JaxprStats()
    for eqn in iter_eqns(jaxpr):
        stats.eqn_count += 1
        name = eqn.primitive.name
        if _is_callback_prim(name):
            stats.callback_prims.append(name)
        out_f64 = any(dt == _F64 for dt in _out_dtypes(eqn))
        if out_f64:
            stats.f64_by_prim[name] = stats.f64_by_prim.get(name, 0) + 1
            if name == "convert_element_type" and not any(
                    dt == _F64 for dt in _in_dtypes(eqn)):
                stats.f64_widenings += 1
    return stats


# -- donation verification ----------------------------------------------------

_ALIAS_RE = re.compile(r"tf\.aliasing_output\s*=")


def count_aliased_outputs(stablehlo_text: str) -> int:
    """Input-output alias count in lowered StableHLO text. Donated args
    that XLA accepted carry a ``tf.aliasing_output = N`` attribute on
    the entry function's parameter."""
    return len(_ALIAS_RE.findall(stablehlo_text))


def donation_of_jitted(jitted, *args, expected: int, **kwargs
                       ) -> tuple[int, int]:
    """(expected, actually-aliased) for a jitted fn lowered at ``args``."""
    text = jitted.lower(*args, **kwargs).as_text()
    return expected, count_aliased_outputs(text)


# -- compile-cache introspection ----------------------------------------------

def jit_cache_size(fn) -> int:
    """Compiled-specialization count of a jitted callable — the probe
    behind the recompile-count guard (one (config, shape) key must mean
    exactly one compile)."""
    return int(fn._cache_size())


# -- hot-path registry --------------------------------------------------------

HOT_PATH_BUILDERS: dict[str, Callable[[], "PathReport"]] = {}


def _hot_path(name: str):
    def deco(fn):
        HOT_PATH_BUILDERS[name] = fn
        return fn
    return deco


@dataclasses.dataclass
class PathReport:
    """Audit result for one named hot path (the budget-file row)."""
    name: str
    eqn_count: int
    f64_ops: int
    f64_by_prim: dict
    f64_widenings: int
    host_callbacks: int
    callback_prims: tuple
    donated_expected: int = 0
    donated_aliased: int = 0

    @classmethod
    def from_stats(cls, name: str, stats: JaxprStats, *,
                   donated: tuple[int, int] = (0, 0)) -> "PathReport":
        return cls(name=name, eqn_count=stats.eqn_count,
                   f64_ops=stats.f64_ops,
                   f64_by_prim=dict(sorted(stats.f64_by_prim.items())),
                   f64_widenings=stats.f64_widenings,
                   host_callbacks=stats.host_callbacks,
                   callback_prims=tuple(stats.callback_prims),
                   donated_expected=donated[0], donated_aliased=donated[1])

    def render(self) -> str:
        parts = [f"{self.name}: {self.f64_ops} f64 ops"]
        if self.f64_by_prim:
            top = ", ".join(f"{k}×{v}" for k, v in
                            sorted(self.f64_by_prim.items(),
                                   key=lambda kv: -kv[1])[:4])
            parts.append(f"({top})")
        parts.append(f"{self.f64_widenings} widenings")
        parts.append(f"{self.host_callbacks} callbacks")
        if self.donated_expected:
            parts.append(f"donation {self.donated_aliased}/"
                         f"{self.donated_expected}")
        return ", ".join(parts)


# -- fixtures -----------------------------------------------------------------

_CHUNK = 256        # small audit chunk: same trace structure, fast


def _fixture_timelines(n: int, domains: bool):
    from repro.core.timeline import RegionCost, synthesize
    costs = [RegionCost("mem", flops=1e10, hbm_bytes=5e10, invocations=4),
             RegionCost("alu", flops=6e11, hbm_bytes=2e9, invocations=4),
             RegionCost("opt", flops=2e10, hbm_bytes=4e10, invocations=1)]
    return [synthesize(costs, steps=8, seed=s, domains=domains)
            for s in range(n)]


def _spec_for(tl):
    from repro.core.sensors import RaplTraceSensor
    return RaplTraceSensor.make_spec(domains=tl.domain_names)


def _region_audit(domains: bool) -> tuple:
    """(jaxpr stats,) of the fused single-worker region run."""
    import jax
    import jax.numpy as jnp
    from jax.experimental import enable_x64

    from repro.core import device_pipeline as dp
    from repro.core.device_pipeline import DeviceTimeline

    (tl,) = _fixture_timelines(1, domains)
    spec = _spec_for(tl)
    dtl = DeviceTimeline.from_timelines([tl])
    with enable_x64():
        fn = dp._region_run_fn(_CHUNK, spec, dtl.num_regions, False,
                               dtl.grid_k)
        args = (*dtl.arrays(), jax.random.PRNGKey(0),
                jnp.float64(10e-3), jnp.float64(200e-6),
                jnp.float64(dtl.t_end), jnp.float64(0.0),
                jnp.float64(55.0), jnp.int32(2))
        jaxpr = jax.make_jaxpr(fn)(*args)
    return (audit_jaxpr(jaxpr),)


@_hot_path("device_pipeline/region_run/d1")
def _region_d1() -> PathReport:
    (stats,) = _region_audit(domains=False)
    return PathReport.from_stats("device_pipeline/region_run/d1", stats)


@_hot_path("device_pipeline/region_run/d3")
def _region_d3() -> PathReport:
    (stats,) = _region_audit(domains=True)
    return PathReport.from_stats("device_pipeline/region_run/d3", stats)


def _combo_audit(domains: bool) -> tuple:
    """(stats, donation) of the fused multi-worker combo chunk step.

    Mirrors ``run_combo_pipeline``'s setup (W=2 workers, minimum table)
    and audits the steady-state step — including that all 5 carry
    leaves donate through to the step's carry output.
    """
    import jax
    import jax.numpy as jnp
    from jax.experimental import enable_x64

    from repro.core import device_pipeline as dp
    from repro.core.device_pipeline import DeviceTimeline
    from repro.core.streaming import CombinationInterner

    tls = _fixture_timelines(2, domains)
    spec = _spec_for(tls[0])
    dtl = DeviceTimeline.from_timelines(tls)
    pack = dp._pack_spec(dtl.num_regions, dtl.num_workers)
    n_chan = dp.num_channels(dtl.num_domains)
    cap = dp._TABLE_MIN
    with enable_x64():
        step = dp._combo_step_fn(_CHUNK, spec, dtl.grid_k, pack)
        table, table_ids, n_rows = dp._build_table(
            CombinationInterner(), cap, dtl.num_workers, pack)
        stat_shape = (cap,) if n_chan == 1 else (cap, n_chan)
        carry = (jnp.zeros(cap, jnp.int64),
                 jnp.zeros(stat_shape, jnp.float64),
                 jnp.zeros(stat_shape, jnp.float64),
                 jnp.zeros((), jnp.int64),
                 -jnp.ones((), jnp.float64))
        args = (carry, table, table_ids, n_rows, *dtl.arrays(),
                jax.random.PRNGKey(0), jnp.int32(0),
                jnp.float64(10e-3), jnp.float64(200e-6),
                jnp.float64(dtl.t_end))
        jaxpr = jax.make_jaxpr(step)(*args)
        donated = donation_of_jitted(step, *args,
                                     expected=len(jax.tree.leaves(carry)))
    return audit_jaxpr(jaxpr), donated


@_hot_path("device_pipeline/combo_step/d1")
def _combo_d1() -> PathReport:
    stats, donated = _combo_audit(domains=False)
    return PathReport.from_stats("device_pipeline/combo_step/d1", stats,
                                 donated=donated)


@_hot_path("device_pipeline/combo_step/d3")
def _combo_d3() -> PathReport:
    stats, donated = _combo_audit(domains=True)
    return PathReport.from_stats("device_pipeline/combo_step/d3", stats,
                                 donated=donated)


def _fold_audit(domains: bool) -> tuple:
    """(stats, donation) of the miss-path admit-or-fold scatter.

    ``_combo_fold`` is the host-assisted half of every miss chunk: the
    recomputed per-sample channel powers scatter into the donated carry
    at host-resolved combination ids (padded with the out-of-bounds cap
    index). Bounded runs (``max_combinations``) take this path for all
    folded-tail traffic, so it is steady-state there — donation of the
    5 carry leaves must alias or peak memory doubles per miss chunk.
    """
    import jax
    import jax.numpy as jnp
    from jax.experimental import enable_x64

    from repro.core import device_pipeline as dp

    n_chan = dp.num_channels(3 if domains else 1)
    cap = dp._TABLE_MIN
    with enable_x64():
        stat_shape = (cap,) if n_chan == 1 else (cap, n_chan)
        carry = (jnp.zeros(cap, jnp.int64),
                 jnp.zeros(stat_shape, jnp.float64),
                 jnp.zeros(stat_shape, jnp.float64),
                 jnp.zeros((), jnp.int64),
                 -jnp.ones((), jnp.float64))
        pows = (jnp.zeros(_CHUNK, jnp.float64) if n_chan == 1
                else jnp.zeros((n_chan, _CHUNK), jnp.float64))
        args = (carry, jnp.full(_CHUNK, cap, jnp.int64), pows,
                jnp.zeros(_CHUNK, jnp.bool_))
        jaxpr = jax.make_jaxpr(dp._combo_fold)(*args)
        donated = donation_of_jitted(dp._combo_fold_jit, *args,
                                     expected=len(jax.tree.leaves(carry)))
    return audit_jaxpr(jaxpr), donated


@_hot_path("device_pipeline/combo_fold/d1")
def _fold_d1() -> PathReport:
    stats, donated = _fold_audit(domains=False)
    return PathReport.from_stats("device_pipeline/combo_fold/d1", stats,
                                 donated=donated)


@_hot_path("device_pipeline/combo_fold/d3")
def _fold_d3() -> PathReport:
    stats, donated = _fold_audit(domains=True)
    return PathReport.from_stats("device_pipeline/combo_fold/d3", stats,
                                 donated=donated)


# serve decode, one audit per KV-cache family (shape-only: params and
# cache come from jax.eval_shape, nothing is materialized).
_CACHE_FAMILIES = {
    "dense": "qwen3-1.7b",
    "moe": "qwen3-moe-30b-a3b",
    "ssm": "xlstm-125m",
    "hybrid": "zamba2-1.2b",
}


def _decode_audit(cfg_name: str) -> JaxprStats:
    import jax
    import jax.numpy as jnp

    from repro.configs.registry import get_config
    from repro.models import model as M

    cfg = get_config(cfg_name).reduced()
    B, T = 2, 16
    params = jax.eval_shape(
        lambda k: M.init_params(k, cfg), jax.random.PRNGKey(0))
    cache = jax.eval_shape(
        lambda: M.init_cache(cfg, B, T, dtype=jnp.bfloat16))
    tokens = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    cur_len = jax.ShapeDtypeStruct((B,), jnp.int32)
    mask = jax.ShapeDtypeStruct((B,), jnp.bool_)

    def decode(p, t, c, l, m):
        return M.decode_step(p, cfg, t, c, l, write_mask=m)

    jaxpr = jax.make_jaxpr(decode)(params, tokens, cache, cur_len, mask)
    return audit_jaxpr(jaxpr)


def _make_decode_path(family: str, cfg_name: str):
    @_hot_path(f"serve/decode/{family}")
    def _build() -> PathReport:
        return PathReport.from_stats(f"serve/decode/{family}",
                                     _decode_audit(cfg_name))
    return _build


for _family, _cfg in _CACHE_FAMILIES.items():
    _make_decode_path(_family, _cfg)


def _spec_audit(cfg_name: str, which: str) -> JaxprStats:
    """Self-speculative serving steps: the windowed draft (single token,
    StreamingLLM mask) and the multi-position verify. Shape-only, like
    the decode audit; L=4 matches the benchmark's headline cell. Neither
    step donates its cache (the window-start buffers are the rollback
    checkpoint — see ``serve.engine._jitted_spec_fns``), so their budget
    rows pin donation at 0/0, same as serve/decode."""
    import jax
    import jax.numpy as jnp

    from repro.configs.registry import get_config
    from repro.models import model as M

    cfg = get_config(cfg_name).reduced()
    B, T, L = 2, 16, 4
    params = jax.eval_shape(
        lambda k: M.init_params(k, cfg), jax.random.PRNGKey(0))
    cache = jax.eval_shape(
        lambda: M.init_cache(cfg, B, T, dtype=jnp.bfloat16))
    cur_len = jax.ShapeDtypeStruct((B,), jnp.int32)
    mask = jax.ShapeDtypeStruct((B,), jnp.bool_)

    if which == "draft":
        tokens = jax.ShapeDtypeStruct((B, 1), jnp.int32)

        def fn(p, t, c, l, m):
            return M.decode_step(p, cfg, t, c, l, write_mask=m,
                                 window=8, sinks=2)
    else:
        tokens = jax.ShapeDtypeStruct((B, L), jnp.int32)

        def fn(p, t, c, l, m):
            return M.decode_verify(p, cfg, t, c, l, write_mask=m)

    jaxpr = jax.make_jaxpr(fn)(params, tokens, cache, cur_len, mask)
    return audit_jaxpr(jaxpr)


def _make_spec_path(which: str, family: str, cfg_name: str):
    @_hot_path(f"serve/{which}/{family}")
    def _build() -> PathReport:
        return PathReport.from_stats(f"serve/{which}/{family}",
                                     _spec_audit(cfg_name, which))
    return _build


for _which in ("draft", "verify"):
    for _family, _cfg in _CACHE_FAMILIES.items():
        _make_spec_path(_which, _family, _cfg)


def _collective_audit(kind: str) -> JaxprStats:
    """Trace the shard_map'd exchange collective on a 1-host mesh."""
    import jax
    import jax.numpy as jnp
    from functools import partial
    from jax.experimental import enable_x64
    from jax.sharding import PartitionSpec as P

    from repro.compat import shard_map
    from repro.core import exchange
    from repro.launch.mesh import make_exchange_mesh

    axis = "hosts"
    mesh = make_exchange_mesh(1, axis=axis)
    smap = partial(shard_map, mesh=mesh, in_specs=P(axis), out_specs=P(),
                   check_vma=False)
    cap, chan, width = 8, 3, 2
    with enable_x64():
        i64 = lambda *s: jax.ShapeDtypeStruct(s, jnp.int64)
        f64 = lambda *s: jax.ShapeDtypeStruct(s, jnp.float64)
        if kind == "region":
            fn = smap(exchange.region_allreduce_fn(axis))
            jaxpr = jax.make_jaxpr(fn)(
                i64(1, cap), f64(1, cap, chan), f64(1, cap, chan))
        else:
            fn = smap(exchange.combo_allgather_fn(axis))
            jaxpr = jax.make_jaxpr(fn)(
                i64(1, cap, width), i64(1, cap), f64(1, cap, chan),
                f64(1, cap, chan), i64(1, 1))
    return audit_jaxpr(jaxpr)


@_hot_path("exchange/collective/region_allreduce")
def _collective_region() -> PathReport:
    return PathReport.from_stats("exchange/collective/region_allreduce",
                                 _collective_audit("region"))


@_hot_path("exchange/collective/combo_allgather")
def _collective_combo() -> PathReport:
    return PathReport.from_stats("exchange/collective/combo_allgather",
                                 _collective_audit("combo"))


def audit_hot_paths(names: Sequence[str] | None = None
                    ) -> list[PathReport]:
    """Trace + audit the registered hot paths (all by default)."""
    if names is None:
        names = list(HOT_PATH_BUILDERS)
    unknown = [n for n in names if n not in HOT_PATH_BUILDERS]
    if unknown:
        raise KeyError(f"unknown hot paths: {unknown}; "
                       f"known: {sorted(HOT_PATH_BUILDERS)}")
    return [HOT_PATH_BUILDERS[n]() for n in names]
