"""Layer 1 of the contract auditor: AST passes over the repo source.

Each pass encodes one *program-structure* invariant the test suite can
only spot-check (see ``ROADMAP.md`` → "Static contracts"): deterministic
sampling means no ambient clock or unseeded RNG in the modules that feed
the sample stream; the typed spill hierarchy only helps if the seams
actually raise it; swallowed exceptions in ``core``/``serve`` turn
partial failures into silent data loss; fault-site names must stay in
lock-step with ``faults.FAULT_SITES`` or chaos configs silently detach
from the code they target; and ``enable_x64`` leaking out of scoped
``with`` blocks flips the global dtype mode for everything else.

Passes run over a list of :class:`FileUnit` (parsed once, shared by all
passes), emit :class:`Finding` rows with ``file:line``, and honour
inline suppression pragmas::

    # audit: allow(<pass-name>) <reason>

A pragma suppresses findings of that pass on the pragma's own line and
on the first code line after any contiguous run of comments that
follows it (so a multi-line justification still attaches to the code it
excuses). Pre-existing violations that are not worth a pragma are
pinned by ``baseline.json`` instead (see :mod:`repro.analysis.baseline`)
— only *new* findings fail the check.
"""

from __future__ import annotations

import ast
import dataclasses
import fnmatch
import re
from typing import Iterable, Sequence

__all__ = [
    "Finding", "FileUnit", "ContractPass", "PASS_REGISTRY",
    "register_pass", "all_passes", "parse_unit", "run_passes",
    "DETERMINISM_CRITICAL_MODULES",
]


# -- findings -----------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Finding:
    """One contract violation at a source location.

    ``ident`` is the *stable* part of the identity — what was violated,
    not where on the page — so baselines survive unrelated edits that
    shift line numbers. Two identical violations in one file share an
    ident; the baseline stores a count per key.
    """
    pass_name: str
    path: str          # repo-relative, e.g. "src/repro/core/exchange.py"
    line: int
    message: str
    ident: str

    @property
    def key(self) -> str:
        return f"{self.pass_name}:{self.path}:{self.ident}"

    def render(self) -> str:
        return f"{self.path}:{self.line}: [{self.pass_name}] {self.message}"


# -- file units + suppression pragmas -----------------------------------------

_PRAGMA_RE = re.compile(r"#\s*audit:\s*allow\(([A-Za-z0-9_-]+)\)")
_COMMENT_OR_BLANK_RE = re.compile(r"^\s*(#.*)?$")


def _suppressed_lines(source: str) -> dict[str, set[int]]:
    """pass-name -> set of 1-based line numbers its pragmas cover."""
    lines = source.splitlines()
    out: dict[str, set[int]] = {}
    for i, text in enumerate(lines):
        m = _PRAGMA_RE.search(text)
        if not m:
            continue
        covered = out.setdefault(m.group(1), set())
        covered.add(i + 1)
        # Extend through the comment block to the first code line, so a
        # justification spanning several comment lines still lands.
        j = i + 1
        while j < len(lines) and _COMMENT_OR_BLANK_RE.match(lines[j]):
            covered.add(j + 1)
            j += 1
        if j < len(lines):
            covered.add(j + 1)
    return out


@dataclasses.dataclass
class FileUnit:
    """One parsed source file, shared by every pass."""
    path: str          # repo-relative display path
    modpath: str       # path relative to the scan root (pass includes)
    source: str
    tree: ast.AST
    suppressed: dict[str, set[int]]


def parse_unit(path: str, modpath: str, source: str) -> FileUnit:
    return FileUnit(path=path, modpath=modpath, source=source,
                    tree=ast.parse(source, filename=path),
                    suppressed=_suppressed_lines(source))


# -- shared AST helpers -------------------------------------------------------

def _dotted(node: ast.AST) -> str | None:
    """``a.b.c`` for an Attribute/Name chain, else None."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if not isinstance(node, ast.Name):
        return None
    parts.append(node.id)
    return ".".join(reversed(parts))


def _import_aliases(tree: ast.AST) -> dict[str, str]:
    """Local name -> canonical dotted origin, from top-level-ish imports.

    ``import time as t`` -> ``{"t": "time"}``; ``from datetime import
    datetime`` -> ``{"datetime": "datetime.datetime"}``. Relative
    imports are skipped (they can't be stdlib clocks/RNGs).
    """
    aliases: dict[str, str] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                if a.asname:
                    aliases[a.asname] = a.name
                else:
                    head = a.name.split(".")[0]
                    aliases[head] = head
        elif isinstance(node, ast.ImportFrom) and node.level == 0 \
                and node.module:
            for a in node.names:
                if a.name == "*":
                    continue
                aliases[a.asname or a.name] = f"{node.module}.{a.name}"
    return aliases


def _canonical(dotted: str, aliases: dict[str, str]) -> str:
    head, _, rest = dotted.partition(".")
    head = aliases.get(head, head)
    if head == "numpy" or head.startswith("numpy."):
        head = "np" + head[len("numpy"):]
    return f"{head}.{rest}" if rest else head


# -- pass base + registry -----------------------------------------------------

class ContractPass:
    """One invariant. Subclasses set ``name``/``description``/``include``
    and implement :meth:`visit_file`; cross-file passes accumulate state
    there and emit from :meth:`finalize`."""

    name: str = ""
    description: str = ""
    include: tuple[str, ...] = ("*",)

    def applies_to(self, modpath: str) -> bool:
        return any(fnmatch.fnmatch(modpath, pat) for pat in self.include)

    def visit_file(self, unit: FileUnit) -> Iterable[Finding]:
        raise NotImplementedError

    def finalize(self) -> Iterable[Finding]:
        return ()


PASS_REGISTRY: dict[str, type[ContractPass]] = {}


def register_pass(cls: type[ContractPass]) -> type[ContractPass]:
    if not cls.name:
        raise ValueError(f"{cls.__name__} has no pass name")
    if cls.name in PASS_REGISTRY:
        raise ValueError(f"duplicate pass name {cls.name!r}")
    PASS_REGISTRY[cls.name] = cls
    return cls


def all_passes() -> list[ContractPass]:
    return [cls() for cls in PASS_REGISTRY.values()]


def run_passes(units: Sequence[FileUnit],
               passes: Sequence[ContractPass] | None = None
               ) -> list[Finding]:
    """Run every pass over every applicable unit; apply suppressions."""
    if passes is None:
        passes = all_passes()
    by_path = {u.path: u for u in units}
    findings: list[Finding] = []
    for p in passes:
        raw: list[Finding] = []
        for unit in units:
            if p.applies_to(unit.modpath):
                raw.extend(p.visit_file(unit))
        raw.extend(p.finalize())
        for f in raw:
            unit = by_path.get(f.path)
            if unit is not None and f.line in unit.suppressed.get(
                    f.pass_name, ()):
                continue
            findings.append(f)
    findings.sort(key=lambda f: (f.path, f.line, f.pass_name))
    return findings


# -- pass (a): no wall-clock / unseeded randomness ----------------------------

DETERMINISM_CRITICAL_MODULES = (
    "core/device_pipeline.py",
    "core/faults.py",
    "core/exchange.py",
    # Heavy-hitters eviction order and hash-range ownership must be
    # pure functions of the arrival stream: a wall-clock (or unseeded
    # random) tiebreak in the sketch would make bounded-state merges
    # and kill/restore replays diverge run-to-run.
    "core/sketch.py",
    "kernels/sample_attr/*",
    # Serving-seam replayability: deadlines, budgets, admission order
    # and snapshot/restore are all keyed on the engine step clock — a
    # wall-clock read here would break bit-exact kill/restore.
    "serve/scheduler.py",
    "serve/recovery.py",
)

_WALLCLOCK = frozenset({
    "time.time", "time.time_ns",
    "time.monotonic", "time.monotonic_ns",
    "time.perf_counter", "time.perf_counter_ns",
    "time.process_time", "time.process_time_ns",
    "time.clock_gettime", "time.clock_gettime_ns",
    "datetime.datetime.now", "datetime.datetime.utcnow",
    "datetime.datetime.today", "datetime.date.today",
})

_SEEDED_NP_CTORS = frozenset({
    "default_rng", "Generator", "RandomState", "SeedSequence",
})


@register_pass
class NoWallclockPass(ContractPass):
    """Determinism-critical modules may not read the ambient clock or an
    unseeded RNG: ALEA's sample clock is counter-keyed precisely so runs
    replay bit-exactly; one ``time.time()`` in the sample path breaks
    the replay *and* the numpy reference oracle. ``time.sleep`` is fine
    (it spends wall time, it doesn't sample it)."""

    name = "no-wallclock"
    description = ("no wall-clock reads or unseeded RNG in "
                   "determinism-critical modules")
    include = DETERMINISM_CRITICAL_MODULES

    def visit_file(self, unit: FileUnit) -> Iterable[Finding]:
        aliases = _import_aliases(unit.tree)
        for node in ast.walk(unit.tree):
            if not isinstance(node, ast.Call):
                continue
            dotted = _dotted(node.func)
            if dotted is None:
                continue
            canon = _canonical(dotted, aliases)
            msg = None
            if canon in _WALLCLOCK:
                msg = f"wall-clock read `{dotted}()`"
            elif canon.startswith("random.") or canon == "random":
                msg = f"global-state stdlib RNG `{dotted}()`"
            elif canon.startswith("np.random."):
                last = canon.rsplit(".", 1)[1]
                if last not in _SEEDED_NP_CTORS:
                    msg = f"unseeded numpy RNG `{dotted}()`"
                elif not node.args:
                    msg = (f"`{dotted}()` without an explicit seed "
                           f"(entropy from the OS)")
            if msg is not None:
                yield Finding(self.name, unit.path, node.lineno,
                              msg + " in a determinism-critical module",
                              ident=canon)


# -- pass (b): typed spill errors at durable seams ----------------------------

_OS_ERROR_BUILTINS = frozenset({
    "IOError", "OSError", "EnvironmentError", "FileNotFoundError",
    "FileExistsError", "PermissionError", "IsADirectoryError",
    "NotADirectoryError", "InterruptedError", "BlockingIOError",
    "TimeoutError",
})


@register_pass
class TypedSpillErrorsPass(ContractPass):
    """The spill/ckpt seams must raise the ``SpillError`` hierarchy, not
    builtin OSError family types: tolerance code dispatches on the typed
    classes (corrupt vs torn vs stale vs missing), and a builtin raise
    is invisible to that dispatch — it reads as an environment failure
    rather than a classified artifact state."""

    name = "typed-spill-errors"
    description = "durable-seam raises use the SpillError hierarchy"
    include = ("core/exchange.py", "checkpoint/ckpt.py")

    def visit_file(self, unit: FileUnit) -> Iterable[Finding]:
        for node in ast.walk(unit.tree):
            if not isinstance(node, ast.Raise) or node.exc is None:
                continue
            exc = node.exc
            name = None
            if isinstance(exc, ast.Call):
                name = _dotted(exc.func)
            elif isinstance(exc, (ast.Name, ast.Attribute)):
                name = _dotted(exc)
            if name in _OS_ERROR_BUILTINS:
                yield Finding(
                    self.name, unit.path, node.lineno,
                    f"raises builtin `{name}` at a durable seam — use a "
                    f"typed SpillError subclass (faults.py)",
                    ident=name)


# -- pass (c): no silent exception swallowing ---------------------------------

_LOG_HEADS = frozenset({"print", "logging", "logger", "log", "warnings"})


def _is_silent_stmt(stmt: ast.stmt) -> bool:
    if isinstance(stmt, (ast.Pass, ast.Continue, ast.Break)):
        return True
    if isinstance(stmt, ast.Return):
        return stmt.value is None or isinstance(stmt.value, ast.Constant)
    if isinstance(stmt, ast.Expr):
        if isinstance(stmt.value, ast.Constant):
            return True      # stray docstring
        if isinstance(stmt.value, ast.Call):
            dotted = _dotted(stmt.value.func)
            if dotted is not None:
                return dotted.split(".")[0] in _LOG_HEADS
    return False


@register_pass
class NoSilentExceptPass(ContractPass):
    """``core``/``serve`` handlers may not swallow exceptions without
    leaving evidence (a counter, a re-raise, a recorded report). A
    quorum gather that drops a host *records* it in provenance; a bare
    ``except: pass`` makes the same loss unobservable and the coverage
    report a lie. Deliberate absence-means-empty handlers carry an
    ``# audit: allow(no-silent-except) <reason>`` pragma."""

    name = "no-silent-except"
    description = "no silent exception swallowing in core/ and serve/"
    include = ("core/*", "serve/*")

    def visit_file(self, unit: FileUnit) -> Iterable[Finding]:
        for node in ast.walk(unit.tree):
            if not isinstance(node, ast.ExceptHandler):
                continue
            if not all(_is_silent_stmt(s) for s in node.body):
                continue
            typ = ast.unparse(node.type) if node.type is not None \
                else "<bare>"
            yield Finding(
                self.name, unit.path, node.lineno,
                f"`except {typ}` swallows the error without evidence "
                f"(counter, re-raise, or provenance record)",
                ident=typ)


# -- pass (d): fault-site hygiene ---------------------------------------------

@register_pass
class FaultSiteHygienePass(ContractPass):
    """Every ``declare_site(...)`` literal must be registered in
    ``faults.FAULT_SITES`` and declared by exactly one seam; every
    registered site must actually be declared somewhere. Drift here
    decouples chaos configs from the seams they think they target."""

    name = "fault-site-hygiene"
    description = "declare_site literals registered, unique, exhaustive"
    include = ("*",)

    _REGISTRY_FILE = "core/faults.py"

    def __init__(self):
        self._registry: tuple[str, ...] | None = None
        self._registry_loc: tuple[str, int] | None = None
        self._declared: list[tuple[str, str, int]] = []   # (name, path, line)

    def visit_file(self, unit: FileUnit) -> Iterable[Finding]:
        if unit.modpath == self._REGISTRY_FILE:
            yield from self._read_registry(unit)
        for node in ast.walk(unit.tree):
            if not isinstance(node, ast.Call):
                continue
            dotted = _dotted(node.func)
            if dotted is None or dotted.split(".")[-1] != "declare_site":
                continue
            if unit.modpath == self._REGISTRY_FILE:
                continue          # the definition, not a declaration
            if not node.args or not (
                    isinstance(node.args[0], ast.Constant)
                    and isinstance(node.args[0].value, str)):
                yield Finding(
                    self.name, unit.path, node.lineno,
                    "fault site name must be a string literal (chaos "
                    "configs grep for it)",
                    ident="<non-literal>")
                continue
            self._declared.append(
                (node.args[0].value, unit.path, node.lineno))

    def _read_registry(self, unit: FileUnit) -> Iterable[Finding]:
        for node in ast.walk(unit.tree):
            targets = []
            if isinstance(node, ast.Assign):
                targets = node.targets
                value = node.value
            elif isinstance(node, ast.AnnAssign) and node.value is not None:
                targets = [node.target]
                value = node.value
            else:
                continue
            if not any(isinstance(t, ast.Name) and t.id == "FAULT_SITES"
                       for t in targets):
                continue
            if not (isinstance(value, ast.Tuple) and all(
                    isinstance(e, ast.Constant)
                    and isinstance(e.value, str) for e in value.elts)):
                yield Finding(
                    self.name, unit.path, node.lineno,
                    "FAULT_SITES must be a tuple of string literals",
                    ident="<registry-shape>")
                return
            names = tuple(e.value for e in value.elts)
            dupes = {n for n in names if names.count(n) > 1}
            for d in sorted(dupes):
                yield Finding(
                    self.name, unit.path, node.lineno,
                    f"site {d!r} registered more than once in FAULT_SITES",
                    ident=f"registry-dup:{d}")
            self._registry = names
            self._registry_loc = (unit.path, node.lineno)
            return

    def finalize(self) -> Iterable[Finding]:
        seen: dict[str, tuple[str, int]] = {}
        for name, path, line in self._declared:
            if self._registry is not None and name not in self._registry:
                yield Finding(
                    self.name, path, line,
                    f"fault site {name!r} is not in faults.FAULT_SITES",
                    ident=f"unregistered:{name}")
            if name in seen:
                p0, l0 = seen[name]
                yield Finding(
                    self.name, path, line,
                    f"fault site {name!r} already declared at {p0}:{l0}",
                    ident=f"duplicate:{name}")
            else:
                seen[name] = (path, line)
        if self._registry is not None and self._registry_loc is not None:
            declared = {n for n, _, _ in self._declared}
            path, line = self._registry_loc
            for name in self._registry:
                if name not in declared:
                    yield Finding(
                        self.name, path, line,
                        f"registered fault site {name!r} is never "
                        f"declared by any seam",
                        ident=f"undeclared:{name}")


# -- pass (e): enable_x64 scoping ---------------------------------------------

@register_pass
class X64ScopingPass(ContractPass):
    """x64 may only be entered through the scoped ``with enable_x64():``
    helper. A bare ``enable_x64()`` call (context manager constructed
    but never entered/exited) or a global
    ``jax.config.update("jax_enable_x64", ...)`` flips the process-wide
    dtype mode — re-tracing *every* cached jit and silently widening
    the serve path, whose budget is zero f64 ops."""

    name = "x64-scoping"
    description = "enable_x64 only as a `with` context; no global flag"
    include = ("*",)

    def visit_file(self, unit: FileUnit) -> Iterable[Finding]:
        aliases = _import_aliases(unit.tree)
        with_calls: set[int] = set()
        for node in ast.walk(unit.tree):
            if isinstance(node, (ast.With, ast.AsyncWith)):
                for item in node.items:
                    with_calls.add(id(item.context_expr))
        for node in ast.walk(unit.tree):
            if not isinstance(node, ast.Call):
                continue
            dotted = _dotted(node.func)
            if dotted is None:
                continue
            if dotted.split(".")[-1] == "enable_x64":
                if id(node) not in with_calls:
                    yield Finding(
                        self.name, unit.path, node.lineno,
                        "`enable_x64()` outside a `with` statement — the "
                        "scope is never entered (or never exited)",
                        ident="enable_x64-unscoped")
                continue
            canon = _canonical(dotted, aliases)
            if canon.endswith("config.update") and node.args and \
                    isinstance(node.args[0], ast.Constant) and \
                    node.args[0].value == "jax_enable_x64":
                yield Finding(
                    self.name, unit.path, node.lineno,
                    "global `config.update(\"jax_enable_x64\", ...)` — "
                    "use the scoped `with enable_x64():` helper",
                    ident="jax_enable_x64-global")
