"""jit'd public wrappers for the flash-attention kernel."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.flash_attention import flash_attention_fwd


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_kv",
                                             "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, block_q: int = 512,
                    block_kv: int = 512, interpret: bool | None = None):
    """q: [B,H,S,dh]; k/v: [B,H,S,dh] (KV pre-repeated to H for GQA)."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    B, H, S, dh = q.shape
    out = flash_attention_fwd(
        q.reshape(B * H, S, dh), k.reshape(B * H, -1, dh),
        v.reshape(B * H, -1, dh), causal=causal,
        block_q=block_q, block_kv=block_kv, interpret=bool(interpret))
    return out.reshape(B, H, S, dh)
