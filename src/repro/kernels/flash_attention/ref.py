"""Pure-jnp oracle for flash attention (MHA form, optional causal)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def attention_ref(q, k, v, *, causal: bool = True):
    """q/k/v: [B, H, S, dh] → [B, H, S, dh]. fp32 softmax."""
    dh = q.shape[-1]
    scores = jnp.einsum("bhqd,bhtd->bhqt", q, k,
                        preferred_element_type=jnp.float32) * dh ** -0.5
    if causal:
        S, T = q.shape[2], k.shape[2]
        mask = jnp.arange(T)[None, :] <= jnp.arange(S)[:, None]
        scores = jnp.where(mask[None, None], scores, -2.0e38)
    probs = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhqt,bhtd->bhqd", probs.astype(v.dtype), v)
