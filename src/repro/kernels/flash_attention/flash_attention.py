"""Pallas TPU flash attention (forward), GQA-ready via pre-repeated heads.

Canonical TPU tiling: grid = (B·H, n_q_blocks, n_kv_blocks) with the KV
dimension innermost. Per (bh, qi) the online-softmax state (m, l, acc)
lives in VMEM scratch that persists across the kv grid steps; the output
block is written on the last kv step. Causal masking skips fully-masked
KV blocks via ``pl.when`` (the block-sparsity that gives flash its ~2×
causal win on TPU, where there are no per-warp early exits).

Block sizes default to (q=512, kv=512): VMEM working set ≈
q·dh·2 + kv·dh·4 + q·kv·4 (fp32 scores) + acc q·dh·4 ≈ 2.6 MB at dh=128 —
comfortably within ~16 MB v5e VMEM and MXU-aligned (multiples of 128).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -2.0e38


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
               scale: float, causal: bool, block_q: int, block_kv: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    n_kv = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi * block_q
    k_start = ki * block_kv
    # Causal: skip KV blocks strictly above the diagonal.
    run = (k_start <= q_start + block_q - 1) if causal else True

    @pl.when(run)
    def _body():
        q = q_ref[0].astype(jnp.float32)              # [bq, dh]
        k = k_ref[0].astype(jnp.float32)              # [bkv, dh]
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if causal:
            rows = q_start + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_kv), 0)
            cols = k_start + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_kv), 1)
            s = jnp.where(cols <= rows, s, NEG_INF)
        m_prev = m_scr[...]
        l_prev = l_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = alpha * l_prev + jnp.sum(p, axis=1)
        acc_scr[...] = (acc_scr[...] * alpha[:, None]
                        + jax.lax.dot_general(
                            p, v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32))
        m_scr[...] = m_new

    @pl.when(ki == n_kv - 1)
    def _finish():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention_fwd(q, k, v, *, causal: bool = True,
                        block_q: int = 512, block_kv: int = 512,
                        interpret: bool = False):
    """q/k/v: [BH, S, dh] (heads pre-flattened/repeated) → [BH, S, dh]."""
    BH, S, dh = q.shape
    T = k.shape[1]
    block_q = min(block_q, S)
    block_kv = min(block_kv, T)
    assert S % block_q == 0 and T % block_kv == 0, (S, T, block_q, block_kv)
    grid = (BH, S // block_q, T // block_kv)
    scale = dh ** -0.5

    kernel = functools.partial(_fa_kernel, scale=scale, causal=causal,
                               block_q=block_q, block_kv=block_kv)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, dh), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, block_kv, dh), lambda bh, qi, ki: (bh, ki, 0)),
            pl.BlockSpec((1, block_kv, dh), lambda bh, qi, ki: (bh, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, dh),
                               lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),      # m (running max)
            pltpu.VMEM((block_q,), jnp.float32),      # l (running denom)
            pltpu.VMEM((block_q, dh), jnp.float32),   # acc
        ],
        interpret=interpret,
    )(q, k, v)
