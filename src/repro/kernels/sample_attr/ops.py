"""jit'd public wrappers for the sample-attribution kernel.

``sample_attr(ids, powers, R)`` dispatches to the Pallas kernel on TPU and
to interpret mode elsewhere; ``as_aggregate_fn`` adapts it to the
estimator's pluggable aggregation interface.

Streaming path: ``chunked_aggregate_fn`` returns an AggregateFn whose
underlying ``pallas_call`` jit is cached by (block_n, block_r, num_regions)
via :func:`sample_attr_chunk` — short chunks are topped up in a
preallocated scratch buffer (two small copies, no per-chunk allocation) so
every chunk of a stream hits the same compiled executable (one trace per
configuration, not one per chunk length).

Fused device pipeline: :func:`make_carry_update` is the reduction seam of
:mod:`repro.core.device_pipeline` — a traceable function folding one
masked fixed-shape chunk *into* the pipeline's device-resident
(counts, Σpow, Σpow²) carry. On TPU it routes through the Pallas one-hot
matmul kernel (mask → ``-1`` ids, which match no one-hot column); on CPU
it lowers to the equivalent scatter-add (compiled XLA, not interpret
mode) with masked lanes dropped via an out-of-bounds index.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.sample_attr.sample_attr import (DEFAULT_BLOCK_N,
                                                   sample_attr_pallas)


@functools.partial(jax.jit, static_argnums=(2, 3))
def sample_attr(region_ids, powers, num_regions: int,
                interpret: bool | None = None):
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return sample_attr_pallas(region_ids.astype(jnp.int32),
                              powers.astype(jnp.float32), num_regions,
                              interpret=interpret)


def as_aggregate_fn(interpret: bool | None = None):
    """Adapter matching estimator.AggregateFn (returns numpy)."""
    def agg(region_ids, powers, num_regions):
        c, s, sq = sample_attr(jnp.asarray(region_ids), jnp.asarray(powers),
                               int(num_regions), interpret)
        return (np.asarray(c).astype(np.int64), np.asarray(s, np.float64),
                np.asarray(sq, np.float64))
    return agg


@functools.lru_cache(maxsize=None)
def sample_attr_chunk(block_n: int, block_r: int | None, num_regions: int,
                      interpret: bool):
    """Compiled fixed-shape chunk reducer, cached by configuration.

    Returns a jitted ``fn(ids[capacity] i32, powers[capacity] f32) ->
    (counts, psum, psumsq)``; the pallas_call is built once per
    (block_n, block_r, num_regions, interpret) and the jit cache is keyed
    on the fixed chunk shape, so a streaming aggregator calling it per
    block never re-traces.
    """
    @jax.jit
    def run(region_ids, powers):
        return sample_attr_pallas(region_ids.astype(jnp.int32),
                                  powers.astype(jnp.float32), num_regions,
                                  block_n=block_n, block_r=block_r,
                                  interpret=interpret)
    return run


def chunked_aggregate_fn(chunk_capacity: int = 16 * DEFAULT_BLOCK_N, *,
                         block_n: int = DEFAULT_BLOCK_N,
                         block_r: int | None = None,
                         interpret: bool | None = None):
    """AggregateFn for ``StreamingAggregator``: fixed-capacity Pallas chunks.

    Short chunks (< ``chunk_capacity`` samples) are topped up in a
    preallocated scratch buffer with region_id = -1 (zero one-hot rows),
    so every update reuses one compiled kernel without allocating — two
    small copies into the scratch instead of four fresh arrays per chunk.
    Oversized chunks are folded in capacity-sized slices. The returned
    closure owns its scratch, so it is not safe to share one aggregate fn
    across threads (each ``StreamingAggregator`` should get its own).
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    scratch_ids = np.full(chunk_capacity, -1, np.int32)
    scratch_pw = np.zeros(chunk_capacity, np.float32)

    def agg(region_ids, powers, num_regions):
        # Quantize the region axis to the next power of two (≥64) so a
        # growing region space (streaming combination interning) hits at
        # most O(log R) compiled kernels instead of one per distinct R.
        num_regions = int(num_regions)
        r_quant = max(64, 1 << (num_regions - 1).bit_length())
        fn = sample_attr_chunk(block_n, block_r, r_quant, bool(interpret))
        ids = np.asarray(region_ids, dtype=np.int32)
        pw = np.asarray(powers, dtype=np.float32)
        counts = np.zeros(num_regions, np.int64)
        psum = np.zeros(num_regions, np.float64)
        psumsq = np.zeros(num_regions, np.float64)
        for lo in range(0, len(ids), chunk_capacity):
            ids_c = ids[lo:lo + chunk_capacity]
            pw_c = pw[lo:lo + chunk_capacity]
            n_c = len(ids_c)
            if n_c < chunk_capacity:
                scratch_ids[:n_c] = ids_c
                scratch_ids[n_c:] = -1
                scratch_pw[:n_c] = pw_c
                scratch_pw[n_c:] = 0.0
                ids_c, pw_c = scratch_ids, scratch_pw
            c, s, sq = fn(ids_c, pw_c)
            # np.asarray blocks until the kernel has consumed its inputs,
            # so reusing the scratch on the next slice is safe.
            counts += np.asarray(c).astype(np.int64)[:num_regions]
            psum += np.asarray(s, np.float64)[:num_regions]
            psumsq += np.asarray(sq, np.float64)[:num_regions]
        return counts, psum, psumsq
    return agg


def make_carry_update(num_regions: int, *, use_pallas: bool | None = None,
                      block_n: int = DEFAULT_BLOCK_N,
                      block_r: int | None = None):
    """Traceable masked chunk→carry reduction for the fused device pipeline.

    Returns ``update(counts, psum, psumsq, ids, pows, valid)`` folding one
    fixed-shape chunk into the carry under a validity mask (lanes past the
    profiled horizon contribute nothing). Two carry layouts, dispatched
    on the carry rank at trace time:

    * scalar — ``psum``/``psumsq`` [R], ``pows`` [c]: the pre-rail
      reduction, kept graph-identical on purpose (D=1 bit-exactness;
      even value-equal graph variants reassociate under XLA fusion).
    * channels — ``psum``/``psumsq`` [R, C], ``pows`` [C, c]: one
      synchronized power reading per rail (+ total) per sample;
      ``counts`` stays [R] (every rail shares the sample clock).

    Carry dtypes are preserved — int64/float64 accumulation on CPU
    (under x64), the kernel's float32 per-chunk statistics added into
    the wider f64 carry on TPU.

    ``use_pallas`` defaults to backend dispatch: the Pallas one-hot matmul
    on TPU, an XLA scatter-add elsewhere (compiled, not interpret mode —
    interpret would put a Python loop back on the per-chunk path).
    """
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"

    if use_pallas:
        def update(counts, psum, psumsq, ids, pows, valid):
            ids_m = jnp.where(valid, ids, -1).astype(jnp.int32)
            if psum.ndim == 1:
                pw_m = jnp.where(valid, pows, 0.0).astype(jnp.float32)
                c, s, sq = sample_attr_pallas(ids_m, pw_m, num_regions,
                                              block_n=block_n,
                                              block_r=block_r,
                                              interpret=False)
                return (counts + c.astype(counts.dtype),
                        psum + s.astype(psum.dtype),
                        psumsq + sq.astype(psumsq.dtype))
            new_psum, new_psumsq = [], []
            c = None
            # One kernel launch per channel: the one-hot matmul reduces a
            # single power stream; rails are independent columns of the
            # same sample set (counts come from the first launch).
            for d in range(psum.shape[1]):
                pw_m = jnp.where(valid, pows[d], 0.0).astype(jnp.float32)
                cd, s, sq = sample_attr_pallas(
                    ids_m, pw_m, num_regions, block_n=block_n,
                    block_r=block_r, interpret=False)
                c = cd if c is None else c
                new_psum.append(s)
                new_psumsq.append(sq)
            return (counts + c.astype(counts.dtype),
                    psum + jnp.stack(new_psum, axis=1).astype(psum.dtype),
                    psumsq + jnp.stack(new_psumsq,
                                       axis=1).astype(psumsq.dtype))
        return update

    if num_regions <= 128:
        # Small region spaces: the same one-hot matmul the Pallas kernel
        # runs on the MXU, as one stacked [1 + 2C, c] @ [c, R] GEMM —
        # counts stay exact (integer-valued f64 sums), and XLA CPU
        # parallelizes dots where scatter is a serial loop.
        def update(counts, psum, psumsq, ids, pows, valid):
            ids_m = jnp.where(valid, ids, -1)
            onehot = (ids_m[:, None]
                      == jnp.arange(num_regions)[None, :]).astype(psum.dtype)
            if psum.ndim == 1:
                # Mask pw explicitly: the all-zero one-hot row alone
                # would turn a nonfinite masked-lane power into
                # 0·inf = NaN.
                pw = jnp.where(valid, pows, 0.0).astype(psum.dtype)
                stats = jnp.stack([valid.astype(psum.dtype), pw, pw * pw]) \
                    @ onehot
                return (counts + stats[0].astype(counts.dtype),
                        psum + stats[1], psumsq + stats[2])
            pw = jnp.where(valid[None, :], pows, 0.0).astype(psum.dtype)
            d = pw.shape[0]
            rows = jnp.concatenate(
                [valid.astype(psum.dtype)[None, :], pw, pw * pw])
            stats = rows @ onehot
            return (counts + stats[0].astype(counts.dtype),
                    psum + stats[1:1 + d].T, psumsq + stats[1 + d:].T)
        return update

    def update(counts, psum, psumsq, ids, pows, valid):
        # Invalid lanes scatter to index R, which is out of bounds for the
        # [R] carry and dropped — no branch, no extra dump slot to slice.
        idx = jnp.where(valid, ids, num_regions)
        pw = pows.astype(psum.dtype)
        counts = counts.at[idx].add(jnp.ones((), counts.dtype), mode="drop")
        if psum.ndim == 1:
            psum = psum.at[idx].add(pw, mode="drop")
            psumsq = psumsq.at[idx].add(pw * pw, mode="drop")
        else:
            psum = psum.at[idx].add(pw.T, mode="drop")
            psumsq = psumsq.at[idx].add((pw * pw).T, mode="drop")
        return counts, psum, psumsq
    return update
