"""jit'd public wrapper for the sample-attribution kernel.

``sample_attr(ids, powers, R)`` dispatches to the Pallas kernel on TPU and
to interpret mode elsewhere; ``as_aggregate_fn`` adapts it to the
estimator's pluggable aggregation interface.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.sample_attr.sample_attr import sample_attr_pallas


@functools.partial(jax.jit, static_argnums=(2, 3))
def sample_attr(region_ids, powers, num_regions: int,
                interpret: bool | None = None):
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return sample_attr_pallas(region_ids.astype(jnp.int32),
                              powers.astype(jnp.float32), num_regions,
                              interpret=interpret)


def as_aggregate_fn(interpret: bool | None = None):
    """Adapter matching estimator.AggregateFn (returns numpy)."""
    def agg(region_ids, powers, num_regions):
        c, s, sq = sample_attr(jnp.asarray(region_ids), jnp.asarray(powers),
                               int(num_regions), interpret)
        return (np.asarray(c).astype(np.int64), np.asarray(s, np.float64),
                np.asarray(sq, np.float64))
    return agg
