"""jit'd public wrappers for the sample-attribution kernel.

``sample_attr(ids, powers, R)`` dispatches to the Pallas kernel on TPU and
to interpret mode elsewhere; ``as_aggregate_fn`` adapts it to the
estimator's pluggable aggregation interface.

Streaming path: ``chunked_aggregate_fn`` returns an AggregateFn whose
underlying ``pallas_call`` jit is cached by (block_n, block_r, num_regions)
via :func:`sample_attr_chunk` — chunks are padded host-side to a fixed
capacity so every chunk of a stream hits the same compiled executable
(one trace per configuration, not one per chunk length).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.sample_attr.sample_attr import (DEFAULT_BLOCK_N,
                                                   sample_attr_pallas)


@functools.partial(jax.jit, static_argnums=(2, 3))
def sample_attr(region_ids, powers, num_regions: int,
                interpret: bool | None = None):
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return sample_attr_pallas(region_ids.astype(jnp.int32),
                              powers.astype(jnp.float32), num_regions,
                              interpret=interpret)


def as_aggregate_fn(interpret: bool | None = None):
    """Adapter matching estimator.AggregateFn (returns numpy)."""
    def agg(region_ids, powers, num_regions):
        c, s, sq = sample_attr(jnp.asarray(region_ids), jnp.asarray(powers),
                               int(num_regions), interpret)
        return (np.asarray(c).astype(np.int64), np.asarray(s, np.float64),
                np.asarray(sq, np.float64))
    return agg


@functools.lru_cache(maxsize=None)
def sample_attr_chunk(block_n: int, block_r: int | None, num_regions: int,
                      interpret: bool):
    """Compiled fixed-shape chunk reducer, cached by configuration.

    Returns a jitted ``fn(ids[capacity] i32, powers[capacity] f32) ->
    (counts, psum, psumsq)``; the pallas_call is built once per
    (block_n, block_r, num_regions, interpret) and the jit cache is keyed
    on the fixed chunk shape, so a streaming aggregator calling it per
    block never re-traces.
    """
    @jax.jit
    def run(region_ids, powers):
        return sample_attr_pallas(region_ids.astype(jnp.int32),
                                  powers.astype(jnp.float32), num_regions,
                                  block_n=block_n, block_r=block_r,
                                  interpret=interpret)
    return run


def chunked_aggregate_fn(chunk_capacity: int = 16 * DEFAULT_BLOCK_N, *,
                         block_n: int = DEFAULT_BLOCK_N,
                         block_r: int | None = None,
                         interpret: bool | None = None):
    """AggregateFn for ``StreamingAggregator``: fixed-capacity Pallas chunks.

    Chunks (≤ ``chunk_capacity`` samples) are padded host-side with
    region_id = -1 (zero one-hot rows) to the fixed capacity, so every
    update reuses one compiled kernel. Oversized chunks are folded in
    capacity-sized slices.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"

    def agg(region_ids, powers, num_regions):
        # Quantize the region axis to the next power of two (≥64) so a
        # growing region space (streaming combination interning) hits at
        # most O(log R) compiled kernels instead of one per distinct R.
        num_regions = int(num_regions)
        r_quant = max(64, 1 << (num_regions - 1).bit_length())
        fn = sample_attr_chunk(block_n, block_r, r_quant, bool(interpret))
        ids = np.asarray(region_ids, dtype=np.int32)
        pw = np.asarray(powers, dtype=np.float32)
        counts = np.zeros(num_regions, np.int64)
        psum = np.zeros(num_regions, np.float64)
        psumsq = np.zeros(num_regions, np.float64)
        for lo in range(0, len(ids), chunk_capacity):
            ids_c = ids[lo:lo + chunk_capacity]
            pw_c = pw[lo:lo + chunk_capacity]
            pad = chunk_capacity - len(ids_c)
            if pad:
                ids_c = np.concatenate([ids_c, np.full(pad, -1, np.int32)])
                pw_c = np.concatenate([pw_c, np.zeros(pad, np.float32)])
            c, s, sq = fn(ids_c, pw_c)
            counts += np.asarray(c).astype(np.int64)[:num_regions]
            psum += np.asarray(s, np.float64)[:num_regions]
            psumsq += np.asarray(sq, np.float64)[:num_regions]
        return counts, psum, psumsq
    return agg
