"""Pallas TPU kernel: ALEA sample-attribution reduction.

TPU adaptation of the tool's aggregation hot spot (billions of samples on
a fleet): instead of a scatter-add histogram (GPU-style atomics — no TPU
analogue), each sample block is turned into a one-hot matrix and the three
statistics become MXU matmuls:

    counts += 1ᵀ · onehot      psum += powᵀ · onehot      psumsq += (pow²)ᵀ · onehot

Grid: one dimension over sample blocks. The [R]-sized accumulators live in
the output blocks (same block every step → VMEM-resident); sample blocks
stream HBM→VMEM. Block size 1024 samples × R≤2048 regions keeps the
one-hot (1024×2048×4B = 8 MB) within VMEM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_N = 1024


def _kernel(ids_ref, pow_ref, counts_ref, psum_ref, psumsq_ref, *,
            num_regions: int):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        counts_ref[...] = jnp.zeros_like(counts_ref)
        psum_ref[...] = jnp.zeros_like(psum_ref)
        psumsq_ref[...] = jnp.zeros_like(psumsq_ref)

    ids = ids_ref[...]                                  # [bn] int32
    pw = pow_ref[...].astype(jnp.float32)               # [bn]
    # One-hot via broadcasted iota compare (2D iota: TPU-legal).
    iota = jax.lax.broadcasted_iota(jnp.int32, (ids.shape[0], num_regions), 1)
    onehot = (ids[:, None] == iota).astype(jnp.float32)  # [bn, R]
    # Padded samples carry region_id = -1 → all-zero one-hot rows.
    counts_ref[...] += jnp.sum(onehot, axis=0)
    psum_ref[...] += pw @ onehot
    psumsq_ref[...] += (pw * pw) @ onehot


def sample_attr_pallas(region_ids: jnp.ndarray, powers: jnp.ndarray,
                       num_regions: int, *, block_n: int = DEFAULT_BLOCK_N,
                       interpret: bool = False):
    """region_ids: [n] int32 (pad with -1); powers: [n] f32."""
    n = region_ids.shape[0]
    n_pad = (block_n - n % block_n) % block_n
    if n_pad:
        region_ids = jnp.concatenate(
            [region_ids, jnp.full((n_pad,), -1, region_ids.dtype)])
        powers = jnp.concatenate([powers, jnp.zeros((n_pad,), powers.dtype)])
    grid = (region_ids.shape[0] // block_n,)

    out_shape = [jax.ShapeDtypeStruct((num_regions,), jnp.float32)] * 3
    out_specs = [pl.BlockSpec((num_regions,), lambda i: (0,))] * 3
    return pl.pallas_call(
        functools.partial(_kernel, num_regions=num_regions),
        grid=grid,
        in_specs=[pl.BlockSpec((block_n,), lambda i: (i,)),
                  pl.BlockSpec((block_n,), lambda i: (i,))],
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )(region_ids, powers.astype(jnp.float32))
