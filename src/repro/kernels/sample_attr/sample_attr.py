"""Pallas TPU kernel: ALEA sample-attribution reduction.

TPU adaptation of the tool's aggregation hot spot (billions of samples on
a fleet): instead of a scatter-add histogram (GPU-style atomics — no TPU
analogue), each sample block is turned into a one-hot matrix and the three
statistics become MXU matmuls:

    counts += 1ᵀ · onehot      psum += powᵀ · onehot      psumsq += (pow²)ᵀ · onehot

Grid: (region tiles, sample blocks), sample axis innermost. Each region
tile's [block_r] accumulators live in the output blocks (same block across
the whole inner sweep → VMEM-resident); sample blocks stream HBM→VMEM.
The region axis is tiled so num_regions is unbounded: R > 2048 (e.g. the
10⁴–10⁵ multi-worker combination space) no longer overflows VMEM — the
default 1024×2048 one-hot tile (1024×2048×4B = 8 MB) is the VMEM budget
regardless of R. Samples are re-streamed once per region tile; the
region-tile loop is the classic reduction-tiling tradeoff (R/block_r ×
sample traffic for O(block_r) on-chip state).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_N = 1024
DEFAULT_BLOCK_R = 2048


def _kernel(ids_ref, pow_ref, counts_ref, psum_ref, psumsq_ref, *,
            block_r: int):
    j = pl.program_id(0)   # region tile (outer)
    i = pl.program_id(1)   # sample block (inner; accumulators stay resident)

    @pl.when(i == 0)
    def _init():
        counts_ref[...] = jnp.zeros_like(counts_ref)
        psum_ref[...] = jnp.zeros_like(psum_ref)
        psumsq_ref[...] = jnp.zeros_like(psumsq_ref)

    ids = ids_ref[...]                                  # [bn] int32
    pw = pow_ref[...].astype(jnp.float32)               # [bn]
    # Tile-local one-hot via broadcasted iota compare (2D iota: TPU-legal).
    # Ids outside this tile (and -1 padding) match no column → zero rows.
    local = ids - j * block_r
    iota = jax.lax.broadcasted_iota(jnp.int32, (ids.shape[0], block_r), 1)
    onehot = (local[:, None] == iota).astype(jnp.float32)  # [bn, block_r]
    counts_ref[...] += jnp.sum(onehot, axis=0)
    psum_ref[...] += pw @ onehot
    psumsq_ref[...] += (pw * pw) @ onehot


def sample_attr_pallas(region_ids: jnp.ndarray, powers: jnp.ndarray,
                       num_regions: int, *, block_n: int = DEFAULT_BLOCK_N,
                       block_r: int | None = None,
                       interpret: bool = False):
    """region_ids: [n] int32 (pad with -1); powers: [n] f32.

    ``block_r`` tiles the region axis (default: min(num_regions, 2048));
    any ``num_regions`` is supported — the region space is padded up to a
    multiple of ``block_r`` and the outputs sliced back.
    """
    if block_r is None:
        block_r = min(num_regions, DEFAULT_BLOCK_R)
    n = region_ids.shape[0]
    n_pad = (block_n - n % block_n) % block_n
    if n_pad:
        region_ids = jnp.concatenate(
            [region_ids, jnp.full((n_pad,), -1, region_ids.dtype)])
        powers = jnp.concatenate([powers, jnp.zeros((n_pad,), powers.dtype)])
    r_pad = (block_r - num_regions % block_r) % block_r
    num_r_padded = num_regions + r_pad
    grid = (num_r_padded // block_r, region_ids.shape[0] // block_n)

    out_shape = [jax.ShapeDtypeStruct((num_r_padded,), jnp.float32)] * 3
    out_specs = [pl.BlockSpec((block_r,), lambda j, i: (j,))] * 3
    counts, psum, psumsq = pl.pallas_call(
        functools.partial(_kernel, block_r=block_r),
        grid=grid,
        in_specs=[pl.BlockSpec((block_n,), lambda j, i: (i,)),
                  pl.BlockSpec((block_n,), lambda j, i: (i,))],
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )(region_ids, powers.astype(jnp.float32))
    if r_pad:
        counts = counts[:num_regions]
        psum = psum[:num_regions]
        psumsq = psumsq[:num_regions]
    return counts, psum, psumsq
