"""Pure-jnp oracle for the ALEA sample-attribution reduction.

Given a stream of (region_id, power) samples, produce per-region:
counts, Σpower, Σpower² — the sufficient statistics for Eqs. 4/6/14.
"""

from __future__ import annotations

import jax.numpy as jnp


def sample_attr_ref(region_ids: jnp.ndarray, powers: jnp.ndarray,
                    num_regions: int):
    """region_ids: [n] int32; powers: [n] float. → (counts f32 [R],
    psum f32 [R], psumsq f32 [R]).

    Counts are returned as float32 (the kernel accumulates everything on
    the MXU in one dtype; exact for n < 2^24).
    """
    powers = powers.astype(jnp.float32)
    onehot = jnp.equal(region_ids[:, None],
                       jnp.arange(num_regions)[None, :]).astype(jnp.float32)
    counts = onehot.sum(axis=0)
    psum = powers @ onehot
    psumsq = (powers * powers) @ onehot
    return counts, psum, psumsq
