"""jit'd public wrapper for fused RMSNorm."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.rmsnorm.rmsnorm import rmsnorm_pallas


@functools.partial(jax.jit, static_argnames=("eps", "interpret"))
def rmsnorm(x, scale, *, eps: float = 1e-5, interpret: bool | None = None):
    """x: [..., d]; scale: [d]."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    shape = x.shape
    out = rmsnorm_pallas(x.reshape(-1, shape[-1]), scale, eps=eps,
                         interpret=bool(interpret))
    return out.reshape(shape)
