"""Pallas TPU kernel: fused RMSNorm.

Eliminates the separate mean-of-squares pass + scale multiply that XLA
sometimes fails to fuse across the norm→matmul boundary. Grid over row
blocks; each block [block_rows, d] is normalized entirely in VMEM with
fp32 accumulation. d padded to the 128-lane boundary by the wrapper.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, s_ref, o_ref, *, eps: float, d_orig: int):
    x = x_ref[...].astype(jnp.float32)          # [br, d_pad]
    # Padded lanes contribute zeros; divide by the true feature count.
    var = jnp.sum(x * x, axis=-1, keepdims=True) / d_orig
    y = x * jax.lax.rsqrt(var + eps) * s_ref[...].astype(jnp.float32)
    o_ref[...] = y.astype(o_ref.dtype)


def rmsnorm_pallas(x, scale, *, eps: float = 1e-5, block_rows: int = 256,
                   interpret: bool = False):
    """x: [n, d]; scale: [d] → [n, d]."""
    n, d = x.shape
    d_pad = (128 - d % 128) % 128
    r_pad = (block_rows - n % block_rows) % block_rows
    xp = jnp.pad(x, ((0, r_pad), (0, d_pad)))
    sp_ = jnp.pad(scale, (0, d_pad))
    grid = (xp.shape[0] // block_rows,)
    out = pl.pallas_call(
        functools.partial(_kernel, eps=eps, d_orig=d),
        grid=grid,
        in_specs=[pl.BlockSpec((block_rows, d + d_pad), lambda i: (i, 0)),
                  pl.BlockSpec((d + d_pad,), lambda i: (0,))],
        out_specs=pl.BlockSpec((block_rows, d + d_pad), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(xp.shape, x.dtype),
        interpret=interpret,
    )(xp, sp_)
    return out[:n, :d]
