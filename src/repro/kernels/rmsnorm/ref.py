"""Pure-jnp oracle for fused RMSNorm."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def rmsnorm_ref(x, scale, *, eps: float = 1e-5):
    """x: [..., d]; scale: [d]. fp32 accumulation, output in x.dtype."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)
            * scale.astype(jnp.float32)).astype(x.dtype)
