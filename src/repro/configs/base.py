"""Model/shape/run configuration dataclasses (the framework's config system).

One ``ModelConfig`` per assigned architecture lives in
``src/repro/configs/<id>.py``; reduced smoke variants derive via
``ModelConfig.reduced()``. Input-shape cells are ``ShapeConfig`` instances
(shared across LM-family archs per the assignment).
"""

from __future__ import annotations

import dataclasses
from typing import Literal

Family = Literal["dense", "moe", "ssm", "hybrid", "audio", "vlm"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: Family
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    d_head: int = 0                  # 0 ⇒ d_model // n_heads
    # attention
    qk_norm: bool = False
    causal: bool = True
    rope_theta: float = 1e4
    use_rope: bool = True
    norm_kind: str = "rms"           # "rms" | "layer"
    gated_mlp: bool = True
    act: str = "silu"
    # MoE
    n_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    capacity_factor: float = 1.25
    router_aux_coeff: float = 0.01
    # SSM / hybrid
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    attn_every: int = 0              # zamba2: shared attn block period
    # xLSTM
    slstm_every: int = 0             # xlstm: sLSTM block period (rest mLSTM)
    # frontends (vlm/audio): inputs are precomputed embeddings (stub)
    embed_inputs: bool = False       # True ⇒ input_specs provide [B,S,d] embeds
    # numerics / training
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    remat: str = "none"              # none | dots | full
    compute_dtype: str = "bfloat16"
    # perf knobs (§Perf hillclimb; safe defaults = paper-faithful baseline)
    bf16_gather: bool = False        # cast params bf16 BEFORE FSDP gathers
    decode_grouped: bool = False     # GQA decode without KV-head repetition
    kv_cache_dtype: str = "bfloat16"  # "float8_e4m3fn" halves decode reads
    disable_sp: bool = False         # no seq_act sharding (tiny-d archs)

    @property
    def head_dim(self) -> int:
        return self.d_head or (self.d_model // self.n_heads)

    @property
    def q_per_kv(self) -> int:
        return self.n_heads // self.n_kv_heads

    @property
    def is_encoder(self) -> bool:
        return not self.causal

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def reduced(self) -> "ModelConfig":
        """Small same-family variant for CPU smoke tests."""
        kw: dict = dict(
            n_layers=min(self.n_layers, 4 if self.attn_every else 2),
            d_model=128,
            n_heads=4,
            n_kv_heads=max(1, min(self.n_kv_heads,
                                  4 * self.n_kv_heads // self.n_heads or 1)),
            d_head=32,
            d_ff=256,
            vocab_size=256,
        )
        if self.n_experts:
            kw.update(n_experts=8, top_k=min(self.top_k, 2), moe_d_ff=64)
        if self.ssm_state:
            kw.update(ssm_state=16, ssm_head_dim=16)
        if self.attn_every:
            kw.update(attn_every=2)
        if self.slstm_every:
            kw.update(slstm_every=2)
        return self.replace(name=self.name + "-smoke", **kw)

    def param_count(self) -> int:
        """Analytic parameter count (for 6·N·D roofline bookkeeping)."""
        d, L = self.d_model, self.n_layers
        dh, H, KV = self.head_dim, self.n_heads, self.n_kv_heads
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        per_layer = 0
        n_attn = L if not self.attn_every else (L // self.attn_every)
        n_ssm = 0
        if self.family in ("ssm", "hybrid"):
            n_ssm = L
            attn_layers = 1 if self.attn_every else 0  # zamba2 shared block
        else:
            attn_layers = 0
        if self.family in ("dense", "moe", "audio", "vlm"):
            attn = d * dh * (H + 2 * KV) + H * dh * d
            per_layer += attn
        if self.family == "moe":
            ff = self.n_experts * 3 * d * self.moe_d_ff + d * self.n_experts
        elif self.family in ("dense", "audio", "vlm"):
            ff = (3 if self.gated_mlp else 2) * d * self.d_ff
        else:
            ff = 0
        per_layer += ff
        total = emb + L * per_layer
        if self.family in ("ssm", "hybrid"):
            d_in = self.ssm_expand * d
            ssm = (d * (2 * d_in + 2 * self.ssm_state) + d_in * d
                   + d_in * self.ssm_conv)
            total += n_ssm * ssm
            if self.attn_every:  # one shared attn+mlp block
                total += d * dh * (H + 2 * KV) + H * dh * d + 3 * d * self.d_ff
        if self.family == "ssm" and self.slstm_every:
            pass  # xlstm counts handled by ssm term approximation
        return int(total)

    def active_param_count(self) -> int:
        """Active params per token (MoE: top_k experts only)."""
        if self.family != "moe":
            return self.param_count()
        d, L = self.d_model, self.n_layers
        dh, H, KV = self.head_dim, self.n_heads, self.n_kv_heads
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        attn = d * dh * (H + 2 * KV) + H * dh * d
        ff = self.top_k * 3 * d * self.moe_d_ff + d * self.n_experts
        return int(emb + L * (attn + ff))


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: Literal["train", "prefill", "decode"]

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


# The assignment's four LM shapes.
TRAIN_4K = ShapeConfig("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524288, 1, "decode")

SHAPES: dict[str, ShapeConfig] = {s.name: s for s in
                                  (TRAIN_4K, PREFILL_32K, DECODE_32K,
                                   LONG_500K)}
