"""xlstm-125m [ssm] — 12L d_model=768 4H vocab=50304; alternating
mLSTM (matrix memory) + sLSTM (scalar memory) blocks. [arXiv:2405.04517;
unverified]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-125m", family="ssm",
    n_layers=12, d_model=768, n_heads=4, n_kv_heads=4, d_head=192,
    d_ff=0, vocab_size=50304,
    use_rope=False, slstm_every=2, remat="full",
)
