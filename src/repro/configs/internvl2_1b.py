"""internvl2-1b [vlm] — InternViT (stub frontend: precomputed patch embeds)
+ Qwen2-0.5B LM backbone: 24L d_model=896 14H (GQA kv=2) d_ff=4864
vocab=151655. [arXiv:2404.16821; hf]"""
from repro.configs.base import ModelConfig

N_PATCHES = 256  # stub ViT frontend emits this many patch embeddings

CONFIG = ModelConfig(
    name="internvl2-1b", family="vlm",
    n_layers=24, d_model=896, n_heads=14, n_kv_heads=2, d_head=64,
    d_ff=4864, vocab_size=151655,
    rope_theta=1e6, remat="full",
)
