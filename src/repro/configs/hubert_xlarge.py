"""hubert-xlarge [audio] — encoder-only (w2v2 arch): 48L d_model=1280
16H MHA d_ff=5120 vocab=504 (masked-unit prediction). The conv waveform
frontend is a STUB: input_specs() provides precomputed frame embeddings.
[arXiv:2106.07447; unverified]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge", family="audio",
    n_layers=48, d_model=1280, n_heads=16, n_kv_heads=16, d_head=80,
    d_ff=5120, vocab_size=504,
    causal=False, use_rope=False, norm_kind="layer", gated_mlp=False,
    act="gelu", embed_inputs=True, remat="full",
)
