"""zamba2-1.2b [hybrid] — 38 Mamba2 layers + ONE weight-shared attention
(+MLP) block invoked every 6 layers: d_model=2048, shared attn 32H MHA,
d_ff=8192, vocab=32000, ssm_state=64. [arXiv:2411.15242; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b", family="hybrid",
    n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32, d_head=64,
    d_ff=8192, vocab_size=32000,
    ssm_state=64, ssm_conv=4, ssm_head_dim=64, ssm_expand=2,
    attn_every=6, remat="full",
)
