"""Architecture registry: --arch <id> → ModelConfig (+ shape applicability).

Per-assignment skips (documented in DESIGN.md §4):
  * ``long_500k`` runs only for sub-quadratic archs (ssm/hybrid);
  * encoder-only archs (hubert) have no decode step.
"""
from __future__ import annotations

import importlib

from repro.configs.base import SHAPES, ModelConfig, ShapeConfig

_MODULES = {
    "qwen3-moe-30b-a3b": "qwen3_moe_30b_a3b",
    "granite-moe-1b-a400m": "granite_moe_1b_a400m",
    "internvl2-1b": "internvl2_1b",
    "qwen3-1.7b": "qwen3_1_7b",
    "yi-6b": "yi_6b",
    "starcoder2-15b": "starcoder2_15b",
    "stablelm-3b": "stablelm_3b",
    "xlstm-125m": "xlstm_125m",
    "hubert-xlarge": "hubert_xlarge",
    "zamba2-1.2b": "zamba2_1_2b",
}

ARCH_IDS = tuple(_MODULES)


def get_config(arch: str) -> ModelConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch]}")
    return mod.CONFIG


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """(runnable, reason-if-skipped) for an (arch × shape) cell."""
    if shape.is_decode and cfg.is_encoder:
        return False, "encoder-only arch has no decode step"
    if (shape.name == "long_500k"
            and cfg.family not in ("ssm", "hybrid")):
        return False, "long_500k needs sub-quadratic attention (full-attn arch)"
    if shape.name == "long_500k" and cfg.is_encoder:
        return False, "encoder-only arch has no decode step"
    return True, ""


def all_cells():
    """Yield (arch_id, shape, runnable, reason) for the 40 assigned cells."""
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape in SHAPES.values():
            ok, why = shape_applicable(cfg, shape)
            yield arch, shape, ok, why
