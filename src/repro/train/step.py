"""The pjit'd train step: loss → grad → (compress) → AdamW, with optional
gradient-accumulation microbatching.

Everything is a pure function of (params, opt_state, batch[, residuals]) so
pjit can donate and shard freely; data parallelism comes from batch sharding,
TP/EP from the param specs, and XLA inserts gradient all-reduces where the
loss contracts over DP axes.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.regions import region
from repro.models import model as M
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update
from repro.optim.compression import compress_decompress, compress_init

__all__ = ["TrainState", "init_state", "make_train_step"]

TrainState = dict[str, Any]


def init_state(key, cfg: ModelConfig, opt_cfg: AdamWConfig, *,
               compression: bool = False) -> TrainState:
    params = M.init_params(key, cfg)
    state: TrainState = {"params": params, "opt": adamw_init(params)}
    if compression:
        state["residuals"] = compress_init(params)
    return state


def make_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig, *,
                    attn_impl: str = "full", ssd_chunk: int = 128,
                    accum_steps: int = 1, compression: bool = False,
                    unroll: bool = False, q_chunk: int = 1024,
                    ce_chunk: int = 512):
    """Returns train_step(state, batch) -> (state, metrics)."""

    def loss(params, batch):
        if cfg.bf16_gather:
            # Mixed-precision layout: matrices cast to bf16 up front so
            # FSDP weight all-gathers move half the bytes (fp32 masters
            # stay sharded; the cast is elementwise → stays sharded too).
            params = jax.tree.map(
                lambda w: w.astype(jnp.bfloat16) if w.ndim >= 2 else w,
                params)
        return M.loss_fn(params, cfg, batch, attn_impl=attn_impl,
                         ssd_chunk=ssd_chunk, unroll=unroll,
                         q_chunk=q_chunk, ce_chunk=ce_chunk)

    grad_fn = jax.value_and_grad(loss, has_aux=True)

    def compute_grads(params, batch):
        if accum_steps == 1:
            (l, metrics), grads = grad_fn(params, batch)
            return l, metrics, grads
        # Microbatch accumulation: static slices along the batch dim
        # (a Python loop partitions robustly under GSPMD; XLA CSEs the
        # repeated structure).
        B = jax.tree.leaves(batch)[0].shape[0]
        mb_size = B // accum_steps
        grads = None
        lsum = 0.0
        for i in range(accum_steps):
            mb = jax.tree.map(
                lambda x: jax.lax.slice_in_dim(x, i * mb_size,
                                               (i + 1) * mb_size, axis=0),
                batch)
            (l, _), g = grad_fn(params, mb)
            lsum = lsum + l
            if grads is None:
                grads = jax.tree.map(lambda a: a.astype(jnp.float32), g)
            else:
                grads = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), grads, g)
        grads = jax.tree.map(lambda g: g / accum_steps, grads)
        l = lsum / accum_steps
        return l, {"ce": l, "aux": jnp.zeros(())}, grads

    def train_step(state: TrainState, batch):
        with region("fwd_bwd"):
            l, metrics, grads = compute_grads(state["params"], batch)
        new_state = dict(state)
        if compression:
            with region("grad_compress"):
                grads, new_state["residuals"] = compress_decompress(
                    grads, state["residuals"])
        with region("optimizer"):
            params, opt, opt_metrics = adamw_update(
                opt_cfg, state["params"], grads, state["opt"])
        new_state["params"] = params
        new_state["opt"] = opt
        metrics = dict(metrics, loss=l, **opt_metrics)
        return new_state, metrics

    return train_step
