"""Fault-tolerant training loop: checkpoint/restart, preemption hook,
step watchdog (straggler mitigation), optional ALEA online profiling.

The loop is host-side orchestration around the pure pjit'd step — at pod
scale this file is what runs on every host (each host feeds its data shard;
collectives live inside the step). Fault-tolerance posture:

  * atomic checkpoints every ``ckpt_every`` steps (async write-behind);
  * resume-from-LATEST on startup (elastic: any mesh shape can restore);
  * SIGTERM handler saves a final checkpoint (preemption-safe);
  * a watchdog thread flags steps exceeding ``watchdog_factor`` × EMA step
    time — at scale this triggers abort-and-restore; here it records the
    event and (configurably) raises ``StragglerAbort``;
  * ALEA host-mode profiling can run continuously (the paper's capped ~1%
    overhead makes it deployable online).
"""

from __future__ import annotations

import dataclasses
import signal
import threading
import time
from typing import Any, Callable

import jax
import numpy as np

from repro.checkpoint import ckpt as ckpt_mod
from repro.core.regions import region

__all__ = ["TrainerConfig", "Trainer", "StragglerAbort"]


class StragglerAbort(RuntimeError):
    pass


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_every: int = 50
    log_every: int = 10
    watchdog_factor: float = 10.0
    watchdog_min_s: float = 30.0
    raise_on_straggler: bool = False


class Trainer:
    def __init__(self, cfg: TrainerConfig, train_step: Callable,
                 state: Any, data_source, *, put_batch=None):
        self.cfg = cfg
        self.train_step = train_step
        self.state = state
        self.data = data_source
        self.put_batch = put_batch or (lambda b: b)
        self.step = 0
        self.straggler_events: list[int] = []
        self.ckpt = ckpt_mod.AsyncCheckpointer(cfg.ckpt_dir)
        self._ema_step_time: float | None = None
        self._watch_deadline: float | None = None
        self._stop_watch = threading.Event()
        self._install_sigterm()

    # -- fault tolerance ------------------------------------------------------
    def _install_sigterm(self):
        def handler(signum, frame):
            self.ckpt.wait()
            ckpt_mod.save(self.cfg.ckpt_dir, self.step,
                          jax.tree.map(np.asarray, self.state))
            raise SystemExit(143)
        try:
            signal.signal(signal.SIGTERM, handler)
        except ValueError:
            pass    # non-main thread (tests)

    def try_resume(self) -> bool:
        latest = ckpt_mod.latest_step(self.cfg.ckpt_dir)
        if latest is None:
            return False
        self.state, self.step = ckpt_mod.restore(self.cfg.ckpt_dir,
                                                 self.state, latest)
        return True

    # -- watchdog ---------------------------------------------------------------
    def _watchdog(self):
        while not self._stop_watch.wait(0.05):
            d = self._watch_deadline
            if d is not None and time.monotonic() > d:
                self.straggler_events.append(self.step)
                self._watch_deadline = None
                if self.cfg.raise_on_straggler:
                    # At scale: abort slow step, restore from checkpoint,
                    # exclude the slow host. Surfaced here as an exception.
                    raise StragglerAbort(f"step {self.step} exceeded deadline")

    # -- main loop ----------------------------------------------------------------
    def run(self, *, profiler_session=None) -> dict[str, Any]:
        watch = threading.Thread(target=self._watchdog, daemon=True)
        self._stop_watch.clear()
        watch.start()
        metrics_log = []
        try:
            while self.step < self.cfg.total_steps:
                with region("data_load"):
                    batch = self.put_batch(self.data.batch(self.step))
                ema = self._ema_step_time
                budget = max(self.cfg.watchdog_min_s,
                             self.cfg.watchdog_factor * (ema or 1e9))
                self._watch_deadline = time.monotonic() + budget
                t0 = time.monotonic()
                with region("train_step"):
                    self.state, metrics = self.train_step(self.state, batch)
                    jax.block_until_ready(
                        jax.tree.leaves(self.state)[0])
                dt = time.monotonic() - t0
                self._watch_deadline = None
                self._ema_step_time = (dt if ema is None
                                       else 0.9 * ema + 0.1 * dt)
                self.step += 1
                if self.step % self.cfg.log_every == 0:
                    metrics_log.append(
                        {k: float(v) for k, v in metrics.items()}
                        | {"step": self.step, "step_time_s": dt})
                if self.step % self.cfg.ckpt_every == 0:
                    with region("checkpoint"):
                        self.ckpt.save_async(self.step, self.state)
        finally:
            self._stop_watch.set()
            self.ckpt.wait()
        return {"metrics": metrics_log,
                "straggler_events": self.straggler_events,
                "final_step": self.step}
