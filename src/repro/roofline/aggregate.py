"""Aggregate dry-run JSON rows into the EXPERIMENTS.md roofline tables.

    PYTHONPATH=src python -m repro.roofline.aggregate results/dryrun/
"""

from __future__ import annotations

import json
import os
import re
import sys


def load_rows(d: str) -> list[dict]:
    rows = []
    for name in sorted(os.listdir(d)):
        if not name.endswith(".json") or name.startswith("VARIANT"):
            continue
        with open(os.path.join(d, name)) as f:
            data = json.load(f)
        mesh = "multi" if "__multi" in name else "single"
        for row in data if isinstance(data, list) else [data]:
            row["mesh_kind"] = mesh
            rows.append(row)
    return rows


def fmt_bytes(n) -> str:
    if n is None:
        return "-"
    return f"{n/2**30:.2f}"


def table(rows: list[dict], mesh_kind: str) -> str:
    hdr = ("| arch | shape | t_comp ms | t_mem ms | t_coll ms | dominant | "
           "roofline frac | model/HLO flops | GB/dev |")
    sep = "|" + "---|" * 9
    lines = [hdr, sep]
    for r in rows:
        if r.get("mesh_kind") != mesh_kind:
            continue
        if "skipped" in r:
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                         f"skip: {r['skipped']} | — | — | — |")
            continue
        if "error" in r:
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                         f"ERROR {r['error'][:40]} | — | — | — |")
            continue
        mem_gb = None
        m = re.search(r"temp_size_in_bytes=(\d+)", r.get("mem_analysis", ""))
        a = re.search(r"argument_size_in_bytes=(\d+)",
                      r.get("mem_analysis", ""))
        if m and a:
            mem_gb = int(m.group(1)) + int(a.group(1))
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute_s']*1e3:.2f} | "
            f"{r['t_memory_s']*1e3:.2f} | {r['t_collective_s']*1e3:.2f} | "
            f"{r['dominant']} | {r['roofline_fraction']:.3f} | "
            f"{r['model_flops_ratio']:.2f} | {fmt_bytes(mem_gb)} |")
    return "\n".join(lines)


def main():
    d = sys.argv[1] if len(sys.argv) > 1 else "results/dryrun"
    rows = load_rows(d)
    done = [r for r in rows if "t_compute_s" in r]
    skipped = [r for r in rows if "skipped" in r]
    failed = [r for r in rows if "error" in r]
    print(f"cells: {len(done)} compiled, {len(skipped)} skipped, "
          f"{len(failed)} failed\n")
    print("## Single-pod mesh 16x16 (256 chips)\n")
    print(table(rows, "single"))
    print("\n## Multi-pod mesh 2x16x16 (512 chips)\n")
    print(table(rows, "multi"))
    # Hillclimb candidates.
    singles = [r for r in done if r["mesh_kind"] == "single"]
    if singles:
        worst = min(singles, key=lambda r: r["roofline_fraction"])
        coll = max(singles, key=lambda r: r["t_collective_s"]
                   / max(r["t_compute_s"] + r["t_memory_s"], 1e-12))
        print(f"\nworst roofline fraction: {worst['arch']}×{worst['shape']}"
              f" ({worst['roofline_fraction']:.3f})")
        print(f"most collective-bound: {coll['arch']}×{coll['shape']}")


if __name__ == "__main__":
    main()
