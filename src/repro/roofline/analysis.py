"""Roofline analysis from compiled artifacts (assignment §ROOFLINE).

Three terms per (arch × shape × mesh), all in seconds:

    compute    = HLO_FLOPs / (chips × peak_FLOP/s)
    memory     = HLO_bytes / (chips × HBM_bw)
    collective = collective_bytes / (chips × link_bw)

HLO_FLOPs / HLO_bytes come from ``compiled.cost_analysis()``. Collective
bytes are parsed from the compiled HLO text: we sum the *output* operand
sizes of every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute (output size ≈ bytes each participating device must
move for ring/torus algorithms, up to the 2(n−1)/n factor, which we fold
into the link-bandwidth derate).

Also reports MODEL_FLOPS = 6·N·D (6·N_active·D for MoE) and its ratio to
HLO_FLOPs (remat/redundancy waste detector).
"""

from __future__ import annotations

import dataclasses
import re

from repro.core.power_model import TPU_V5E, HardwareSpec

__all__ = ["CollectiveStats", "RooflineReport", "parse_collective_bytes",
           "roofline_terms", "model_flops"]

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                   "collective-permute")

# e.g. "bf16[16,4096,128]{2,1,0}" or "f32[]"
_SHAPE_RE = re.compile(r"\b([a-z]+\d*)\[([\d,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    nbytes = _DTYPE_BYTES.get(dtype)
    if nbytes is None:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * nbytes


@dataclasses.dataclass
class CollectiveStats:
    bytes_by_kind: dict[str, int]
    count_by_kind: dict[str, int]

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())


def parse_collective_bytes(hlo_text: str) -> CollectiveStats:
    """Sum output-shape bytes of every collective op in HLO text."""
    bytes_by: dict[str, int] = {k: 0 for k in _COLLECTIVE_OPS}
    count_by: dict[str, int] = {k: 0 for k in _COLLECTIVE_OPS}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        # match instruction lines: "%name = TYPE[dims] op-name(...)"
        m = re.match(r"^[%\w.\-]+\s*=\s*(.+)$", stripped)
        if not m:
            continue
        rhs = m.group(1)
        opm = re.search(r"\b(" + "|".join(_COLLECTIVE_OPS)
                        + r")(?:-start|-done)?\(", rhs)
        if not opm:
            continue
        kind = opm.group(1)
        if "-done(" in rhs:   # avoid double counting start/done pairs
            continue
        # Output shape(s): everything before the op name. Tuples sum.
        head = rhs[:opm.start()]
        total = sum(_shape_bytes(d, dims)
                    for d, dims in _SHAPE_RE.findall(head))
        bytes_by[kind] += total
        count_by[kind] += 1
    return CollectiveStats(bytes_by, count_by)


def model_flops(n_params_active: int, n_tokens: int, *,
                training: bool = True) -> float:
    """6·N·D for a train step; 2·N·D for inference forward."""
    return (6.0 if training else 2.0) * n_params_active * n_tokens


@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float
    hlo_bytes: float
    collective_bytes: int
    collectives: dict[str, int]
    collective_counts: dict[str, int]
    t_compute: float
    t_memory: float
    t_collective: float
    model_flops_: float
    bytes_per_device: int | None = None

    @property
    def dominant(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def bound_time(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def roofline_fraction(self) -> float:
        """compute-term / max-term: 1.0 ⇒ perfectly compute-bound."""
        return self.t_compute / self.bound_time if self.bound_time else 0.0

    @property
    def useful_flops_ratio(self) -> float:
        return self.model_flops_ / self.hlo_flops if self.hlo_flops else 0.0

    def row(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips,
            "t_compute_s": self.t_compute, "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "dominant": self.dominant,
            "roofline_fraction": self.roofline_fraction,
            "hlo_gflops": self.hlo_flops / 1e9,
            "hlo_gbytes": self.hlo_bytes / 1e9,
            "coll_gbytes": self.collective_bytes / 1e9,
            "model_flops_ratio": self.useful_flops_ratio,
            "bytes_per_device": self.bytes_per_device,
        }


def roofline_terms(*, arch: str, shape: str, mesh_name: str, chips: int,
                   cost_analysis: dict, hlo_text: str,
                   n_params_active: int, n_tokens: int, training: bool,
                   bytes_per_device: int | None = None,
                   hw: HardwareSpec = TPU_V5E) -> RooflineReport:
    """Build the three-term report from a compiled dry-run artifact.

    SEMANTICS (measured, see tests): ``compiled.cost_analysis()`` on an
    SPMD module reports **per-device** flops/bytes, and the compiled HLO
    text is the per-device program (collective output shapes are
    per-device). So the assignment's formulas

        compute    = HLO_FLOPs   / (chips × peak)
        memory     = HLO_bytes   / (chips × HBM_bw)
        collective = coll_bytes  / (chips × link_bw)

    are applied with HLO_* = per-device value × chips — equivalently,
    per-device value / per-chip rate.
    """
    flops_dev = float(cost_analysis.get("flops", 0.0))
    hbm_dev = float(cost_analysis.get("bytes accessed", 0.0))
    coll = parse_collective_bytes(hlo_text)   # per-device module
    t_compute = flops_dev / hw.peak_flops_bf16
    t_memory = hbm_dev / hw.hbm_bandwidth
    t_coll = coll.total_bytes / hw.ici_bandwidth_per_link
    return RooflineReport(
        arch=arch, shape=shape, mesh=mesh_name, chips=chips,
        hlo_flops=flops_dev * chips, hlo_bytes=hbm_dev * chips,
        collective_bytes=coll.total_bytes,
        collectives=coll.bytes_by_kind, collective_counts=coll.count_by_kind,
        t_compute=t_compute, t_memory=t_memory, t_collective=t_coll,
        model_flops_=model_flops(n_params_active, n_tokens,
                                 training=training),
        bytes_per_device=bytes_per_device,
    )
