"""Analytic per-region cost model: (arch × shape) → list[RegionCost].

Used to synthesize device timelines for ALEA validation (§5 protocol) and
the §7 energy-optimization use cases. Totals are cross-checked against the
dry-run's compiled cost_analysis in tests (MODEL_FLOPS ratio) — this model
intentionally counts *useful* work (causal attention halved, no remat
recompute), so it is the 6·N·D-style denominator, not the HLO numerator.

All FLOPs/bytes are whole-step (all chips), matching RegionCost semantics;
``ici_bytes`` is per-chip link traffic.
"""

from __future__ import annotations

from repro.configs.base import ModelConfig, ShapeConfig
from repro.core.timeline import RegionCost

__all__ = ["step_region_costs"]


def _attn_region(cfg: ModelConfig, tokens: int, kv_len: int, *,
                 training: bool, n_layers: int, causal: bool) -> list[RegionCost]:
    d, H, KV, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    mult = 3 if training else 1          # fwd + 2x bwd
    proj_flops = 2 * tokens * d * dh * (H + 2 * KV) + 2 * tokens * H * dh * d
    score_flops = 2 * tokens * kv_len * dh * H * 2
    if causal and kv_len == 0:
        pass
    if causal and kv_len > 1:
        score_flops //= 2                # causal triangle
    bytes_proj = 2 * (tokens * d * 2 + d * dh * (H + 2 * KV))
    bytes_score = 2 * tokens * H * dh * 2 + 2 * tokens * KV * dh * 2 * (
        kv_len // max(tokens, 1) if kv_len > tokens else 1)
    return [
        RegionCost("attn_qkv", mult * proj_flops * 0.6,
                   mult * bytes_proj, invocations=n_layers),
        RegionCost("attn_score", mult * score_flops,
                   mult * bytes_score, invocations=n_layers),
        RegionCost("attn_out", mult * proj_flops * 0.4,
                   mult * bytes_proj * 0.4, invocations=n_layers),
    ]


def _ffn_region(cfg: ModelConfig, tokens: int, *, training: bool,
                n_layers: int) -> list[RegionCost]:
    d = cfg.d_model
    mult = 3 if training else 1
    if cfg.family == "moe":
        ff = cfg.moe_d_ff
        flops = 2 * tokens * cfg.top_k * 3 * d * ff
        wbytes = cfg.n_experts * 3 * d * ff * 2
        return [
            RegionCost("moe_router", mult * 2 * tokens * d * cfg.n_experts,
                       mult * tokens * d * 2, invocations=n_layers),
            RegionCost("moe_ffn", mult * flops, mult * (wbytes + tokens * d * 4),
                       ici_bytes=2 * tokens * d * 2 / 16,  # dispatch+combine
                       invocations=n_layers),
        ]
    n_mats = 3 if cfg.gated_mlp else 2
    ff = cfg.d_ff
    flops = 2 * tokens * n_mats * d * ff
    wbytes = n_mats * d * ff * 2
    return [RegionCost("ffn", mult * flops,
                       mult * (wbytes + tokens * d * 4),
                       invocations=n_layers)]


def _ssm_region(cfg: ModelConfig, tokens: int, *, training: bool,
                n_layers: int) -> list[RegionCost]:
    d = cfg.d_model
    d_in = cfg.ssm_expand * d
    N = cfg.ssm_state
    H = d_in // cfg.ssm_head_dim
    mult = 3 if training else 1
    proj = 2 * tokens * d * (2 * d_in + 2 * N + H) + 2 * tokens * d_in * d
    scan = tokens * (cfg.ssm_head_dim * N * H * 6)    # SSD state updates
    return [
        RegionCost("ssm_proj", mult * proj,
                   mult * (tokens * d * 2 + d * 2 * d_in * 2),
                   invocations=n_layers),
        RegionCost("ssm_scan", mult * scan,
                   mult * tokens * d_in * 4, invocations=n_layers),
    ]


def step_region_costs(cfg: ModelConfig, shape: ShapeConfig,
                      *, chips: int = 256) -> list[RegionCost]:
    """Per-region costs of one step (train/prefill/decode per shape.kind)."""
    training = shape.kind == "train"
    B, S = shape.global_batch, shape.seq_len
    tokens = B * (S if shape.kind != "decode" else 1)
    kv_len = S
    costs: list[RegionCost] = []

    # Embedding + head + loss.
    emb_bytes = tokens * cfg.d_model * 4 * (3 if training else 1)
    costs.append(RegionCost("embed", 0.0, emb_bytes))
    head_flops = 2 * tokens * cfg.d_model * cfg.vocab_size
    costs.append(RegionCost(
        "lm_head", (3 if training else 1) * head_flops,
        cfg.d_model * cfg.vocab_size * 2 + tokens * cfg.vocab_size * 4))
    if training:
        costs.append(RegionCost("loss", 6 * tokens * cfg.vocab_size,
                                tokens * cfg.vocab_size * 8))

    L = cfg.n_layers
    fam = cfg.family
    if fam in ("dense", "moe", "audio", "vlm"):
        costs += _attn_region(cfg, tokens, kv_len, training=training,
                              n_layers=L, causal=cfg.causal)
        costs += _ffn_region(cfg, tokens, training=training, n_layers=L)
    elif fam == "ssm":        # xLSTM: mLSTM ~ attnless linear + sLSTM scan
        costs += _ssm_region(
            cfg.replace(ssm_expand=1, ssm_state=cfg.head_dim,
                        ssm_head_dim=cfg.head_dim),
            tokens, training=training, n_layers=L)
    else:                      # hybrid
        n_attn = L // cfg.attn_every
        costs += _ssm_region(cfg, tokens, training=training, n_layers=L)
        costs += _attn_region(cfg, tokens, kv_len, training=training,
                              n_layers=n_attn, causal=True)
        costs += _ffn_region(cfg.replace(family="dense"), tokens,
                             training=training, n_layers=n_attn)

    if training:
        # Optimizer + gradient all-reduce/reduce-scatter over DP.
        n_params = cfg.param_count()
        costs.append(RegionCost("optimizer", 8 * n_params, 16 * n_params))
        costs.append(RegionCost("grad_allreduce", 0.0, 2 * n_params * 4,
                                ici_bytes=2 * n_params * 4 / chips))
    return costs
