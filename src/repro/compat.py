"""jax version-compat shims (0.4.x ↔ 0.5+).

The repo targets the latest jax API surface; this module bridges the
names that moved or were renamed so the same code runs on jax 0.4.37
(the CI pin) and newer releases:

* ``shard_map`` — top-level ``jax.shard_map`` only exists from 0.5;
  before that it lives in ``jax.experimental.shard_map`` and spells the
  replication check ``check_rep`` instead of ``check_vma``.

Mesh-construction compat (``jax.sharding.AxisType`` / the ``axis_types=``
kwarg of ``jax.make_mesh``) lives in :mod:`repro.launch.mesh` next to the
mesh builders themselves.
"""

from __future__ import annotations

import jax

__all__ = ["shard_map"]


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
    """``jax.shard_map`` on any supported jax version.

    ``check_vma`` follows the modern spelling; it is forwarded as
    ``check_rep`` to the 0.4.x experimental implementation (same
    semantics: verify that ``out_specs`` replication is provable).
    """
    impl = getattr(jax, "shard_map", None)
    if impl is not None:
        return impl(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                    check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as impl_04
    return impl_04(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                   check_rep=check_vma)
