"""Admission control, deadlines, energy budgets and overload shedding
for the serving engine — the serving half of the ROADMAP failure model.

The profiling fleet got a *may-lose / never-corrupt* contract in PR 6;
this module gives the thing being profiled the same discipline. Every
quantity here is measured in the deterministic **engine step clock**
(``Engine.step_count``), never wall clock: a chaos scenario that kills
and restores an engine replays bit-exactly, and the ``no-wallclock``
static pass covers this module.

Pieces:

* A typed rejection hierarchy rooted at :class:`AdmissionError` —
  :class:`QueueFullError`, :class:`DeadlineExceededError`,
  :class:`EnergyBudgetExceededError` — plus :class:`ServeTimeoutError`
  for a drain loop that runs out of steps with work still in flight.
  Every rejection/abort is counted in the :class:`ServeReport`, never
  silent.

* A bounded :class:`AdmissionQueue` with priorities: admission order is
  (priority desc, submit sequence asc) — deterministic under equal
  priorities — and shedding takes the *lowest* priority, *youngest*
  submission first (oldest work is preserved).

* A :class:`ServeScheduler` owning the queue, the per-request
  :class:`ServeReport` provenance (mirroring the exchange layer's
  ``GatherResult``/``HostReport`` contract), and the overload
  degradation ladder: ``normal`` → ``backpressure`` (submitters are
  signalled to slow down) → ``shed`` (lowest-priority queued requests
  are dropped, counted) → ``degraded`` (the energy accountant's
  sampling period is widened so the monitor itself stops competing for
  the overloaded host — the PAPERS.md RAPL-overhead critique). Every
  transition, both up and down, is recorded with its step and reason.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Iterable

from repro.core.faults import FaultPlan, declare_site, resolve_plan

__all__ = [
    "ServeError", "AdmissionError", "QueueFullError",
    "DeadlineExceededError", "EnergyBudgetExceededError",
    "ServeTimeoutError", "PriceSignalUnavailableError", "OverloadPolicy",
    "AdmissionQueue", "RequestRecord", "ServeReport", "ServeScheduler",
    "LADDER",
]

# Injection seam this module owns (see faults.FAULT_SITES): transient
# submit-time admission faults (counted, typed, never silent).
_SITE_ADMISSION = declare_site("serve.admission")

# The overload degradation ladder, in escalation order.
LADDER = ("normal", "backpressure", "shed", "degraded")


# -- typed serving failures ---------------------------------------------------

class ServeError(RuntimeError):
    """Base for typed serving-layer failures."""


class AdmissionError(ServeError):
    """A request could not be (or stay) admitted. Subclasses say why;
    every raise is preceded by a ServeReport count — rejections are
    load-shedding decisions, not silent drops."""


class QueueFullError(AdmissionError):
    """The bounded admission queue is full and the submitted request
    does not outrank anything sheddable."""


class DeadlineExceededError(AdmissionError):
    """The request's step-clock deadline elapsed (in queue or mid-run)."""


class EnergyBudgetExceededError(AdmissionError):
    """The request's measured/charged energy crossed its budget."""


class ServeTimeoutError(ServeError):
    """``run_until_drained`` ran out of steps with requests still
    pending or in flight. Carries the undrained request ids so the
    caller knows exactly which work was abandoned."""

    def __init__(self, msg: str, undrained: Iterable[int] = ()):
        super().__init__(msg)
        self.undrained = tuple(undrained)


class PriceSignalUnavailableError(ServeError):
    """``Engine.current_joules_per_token`` cannot quote yet: no
    accountant / no tokens / no drained decode-phase samples, the Wald
    CI is invalid (estimator normality guard), or the CI is wider than
    the caller's quoting threshold. Admission price tiers must treat
    this as "no signal", never as a free tier — a silent zero-J quote
    would price overload exactly backwards."""


# -- policy -------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class OverloadPolicy:
    """Thresholds (queued-request depths) of the degradation ladder.

    ``backpressure_at <= shed_at <= widen_at <= queue_capacity``; each
    level engages while the queue depth is at or above its threshold
    and releases below it. ``shed`` drops lowest-priority queued
    requests until the depth falls back to ``backpressure_at``;
    ``degraded`` multiplies the accountant's sampling period by
    ``widen_factor`` (restored on de-escalation).
    """
    queue_capacity: int = 64
    backpressure_at: int = 8
    shed_at: int = 16
    widen_at: int = 32
    widen_factor: float = 4.0

    def __post_init__(self):
        if self.queue_capacity < 1:
            raise ValueError(
                f"queue_capacity must be >= 1; got {self.queue_capacity}")
        if not (1 <= self.backpressure_at <= self.shed_at
                <= self.widen_at <= self.queue_capacity):
            raise ValueError(
                "ladder thresholds must satisfy 1 <= backpressure_at <= "
                f"shed_at <= widen_at <= queue_capacity; got "
                f"{self.backpressure_at}/{self.shed_at}/{self.widen_at}"
                f"/{self.queue_capacity}")
        if self.widen_factor < 1.0:
            raise ValueError(
                f"widen_factor must be >= 1; got {self.widen_factor}")

    def level_for(self, depth: int) -> int:
        """Ladder level index for a queue depth (pure, step-clocked)."""
        if depth >= self.widen_at:
            return 3
        if depth >= self.shed_at:
            return 2
        if depth >= self.backpressure_at:
            return 1
        return 0


# -- bounded priority queue ---------------------------------------------------

class AdmissionQueue:
    """Bounded priority queue with deterministic order.

    Entries are ``(priority, seq, request)``. :meth:`pop_best` returns
    the highest priority, then lowest submit sequence (FIFO within a
    priority class — admission order is a pure function of the submit
    order, never of hashes or arrival wall time). :meth:`shed_worst`
    removes the lowest priority, then *highest* sequence (the youngest
    of the least-important work dies first).
    """

    def __init__(self, capacity: int):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1; got {capacity}")
        self.capacity = capacity
        self._items: list[tuple[int, int, object]] = []

    def __len__(self) -> int:
        return len(self._items)

    @property
    def full(self) -> bool:
        return len(self._items) >= self.capacity

    def push(self, priority: int, seq: int, req) -> None:
        if self.full:
            raise QueueFullError(
                f"admission queue at capacity {self.capacity}")
        self._items.append((priority, seq, req))

    def min_priority(self) -> int | None:
        """Lowest queued priority, or None when empty."""
        if not self._items:
            return None
        return min(p for p, _, _ in self._items)

    def pop_best(self):
        if not self._items:
            return None
        best = max(range(len(self._items)),
                   key=lambda i: (self._items[i][0], -self._items[i][1]))
        return self._items.pop(best)[2]

    def shed_worst(self):
        if not self._items:
            return None
        worst = min(range(len(self._items)),
                    key=lambda i: (self._items[i][0], -self._items[i][1]))
        return self._items.pop(worst)[2]

    def remove_expired(self, expired: Callable[[object], bool]) -> list:
        """Pop every queued request for which ``expired`` holds
        (deterministic submit-sequence order)."""
        hit = [(p, s, r) for (p, s, r) in self._items if expired(r)]
        if hit:
            self._items = [e for e in self._items if not expired(e[2])]
        return [r for _, _, r in sorted(hit, key=lambda e: e[1])]

    def snapshot(self) -> list[tuple[int, int, object]]:
        """Queued entries in submit order (for durable snapshots)."""
        return sorted(self._items, key=lambda e: e[1])


# -- per-request provenance ---------------------------------------------------

_STATUSES = ("queued", "admitted", "completed", "shed",
             "aborted_deadline", "aborted_budget", "recovered")


@dataclasses.dataclass
class RequestRecord:
    """One request's provenance through the serving layer.

    ``status`` is one of:

    * ``"queued"``           — submitted, waiting for a slot.
    * ``"admitted"``         — holds a slot, decoding.
    * ``"completed"``        — finished normally (EOS / token budget).
    * ``"shed"``             — dropped by overload control before it
      ever ran (``reason`` says whether at submit time or by the
      shed rung of the ladder).
    * ``"aborted_deadline"`` — step-clock deadline elapsed; any tokens
      generated so far were returned as partial output.
    * ``"aborted_budget"``   — energy budget exhausted mid-decode;
      partial output returned.
    * ``"recovered"``        — restored from a durable snapshot and
      re-admitted; moves on to ``completed``/aborted as usual, with
      :attr:`recovered` staying True for provenance.
    """
    rid: int
    status: str
    priority: int = 0
    submit_step: int = 0
    admit_step: int | None = None
    finish_step: int | None = None
    tokens_out: int = 0
    energy_j: float = 0.0
    recovered: bool = False
    reason: str | None = None
    error: str | None = None
    # Self-speculative decoding provenance: draft tokens proposed for /
    # accepted by this request's slot (0/0 when speculation is off).
    spec_drafted: int = 0
    spec_accepted: int = 0

    @property
    def acceptance_rate(self) -> float | None:
        """Accepted / drafted for this request, or None when no window
        ever covered it (speculation off, or only fallback steps)."""
        if self.spec_drafted == 0:
            return None
        return self.spec_accepted / self.spec_drafted

    def to_json(self) -> dict:
        d = dataclasses.asdict(self)
        d["acceptance_rate"] = self.acceptance_rate
        return d

    @classmethod
    def from_json(cls, d: dict) -> "RequestRecord":
        d = dict(d)
        d.pop("acceptance_rate", None)   # derived, not a field
        return cls(**d)


class ServeReport:
    """Fleet-style provenance for one serving run.

    Mirrors ``exchange.GatherResult``: every request that ever touched
    the engine gets a :class:`RequestRecord`; overload-ladder
    transitions are logged with their step and reason; and the typed
    rejection counters make every loss observable. Nothing is dropped
    without a record saying so.
    """

    def __init__(self):
        self._records: dict[int, RequestRecord] = {}
        self.transitions: list[tuple[int, str, str, str]] = []
        # `shed` counts every request that ended with status "shed";
        # `rejected_full` is the subset refused at submit time with a
        # QueueFullError (the rest were dropped from the queue by the
        # ladder or displaced by higher priority). Conservation:
        # completed + shed + aborted_* covers every terminal request.
        self.rejected_full = 0
        self.shed = 0
        self.aborted_deadline = 0
        self.aborted_budget = 0
        self.completed = 0
        self.recovered = 0
        self.admission_faults = 0
        self.buffer_overruns = 0
        # Self-speculative decoding counters. Conservation per window:
        # drafted = accepted + rejected for every slot; `rollbacks`
        # counts windows that discarded at least one draft (the
        # KV-rewind / checkpoint-replay events).
        self.drafted = 0
        self.accepted = 0
        self.rejected = 0
        self.rollbacks = 0
        # Interner pressure of the accountant's per-request combination
        # table (engine-maintained; None without track_requests):
        # distinct/miss/growth counters plus, in bounded mode, the
        # k/resident/tail-fold block — how close attribution state is
        # to its cap, and what the tail cost so far.
        self.attribution: dict | None = None

    # -- records --------------------------------------------------------------
    def open(self, rid: int, *, status: str, step: int,
             priority: int = 0) -> RequestRecord:
        if rid in self._records:
            raise ValueError(f"request {rid} already tracked "
                             f"({self._records[rid].status})")
        rec = RequestRecord(rid=rid, status=status, priority=priority,
                            submit_step=step)
        self._records[rid] = rec
        return rec

    def request(self, rid: int) -> RequestRecord:
        return self._records[rid]

    def __contains__(self, rid: int) -> bool:
        return rid in self._records

    @property
    def requests(self) -> tuple[RequestRecord, ...]:
        return tuple(self._records[r] for r in sorted(self._records))

    def set_status(self, rid: int, status: str, *, step: int | None = None,
                   reason: str | None = None,
                   error: str | None = None) -> RequestRecord:
        if status not in _STATUSES:
            raise ValueError(f"unknown request status {status!r}")
        rec = self._records[rid]
        rec.status = status
        if status == "recovered":
            rec.recovered = True
            self.recovered += 1
        if reason is not None:
            rec.reason = reason
        if error is not None:
            rec.error = error
        if status in ("completed", "shed", "aborted_deadline",
                      "aborted_budget"):
            rec.finish_step = step
            if status == "completed":
                self.completed += 1
            elif status == "shed":
                self.shed += 1
            elif status == "aborted_deadline":
                self.aborted_deadline += 1
            else:
                self.aborted_budget += 1
        return rec

    # -- ladder ---------------------------------------------------------------
    def transition(self, step: int, frm: str, to: str, reason: str) -> None:
        self.transitions.append((step, frm, to, reason))

    # -- rendering ------------------------------------------------------------
    def by_status(self) -> dict[str, list[int]]:
        out: dict[str, list[int]] = {}
        for rec in self.requests:
            out.setdefault(rec.status, []).append(rec.rid)
        return out

    def coverage(self) -> dict:
        """JSON-able run provenance (the serving analogue of
        ``GatherResult.coverage``)."""
        by = self.by_status()
        n = len(self._records)
        done = len(by.get("completed", ()))
        parts = [f"completed {done}/{n} requests"]
        for label in ("shed", "aborted_deadline", "aborted_budget",
                      "queued", "admitted"):
            if by.get(label):
                parts.append(f"{label}: {by[label]}")
        if self.drafted:
            # ACCEPTANCE disclosure (mirrors COVERAGE/TAIL): speculation
            # quality is reported whenever any window ran, so a
            # regression to 0% acceptance is visible, not silent.
            rate = 100.0 * self.accepted / self.drafted
            parts.append(
                f"ACCEPTANCE {self.accepted}/{self.drafted} drafted "
                f"tokens accepted ({rate:.1f}%), "
                f"{self.rollbacks} rollbacks")
        out = {
            "requests": {str(r.rid): r.to_json() for r in self.requests},
            "by_status": by,
            "transitions": [list(t) for t in self.transitions],
            "counters": {
                "rejected_full": self.rejected_full,
                "shed": self.shed,
                "aborted_deadline": self.aborted_deadline,
                "aborted_budget": self.aborted_budget,
                "completed": self.completed,
                "recovered": self.recovered,
                "admission_faults": self.admission_faults,
                "buffer_overruns": self.buffer_overruns,
                "drafted": self.drafted,
                "accepted": self.accepted,
                "rejected": self.rejected,
                "rollbacks": self.rollbacks,
            },
            "summary": "; ".join(parts),
        }
        if self.attribution is not None:
            out["attribution"] = dict(self.attribution)
        return out

    # -- durable snapshot round-trip ------------------------------------------
    def to_json(self) -> dict:
        out = {
            "records": [r.to_json() for r in self.requests],
            "transitions": [list(t) for t in self.transitions],
            "counters": [self.rejected_full, self.shed,
                         self.aborted_deadline, self.aborted_budget,
                         self.completed, self.recovered,
                         self.admission_faults, self.buffer_overruns,
                         self.drafted, self.accepted, self.rejected,
                         self.rollbacks],
        }
        if self.attribution is not None:
            out["attribution"] = dict(self.attribution)
        return out

    @classmethod
    def from_json(cls, d: dict) -> "ServeReport":
        rep = cls()
        for rj in d["records"]:
            rec = RequestRecord.from_json(rj)
            rep._records[rec.rid] = rec
        rep.transitions = [tuple(t) for t in d["transitions"]]
        # Pre-speculation snapshots carry 8 counters; pad with zeros so
        # old snapshots restore cleanly (same discipline as the
        # attribution key below).
        counters = list(d["counters"]) + [0] * (12 - len(d["counters"]))
        (rep.rejected_full, rep.shed, rep.aborted_deadline,
         rep.aborted_budget, rep.completed, rep.recovered,
         rep.admission_faults, rep.buffer_overruns,
         rep.drafted, rep.accepted, rep.rejected,
         rep.rollbacks) = counters
        # Pre-bounded snapshots have no attribution key; .get keeps the
        # round-trip backward compatible.
        rep.attribution = d.get("attribution")
        return rep


# -- the scheduler ------------------------------------------------------------

class ServeScheduler:
    """Admission queue + overload ladder + provenance, step-clocked.

    The engine drives it: :meth:`submit` at the edge, :meth:`admit`
    when slots free up, :meth:`tick` once per engine step. All decisions
    are pure functions of (submit order, step clock, queue state), so a
    killed-and-restored engine — the queue rides in the snapshot —
    reproduces the exact same admission/shed schedule.
    """

    def __init__(self, policy: OverloadPolicy | None = None, *,
                 faults: FaultPlan | None = None):
        self.policy = policy or OverloadPolicy()
        self.queue = AdmissionQueue(self.policy.queue_capacity)
        self.report = ServeReport()
        self.level = 0
        self._seq = 0
        self._faults = resolve_plan(faults)
        # set while the ladder sits at `degraded`; cleared (and the
        # widen undone via the callback) on de-escalation.
        self._widened = False

    # -- edge -----------------------------------------------------------------
    @property
    def backpressure(self) -> bool:
        """True while the ladder is at or above ``backpressure`` —
        submitters should slow down (the signal is advisory; the shed
        rung is the enforcement)."""
        return self.level >= 1

    @property
    def widened(self) -> bool:
        """True while the degraded rung's widen hook is engaged. The
        engine derives its effective speculation length from this flag
        (``degraded_spec_len`` while True), so de-escalation restores L
        through the same single unwiden edge that restores the sampling
        period — the flag rides in :meth:`state_json`, making the
        derived knobs snapshot-consistent for free."""
        return self._widened

    def submit(self, req, step: int) -> None:
        """Enqueue ``req`` at engine step ``step``.

        Raises typed admission errors; every raise is counted in the
        report first. A full queue sheds its worst entry when the new
        request outranks it (strictly higher priority), else rejects
        the new request with :class:`QueueFullError`.
        """
        seq = self._seq
        self._seq += 1
        plan = self._faults
        if plan is not None and plan.admission_fails(seq):
            self.report.admission_faults += 1
            raise AdmissionError(
                f"injected admission fault at submit #{seq} "
                f"(request {req.rid})")
        priority = getattr(req, "priority", 0)
        if req.rid in self.report:
            raise ValueError(f"request id {req.rid} already submitted")
        rec = self.report.open(req.rid, status="queued", step=step,
                               priority=priority)
        if req.deadline is not None and req.deadline <= 0:
            self.report.set_status(req.rid, "aborted_deadline", step=step,
                                   error="deadline <= 0 at submit")
            raise DeadlineExceededError(
                f"request {req.rid}: non-positive deadline {req.deadline}")
        if self.queue.full:
            worst = self.queue.min_priority()
            if worst is not None and priority > worst:
                victim = self.queue.shed_worst()
                self._shed(victim, step, "displaced by higher priority")
            else:
                self.report.rejected_full += 1
                self.report.set_status(req.rid, "shed", step=step,
                                       reason="queue_full")
                raise QueueFullError(
                    f"request {req.rid}: queue at capacity "
                    f"{self.queue.capacity} and priority {priority} does "
                    f"not outrank any queued request")
        req.submit_step = step
        self.queue.push(priority, seq, req)
        rec.submit_step = step

    # -- engine side ----------------------------------------------------------
    def admit(self, step: int):
        """Next request for a free slot, or None. Queue-expired
        deadlines are aborted here (counted), never handed to a slot."""
        self._drop_expired(step)
        req = self.queue.pop_best()
        if req is None:
            return None
        self.report.set_status(req.rid, "admitted")
        rec = self.report.request(req.rid)
        rec.admit_step = step
        return req

    def _drop_expired(self, step: int) -> None:
        def expired(r) -> bool:
            return (r.deadline is not None
                    and step - r.submit_step >= r.deadline)
        for req in self.queue.remove_expired(expired):
            req.status = "aborted_deadline"
            self.report.set_status(
                req.rid, "aborted_deadline", step=step,
                error=f"deadline {req.deadline} elapsed in queue")

    def tick(self, step: int, *,
             widen_fn: Callable[[float], None] | None = None,
             unwiden_fn: Callable[[], None] | None = None) -> None:
        """Evaluate the overload ladder once per engine step."""
        self._drop_expired(step)
        target = self.policy.level_for(len(self.queue))
        if target >= 2:
            # Shed rung: drop lowest-priority queued work until the
            # depth is back at the backpressure threshold.
            while len(self.queue) > self.policy.backpressure_at:
                victim = self.queue.shed_worst()
                if victim is None:
                    break
                self._shed(victim, step, "load_shed")
        hooks = ""
        if target >= 3 and not self._widened:
            if widen_fn is not None:
                widen_fn(self.policy.widen_factor)
            self._widened = True
            hooks = ("; degraded hooks engaged (sampling widened, "
                     "speculation shrunk)")
        elif target < 3 and self._widened:
            # The single de-escalation reset edge: one unwiden call
            # restores the sampling period, and clearing the flag
            # restores the effective speculation length (derived from
            # it) — recorded on the same transition below so neither
            # knob can stay degraded silently.
            if unwiden_fn is not None:
                unwiden_fn()
            self._widened = False
            hooks = ("; degraded hooks reset (sampling period and "
                     "speculation length restored)")
        if target != self.level:
            self.report.transition(
                step, LADDER[self.level], LADDER[target],
                f"queue depth {len(self.queue)}"
                + (" after shedding" if target >= 2 else "") + hooks)
            self.level = target

    def _shed(self, req, step: int, reason: str) -> None:
        req.status = "shed"
        self.report.set_status(req.rid, "shed", step=step, reason=reason)

    # -- durable state --------------------------------------------------------
    def state_json(self) -> dict:
        """Scheduler state for the engine snapshot (queue entries are
        serialized by the snapshot writer, which owns the arrays)."""
        return {"seq": self._seq, "level": self.level,
                "widened": self._widened,
                "report": self.report.to_json()}

    def load_state(self, d: dict) -> None:
        self._seq = int(d["seq"])
        self.level = int(d["level"])
        self._widened = bool(d["widened"])
        self.report = ServeReport.from_json(d["report"])

    def requeue(self, req, priority: int, seq: int) -> None:
        """Re-enter a snapshot's queued request after a restore (its
        record already exists; identity — priority and submit order —
        is preserved so the replayed schedule is bit-identical)."""
        self.queue.push(priority, seq, req)
