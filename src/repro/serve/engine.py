"""Serving engine: pjit'd prefill/decode steps + a continuous-batching
host scheduler (slot-based, vLLM-lite).

The device side is two pure functions (prefill fills a slot's cache pages;
decode advances every active slot one token). The host side packs requests
into fixed slots so the decode step shape stays static (no recompiles).
ALEA regions wrap both so serving energy is attributable per phase:
attach a :class:`PhaseEnergyAccountant` and the engine drains the host
sampler's ring buffer into a StreamingAggregator after every scheduler
step — a serving run of any length holds O(R + drain chunk) profiling
state, never the full sample stream.
"""

from __future__ import annotations

import contextlib
import dataclasses
import functools
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import regions as regions_mod
from repro.core.estimator import EstimateSet
from repro.core.faults import InjectedCrash, declare_site, resolve_plan
from repro.core.sampler import HostSampler, RegionMarker
from repro.core.sensors import available_host_sensor
from repro.core.streaming import (StreamingAggregator,
                                  StreamingCombinationAggregator)
from repro.models import model as M
from repro.serve.scheduler import (PriceSignalUnavailableError,
                                   ServeScheduler, ServeTimeoutError)

__all__ = ["ServeConfig", "Request", "Engine", "PhaseEnergyAccountant",
           "ServeTimeoutError", "PriceSignalUnavailableError",
           "JoulesPerToken"]

# Injection seam this module owns (see faults.FAULT_SITES): the engine
# step loop can be killed at a chosen step-clock value, before any state
# mutation, to exercise snapshot/restore.
_SITE_STEP_CRASH = declare_site("serve.step.crash")


class PhaseEnergyAccountant:
    """Constant-memory per-phase energy accounting for serving runs.

    Owns the §4.8 control thread (RegionMarker + HostSampler) and a
    :class:`StreamingAggregator`; callers (the Engine) periodically call
    :meth:`drain` to fold newly collected samples into the per-region
    sufficient statistics and discard them. Region ids come from the
    process-wide registry, so the accumulators grow only with the number
    of distinct phases, not with run length.

    With ``spill_dir`` set, every ``spill_every``-th drain (one drain per
    scheduler step) atomically publishes this host's shard via a
    :class:`repro.core.exchange.ShardSpiller`, so a fleet of serving
    hosts can be reduced with ``gather_shards`` at any time — and a host
    killed mid-run loses at most ``spill_every`` epochs of samples.
    ``spill_mode="delta"`` (the default) publishes only the rows whose
    statistics changed since the last publish plus a periodic compacted
    base (``compact_every``), so steady-state spill bandwidth is O(rows
    touched per epoch), not O(distinct phases) — always-on fleet
    monitoring stays within ALEA's overhead budget. Cross-host
    region ids assume the hosts register serving phases in the same
    order (they do: phase names are code paths, not data).

    Spill failures (full disk, flaky NFS, injected faults) never kill
    the serving loop and never pass silently: a failed publish is
    retried at each subsequent :meth:`drain` up to ``spill_retries``
    consecutive attempts, then counted in :attr:`spill_drops` and
    abandoned until the next scheduled spill point. The aggregator is
    cumulative, so a later successful spill republishes everything a
    dropped one would have — a drop is a durability gap (a crash inside
    it loses those epochs' samples), not data loss in a surviving
    process. The final spill at ``__exit__`` raises instead of
    dropping.
    """

    def __init__(self, *, period: float = 2e-3, jitter: float = 1e-4,
                 seed: int = 0, sensor=None, spill_dir: str | None = None,
                 host_id: int = 0, spill_every: int = 50,
                 spill_mode: str = "delta", compact_every: int = 16,
                 spill_retries: int = 3, faults=None,
                 track_requests: bool = False,
                 max_combinations: int | None = None,
                 buffer_capacity: int | None = None):
        self.marker = RegionMarker()
        self.sampler = HostSampler(self.marker,
                                   sensor or available_host_sensor(),
                                   period=period, jitter=jitter, seed=seed,
                                   buffer_capacity=buffer_capacity)
        self._base_period = period
        # A multi-channel sensor bank (e.g. sensors.HostSensorBank over
        # PKG + DRAM rails) widens the accumulators to one column per
        # rail: estimates() then reports per-phase × per-domain energy.
        self.domains = self.sampler.domains
        self.agg = StreamingAggregator(len(regions_mod.registry.names),
                                       domains=self.domains)
        # Per-request attribution (the serving budget meter): request id
        # becomes a combination axis — width-2 (phase_rid, request_id)
        # rows through the same CombinationInterner path the §4.4
        # multi-worker attribution uses. A sample taken while k requests
        # are in flight is split 1/k across them, so the combination
        # psums partition the phase psums exactly (no double count).
        # ``max_combinations`` bounds that table (heavy-hitters tier):
        # a long-running fleet tracks at most that many identified
        # (phase, request) rows; the tail folds into per-phase `other`
        # buckets, so per-phase totals stay exact while memory stays
        # O(max_combinations) regardless of request count.
        self.track_requests = track_requests
        self.max_combinations = max_combinations
        self.request_agg = (StreamingCombinationAggregator(
            domains=self.domains, k=max_combinations)
            if track_requests else None)
        self._req_energy: dict[int, float] = {}   # cumulative J / request
        self._req_charges: dict[int, float] = {}  # J since last take
        self.spill_dir = spill_dir
        self.host_id = host_id
        self.spill_every = spill_every
        self._epoch = 0
        self._last_spill_epoch: int | None = None
        self._last_spill_path: str | None = None
        self._elapsed_offset = 0.0
        self._spiller = None
        self._ctx: contextlib.ExitStack | None = None
        self.spill_retries = spill_retries
        self.spill_failures = 0          # individual failed attempts
        self.spill_drops = 0             # retry budgets exhausted
        self.last_spill_error: OSError | None = None
        self._spill_pending = False      # retry at next drain
        self._spill_attempts = 0
        if spill_dir is not None:
            # Restart-and-rejoin: a killed host resumes from its own
            # LATEST shard instead of republishing a fresh low-epoch one
            # over it (which would silently drop all pre-crash samples).
            from repro.core.exchange import ShardSpiller
            self._spiller = ShardSpiller(spill_dir, host_id,
                                         mode=spill_mode,
                                         compact_every=compact_every,
                                         faults=faults)
            if self._spiller.resumed is not None:
                self.agg.merge(self._spiller.resumed)
                self._epoch = self._spiller.epoch
                # The restored epoch is already durable: spill() before
                # the next drain must be a no-op, not a republish.
                self._last_spill_epoch = self._epoch
                self._last_spill_path = self._spiller.resumed_dir
                meta = self._spiller.resumed_meta or {}
                # Pre-crash wall time rides in the shard meta; without it
                # estimates() would divide merged counts by only this
                # process's session time, inflating every p_hat.
                self._elapsed_offset = float(
                    meta.get("extra", {}).get("elapsed", 0.0))
        self._last_drain_elapsed = self._elapsed_offset

    def __enter__(self) -> "PhaseEnergyAccountant":
        self._ctx = contextlib.ExitStack()
        self._ctx.enter_context(regions_mod.profiling_session(self.marker))
        self._ctx.enter_context(self.sampler)
        return self

    def __exit__(self, *exc) -> None:
        assert self._ctx is not None
        self._ctx.close()
        self._ctx = None
        self.drain()
        if self._spiller is not None:
            # Final durable publish: a failure here would silently lose
            # the whole tail of the run, so it raises instead of being
            # queued behind drains that will never come.
            self.spill(raise_on_failure=True)

    def drain(self, active_requests=None) -> int:
        """Fold samples collected since the last drain; returns the count.

        Each call is one scheduler epoch; periodic durable spills happen
        here when configured.

        With ``track_requests`` set, ``active_requests`` names the
        request ids in flight while these samples were taken: each
        sample's power is split equally across them and folded into the
        per-(phase, request) combination table, and each request is
        charged its share of the wall-time × mean-power energy since the
        previous drain (consumed by the engine via
        :meth:`take_request_charges` to enforce budgets).
        """
        rids, pows = self.sampler.drain()
        now = self.elapsed
        dt = max(now - self._last_drain_elapsed, 0.0)
        self._last_drain_elapsed = now
        if len(rids):
            names = regions_mod.registry.names
            if len(names) > self.agg.num_regions:
                self.agg.grow(len(names))
            self.agg.update(rids, pows)
            if self.track_requests and active_requests:
                reqs = sorted({int(r) for r in active_requests})
                k = len(reqs)
                pows_arr = np.asarray(pows, np.float64)
                total = (pows_arr if pows_arr.ndim == 1
                         else pows_arr.sum(axis=1))
                share = dt * float(total.mean()) / k
                n = len(rids)
                mat = np.empty((n * k, 2), np.int64)
                for j, r in enumerate(reqs):
                    mat[j * n:(j + 1) * n, 0] = rids
                    mat[j * n:(j + 1) * n, 1] = r
                    self._req_energy[r] = (
                        self._req_energy.get(r, 0.0) + share)
                    self._req_charges[r] = (
                        self._req_charges.get(r, 0.0) + share)
                self.request_agg.update(
                    mat, np.concatenate([pows_arr / k] * k, axis=0))
        self._epoch += 1
        if self.spill_dir is not None and (
                self._spill_pending
                or (self.spill_every > 0
                    and self._epoch % self.spill_every == 0)):
            self.spill()
        return len(rids)

    @property
    def elapsed(self) -> float:
        """Accounted wall time: this session plus any resumed sessions."""
        return self._elapsed_offset + self.sampler.elapsed

    @property
    def epoch(self) -> int:
        """Drain epochs completed (the spill fence's clock)."""
        return self._epoch

    @property
    def last_spill_epoch(self) -> int | None:
        """Epoch of the last durable shard publish, if any — recorded in
        engine snapshots as the energy never-double-count fence."""
        return self._last_spill_epoch

    def spill(self, *, raise_on_failure: bool = False) -> str | None:
        """Durably publish this host's current shard (atomic, CRC'd).

        Idempotent within a drain epoch: a second call before the next
        :meth:`drain` (e.g. a shutdown hook racing the periodic spill)
        returns the already-published directory instead of pushing the
        same epoch through the manifest protocol twice.

        On I/O failure returns ``None`` (unless ``raise_on_failure``)
        and schedules a retry at the next drain; after ``spill_retries``
        consecutive failures the epoch is counted in
        :attr:`spill_drops` and abandoned — never retried forever,
        never dropped silently. Injected crashes
        (:class:`repro.core.faults.InjectedCrash`) are not I/O failures
        and propagate.
        """
        if self._last_spill_epoch == self._epoch:
            self._spill_pending = False
            return self._last_spill_path
        try:
            out = self._spiller.spill(self.agg, self._epoch,
                                      extra_meta={"elapsed": self.elapsed})
        except OSError as e:     # includes the SpillError hierarchy
            self.spill_failures += 1
            self.last_spill_error = e
            self._spill_attempts += 1
            if self._spill_attempts >= self.spill_retries:
                self.spill_drops += 1
                self._spill_attempts = 0
                self._spill_pending = False
            else:
                self._spill_pending = True
            if raise_on_failure:
                raise
            return None
        self._spill_attempts = 0
        self._spill_pending = False
        self._last_spill_epoch = self._epoch
        self._last_spill_path = out
        return out

    # -- serving hooks --------------------------------------------------------
    @property
    def sampling_period(self) -> float:
        """The live sampling period (the control thread reads it each
        iteration, so ladder widening takes effect immediately)."""
        return self.sampler.period

    def scale_period(self, factor: float) -> None:
        """Overload-ladder hook: widen the sampling period so the
        monitor stops competing with overloaded serving work (the
        energy-monitoring-cost critique from PAPERS.md). Scales from the
        construction-time base, so repeated calls don't compound."""
        self.sampler.period = self._base_period * float(factor)

    def reset_period(self) -> None:
        """Undo :meth:`scale_period` on ladder de-escalation."""
        self.sampler.period = self._base_period

    def shrink_tracking(self, max_combinations: int) -> None:
        """Overload-ladder hook: lower (never raise) the per-request
        combination table's heavy-hitters capacity in place. The
        lowest-count (phase, request) rows fold into their phase's
        ``other`` bucket — per-phase totals stay exact, so budgets and
        phase estimates are unaffected; only cold requests' identity
        coarsens. Irreversible by design (eviction already folded the
        tail), so de-escalation does not undo it."""
        if self.request_agg is None:
            return
        self.request_agg.shrink_k(max_combinations)
        self.max_combinations = self.request_agg.k

    def attribution_pressure(self) -> dict | None:
        """Interner pressure counters of the per-request combination
        table (None without ``track_requests``) — the ServeReport's
        ``attribution`` block."""
        if self.request_agg is None:
            return None
        return self.request_agg.interner_pressure()

    @property
    def buffer_overruns(self) -> int:
        """Samples dropped because the bounded ring was full — each one
        counted by the buffer, surfaced here for the ServeReport."""
        return self.sampler.buffer_overruns

    def take_request_charges(self) -> dict[int, float]:
        """Measured per-request joules accumulated since the last call
        (engine-side budget enforcement consumes these every step)."""
        out, self._req_charges = self._req_charges, {}
        return out

    def request_energy(self) -> dict[int, float]:
        """Cumulative measured J per request id (J/request headline)."""
        return dict(self._req_energy)

    def request_phase_energy(self) -> dict[int, dict[str, float]]:
        """Measured per-request × per-phase energy [J].

        The combination view of the same samples :meth:`estimates`
        aggregates per phase: each (phase, request) cell gets
        ``elapsed × psum_cell / n_total``, with psums split 1/k across
        the requests in flight at sample time — summing a phase's cells
        over requests recovers that phase's energy for the sampled
        in-flight intervals (no sample is double-counted).

        Under a bounded table (``max_combinations``) the folded tail
        appears under request id ``-1`` per phase — the per-phase
        ``other`` bucket — so the partition property still holds.
        """
        if self.request_agg is None:
            raise RuntimeError("accountant built without track_requests")
        out: dict[int, dict[str, float]] = {}
        if self.agg.n_total == 0:
            return out
        names = regions_mod.registry.names
        inner = self.request_agg.agg
        scale = self.elapsed / self.agg.n_total
        for cid, (phase_rid, rid) in enumerate(
                self.request_agg.interner.combos):
            e = scale * float(inner.chan_psum[cid].sum())
            out.setdefault(int(rid), {})[names[int(phase_rid)]] = e
        return out

    def estimates(self, alpha: float = 0.05) -> EstimateSet:
        """Per-phase estimates over everything drained so far.

        With a multi-channel sensor bank the table carries the per-phase
        per-domain decomposition (``table.e_rails`` /
        ``EstimateSet.energy_by_domain``).
        """
        if self.agg.n_total == 0:
            raise RuntimeError("no samples collected")
        return self.agg.estimates(self.elapsed,
                                  regions_mod.registry.names, alpha=alpha)

    def domain_energy(self) -> dict[str, dict[str, float]]:
        """Per-phase × per-domain energy [J] drained so far.

        The serving-fleet answer to "which phase burns energy on which
        rail": ``{phase: {domain: joules}}``. Single-channel sensors
        report their one ``"total"`` rail.
        """
        est = self.estimates()
        tbl = est.table
        if tbl.domains is None:
            return {tbl.names[i]: {"total": float(tbl.e_hat[i])}
                    for i in range(len(tbl))}
        return {tbl.names[i]: {d: float(tbl.e_rails[i, j])
                               for j, d in enumerate(tbl.domains)}
                for i in range(len(tbl))}

    @staticmethod
    def gather_estimates(spill_dir: str, t_exec: float,
                         alpha: float = 0.05) -> EstimateSet:
        """Fleet view: merge every host's published shard and estimate."""
        from repro.core.exchange import gather_shards
        merged = gather_shards(spill_dir)
        return merged.estimates(t_exec, regions_mod.registry.names,
                                alpha=alpha)


@functools.lru_cache(maxsize=None)
def _jitted_fns(cfg: ModelConfig):
    """(masked decode step, slot-state reset), shared across Engines.

    Keyed on the (frozen, hashable) model config so engines over the
    same architecture reuse one trace/compile per shape.
    """
    decode = jax.jit(
        lambda p, t, c, l, m: M.decode_step(p, cfg, t, c, l, write_mask=m))
    reset = jax.jit(lambda c, m: M.reset_cache_slots(cfg, c, m))
    return decode, reset


@functools.lru_cache(maxsize=None)
def _jitted_spec_fns(cfg: ModelConfig, window: int, sinks: int):
    """(windowed draft step, multi-position verify step) for
    self-speculative decoding, shared across Engines.

    Keyed on (config, window, sinks); within each jitted function the
    compile-key set is bounded by the token shapes fed to it — [B,1] for
    draft, [B,L] per speculation length L for verify — which the
    recompile guard pins (see tests/test_recompile_guard.py).

    Neither function donates its cache argument: the speculative step
    holds the window-start cache as the recurrent families' rollback
    checkpoint (and the KV families' verify input), so the buffers the
    jitted call consumes must stay alive after it returns.
    """
    draft = jax.jit(
        lambda p, t, c, l, m: M.decode_step(p, cfg, t, c, l, write_mask=m,
                                            window=window, sinks=sinks))
    verify = jax.jit(
        lambda p, t, c, l, m: M.decode_verify(p, cfg, t, c, l,
                                              write_mask=m))
    return draft, verify


@dataclasses.dataclass
class ServeConfig:
    max_batch: int = 8
    max_len: int = 512
    eos_token: int = 0
    cache_dtype: str = "bfloat16"
    # Deterministic energy proxy: J charged per slot per decode step (and
    # per prompt token at prefill) against each request's budget. Replayable
    # under the step clock — measured charges from a track_requests
    # accountant are added on top when one is attached.
    step_energy: float | None = None
    # Overload response (degraded rung): shrink the accountant's
    # per-request combination table to this heavy-hitters capacity when
    # the ladder widens sampling. None leaves the table alone. The
    # shrink is irreversible (the folded tail is gone), so
    # de-escalation restores the sampling period and the speculation
    # length but not the table capacity.
    degraded_max_combinations: int | None = None
    # -- self-speculative decoding (MagicDec-style, same weights) ----------
    # spec_len L >= 2 turns speculation on: each engine step drafts L-1
    # tokens per active slot with sliding-window attention, then one
    # batched verify scores all L positions; the greedy accept-prefix
    # keeps output token-exact to spec_len=0. 0 disables.
    spec_len: int = 0
    # StreamingLLM draft mask geometry: last `spec_window` positions plus
    # the first `spec_sinks` attention-sink positions.
    spec_window: int = 16
    spec_sinks: int = 4
    # Effective speculation length while the overload ladder is widened
    # (the degraded rung's L knob). None = speculation off under
    # overload; de-escalation restores spec_len through the same
    # unwiden edge that restores the sampling period.
    degraded_spec_len: int | None = None
    # Proxy J charged per drafted token (the windowed pass reads
    # O(window+sinks) cache rows instead of O(max_len)). Defaults to
    # step_energy * (spec_window + spec_sinks) / max_len.
    draft_energy: float | None = None


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray          # [S] int32
    max_new_tokens: int = 32
    out_tokens: list[int] = dataclasses.field(default_factory=list)
    done: bool = False
    # -- scheduling contract (engine step clock, never wall clock) ----------
    priority: int = 0               # higher admits first / sheds last
    deadline: int | None = None     # max steps after submit (incl. queue wait)
    energy_budget: float | None = None  # max charged J before mid-decode abort
    status: str = "queued"
    energy_j: float = 0.0           # charged so far (proxy + measured)
    submit_step: int = 0


@dataclasses.dataclass(frozen=True)
class JoulesPerToken:
    """A quotable live J/token price signal (satellite of ROADMAP item 1).

    ``j_per_token`` is total decode-phase energy (serve/decode +
    serve/draft + serve/verify) divided by tokens emitted this session;
    ``lo``/``hi`` carry the same ratio through the phases' summed Wald
    interval bounds (estimator Eq. 16), so the CI reflects sampling
    uncertainty in the energy numerator (the token count is exact).
    """
    j_per_token: float
    lo: float
    hi: float
    alpha: float
    tokens: int
    energy_j: float
    phases: tuple[str, ...]
    domain: str | None = None


# Phases that count toward the J/token quote: the decode hot path in all
# its forms. serve/prefill is admission-side work (priced separately by
# the per-prompt-token proxy) and serve/replay is recovery/rollback
# bookkeeping — charging either to the per-emitted-token price would
# make the quote depend on restore history.
_JPT_PHASES = ("serve/decode", "serve/draft", "serve/verify")


class Engine:
    """Slot-based continuous batching over the pure decode step.

    With ``ServeConfig.spec_len`` set, the engine runs self-speculative
    decoding: each step drafts ``L-1`` tokens per slot with a cheap
    sliding-window pass over the *same* weights, then verifies all L
    positions in one batched target step and emits the greedy-accepted
    prefix plus the verify's bonus token — token-exact to the
    non-speculative engine by construction (see :meth:`step`).
    """

    def __init__(self, cfg: ModelConfig, params, serve_cfg: ServeConfig,
                 *, sample: Callable | None = None,
                 accountant: PhaseEnergyAccountant | None = None,
                 scheduler: ServeScheduler | None = None, faults=None):
        self.cfg = cfg
        self.params = params
        self.scfg = serve_cfg
        self.accountant = accountant
        self.scheduler = scheduler or ServeScheduler()
        self.report = self.scheduler.report
        # Deterministic step clock: number of completed engine steps.
        # Deadlines, budgets, snapshots and injected crashes are all
        # keyed on it, never on wall time.
        self.step_count = 0
        self._faults = faults
        self._requests: dict[int, Request] = {}
        B, T = serve_cfg.max_batch, serve_cfg.max_len
        dt = jnp.bfloat16 if serve_cfg.cache_dtype == "bfloat16" else jnp.float32
        self.cache = M.init_cache(cfg, B, T, dtype=dt)
        self.tokens = np.zeros((B, 1), np.int32)
        self.slot_req: list[Request | None] = [None] * B
        self.slot_len = np.zeros(B, np.int32)
        self.sample = sample or (lambda logits: jnp.argmax(logits, -1))
        # Session-local emitted-token counter for the J/token quote
        # (serve/replay work after a restore re-derives cache state for
        # tokens a previous session already emitted and charged, so
        # neither its energy nor its tokens enter the price).
        self._tokens_emitted = 0

        self._draft_step = self._verify_step = None
        if serve_cfg.spec_len:
            if serve_cfg.spec_len < 2:
                raise ValueError(
                    f"spec_len={serve_cfg.spec_len}: speculation needs a "
                    "verify width of at least 2 (1 draft + 1 bonus); use "
                    "0 to disable")
            if serve_cfg.degraded_spec_len is not None and not (
                    2 <= serve_cfg.degraded_spec_len <= serve_cfg.spec_len):
                raise ValueError(
                    f"degraded_spec_len={serve_cfg.degraded_spec_len} must "
                    f"be in [2, spec_len={serve_cfg.spec_len}] or None "
                    "(None = speculation off under overload)")
            if sample is not None:
                # The accept rule compares draft tokens against the
                # verify argmax; a non-greedy sampler would make
                # "token-exact to the baseline" ill-defined.
                raise ValueError(
                    "speculative decoding is token-exact only under the "
                    "default greedy sampler; pass sample=None with "
                    "spec_len > 0")
            self._draft_step, self._verify_step = _jitted_spec_fns(
                cfg, serve_cfg.spec_window, serve_cfg.spec_sinks)

        # Cache-position contract: every decode step takes a [B] per-slot
        # position vector — each slot's K/V is written at its OWN length
        # (a single scalar would leave gaps for short slots and overwrite
        # live entries of long ones under ragged continuous batching) —
        # plus a [B] write mask confining cache mutation to the slot
        # being prefilled (prefill) / the active slots (decode steps, so
        # free slots' recurrent SSM/xLSTM state doesn't advance on
        # garbage tokens between requests).
        self._decode_masked, self._reset_slots = _jitted_fns(cfg)

    # -- host scheduler --------------------------------------------------------
    def _free_slots(self) -> list[int]:
        return [i for i, r in enumerate(self.slot_req) if r is None]

    def _validate(self, req: Request) -> None:
        if len(req.prompt) == 0:
            # Without at least one prompt token there are no logits to
            # sample the first output token from (and the teacher-forced
            # prefill loop below would leave `logits` unbound).
            raise ValueError(f"request {req.rid}: empty prompt")
        if len(req.prompt) + 1 > self.scfg.max_len:
            # The cache ring holds max_len positions; the prompt plus at
            # least the first generated token must fit or the decode
            # write would run past the ring.
            raise ValueError(
                f"request {req.rid}: prompt length {len(req.prompt)} "
                f"does not fit max_len {self.scfg.max_len} "
                f"(need len(prompt) + 1 <= max_len)")

    def submit(self, req: Request) -> None:
        """Queue-admission edge: enqueue for the scheduler to admit as
        slots free up. Raises typed ``AdmissionError`` subclasses on
        rejection — every rejection is counted in :attr:`report` first,
        never silent. ``add_request`` remains the direct-placement path
        (bypasses the queue; returns False when no slot is free)."""
        self._validate(req)
        self._requests[req.rid] = req
        self.scheduler.submit(req, self.step_count)

    def add_request(self, req: Request) -> bool:
        self._validate(req)
        if not self._free_slots():
            return False
        if req.rid not in self.report:
            self.report.open(req.rid, status="queued",
                             step=self.step_count, priority=req.priority)
            req.submit_step = self.step_count
        self._place(req)
        return True

    def _place(self, req: Request) -> None:
        """Prefill ``req`` into the first free slot (caller checked one
        exists) and mark it admitted."""
        s = self._free_slots()[0]
        self.slot_req[s] = req
        self._requests[req.rid] = req
        mask = np.zeros(len(self.slot_req), bool)
        mask[s] = True
        # Zero the claimed slot's cache state: recurrent SSM/xLSTM state
        # is *input* to the next step, so a reused slot would otherwise
        # seed this request with its previous occupant's final state
        # (KV rows are rewritten by prefill anyway).
        self.cache = self._reset_slots(self.cache, jnp.asarray(mask))
        # Prefill via teacher-forced decode steps on this slot (host loop;
        # fine at example scale). Writes are masked to slot s: the decode
        # step runs the whole batch, and without the mask every
        # concurrently-active slot's cache (KV at position t, and any
        # recurrent state) would be stomped at each prompt position.
        cur = self.slot_len.astype(np.int32).copy()
        with regions_mod.region("serve/prefill"):
            for t, tok in enumerate(req.prompt):
                self.tokens[s, 0] = tok
                cur[s] = t
                # Hand jax a FRESH host buffer each step: the host→device
                # transfer is async, and this loop mutates
                # self.tokens/cur in place while earlier decode steps may
                # still be in flight — a shared buffer hands those steps
                # the *next* iteration's values (observed as
                # nondeterministic prefill logits on CPU).
                logits, self.cache = self._decode_masked(
                    self.params, jnp.asarray(self.tokens.copy()),
                    self.cache, jnp.asarray(cur.copy()), jnp.asarray(mask))
                if self.accountant is not None and t % 32 == 31:
                    # A long prefill is many sampler periods with no
                    # scheduler step in between: drain mid-loop so the
                    # bounded ring can't overrun (satellite of the
                    # never-silent contract — overruns that do happen
                    # are counted, see SampleBuffer.overruns).
                    self.accountant.drain(active_requests=(req.rid,))
        self.slot_len[s] = len(req.prompt)
        self.tokens[s, 0] = int(np.asarray(
            self.sample(logits[s:s + 1, -1, :]))[0])
        rec = self.report.set_status(req.rid, "admitted")
        rec.admit_step = self.step_count
        req.status = "admitted"
        if self.scfg.step_energy is not None:
            self._charge(req, self.scfg.step_energy * len(req.prompt))
        if self.accountant is not None:
            self.accountant.drain(active_requests=(req.rid,))
            self._apply_measured_charges()

    # -- energy charging -------------------------------------------------------
    def _charge(self, req: Request, joules: float) -> None:
        req.energy_j += joules
        if req.rid in self.report:
            self.report.request(req.rid).energy_j = req.energy_j

    def _apply_measured_charges(self) -> None:
        if self.accountant is None or not self.accountant.track_requests:
            return
        for rid, dj in self.accountant.take_request_charges().items():
            req = self._requests.get(rid)
            if req is not None:
                self._charge(req, dj)
        # Pressure counters ride on the report so fleet dashboards see
        # interner growth (and bounded-mode folds) without touching the
        # accountant directly.
        self.report.attribution = self.accountant.attribution_pressure()

    def _widen_sampling(self, factor: float) -> None:
        if self.accountant is not None:
            self.accountant.scale_period(factor)
            if self.scfg.degraded_max_combinations is not None:
                self.accountant.shrink_tracking(
                    self.scfg.degraded_max_combinations)

    def _restore_sampling(self) -> None:
        # The single de-escalation reset path: the scheduler's unwiden
        # edge clears its widened flag (restoring the effective
        # speculation length, which is derived from that flag — see
        # _spec_len_now) and lands here to restore the sampling period.
        if self.accountant is not None:
            self.accountant.reset_period()

    def _spec_len_now(self) -> int:
        """Effective speculation length this step: the configured L,
        shrunk to ``degraded_spec_len`` (or off, when that is None)
        while the overload ladder is widened. A pure function of
        snapshot-carried scheduler state, so restored engines speculate
        identically to the uninterrupted run."""
        L = self.scfg.spec_len
        if not L or not self.scheduler.widened:
            return L
        d = self.scfg.degraded_spec_len
        return 0 if d is None else min(d, L)

    def _draft_energy(self) -> float:
        de = self.scfg.draft_energy
        if de is not None:
            return de
        frac = (self.scfg.spec_window + self.scfg.spec_sinks) / max(
            self.scfg.max_len, 1)
        return self.scfg.step_energy * min(frac, 1.0)

    def step(self) -> list[Request]:
        """One engine step: admit queued requests into free slots, run
        the overload ladder, decode every active slot (one token
        baseline, or one speculation window of up to ``spec_len`` tokens
        — see :meth:`_step_speculative`), charge energy, and enforce
        deadlines/budgets. Returns requests that left their slot this
        step — completed (``done=True``) or aborted (typed status,
        partial ``out_tokens``, ``done=False``)."""
        step = self.step_count
        plan = resolve_plan(self._faults)
        if plan is not None and plan.serve_crash_at(step):
            # Before ANY mutation: a killed step leaves the engine
            # exactly as the previous step published it, so the
            # snapshot/restore contract is bit-exact.
            raise InjectedCrash(
                f"injected crash at engine step {step} "
                f"({_SITE_STEP_CRASH})")
        while self._free_slots():
            req = self.scheduler.admit(step)
            if req is None:
                break
            self._place(req)
        self.scheduler.tick(step, widen_fn=self._widen_sampling,
                            unwiden_fn=self._restore_sampling)
        active = [i for i, r in enumerate(self.slot_req) if r is not None]
        finished: list[Request] = []
        if active:
            L = self._spec_len_now()
            # Speculation needs room for all L cache writes in every
            # active slot; near the ring's end this window falls back to
            # the baseline single-token step (same compile key as
            # prefill, so the key set stays bounded).
            if L and max(int(self.slot_len[s]) for s in active
                         ) + L <= self.scfg.max_len - 1:
                finished = self._step_speculative(step, active, L)
            else:
                finished = self._step_baseline(step, active)
        if self.accountant is not None:
            # Fold freshly sampled (phase, power) pairs into the
            # streaming accumulators; the raw stream never accumulates.
            rids = tuple(r.rid for r in self.slot_req if r is not None)
            self.accountant.drain(active_requests=rids or None)
            self._apply_measured_charges()
            self.report.buffer_overruns = self.accountant.buffer_overruns
        # Deadline / budget enforcement after this step's work is charged:
        # the violator leaves with partial output and a typed status.
        for s, r in enumerate(self.slot_req):
            if r is None:
                continue
            age = (step + 1) - self.report.request(r.rid).submit_step
            if r.deadline is not None and age >= r.deadline:
                self._release(
                    s, "aborted_deadline", step,
                    error=f"deadline {r.deadline} steps reached "
                          f"(age {age} at end of step {step})")
                finished.append(r)
            elif (r.energy_budget is not None
                    and r.energy_j > r.energy_budget):
                self._release(
                    s, "aborted_budget", step,
                    error=f"charged {r.energy_j:.6g} J exceeds budget "
                          f"{r.energy_budget:.6g} J")
                finished.append(r)
        self.step_count = step + 1
        return finished

    def _step_baseline(self, step: int, active: list[int]) -> list[Request]:
        """Advance every active slot one token (the non-speculative hot
        path, and the speculative engine's fallback near the cache
        ring's end)."""
        finished: list[Request] = []
        # Mask writes to active slots: free slots must not advance
        # their recurrent state on the garbage tokens in their rows.
        mask = np.asarray([r is not None for r in self.slot_req])
        with regions_mod.region("serve/decode"):
            # Fresh host buffers (see prefill loop): the scheduler
            # mutates self.tokens/slot_len right after this dispatch.
            logits, self.cache = self._decode_masked(
                self.params, jnp.asarray(self.tokens.copy()),
                self.cache,
                jnp.asarray(self.slot_len.astype(np.int32)),
                jnp.asarray(mask))
        nxt = np.asarray(self.sample(logits[:, -1, :]))
        for s in active:
            r = self.slot_req[s]
            r.out_tokens.append(int(self.tokens[s, 0]))
            self.slot_len[s] += 1
            self._tokens_emitted += 1
            self.tokens[s, 0] = int(nxt[s])
            if self.scfg.step_energy is not None:
                self._charge(r, self.scfg.step_energy)
            hit_eos = int(nxt[s]) == self.scfg.eos_token
            if (len(r.out_tokens) >= r.max_new_tokens or hit_eos
                    or self.slot_len[s] >= self.scfg.max_len - 1):
                r.done = True
                self._release(s, "completed", step)
                finished.append(r)
        return finished

    def _step_speculative(self, step: int, active: list[int],
                          L: int) -> list[Request]:
        """One speculation window: draft L-1 tokens per slot with the
        windowed pass, verify all L positions in one batched target
        step, emit the greedy-accepted prefix plus the verify's bonus
        token.

        Token-exactness argument, per cache family:

        * The verify step writes each slot's L fresh K/V rows and then
          attends over the full cache under per-position causal masks —
          the same reduction the single-token step performs — so its
          logits are the baseline's logits wherever the input prefix
          matches, which the accept rule guarantees position by
          position (accepted token j+1 must equal argmax of verify
          position j; the first mismatch truncates the window and the
          verify argmax itself is emitted, exactly the token the
          baseline would have produced).
        * KV families (dense/moe) roll back rejected positions by slot
          length alone: rows past ``slot_len`` are invisible to every
          mask and are rewritten by the next window before they can be
          read.
        * Recurrent families (ssm/hybrid) advance state once per call,
          so rejected drafts would leave wrong state behind. The
          window-start cache (immutable jax arrays — holding the
          reference IS the checkpoint) is the verify input and the
          rollback target: after acceptance the emitted tokens are
          replayed from the checkpoint through the baseline masked
          single-token step (bit-exact by construction, no new compile
          key) under the ``serve/replay`` phase.

        The window is atomic on the step clock: the injected-crash site
        fires before any mutation, so snapshots only ever observe
        window boundaries and mid-window kill-and-restore is bit-exact.
        """
        scfg = self.scfg
        rep = self.report
        recurrent = self.cfg.family in ("ssm", "hybrid")
        mask = np.asarray([r is not None for r in self.slot_req])
        checkpoint = self.cache        # window-start state (see docstring)
        n0 = self.slot_len.astype(np.int32).copy()

        # Draft matrix row s: [t0, d1, .., d_{L-1}] — the pending token
        # followed by L-1 windowed-greedy proposals.
        draft = np.zeros((len(self.slot_req), L), np.int32)
        draft[:, 0] = self.tokens[:, 0]
        cur = n0.copy()
        toks = self.tokens.copy()
        with regions_mod.region("serve/draft"):
            for j in range(1, L):
                logits, self.cache = self._draft_step(
                    self.params, jnp.asarray(toks.copy()), self.cache,
                    jnp.asarray(cur.copy()), jnp.asarray(mask))
                prop = np.asarray(jnp.argmax(logits[:, -1, :], -1))
                draft[:, j] = prop
                toks[:, 0] = prop
                cur += 1

        # One batched target step scores all L positions. KV families
        # verify on the post-draft cache (the draft already wrote rows
        # n0..n0+L-2; verify rewrites n0..n0+L-1 with its own K/V);
        # recurrent families verify from the checkpoint.
        vin = checkpoint if recurrent else self.cache
        with regions_mod.region("serve/verify"):
            vlogits, vcache = self._verify_step(
                self.params, jnp.asarray(draft), vin,
                jnp.asarray(n0.copy()), jnp.asarray(mask))
        v = np.asarray(jnp.argmax(vlogits, -1))        # [B, L]
        if not recurrent:
            self.cache = vcache

        # Proxy charges: the windowed draft reads O(window) cache rows
        # per token; the verify is one full-cache sweep per slot
        # regardless of L (the MagicDec bandwidth model — that is the
        # whole win).
        if scfg.step_energy is not None:
            de = self._draft_energy()
            for s in active:
                self._charge(self.slot_req[s],
                             de * (L - 1) + scfg.step_energy)

        # Greedy accept-prefix, mirroring the baseline's per-token
        # emit/finish semantics exactly.
        finished: list[Request] = []
        emitted: dict[int, list[int]] = {}
        for s in active:
            r = self.slot_req[s]
            rec = rep.request(r.rid)
            rep.drafted += L - 1
            rec.spec_drafted += L - 1
            accepted = 0
            seq: list[int] = []
            pend = int(draft[s, 0])
            released = False
            for j in range(L):
                r.out_tokens.append(pend)
                seq.append(pend)
                self.slot_len[s] += 1
                self._tokens_emitted += 1
                nxt = int(v[s, j])
                hit_eos = nxt == scfg.eos_token
                if (len(r.out_tokens) >= r.max_new_tokens or hit_eos
                        or self.slot_len[s] >= scfg.max_len - 1):
                    r.done = True
                    self._release(s, "completed", step)
                    finished.append(r)
                    released = True
                    break
                if j + 1 < L and int(draft[s, j + 1]) == nxt:
                    accepted += 1
                    pend = nxt
                    continue
                pend = nxt          # first mismatch (or bonus token)
                break
            if not released:
                self.tokens[s, 0] = pend
                emitted[s] = seq
            rep.accepted += accepted
            rep.rejected += (L - 1) - accepted
            rec.spec_accepted += accepted
            if accepted < L - 1:
                rep.rollbacks += 1

        if recurrent:
            # Roll back to the window-start checkpoint and replay each
            # surviving slot's emitted tokens through the baseline
            # masked step. Released slots skip replay: admission resets
            # their state before reuse.
            self.cache = checkpoint
            depth = max((len(t) for t in emitted.values()), default=0)
            rcur = n0.copy()
            rtoks = self.tokens.copy()
            with regions_mod.region("serve/replay"):
                for k in range(depth):
                    wmask = np.zeros(len(self.slot_req), bool)
                    for s, t in emitted.items():
                        if k < len(t):
                            wmask[s] = True
                            rtoks[s, 0] = t[k]
                    _, self.cache = self._decode_masked(
                        self.params, jnp.asarray(rtoks.copy()), self.cache,
                        jnp.asarray(rcur.copy()), jnp.asarray(wmask))
                    rcur += wmask
        return finished

    def current_joules_per_token(self, *, alpha: float = 0.05,
                                 max_rel_halfwidth: float = 0.5,
                                 domain: str | None = None
                                 ) -> JoulesPerToken:
        """Live J/token over the decode phases (serve/decode +
        serve/draft + serve/verify), with the streaming Wald CI carried
        through — the admission price-tier signal from ROADMAP item 1.

        Raises :class:`PriceSignalUnavailableError` (typed, never a
        silent bad quote) when no accountant is attached, nothing has
        been emitted or drained yet, any decode phase's CI is invalid
        (estimator Eq. 16 normality guard), or the summed CI halfwidth
        exceeds ``max_rel_halfwidth`` of the estimate. ``domain``
        selects one rail of a multi-channel sensor bank (e.g. "hbm" for
        the accepted-tokens-per-HBM-joule headline).
        """
        if self.accountant is None:
            raise PriceSignalUnavailableError(
                "no accountant attached: the J/token quote needs "
                "measured phase energy, not the step_energy proxy")
        if self._tokens_emitted <= 0:
            raise PriceSignalUnavailableError(
                "no tokens emitted this session yet")
        try:
            est = self.accountant.estimates(alpha)
        except RuntimeError as e:
            raise PriceSignalUnavailableError(
                f"no samples drained yet: {e}") from e
        tbl = est.table
        # Only phases that have actually been sampled participate: a
        # zero-sample row (e.g. serve/draft interned but speculation
        # off) contributes no energy and its Wald guard is vacuously
        # invalid — it must not block the quote.
        idx = [i for i in range(len(tbl)) if tbl.names[i] in _JPT_PHASES
               and int(tbl.n_samples[i]) > 0]
        if not idx:
            raise PriceSignalUnavailableError(
                "no decode-phase samples yet (phases "
                f"{_JPT_PHASES} absent from the estimate table)")
        invalid = [tbl.names[i] for i in idx if not bool(tbl.ci_valid[i])]
        if invalid:
            raise PriceSignalUnavailableError(
                f"Wald CI not yet valid for phase(s) {invalid} "
                "(normality guard n*p>5 — keep serving and re-quote)")
        if domain is None:
            e = float(sum(tbl.e_hat[i] for i in idx))
            lo = float(sum(tbl.e_lo[i] for i in idx))
            hi = float(sum(tbl.e_hi[i] for i in idx))
        else:
            if tbl.domains is None or domain not in tbl.domains:
                raise PriceSignalUnavailableError(
                    f"domain {domain!r} not measured (sensor rails: "
                    f"{tbl.domains})")
            j = tbl.domains.index(domain)
            e = float(sum(tbl.e_rails[i, j] for i in idx))
            lo = float(sum(tbl.e_rails_lo[i, j] for i in idx))
            hi = float(sum(tbl.e_rails_hi[i, j] for i in idx))
        half = 0.5 * (hi - lo)
        if e <= 0.0 or half > max_rel_halfwidth * e:
            raise PriceSignalUnavailableError(
                f"CI too wide to quote: halfwidth {half:.3g} J on "
                f"{e:.3g} J exceeds {max_rel_halfwidth:.0%} "
                "(keep serving and re-quote)")
        t = self._tokens_emitted
        return JoulesPerToken(
            j_per_token=e / t, lo=lo / t, hi=hi / t, alpha=alpha,
            tokens=t, energy_j=e,
            phases=tuple(tbl.names[i] for i in idx), domain=domain)

    def _release(self, s: int, status: str, step: int,
                 error: str | None = None) -> None:
        r = self.slot_req[s]
        r.status = status
        rec = self.report.set_status(r.rid, status, step=step, error=error)
        rec.tokens_out = len(r.out_tokens)
        self.slot_req[s] = None
        self.slot_len[s] = 0

    def run_until_drained(self, requests: list[Request],
                          max_steps: int = 10_000) -> list[Request]:
        """Drive the engine until every pending, queued and in-flight
        request has left its slot. Raises :class:`ServeTimeoutError`
        carrying the undrained request ids if ``max_steps`` elapses with
        work still outstanding — never a silent partial return."""
        done: list[Request] = []
        pending = list(requests)
        for _ in range(max_steps):
            while pending and self._free_slots():
                self.add_request(pending.pop(0))
            done += self.step()
            if (not pending and not len(self.scheduler.queue)
                    and all(r is None for r in self.slot_req)):
                return done
        undrained = sorted(
            [r.rid for r in pending]
            + [r.rid for r in self.slot_req if r is not None]
            + [e[2].rid for e in self.scheduler.queue.snapshot()])
        raise ServeTimeoutError(
            f"{len(undrained)} request(s) undrained after {max_steps} "
            f"steps: {undrained}", undrained)

    # -- durability ------------------------------------------------------------
    def snapshot(self, path: str) -> str:
        """Publish a durable crash-recovery snapshot under ``path``
        (see :mod:`repro.serve.recovery` for the contract)."""
        from repro.serve.recovery import snapshot as _snapshot
        return _snapshot(self, path)

    @classmethod
    def restore(cls, cfg: ModelConfig, params, serve_cfg: ServeConfig,
                path: str, **kwargs) -> "Engine":
        """Rebuild an engine from its last durable snapshot, replaying
        generated prefixes so subsequent tokens are bit-exact with the
        uninterrupted run (:func:`repro.serve.recovery.restore_engine`)."""
        from repro.serve.recovery import restore_engine
        return restore_engine(cfg, params, serve_cfg, path, **kwargs)
