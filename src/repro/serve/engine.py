"""Serving engine: pjit'd prefill/decode steps + a continuous-batching
host scheduler (slot-based, vLLM-lite).

The device side is two pure functions (prefill fills a slot's cache pages;
decode advances every active slot one token). The host side packs requests
into fixed slots so the decode step shape stays static (no recompiles).
ALEA regions wrap both so serving energy is attributable per phase:
attach a :class:`PhaseEnergyAccountant` and the engine drains the host
sampler's ring buffer into a StreamingAggregator after every scheduler
step — a serving run of any length holds O(R + drain chunk) profiling
state, never the full sample stream.
"""

from __future__ import annotations

import contextlib
import dataclasses
import functools
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import regions as regions_mod
from repro.core.estimator import EstimateSet
from repro.core.sampler import HostSampler, RegionMarker
from repro.core.sensors import available_host_sensor
from repro.core.streaming import StreamingAggregator
from repro.models import model as M

__all__ = ["ServeConfig", "Request", "Engine", "PhaseEnergyAccountant"]


class PhaseEnergyAccountant:
    """Constant-memory per-phase energy accounting for serving runs.

    Owns the §4.8 control thread (RegionMarker + HostSampler) and a
    :class:`StreamingAggregator`; callers (the Engine) periodically call
    :meth:`drain` to fold newly collected samples into the per-region
    sufficient statistics and discard them. Region ids come from the
    process-wide registry, so the accumulators grow only with the number
    of distinct phases, not with run length.

    With ``spill_dir`` set, every ``spill_every``-th drain (one drain per
    scheduler step) atomically publishes this host's shard via a
    :class:`repro.core.exchange.ShardSpiller`, so a fleet of serving
    hosts can be reduced with ``gather_shards`` at any time — and a host
    killed mid-run loses at most ``spill_every`` epochs of samples.
    ``spill_mode="delta"`` (the default) publishes only the rows whose
    statistics changed since the last publish plus a periodic compacted
    base (``compact_every``), so steady-state spill bandwidth is O(rows
    touched per epoch), not O(distinct phases) — always-on fleet
    monitoring stays within ALEA's overhead budget. Cross-host
    region ids assume the hosts register serving phases in the same
    order (they do: phase names are code paths, not data).

    Spill failures (full disk, flaky NFS, injected faults) never kill
    the serving loop and never pass silently: a failed publish is
    retried at each subsequent :meth:`drain` up to ``spill_retries``
    consecutive attempts, then counted in :attr:`spill_drops` and
    abandoned until the next scheduled spill point. The aggregator is
    cumulative, so a later successful spill republishes everything a
    dropped one would have — a drop is a durability gap (a crash inside
    it loses those epochs' samples), not data loss in a surviving
    process. The final spill at ``__exit__`` raises instead of
    dropping.
    """

    def __init__(self, *, period: float = 2e-3, jitter: float = 1e-4,
                 seed: int = 0, sensor=None, spill_dir: str | None = None,
                 host_id: int = 0, spill_every: int = 50,
                 spill_mode: str = "delta", compact_every: int = 16,
                 spill_retries: int = 3, faults=None):
        self.marker = RegionMarker()
        self.sampler = HostSampler(self.marker,
                                   sensor or available_host_sensor(),
                                   period=period, jitter=jitter, seed=seed)
        # A multi-channel sensor bank (e.g. sensors.HostSensorBank over
        # PKG + DRAM rails) widens the accumulators to one column per
        # rail: estimates() then reports per-phase × per-domain energy.
        self.domains = self.sampler.domains
        self.agg = StreamingAggregator(len(regions_mod.registry.names),
                                       domains=self.domains)
        self.spill_dir = spill_dir
        self.host_id = host_id
        self.spill_every = spill_every
        self._epoch = 0
        self._last_spill_epoch: int | None = None
        self._last_spill_path: str | None = None
        self._elapsed_offset = 0.0
        self._spiller = None
        self._ctx: contextlib.ExitStack | None = None
        self.spill_retries = spill_retries
        self.spill_failures = 0          # individual failed attempts
        self.spill_drops = 0             # retry budgets exhausted
        self.last_spill_error: OSError | None = None
        self._spill_pending = False      # retry at next drain
        self._spill_attempts = 0
        if spill_dir is not None:
            # Restart-and-rejoin: a killed host resumes from its own
            # LATEST shard instead of republishing a fresh low-epoch one
            # over it (which would silently drop all pre-crash samples).
            from repro.core.exchange import ShardSpiller
            self._spiller = ShardSpiller(spill_dir, host_id,
                                         mode=spill_mode,
                                         compact_every=compact_every,
                                         faults=faults)
            if self._spiller.resumed is not None:
                self.agg.merge(self._spiller.resumed)
                self._epoch = self._spiller.epoch
                # The restored epoch is already durable: spill() before
                # the next drain must be a no-op, not a republish.
                self._last_spill_epoch = self._epoch
                self._last_spill_path = self._spiller.resumed_dir
                meta = self._spiller.resumed_meta or {}
                # Pre-crash wall time rides in the shard meta; without it
                # estimates() would divide merged counts by only this
                # process's session time, inflating every p_hat.
                self._elapsed_offset = float(
                    meta.get("extra", {}).get("elapsed", 0.0))

    def __enter__(self) -> "PhaseEnergyAccountant":
        self._ctx = contextlib.ExitStack()
        self._ctx.enter_context(regions_mod.profiling_session(self.marker))
        self._ctx.enter_context(self.sampler)
        return self

    def __exit__(self, *exc) -> None:
        assert self._ctx is not None
        self._ctx.close()
        self._ctx = None
        self.drain()
        if self._spiller is not None:
            # Final durable publish: a failure here would silently lose
            # the whole tail of the run, so it raises instead of being
            # queued behind drains that will never come.
            self.spill(raise_on_failure=True)

    def drain(self) -> int:
        """Fold samples collected since the last drain; returns the count.

        Each call is one scheduler epoch; periodic durable spills happen
        here when configured.
        """
        rids, pows = self.sampler.drain()
        if len(rids):
            names = regions_mod.registry.names
            if len(names) > self.agg.num_regions:
                self.agg.grow(len(names))
            self.agg.update(rids, pows)
        self._epoch += 1
        if self.spill_dir is not None and (
                self._spill_pending
                or (self.spill_every > 0
                    and self._epoch % self.spill_every == 0)):
            self.spill()
        return len(rids)

    @property
    def elapsed(self) -> float:
        """Accounted wall time: this session plus any resumed sessions."""
        return self._elapsed_offset + self.sampler.elapsed

    def spill(self, *, raise_on_failure: bool = False) -> str | None:
        """Durably publish this host's current shard (atomic, CRC'd).

        Idempotent within a drain epoch: a second call before the next
        :meth:`drain` (e.g. a shutdown hook racing the periodic spill)
        returns the already-published directory instead of pushing the
        same epoch through the manifest protocol twice.

        On I/O failure returns ``None`` (unless ``raise_on_failure``)
        and schedules a retry at the next drain; after ``spill_retries``
        consecutive failures the epoch is counted in
        :attr:`spill_drops` and abandoned — never retried forever,
        never dropped silently. Injected crashes
        (:class:`repro.core.faults.InjectedCrash`) are not I/O failures
        and propagate.
        """
        if self._last_spill_epoch == self._epoch:
            self._spill_pending = False
            return self._last_spill_path
        try:
            out = self._spiller.spill(self.agg, self._epoch,
                                      extra_meta={"elapsed": self.elapsed})
        except OSError as e:     # includes the SpillError hierarchy
            self.spill_failures += 1
            self.last_spill_error = e
            self._spill_attempts += 1
            if self._spill_attempts >= self.spill_retries:
                self.spill_drops += 1
                self._spill_attempts = 0
                self._spill_pending = False
            else:
                self._spill_pending = True
            if raise_on_failure:
                raise
            return None
        self._spill_attempts = 0
        self._spill_pending = False
        self._last_spill_epoch = self._epoch
        self._last_spill_path = out
        return out

    def estimates(self, alpha: float = 0.05) -> EstimateSet:
        """Per-phase estimates over everything drained so far.

        With a multi-channel sensor bank the table carries the per-phase
        per-domain decomposition (``table.e_rails`` /
        ``EstimateSet.energy_by_domain``).
        """
        if self.agg.n_total == 0:
            raise RuntimeError("no samples collected")
        return self.agg.estimates(self.elapsed,
                                  regions_mod.registry.names, alpha=alpha)

    def domain_energy(self) -> dict[str, dict[str, float]]:
        """Per-phase × per-domain energy [J] drained so far.

        The serving-fleet answer to "which phase burns energy on which
        rail": ``{phase: {domain: joules}}``. Single-channel sensors
        report their one ``"total"`` rail.
        """
        est = self.estimates()
        tbl = est.table
        if tbl.domains is None:
            return {tbl.names[i]: {"total": float(tbl.e_hat[i])}
                    for i in range(len(tbl))}
        return {tbl.names[i]: {d: float(tbl.e_rails[i, j])
                               for j, d in enumerate(tbl.domains)}
                for i in range(len(tbl))}

    @staticmethod
    def gather_estimates(spill_dir: str, t_exec: float,
                         alpha: float = 0.05) -> EstimateSet:
        """Fleet view: merge every host's published shard and estimate."""
        from repro.core.exchange import gather_shards
        merged = gather_shards(spill_dir)
        return merged.estimates(t_exec, regions_mod.registry.names,
                                alpha=alpha)


@functools.lru_cache(maxsize=None)
def _jitted_fns(cfg: ModelConfig):
    """(masked decode step, slot-state reset), shared across Engines.

    Keyed on the (frozen, hashable) model config so engines over the
    same architecture reuse one trace/compile per shape.
    """
    decode = jax.jit(
        lambda p, t, c, l, m: M.decode_step(p, cfg, t, c, l, write_mask=m))
    reset = jax.jit(lambda c, m: M.reset_cache_slots(cfg, c, m))
    return decode, reset


@dataclasses.dataclass
class ServeConfig:
    max_batch: int = 8
    max_len: int = 512
    eos_token: int = 0
    cache_dtype: str = "bfloat16"


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray          # [S] int32
    max_new_tokens: int = 32
    out_tokens: list[int] = dataclasses.field(default_factory=list)
    done: bool = False


class Engine:
    """Slot-based continuous batching over the pure decode step."""

    def __init__(self, cfg: ModelConfig, params, serve_cfg: ServeConfig,
                 *, sample: Callable | None = None,
                 accountant: PhaseEnergyAccountant | None = None):
        self.cfg = cfg
        self.params = params
        self.scfg = serve_cfg
        self.accountant = accountant
        B, T = serve_cfg.max_batch, serve_cfg.max_len
        dt = jnp.bfloat16 if serve_cfg.cache_dtype == "bfloat16" else jnp.float32
        self.cache = M.init_cache(cfg, B, T, dtype=dt)
        self.tokens = np.zeros((B, 1), np.int32)
        self.slot_req: list[Request | None] = [None] * B
        self.slot_len = np.zeros(B, np.int32)
        self.sample = sample or (lambda logits: jnp.argmax(logits, -1))

        # Cache-position contract: every decode step takes a [B] per-slot
        # position vector — each slot's K/V is written at its OWN length
        # (a single scalar would leave gaps for short slots and overwrite
        # live entries of long ones under ragged continuous batching) —
        # plus a [B] write mask confining cache mutation to the slot
        # being prefilled (prefill) / the active slots (decode steps, so
        # free slots' recurrent SSM/xLSTM state doesn't advance on
        # garbage tokens between requests).
        self._decode_masked, self._reset_slots = _jitted_fns(cfg)

    # -- host scheduler --------------------------------------------------------
    def _free_slots(self) -> list[int]:
        return [i for i, r in enumerate(self.slot_req) if r is None]

    def add_request(self, req: Request) -> bool:
        if len(req.prompt) == 0:
            # Without at least one prompt token there are no logits to
            # sample the first output token from (and the teacher-forced
            # prefill loop below would leave `logits` unbound).
            raise ValueError(f"request {req.rid}: empty prompt")
        if len(req.prompt) + 1 > self.scfg.max_len:
            # The cache ring holds max_len positions; the prompt plus at
            # least the first generated token must fit or the decode
            # write would run past the ring.
            raise ValueError(
                f"request {req.rid}: prompt length {len(req.prompt)} "
                f"does not fit max_len {self.scfg.max_len} "
                f"(need len(prompt) + 1 <= max_len)")
        slots = self._free_slots()
        if not slots:
            return False
        s = slots[0]
        self.slot_req[s] = req
        mask = np.zeros(len(self.slot_req), bool)
        mask[s] = True
        # Zero the claimed slot's cache state: recurrent SSM/xLSTM state
        # is *input* to the next step, so a reused slot would otherwise
        # seed this request with its previous occupant's final state
        # (KV rows are rewritten by prefill anyway).
        self.cache = self._reset_slots(self.cache, jnp.asarray(mask))
        # Prefill via teacher-forced decode steps on this slot (host loop;
        # fine at example scale). Writes are masked to slot s: the decode
        # step runs the whole batch, and without the mask every
        # concurrently-active slot's cache (KV at position t, and any
        # recurrent state) would be stomped at each prompt position.
        cur = self.slot_len.astype(np.int32).copy()
        with regions_mod.region("serve/prefill"):
            for t, tok in enumerate(req.prompt):
                self.tokens[s, 0] = tok
                cur[s] = t
                # Hand jax a FRESH host buffer each step: the host→device
                # transfer is async, and this loop mutates
                # self.tokens/cur in place while earlier decode steps may
                # still be in flight — a shared buffer hands those steps
                # the *next* iteration's values (observed as
                # nondeterministic prefill logits on CPU).
                logits, self.cache = self._decode_masked(
                    self.params, jnp.asarray(self.tokens.copy()),
                    self.cache, jnp.asarray(cur.copy()), jnp.asarray(mask))
        self.slot_len[s] = len(req.prompt)
        self.tokens[s, 0] = int(np.asarray(
            self.sample(logits[s:s + 1, -1, :]))[0])
        return True

    def step(self) -> list[Request]:
        """One decode step for all active slots; returns finished requests."""
        active = [i for i, r in enumerate(self.slot_req) if r is not None]
        if not active:
            return []
        # Mask writes to active slots: free slots must not advance their
        # recurrent state on the garbage tokens left in their rows.
        mask = np.asarray([r is not None for r in self.slot_req])
        with regions_mod.region("serve/decode"):
            # Fresh host buffers (see prefill loop): the scheduler
            # mutates self.tokens/slot_len right after this dispatch.
            logits, self.cache = self._decode_masked(
                self.params, jnp.asarray(self.tokens.copy()), self.cache,
                jnp.asarray(self.slot_len.astype(np.int32)),
                jnp.asarray(mask))
        nxt = np.asarray(self.sample(logits[:, -1, :]))
        finished = []
        for s in active:
            r = self.slot_req[s]
            r.out_tokens.append(int(self.tokens[s, 0]))
            self.slot_len[s] += 1
            self.tokens[s, 0] = int(nxt[s])
            hit_eos = int(nxt[s]) == self.scfg.eos_token
            if (len(r.out_tokens) >= r.max_new_tokens or hit_eos
                    or self.slot_len[s] >= self.scfg.max_len - 1):
                r.done = True
                finished.append(r)
                self.slot_req[s] = None
                self.slot_len[s] = 0
        return finished

    def run_until_drained(self, requests: list[Request],
                          max_steps: int = 10_000) -> list[Request]:
        done: list[Request] = []
        pending = list(requests)
        for _ in range(max_steps):
            while pending and self._free_slots():
                self.add_request(pending.pop(0))
            done += self.step()
            if self.accountant is not None:
                # Fold freshly sampled (phase, power) pairs into the
                # streaming accumulators; the raw stream never accumulates.
                self.accountant.drain()
            if not pending and all(r is None for r in self.slot_req):
                break
        return done
