"""Serving engine: pjit'd prefill/decode steps + a continuous-batching
host scheduler (slot-based, vLLM-lite).

The device side is two pure functions (prefill fills a slot's cache pages;
decode advances every active slot one token). The host side packs requests
into fixed slots so the decode step shape stays static (no recompiles).
ALEA regions wrap both so serving energy is attributable per phase:
attach a :class:`PhaseEnergyAccountant` and the engine drains the host
sampler's ring buffer into a StreamingAggregator after every scheduler
step — a serving run of any length holds O(R + drain chunk) profiling
state, never the full sample stream.
"""

from __future__ import annotations

import contextlib
import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import regions as regions_mod
from repro.core.estimator import EstimateSet
from repro.core.sampler import HostSampler, RegionMarker
from repro.core.sensors import available_host_sensor
from repro.core.streaming import StreamingAggregator
from repro.models import model as M

__all__ = ["ServeConfig", "Request", "Engine", "PhaseEnergyAccountant"]


class PhaseEnergyAccountant:
    """Constant-memory per-phase energy accounting for serving runs.

    Owns the §4.8 control thread (RegionMarker + HostSampler) and a
    :class:`StreamingAggregator`; callers (the Engine) periodically call
    :meth:`drain` to fold newly collected samples into the per-region
    sufficient statistics and discard them. Region ids come from the
    process-wide registry, so the accumulators grow only with the number
    of distinct phases, not with run length.

    With ``spill_dir`` set, every ``spill_every``-th drain (one drain per
    scheduler step) atomically publishes this host's shard via
    :func:`repro.core.exchange.spill_shard`, so a fleet of serving hosts
    can be reduced with ``gather_shards`` at any time — and a host killed
    mid-run loses at most ``spill_every`` epochs of samples. Cross-host
    region ids assume the hosts register serving phases in the same
    order (they do: phase names are code paths, not data).
    """

    def __init__(self, *, period: float = 2e-3, jitter: float = 1e-4,
                 seed: int = 0, sensor=None, spill_dir: str | None = None,
                 host_id: int = 0, spill_every: int = 50):
        self.marker = RegionMarker()
        self.sampler = HostSampler(self.marker,
                                   sensor or available_host_sensor(),
                                   period=period, jitter=jitter, seed=seed)
        self.agg = StreamingAggregator(len(regions_mod.registry.names))
        self.spill_dir = spill_dir
        self.host_id = host_id
        self.spill_every = spill_every
        self._epoch = 0
        self._elapsed_offset = 0.0
        self._ctx: contextlib.ExitStack | None = None
        if spill_dir is not None:
            # Restart-and-rejoin: a killed host resumes from its own
            # LATEST shard instead of republishing a fresh low-epoch one
            # over it (which would silently drop all pre-crash samples).
            from repro.core.exchange import read_shard_meta, restore_shard
            prev = restore_shard(spill_dir, host_id)
            if prev is not None:
                restored, self._epoch = prev
                self.agg.merge(restored)
                meta = read_shard_meta(spill_dir, host_id) or {}
                # Pre-crash wall time rides in the shard meta; without it
                # estimates() would divide merged counts by only this
                # process's session time, inflating every p_hat.
                self._elapsed_offset = float(
                    meta.get("extra", {}).get("elapsed", 0.0))

    def __enter__(self) -> "PhaseEnergyAccountant":
        self._ctx = contextlib.ExitStack()
        self._ctx.enter_context(regions_mod.profiling_session(self.marker))
        self._ctx.enter_context(self.sampler)
        return self

    def __exit__(self, *exc) -> None:
        assert self._ctx is not None
        self._ctx.close()
        self._ctx = None
        self.drain()
        if self.spill_dir is not None:
            self.spill()

    def drain(self) -> int:
        """Fold samples collected since the last drain; returns the count.

        Each call is one scheduler epoch; periodic durable spills happen
        here when configured.
        """
        rids, pows = self.sampler.drain()
        if len(rids):
            names = regions_mod.registry.names
            if len(names) > self.agg.num_regions:
                self.agg.grow(len(names))
            self.agg.update(rids, pows)
        self._epoch += 1
        if (self.spill_dir is not None and self.spill_every > 0
                and self._epoch % self.spill_every == 0):
            self.spill()
        return len(rids)

    @property
    def elapsed(self) -> float:
        """Accounted wall time: this session plus any resumed sessions."""
        return self._elapsed_offset + self.sampler.elapsed

    def spill(self) -> str:
        """Durably publish this host's current shard (atomic, CRC'd)."""
        from repro.core.exchange import spill_shard
        return spill_shard(self.spill_dir, self.host_id, self._epoch,
                           self.agg, extra_meta={"elapsed": self.elapsed})

    def estimates(self, alpha: float = 0.05) -> EstimateSet:
        """Per-phase estimates over everything drained so far."""
        if self.agg.n_total == 0:
            raise RuntimeError("no samples collected")
        return self.agg.estimates(self.elapsed,
                                  regions_mod.registry.names, alpha=alpha)

    @staticmethod
    def gather_estimates(spill_dir: str, t_exec: float,
                         alpha: float = 0.05) -> EstimateSet:
        """Fleet view: merge every host's published shard and estimate."""
        from repro.core.exchange import gather_shards
        merged = gather_shards(spill_dir)
        return merged.estimates(t_exec, regions_mod.registry.names,
                                alpha=alpha)


@dataclasses.dataclass
class ServeConfig:
    max_batch: int = 8
    max_len: int = 512
    eos_token: int = 0
    cache_dtype: str = "bfloat16"


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray          # [S] int32
    max_new_tokens: int = 32
    out_tokens: list[int] = dataclasses.field(default_factory=list)
    done: bool = False


class Engine:
    """Slot-based continuous batching over the pure decode step."""

    def __init__(self, cfg: ModelConfig, params, serve_cfg: ServeConfig,
                 *, sample: Callable | None = None,
                 accountant: PhaseEnergyAccountant | None = None):
        self.cfg = cfg
        self.params = params
        self.scfg = serve_cfg
        self.accountant = accountant
        B, T = serve_cfg.max_batch, serve_cfg.max_len
        dt = jnp.bfloat16 if serve_cfg.cache_dtype == "bfloat16" else jnp.float32
        self.cache = M.init_cache(cfg, B, T, dtype=dt)
        self.tokens = np.zeros((B, 1), np.int32)
        self.slot_req: list[Request | None] = [None] * B
        self.slot_len = np.zeros(B, np.int32)
        self.sample = sample or (lambda logits: jnp.argmax(logits, -1))

        self._decode = jax.jit(
            lambda p, t, c, l: M.decode_step(p, cfg, t, c, l))

        def _prefill_one(p, tokens, cache, slot):
            """Sequential prefill through decode steps for one slot.

            Simple and always-correct (slot-local cache update); the pjit'd
            bulk prefill path (M.prefill) serves the large-shape cells.
            """
            return None
        self._prefill_one = _prefill_one

    # -- host scheduler --------------------------------------------------------
    def _free_slots(self) -> list[int]:
        return [i for i, r in enumerate(self.slot_req) if r is None]

    def add_request(self, req: Request) -> bool:
        if len(req.prompt) == 0:
            # Without at least one prompt token there are no logits to
            # sample the first output token from (and the teacher-forced
            # prefill loop below would leave `logits` unbound).
            raise ValueError(f"request {req.rid}: empty prompt")
        slots = self._free_slots()
        if not slots:
            return False
        s = slots[0]
        self.slot_req[s] = req
        # Prefill via teacher-forced decode steps on this slot (host loop;
        # fine at example scale).
        with regions_mod.region("serve/prefill"):
            for t, tok in enumerate(req.prompt):
                self.tokens[s, 0] = tok
                logits, self.cache = self._decode(
                    self.params, jnp.asarray(self.tokens), self.cache,
                    jnp.int32(t))
        self.slot_len[s] = len(req.prompt)
        self.tokens[s, 0] = int(np.asarray(
            self.sample(logits[s:s + 1, -1, :]))[0])
        return True

    def step(self) -> list[Request]:
        """One decode step for all active slots; returns finished requests."""
        active = [i for i, r in enumerate(self.slot_req) if r is not None]
        if not active:
            return []
        cur = int(self.slot_len.max())
        with regions_mod.region("serve/decode"):
            logits, self.cache = self._decode(
                self.params, jnp.asarray(self.tokens), self.cache,
                jnp.int32(cur))
        nxt = np.asarray(self.sample(logits[:, -1, :]))
        finished = []
        for s in active:
            r = self.slot_req[s]
            r.out_tokens.append(int(self.tokens[s, 0]))
            self.slot_len[s] += 1
            self.tokens[s, 0] = int(nxt[s])
            hit_eos = int(nxt[s]) == self.scfg.eos_token
            if (len(r.out_tokens) >= r.max_new_tokens or hit_eos
                    or self.slot_len[s] >= self.scfg.max_len - 1):
                r.done = True
                finished.append(r)
                self.slot_req[s] = None
                self.slot_len[s] = 0
        return finished

    def run_until_drained(self, requests: list[Request],
                          max_steps: int = 10_000) -> list[Request]:
        done: list[Request] = []
        pending = list(requests)
        for _ in range(max_steps):
            while pending and self._free_slots():
                self.add_request(pending.pop(0))
            done += self.step()
            if self.accountant is not None:
                # Fold freshly sampled (phase, power) pairs into the
                # streaming accumulators; the raw stream never accumulates.
                self.accountant.drain()
            if not pending and all(r is None for r in self.slot_req):
                break
        return done
