"""Crash-safe engine snapshots and bit-exact restore.

Extends the may-lose/never-corrupt contract (ROADMAP "Failure model")
to the serving seam: a serving host can be killed at any engine step and
restored from its last durable snapshot with

* **bit-exact token streams** — the restored engine replays each
  occupied slot's prompt + generated prefix through the same masked
  teacher-forced decode path that produced it, rebuilding the slot's
  KV/recurrent cache state exactly, then resumes decoding from the
  snapshotted pending token. Tokens generated after the snapshot are
  lost by the kill — and regenerated deterministically, so the merged
  stream equals the uninterrupted run's.
* **no double-counted energy** — the accountant's durable shard is
  published through :class:`repro.core.exchange.ShardSpiller`, whose
  epoch fence (``spill`` refuses ``epoch <= resumed epoch``) already
  makes replays idempotent; the snapshot records the accountant's
  ``(epoch, last_spill_epoch)`` fence as provenance so a restore can be
  audited against the shard it resumed from.
* **full provenance** — the scheduler queue, per-request records and
  overload-ladder state ride in the snapshot; every restored request is
  marked ``recovered`` in the :class:`~repro.serve.scheduler.ServeReport`.

Self-speculative decoding (``ServeConfig.spec_len``) needs no snapshot
schema of its own: a speculation window is **atomic on the step clock**
(the injected-crash site fires before any mutation, so a killed step
leaves the engine exactly as the previous window published it), which
means snapshots only ever observe window boundaries — the emitted
prefix, pending token and slot lengths the baseline contract already
serializes. The replay path below is the baseline single-token
teacher-forced step, valid for every cache family regardless of how the
tokens were originally produced, so "kill mid-speculation-window and
restore" reduces to the established bit-exact replay; the effective
speculation length after restore is derived from the snapshotted
scheduler ``widened`` flag, so a degraded engine resumes degraded.
Replay energy lands in the ``serve/replay`` phase (as for rollback),
never in the per-token price phases, and the spill-epoch fence above
keeps pre-crash speculation energy from being double-charged.

Snapshots use the shared ``ckpt`` manifest+CRC+rename protocol
(``snap_%09d`` directories plus an atomically-replaced ``LATEST``
pointer), so torn writes are invisible to readers and corruption
surfaces as typed :class:`~repro.core.faults.SpillError`\\ s, never as a
silently wrong engine.
"""

from __future__ import annotations

import os

import jax.numpy as jnp
import numpy as np

from repro.checkpoint.ckpt import (latest_step, publish_latest,
                                   read_manifest_dir, write_manifest_dir)
from repro.configs.base import ModelConfig
from repro.core import regions as regions_mod
from repro.core.faults import (MissingArtifactError, TornWriteError,
                               declare_site, resolve_plan)
from repro.serve.engine import Engine, Request, ServeConfig
from repro.serve.scheduler import ServeScheduler

__all__ = ["snapshot", "restore_engine"]

# Injection seam this module owns (see faults.FAULT_SITES): transient
# snapshot-publish failures at chosen step-clock values. Byte-level
# corruption of snapshot artifacts needs no site of its own — snapshots
# ride the shared ckpt leaf/manifest codec, so `leaf_faults` matching
# snap paths already covers torn/corrupt snapshot bytes.
_SITE_SNAPSHOT = declare_site("serve.snapshot.write")


def _req_meta(r: Request) -> dict:
    return {"rid": int(r.rid), "max_new_tokens": int(r.max_new_tokens),
            "priority": int(r.priority), "deadline": r.deadline,
            "energy_budget": r.energy_budget, "energy_j": float(r.energy_j),
            "submit_step": int(r.submit_step), "done": bool(r.done)}


def _req_from_meta(m: dict, prompt: np.ndarray,
                   out_tokens: list[int]) -> Request:
    return Request(rid=int(m["rid"]), prompt=np.asarray(prompt, np.int32),
                   max_new_tokens=int(m["max_new_tokens"]),
                   out_tokens=out_tokens, done=bool(m["done"]),
                   priority=int(m["priority"]), deadline=m["deadline"],
                   energy_budget=m["energy_budget"],
                   status="recovered", energy_j=float(m["energy_j"]),
                   submit_step=int(m["submit_step"]))


def snapshot(engine: Engine, path: str, *, faults=None) -> str:
    """Durably publish the engine's recoverable state under ``path``.

    Keyed by the step clock: ``<path>/snap_<step_count>`` plus an
    atomic ``LATEST`` pointer. Contents: the slot table (pending tokens,
    per-slot cache lengths, each occupied slot's prompt and generated
    tokens), the admission queue, the full :class:`ServeReport`, the
    overload-ladder state and the accountant's spill-epoch fence. The
    device cache is deliberately NOT serialized — restore rebuilds it
    deterministically by replaying prefixes, which keeps snapshots
    O(tokens), not O(cache).

    Idempotent per step: re-publishing an existing step's directory is
    a no-op beyond repointing ``LATEST``. Injected failures
    (``FaultPlan.snapshot_failures``) raise a typed transient
    :class:`TornWriteError` before anything is written.
    """
    step = engine.step_count
    plan = resolve_plan(faults if faults is not None else engine._faults)
    if plan is not None and plan.snapshot_fails(step):
        raise TornWriteError(
            f"injected snapshot publish failure at engine step {step} "
            f"({_SITE_SNAPSHOT})")
    final = os.path.join(path, f"snap_{step:09d}")
    if not os.path.isdir(final):
        arrays: list[np.ndarray] = [
            np.asarray(engine.tokens, np.int32),
            np.asarray(engine.slot_len, np.int32)]
        slots_meta: list[dict | None] = []
        for r in engine.slot_req:
            if r is None:
                slots_meta.append(None)
                continue
            sm = _req_meta(r)
            sm["prompt_leaf"] = len(arrays)
            sm["out_leaf"] = len(arrays) + 1
            arrays.append(np.asarray(r.prompt, np.int32))
            arrays.append(np.asarray(r.out_tokens, np.int32))
            slots_meta.append(sm)
        queue_meta: list[dict] = []
        for priority, seq, r in engine.scheduler.queue.snapshot():
            qm = _req_meta(r)
            qm["queue_priority"] = int(priority)
            qm["queue_seq"] = int(seq)
            qm["prompt_leaf"] = len(arrays)
            arrays.append(np.asarray(r.prompt, np.int32))
            queue_meta.append(qm)
        acct = engine.accountant
        fence = None if acct is None else {
            "epoch": acct.epoch, "last_spill_epoch": acct.last_spill_epoch}
        write_manifest_dir(final, arrays, meta={"serve": {
            "step_count": step,
            "max_batch": engine.scfg.max_batch,
            "max_len": engine.scfg.max_len,
            "slots": slots_meta,
            "queue": queue_meta,
            "scheduler": engine.scheduler.state_json(),
            "accountant_fence": fence,
        }})
    publish_latest(path, step)
    return final


def _replay_slot(eng: Engine, s: int, req: Request) -> None:
    """Rebuild slot ``s``'s cache state by teacher-forcing the request's
    prompt + generated prefix through the shared masked decode step —
    the exact positions the live run wrote (prompt token t at position
    t, generated token k at position len(prompt)+k), masked to this
    slot only. Reuses ``_jitted_fns``' traces: replay introduces no new
    ``(config, shape)`` compile keys."""
    eng.slot_req[s] = req
    mask = np.zeros(len(eng.slot_req), bool)
    mask[s] = True
    eng.cache = eng._reset_slots(eng.cache, jnp.asarray(mask))
    toks = [int(t) for t in req.prompt] + [int(t) for t in req.out_tokens]
    cur = eng.slot_len.astype(np.int32).copy()
    with regions_mod.region("serve/replay"):
        for t, tok in enumerate(toks):
            eng.tokens[s, 0] = tok
            cur[s] = t
            # Fresh host buffers each step — same async-dispatch hazard
            # as the prefill loop (see Engine._place).
            _, eng.cache = eng._decode_masked(
                eng.params, jnp.asarray(eng.tokens.copy()), eng.cache,
                jnp.asarray(cur.copy()), jnp.asarray(mask))


def restore_engine(cfg: ModelConfig, params, serve_cfg: ServeConfig,
                   path: str, *, step: int | None = None,
                   sample=None, accountant=None, faults=None) -> Engine:
    """Rebuild an engine from the snapshot at ``step`` (default: LATEST).

    Raises :class:`MissingArtifactError` when no snapshot was ever
    published; CRC mismatches and torn snapshot directories surface as
    the ckpt protocol's typed errors. The returned engine carries
    ``restored_fence`` (the snapshotted accountant spill fence) for
    audit, and its report marks every restored request ``recovered``.

    To also resume pre-crash *energy* state, pass an ``accountant``
    built with the same ``spill_dir``/``host_id`` as the dead host's —
    :class:`ShardSpiller` resume plus its epoch fence guarantee no
    sample is double-published.
    """
    if step is None:
        step = latest_step(path)
    if step is None:
        raise MissingArtifactError(f"no LATEST snapshot under {path}")
    d = os.path.join(path, f"snap_{step:09d}")
    if not os.path.isdir(d):
        raise MissingArtifactError(
            f"snapshot dir {d} missing (LATEST says step {step})")
    arrays, manifest = read_manifest_dir(d)
    meta = manifest["serve"]
    if (int(meta["max_batch"]) != serve_cfg.max_batch
            or int(meta["max_len"]) != serve_cfg.max_len):
        raise ValueError(
            f"snapshot slot geometry (max_batch={meta['max_batch']}, "
            f"max_len={meta['max_len']}) does not match serve config "
            f"({serve_cfg.max_batch}, {serve_cfg.max_len}); restoring "
            f"across geometries would misplace cache positions")
    sched = ServeScheduler()
    sched.load_state(meta["scheduler"])
    eng = Engine(cfg, params, serve_cfg, sample=sample,
                 accountant=accountant, scheduler=sched, faults=faults)
    eng.step_count = int(meta["step_count"])
    tokens, slot_len = arrays[0], arrays[1]
    for s, sm in enumerate(meta["slots"]):
        if sm is None:
            continue
        req = _req_from_meta(
            sm, arrays[sm["prompt_leaf"]],
            [int(t) for t in arrays[sm["out_leaf"]]])
        _replay_slot(eng, s, req)
        eng._requests[req.rid] = req
        eng.report.set_status(req.rid, "recovered")
    # The snapshotted pending tokens / lengths overwrite replay
    # scratch: position slot_len is where the next decode step writes.
    eng.tokens[:] = np.asarray(tokens, np.int32)
    eng.slot_len[:] = np.asarray(slot_len, np.int32)
    for qm in meta["queue"]:
        req = _req_from_meta(qm, arrays[qm["prompt_leaf"]], [])
        eng._requests[req.rid] = req
        eng.report.set_status(req.rid, "recovered")
        eng.scheduler.requeue(req, qm["queue_priority"], qm["queue_seq"])
    eng.restored_fence = meta["accountant_fence"]
    return eng
