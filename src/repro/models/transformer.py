"""Block composition: dense/MoE transformer blocks, xLSTM pairs, zamba2
hybrid groups — each with init / forward / decode triplets.

All block forwards return ``(x, aux)`` where aux is the accumulated
auxiliary loss (MoE load balancing; 0 elsewhere) so scans can carry it.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.regions import region
from repro.models import attention as attn_mod
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models import xlstm as xlstm_mod
from repro.models.layers import Params, mlp, mlp_init, norm, norm_init

__all__ = ["tblock_init", "tblock_forward", "tblock_decode",
           "xlstm_pair_init", "xlstm_pair_forward", "xlstm_pair_decode",
           "zamba_group_init", "zamba_group_forward", "zamba_group_decode",
           "shared_attn_init", "shared_attn_forward", "shared_attn_decode"]


# -- standard transformer block (dense / moe / audio / vlm) -------------------

def tblock_init(key, cfg: ModelConfig) -> Params:
    ks = jax.random.split(key, 2)
    p: Params = {
        "ln1": norm_init(cfg.d_model, cfg.norm_kind),
        "ln2": norm_init(cfg.d_model, cfg.norm_kind),
        "attn": attn_mod.attention_init(ks[0], cfg),
    }
    if cfg.family == "moe":
        p["moe"] = moe_mod.moe_init(ks[1], cfg)
    else:
        p["mlp"] = mlp_init(ks[1], cfg.d_model, cfg.d_ff, gated=cfg.gated_mlp)
    return p


def tblock_forward(p: Params, cfg: ModelConfig, x: jnp.ndarray,
                   positions: jnp.ndarray, *, attn_impl: str = "full",
                   q_chunk: int = 1024, unroll_chunks: bool = False):
    with region("attn"):
        h = attn_mod.attention(
            p["attn"], cfg, norm(p["ln1"], x, kind=cfg.norm_kind,
                                 eps=cfg.norm_eps),
            positions, impl=attn_impl, q_chunk=q_chunk,
            unroll_chunks=unroll_chunks)
    x = x + h
    aux = jnp.zeros((), jnp.float32)
    if cfg.family == "moe":
        y, aux = moe_mod.moe_ffn(p["moe"], cfg,
                                 norm(p["ln2"], x, kind=cfg.norm_kind,
                                      eps=cfg.norm_eps))
    else:
        with region("ffn"):
            y = mlp(p["mlp"], norm(p["ln2"], x, kind=cfg.norm_kind,
                                   eps=cfg.norm_eps),
                    gated=cfg.gated_mlp, act=cfg.act)
    return x + y, aux


def tblock_decode(p: Params, cfg: ModelConfig, x: jnp.ndarray, cache: Params,
                  cur_len: jnp.ndarray, *, window: int | None = None,
                  sinks: int = 0):
    h, ck, cv = attn_mod.attention_decode(
        p["attn"], cfg, norm(p["ln1"], x, kind=cfg.norm_kind,
                             eps=cfg.norm_eps),
        cache["k"], cache["v"], cur_len, window=window, sinks=sinks)
    x = x + h
    if cfg.family == "moe":
        # Dropless at decode: capacity drops are batch-composition
        # dependent, which would break continuous-batching equivalence
        # with single-request runs (see moe_ffn docstring).
        y, _ = moe_mod.moe_ffn(p["moe"], cfg,
                               norm(p["ln2"], x, kind=cfg.norm_kind,
                                    eps=cfg.norm_eps), dropless=True)
    else:
        y = mlp(p["mlp"], norm(p["ln2"], x, kind=cfg.norm_kind,
                               eps=cfg.norm_eps),
                gated=cfg.gated_mlp, act=cfg.act)
    return x + y, {"k": ck, "v": cv}


def tblock_prefill(p: Params, cfg: ModelConfig, x: jnp.ndarray,
                   positions: jnp.ndarray, max_len: int, *,
                   attn_impl: str = "chunked", cache_dtype=jnp.bfloat16,
                   q_chunk: int = 1024, unroll_chunks: bool = False):
    with region("attn"):
        h, ck, cv = attn_mod.attention_prefill(
            p["attn"], cfg, norm(p["ln1"], x, kind=cfg.norm_kind,
                                 eps=cfg.norm_eps),
            positions, max_len, impl=attn_impl, cache_dtype=cache_dtype,
            q_chunk=q_chunk, unroll_chunks=unroll_chunks)
    x = x + h
    if cfg.family == "moe":
        y, _ = moe_mod.moe_ffn(p["moe"], cfg,
                               norm(p["ln2"], x, kind=cfg.norm_kind,
                                    eps=cfg.norm_eps))
    else:
        with region("ffn"):
            y = mlp(p["mlp"], norm(p["ln2"], x, kind=cfg.norm_kind,
                                   eps=cfg.norm_eps),
                    gated=cfg.gated_mlp, act=cfg.act)
    return x + y, {"k": ck, "v": cv}


# -- xLSTM pair (mLSTM block + sLSTM block) -----------------------------------

def xlstm_pair_init(key, cfg: ModelConfig) -> Params:
    ks = jax.random.split(key, 2)
    return {
        "ln_m": norm_init(cfg.d_model, cfg.norm_kind),
        "ln_s": norm_init(cfg.d_model, cfg.norm_kind),
        "m": xlstm_mod.mlstm_init(ks[0], cfg),
        "s": xlstm_mod.slstm_init(ks[1], cfg),
    }


def xlstm_pair_forward(p: Params, cfg: ModelConfig, x: jnp.ndarray,
                       positions, *, attn_impl: str = "full",
                       chunk: int = 128, unroll_chunks: bool = False):
    del positions, attn_impl
    x = x + xlstm_mod.mlstm_forward(
        p["m"], cfg, norm(p["ln_m"], x, kind=cfg.norm_kind, eps=cfg.norm_eps),
        chunk=chunk, unroll_chunks=unroll_chunks)
    x = x + xlstm_mod.slstm_forward(
        p["s"], cfg, norm(p["ln_s"], x, kind=cfg.norm_kind, eps=cfg.norm_eps))
    return x, jnp.zeros((), jnp.float32)


def xlstm_pair_decode(p: Params, cfg: ModelConfig, x, cache, cur_len):
    del cur_len
    h, cm = xlstm_mod.mlstm_decode(
        p["m"], cfg, norm(p["ln_m"], x, kind=cfg.norm_kind, eps=cfg.norm_eps),
        cache["m"])
    x = x + h
    h, cs = xlstm_mod.slstm_decode(
        p["s"], cfg, norm(p["ln_s"], x, kind=cfg.norm_kind, eps=cfg.norm_eps),
        cache["s"])
    return x + h, {"m": cm, "s": cs}


def xlstm_pair_prefill(p: Params, cfg: ModelConfig, x, positions, *,
                       chunk: int = 128, unroll_chunks: bool = False):
    del positions
    h, cm = xlstm_mod.mlstm_forward(
        p["m"], cfg, norm(p["ln_m"], x, kind=cfg.norm_kind, eps=cfg.norm_eps),
        return_cache=True, chunk=chunk, unroll_chunks=unroll_chunks)
    x = x + h
    h, cs = xlstm_mod.slstm_forward(
        p["s"], cfg, norm(p["ln_s"], x, kind=cfg.norm_kind, eps=cfg.norm_eps),
        return_cache=True)
    return x + h, {"m": cm, "s": cs}


# -- zamba2 hybrid: groups of mamba2 layers + a weight-shared attn block ------

def shared_attn_init(key, cfg: ModelConfig) -> Params:
    ks = jax.random.split(key, 2)
    return {
        "ln1": norm_init(cfg.d_model, cfg.norm_kind),
        "ln2": norm_init(cfg.d_model, cfg.norm_kind),
        "attn": attn_mod.attention_init(ks[0], cfg),
        "mlp": mlp_init(ks[1], cfg.d_model, cfg.d_ff, gated=cfg.gated_mlp),
    }


def shared_attn_forward(p: Params, cfg: ModelConfig, x, positions, *,
                        attn_impl: str = "full", q_chunk: int = 1024,
                        unroll_chunks: bool = False):
    with region("shared_attn"):
        x = x + attn_mod.attention(
            p["attn"], cfg, norm(p["ln1"], x, kind=cfg.norm_kind,
                                 eps=cfg.norm_eps), positions,
            impl=attn_impl, q_chunk=q_chunk, unroll_chunks=unroll_chunks)
        x = x + mlp(p["mlp"], norm(p["ln2"], x, kind=cfg.norm_kind,
                                   eps=cfg.norm_eps),
                    gated=cfg.gated_mlp, act=cfg.act)
    return x


def shared_attn_decode(p: Params, cfg: ModelConfig, x, cache, cur_len, *,
                       window: int | None = None, sinks: int = 0):
    h, ck, cv = attn_mod.attention_decode(
        p["attn"], cfg, norm(p["ln1"], x, kind=cfg.norm_kind,
                             eps=cfg.norm_eps),
        cache["k"], cache["v"], cur_len, window=window, sinks=sinks)
    x = x + h
    x = x + mlp(p["mlp"], norm(p["ln2"], x, kind=cfg.norm_kind,
                               eps=cfg.norm_eps),
                gated=cfg.gated_mlp, act=cfg.act)
    return x, {"k": ck, "v": cv}


def zamba_group_init(key, cfg: ModelConfig, group_size: int) -> Params:
    """``group_size`` mamba2 layers (stacked for inner scan)."""
    ks = jax.random.split(key, group_size)
    layer = jax.vmap(lambda k: {"ln": norm_init(cfg.d_model, cfg.norm_kind),
                                "ssm": ssm_mod.ssm_init(k, cfg)})
    return layer(ks)


def zamba_group_forward(p: Params, cfg: ModelConfig, x: jnp.ndarray, *,
                        chunk: int = 128, unroll_chunks: bool = False):
    """Inner scan over the group's mamba2 layers."""

    def body(h, pl):
        h = h + ssm_mod.ssm_forward(
            pl["ssm"], cfg, norm(pl["ln"], h, kind=cfg.norm_kind,
                                 eps=cfg.norm_eps), chunk=chunk,
            unroll_chunks=unroll_chunks)
        return h, None

    if unroll_chunks:   # cost-compile: unroll the group's layer scan too
        L = jax.tree.leaves(p)[0].shape[0]
        for i in range(L):
            x, _ = body(x, jax.tree.map(lambda t: t[i], p))
        return x
    x, _ = jax.lax.scan(body, x, p)
    return x


def shared_attn_prefill(p: Params, cfg: ModelConfig, x, positions,
                        max_len: int, *, attn_impl: str = "chunked",
                        cache_dtype=jnp.bfloat16, q_chunk: int = 1024,
                        unroll_chunks: bool = False):
    with region("shared_attn"):
        h, ck, cv = attn_mod.attention_prefill(
            p["attn"], cfg, norm(p["ln1"], x, kind=cfg.norm_kind,
                                 eps=cfg.norm_eps),
            positions, max_len, impl=attn_impl, cache_dtype=cache_dtype,
            q_chunk=q_chunk, unroll_chunks=unroll_chunks)
        x = x + h
        x = x + mlp(p["mlp"], norm(p["ln2"], x, kind=cfg.norm_kind,
                                   eps=cfg.norm_eps),
                    gated=cfg.gated_mlp, act=cfg.act)
    return x, {"k": ck, "v": cv}


def zamba_group_prefill(p: Params, cfg: ModelConfig, x, *, chunk: int = 128,
                        unroll_chunks: bool = False):
    def body(h, pl):
        y, cache = ssm_mod.ssm_forward(
            pl["ssm"], cfg, norm(pl["ln"], h, kind=cfg.norm_kind,
                                 eps=cfg.norm_eps), chunk=chunk,
            return_cache=True, unroll_chunks=unroll_chunks)
        return h + y, cache

    if unroll_chunks:
        L = jax.tree.leaves(p)[0].shape[0]
        caches = []
        for i in range(L):
            x, c = body(x, jax.tree.map(lambda t: t[i], p))
            caches.append(c)
        return x, jax.tree.map(lambda *a: jnp.stack(a), *caches)
    x, caches = jax.lax.scan(body, x, p)
    return x, caches


def zamba_group_decode(p: Params, cfg: ModelConfig, x, caches):
    def body(h, inp):
        pl, cache = inp
        y, new_cache = ssm_mod.ssm_decode(
            pl["ssm"], cfg, norm(pl["ln"], h, kind=cfg.norm_kind,
                                 eps=cfg.norm_eps), cache)
        return h + y, new_cache

    x, new_caches = jax.lax.scan(body, x, (p, caches))
    return x, new_caches
