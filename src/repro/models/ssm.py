"""Mamba2 (SSD, state-space duality) blocks: chunked train/prefill scan +
O(1) recurrent decode.

TPU adaptation notes (DESIGN.md §2): the CUDA Mamba2 kernel fuses the
chunked scan in shared memory; here the chunk loop is a ``lax.scan`` whose
body is MXU-shaped einsums (chunk=128/256 keeps the [Q,Q] intra-chunk
attention matrix VMEM-resident after XLA fusion). The depthwise conv is
split: x-channels (TP-sharded over SSM heads) and B/C channels
(replicated) get separate convolutions — equivalent expressiveness,
shard-friendly layout.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.regions import region
from repro.models.layers import Params, dense_init, rmsnorm
from repro.sharding.rules import constrain

__all__ = ["ssm_init", "ssm_forward", "ssm_decode", "ssm_cache_init"]


def _dims(cfg: ModelConfig):
    d_in = cfg.ssm_expand * cfg.d_model
    heads = d_in // cfg.ssm_head_dim
    return d_in, heads, cfg.ssm_head_dim, cfg.ssm_state


def ssm_init(key, cfg: ModelConfig) -> Params:
    d = cfg.d_model
    d_in, H, hd, N = _dims(cfg)
    k = jax.random.split(key, 8)
    return {
        "in_x": dense_init(k[0], d, d_in),
        "in_z": dense_init(k[1], d, d_in),
        "in_bc": dense_init(k[2], d, 2 * N),
        "in_dt": dense_init(k[3], d, H),
        "conv_x": 0.1 * jax.random.normal(k[4], (cfg.ssm_conv, d_in),
                                          jnp.float32),
        "conv_bc": 0.1 * jax.random.normal(k[5], (cfg.ssm_conv, 2 * N),
                                           jnp.float32),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H).astype(jnp.float32)),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.log(jnp.expm1(
            jnp.exp(jax.random.uniform(k[6], (H,), jnp.float32,
                                       jnp.log(1e-3), jnp.log(1e-1))))),
        "norm": {"scale": jnp.ones((d_in,), jnp.float32)},
        "out": dense_init(k[7], d_in, d),
    }


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray,
                 state: jnp.ndarray | None = None):
    """Depthwise causal conv. x: [B,S,C], w: [K,C]. state: [B,K-1,C] tail of
    the previous tokens (decode). Returns (y [B,S,C], new_state)."""
    K = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], K - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)          # [B, S+K-1, C]
    y = sum(xp[:, i:i + x.shape[1], :] * w[i].astype(x.dtype)
            for i in range(K))
    new_state = xp[:, -(K - 1):, :] if K > 1 else pad
    return jax.nn.silu(y), new_state


def _ssd_inputs(p: Params, cfg: ModelConfig, u: jnp.ndarray,
                conv_x_state=None, conv_bc_state=None):
    """Project u [B,S,d] → (x [B,S,H,hd], Bmat/Cmat [B,S,N], dt [B,S,H],
    z [B,S,d_in], conv states)."""
    d_in, H, hd, N = _dims(cfg)
    z = u @ p["in_z"].astype(u.dtype)
    x = u @ p["in_x"].astype(u.dtype)
    bc = u @ p["in_bc"].astype(u.dtype)
    x = constrain(x, "batch", "seq", "conv_dim")
    x, cxs = _causal_conv(x, p["conv_x"], conv_x_state)
    bc, cbs = _causal_conv(bc, p["conv_bc"], conv_bc_state)
    Bmat, Cmat = bc[..., :N], bc[..., N:]
    dt = jax.nn.softplus((u @ p["in_dt"].astype(u.dtype)).astype(jnp.float32)
                         + p["dt_bias"])                      # [B,S,H]
    x = x.reshape(*x.shape[:2], H, hd)
    return x, Bmat, Cmat, dt, z, cxs, cbs


def _ssd_chunked(x, Bmat, Cmat, dt, A, *, chunk: int, h0=None,
                 unroll: bool = False):
    """Chunked SSD scan.

    x: [B,S,H,hd]; Bmat/Cmat: [B,S,N]; dt: [B,S,H] (fp32); A: [H] (fp32, <0).
    Returns (y [B,S,H,hd], h_final [B,H,hd,N]).
    """
    Bsz, S, H, hd = x.shape
    N = Bmat.shape[-1]
    assert S % chunk == 0, (S, chunk)
    n_chunks = S // chunk
    dA = dt * A                                            # [B,S,H]  (<= 0)

    def to_chunks(t):
        return jnp.moveaxis(t.reshape(Bsz, n_chunks, chunk, *t.shape[2:]),
                            1, 0)

    xc, Bc, Cc, dAc, dtc = map(to_chunks, (x, Bmat, Cmat, dA, dt))
    if h0 is None:
        h0 = jnp.zeros((Bsz, H, hd, N), jnp.float32)

    def body(h, inp):
        xq, Bq, Cq, dAq, dtq = inp        # [B,Q,...]
        cs = jnp.cumsum(dAq, axis=1)      # [B,Q,H]
        total = cs[:, -1]                 # [B,H]
        # Intra-chunk (masked) attention: L[i,j] = exp(cs_i - cs_j), i >= j.
        diff = cs[:, :, None, :] - cs[:, None, :, :]        # [B,Q,Q,H]
        mask = jnp.tril(jnp.ones((xq.shape[1], xq.shape[1]), bool))
        L = jnp.where(mask[None, :, :, None], jnp.exp(diff), 0.0)
        scores = jnp.einsum("bin,bjn->bij", Cq.astype(jnp.float32),
                            Bq.astype(jnp.float32))         # [B,Q,Q]
        att = scores[..., None] * L * dtq[:, None, :, :]     # [B,Q,Q,H]
        y_intra = jnp.einsum("bijh,bjhp->bihp", att,
                             xq.astype(jnp.float32))
        # Inter-chunk: contribution of the carried state.
        y_inter = jnp.exp(cs)[..., None] * jnp.einsum(
            "bin,bhpn->bihp", Cq.astype(jnp.float32), h)
        # State update: h' = exp(total)·h + Σ_j exp(total - cs_j)·dt_j·B_j x_j.
        w = jnp.exp(total[:, None] - cs) * dtq               # [B,Q,H]
        h_new = (jnp.exp(total)[:, :, None, None] * h
                 + jnp.einsum("bjh,bjn,bjhp->bhpn", w,
                              Bq.astype(jnp.float32), xq.astype(jnp.float32)))
        return h_new, y_intra + y_inter

    if unroll:
        h, ys = h0, []
        for i in range(n_chunks):
            h, yi = body(h, (xc[i], Bc[i], Cc[i], dAc[i], dtc[i]))
            ys.append(yi)
        h_final, yc = h, jnp.stack(ys)
    else:
        h_final, yc = jax.lax.scan(body, h0, (xc, Bc, Cc, dAc, dtc))
    y = jnp.moveaxis(yc, 0, 1).reshape(Bsz, S, H, hd)
    return y, h_final


def ssm_forward(p: Params, cfg: ModelConfig, u: jnp.ndarray, *,
                chunk: int = 128, return_cache: bool = False,
                unroll_chunks: bool = False):
    """Full-sequence Mamba2 block (train / prefill). u: [B,S,d] → [B,S,d].

    With ``return_cache`` also returns the recurrent cache (final SSM state
    + conv tails), i.e. the prefill path."""
    d_in, H, hd, N = _dims(cfg)
    with region("ssm_proj"):
        x, Bmat, Cmat, dt, z, cxs, cbs = _ssd_inputs(p, cfg, u)
    A = -jnp.exp(p["A_log"])
    with region("ssm_scan"):
        y, h_final = _ssd_chunked(x, Bmat, Cmat, dt, A,
                                  chunk=min(chunk, u.shape[1]),
                                  unroll=unroll_chunks)
        y = y + p["D"][None, None, :, None] * x.astype(jnp.float32)
    y = y.reshape(*u.shape[:2], d_in).astype(u.dtype)
    y = y * jax.nn.silu(z)
    y = rmsnorm(p["norm"], y, eps=cfg.norm_eps)
    with region("ssm_out"):
        out = y @ p["out"].astype(u.dtype)
    out = constrain(out, "batch", "seq", "embed")
    if return_cache:
        return out, {"h": h_final, "conv_x": cxs, "conv_bc": cbs}
    return out


def ssm_cache_init(cfg: ModelConfig, batch: int, dtype=jnp.float32) -> Params:
    d_in, H, hd, N = _dims(cfg)
    K = cfg.ssm_conv
    return {
        "h": jnp.zeros((batch, H, hd, N), jnp.float32),
        "conv_x": jnp.zeros((batch, K - 1, d_in), dtype),
        "conv_bc": jnp.zeros((batch, K - 1, 2 * N), dtype),
    }


def ssm_decode(p: Params, cfg: ModelConfig, u: jnp.ndarray, cache: Params):
    """Single-token recurrent update. u: [B,1,d]. Returns (y, new_cache)."""
    d_in, H, hd, N = _dims(cfg)
    x, Bmat, Cmat, dt, z, cxs, cbs = _ssd_inputs(
        p, cfg, u, cache["conv_x"], cache["conv_bc"])
    A = -jnp.exp(p["A_log"])
    xq = x[:, 0].astype(jnp.float32)              # [B,H,hd]
    Bq = Bmat[:, 0].astype(jnp.float32)           # [B,N]
    Cq = Cmat[:, 0].astype(jnp.float32)
    dtq = dt[:, 0]                                # [B,H]
    with region("ssm_decode"):
        decay = jnp.exp(dtq * A)                  # [B,H]
        h = cache["h"] * decay[:, :, None, None] + jnp.einsum(
            "bh,bn,bhp->bhpn", dtq, Bq, xq)
        y = jnp.einsum("bn,bhpn->bhp", Cq, h) + p["D"][None, :, None] * xq
    y = y.reshape(u.shape[0], 1, d_in).astype(u.dtype)
    y = y * jax.nn.silu(z)
    y = rmsnorm(p["norm"], y, eps=cfg.norm_eps)
    out = y @ p["out"].astype(u.dtype)
    new_cache = {"h": h, "conv_x": cxs, "conv_bc": cbs}
    return out, new_cache
