"""Foundational layers (pure JAX, no flax): norms, linears, rope, embeddings.

Parameters are plain pytrees (nested dicts of jnp arrays). Initializers take
an explicit PRNG key. Compute dtype is configurable (bf16 default on TPU);
parameters are kept in fp32 (master weights) and cast at use sites.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

Params = dict[str, Any]


# -- init ---------------------------------------------------------------------

def dense_init(key, d_in: int, d_out: int, *, scale: float | None = None
               ) -> jnp.ndarray:
    """Truncated-normal fan-in init (LLM-standard)."""
    std = scale if scale is not None else d_in ** -0.5
    return (jax.random.truncated_normal(key, -3, 3, (d_in, d_out), jnp.float32)
            * std)


def embed_init(key, vocab: int, d: int, *, scale: float = 0.02) -> jnp.ndarray:
    return (jax.random.truncated_normal(key, -3, 3, (vocab, d), jnp.float32)
            * scale)


# -- norms --------------------------------------------------------------------

def rmsnorm_init(d: int) -> Params:
    return {"scale": jnp.ones((d,), jnp.float32)}


def rmsnorm(p: Params, x: jnp.ndarray, *, eps: float = 1e-5) -> jnp.ndarray:
    """RMSNorm in fp32 accumulation, output in input dtype."""
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps) * p["scale"]
    return y.astype(dtype)


def layernorm_init(d: int) -> Params:
    return {"scale": jnp.ones((d,), jnp.float32),
            "bias": jnp.zeros((d,), jnp.float32)}


def layernorm(p: Params, x: jnp.ndarray, *, eps: float = 1e-5) -> jnp.ndarray:
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps) * p["scale"] + p["bias"]
    return y.astype(dtype)


def norm(p: Params, x: jnp.ndarray, *, kind: str = "rms",
         eps: float = 1e-5) -> jnp.ndarray:
    if kind == "rms":
        return rmsnorm(p, x, eps=eps)
    return layernorm(p, x, eps=eps)


def norm_init(d: int, kind: str = "rms") -> Params:
    return rmsnorm_init(d) if kind == "rms" else layernorm_init(d)


# -- linear -------------------------------------------------------------------

def linear(w: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """x @ w with weight cast to activation dtype."""
    return x @ w.astype(x.dtype)


# -- MLPs ---------------------------------------------------------------------

def mlp_init(key, d: int, d_ff: int, *, gated: bool = True) -> Params:
    ks = jax.random.split(key, 3)
    p: Params = {"up": dense_init(ks[0], d, d_ff),
                 "down": dense_init(ks[1], d_ff, d)}
    if gated:
        p["gate"] = dense_init(ks[2], d, d_ff)
    return p


def mlp(p: Params, x: jnp.ndarray, *, gated: bool = True,
        act: str = "silu") -> jnp.ndarray:
    a = jax.nn.silu if act == "silu" else jax.nn.gelu
    up = linear(p["up"], x)
    h = a(linear(p["gate"], x)) * up if gated else a(up)
    return linear(p["down"], h)


# -- rotary embeddings ----------------------------------------------------------

def rope_frequencies(d_head: int, theta: float = 1e4) -> np.ndarray:
    return 1.0 / (theta ** (np.arange(0, d_head, 2, dtype=np.float32) / d_head))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray,
               theta: float = 1e4) -> jnp.ndarray:
    """Rotate pairs. x: [B, H, S, d_head] or [B, S, d_head]; positions: [B, S]."""
    d_head = x.shape[-1]
    freqs = jnp.asarray(rope_frequencies(d_head, theta))
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # [B, S, d/2]
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    if x.ndim == 4:  # insert head axis
        cos, sin = cos[:, None], sin[:, None]
    x1, x2 = x[..., 0::2], x[..., 1::2]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    r1 = xf1 * cos - xf2 * sin
    r2 = xf1 * sin + xf2 * cos
    out = jnp.stack([r1, r2], axis=-1).reshape(x.shape)
    return out.astype(x.dtype)


def sinusoidal_positions(seq: int, d: int) -> np.ndarray:
    """Absolute sinusoidal table (encoder models without RoPE)."""
    pos = np.arange(seq, dtype=np.float32)[:, None]
    i = np.arange(d // 2, dtype=np.float32)[None, :]
    ang = pos / np.power(10000.0, 2 * i / d)
    out = np.zeros((seq, d), dtype=np.float32)
    out[:, 0::2] = np.sin(ang)
    out[:, 1::2] = np.cos(ang)
    return out
