"""Top-level model assembly: init / forward / loss / cache / decode for all
ten architecture families, with scan-over-layers and selectable remat.

Vocab-parallel cross-entropy: logits stay sharded over the ``model`` mesh
axis on the vocab dim; max/logsumexp/label-pick reductions over the sharded
axis lower to psums (Megatron-style) instead of gathering [B,S,V].
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.regions import region
from repro.models import transformer as tb
from repro.models.layers import (Params, embed_init, norm, norm_init,
                                 sinusoidal_positions)
from repro.sharding.rules import constrain

__all__ = ["init_params", "forward", "loss_fn", "init_cache",
           "decode_step", "decode_verify", "reset_cache_slots"]


def _compute_dtype(cfg: ModelConfig):
    return jnp.bfloat16 if cfg.compute_dtype == "bfloat16" else jnp.float32


def _remat(fn, cfg: ModelConfig):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        policy = jax.checkpoint_policies.checkpoint_dots
        return jax.checkpoint(fn, policy=policy)
    return jax.checkpoint(fn)


def _scan(body, carry, xs, unroll: bool):
    """lax.scan or a Python-unrolled loop.

    Unrolling exists for the dry-run/roofline path: XLA's cost analysis
    counts a while-loop body ONCE, so scanned-layer FLOPs/collectives would
    be undercounted by n_layers. Production runs keep scan (compact HLO).
    """
    if not unroll:
        return jax.lax.scan(body, carry, xs)
    L = jax.tree.leaves(xs)[0].shape[0]
    ys = []
    for i in range(L):
        x_i = jax.tree.map(lambda t: t[i], xs)
        carry, y = body(carry, x_i)
        ys.append(y)
    if ys and ys[0] is not None:
        ys = jax.tree.map(lambda *a: jnp.stack(a), *ys)
    else:
        ys = None
    return carry, ys


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def init_params(key, cfg: ModelConfig) -> Params:
    keys = jax.random.split(key, 8)
    p: Params = {}
    if not cfg.embed_inputs:
        p["embed"] = embed_init(keys[0], cfg.vocab_size, cfg.d_model)
    p["final_norm"] = norm_init(cfg.d_model, cfg.norm_kind)
    from repro.models.layers import dense_init
    p["lm_head"] = dense_init(keys[1], cfg.d_model, cfg.vocab_size)

    if cfg.family in ("dense", "moe", "audio", "vlm"):
        lk = jax.random.split(keys[2], cfg.n_layers)
        p["blocks"] = jax.vmap(lambda k: tb.tblock_init(k, cfg))(lk)
    elif cfg.family == "ssm" and cfg.slstm_every:          # xLSTM
        n_pairs = cfg.n_layers // 2
        lk = jax.random.split(keys[2], n_pairs)
        p["pairs"] = jax.vmap(lambda k: tb.xlstm_pair_init(k, cfg))(lk)
    elif cfg.family == "hybrid":                           # zamba2
        gs = cfg.attn_every
        n_groups = cfg.n_layers // gs
        tail = cfg.n_layers - n_groups * gs
        gk = jax.random.split(keys[2], n_groups)
        p["groups"] = jax.vmap(
            lambda k: tb.zamba_group_init(k, cfg, gs))(gk)
        if tail:
            p["tail"] = tb.zamba_group_init(keys[3], cfg, tail)
        p["shared_attn"] = tb.shared_attn_init(keys[4], cfg)
    else:
        raise ValueError(f"unknown family {cfg.family}")
    return p


# ---------------------------------------------------------------------------
# Forward (train / prefill)
# ---------------------------------------------------------------------------

def _embed(p: Params, cfg: ModelConfig, batch: dict[str, jax.Array]):
    """Token/frontend embedding → x [B,S,d] (compute dtype), positions [B,S]."""
    dt = _compute_dtype(cfg)
    if cfg.embed_inputs:                     # audio: precomputed frame embeds
        x = batch["embeds"].astype(dt)
        B, S = x.shape[:2]
        x = x + jnp.asarray(sinusoidal_positions(S, cfg.d_model), dt)[None]
    else:
        tokens = batch["tokens"]
        with region("embed"):
            x = jnp.take(p["embed"].astype(dt), tokens, axis=0)
        if cfg.family == "vlm" and "patch_embeds" in batch:
            x = jnp.concatenate([batch["patch_embeds"].astype(dt), x], axis=1)
        B, S = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    x = constrain(x, "batch", "seq", "embed")
    return x, positions


def _backbone(p: Params, cfg: ModelConfig, x: jax.Array,
              positions: jax.Array, *, attn_impl: str = "full",
              ssd_chunk: int = 128, unroll: bool = False,
              q_chunk: int = 1024):
    """All blocks (no embed / final norm / head). Returns (x, aux)."""
    aux0 = jnp.zeros((), jnp.float32)

    if cfg.family in ("dense", "moe", "audio", "vlm"):
        def body(carry, pl):
            h, aux = carry
            h = constrain(h, "batch", "seq_act", "embed")   # Megatron SP
            h, a = tb.tblock_forward(pl, cfg, h, positions,
                                     attn_impl=attn_impl, q_chunk=q_chunk,
                                     unroll_chunks=unroll)
            return (h, aux + a), None
        (x, aux), _ = _scan(_remat(body, cfg), (x, aux0), p["blocks"], unroll)
    elif cfg.family == "ssm":
        def body(carry, pl):
            h, aux = carry
            h = constrain(h, "batch", "seq_act", "embed")
            h, a = tb.xlstm_pair_forward(pl, cfg, h, positions,
                                         chunk=ssd_chunk,
                                         unroll_chunks=unroll)
            return (h, aux + a), None
        (x, aux), _ = _scan(_remat(body, cfg), (x, aux0), p["pairs"], unroll)
    else:                                                   # hybrid (zamba2)
        shared = p["shared_attn"]

        def body(h, pg):
            h = constrain(h, "batch", "seq_act", "embed")
            h = tb.zamba_group_forward(pg, cfg, h, chunk=ssd_chunk,
                                       unroll_chunks=unroll)
            h = tb.shared_attn_forward(shared, cfg, h, positions,
                                       attn_impl=attn_impl, q_chunk=q_chunk,
                                       unroll_chunks=unroll)
            return h, None
        x, _ = _scan(_remat(body, cfg), x, p["groups"], unroll)
        if "tail" in p:
            x = tb.zamba_group_forward(p["tail"], cfg, x, chunk=ssd_chunk,
                                       unroll_chunks=unroll)
        aux = aux0
    return x, aux


def forward(p: Params, cfg: ModelConfig, batch: dict[str, jax.Array], *,
            attn_impl: str = "full", ssd_chunk: int = 128,
            unroll: bool = False, q_chunk: int = 1024):
    """Full-sequence forward → logits [B, S, V] (vocab-sharded), aux loss."""
    x, positions = _embed(p, cfg, batch)
    x, aux = _backbone(p, cfg, x, positions, attn_impl=attn_impl,
                       ssd_chunk=ssd_chunk, unroll=unroll, q_chunk=q_chunk)
    x = constrain(x, "batch", None, "embed")
    x = norm(p["final_norm"], x, kind=cfg.norm_kind, eps=cfg.norm_eps)
    with region("lm_head"):
        logits = x @ p["lm_head"].astype(x.dtype)
        logits = constrain(logits, "batch", "seq", "vocab")
    return logits, aux


def cross_entropy(logits: jax.Array, labels: jax.Array,
                  mask: jax.Array | None = None) -> jax.Array:
    """Vocab-parallel stable CE. logits [B,S,V] sharded on V; labels [B,S].

    Every [B,S,V]-shaped intermediate is explicitly constrained to the
    logits sharding: without this, the label one-hot (built from an
    unsharded iota) makes GSPMD all-gather the fp32 logits — a
    B·S·V·4-byte replication that single-handedly OOMs the step (seen as
    268 GB/device in the yi-6b dry-run; §Perf log).
    """
    lf = logits.astype(jnp.float32)
    lf = constrain(lf, "batch", "seq", "vocab")
    m = jax.lax.stop_gradient(lf.max(axis=-1, keepdims=True))
    lse = jnp.log(jnp.sum(jnp.exp(lf - m), axis=-1)) + m[..., 0]
    iota = jax.lax.broadcasted_iota(jnp.int32, lf.shape, len(lf.shape) - 1)
    iota = constrain(iota, "batch", "seq", "vocab")
    onehot = labels[..., None] == iota
    ll = jnp.sum(jnp.where(onehot, lf, 0.0), axis=-1)
    nll = constrain(lse - ll, "batch", "seq")
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)


def _ce_sum(logits, labels):
    """Vocab-parallel CE, summed (not meaned) over positions."""
    lf = logits.astype(jnp.float32)
    lf = constrain(lf, "batch", "seq", "vocab")
    m = jax.lax.stop_gradient(lf.max(axis=-1, keepdims=True))
    lse = jnp.log(jnp.sum(jnp.exp(lf - m), axis=-1)) + m[..., 0]
    iota = jax.lax.broadcasted_iota(jnp.int32, lf.shape, len(lf.shape) - 1)
    iota = constrain(iota, "batch", "seq", "vocab")
    ll = jnp.sum(jnp.where(labels[..., None] == iota, lf, 0.0), axis=-1)
    return jnp.sum(lse - ll)


def fused_lm_head_ce(p: Params, cfg: ModelConfig, x: jax.Array,
                     labels: jax.Array, *, seq_chunk: int = 512):
    """lm_head matmul + CE fused over sequence chunks.

    Never materializes the full [B,S,V] logits: each chunk's logits are
    produced, consumed, and (via checkpoint) recomputed in backward —
    the dominant memory saving for large-vocab training steps (§Perf).
    """
    B, S, _ = x.shape
    W = p["lm_head"]
    if S % seq_chunk != 0:
        # largest divisor of S not exceeding the requested chunk (falling
        # back to one chunk would resurrect the full-logits buffer — seen
        # as 115 GB/dev on the VLM cell whose text length isn't 2^k)
        seq_chunk = next((c for c in range(seq_chunk, 0, -1)
                          if S % c == 0), S)
    n_chunks = S // seq_chunk
    xc = jnp.moveaxis(x.reshape(B, n_chunks, seq_chunk, -1), 1, 0)
    lc = jnp.moveaxis(labels.reshape(B, n_chunks, seq_chunk), 1, 0)

    @jax.checkpoint
    def body(acc, inp):
        xi, li = inp
        logits = xi @ W.astype(xi.dtype)
        logits = constrain(logits, "batch", "seq", "vocab")
        return acc + _ce_sum(logits, li), None

    total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (xc, lc))
    return total / (B * S)


def loss_fn(p: Params, cfg: ModelConfig, batch: dict[str, jax.Array], *,
            attn_impl: str = "full", ssd_chunk: int = 128,
            unroll: bool = False, fuse_ce: bool | None = None,
            q_chunk: int = 1024, ce_chunk: int = 512):
    labels = batch["labels"]
    if fuse_ce is None:
        fuse_ce = (batch.get("loss_mask") is None
                   and labels.shape[-1] >= 2048)
    if fuse_ce:
        # Run the backbone, then the fused chunked lm_head+CE. For VLM,
        # loss covers text positions only: slice the backbone output (the
        # patch prefix carries no labels) before the head.
        x, positions = _embed(p, cfg, batch)
        x, aux = _backbone(p, cfg, x, positions, attn_impl=attn_impl,
                           ssd_chunk=ssd_chunk, unroll=unroll,
                           q_chunk=q_chunk)
        if cfg.family == "vlm" and "patch_embeds" in batch:
            n_patch = batch["patch_embeds"].shape[1]
            x = constrain(x, "batch", None, "embed")[:, n_patch:, :]
        x = constrain(x, "batch", "seq_act", "embed")
        x = norm(p["final_norm"], x, kind=cfg.norm_kind, eps=cfg.norm_eps)
        with region("loss"):
            ce = fused_lm_head_ce(p, cfg, x, labels, seq_chunk=ce_chunk)
        return ce + aux, {"ce": ce, "aux": aux}

    logits, aux = forward(p, cfg, batch, attn_impl=attn_impl,
                          ssd_chunk=ssd_chunk, unroll=unroll,
                          q_chunk=q_chunk)
    with region("loss"):
        if cfg.family == "vlm" and "patch_embeds" in batch:
            n_patch = batch["patch_embeds"].shape[1]
            logits = logits[:, n_patch:, :]
        ce = cross_entropy(logits, labels, batch.get("loss_mask"))
    metrics = {"ce": ce, "aux": aux}
    return ce + aux, metrics


def prefill(p: Params, cfg: ModelConfig, batch: dict[str, jax.Array],
            max_len: int, *, attn_impl: str = "chunked",
            ssd_chunk: int = 128, cache_dtype=jnp.bfloat16,
            unroll: bool = False, q_chunk: int = 1024):
    """Inference prefill: forward over the prompt, returning (logits of the
    last position [B,1,V], populated cache, cur_len)."""
    x, positions = _embed(p, cfg, batch)
    S = x.shape[1]

    if cfg.family in ("dense", "moe", "audio", "vlm"):
        def body(h, pl):
            h = constrain(h, "batch", "seq_act", "embed")   # Megatron SP
            h, cache = tb.tblock_prefill(pl, cfg, h, positions, max_len,
                                         attn_impl=attn_impl,
                                         cache_dtype=cache_dtype,
                                         q_chunk=q_chunk,
                                         unroll_chunks=unroll)
            return h, cache
        x, caches = _scan(body, x, p["blocks"], unroll)
        cache = {"blocks": caches}
    elif cfg.family == "ssm":
        def body(h, pl):
            h = constrain(h, "batch", "seq_act", "embed")
            h, cache = tb.xlstm_pair_prefill(pl, cfg, h, positions,
                                             chunk=ssd_chunk,
                                             unroll_chunks=unroll)
            return h, cache
        x, caches = _scan(body, x, p["pairs"], unroll)
        cache = {"pairs": caches}
    else:
        shared = p["shared_attn"]

        def body(h, pg):
            h = constrain(h, "batch", "seq_act", "embed")
            h, cg = tb.zamba_group_prefill(pg, cfg, h, chunk=ssd_chunk,
                                           unroll_chunks=unroll)
            h, ca = tb.shared_attn_prefill(shared, cfg, h, positions,
                                           max_len, attn_impl=attn_impl,
                                           cache_dtype=cache_dtype,
                                           q_chunk=q_chunk,
                                           unroll_chunks=unroll)
            return h, (cg, ca)
        x, (cgs, cas) = _scan(body, x, p["groups"], unroll)
        cache = {"groups": cgs, "shared_attn": cas}
        if "tail" in p:
            x, ct = tb.zamba_group_prefill(p["tail"], cfg, x,
                                           chunk=ssd_chunk,
                                           unroll_chunks=unroll)
            cache["tail"] = ct

    x = norm(p["final_norm"], x, kind=cfg.norm_kind, eps=cfg.norm_eps)
    with region("lm_head"):
        logits = x[:, -1:, :] @ p["lm_head"].astype(x.dtype)
        logits = constrain(logits, "batch", None, "vocab")
    return logits, cache, jnp.asarray(S, jnp.int32)


# ---------------------------------------------------------------------------
# Cache + decode
# ---------------------------------------------------------------------------

def _kv_cache(cfg: ModelConfig, batch: int, max_len: int, dtype) -> Params:
    KV, dh = cfg.n_kv_heads, cfg.head_dim
    shape = (batch, KV, max_len, dh)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16) -> Params:
    from repro.models.ssm import ssm_cache_init
    from repro.models.xlstm import mlstm_cache_init, slstm_cache_init

    if cfg.family in ("dense", "moe", "audio", "vlm"):
        def one(_):
            return _kv_cache(cfg, batch, max_len, dtype)
        return {"blocks": jax.vmap(one)(jnp.arange(cfg.n_layers))}
    if cfg.family == "ssm":
        n_pairs = cfg.n_layers // 2
        def one(_):
            return {"m": mlstm_cache_init(cfg, batch),
                    "s": slstm_cache_init(cfg, batch)}
        return {"pairs": jax.vmap(one)(jnp.arange(n_pairs))}
    # hybrid
    gs = cfg.attn_every
    n_groups = cfg.n_layers // gs
    tail = cfg.n_layers - n_groups * gs
    def ssm_g(n):
        return jax.vmap(lambda _: ssm_cache_init(cfg, batch, dtype))(
            jnp.arange(n))
    cache: Params = {
        "groups": jax.vmap(lambda _: ssm_g(gs))(jnp.arange(n_groups)),
        "shared_attn": jax.vmap(
            lambda _: _kv_cache(cfg, batch, max_len, dtype))(
                jnp.arange(n_groups)),
    }
    if tail:
        cache["tail"] = ssm_g(tail)
    return cache


def _mask_cache(new: Params, old: Params, write_mask, batch_axis: int = 0):
    """Keep ``old`` cache rows where ``write_mask`` [B] is False.

    Slot-masked cache updates for continuous batching: a teacher-forced
    prefill of one slot runs the whole-batch decode step, and without the
    mask every *other* slot's KV entries (and recurrent SSM/xLSTM state,
    which advances on every call regardless of position) would be
    stomped at the prefilled positions.
    """
    if write_mask is None:
        return new

    def sel(n, o):
        shape = [1] * n.ndim
        shape[batch_axis] = n.shape[batch_axis]
        return jnp.where(write_mask.reshape(shape), n, o)
    return jax.tree.map(sel, new, old)


def reset_cache_slots(cfg: ModelConfig, cache: Params,
                      slot_mask: jax.Array) -> Params:
    """Zero the cache state of every True row of ``slot_mask`` [B].

    Slot admission for continuous batching: every cache family
    initializes to zeros, so re-zeroing a slot's rows restores it to
    init-time state. KV caches don't strictly need it (prefill rewrites
    positions 0.. under a causal mask), but recurrent SSM/xLSTM state is
    *input* to the next step — a reused slot would otherwise seed the
    new request with its previous occupant's final state.
    """
    del cfg
    # Every top-level cache group stacks layers ahead of batch; zamba2
    # groups stack (n_groups, group_size) — two leading layer axes.
    axis_by_key = {"groups": 2}

    def zero(sub, batch_axis):
        def sel(n):
            shape = [1] * n.ndim
            shape[batch_axis] = n.shape[batch_axis]
            return jnp.where(slot_mask.reshape(shape),
                             jnp.zeros((), n.dtype), n)
        return jax.tree.map(sel, sub)
    return {k: zero(v, axis_by_key.get(k, 1)) for k, v in cache.items()}


def decode_step(p: Params, cfg: ModelConfig, tokens: jax.Array,
                cache: Params, cur_len: jax.Array, *,
                write_mask: jax.Array | None = None, unroll: bool = False,
                window: int | None = None, sinks: int = 0):
    """One decode step. tokens: [B,S] int32 (or embeds [B,S,d] for audio);
    S=1 is the classic single-token step. S>1 (the self-speculative
    verify sweep) is only meaningful for KV-attention families — the
    recurrent families advance state once per *call*, not per position,
    so multi-position scoring for them goes through ``decode_verify``.

    ``cur_len`` is [] or [B] int32 — per-row cache depth (scalar = every
    row at the same depth); position j of row b lands at cache position
    ``cur_len[b] + j``. ``write_mask`` [B] bool, when given, confines
    cache mutation to True rows (False rows' cache state — KV entries and
    recurrent state — passes through untouched); logits are still
    computed for every row. ``window``/``sinks`` select the StreamingLLM
    sliding-window attention mask used by the speculative draft pass
    (KV-attention layers only; recurrent layers are unaffected).

    Returns (logits [B,S,V], new_cache).
    """
    dt = _compute_dtype(cfg)
    if cfg.embed_inputs:
        x = tokens.astype(dt)
    else:
        with region("embed"):
            x = jnp.take(p["embed"].astype(dt), tokens, axis=0)
    x = constrain(x, "batch", None, "embed")

    if cfg.family in ("dense", "moe", "audio", "vlm"):
        def body(h, inp):
            pl, cl = inp
            h, ncl = tb.tblock_decode(pl, cfg, h, cl, cur_len,
                                      window=window, sinks=sinks)
            return h, _mask_cache(ncl, cl, write_mask)
        x, nc = _scan(body, x, (p["blocks"], cache["blocks"]), unroll)
        new_cache = {"blocks": nc}
    elif cfg.family == "ssm":
        def body(h, inp):
            pl, cl = inp
            h, ncl = tb.xlstm_pair_decode(pl, cfg, h, cl, cur_len)
            return h, _mask_cache(ncl, cl, write_mask)
        x, nc = _scan(body, x, (p["pairs"], cache["pairs"]), unroll)
        new_cache = {"pairs": nc}
    else:                                                   # hybrid
        shared = p["shared_attn"]

        def body(h, inp):
            pg, cg, ca = inp
            h, ncg = tb.zamba_group_decode(pg, cfg, h, cg)
            h, nca = tb.shared_attn_decode(shared, cfg, h, ca, cur_len,
                                           window=window, sinks=sinks)
            # group caches stack layers ahead of batch: [gs, B, ...]
            return h, (_mask_cache(ncg, cg, write_mask, batch_axis=1),
                       _mask_cache(nca, ca, write_mask))
        x, (ncg, nca) = _scan(
            body, x, (p["groups"], cache["groups"], cache["shared_attn"]),
            unroll)
        new_cache = {"groups": ncg, "shared_attn": nca}
        if "tail" in cache:
            x, nct = tb.zamba_group_decode(p["tail"], cfg, x, cache["tail"])
            new_cache["tail"] = _mask_cache(nct, cache["tail"], write_mask,
                                            batch_axis=1)

    x = norm(p["final_norm"], x, kind=cfg.norm_kind, eps=cfg.norm_eps)
    with region("lm_head"):
        logits = x @ p["lm_head"].astype(x.dtype)
        logits = constrain(logits, "batch", None, "vocab")
    return logits, new_cache


def decode_verify(p: Params, cfg: ModelConfig, tokens: jax.Array,
                  cache: Params, cur_len: jax.Array, *,
                  write_mask: jax.Array | None = None,
                  unroll: bool = False):
    """Self-speculative verify: score L >= 1 positions in one jitted step.

    ``tokens`` [B,L] int32; position j of row b is the model input at
    cache position ``cur_len[b] + j`` — row layout is the draft matrix
    ``[t_0, d_1, .., d_{L-1}]`` where t_0 is the pending baseline token
    and d_j are draft proposals. Returns ``(logits [B,L,V], new_cache)``
    with logits[:, j] scoring the successor of position cur_len+j — the
    greedy accept-prefix compares argmax(logits[:, j]) against d_{j+1}.

    KV-attention families run the batched multi-position ``decode_step``
    directly: each query row attends over the full cache under its own
    causal mask — the same reduction the single-token step performs, so
    accepted positions are token-exact to sequential decoding.

    Recurrent families (ssm/hybrid) advance state once per call, so the
    batched form would be wrong; they scan the single-token step over
    the position axis instead — bit-exact to sequential decoding by
    construction, still one compile key per (config, L).
    """
    if cfg.family in ("dense", "moe", "audio", "vlm"):
        return decode_step(p, cfg, tokens, cache, cur_len,
                           write_mask=write_mask, unroll=unroll)

    B, L = tokens.shape
    cl = jnp.broadcast_to(jnp.asarray(cur_len, jnp.int32), (B,))

    def body(c, inp):
        tok_j, off = inp
        logits_j, c = decode_step(p, cfg, tok_j[:, None], c, cl + off,
                                  write_mask=write_mask, unroll=unroll)
        return c, logits_j[:, 0]

    xs = (jnp.moveaxis(tokens, 1, 0), jnp.arange(L, dtype=jnp.int32))
    if unroll:
        ls = []
        c = cache
        for j in range(L):
            c, lj = body(c, jax.tree.map(lambda t: t[j], xs))
            ls.append(lj)
        return jnp.stack(ls, axis=1), c
    cache, ls = jax.lax.scan(body, cache, xs)
    return jnp.moveaxis(ls, 0, 1), cache
