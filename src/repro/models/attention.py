"""GQA attention: full / chunked-prefill / cached-decode paths.

TP sharding: head-parallel projections, row-parallel output (Megatron).
GQA is computed in MHA form — KV heads are repeated to the full head count
(`jnp.repeat` on the head axis, which XLA fuses into the score matmuls) so
the *query-head axis stays intact* end-to-end and shards cleanly over the
``model`` mesh axis even when kv_heads < TP width. A [KV, G] reshape would
instead break GSPMD propagation and force activation all-gathers (measured
in the §Perf log).

Decode supports a sequence-sharded KV cache: the softmax reductions over
the sharded key axis lower to psums (flash-decoding split-K) under GSPMD.

The Pallas flash-attention kernel (``repro.kernels.flash_attention``) is
selected with ``impl="pallas"`` on TPU; ``impl="chunked"`` is the jnp path
used by the CPU dry-run and as the oracle.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.regions import region
from repro.models.layers import Params, apply_rope, dense_init, linear, rmsnorm
from repro.sharding.rules import constrain

NEG_INF = -2.0e38


def attention_init(key, cfg: ModelConfig) -> Params:
    dh, H, KV, d = cfg.head_dim, cfg.n_heads, cfg.n_kv_heads, cfg.d_model
    ks = jax.random.split(key, 4)
    p: Params = {
        "wq": dense_init(ks[0], d, H * dh),
        "wk": dense_init(ks[1], d, KV * dh),
        "wv": dense_init(ks[2], d, KV * dh),
        "wo": dense_init(ks[3], H * dh, d, scale=(H * dh) ** -0.5),
    }
    if cfg.qk_norm:
        p["q_norm"] = {"scale": jnp.ones((dh,), jnp.float32)}
        p["k_norm"] = {"scale": jnp.ones((dh,), jnp.float32)}
    return p


def _project_qkv(p: Params, cfg: ModelConfig, x: jnp.ndarray,
                 positions: jnp.ndarray):
    """x [B,S,d] → q [B,H,S,dh], k/v [B,KV,S,dh] (roped, normed)."""
    B, S, _ = x.shape
    dh, H, KV = cfg.head_dim, cfg.n_heads, cfg.n_kv_heads
    q = linear(p["wq"], x).reshape(B, S, H, dh).transpose(0, 2, 1, 3)
    k = linear(p["wk"], x).reshape(B, S, KV, dh).transpose(0, 2, 1, 3)
    v = linear(p["wv"], x).reshape(B, S, KV, dh).transpose(0, 2, 1, 3)
    if cfg.qk_norm:
        q = rmsnorm(p["q_norm"], q, eps=cfg.norm_eps)
        k = rmsnorm(p["k_norm"], k, eps=cfg.norm_eps)
    if cfg.use_rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    q = constrain(q, "batch", "heads", "seq", "head_dim")
    k = constrain(k, "batch", "kv_heads", "seq", "head_dim")
    v = constrain(v, "batch", "kv_heads", "seq", "head_dim")
    return q, k, v


def _repeat_kv(t: jnp.ndarray, cfg: ModelConfig, *, seq_axis: str | None
               ) -> jnp.ndarray:
    """[B,KV,S,dh] → [B,H,S,dh]; keeps the head axis TP-shardable.

    When the KV-cache *sequence* is sharded (flash-decoding split-K for
    GQA groups narrower than the TP axis), the head axis must stay
    replicated — both can't land on the same mesh axis.
    """
    from repro.sharding.rules import current_rules
    if cfg.q_per_kv != 1:
        t = jnp.repeat(t, cfg.q_per_kv, axis=1)
    r = current_rules()
    head_axis = "heads"
    if (seq_axis is not None and r is not None
            and r.mapping.get(seq_axis) is not None):
        head_axis = None
    return constrain(t, "batch", head_axis, seq_axis, "head_dim")


def _sdpa(q, k, v, mask) -> jnp.ndarray:
    """MHA scaled-dot-product. q: [B,H,Sq,dh], k/v: [B,H,Skv,dh], mask
    broadcastable to [B,H,Sq,Skv] (True = attend). fp32 softmax."""
    dh = q.shape[-1]
    scores = jnp.einsum("bhqd,bhtd->bhqt", q, k,
                        preferred_element_type=jnp.float32)
    scores = scores * (dh ** -0.5)
    scores = jnp.where(mask, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhqt,bhtd->bhqd", probs.astype(v.dtype), v)


def _merge_heads(p: Params, out: jnp.ndarray) -> jnp.ndarray:
    """[B,H,S,dh] → o-proj → [B,S,d]."""
    B, H, S, dh = out.shape
    out = out.transpose(0, 2, 1, 3).reshape(B, S, H * dh)
    y = linear(p["wo"], out)
    return constrain(y, "batch", "seq", "embed")


def _attend(cfg: ModelConfig, q, k, v, positions, *, impl: str,
            q_chunk: int, unroll_chunks: bool = False):
    """Core attention. q: [B,H,S,dh]; k/v: [B,KV,S,dh] → [B,H,S,dh]."""
    B, H, S, dh = q.shape

    if impl == "pallas":
        from repro.kernels.flash_attention import ops as fa_ops
        return fa_ops.flash_attention(q, _repeat_kv(k, cfg, seq_axis="seq"),
                                      _repeat_kv(v, cfg, seq_axis="seq"),
                                      causal=cfg.causal)

    kr = _repeat_kv(k, cfg, seq_axis="seq")
    vr = _repeat_kv(v, cfg, seq_axis="seq")

    if impl == "full" or S <= q_chunk:
        with region("attn_score"):
            pos_q = positions[:, None, :, None]
            pos_k = positions[:, None, None, :]
            mask = (pos_k <= pos_q) if cfg.causal else jnp.ones(
                (B, 1, S, S), bool)
            return _sdpa(q, kr, vr, mask)

    # chunked: lax.scan over query chunks; keys/values stay whole.
    assert S % q_chunk == 0, (S, q_chunk)
    n_chunks = S // q_chunk
    qc = jnp.moveaxis(q.reshape(B, H, n_chunks, q_chunk, dh), 2, 0)
    pc = jnp.moveaxis(positions.reshape(B, n_chunks, q_chunk), 1, 0)

    def body(_, inp):
        qi, pi = inp
        with region("attn_score"):
            pos_k = positions[:, None, None, :]
            mask = (pos_k <= pi[:, None, :, None]) if cfg.causal \
                else jnp.ones((B, 1, q_chunk, S), bool)
            oi = _sdpa(qi, kr, vr, mask)
        return None, oi

    if unroll_chunks:
        # Cost-compile path: Python loop so XLA cost analysis counts every
        # chunk (a while body is counted once — see dryrun docstring).
        outs = [body(None, (qc[i], pc[i]))[1] for i in range(n_chunks)]
        out = jnp.stack(outs)
    else:
        # Nested remat: without it, backward through the chunk scan saves
        # every chunk's fp32 scores/probs (≈ full S² materialization again,
        # defeating chunking); with it, each chunk's scores are recomputed
        # in its own bwd.
        _, out = jax.lax.scan(jax.checkpoint(body), None, (qc, pc))
    return jnp.moveaxis(out, 0, 2).reshape(B, H, S, dh)


def attention(p: Params, cfg: ModelConfig, x: jnp.ndarray,
              positions: jnp.ndarray, *, impl: str = "full",
              q_chunk: int = 1024,
              unroll_chunks: bool = False) -> jnp.ndarray:
    """Self-attention over a full sequence (train / prefill).

    impl: "full" materializes [Sq,Skv] scores (small seq);
          "chunked" scans over query chunks (bounded memory at 32k);
          "pallas" dispatches to the flash-attention kernel (TPU).
    """
    q, k, v = _project_qkv(p, cfg, x, positions)
    out = _attend(cfg, q, k, v, positions, impl=impl, q_chunk=q_chunk,
                  unroll_chunks=unroll_chunks)
    return _merge_heads(p, out)


def attention_prefill(p: Params, cfg: ModelConfig, x: jnp.ndarray,
                      positions: jnp.ndarray, max_len: int, *,
                      impl: str = "chunked", q_chunk: int = 1024,
                      cache_dtype=jnp.bfloat16, unroll_chunks: bool = False):
    """Prefill: forward over the prompt AND populate a [.., max_len, ..]
    KV cache. Returns (y, cache_k, cache_v)."""
    B, S, _ = x.shape
    q, k, v = _project_qkv(p, cfg, x, positions)
    out = _attend(cfg, q, k, v, positions, impl=impl, q_chunk=q_chunk,
                  unroll_chunks=unroll_chunks)
    y = _merge_heads(p, out)
    shape = (B, cfg.n_kv_heads, max_len, cfg.head_dim)
    pad = [(0, 0), (0, 0), (0, max_len - S), (0, 0)]
    ck = jnp.pad(k.astype(cache_dtype), pad)
    cv = jnp.pad(v.astype(cache_dtype), pad)
    ck = constrain(ck, "batch", "kv_heads", "kv_seq", "head_dim")
    cv = constrain(cv, "batch", "kv_heads", "kv_seq", "head_dim")
    return y, ck, cv


def attention_decode(p: Params, cfg: ModelConfig, x: jnp.ndarray,
                     cache_k: jnp.ndarray, cache_v: jnp.ndarray,
                     cur_len: jnp.ndarray, *, window: int | None = None,
                     sinks: int = 0):
    """Cached decode over S >= 1 fresh positions. x: [B,S,d]; cache_k/v:
    [B,KV,T,dh]; cur_len: [] or [B] int32 = number of valid positions
    already in the cache, per row. Query j of row b lands at cache
    position ``cur_len[b] + j``; S=1 is the classic one-token step, S=L
    scores a whole self-speculation window in one sweep.

    A scalar ``cur_len`` broadcasts to the whole batch (all rows at the
    same depth — the dryrun/benchmark path). Continuous-batching callers
    pass a [B] vector: each row's K/V is written at *its own* position
    and attends under its own causal mask, so slots at different depths
    share one decode step without corrupting each other's cache.

    ``window`` switches on the sliding-window draft mask (StreamingLLM):
    each query attends only to the last ``window`` cache positions plus
    the first ``sinks`` attention-sink positions. ``None`` keeps the full
    causal mask over the valid prefix — the target/verify semantics.

    Returns (y [B,S,d], new_cache_k, new_cache_v).
    """
    B, S, _ = x.shape
    T = cache_k.shape[2]
    cl = jnp.broadcast_to(jnp.asarray(cur_len, jnp.int32), (B,))
    positions = cl[:, None] + jnp.arange(S, dtype=jnp.int32)[None, :]
    q, k, v = _project_qkv(p, cfg, x, positions)

    # Write each row's new K/V at that row's own position (a single
    # scalar start index would leave gaps for shallow rows and overwrite
    # live entries of deep ones under ragged slot lengths).
    def _write_row(c, u, l):
        return jax.lax.dynamic_update_slice(c, u, (0, l, 0))
    cache_k = jax.vmap(_write_row)(cache_k, k.astype(cache_k.dtype), cl)
    cache_v = jax.vmap(_write_row)(cache_v, v.astype(cache_v.dtype), cl)
    cache_k = constrain(cache_k, "batch", "kv_heads", "kv_seq", "head_dim")
    cache_v = constrain(cache_v, "batch", "kv_heads", "kv_seq", "head_dim")

    with region("attn_decode"):
        t_idx = jnp.arange(T)[None, None, None, :]
        pos_q = positions[:, None, :, None]
        valid = t_idx <= pos_q
        if window is not None:
            # Sliding-window + sinks: StreamingLLM draft mask. The sink
            # prefix anchors softmax mass so narrow windows stay stable.
            keep = t_idx > pos_q - jnp.int32(window)
            if sinks:
                keep = keep | (t_idx < sinks)
            valid = valid & keep
        if cfg.decode_grouped and cfg.q_per_kv > 1:
            # Grouped form: contract q-groups directly against the raw
            # [B,KV,T,dh] cache — no head-repetition, so the cache is read
            # once instead of q_per_kv times (§Perf: memory-bound decode).
            # Only safe when heads aren't TP-sharded (kv_seq decode mode).
            KV, G, dh = cfg.n_kv_heads, cfg.q_per_kv, cfg.head_dim
            qg = q.reshape(B, KV, G, S, dh).astype(jnp.float32)
            kc = cache_k.astype(jnp.float32)
            scores = jnp.einsum("bkgqd,bktd->bkgqt", qg, kc) * dh ** -0.5
            scores = jnp.where(valid[:, :, None], scores, NEG_INF)
            probs = jax.nn.softmax(scores, axis=-1)
            out = jnp.einsum("bkgqt,bktd->bkgqd", probs,
                             cache_v.astype(jnp.float32))
            out = out.reshape(B, KV * G, S, dh).astype(q.dtype)
        else:
            kr = _repeat_kv(cache_k.astype(q.dtype), cfg, seq_axis="kv_seq")
            vr = _repeat_kv(cache_v.astype(q.dtype), cfg, seq_axis="kv_seq")
            out = _sdpa(q, kr, vr, valid)
    y = _merge_heads(p, out)
    return y, cache_k, cache_v
