"""Top-k MoE with capacity-based expert-parallel dispatch.

Router (replicated math) runs in pjit-land; expert compute runs either:

  * locally (single process / smoke tests): all experts on one device;
  * under ``shard_map`` with experts sharded over the ``model`` mesh axis:
    every rank selects, for each of its local experts, the top-capacity
    tokens assigned to that expert, runs the expert FFN on the gathered
    slab, scatter-adds weighted outputs, and a single ``psum`` over the
    expert axis combines contributions — an allreduce-combine EP scheme.
    (The all-to-all dispatch variant is a §Perf hillclimb alternative —
    see ``moe_apply_a2a``.)

Tokens beyond an expert's capacity are dropped (standard capacity-factor
semantics); dropped tokens pass through on the residual path.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.configs.base import ModelConfig
from repro.core.regions import region
from repro.models.layers import Params, dense_init
from repro.sharding.rules import constrain, current_rules

__all__ = ["moe_init", "moe_ffn", "router"]


def moe_init(key, cfg: ModelConfig) -> Params:
    d, E, ff = cfg.d_model, cfg.n_experts, cfg.moe_d_ff
    ks = jax.random.split(key, 4)
    return {
        "router": dense_init(ks[0], d, E),
        "up": jax.vmap(lambda k: dense_init(k, d, ff))(
            jax.random.split(ks[1], E)),
        "gate": jax.vmap(lambda k: dense_init(k, d, ff))(
            jax.random.split(ks[2], E)),
        "down": jax.vmap(lambda k: dense_init(k, ff, d))(
            jax.random.split(ks[3], E)),
    }


def router(p: Params, cfg: ModelConfig, x: jnp.ndarray):
    """x: [T,d] → (combine_weights [T,k], expert_idx [T,k], aux_loss)."""
    logits = (x.astype(jnp.float32) @ p["router"])          # [T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = jax.lax.top_k(probs, cfg.top_k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)
    # Switch-style load-balance auxiliary loss.
    E = cfg.n_experts
    assign = jax.nn.one_hot(top_i[:, 0], E, dtype=jnp.float32)
    f = assign.mean(0)                  # dispatch fraction per expert
    pr = probs.mean(0)                  # mean router prob per expert
    aux = cfg.router_aux_coeff * E * jnp.sum(f * pr)
    return top_p, top_i, aux


def _expert_compute(up, gate, down, x_slab):
    """Batched expert FFN. x_slab: [El, C, d] → [El, C, d]."""
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", x_slab, gate.astype(x_slab.dtype)))
    h = h * jnp.einsum("ecd,edf->ecf", x_slab, up.astype(x_slab.dtype))
    return jnp.einsum("ecf,efd->ecd", h, down.astype(x_slab.dtype))


def _dispatch_dense(up, gate, down, x, top_p, top_i):
    """Dropless per-token dispatch: gather each token's top-k experts'
    weights and run them directly — T·k expert-rows of compute instead
    of the capacity path's E·T (which, at dropless capacity, runs every
    expert over every token and zero-weights the misses). The gather
    reads at most T·k experts' weights; decode-sized T makes that far
    below the capacity path's all-E read.
    """
    gu = jnp.take(up, top_i, axis=0)          # [T, k, d, ff]
    gg = jnp.take(gate, top_i, axis=0)
    gd = jnp.take(down, top_i, axis=0)        # [T, k, ff, d]
    h = jax.nn.silu(jnp.einsum("td,tkdf->tkf", x, gg.astype(x.dtype)))
    h = h * jnp.einsum("td,tkdf->tkf", x, gu.astype(x.dtype))
    h = h * top_p[..., None].astype(x.dtype)
    return jnp.einsum("tkf,tkfd->td", h, gd.astype(x.dtype))


def _dispatch_local(up, gate, down, x, top_p, top_i, *, e0: int,
                    n_local: int, n_total: int, capacity: int):
    """Capacity-gather dispatch for experts [e0, e0+n_local).

    x: [T,d]; top_p/top_i: [T,k]. Returns partial y [T,d] containing only
    the local experts' contributions (caller psums across expert shards).
    """
    T = x.shape[0]
    # score[e_local, t]: combine weight if token t routed to local expert e.
    local_ids = e0 + jnp.arange(n_local)                       # [El]
    match = (top_i[None, :, :] == local_ids[:, None, None])    # [El, T, k]
    score = jnp.where(match, top_p[None, :, :], 0.0).sum(-1)   # [El, T]
    # Per-expert top-capacity token selection (tokens over capacity drop).
    cap = min(capacity, T)
    w, tok_idx = jax.lax.top_k(score, cap)                     # [El, C]
    x_slab = jnp.take(x, tok_idx.reshape(-1), axis=0)          # [El*C, d]
    x_slab = x_slab.reshape(n_local, cap, -1)
    y_slab = _expert_compute(up, gate, down, x_slab)           # [El, C, d]
    y_slab = y_slab * w[..., None].astype(y_slab.dtype)
    y = jnp.zeros_like(x)
    y = y.at[tok_idx.reshape(-1)].add(y_slab.reshape(n_local * cap, -1))
    return y


def moe_ffn(p: Params, cfg: ModelConfig, x: jnp.ndarray, *,
            dropless: bool = False):
    """MoE FFN over x: [B,S,d] (or [T,d]). Returns (y, aux_loss).

    ``dropless=True`` guarantees no token is ever dropped. Decode paths
    use it: the capacity heuristic is a load-balancing device calibrated
    for training-scale T, and at decode batch sizes it quantizes to ~1
    slot — making each slot's output depend on which *other* requests
    share the batch (a dropped token silently degrades to its residual).
    Dropless dispatch keeps every row's computation row-local, so
    continuous batching is token-exact against single-request decoding.
    Local (unsharded) dropless routes through :func:`_dispatch_dense`
    (T·k expert-rows); the expert-parallel path keeps the capacity
    gather with capacity = local token count (dense gather would need
    cross-shard expert weights).
    """
    orig_shape = x.shape
    x2 = x.reshape(-1, orig_shape[-1])
    with region("moe_router"):
        top_p, top_i, aux = router(p, cfg, x2)
    E = cfg.n_experts

    rules = current_rules()
    expert_axis = None if rules is None else rules.mapping.get("experts")
    if expert_axis is None or rules.mesh is None:
        if dropless:
            with region("moe_ffn"):
                y = _dispatch_dense(p["up"], p["gate"], p["down"], x2,
                                    top_p, top_i)
            return y.reshape(orig_shape), aux
        cap = max(int(cfg.capacity_factor * x2.shape[0] * cfg.top_k / E), 1)
        with region("moe_ffn"):
            y = _dispatch_local(p["up"], p["gate"], p["down"], x2,
                                top_p.astype(x2.dtype), top_i,
                                e0=0, n_local=E, n_total=E, capacity=cap)
        return y.reshape(orig_shape), aux

    mesh = rules.mesh
    n_shards = mesh.shape[expert_axis]
    assert E % n_shards == 0, (E, n_shards)
    n_local = E // n_shards
    batch_axes = rules.mapping.get("batch")

    # Per-DP-shard token count sets capacity (tokens are sharded over DP
    # axes and replicated over the expert axis inside the shard_map block).
    dp = 1
    if batch_axes is not None:
        for a in ((batch_axes,) if isinstance(batch_axes, str) else batch_axes):
            dp *= mesh.shape[a]
    t_local = max(x2.shape[0] // dp, 1)
    cap = t_local if dropless else max(
        int(cfg.capacity_factor * t_local * cfg.top_k / E), 1)

    bspec = batch_axes if batch_axes is not None else None
    tok_spec = P(bspec, None)       # [T, d] with T sharded over DP axes
    rt_spec = P(bspec, None)

    def wrapped(xl, pl, il, up, gate, down):
        e0 = jax.lax.axis_index(expert_axis) * n_local
        y = _dispatch_local(up, gate, down, xl, pl.astype(xl.dtype), il,
                            e0=e0, n_local=n_local, n_total=E, capacity=cap)
        return jax.lax.psum(y, expert_axis)

    with region("moe_ffn"):
        y2 = shard_map(
            wrapped, mesh=mesh,
            in_specs=(tok_spec, rt_spec, rt_spec,
                      P(expert_axis, None, None), P(expert_axis, None, None),
                      P(expert_axis, None, None)),
            out_specs=tok_spec,
            check_vma=False,
        )(x2, top_p, top_i, p["up"], p["gate"], p["down"])
    return y2.reshape(orig_shape), aux
