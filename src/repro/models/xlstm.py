"""xLSTM blocks: mLSTM (matrix memory, chunkwise-parallel) + sLSTM (scalar
memory, strictly recurrent scan — the architecture's stated property).

mLSTM math follows the paper's stabilized exponential gating: running
stabilizer m, stabilized state (C̃, ñ) with true state C = C̃·exp(m).
The chunkwise form processes Q-token chunks with an intra-chunk masked
(gated) attention and an inter-chunk recurrent carry, validated against the
step-by-step recurrent reference in tests.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.regions import region
from repro.models.layers import Params, dense_init, rmsnorm
from repro.sharding.rules import constrain

__all__ = ["mlstm_init", "mlstm_forward", "mlstm_decode", "mlstm_cache_init",
           "slstm_init", "slstm_forward", "slstm_decode", "slstm_cache_init"]

NEG = -1e30


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def mlstm_init(key, cfg: ModelConfig) -> Params:
    d, H, hd = cfg.d_model, cfg.n_heads, cfg.head_dim
    k = jax.random.split(key, 6)
    return {
        "wq": dense_init(k[0], d, H * hd),
        "wk": dense_init(k[1], d, H * hd),
        "wv": dense_init(k[2], d, H * hd),
        "wif": dense_init(k[3], d, 2 * H),   # input & forget gate pre-acts
        "wo": dense_init(k[4], H * hd, d, scale=(H * hd) ** -0.5),
        "ogate": dense_init(k[5], d, H * hd),
        "norm": {"scale": jnp.ones((H * hd,), jnp.float32)},
        "f_bias": 3.0 * jnp.ones((H,), jnp.float32),   # open forget gates
    }


def _mlstm_qkv(p: Params, cfg: ModelConfig, x: jnp.ndarray):
    B, S, _ = x.shape
    H, hd = cfg.n_heads, cfg.head_dim
    def heads(w):
        return (x @ w.astype(x.dtype)).reshape(B, S, H, hd).transpose(0, 2, 1, 3)
    q, k, v = heads(p["wq"]), heads(p["wk"]), heads(p["wv"])
    q = constrain(q, "batch", "heads", "seq", "head_dim")
    k = constrain(k, "batch", "heads", "seq", "head_dim")
    v = constrain(v, "batch", "heads", "seq", "head_dim")
    gif = (x @ p["wif"].astype(x.dtype)).astype(jnp.float32)
    gi = gif[..., :H].transpose(0, 2, 1)                    # [B,H,S]
    gf = gif[..., H:].transpose(0, 2, 1) + p["f_bias"][None, :, None]
    return q, k, v * 1.0, gi, gf


def _mlstm_chunk_body(carry, inp, *, scale):
    """One chunk. carry: (C̃ [B,H,dk,dv], ñ [B,H,dk], m [B,H])."""
    Ct, nt, m = carry
    q, k, v, gi, lf = inp       # q/k/v: [B,H,Q,hd]; gi/lf: [B,H,Q]
    Q = q.shape[2]
    Fcs = jnp.cumsum(lf, axis=2)                            # [B,H,Q]
    # Intra-chunk log weights W[i,j] = Fcs_i − Fcs_j + gi_j  (i ≥ j).
    W = Fcs[..., :, None] - Fcs[..., None, :] + gi[..., None, :]
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    W = jnp.where(mask, W, NEG)
    inter = Fcs + m[..., None]                              # [B,H,Q]
    m_i = jnp.maximum(W.max(-1), inter)                     # row stabilizer
    w = jnp.exp(W - m_i[..., None])                         # [B,H,Q,Q]
    s_inter = jnp.exp(inter - m_i)                          # [B,H,Q]
    qk = jnp.einsum("bhid,bhjd->bhij", q.astype(jnp.float32),
                    k.astype(jnp.float32)) * scale
    num = (jnp.einsum("bhij,bhjd->bhid", w * qk, v.astype(jnp.float32))
           + s_inter[..., None] * jnp.einsum(
               "bhid,bhdv->bhiv", q.astype(jnp.float32) * scale, Ct))
    # ñ_i = Σ_j w_ij k_j + s_inter_i · ñ   (denominator vector)
    nvec = (jnp.einsum("bhij,bhjd->bhid", w, k.astype(jnp.float32))
            + s_inter[..., None] * nt[:, :, None, :])
    denom = jnp.abs(jnp.einsum("bhid,bhid->bhi",
                               q.astype(jnp.float32) * scale, nvec))
    denom = jnp.maximum(denom, jnp.exp(-m_i))
    y = num / denom[..., None]                              # [B,H,Q,hd]
    # Chunk-end state update.
    Ftot = Fcs[..., -1]                                     # [B,H]
    wj = Ftot[..., None] - Fcs + gi                         # [B,H,Q]
    m_new = jnp.maximum(Ftot + m, wj.max(-1))
    sC = jnp.exp(Ftot + m - m_new)
    wj = jnp.exp(wj - m_new[..., None])
    C_new = (sC[..., None, None] * Ct
             + jnp.einsum("bhj,bhjd,bhjv->bhdv", wj, k.astype(jnp.float32),
                          v.astype(jnp.float32)))
    n_new = sC[..., None] * nt + jnp.einsum("bhj,bhjd->bhd", wj,
                                            k.astype(jnp.float32))
    return (C_new, n_new, m_new), y


def mlstm_forward(p: Params, cfg: ModelConfig, x: jnp.ndarray, *,
                  chunk: int = 128, return_cache: bool = False,
                  unroll_chunks: bool = False):
    """Full-sequence mLSTM. x: [B,S,d] → [B,S,d]."""
    B, S, d = x.shape
    H, hd = cfg.n_heads, cfg.head_dim
    q, k, v, gi, gf = _mlstm_qkv(p, cfg, x)
    lf = jax.nn.log_sigmoid(gf)
    Q = min(chunk, S)
    assert S % Q == 0
    n_chunks = S // Q

    def to_chunks(t, axis=2):
        return jnp.moveaxis(
            t.reshape(*t.shape[:axis], n_chunks, Q, *t.shape[axis + 1:]),
            axis, 0)

    inputs = (to_chunks(q), to_chunks(k), to_chunks(v),
              to_chunks(gi), to_chunks(lf))
    carry = (jnp.zeros((B, H, hd, hd), jnp.float32),
             jnp.zeros((B, H, hd), jnp.float32),
             jnp.full((B, H), 0.0, jnp.float32))
    with region("mlstm_scan"):
        body = lambda c, i: _mlstm_chunk_body(c, i, scale=hd ** -0.5)
        if unroll_chunks:
            ys = []
            for i in range(n_chunks):
                carry, yi = body(carry, jax.tree.map(lambda t: t[i], inputs))
                ys.append(yi)
            (Cf, nf, mf), yc = carry, jnp.stack(ys)
        else:
            (Cf, nf, mf), yc = jax.lax.scan(body, carry, inputs)
    y = jnp.moveaxis(yc, 0, 2).reshape(B, H, S, hd)
    y = y.transpose(0, 2, 1, 3).reshape(B, S, H * hd)
    og = jax.nn.sigmoid(x @ p["ogate"].astype(x.dtype))
    y = rmsnorm(p["norm"], y.astype(x.dtype), eps=cfg.norm_eps) * og
    out = constrain(y @ p["wo"].astype(x.dtype), "batch", "seq", "embed")
    if return_cache:
        return out, {"C": Cf, "n": nf, "m": mf}
    return out


def mlstm_cache_init(cfg: ModelConfig, batch: int):
    H, hd = cfg.n_heads, cfg.head_dim
    return {"C": jnp.zeros((batch, H, hd, hd), jnp.float32),
            "n": jnp.zeros((batch, H, hd), jnp.float32),
            "m": jnp.zeros((batch, H), jnp.float32)}


def mlstm_decode(p: Params, cfg: ModelConfig, x: jnp.ndarray, cache):
    """Single-token recurrent mLSTM. x: [B,1,d]."""
    B = x.shape[0]
    H, hd = cfg.n_heads, cfg.head_dim
    q, k, v, gi, gf = _mlstm_qkv(p, cfg, x)
    lf = jax.nn.log_sigmoid(gf)[..., 0]                     # [B,H]
    gi = gi[..., 0]
    qs = q[:, :, 0].astype(jnp.float32) * hd ** -0.5
    ks = k[:, :, 0].astype(jnp.float32)
    vs = v[:, :, 0].astype(jnp.float32)
    with region("mlstm_decode"):
        m_new = jnp.maximum(lf + cache["m"], gi)
        f_ = jnp.exp(lf + cache["m"] - m_new)
        i_ = jnp.exp(gi - m_new)
        C = f_[..., None, None] * cache["C"] + i_[..., None, None] * (
            ks[..., :, None] * vs[..., None, :])
        n = f_[..., None] * cache["n"] + i_[..., None] * ks
        num = jnp.einsum("bhd,bhdv->bhv", qs, C)
        den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", qs, n)),
                          jnp.exp(-m_new))
        y = (num / den[..., None]).reshape(B, 1, H * hd)
    og = jax.nn.sigmoid(x @ p["ogate"].astype(x.dtype))
    y = rmsnorm(p["norm"], y.astype(x.dtype), eps=cfg.norm_eps) * og
    out = y @ p["wo"].astype(x.dtype)
    return out, {"C": C, "n": n, "m": m_new}


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def slstm_init(key, cfg: ModelConfig) -> Params:
    d, H = cfg.d_model, cfg.n_heads
    hd = d // H
    k = jax.random.split(key, 3)
    return {
        "w": dense_init(k[0], d, 4 * d),
        "r": 0.1 * jax.random.normal(k[1], (H, hd, 4 * hd), jnp.float32),
        "b": jnp.concatenate([jnp.zeros((2 * d,)), 3.0 * jnp.ones((d,)),
                              jnp.zeros((d,))]).astype(jnp.float32),
        "wo": dense_init(k[2], d, d),
    }


def _slstm_step(p, cfg, carry, xw_t):
    """carry: (c, n, h, m) each [B,d]; xw_t: [B,4d] (x-projection at t)."""
    c, n, h, m = carry
    B, d = h.shape
    H = cfg.n_heads
    hd = d // H
    hh = h.reshape(B, H, hd)
    rec = jnp.einsum("bhi,hij->bhj", hh, p["r"]).reshape(B, 4 * d)
    zifo = (xw_t + rec + p["b"]).astype(jnp.float32)
    zt, it, ft, ot = jnp.split(zifo, 4, axis=-1)
    m_new = jnp.maximum(ft + m, it)                # log-space stabilizer
    i_ = jnp.exp(it - m_new)
    f_ = jnp.exp(ft + m - m_new)
    c_new = f_ * c + i_ * jnp.tanh(zt)
    n_new = f_ * n + i_
    h_new = jax.nn.sigmoid(ot) * c_new / jnp.maximum(n_new, 1e-6)
    return (c_new, n_new, h_new, m_new), h_new


def slstm_cache_init(cfg: ModelConfig, batch: int):
    d = cfg.d_model
    z = jnp.zeros((batch, d), jnp.float32)
    return {"c": z, "n": z, "h": z, "m": z}


def slstm_forward(p: Params, cfg: ModelConfig, x: jnp.ndarray, *,
                  return_cache: bool = False):
    """Strictly-recurrent sLSTM over the sequence. x: [B,S,d]."""
    B, S, d = x.shape
    xw = (x @ p["w"].astype(x.dtype))                       # [B,S,4d]
    carry = tuple(jnp.zeros((B, d), jnp.float32) for _ in range(4))
    with region("slstm_scan"):
        step = lambda c, t: _slstm_step(p, cfg, c, t)
        (c, n, h, m), hs = jax.lax.scan(step, carry, jnp.moveaxis(xw, 1, 0))
    y = jnp.moveaxis(hs, 0, 1).astype(x.dtype)              # [B,S,d]
    out = constrain(y @ p["wo"].astype(x.dtype), "batch", "seq", "embed")
    if return_cache:
        return out, {"c": c, "n": n, "h": h, "m": m}
    return out


def slstm_decode(p: Params, cfg: ModelConfig, x: jnp.ndarray, cache):
    xw = (x @ p["w"].astype(x.dtype))[:, 0]
    carry = (cache["c"], cache["n"], cache["h"], cache["m"])
    (c, n, h, m), h_out = _slstm_step(p, cfg, carry, xw)
    y = (h_out[:, None, :].astype(x.dtype)) @ p["wo"].astype(x.dtype)
    return y, {"c": c, "n": n, "h": h, "m": m}
