"""Parameter / cache / batch PartitionSpec assignment by pytree path.

Rules give logical axes for the *trailing* dims of each named leaf; any
extra leading dims (stacked scan layers, zamba [G, group, ...] nesting) are
replicated automatically. Every mapped dim is divisibility-checked against
the mesh extent and degrades to replicated when it doesn't divide (e.g.
4 KV heads on a 16-way model axis).
"""

from __future__ import annotations

import re
from typing import Any

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.sharding.rules import AxisRules

__all__ = ["param_specs", "cache_specs", "batch_specs", "spec_for_path",
           "to_shardings"]

# (regex on '/'-joined path, logical axes for trailing dims)
_PARAM_RULES: list[tuple[str, tuple]] = [
    (r"(^|/)embed$", (None, "embed_shard")),
    (r"(^|/)lm_head$", (None, "vocab")),
    (r"/attn/w[qkv]$", (None, "heads")),
    (r"/attn/wo$", ("heads", None)),
    (r"/mlp/(up|gate)$", (None, "q_ff")),
    (r"/mlp/down$", ("q_ff", None)),
    (r"/moe/(up|gate|down)$", ("experts", None, None)),
    (r"/moe/router$", (None, None)),
    (r"/ssm/in_[xz]$", (None, "conv_dim")),
    (r"/ssm/out$", ("conv_dim", None)),
    (r"/ssm/conv_x$", (None, "conv_dim")),
    (r"/ssm/in_dt$", (None, "ssm_heads")),
    (r"/ssm/(A_log|D|dt_bias)$", ("ssm_heads",)),
    (r"/ssm/norm/scale$", ("conv_dim",)),
    # xLSTM inner projections replicate (125M model, heads < TP width).
]

_CACHE_RULES: list[tuple[str, tuple]] = [
    (r"(^|/)[kv]$", ("batch", "kv_heads", "kv_seq", None)),
    (r"(^|/)h$", ("batch", "ssm_heads", None, None)),
    (r"(^|/)conv_x$", ("batch", None, "conv_dim")),
    (r"(^|/)conv_bc$", ("batch", None, None)),
    (r"(^|/)C$", ("batch", None, None, None)),
    (r"(^|/)n$", ("batch", None, None)),
    (r"(^|/)m$", ("batch", None)),
    (r"(^|/)[cnh]$", ("batch", None)),
]


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def _extent(rules: AxisRules, mesh_axes) -> int:
    if mesh_axes is None or rules.mesh is None:
        return 1
    axes = (mesh_axes,) if isinstance(mesh_axes, str) else tuple(mesh_axes)
    e = 1
    for a in axes:
        e *= rules.mesh.shape[a]
    return e


def _safe_spec(shape: tuple[int, ...], trailing: tuple, rules: AxisRules) -> P:
    """Pad leading None; drop axes that don't divide the mesh extent."""
    n_lead = len(shape) - len(trailing)
    if n_lead < 0:          # leaf has fewer dims than the rule (edge case)
        trailing = trailing[-len(shape):] if len(shape) else ()
        n_lead = len(shape) - len(trailing)
    dims: list = [None] * n_lead
    for size, logical in zip(shape[n_lead:], trailing):
        mesh_axes = None if logical is None else rules.mapping.get(logical)
        if mesh_axes is not None and size % _extent(rules, mesh_axes) != 0:
            mesh_axes = None
        dims.append(mesh_axes)
    return P(*dims)


def spec_for_path(path_str: str, shape: tuple[int, ...],
                  rules: AxisRules,
                  rule_table: list[tuple[str, tuple]] | None = None) -> P:
    for pat, trailing in (rule_table or _PARAM_RULES):
        if re.search(pat, path_str):
            return _safe_spec(shape, trailing, rules)
    return P(*([None] * len(shape)))            # replicate by default


def _add_fsdp(spec: P, shape: tuple[int, ...], rules: AxisRules,
              dp_axes: tuple[str, ...], min_size: int) -> P:
    """ZeRO/FSDP: additionally shard the largest unmapped dim over the DP
    axes (params + optimizer states). GSPMD then all-gathers weights at use
    sites and reduce-scatters grads — visible in the collective roofline
    term and hillclimbable."""
    if not dp_axes or not shape:
        return spec
    extent = 1
    for a in dp_axes:
        extent *= rules.mesh.shape[a]
    dims = list(spec)
    # biggest eligible dim first (skip tiny leaves: not worth the gather)
    order = sorted(range(len(shape)), key=lambda i: -shape[i])
    for i in order:
        if dims[i] is None and shape[i] % extent == 0 and shape[i] >= min_size:
            dims[i] = dp_axes[0] if len(dp_axes) == 1 else tuple(dp_axes)
            return P(*dims)
    return spec


def param_specs(params: Any, rules: AxisRules, *, fsdp: bool = False,
                fsdp_min_size: int = 1024) -> Any:
    """PartitionSpec pytree matching ``params``.

    fsdp=True additionally shards each large leaf over the DP axes (ZeRO-3
    posture for train states; leave False for serving params).
    """
    dp = rules.mapping.get("batch") if fsdp else None
    dp_axes: tuple[str, ...] = ()
    if dp is not None and rules.mesh is not None:
        dp_axes = (dp,) if isinstance(dp, str) else tuple(dp)

    def one(path, leaf):
        s = spec_for_path(_path_str(path), leaf.shape, rules)
        if fsdp and dp_axes:
            s = _add_fsdp(s, leaf.shape, rules, dp_axes, fsdp_min_size)
        return s
    return jax.tree_util.tree_map_with_path(one, params)


def cache_specs(cache: Any, rules: AxisRules) -> Any:
    def one(path, leaf):
        return spec_for_path(_path_str(path), leaf.shape, rules,
                             rule_table=_CACHE_RULES)
    return jax.tree_util.tree_map_with_path(one, cache)


def batch_specs(batch: Any, rules: AxisRules) -> Any:
    """Input batches: leading batch dim over DP axes (if divisible)."""
    def one(leaf):
        trailing = ("batch",) + (None,) * (leaf.ndim - 1)
        return _safe_spec(leaf.shape, trailing, rules)
    return jax.tree.map(one, batch)


def to_shardings(spec_tree: Any, rules: AxisRules) -> Any:
    if rules.mesh is None:
        return None
    return jax.tree.map(lambda s: NamedSharding(rules.mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))
