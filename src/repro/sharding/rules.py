"""Logical-axis sharding rules (DP/TP/EP/SP) for pjit'd model code.

Model code never names mesh axes; it constrains activations by *logical*
axes (``constrain(x, "batch", "seq", "embed")``) and parameters get specs
from :func:`param_specs` by pytree path. A per-run :class:`AxisRules` maps
logical axes → mesh axes, chosen by the launcher from (arch, shape, mesh):

  batch    → ("pod", "data")     data parallelism (both DP axes)
  embed    → None                activations replicated on features (Megatron)
  heads    → "model"             TP over attention heads / SSM heads
  kv_heads → "model" if divisible else None (GQA groups < model shards)
  q_ff     → "model"             column-parallel FFN
  experts  → "model"             expert parallelism
  vocab    → "model"             vocab-parallel logits + loss
  kv_seq   → decode: "model" (flash-decoding split-K) or DP axes for batch=1
  seq      → None (training); "model"-sharded variants are a §Perf knob

Unmappable axes (size not divisible by the mesh axis) degrade to None
(replicated) with a warning collected for the dry-run report.
"""

from __future__ import annotations

import contextlib
import threading
from typing import Iterator, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["AxisRules", "axis_rules", "constrain", "current_rules",
           "logical_spec"]


class AxisRules:
    """Mapping from logical axis names to mesh axis names (or tuples)."""

    def __init__(self, mesh: Mesh | None, mapping: dict[str, object]):
        self.mesh = mesh
        self.mapping = dict(mapping)
        self.warnings: list[str] = []

    def spec(self, *logical: str | None) -> P:
        """PartitionSpec for logical axes; a mesh axis may appear once, so
        later duplicates degrade to replicated (e.g. context-parallel
        ``seq``→model colliding with ``vocab``→model on logits)."""
        used: set[str] = set()
        dims: list = []
        for ax in logical:
            mesh_axes = self.mapping.get(ax) if ax else None
            if mesh_axes is not None:
                flat = ((mesh_axes,) if isinstance(mesh_axes, str)
                        else tuple(mesh_axes))
                if any(a in used for a in flat):
                    mesh_axes = None
                else:
                    used.update(flat)
            dims.append(mesh_axes)
        return P(*dims)

    def sharding(self, *logical: str | None) -> NamedSharding | None:
        if self.mesh is None:
            return None
        return NamedSharding(self.mesh, self.spec(*logical))

    def resolve_divisibility(self, sizes: dict[str, int]) -> "AxisRules":
        """Drop mappings whose dim size isn't divisible by the mesh extent."""
        if self.mesh is None:
            return self
        new = dict(self.mapping)
        for ax, size in sizes.items():
            mesh_axes = new.get(ax)
            if mesh_axes is None:
                continue
            axes = (mesh_axes,) if isinstance(mesh_axes, str) else tuple(mesh_axes)
            extent = 1
            for a in axes:
                extent *= self.mesh.shape[a]
            if size % extent != 0:
                self.warnings.append(
                    f"logical axis {ax!r} (size {size}) not divisible by mesh "
                    f"extent {extent}; replicating")
                new[ax] = None
        r = AxisRules(self.mesh, new)
        r.warnings = self.warnings
        return r


_tls = threading.local()


def current_rules() -> AxisRules | None:
    return getattr(_tls, "rules", None)


@contextlib.contextmanager
def axis_rules(rules: AxisRules | None) -> Iterator[None]:
    prev = current_rules()
    _tls.rules = rules
    try:
        yield
    finally:
        _tls.rules = prev


def logical_spec(*logical: str | None) -> P:
    r = current_rules()
    if r is None:
        return P(*[None] * len(logical))
    return r.spec(*logical)


def constrain(x: jax.Array, *logical: str | None) -> jax.Array:
    """with_sharding_constraint by logical axes; no-op outside axis_rules."""
    r = current_rules()
    if r is None or r.mesh is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(r.mesh, r.spec(*logical)))


# -- default rule sets ---------------------------------------------------------

def make_rules(mesh: Mesh | None, *, dp_axes: Sequence[str] = ("data",),
               tp_axis: str | None = "model",
               kv_seq_axis: object = None) -> AxisRules:
    """Standard mapping. ``kv_seq_axis`` set for decode cache sharding."""
    dp: object = tuple(a for a in dp_axes if mesh is None or a in mesh.shape)
    if isinstance(dp, tuple) and len(dp) == 1:
        dp = dp[0]
    mapping: dict[str, object] = {
        "batch": dp,
        "seq": None,
        "seq_act": None,   # residual-stream sequence sharding (Megatron SP)
        "embed": None,
        "heads": tp_axis,
        "kv_heads": tp_axis,
        "head_dim": None,
        "q_ff": tp_axis,
        "ff": tp_axis,
        "experts": tp_axis,
        "vocab": tp_axis,
        "embed_shard": tp_axis,
        "kv_seq": kv_seq_axis,
        "ssm_heads": tp_axis,
        "ssm_state": None,
        "conv_dim": tp_axis,
    }
    return AxisRules(mesh, mapping)
