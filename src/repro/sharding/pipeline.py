"""GPipe-style pipeline parallelism over a ``pipe`` mesh axis.

For meshes with a pipeline axis (not the assigned production mesh — see
DESIGN.md §6), layers are partitioned into S stages; microbatches stream
through stages with ``jax.lax.ppermute`` boundary transfers inside a
``shard_map``. The schedule is the classic GPipe fill-drain loop: with M
microbatches and S stages, bubble fraction = (S−1)/(M+S−1).

Implementation notes (TPU-native): each device holds its stage's stacked
layer params; the loop body runs every stage in SPMD (devices compute
their own stage), then rotates activations one stage forward. Stage
assignment of layers is contiguous. Works with any per-layer block fn of
signature ``(params_i, x) -> x``.
"""

from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import shard_map

__all__ = ["pipeline_forward", "bubble_fraction"]


def bubble_fraction(n_stages: int, n_micro: int) -> float:
    return (n_stages - 1) / (n_micro + n_stages - 1)


def pipeline_forward(block_fn: Callable, mesh: Mesh, *, axis: str = "pipe",
                     n_micro: int):
    """Build a pipelined forward: (stage_params, x) → y.

    Args:
      block_fn: per-stage function ``(stage_params, x_micro) -> x_micro``;
        stage_params are the layers owned by one stage (leading dim =
        layers-per-stage, already sliced by shard_map).
      mesh: mesh containing ``axis``.
      n_micro: number of microbatches (global batch must divide).

    Returns a function ``f(params_stacked, x) -> y`` where
    ``params_stacked`` leaves have leading dim n_stages·layers_per_stage
    and x is [B, ...]; y is x after all stages, microbatched.
    """
    n_stages = mesh.shape[axis]

    def staged(params_local, x_local):
        # params_local: this stage's layers [L/S, ...]; x_local: the full
        # microbatch set [M, B/M, ...] (replicated over the pipe axis).
        stage = jax.lax.axis_index(axis)
        M = n_micro
        T = M + n_stages - 1          # schedule ticks

        def tick(carry, t):
            buf, out = carry          # buf: activation entering this stage
            # Which microbatch does stage 0 inject at tick t?
            mb_idx = jnp.clip(t, 0, M - 1)
            inject = x_local[mb_idx]
            cur = jnp.where(stage == 0, inject, buf)
            y = block_fn(params_local, cur)
            # Rotate stage s → s+1 (last stage's output is collected).
            nxt = jax.lax.ppermute(
                y, axis, [(i, (i + 1) % n_stages) for i in range(n_stages)])
            # Last stage emits microbatch (t - (S-1)) at ticks ≥ S-1.
            emit_idx = jnp.clip(t - (n_stages - 1), 0, M - 1)
            do_emit = jnp.logical_and(t >= n_stages - 1,
                                      stage == n_stages - 1)
            out = jnp.where(do_emit,
                            out.at[emit_idx].set(y), out)
            return (nxt, out), None

        buf0 = jnp.zeros_like(x_local[0])
        out0 = jnp.zeros_like(x_local)
        (buf, out), _ = jax.lax.scan(tick, (buf0, out0), jnp.arange(T))
        del buf
        # Only the last stage holds real outputs; broadcast them.
        out = jax.lax.psum(
            jnp.where(stage == n_stages - 1, out, jnp.zeros_like(out)),
            axis)
        return out

    def run(params_stacked, x):
        B = x.shape[0]
        assert B % n_micro == 0, (B, n_micro)
        xm = x.reshape(n_micro, B // n_micro, *x.shape[1:])
        f = shard_map(
            staged, mesh=mesh,
            in_specs=(P(axis), P()),      # layers split over stages
            out_specs=P(),
            check_vma=False)
        out = f(params_stacked, xm)
        return out.reshape(B, *x.shape[1:])

    return run
