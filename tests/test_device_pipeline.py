"""Fused device pipeline ≡ numpy reference (same counter-based sample
clock): bit-exact counts, float64-tolerance sums, donated carries, and the
profiler/benchmark wiring."""

import numpy as np
import pytest

from repro.core import device_pipeline as dp
from repro.core.profiler import EnergyProfiler
from repro.core.sensors import (Ina231TraceSensor, InstantTraceSensor,
                                RaplTraceSensor)
from repro.core.timeline import RegionCost, Timeline, ground_truth, synthesize

_SENSORS = {
    "instant": InstantTraceSensor,
    "rapl": RaplTraceSensor,
    "ina231": Ina231TraceSensor,
}


def _timelines(w, steps=60, base_seed=0):
    costs = [RegionCost("mem", flops=1e10, hbm_bytes=5e10, invocations=4),
             RegionCost("alu", flops=6e11, hbm_bytes=2e9, invocations=4),
             RegionCost("opt", flops=2e10, hbm_bytes=4e10, invocations=1)]
    return [synthesize(costs, steps=steps, seed=base_seed + s)
            for s in range(w)]


def _assert_stats_close(got, want, rtol=1e-9):
    np.testing.assert_array_equal(got[0], want[0])
    np.testing.assert_allclose(got[1], want[1], rtol=rtol)
    np.testing.assert_allclose(got[2], want[2], rtol=rtol)


# ---------------------------------------------------------------------------
# Region (single-worker) pipeline ≡ reference.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("sensor", ["instant", "rapl", "ina231"])
def test_region_pipeline_matches_reference(sensor):
    (tl,) = _timelines(1)
    spec = _SENSORS[sensor].make_spec()
    res = dp.run_region_pipeline(tl.to_device(), spec, period=10e-3,
                                 jitter=200e-6, seed=3, chunk_size=1024)
    ref = dp.reference_region_pipeline(tl, spec, period=10e-3,
                                       jitter=200e-6, seed=3,
                                       chunk_size=1024)
    assert res.n == ref.n
    assert res.t_exec == ref.t_exec
    _assert_stats_close((res.counts, res.psum, res.psumsq),
                        (ref.counts, ref.psum, ref.psumsq))


def test_region_pipeline_overhead_blending_matches_reference():
    (tl,) = _timelines(1)
    spec = InstantTraceSensor.make_spec()
    kw = dict(period=5e-3, jitter=100e-6, seed=9, chunk_size=512,
              overhead_per_sample=1e-3, idle_power=55.0)
    res = dp.run_region_pipeline(tl.to_device(), spec, **kw)
    ref = dp.reference_region_pipeline(tl, spec, **kw)
    assert res.n == ref.n
    assert res.t_exec == pytest.approx(tl.t_exec + res.n * 1e-3)
    _assert_stats_close((res.counts, res.psum, res.psumsq),
                        (ref.counts, ref.psum, ref.psumsq))


def test_region_pipeline_deterministic_and_chunk_grid_keyed():
    """Statistics are a pure function of (seed, chunk grid): identical
    across runs at the same chunk size, and still oracle-exact at any
    other chunk size (each grid draws its own — equally valid — jitter
    sequence, like the host streaming path does vs the one-shot path)."""
    (tl,) = _timelines(1)
    spec = RaplTraceSensor.make_spec()
    a = dp.run_region_pipeline(tl.to_device(), spec, period=10e-3, seed=1,
                               chunk_size=768)
    b = dp.run_region_pipeline(tl.to_device(), spec, period=10e-3, seed=1,
                               chunk_size=768)
    _assert_stats_close((a.counts, a.psum, a.psumsq),
                        (b.counts, b.psum, b.psumsq), rtol=0.0)
    c = dp.run_region_pipeline(tl.to_device(), spec, period=10e-3, seed=1,
                               chunk_size=2048)
    ref = dp.reference_region_pipeline(tl, spec, period=10e-3, seed=1,
                                       chunk_size=2048)
    np.testing.assert_array_equal(c.counts, ref.counts)
    # Different grids sample the same process: totals agree closely.
    assert c.n == pytest.approx(a.n, rel=0.02)


def test_region_pipeline_validates_args():
    (tl,) = _timelines(1)
    with pytest.raises(ValueError):   # period below sensor minimum
        dp.run_region_pipeline(tl.to_device(),
                               Ina231TraceSensor.make_spec(window=280e-6),
                               period=100e-6)
    with pytest.raises(ValueError):   # jitter > period: non-monotone clock
        dp.run_region_pipeline(tl.to_device(),
                               InstantTraceSensor.make_spec(),
                               period=1e-3, jitter=5e-3)
    with pytest.raises(ValueError):   # multi-worker needs combo pipeline
        dp.run_region_pipeline(
            dp.DeviceTimeline.from_timelines(_timelines(2)),
            InstantTraceSensor.make_spec(), period=1e-3)


# ---------------------------------------------------------------------------
# Combination (multi-worker) pipeline ≡ reference.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("w", [1, 4])
@pytest.mark.parametrize("sensor", ["instant", "rapl", "ina231"])
def test_combo_pipeline_matches_reference(sensor, w):
    tls = _timelines(w)
    spec = _SENSORS[sensor].make_spec()
    dtl = dp.DeviceTimeline.from_timelines(tls)
    agg, n = dp.run_combo_pipeline(dtl, spec, period=10e-3, jitter=200e-6,
                                   seed=7, chunk_size=512)
    ragg, rn = dp.reference_combo_pipeline(tls, lambda tl: spec,
                                           period=10e-3, jitter=200e-6,
                                           seed=7, chunk_size=512)
    assert n == rn
    # Device misses intern through the same chunk-order first-appearance
    # process as the reference, so ids (not just sets) line up.
    assert agg.interner.combos == ragg.interner.combos
    _assert_stats_close((agg.agg.counts, agg.agg.psum, agg.agg.psumsq),
                        (ragg.agg.counts, ragg.agg.psum, ragg.agg.psumsq))


def test_combo_pipeline_multiword_keys_match_reference():
    """W·bits > 62 forces the multi-word packed-key path
    (_lex_less/_lex_search): a wide region space (R=300 → 9 bits) across
    W=8 workers packs to 2 int64 words per row."""
    rng = np.random.default_rng(23)
    R, m = 300, 50
    names = tuple(f"bb_{i}" for i in range(R))
    base = Timeline(rng.integers(0, R, m).astype(np.int32),
                    rng.uniform(5e-3, 15e-3, m),
                    50.0 + 150.0 * rng.random(m), names).tile(8)
    tls = []
    for w in range(8):
        # Phase-shifted copies of one tiled structure: combination pairs
        # repeat after the first tile, so later chunks must fold through
        # the device-side multi-word table search (not the miss path).
        tls.append(Timeline(
            np.concatenate([[base.region_ids[0]], base.region_ids]),
            np.concatenate([[w * 2e-4 + 1e-9], base.durations]),
            np.concatenate([[base.powers[0]], base.powers]), names))
    assert dp._pack_spec(R, 8)[2] >= 2
    spec = RaplTraceSensor.make_spec()
    dtl = dp.DeviceTimeline.from_timelines(tls)
    stats = {}
    agg, n = dp.run_combo_pipeline(dtl, spec, period=2e-3, jitter=100e-6,
                                   seed=5, chunk_size=256, stats=stats)
    assert stats["miss_chunks"] < stats["chunks"]   # device folds happened
    ragg, rn = dp.reference_combo_pipeline(tls, lambda tl: spec,
                                           period=2e-3, jitter=100e-6,
                                           seed=5, chunk_size=256)
    assert n == rn
    assert agg.interner.combos == ragg.interner.combos
    _assert_stats_close((agg.agg.counts, agg.agg.psum, agg.agg.psumsq),
                        (ragg.agg.counts, ragg.agg.psum, ragg.agg.psumsq))


def test_combo_pipeline_steady_state_stops_transferring():
    """Once the combination table is complete, chunks fold on device:
    misses stop long before the run does (the zero-per-chunk-transfer
    steady state of the acceptance criteria)."""
    tls = _timelines(2, steps=120)
    dtl = dp.DeviceTimeline.from_timelines(tls)
    stats = {}
    agg, n = dp.run_combo_pipeline(dtl, InstantTraceSensor.make_spec(),
                                   period=5e-3, seed=0, chunk_size=256,
                                   stats=stats)
    assert n > 0
    assert stats["chunks"] >= 10
    # Misses are bounded by distinct-combination appearances, not run
    # length: a strict majority of chunks must fold with no fallback.
    assert stats["miss_chunks"] < stats["chunks"] / 2
    assert stats["miss_chunks"] <= len(agg.interner)


def test_chunk_step_carry_is_donated():
    """The donated carry contract: after a step, the previous carry's
    buffers are consumed (no second live copy of the accumulators)."""
    import jax
    import jax.numpy as jnp
    from jax.experimental import enable_x64
    tls = _timelines(2, steps=20)
    dtl = dp.DeviceTimeline.from_timelines(tls)
    spec = InstantTraceSensor.make_spec()
    pack = dp._pack_spec(dtl.num_regions, 2)
    with enable_x64():
        step = dp._combo_step_fn(256, spec, dtl.grid_k, pack)
        cap = dp._TABLE_MIN
        table, tids, n_rows = dp._build_table(dp.CombinationInterner(),
                                              cap, 2, pack)
        carry = (jnp.zeros(cap, jnp.int64), jnp.zeros(cap, jnp.float64),
                 jnp.zeros(cap, jnp.float64), jnp.zeros((), jnp.int64),
                 -jnp.ones((), jnp.float64))
        new_carry, miss, *_ = step(carry, table, tids, n_rows,
                                   *dtl.arrays(), jax.random.PRNGKey(0),
                                   jnp.int32(0), jnp.float64(1e-2),
                                   jnp.float64(2e-4),
                                   jnp.float64(dtl.t_end))
    assert all(buf.is_deleted() for buf in carry)
    assert not any(buf.is_deleted() for buf in new_carry[:3])


# ---------------------------------------------------------------------------
# DeviceTimeline substrate.
# ---------------------------------------------------------------------------

def test_heavy_tailed_durations_fall_back_to_searchsorted():
    """One long interval + many micro-intervals concentrates intervals in
    a single grid cell; past _GRID_K_MAX the accelerator must hand the
    lookup to a real binary search (same results, bounded compile)."""
    rng = np.random.default_rng(31)
    m = 4000
    tl = Timeline(rng.integers(0, 4, m + 1).astype(np.int32),
                  np.concatenate([[5.0], rng.uniform(1e-6, 3e-6, m)]),
                  50.0 + 100.0 * rng.random(m + 1), ("a", "b", "c", "d"))
    dtl = tl.to_device()
    assert dtl.grid_k == 0          # fallback engaged
    spec = InstantTraceSensor.make_spec()
    res = dp.run_region_pipeline(dtl, spec, period=5e-3, jitter=100e-6,
                                 seed=2, chunk_size=512)
    ref = dp.reference_region_pipeline(tl, spec, period=5e-3,
                                       jitter=100e-6, seed=2,
                                       chunk_size=512)
    assert res.n == ref.n
    _assert_stats_close((res.counts, res.psum, res.psumsq),
                        (ref.counts, ref.psum, ref.psumsq))


def test_device_timeline_ragged_workers_pad():
    a = Timeline(np.array([0, 1]), np.array([1.0, 2.0]),
                 np.array([50.0, 100.0]), ("x", "y"))
    b = Timeline(np.array([1, 0, 1, 0]), np.array([0.5, 0.5, 1.0, 3.0]),
                 np.array([80.0, 60.0, 90.0, 70.0]), ("x", "y"))
    dtl = dp.DeviceTimeline.from_timelines([a, b])
    assert dtl.num_workers == 2
    assert dtl.ends.shape == (2, 4)
    assert dtl.t_end == pytest.approx(3.0)       # min worker horizon
    np.testing.assert_array_equal(np.asarray(dtl.m_true), [2, 4])
    assert np.isinf(np.asarray(dtl.ends)[0, 2])  # ragged pad
    # to_device() is the single-worker shorthand.
    assert a.to_device().num_workers == 1
    with pytest.raises(ValueError):
        dp.DeviceTimeline.from_timelines([])
    other = Timeline(np.array([0, 1]), np.array([1.0, 2.0]),
                     np.array([50.0, 100.0]), ("p", "q"))
    with pytest.raises(ValueError, match="name space"):
        dp.DeviceTimeline.from_timelines([a, other])


# ---------------------------------------------------------------------------
# Profiler wiring: device backend is the default, host stays the oracle.
# ---------------------------------------------------------------------------

def test_profiler_streaming_device_vs_host_accuracy():
    # Same workload/tolerances as test_profile_timeline_streaming_accuracy
    # (regions with enough samples for the 10–12% bands at this period).
    costs = [RegionCost("attn", flops=4e11, hbm_bytes=1.5e10, invocations=8),
             RegionCost("ffn", flops=9e11, hbm_bytes=2.5e10, invocations=8)]
    tl = synthesize(costs, steps=150, seed=5)
    prof = EnergyProfiler(period=10e-3, seed=6)
    est_dev = prof.profile_timeline_streaming(tl, sensor="rapl",
                                              chunk_size=1024,
                                              pipeline="device")
    est_host = prof.profile_timeline_streaming(tl, sensor="rapl",
                                               chunk_size=1024,
                                               pipeline="host")
    gt = ground_truth(tl)
    for name, g in gt.items():
        for est in (est_dev, est_host):
            r = est.by_name()[name]
            assert r.t_hat == pytest.approx(g["time"], rel=0.10)
            assert r.e_hat == pytest.approx(g["energy"], rel=0.12)


def test_profiler_auto_prefers_device_and_respects_overrides():
    (tl,) = _timelines(1)
    prof = EnergyProfiler(period=10e-3, seed=2)
    est_auto = prof.profile_timeline_streaming(tl, sensor="instant",
                                               chunk_size=1024)
    est_dev = prof.profile_timeline_streaming(tl, sensor="instant",
                                              chunk_size=1024,
                                              pipeline="device")
    # auto == device (bit-identical estimates: same fused path).
    assert est_auto.n_total == est_dev.n_total
    np.testing.assert_array_equal(est_auto.table.n_samples,
                                  est_dev.table.n_samples)
    np.testing.assert_array_equal(est_auto.table.e_hat, est_dev.table.e_hat)
    # An explicit host aggregate_fn implies the host chunk seam.
    seen = []

    def spy_agg(ids, pows, num_regions):
        seen.append(len(ids))
        from repro.core.estimator import aggregate_samples_np
        return aggregate_samples_np(ids, pows, num_regions)

    prof.profile_timeline_streaming(tl, sensor="instant", chunk_size=1024,
                                    aggregate_fn=spy_agg)
    assert seen, "aggregate_fn must route through the host path"
    with pytest.raises(ValueError):
        prof.profile_timeline_streaming(tl, pipeline="gpu")
    # Explicit device + host-seam aggregate_fn is a contradiction, not a
    # silent drop of the caller's kernel.
    with pytest.raises(ValueError, match="aggregate_fn"):
        prof.profile_timeline_streaming(tl, pipeline="device",
                                        aggregate_fn=spy_agg)


def test_sensor_instance_spec_matches_classmethod():
    """Instance .spec() carries instance parameters — the handle for
    driving the device pipeline with a customized sensor."""
    (tl,) = _timelines(1)
    assert InstantTraceSensor(tl).spec() == InstantTraceSensor.make_spec()
    assert RaplTraceSensor(tl, update_period=2e-3).spec() == \
        RaplTraceSensor.make_spec(update_period=2e-3)
    assert Ina231TraceSensor(tl, window=1e-3).spec() == \
        Ina231TraceSensor.make_spec(window=1e-3)
    res = dp.run_region_pipeline(
        tl.to_device(), RaplTraceSensor(tl, update_period=2e-3).spec(),
        period=10e-3, seed=0, chunk_size=2048)
    assert res.n > 0


def test_profiler_multiworker_device_matches_host_semantics():
    tls = _timelines(2, steps=120)
    prof = EnergyProfiler(period=10e-3)
    est, combos = prof.profile_multiworker_streaming(tls, sensor="instant",
                                                     chunk_size=256,
                                                     pipeline="device")
    assert len(combos) >= 2
    assert sum(r.t_hat for r in est.regions) == pytest.approx(
        min(t.t_exec for t in tls), rel=1e-6)


def test_device_result_merges_into_exchange_seams():
    """The fused result is a plain aggregator: shard merge with a host
    shard stays associative and exact."""
    from repro.core.streaming import StreamingAggregator
    (tl,) = _timelines(1)
    spec = InstantTraceSensor.make_spec()
    res = dp.run_region_pipeline(tl.to_device(), spec, period=10e-3, seed=4)
    dev_agg = StreamingAggregator.from_statistics(res.counts, res.psum,
                                                  res.psumsq)
    host_agg = StreamingAggregator(dev_agg.num_regions)
    host_agg.update([0, 1, 1], [10.0, 20.0, 30.0])
    merged = StreamingAggregator(dev_agg.num_regions)
    merged.merge(dev_agg).merge(host_agg)
    assert merged.n_total == res.n + 3
    np.testing.assert_allclose(
        merged.psum, res.psum + np.bincount(
            [0, 1, 1], weights=[10.0, 20.0, 30.0],
            minlength=dev_agg.num_regions))


# ---------------------------------------------------------------------------
# Benchmark entry point can't rot.
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_pipeline_benchmark_smoke(monkeypatch, tmp_path):
    import benchmarks.pipeline as bench
    monkeypatch.setenv("ALEA_BENCH_N", "20000")
    monkeypatch.setattr(bench, "_JSON_PATH",
                        tmp_path / "BENCH_pipeline.json")
    monkeypatch.setattr(bench, "WORKER_CONFIGS", (1, 4))
    rows = bench.run(verbose=False)
    assert rows and all(r.count(",") >= 2 for r in rows)
    assert (tmp_path / "BENCH_pipeline.json").exists()
