"""Corruption fuzz over the durable spill file classes.

Every file class the exchange protocol persists — shard/delta
``manifest.json``, array leaves, the per-host ``LATEST`` pointer — is
corrupted on disk (deterministic bit flips and truncations, several
positions per file) in both the checked-in schema-v1 fixture
(``tests/data/spill_v1``) and freshly written v2 trees. The invariant
is the PR-6 failure-model contract, phrased as a closed outcome set:

* strict ``gather_shards`` either raises a typed :class:`SpillError`
  subclass or returns statistics bit-identical to a *valid* durable
  state (the full fleet, or an intact per-host epoch prefix when a
  corrupted ``LATEST`` legitimately parses to an older epoch);
* quorum ``gather_shards`` never raises for a single bad host — it
  returns statistics bit-exact to a replay of exactly the epochs its
  own provenance reports, and any host folded below its requested
  epoch is disclosed as non-``merged``.

Silently-wrong statistics — numbers that match no valid durable state
— fail both checks.
"""

import hashlib
import os
import shutil

import numpy as np
import pytest

from repro.core import exchange as ex
from repro.core.faults import QuorumError, SpillError
from repro.core.streaming import StreamingAggregator

pytestmark = pytest.mark.chaos

DATA = os.path.join(os.path.dirname(__file__), "data", "spill_v1")
R = 12

# host -> LATEST epoch in the checked-in fixture tree.
FIXTURE_EPOCHS = {0: 2, 1: 4, 2: 5}


# ---------------------------------------------------------------------------
# Deterministic replays of the update streams behind each tree.
# ---------------------------------------------------------------------------

def _fixture_updates(host, epoch):
    rng = np.random.default_rng(1000 * host + epoch)
    return (rng.integers(0, R, size=257),
            rng.uniform(50.0, 250.0, size=257))


def _fresh_updates(host, epoch):
    rng = np.random.default_rng(300 * host + epoch)
    return (rng.integers(0, R, size=111),
            rng.uniform(30.0, 280.0, size=111))


def _replay(updates, host, upto):
    agg = StreamingAggregator(R)
    for e in range(1, upto + 1):
        agg.update(*updates(host, e))
    return agg


def _key(agg):
    """Bit-exact fingerprint of sufficient statistics."""
    return (tuple(int(c) for c in agg.counts),
            tuple(float(x).hex() for x in np.ravel(agg.psum)),
            tuple(float(x).hex() for x in np.ravel(agg.psumsq)))


def _reduce_key(updates, epochs_by_host):
    shards = [_replay(updates, h, e)
              for h, e in sorted(epochs_by_host.items()) if e > 0]
    return _key(ex.tree_reduce(shards))


def _allowed_strict_keys(updates, epochs_by_host, vary_host):
    """Every valid durable state the strict gather may legally return:
    the full fleet, with the corrupted host at any intact epoch prefix
    (a flipped LATEST may parse to an older — still valid — epoch)."""
    allowed = set()
    for e in range(1, epochs_by_host[vary_host] + 1):
        eb = dict(epochs_by_host)
        eb[vary_host] = e
        allowed.add(_reduce_key(updates, eb))
    return allowed


# ---------------------------------------------------------------------------
# Deterministic corruption: position derived from the file, not an RNG.
# ---------------------------------------------------------------------------

def _corrupt_file(path, kind, salt):
    with open(path, "rb") as f:
        data = bytearray(f.read())
    h = int.from_bytes(
        hashlib.sha256(f"{os.path.basename(path)}:{salt}".encode())
        .digest()[:8], "big")
    if kind == "bitflip":
        bit = h % (len(data) * 8)
        data[bit // 8] ^= 1 << (bit % 8)
    else:
        assert kind == "truncate"
        data = data[: h % len(data)]      # always strictly shorter
    with open(path, "wb") as f:
        f.write(bytes(data))


def _check_strict(root, allowed):
    """Strict gather: typed failure or a member of the valid-state set."""
    try:
        g = ex.gather_shards(root)
    except SpillError:
        return "raised"
    assert _key(g) in allowed, "strict gather returned silently-wrong stats"
    return "valid"


def _check_quorum(root, updates, roster):
    """Quorum gather: provenance-consistent stats, degradation disclosed."""
    res = ex.gather_shards(root, quorum=ex.QuorumPolicy(
        expected_hosts=roster, min_hosts=1, backoff=0.0))
    shards, short = [], False
    for rep in sorted(res.hosts, key=lambda r: r.host_id):
        if rep.epoch is None:
            assert rep.status != "merged"
            short = True
            continue
        if rep.requested_epoch is not None and rep.epoch < rep.requested_epoch:
            assert rep.status != "merged"   # fold-back is disclosed
            short = True
        shards.append(_replay(updates, rep.host_id, rep.epoch))
    assert shards, "quorum gather merged nothing without raising"
    ref = ex.tree_reduce(shards)
    assert np.array_equal(res.agg.counts, ref.counts)
    assert np.array_equal(res.agg.chan_psum, ref.chan_psum)
    assert np.array_equal(res.agg.chan_psumsq, ref.chan_psumsq)
    if short:
        assert not res.complete
    return res


# (class name, corrupted host, relative path) for the fixture tree.
FIXTURE_TARGETS = [
    ("delta-manifest", 1, "host_0001/epoch_000000004/manifest.json"),
    ("base-manifest", 1, "host_0001/epoch_000000001/manifest.json"),
    ("leaf", 1, "host_0001/epoch_000000004/arr_00001.npy"),
    ("latest", 1, "host_0001/LATEST"),
]


@pytest.mark.parametrize("kind", ["bitflip", "truncate"])
@pytest.mark.parametrize("cls,host,rel",
                         FIXTURE_TARGETS,
                         ids=[t[0] for t in FIXTURE_TARGETS])
def test_fixture_tree_corruption(tmp_path, cls, host, rel, kind):
    allowed = _allowed_strict_keys(_fixture_updates, FIXTURE_EPOCHS, host)
    for salt in range(3):                  # several deterministic positions
        root = tmp_path / f"{kind}-{salt}"
        shutil.copytree(os.path.join(DATA, "region"), root)
        _corrupt_file(str(root / rel), kind, salt)
        _check_strict(str(root), allowed)
        _check_quorum(str(root), _fixture_updates, tuple(FIXTURE_EPOCHS))


def _write_fresh_tree(root):
    """A v2 tree: host 0 publishes full shards, host 1 a delta chain."""
    epochs = {}
    for host, mode, last in ((0, "full", 3), (1, "delta", 4)):
        agg = StreamingAggregator(R)
        sp = ex.ShardSpiller(str(root), host, mode=mode, compact_every=16)
        for e in range(1, last + 1):
            agg.update(*_fresh_updates(host, e))
            sp.spill(agg, e)
        epochs[host] = last
    return epochs


FRESH_TARGETS = [
    ("full-manifest", 0, "host_0000/epoch_000000003/manifest.json"),
    ("full-leaf", 0, "host_0000/epoch_000000003/arr_00001.npy"),
    ("delta-manifest", 1, "host_0001/epoch_000000004/manifest.json"),
    ("delta-leaf", 1, "host_0001/epoch_000000004/arr_00002.npy"),
    ("latest", 1, "host_0001/LATEST"),
]


@pytest.mark.parametrize("kind", ["bitflip", "truncate"])
@pytest.mark.parametrize("cls,host,rel",
                         FRESH_TARGETS,
                         ids=[t[0] for t in FRESH_TARGETS])
def test_fresh_tree_corruption(tmp_path, cls, host, rel, kind):
    for salt in range(3):
        root = tmp_path / f"{kind}-{salt}"
        epochs = _write_fresh_tree(root)
        allowed = _allowed_strict_keys(_fresh_updates, epochs, host)
        _corrupt_file(str(root / rel), kind, salt)
        _check_strict(str(root), allowed)
        _check_quorum(str(root), _fresh_updates, tuple(epochs))


def test_uncorrupted_trees_pass_both_checks(tmp_path):
    """The harness itself must accept pristine trees (no false alarms)
    and report them as complete coverage."""
    fix_allowed = {_reduce_key(_fixture_updates, FIXTURE_EPOCHS)}
    assert _check_strict(os.path.join(DATA, "region"), fix_allowed) == "valid"
    res = _check_quorum(os.path.join(DATA, "region"), _fixture_updates,
                        tuple(FIXTURE_EPOCHS))
    assert res.complete
    epochs = _write_fresh_tree(tmp_path)
    allowed = {_reduce_key(_fresh_updates, epochs)}
    assert _check_strict(str(tmp_path), allowed) == "valid"
    assert _check_quorum(str(tmp_path), _fresh_updates,
                         tuple(epochs)).complete


def test_every_host_corrupt_is_a_typed_quorum_failure(tmp_path):
    """When no host has any intact durable epoch, the quorum path must
    raise the typed QuorumError — never return fabricated statistics."""
    _write_fresh_tree(tmp_path)
    for dirpath, _dirnames, filenames in os.walk(tmp_path):
        for name in filenames:
            if name.startswith("arr_") or name == "manifest.json":
                _corrupt_file(os.path.join(dirpath, name), "truncate", 0)
    with pytest.raises((QuorumError, SpillError)):
        ex.gather_shards(str(tmp_path), quorum=ex.QuorumPolicy(
            expected_hosts=(0, 1), min_hosts=1, backoff=0.0))
