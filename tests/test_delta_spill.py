"""Incremental (delta) spills + compaction: chain fold correctness,
crash/restart stories, mixed full/delta gathers, and the profiler /
serving-accountant wiring."""

import os
import time

import numpy as np
import pytest

from repro.core import exchange as ex
from repro.core.profiler import EnergyProfiler
from repro.core.streaming import (StreamingAggregator,
                                  StreamingCombinationAggregator)
from repro.core.timeline import RegionCost, synthesize


def _dyadic(rng, n):
    """Powers exactly representable (k/64): sums bit-exact under any
    association order."""
    return rng.integers(50 * 64, 200 * 64, n) / 64.0


def _epoch_dirs(path, host_id):
    hd = os.path.join(path, f"host_{host_id:04d}")
    return sorted(n for n in os.listdir(hd) if n.startswith("epoch_")
                  and ".tmp" not in n)


# ---------------------------------------------------------------------------
# Delta primitives
# ---------------------------------------------------------------------------

def test_compute_apply_roundtrip_combination():
    rng = np.random.default_rng(0)
    agg = StreamingCombinationAggregator()
    agg.update(rng.integers(0, 4, (500, 2)).astype(np.int64),
               _dyadic(rng, 500))
    prev = ex.pack_shard(agg)
    prev = ex._copy_shard(prev)
    agg.update(rng.integers(0, 6, (300, 2)).astype(np.int64),
               _dyadic(rng, 300))
    cur = ex.pack_shard(agg)
    delta = ex.compute_shard_delta(prev, cur)
    assert delta.n_rows == cur.n_rows and delta.prev_rows == prev.n_rows
    # sparse: only touched rows ride along
    assert len(delta.idx) <= cur.n_rows
    back = ex.apply_shard_delta(prev, delta)
    assert back.n_rows == cur.n_rows
    assert np.array_equal(back.counts, cur.counts[:cur.n_rows])
    assert np.array_equal(back.psum, cur.psum[:cur.n_rows])
    assert np.array_equal(back.psumsq, cur.psumsq[:cur.n_rows])
    assert np.array_equal(back.combos, cur.combos[:cur.n_rows])


def test_apply_rejects_chain_mismatch():
    rng = np.random.default_rng(1)
    a = StreamingAggregator(4).update(
        rng.integers(0, 4, 100).astype(np.int64), _dyadic(rng, 100))
    s0 = ex._copy_shard(ex.pack_shard(a))
    a.update(rng.integers(0, 4, 100).astype(np.int64), _dyadic(rng, 100))
    delta = ex.compute_shard_delta(s0, ex.pack_shard(a))
    wrong = ex.PackedShard(counts=np.zeros(7, np.int64),
                           psum=np.zeros(7), psumsq=np.zeros(7), n_rows=7)
    with pytest.raises(IOError, match="chain mismatch"):
        ex.apply_shard_delta(wrong, delta)


def test_compute_delta_rejects_non_append_only():
    rng = np.random.default_rng(2)
    a = StreamingCombinationAggregator().update(
        rng.integers(0, 3, (50, 2)).astype(np.int64), _dyadic(rng, 50))
    b = StreamingCombinationAggregator().update(
        rng.integers(3, 6, (50, 2)).astype(np.int64), _dyadic(rng, 50))
    pa, pb = ex.pack_shard(a), ex.pack_shard(b)
    if pa.n_rows and pb.n_rows:
        with pytest.raises(ValueError):
            ex.compute_shard_delta(pa, pb)


# ---------------------------------------------------------------------------
# Spiller: chains, compaction, GC
# ---------------------------------------------------------------------------

def test_delta_gather_bit_exact_vs_full_4hosts(tmp_path):
    """Acceptance: a delta-spilled 4-host run gathers bit-exactly vs the
    same run with full spills (int64 counts, float64 sums)."""
    d_delta = str(tmp_path / "delta")
    d_full = str(tmp_path / "full")
    rng = np.random.default_rng(3)
    for h in range(4):
        sp = ex.ShardSpiller(d_delta, h, mode="delta", compact_every=6)
        agg = StreamingCombinationAggregator()
        for e in range(1, 21):
            agg.update(rng.integers(0, 5, (40, 2)).astype(np.int64),
                       _dyadic(rng, 40))
            sp.spill(agg, e)
            ex.spill_shard(d_full, h, e, agg)
    ga = ex.gather_shards(d_delta)
    gb = ex.gather_shards(d_full)
    assert ga.interner.combos == gb.interner.combos
    assert np.array_equal(ga.agg.counts, gb.agg.counts)
    assert np.array_equal(ga.agg.psum, gb.agg.psum)
    assert np.array_equal(ga.agg.psumsq, gb.agg.psumsq)


def test_mixed_full_and_delta_hosts_gather(tmp_path):
    """Readers must transparently merge hosts publishing full shards with
    hosts publishing delta chains."""
    rng = np.random.default_rng(4)
    ref = StreamingCombinationAggregator()
    for h, mode in enumerate(("full", "delta", "delta")):
        sp = ex.ShardSpiller(str(tmp_path), h, mode=mode, compact_every=4)
        agg = StreamingCombinationAggregator()
        for e in range(1, 8):
            mat = rng.integers(0, 4, (30, 2)).astype(np.int64)
            pows = _dyadic(rng, 30)
            agg.update(mat, pows)
            sp.spill(agg, e)
        ref.merge(agg)
    merged = ex.gather_shards(str(tmp_path))
    assert merged.interner.combos == ref.interner.combos
    assert np.array_equal(merged.agg.counts, ref.agg.counts)
    assert np.array_equal(merged.agg.psum, ref.agg.psum)


def test_compaction_gc_keeps_directory_bounded(tmp_path):
    rng = np.random.default_rng(5)
    sp = ex.ShardSpiller(str(tmp_path), 0, mode="delta", compact_every=5)
    agg = StreamingAggregator(6)
    for e in range(1, 26):
        agg.update(rng.integers(0, 6, 20).astype(np.int64),
                   _dyadic(rng, 20))
        sp.spill(agg, e)
        assert len(_epoch_dirs(str(tmp_path), 0)) <= 5
    # the live chain alone survives; it folds to the live aggregator
    restored, epoch = ex.restore_shard(str(tmp_path), 0)
    assert epoch == 25
    assert np.array_equal(restored.counts, agg.counts)
    assert np.array_equal(restored.psum, agg.psum)


def test_killed_host_mid_delta_leaves_only_tmp_litter(tmp_path):
    """A writer killed mid-delta leaves a ``.tmp-`` dir; readers ignore
    it and fold the intact chain."""
    rng = np.random.default_rng(6)
    sp = ex.ShardSpiller(str(tmp_path), 0, mode="delta", compact_every=10)
    agg = StreamingCombinationAggregator()
    for e in range(1, 4):
        agg.update(rng.integers(0, 4, (25, 2)).astype(np.int64),
                   _dyadic(rng, 25))
        sp.spill(agg, e)
    # crash mid-write of epoch 4's delta: partial tmp dir, LATEST at 3
    hd = tmp_path / "host_0000"
    dead = hd / "epoch_000000004.tmp-deadbeef"
    dead.mkdir()
    (dead / "arr_00000.npy").write_bytes(b"\x93NUMPY partial")
    restored, epoch = ex.restore_shard(str(tmp_path), 0)
    assert epoch == 3
    assert np.array_equal(restored.agg.counts, agg.agg.counts)
    merged = ex.gather_shards(str(tmp_path))
    assert np.array_equal(merged.agg.counts, agg.agg.counts)


def test_crash_between_delta_and_compaction_no_double_count(tmp_path):
    """Acceptance: a host killed between a delta publish and compaction
    restarts from the on-disk chain and re-gathers without
    double-counting."""
    rng = np.random.default_rng(7)
    ref = StreamingCombinationAggregator()

    sp = ex.ShardSpiller(str(tmp_path), 0, mode="delta", compact_every=4)
    agg = StreamingCombinationAggregator()
    chunks = [(rng.integers(0, 5, (30, 2)).astype(np.int64),
               _dyadic(rng, 30)) for _ in range(10)]
    # epochs 1..6: base at 1, deltas 2-4... then die at epoch 6 — a delta
    # epoch, published but not yet compacted (in-memory spiller lost).
    for e in range(1, 7):
        agg.update(*chunks[e - 1])
        sp.spill(agg, e)
    del sp

    # restart: resume the folded chain, replay post-spill work only.
    sp2 = ex.ShardSpiller(str(tmp_path), 0, mode="delta", compact_every=4)
    assert sp2.epoch == 6
    agg2 = StreamingCombinationAggregator().merge(sp2.resumed)
    for e in range(7, 11):
        agg2.update(*chunks[e - 1])
        sp2.spill(agg2, e)

    for mat, pows in chunks:
        ref.update(mat, pows)
    merged = ex.gather_shards(str(tmp_path))
    assert merged.interner.combos == ref.interner.combos
    assert np.array_equal(merged.agg.counts, ref.agg.counts)
    assert np.array_equal(merged.agg.psum, ref.agg.psum)
    assert np.array_equal(merged.agg.psumsq, ref.agg.psumsq)


def test_broken_chain_raises(tmp_path):
    rng = np.random.default_rng(8)
    sp = ex.ShardSpiller(str(tmp_path), 0, mode="delta", compact_every=99)
    agg = StreamingAggregator(4)
    for e in range(1, 5):
        agg.update(rng.integers(0, 4, 10).astype(np.int64),
                   _dyadic(rng, 10))
        sp.spill(agg, e)
    # delete a mid-chain delta: the chain is unreadable and must say so
    import shutil
    shutil.rmtree(tmp_path / "host_0000" / "epoch_000000002")
    with pytest.raises(IOError, match="chain"):
        ex.restore_shard(str(tmp_path), 0)


# ---------------------------------------------------------------------------
# Profiler / accountant wiring
# ---------------------------------------------------------------------------

def _timelines():
    costs = [RegionCost("mem", flops=1e10, hbm_bytes=5e10, invocations=4),
             RegionCost("alu", flops=6e11, hbm_bytes=2e9, invocations=4)]
    return [synthesize(costs, steps=60, seed=s) for s in (0, 1)]


def test_profiler_delta_exchange_restart_idempotent(tmp_path):
    """A deterministic profiler re-run against the same delta spill dir
    republishes as an (empty) delta epoch — same estimates, no
    double-counting."""
    tls = _timelines()
    prof = EnergyProfiler(period=10e-3)
    est_ref, combos_ref = prof.profile_multiworker_streaming(
        tls, sensor="instant", chunk_size=256)
    est1, combos1 = prof.profile_multiworker_streaming(
        tls, sensor="instant", chunk_size=256,
        exchange=ex.CheckpointExchange(str(tmp_path), host_id=0,
                                       mode="delta"))
    assert combos1 == combos_ref
    assert est1.n_total == est_ref.n_total

    est2, combos2 = prof.profile_multiworker_streaming(
        tls, sensor="instant", chunk_size=256,
        exchange=ex.CheckpointExchange(str(tmp_path), host_id=0,
                                       mode="delta"))
    assert combos2 == combos_ref
    assert est2.n_total == est_ref.n_total
    assert np.array_equal(est2.table.e_hat, est_ref.table.e_hat)
    # the second publish was an incremental epoch on the same chain
    restored, epoch = ex.restore_shard(str(tmp_path), 0)
    assert epoch == 2


def test_accountant_exit_publishes_each_epoch_once(tmp_path):
    """__exit__ must not re-publish the epoch drain() just spilled."""
    from repro.core import regions as regions_mod
    from repro.serve.engine import PhaseEnergyAccountant

    acct = PhaseEnergyAccountant(period=1e-3, jitter=1e-4,
                                 spill_dir=str(tmp_path), host_id=0,
                                 spill_every=1)
    published = []
    orig = acct._spiller.spill

    def counting_spill(agg, epoch, extra_meta=None):
        published.append(epoch)
        return orig(agg, epoch, extra_meta=extra_meta)
    acct._spiller.spill = counting_spill

    with acct:
        for _ in range(3):
            with regions_mod.region("serve/busy"):
                t0 = time.monotonic()
                while time.monotonic() - t0 < 2e-3:
                    pass
            acct.drain()
    # every drain spilled (spill_every=1) incl. the exit drain; no epoch
    # may appear twice (the pre-fix behaviour published the last twice).
    assert len(published) == len(set(published))
    assert ex.restore_shard(str(tmp_path), 0)[1] == max(published)


def test_accountant_delta_restart_resume(tmp_path):
    """Accountant spill_mode='delta' (default): restart resumes the
    folded chain, epochs keep counting, elapsed time is carried."""
    from repro.core import regions as regions_mod
    from repro.serve.engine import PhaseEnergyAccountant

    acct = PhaseEnergyAccountant(period=1e-3, jitter=1e-4,
                                 spill_dir=str(tmp_path), host_id=1,
                                 spill_every=2, compact_every=3)
    with acct:
        for _ in range(7):
            with regions_mod.region("serve/busy"):
                t0 = time.monotonic()
                while time.monotonic() - t0 < 2e-3:
                    pass
            acct.drain()
    restored, epoch = ex.restore_shard(str(tmp_path), 1)
    assert np.array_equal(restored.counts[:acct.agg.num_regions],
                          acct.agg.counts[:restored.num_regions])

    acct2 = PhaseEnergyAccountant(period=1e-3, jitter=1e-4,
                                  spill_dir=str(tmp_path), host_id=1,
                                  spill_every=2, compact_every=3)
    assert acct2.agg.n_total == acct.agg.n_total
    assert acct2._epoch == epoch
    assert acct2._elapsed_offset == pytest.approx(acct.elapsed)
