"""Unit + property tests for the ALEA probabilistic estimator (Eqs. 2-16)."""

import math

import numpy as np
import pytest
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # degrade gracefully: deterministic fixed-seed draws
    from _hypothesis_fallback import given, settings, st

from repro.core.estimator import (aggregate_samples_np, encode_combinations,
                                  estimate_combinations, estimate_regions,
                                  marginalize_worker, z_quantile)


def test_z_quantile_known_values():
    assert z_quantile(0.05) == pytest.approx(1.959964, abs=1e-4)
    assert z_quantile(0.01) == pytest.approx(2.575829, abs=1e-4)
    assert z_quantile(0.32) == pytest.approx(0.994458, abs=1e-4)


def test_point_estimates_match_equations():
    # 3 regions; hand-checkable counts.
    rids = np.array([0, 1, 1, 2, 2, 2, 1, 1])
    pows = np.array([10.0, 20.0, 22.0, 30.0, 32.0, 28.0, 18.0, 20.0])
    est = estimate_regions(rids, pows, t_exec=8.0, names=["a", "b", "c"])
    by = est.by_name()
    assert by["a"].t_hat == pytest.approx(1.0)          # 1/8 · 8
    assert by["b"].t_hat == pytest.approx(4.0)          # 4/8 · 8
    assert by["c"].t_hat == pytest.approx(3.0)
    assert by["b"].pow_hat == pytest.approx(20.0)       # mean(20,22,18,20)
    assert by["c"].e_hat == pytest.approx(30.0 * 3.0)   # Eq. 7
    assert sum(r.t_hat for r in est.regions) == pytest.approx(8.0)


def test_ci_validity_rule():
    rids = np.array([0] * 3 + [1] * 97)
    pows = np.ones(100)
    est = estimate_regions(rids, pows, 1.0, ["rare", "hot"])
    assert not est.by_name()["rare"].ci_valid      # n·p = 3 < 5
    assert est.by_name()["hot"].ci_valid is False  # n·(1-p) = 3 < 5
    rids = np.array([0] * 30 + [1] * 70)
    est = estimate_regions(rids, np.ones(100), 1.0, ["a", "b"])
    assert est.by_name()["a"].ci_valid and est.by_name()["b"].ci_valid


def test_energy_ci_is_product_interval():
    rng = np.random.default_rng(0)
    rids = rng.integers(0, 2, size=5000)
    pows = np.where(rids == 0, 10.0, 20.0) + rng.normal(0, 0.5, 5000)
    est = estimate_regions(rids, pows, 10.0, ["x", "y"])
    for r in est.regions:
        assert r.e_lo == pytest.approx(r.t_lo * r.pow_lo)
        assert r.e_hi == pytest.approx(r.t_hi * r.pow_hi)
        assert r.e_lo <= r.e_hat <= r.e_hi


@given(n=st.integers(200, 5000), p=st.floats(0.1, 0.9),
       seed=st.integers(0, 2**31))
@settings(max_examples=30, deadline=None)
def test_property_bernoulli_mle_converges(n, p, seed):
    """p̂ = n_bb/n is unbiased; error shrinks like 1/sqrt(n) (§4.3)."""
    rng = np.random.default_rng(seed)
    rids = (rng.random(n) < p).astype(np.int32)
    est = estimate_regions(rids, np.ones(n), 1.0, ["zero", "one"])
    r = est.by_name().get("one")
    if r is None:
        return
    # 6-sigma bound on the MLE deviation.
    assert abs(r.p_hat - p) < 6 * math.sqrt(p * (1 - p) / n) + 1e-9


@given(seed=st.integers(0, 2**31))
@settings(max_examples=20, deadline=None)
def test_property_ci_shrinks_with_n(seed):
    rng = np.random.default_rng(seed)
    widths = []
    for n in (500, 5000, 50000):
        rids = (rng.random(n) < 0.4).astype(np.int32)
        est = estimate_regions(rids, np.ones(n), 1.0, ["a", "b"])
        widths.append(est.by_name()["b"].t_ci_halfwidth)
    assert widths[0] > widths[1] > widths[2]
    # ~ 1/sqrt(n): 100x samples → ~10x narrower (allow 2x slack).
    assert widths[0] / widths[2] > 5.0


def test_ci_coverage_monte_carlo():
    """~95% of 95%-CIs contain the true proportion (Eq. 10)."""
    rng = np.random.default_rng(42)
    p_true, n, trials, hits = 0.3, 2000, 300, 0
    for _ in range(trials):
        rids = (rng.random(n) < p_true).astype(np.int32)
        est = estimate_regions(rids, np.ones(n), 1.0, ["a", "b"])
        r = est.by_name()["b"]
        hits += (r.t_lo <= p_true * 1.0 <= r.t_hi)
    assert 0.90 <= hits / trials <= 0.99


def test_aggregate_matches_manual():
    rids = np.array([2, 0, 2, 1, 2])
    pows = np.array([1.0, 2.0, 3.0, 4.0, 5.0])
    counts, psum, psumsq = aggregate_samples_np(rids, pows, 4)
    np.testing.assert_array_equal(counts, [1, 1, 3, 0])
    np.testing.assert_allclose(psum, [2.0, 4.0, 9.0, 0.0])
    np.testing.assert_allclose(psumsq, [4.0, 16.0, 35.0, 0.0])


def test_combinations_roundtrip():
    mat = np.array([[0, 1], [0, 1], [1, 1], [0, 2]])
    ids, combos = encode_combinations(mat)
    assert len(combos) == 3
    for i, cid in enumerate(ids):
        assert combos[cid] == tuple(mat[i])


def test_combination_estimation_and_marginals():
    rng = np.random.default_rng(1)
    n = 20000
    # Worker 0 alternates regions 1/2; worker 1 mostly region 1.
    w0 = rng.choice([1, 2], size=n, p=[0.6, 0.4])
    w1 = rng.choice([1, 2], size=n, p=[0.9, 0.1])
    pows = 50.0 + 10.0 * (w0 == 1) + 10.0 * (w1 == 1)
    est, combos = estimate_combinations(np.stack([w0, w1], 1), pows, 100.0,
                                        ["<other>", "hot", "cold"])
    assert sum(r.t_hat for r in est.regions) == pytest.approx(100.0)
    # (hot,hot) combination should be the dominant one: p≈0.54.
    top = max(est.regions, key=lambda r: r.t_hat)
    assert top.name == "hot+hot"
    assert top.t_hat == pytest.approx(54.0, rel=0.05)
    marg = marginalize_worker(est, combos, ["<other>", "hot", "cold"])
    t_hot = marg.by_name()["hot"].t_hat
    # hot appears in any combination containing region 1 ≈ 96% of time.
    assert t_hot == pytest.approx(100 * (1 - 0.4 * 0.1), rel=0.05)


def test_no_samples_raises():
    with pytest.raises(ValueError):
        estimate_regions(np.array([], dtype=int), np.array([]), 1.0, ["a"])
