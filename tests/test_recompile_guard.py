"""Recompile-count guard: one (ModelConfig, shape) key means exactly one
compile. The serve decode step and the fused device-pipeline chunk steps
are traced once and reused across requests/chunks/runs — a shape or
hashable-config leak here multiplies latency by the compile time and
breaks the paper's overhead budget silently (everything still computes
the right numbers, just slowly)."""

import dataclasses

import jax
import numpy as np

from repro.analysis.jaxpr_audit import jit_cache_size
from repro.configs.registry import get_config
from repro.core import device_pipeline as dp
from repro.core.sensors import InstantTraceSensor
from repro.core.timeline import RegionCost, synthesize
from repro.models import model as M
from repro.serve.engine import (Engine, Request, ServeConfig, _jitted_fns,
                                _jitted_spec_fns)


def _fresh_cfg():
    """A config no other test shares, so the session-wide lru-cached
    jitted fns start cold for this module."""
    cfg = get_config("qwen3-1.7b").reduced()
    return dataclasses.replace(cfg, vocab_size=cfg.vocab_size + 3)


def test_engine_decode_compiles_once_across_requests():
    cfg = _fresh_cfg()
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    eng = Engine(cfg, params, ServeConfig(max_batch=3, max_len=64,
                                          eos_token=-1))
    decode, reset = _jitted_fns(cfg)
    assert decode is eng._decode_masked     # config-keyed cache shared
    assert jit_cache_size(decode) == 0

    rng = np.random.default_rng(7)
    reqs = [Request(rid=i,
                    prompt=rng.integers(1, cfg.vocab_size, n)
                    .astype(np.int32),
                    max_new_tokens=6)
            for i, n in enumerate((5, 3, 9, 4))]
    # Staggered multi-request run: prefills at several depths, ragged
    # decode, slot reuse after the first requests drain.
    eng.add_request(reqs[0])
    eng.step()
    eng.add_request(reqs[1])
    eng.add_request(reqs[2])
    for _ in range(30):
        eng.step()
        if all(r is None for r in eng.slot_req):
            break
    eng.add_request(reqs[3])                # reuses a drained slot
    for _ in range(30):
        eng.step()
        if all(r is None for r in eng.slot_req):
            break
    assert all(r.done for r in reqs)

    assert jit_cache_size(decode) == 1, \
        "decode step recompiled within one (config, shape) key"
    assert jit_cache_size(reset) == 1

    # A second engine over the same config keeps sharing the same trace.
    eng2 = Engine(cfg, params, ServeConfig(max_batch=3, max_len=64,
                                           eos_token=-1))
    eng2.run_until_drained([Request(rid=99,
                                    prompt=np.array([1, 2], np.int32),
                                    max_new_tokens=4)])
    assert jit_cache_size(decode) == 1
    assert jit_cache_size(reset) == 1


def test_snapshot_restore_and_aborts_add_no_compile_keys(tmp_path):
    # The reworked engine paths — queue-driven admission, budget/deadline
    # aborts, snapshot publish and restore-replay — must all reuse the
    # one (config, shape) decode trace: replay teacher-forces through
    # the SAME masked decode step at the same shapes.
    cfg = dataclasses.replace(_fresh_cfg(), vocab_size=_fresh_cfg()
                              .vocab_size + 4)   # own key for this test
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    scfg = ServeConfig(max_batch=2, max_len=48, eos_token=-1,
                       step_energy=1.0)
    decode, reset = _jitted_fns(cfg)
    assert jit_cache_size(decode) == 0

    rng = np.random.default_rng(3)
    eng = Engine(cfg, params, scfg)
    eng.submit(Request(0, rng.integers(1, cfg.vocab_size, 5)
                       .astype(np.int32), max_new_tokens=8))
    eng.submit(Request(1, rng.integers(1, cfg.vocab_size, 4)
                       .astype(np.int32), max_new_tokens=12,
                       energy_budget=6.0))      # budget-aborts mid-decode
    eng.step()
    eng.step()
    eng.snapshot(str(tmp_path))
    assert jit_cache_size(decode) == 1

    restored = Engine.restore(cfg, params, scfg, str(tmp_path))
    restored.run_until_drained([])              # replay + finish + abort
    assert restored.report.aborted_budget == 1
    assert jit_cache_size(decode) == 1, \
        "snapshot/restore or abort path introduced a new compile key"
    assert jit_cache_size(reset) == 1


def test_speculative_draft_and_verify_compile_once():
    # The speculative hot loop adds exactly two traces per
    # (config, window, sinks) key — one windowed draft step and one
    # L-wide verify step — reused across windows, slots and engines.
    # Rollback replay rides the baseline masked-decode trace, so a full
    # speculative run must not grow any cache beyond those.
    cfg = dataclasses.replace(_fresh_cfg(), vocab_size=_fresh_cfg()
                              .vocab_size + 9)   # own key for this test
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    scfg = ServeConfig(max_batch=2, max_len=64, eos_token=-1,
                       spec_len=4, spec_window=8, spec_sinks=2)
    draft, verify = _jitted_spec_fns(cfg, scfg.spec_window, scfg.spec_sinks)
    decode, reset = _jitted_fns(cfg)
    assert jit_cache_size(draft) == 0 and jit_cache_size(verify) == 0

    rng = np.random.default_rng(5)
    eng = Engine(cfg, params, scfg)
    assert eng._draft_step is draft and eng._verify_step is verify
    reqs = [Request(rid=i,
                    prompt=rng.integers(1, cfg.vocab_size, n)
                    .astype(np.int32),
                    max_new_tokens=8)
            for i, n in enumerate((6, 3))]
    eng.add_request(reqs[0])
    eng.step()
    eng.add_request(reqs[1])                    # ragged speculative decode
    for _ in range(40):
        eng.step()
        if all(r is None for r in eng.slot_req):
            break
    assert all(r.done for r in reqs)
    assert eng.report.drafted > 0

    assert jit_cache_size(draft) == 1, \
        "draft step recompiled within one (config, window, sinks) key"
    assert jit_cache_size(verify) == 1, \
        "verify step recompiled within one (config, L) key"
    assert jit_cache_size(decode) == 1          # prefill + rollback replay
    assert jit_cache_size(reset) == 1

    # A second speculative engine over the same config reuses all traces.
    eng2 = Engine(cfg, params, scfg)
    eng2.run_until_drained([Request(rid=9,
                                    prompt=np.array([1, 2, 3], np.int32),
                                    max_new_tokens=6)])
    assert jit_cache_size(draft) == 1
    assert jit_cache_size(verify) == 1
    assert jit_cache_size(decode) == 1


_GUARD_CHUNK = 333        # unique chunk size => this module owns the key


def _timeline(seed):
    costs = [RegionCost("mem", flops=1e10, hbm_bytes=5e10, invocations=4),
             RegionCost("alu", flops=6e11, hbm_bytes=2e9, invocations=4),
             RegionCost("opt", flops=2e10, hbm_bytes=4e10, invocations=1)]
    return synthesize(costs, steps=40, seed=seed)


def test_region_chunk_step_compiles_once_across_runs():
    spec = InstantTraceSensor.make_spec()
    dtls = [_timeline(s).to_device() for s in (0, 1)]
    assert dtls[0].grid_k == dtls[1].grid_k, "fixture must share the key"
    for seed, dtl in enumerate(dtls):
        dp.run_region_pipeline(dtl, spec, period=5e-3, seed=seed,
                               chunk_size=_GUARD_CHUNK)
    fn = dp._region_run_fn(_GUARD_CHUNK, spec, dtls[0].num_regions, False,
                           dtls[0].grid_k)
    assert jit_cache_size(fn) == 1, \
        "region chunk step recompiled within one (spec, shape) key"


def test_combo_chunk_step_compiles_once_across_runs():
    from repro.core.device_pipeline import DeviceTimeline

    spec = InstantTraceSensor.make_spec()
    dtl = DeviceTimeline.from_timelines([_timeline(0), _timeline(1)])
    for seed in (0, 1):
        dp.run_combo_pipeline(dtl, spec, period=5e-3, seed=seed,
                              chunk_size=_GUARD_CHUNK)
    pack = dp._pack_spec(dtl.num_regions, dtl.num_workers)
    step = dp._combo_step_fn(_GUARD_CHUNK, spec, dtl.grid_k, pack)
    assert jit_cache_size(step) == 1, \
        "combo chunk step recompiled within one (spec, shape) key"
