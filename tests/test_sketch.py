"""Bounded-state attribution: heavy-hitters tier + hash-range sharding.

The contract under test (ROADMAP item 4): a ``k``-bounded combination
table keeps *per-region totals bit-exact* for every k — only tail
identity coarsens into per-region ``other`` rows — and with
``k >= distinct`` the bounded path is byte-for-byte the exact
aggregator (the pinned oracle). Mixed bounded-state configs refuse with
a typed error everywhere (merge, wire, collective), the v3 wire schema
only appears when a shard actually is bounded, and eviction + spill +
restore never double-counts — including under injected crashes.

Power values throughout are dyadic (multiples of 1/64) so float64
summation is exact in any order: "bit-exact" assertions compare
fold orders, not rounding luck.
"""

import json
import os

import numpy as np
import pytest
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # degrade gracefully: deterministic fixed-seed draws
    from _hypothesis_fallback import given, settings, st

from repro.checkpoint import ckpt
from repro.core import device_pipeline as dp
from repro.core import exchange as ex
from repro.core import faults
from repro.core import regions as regions_mod
from repro.core.attribution import AttributionReport
from repro.core.faults import FaultPlan, InjectedCrash, SketchConfigError
from repro.core.sensors import InstantTraceSensor
from repro.core.sketch import (OTHER, HashRange, combo_hashes, is_other_rows,
                               mix64, other_row)
from repro.core.streaming import StreamingCombinationAggregator
from repro.core.timeline import RegionCost, synthesize
from repro.launch.mesh import make_exchange_mesh
from repro.serve.engine import PhaseEnergyAccountant
from repro.serve.scheduler import ServeReport

R = 5          # regions (combination column 0)
W = 3          # key width


def _stream(seed: int, n: int, r: int = R, w: int = W):
    """(rows, dyadic powers): combination keys over a small id space so
    streams collide (heavy hitters exist) while still growing distinct."""
    rng = np.random.default_rng(seed)
    mat = rng.integers(0, (r,) + (6,) * (w - 1), (n, w)).astype(np.int64)
    pows = rng.integers(40 * 64, 260 * 64, n) / 64.0
    return mat, pows


def _region_totals(agg: StreamingCombinationAggregator, r: int = R):
    """Per-region (counts, Σpow, Σpow²) folded over the table — the
    quantity the heavy-hitters tier promises to keep bit-exact."""
    n = len(agg.interner)
    mat = agg.interner.combo_matrix()
    counts = np.zeros(r, np.int64)
    ps = np.zeros(r, np.float64)
    psq = np.zeros(r, np.float64)
    if n:
        reg = mat[:, 0]
        np.add.at(counts, reg, agg.agg.counts[:n])
        np.add.at(ps, reg, agg.agg.psum[:n])
        np.add.at(psq, reg, agg.agg.psumsq[:n])
    return counts, ps, psq


def _assert_bitexact(a: StreamingCombinationAggregator,
                     b: StreamingCombinationAggregator):
    assert a.interner.combos == b.interner.combos
    n = len(a.interner)
    assert np.array_equal(a.agg.counts[:n], b.agg.counts[:n])
    assert np.array_equal(a.agg.chan_psum[:n], b.agg.chan_psum[:n])
    assert np.array_equal(a.agg.chan_psumsq[:n], b.agg.chan_psumsq[:n])


# ---------------------------------------------------------------------------
# Hash primitives: one mixer fleet-wide.
# ---------------------------------------------------------------------------

def test_combo_hashes_match_scalar_fault_mixer():
    """Vectorized row hashes == faults._mix64 word-for-word (hosts agree
    on range ownership with no coordination), including the negative
    OTHER sentinel absorbing as its two's-complement image."""
    rng = np.random.default_rng(11)
    mat = rng.integers(-2, 2 ** 40, (64, 4)).astype(np.int64)
    mat[0] = other_row(3, 4)
    got = combo_hashes(mat)
    for i in range(len(mat)):
        want = faults._mix64(*(int(v) for v in mat[i]))
        assert int(got[i]) == want
    # Single mix64 round == one-word scalar mix (absorb from 0 seed).
    h = mix64(np.zeros(3, np.uint64),
              np.array([1, 2, 3], np.int64).view(np.uint64))
    base = 0x9E3779B97F4A7C15
    for i, w in enumerate((1, 2, 3)):
        assert int(h[i]) == faults._mix64(w - base)


def test_hash_range_split_owns_and_validates():
    full = HashRange.full()
    assert HashRange.split(1) == (full,)
    parts = HashRange.split(7)
    assert parts[0].lo == 0 and parts[-1].hi == 1 << 64
    for a, b in zip(parts, parts[1:]):
        assert a.hi == b.lo                       # contiguous, no gaps
    h = combo_hashes(_stream(0, 500)[0])
    owned = np.stack([p.owns(h) for p in parts])
    assert np.array_equal(owned.sum(axis=0), np.ones(len(h)))  # partition
    assert full.owns(h).all()
    row = np.array([1, 2, 3], np.int64)
    assert sum(p.owns_row(row) for p in parts) == 1
    for lo, hi in ((5, 5), (-1, 10), (0, (1 << 64) + 1)):
        with pytest.raises(ValueError):
            HashRange(lo, hi)
    with pytest.raises(ValueError):
        HashRange.split(0)


def test_other_row_sentinel_and_width_guard():
    assert other_row(3, 4) == (3, OTHER, OTHER, OTHER)
    mask = is_other_rows(np.array([[1, 2], [1, OTHER], [0, 0]], np.int64))
    assert mask.tolist() == [False, True, False]
    with pytest.raises(SketchConfigError):
        other_row(0, 1)
    agg = StreamingCombinationAggregator(k=4)
    with pytest.raises(SketchConfigError):
        agg.update(np.zeros((3, 1), np.int64), np.ones(3))
    with pytest.raises(ValueError):
        StreamingCombinationAggregator(k=0)


# ---------------------------------------------------------------------------
# The tier's core contract, as a property over (seed, k, n).
# ---------------------------------------------------------------------------

@settings(max_examples=30, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10 ** 6),
       k=st.integers(min_value=1, max_value=48),
       n=st.integers(min_value=1, max_value=500))
def test_bounded_region_totals_bitexact_for_every_k(seed, k, n):
    mat, pows = _stream(seed, n)
    exact = StreamingCombinationAggregator()
    bounded = StreamingCombinationAggregator(k=k)
    for lo in range(0, n, 64):                    # chunked, like real feeds
        exact.update(mat[lo:lo + 64], pows[lo:lo + 64])
        bounded.update(mat[lo:lo + 64], pows[lo:lo + 64])
    ec, eps, epsq = _region_totals(exact)
    bc, bps, bpsq = _region_totals(bounded)
    assert np.array_equal(ec, bc)
    assert np.array_equal(eps, bps)               # dyadic → order-free
    assert np.array_equal(epsq, bpsq)
    assert bounded.resident <= k
    assert bounded.n_total == exact.n_total
    distinct = len(exact.interner)
    if k >= distinct:                             # pinned oracle
        _assert_bitexact(bounded, exact)
        assert bounded.tail_folds == 0 and bounded.evictions == 0
    else:
        assert bounded.tail_folds > 0


def test_k_ge_distinct_is_byte_for_byte_including_spill(tmp_path):
    mat, pows = _stream(3, 800)
    exact = StreamingCombinationAggregator().update(mat, pows)
    bounded = StreamingCombinationAggregator(k=4096).update(mat, pows)
    _assert_bitexact(bounded, exact)
    # ... and stays the oracle through a spill/restore round trip.
    ex.spill_shard(str(tmp_path), 0, epoch=1, agg=bounded)
    back = ex.gather_shards(str(tmp_path))
    _assert_bitexact(back, exact)
    assert back.k == 4096


def test_min_floor_resets_between_chunks():
    """The victim-scan floor is per-ingest state: a chunk-protected
    light row inflates the scanned minimum for THAT chunk only. If the
    floor leaked across chunks (regression), later arrivals would skip
    the victim scan and fold to the tail even though the light row is
    evictable again — silently deviating from the space-saving policy."""
    agg = StreamingCombinationAggregator(k=2)

    def chunk(rows_weights):
        rows = []
        for row, w in rows_weights:
            rows += [row] * w
        m = np.asarray(rows, np.int64)
        agg.update(m, np.full(len(m), 64.0))

    chunk([((0, 0), 1), ((0, 1), 10)])     # residents: A light, B heavy
    # A is touched (chunk-protected), so C's victim scan sees only B
    # (count 10): the floor inflates to 10 and C (weight 5) folds.
    chunk([((0, 0), 1), ((0, 2), 5)])
    assert agg.tail_folds == 1 and agg.evictions == 0
    # Next chunk: A (count 2) is unprotected and evictable again. D's
    # weight 3 beats it, so D must evict A — not skip the scan against
    # a stale floor of 10 and fold.
    chunk([((0, 3), 3)])
    assert agg.evictions == 1
    combos = set(agg.interner.combos)
    assert (0, 3) in combos and (0, 0) not in combos
    # Per-region totals stay exact through it all.
    counts, ps, _ = _region_totals(agg, 1)
    assert counts[0] == 20 and ps[0] == 20 * 64.0


# ---------------------------------------------------------------------------
# Typed refusal of mixed configs.
# ---------------------------------------------------------------------------

def test_merge_refuses_mixed_bounded_configs():
    mat, pows = _stream(1, 200)
    b8 = StreamingCombinationAggregator(k=8).update(mat, pows)
    n = len(b8.interner)
    tbl = (b8.interner.combo_matrix(), b8.agg.counts[:n],
           b8.agg.chan_psum[:n], b8.agg.chan_psumsq[:n])
    with pytest.raises(SketchConfigError, match="k mismatch"):
        StreamingCombinationAggregator(k=4).merge_table(*tbl, k=8)
    with pytest.raises(SketchConfigError, match="k mismatch"):
        StreamingCombinationAggregator().merge_table(*tbl, k=8)
    # Sentinel rows offered without declaring k: still refused by the
    # exact destination (never a silent union with a coarsened tail).
    with pytest.raises(SketchConfigError, match="exact"):
        StreamingCombinationAggregator().merge_table(*tbl)
    lo_half, hi_half = HashRange.split(2)
    with pytest.raises(SketchConfigError, match="ownership mismatch"):
        StreamingCombinationAggregator(k=8, hash_range=lo_half).merge_table(
            *tbl, k=8, hash_range=hi_half)
    with pytest.raises(SketchConfigError, match="outside"):
        # The full table can't hash entirely into one half-range.
        StreamingCombinationAggregator(k=8, hash_range=lo_half).merge_table(
            *tbl, k=8)
    with pytest.raises(SketchConfigError, match="k mismatch"):
        b8.merge(StreamingCombinationAggregator().update(mat, pows))


def test_collective_reduce_refuses_mixed_configs():
    mat, pows = _stream(2, 300)
    a = StreamingCombinationAggregator().update(mat, pows)
    b = StreamingCombinationAggregator(k=8).update(mat, pows)
    # Config identity is checked before any device collective runs, so
    # a 1-device mesh suffices to pin the refusal.
    with pytest.raises(SketchConfigError, match="mixed bounded-state"):
        ex.collective_reduce([a, b], mesh=make_exchange_mesh(1))


# ---------------------------------------------------------------------------
# Wire schema v3: bounded shards disclose, exact shards stay v2.
# ---------------------------------------------------------------------------

def test_spill_meta_v3_only_when_bounded(tmp_path):
    mat, pows = _stream(4, 400)
    exact_dir = tmp_path / "exact"
    ex.spill_shard(str(exact_dir), 0, epoch=1,
                   agg=StreamingCombinationAggregator().update(mat, pows))
    meta = ckpt.read_manifest_meta(
        os.path.join(str(exact_dir), "host_0000", "epoch_000000001"))
    # Exact shards must stay byte-compatible with pre-bounded readers:
    # no v3 keys, schema_version stays 2.
    assert meta["schema_version"] == 2
    for key in ("k", "hash_range", "other_rows"):
        assert key not in meta

    b_dir = tmp_path / "bounded"
    lo_half = HashRange.split(2)[0]
    bagg = StreamingCombinationAggregator(k=6).update(mat, pows)
    bagg = bagg.filter_range(lo_half)
    ex.spill_shard(str(b_dir), 0, epoch=1, agg=bagg)
    meta = ckpt.read_manifest_meta(
        os.path.join(str(b_dir), "host_0000", "epoch_000000001"))
    assert meta["schema_version"] == 3
    assert meta["k"] == 6
    assert meta["hash_range"] == [lo_half.lo, lo_half.hi]
    assert meta["other_rows"] == bagg.other_rows
    back = ex.gather_shards(str(b_dir))
    _assert_bitexact(back, bagg)
    assert back.k == 6 and back.hash_range == lo_half


def test_gather_refuses_mixed_config_shards(tmp_path):
    mat, pows = _stream(5, 300)
    ex.spill_shard(str(tmp_path), 0, epoch=1,
                   agg=StreamingCombinationAggregator().update(mat, pows))
    ex.spill_shard(str(tmp_path), 1, epoch=1,
                   agg=StreamingCombinationAggregator(k=8).update(mat, pows))
    with pytest.raises(SketchConfigError):
        ex.gather_shards(str(tmp_path))


# ---------------------------------------------------------------------------
# Hash-range shuffle: n range-gathers partition the fleet exactly once.
# ---------------------------------------------------------------------------

def test_hash_range_shuffle_gather_partitions_union(tmp_path):
    for h in range(3):
        mat, pows = _stream(100 + h, 600)
        ex.spill_shard(str(tmp_path), h, epoch=1,
                       agg=StreamingCombinationAggregator().update(mat, pows))
    whole = ex.gather_shards(str(tmp_path))
    parts = [ex.gather_shards(str(tmp_path), hash_range=r)
             for r in HashRange.split(3)]
    assert sum(p.n_total for p in parts) == whole.n_total
    seen: dict[tuple, tuple] = {}
    for p in parts:
        n = len(p.interner)
        mat = p.interner.combo_matrix()
        assert p.hash_range.owns(combo_hashes(mat)).all()
        for i in range(n):
            key = tuple(int(v) for v in mat[i])
            assert key not in seen                # no row in two ranges
            seen[key] = (int(p.agg.counts[i]), float(p.agg.psum[i]),
                         float(p.agg.psumsq[i]))
    wmat = whole.interner.combo_matrix()
    assert len(seen) == len(whole.interner)       # union covers everything
    for i in range(len(whole.interner)):
        key = tuple(int(v) for v in wmat[i])
        assert seen[key] == (int(whole.agg.counts[i]),
                             float(whole.agg.psum[i]),
                             float(whole.agg.psumsq[i]))


def test_sharded_bounded_spill_restore_after_folds(tmp_path):
    """A bounded + sharded aggregator folds its tail locally, minting
    per-region sentinel keys whose hashes land anywhere in [0, 2**64).
    Ownership applies to identified rows only, so the aggregator's own
    table must round-trip through spill -> gather and peer merges even
    when a sentinel hashes outside the owned range (regression: the
    unpack-side owns() check rejected its own legitimate state as a
    'mis-routed shuffle', breaking crash recovery)."""
    lo_half = HashRange.split(2)[0]
    mat, pows = _stream(42, 2000)
    own = lo_half.owns(combo_hashes(mat))
    mat, pows = mat[own], pows[own]
    agg = StreamingCombinationAggregator(k=3, hash_range=lo_half)
    for lo in range(0, len(mat), 64):
        agg.update(mat[lo:lo + 64], pows[lo:lo + 64])
    assert agg.tail_folds > 0 and agg.other_rows > 0
    smat = agg.interner.combo_matrix()
    sent = is_other_rows(smat)
    # Regression precondition: at least one locally-minted sentinel
    # hashes OUTSIDE the owned range (regions 2/3 at width 3 do).
    assert not lo_half.owns(combo_hashes(smat[sent])).all()
    ex.spill_shard(str(tmp_path), 0, epoch=1, agg=agg)
    back = ex.gather_shards(str(tmp_path))
    _assert_bitexact(back, agg)
    assert back.k == 3 and back.hash_range == lo_half
    # Peer merge of two legitimately-produced sharded tables (the
    # tree_reduce shape) must accept the sentinels too.
    peer = StreamingCombinationAggregator(k=3, hash_range=lo_half)
    for lo in range(0, len(mat), 64):
        peer.update(mat[lo:lo + 64], pows[lo:lo + 64])
    merged = StreamingCombinationAggregator(k=3, hash_range=lo_half)
    merged.merge(agg).merge(peer)
    counts, ps, psq = _region_totals(merged)
    ac, aps, apsq = _region_totals(agg)
    assert np.array_equal(counts, 2 * ac)
    assert np.array_equal(ps, 2 * aps) and np.array_equal(psq, 2 * apsq)


def test_sharded_update_refuses_unowned_rows():
    """Live ingest enforces ownership (the class docstring's contract):
    a mis-routed sample stream fails at update(), not as a confusing
    downstream merge/restore error. Sentinel-free, both modes."""
    lo_half = HashRange.split(2)[0]
    mat, pows = _stream(9, 400)
    own = lo_half.owns(combo_hashes(mat))
    assert own.any() and not own.all()
    for k in (None, 8):
        agg = StreamingCombinationAggregator(k=k, hash_range=lo_half)
        agg.update(mat[own], pows[own])            # owned rows: fine
        with pytest.raises(SketchConfigError, match="outside"):
            agg.update(mat[~own], pows[~own])


def test_region_shards_have_no_hash_range(tmp_path):
    from repro.core.streaming import StreamingAggregator
    agg = StreamingAggregator(4).update(np.array([0, 1, 2, 3]), np.ones(4))
    ex.spill_shard(str(tmp_path), 0, epoch=1, agg=agg)
    with pytest.raises(SketchConfigError):
        ex.gather_shards(str(tmp_path), hash_range=HashRange.full())


# ---------------------------------------------------------------------------
# Eviction + delta spill + restore: never double-counts.
# ---------------------------------------------------------------------------

def test_shard_spiller_eviction_fallback_restores_bitexact(tmp_path):
    """Evictions rewrite row identity, killing the append-only dirty
    overlay; the spiller must fall back to exact snapshot diffs (or a
    fresh full base) and every restore must equal the live table."""
    agg = StreamingCombinationAggregator(k=6)
    sp = ex.ShardSpiller(str(tmp_path), 0, mode="delta", compact_every=4)
    for e in range(1, 9):
        mat, pows = _stream(200 + e, 150)
        agg.update(mat, pows)
        sp.spill(agg, e)
        back = ex.gather_shards(str(tmp_path))
        _assert_bitexact(back, agg)
        assert back.k == 6 and back.tail_folds == agg.tail_folds
    assert agg.evictions > 0 and not agg.append_only


def test_shrink_k_mid_chain_restores(tmp_path):
    agg = StreamingCombinationAggregator(k=12)
    sp = ex.ShardSpiller(str(tmp_path), 0, mode="delta", compact_every=8)
    for e in range(1, 4):
        agg.update(*_stream(300 + e, 120))
        sp.spill(agg, e)
    agg.shrink_k(5)                               # degraded-ladder rung
    assert agg.resident <= 5
    agg.update(*_stream(399, 120))
    sp.spill(agg, 4)
    back = ex.gather_shards(str(tmp_path))
    _assert_bitexact(back, agg)
    assert back.k == 5
    with pytest.raises(ValueError):
        agg.shrink_k(0)
    agg.shrink_k(9)                               # never widens: no-op
    assert agg.k == 5


def test_chaos_crash_restore_conserves_bounded_totals(tmp_path):
    """A host dies with an epoch in flight, restarts from its LATEST
    chain, and replays forward: the result is bit-exact to the host that
    never crashed — evictions, tail folds and all. (If restore double-
    counted or lost folded tail weight, region totals would drift.)"""
    def updates(e):
        return _stream(7000 + e, 130)

    ref = StreamingCombinationAggregator(k=5)
    for e in range(1, 9):
        ref.update(*updates(e))
    assert ref.evictions > 0                      # the tier actually fired

    plan = FaultPlan(seed=1, crashes=((0, 5),))
    agg = StreamingCombinationAggregator(k=5)
    died_at = None
    with faults.install(plan):
        sp = ex.ShardSpiller(str(tmp_path), 0, mode="delta",
                             compact_every=3)
        for e in range(1, 9):
            agg.update(*updates(e))
            try:
                sp.spill(agg, e)
            except InjectedCrash:
                died_at = e                       # epoch e never published
                break
    assert died_at == 5
    # Restart: resume from the durable chain (epochs 1..4) and replay.
    sp2 = ex.ShardSpiller(str(tmp_path), 0, mode="delta", compact_every=3)
    agg2 = sp2.resumed
    assert agg2 is not None and agg2.k == 5
    for e in range(died_at, 9):
        agg2.update(*updates(e))
        sp2.spill(agg2, e)
    _assert_bitexact(agg2, ref)
    assert agg2.tail_folds == ref.tail_folds
    assert agg2.evictions == ref.evictions
    _assert_bitexact(ex.gather_shards(str(tmp_path)), ref)


# ---------------------------------------------------------------------------
# Device pipeline: admit-or-fold on the miss path.
# ---------------------------------------------------------------------------

def _pipeline_fixtures(w=2, steps=40):
    costs = [RegionCost("mem", flops=1e10, hbm_bytes=5e10, invocations=4),
             RegionCost("alu", flops=6e11, hbm_bytes=2e9, invocations=4),
             RegionCost("opt", flops=2e10, hbm_bytes=4e10, invocations=1)]
    tls = [synthesize(costs, steps=steps, seed=s) for s in range(w)]
    return dp.DeviceTimeline.from_timelines(tls), InstantTraceSensor.make_spec()


def test_combo_pipeline_k_ge_distinct_bitexact():
    dtl, spec = _pipeline_fixtures()
    kw = dict(period=10e-3, jitter=200e-6, seed=7, chunk_size=512)
    exact, n0 = dp.run_combo_pipeline(dtl, spec, **kw)
    stats: dict = {}
    bounded, n1 = dp.run_combo_pipeline(dtl, spec, max_combinations=4096,
                                        stats=stats, **kw)
    assert n0 == n1
    _assert_bitexact(bounded, exact)
    assert stats["tail_folds"] == 0 and bounded.tail_folds == 0
    assert bounded.k == 4096


def test_combo_pipeline_bounded_folds_tail_exactly():
    dtl, spec = _pipeline_fixtures()
    kw = dict(period=10e-3, jitter=200e-6, seed=7, chunk_size=512)
    exact, n0 = dp.run_combo_pipeline(dtl, spec, **kw)
    distinct = len(exact.interner)
    k = max(2, distinct // 3)
    stats: dict = {}
    bounded, n1 = dp.run_combo_pipeline(dtl, spec, max_combinations=k,
                                        stats=stats, **kw)
    assert n0 == n1
    assert bounded.resident <= k
    assert stats["tail_folds"] > 0
    assert stats["tail_folds"] == bounded.tail_folds
    r = dtl.num_regions
    ec, eps, epsq = _region_totals(exact, r)
    bc, bps, bpsq = _region_totals(bounded, r)
    assert np.array_equal(ec, bc)                 # counts: bit-exact
    np.testing.assert_allclose(bps, eps, rtol=1e-9)
    np.testing.assert_allclose(bpsq, epsq, rtol=1e-9)
    with pytest.raises(ValueError):
        dp.run_combo_pipeline(dtl, spec, max_combinations=0, **kw)


# ---------------------------------------------------------------------------
# Surfaces: TAIL disclosure, serve accountant, ServeReport.
# ---------------------------------------------------------------------------

def test_tail_disclosure_line_in_report():
    mat, pows = _stream(6, 400)
    names = [f"r{i}" for i in range(R)]
    bounded = StreamingCombinationAggregator(k=3).update(mat, pows)
    est, combos = bounded.estimates(1.0, names)
    assert est.tail is not None and est.tail["k"] == 3
    assert est.coverage["interner"]["resident"] <= 3
    txt = AttributionReport(est).table()
    assert "TAIL (bounded combinations, k=3)" in txt
    assert "per-region totals exact" in txt
    assert any("other" in str(name) for name in est.table.names)

    exact = StreamingCombinationAggregator().update(mat, pows)
    est2, _ = exact.estimates(1.0, names)
    assert est2.tail is None and est2.coverage is None
    assert "TAIL" not in AttributionReport(est2).table()


class _FakeSampler:
    def __init__(self):
        self.period = 2e-3
        self.elapsed = 0.0
        self.buffer_overruns = 0
        self.queue = []

    def drain(self):
        if self.queue:
            return self.queue.pop(0)
        return np.empty(0, np.int64), np.empty(0)


def test_accountant_max_combinations_bounds_request_table():
    rid = regions_mod.registry.intern("serve/decode")
    acct = PhaseEnergyAccountant(track_requests=True, max_combinations=3)
    acct.sampler = _FakeSampler()
    for i, req in enumerate(range(100, 108)):
        acct.sampler.queue.append((np.asarray([rid] * 4),
                                   np.asarray([float(64 + i)] * 4)))
        acct.sampler.elapsed = float(i + 1)
        acct.drain(active_requests=(req,))
    assert acct.request_agg.resident <= 3
    pressure = acct.attribution_pressure()
    assert pressure["k"] == 3 and pressure["tail_folds"] > 0
    per_phase = acct.request_phase_energy()
    assert -1 in per_phase                        # the folded tail bucket
    # The (identified + tail) request cells still partition the phase
    # total: bounding never loses or double-counts energy.
    est = acct.estimates()
    name = regions_mod.registry.names[rid]
    phase_total = float(est.table.e_hat[list(est.table.names).index(name)])
    split = sum(sum(d.values()) for d in per_phase.values())
    assert split == pytest.approx(phase_total)
    acct.shrink_tracking(2)
    assert acct.max_combinations == 2 and acct.request_agg.resident <= 2
    assert sum(sum(d.values())
               for d in acct.request_phase_energy().values()) == (
        pytest.approx(phase_total))


def test_serve_report_attribution_roundtrip():
    rep = ServeReport()
    assert "attribution" not in rep.coverage()
    rep.attribution = {"distinct": 9, "k": 4, "resident": 4,
                       "tail_folds": 5, "evictions": 2, "other_rows": 2,
                       "intern_misses": 9, "growth_events": 1}
    cov = rep.coverage()
    assert cov["attribution"]["k"] == 4
    back = ServeReport.from_json(json.loads(json.dumps(rep.to_json())))
    assert back.attribution == rep.attribution
    legacy = rep.to_json()
    del legacy["attribution"]
    assert ServeReport.from_json(legacy).attribution is None
