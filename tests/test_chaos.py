"""Chaos suite: deterministic fault injection across the fleet seams.

Every fault comes from a seeded :class:`repro.core.faults.FaultPlan`
(counter-keyed, no wall-clock randomness), so each scenario replays
bit-exactly. The invariants under test are the ROADMAP "Failure model"
contract: faults may lose recency or samples, but never merge corrupt
rows, never double-count, and never pass silently.
"""

import contextlib
import hashlib
import os
import time

import numpy as np
import pytest

from repro.core import exchange as ex
from repro.core import faults
from repro.core import regions as regions_mod
from repro.core.faults import (ChannelDropout, CorruptShardError, FaultPlan,
                               InjectedCrash, LeafFault, QuorumError,
                               SpillError, TornWriteError)
from repro.core.profiler import EnergyProfiler
from repro.core.sampler import HostSampler, RegionMarker, iter_sample_chunks
from repro.core.sensors import (FailoverTraceBank, HostSensorBank,
                                InstantTraceSensor, RaplTraceSensor)
from repro.core.streaming import StreamingAggregator
from repro.core.timeline import RegionCost, synthesize

pytestmark = pytest.mark.chaos

R = 12

COSTS = [
    RegionCost("matmul", flops=2.4e12, hbm_bytes=1.6e9, invocations=3),
    RegionCost("attn", flops=0.8e12, hbm_bytes=2.4e9, ici_bytes=1e8,
               invocations=2),
    RegionCost("embed", flops=1e10, hbm_bytes=3.2e9, invocations=1),
]


def _updates(host, epoch):
    rng = np.random.default_rng(5000 * host + epoch)
    return rng.integers(0, R, size=137), rng.uniform(40.0, 260.0, size=137)


def _ref_agg(host, upto):
    """Fault-free reference: the host's aggregator after epochs 1..upto."""
    agg = StreamingAggregator(R)
    for e in range(1, upto + 1):
        agg.update(*_updates(host, e))
    return agg


def _drive_fleet(root, hosts, epochs, plan=None):
    """Each host accumulates + spills per epoch under ``plan``.

    A host that draws an :class:`InjectedCrash` stops (it died); a
    transient :class:`SpillError` is ignored (the host keeps running
    without that epoch becoming durable). Returns {host: live agg}.
    """
    aggs = {}
    cm = faults.install(plan) if plan is not None else contextlib.nullcontext()
    with cm:
        for h in hosts:
            agg = StreamingAggregator(R)
            sp = ex.ShardSpiller(str(root), h, mode="delta",
                                 compact_every=16)
            aggs[h] = agg
            for e in range(1, epochs + 1):
                agg.update(*_updates(h, e))
                try:
                    sp.spill(agg, e)
                except InjectedCrash:
                    break
                except SpillError:
                    pass
    return aggs


def _tree_digest(root):
    """Stable digest of every file (relative path + bytes) under root."""
    h = hashlib.sha256()
    for dirpath, dirnames, filenames in sorted(os.walk(root)):
        dirnames.sort()
        for name in sorted(filenames):
            fp = os.path.join(dirpath, name)
            h.update(os.path.relpath(fp, root).encode())
            with open(fp, "rb") as f:
                h.update(f.read())
    return h.hexdigest()


def _assert_stats_equal(a, b):
    assert np.array_equal(a.counts, b.counts)
    assert np.array_equal(a.chan_psum, b.chan_psum)
    assert np.array_equal(a.chan_psumsq, b.chan_psumsq)


# ---------------------------------------------------------------------------
# FaultPlan: pure, seeded, replayable; the empty plan is a no-op.
# ---------------------------------------------------------------------------

def test_corrupt_bytes_deterministic_and_tmp_nonce_invariant():
    plan = FaultPlan(seed=3, leaf_faults=(
        LeafFault(match="epoch_000000002/arr_00000"),))
    data = bytes(range(256)) * 4
    path = "/x/host_0000/epoch_000000002/arr_00000.npy"
    a = plan.corrupt_bytes(path, data, "write")
    assert a == plan.corrupt_bytes(path, data, "write")   # replayable
    assert a != data and len(a) == len(data)              # one flipped bit
    # The write protocol's random tmp-dir nonce must not change which
    # byte is hit (else replays diverge between runs).
    tmp = "/x/host_0000/epoch_000000002.tmp-deadbeef/arr_00000.npy"
    assert plan.corrupt_bytes(tmp, data, "write") == a
    # Stage and match are exact filters.
    assert plan.corrupt_bytes(path, data, "read") is data
    assert plan.corrupt_bytes(
        "/x/host_0000/epoch_000000003/arr_00000.npy", data, "write") is data
    # Truncation is always strictly shorter.
    tplan = FaultPlan(seed=3, leaf_faults=(
        LeafFault(match="arr_00000", kind="truncate"),))
    assert len(tplan.corrupt_bytes(path, data, "write")) < len(data)


def test_empty_plan_is_byte_for_byte_noop():
    p = FaultPlan()
    data = b"anything"
    assert p.corrupt_bytes("/any/path", data, "write") is data
    assert p.corrupt_bytes("/any/path", data, "read") is data
    assert p.dropout_mask(("package", "hbm"), np.array([0.5])) is None
    assert not p.sampler_should_fail(10 ** 9)
    assert not p.crash_at(0, 1)
    assert not p.straggles(0, 1)
    assert not p.spill_fails(0, 1)


def test_leaf_fault_validation():
    with pytest.raises(ValueError, match="kind"):
        LeafFault(match="x", kind="scramble")
    with pytest.raises(ValueError, match="stage"):
        LeafFault(match="x", stage="mid-air")


# ---------------------------------------------------------------------------
# Typed failure hierarchy at the spill read path.
# ---------------------------------------------------------------------------

def test_disk_corruption_raises_typed_errors(tmp_path):
    agg = StreamingAggregator(R)
    agg.update(*_updates(0, 1))
    ex.spill_shard(str(tmp_path), 0, 1, agg)
    agg.update(*_updates(0, 2))
    ex.spill_shard(str(tmp_path), 0, 2, agg)
    leaf = os.path.join(str(tmp_path), "host_0000", "epoch_000000002",
                        "arr_00000.npy")
    orig = open(leaf, "rb").read()

    # Bit flip: bytes present but wrong → CorruptShardError.
    bad = bytearray(orig)
    bad[len(bad) // 2] ^= 0x40
    with open(leaf, "wb") as f:
        f.write(bytes(bad))
    with pytest.raises(CorruptShardError):
        ex.restore_shard(str(tmp_path), 0)

    # Truncation below the payload size → TornWriteError.
    with open(leaf, "wb") as f:
        f.write(orig[:4])
    with pytest.raises(TornWriteError):
        ex.restore_shard(str(tmp_path), 0)

    # Both are SpillError and IOError — legacy retry loops keep working.
    for err in (CorruptShardError, TornWriteError):
        assert issubclass(err, SpillError)
        assert issubclass(err, IOError)
    assert issubclass(ex.DeltaMismatchError, ValueError)  # spiller fallback
    assert issubclass(QuorumError, SpillError)
    assert not issubclass(InjectedCrash, SpillError)      # never caught


def test_strict_gather_refuses_unreadable_latest(tmp_path):
    """An unparseable LATEST must not silently shrink the fleet."""
    for h in (0, 1):
        agg = StreamingAggregator(R)
        agg.update(*_updates(h, 1))
        ex.spill_shard(str(tmp_path), h, 1, agg)
    with open(os.path.join(str(tmp_path), "host_0001", "LATEST"), "w") as f:
        f.write("not-an-epoch")
    with pytest.raises(CorruptShardError, match="LATEST"):
        ex.gather_shards(str(tmp_path))
    # The quorum path recovers the host from its durable epoch dirs.
    res = ex.gather_shards(str(tmp_path), quorum=ex.QuorumPolicy(
        backoff=0.0))
    by = {r.host_id: r for r in res.hosts}
    assert by[1].status == "degraded" and by[1].epoch == 1
    _assert_stats_equal(res.agg, ex.tree_reduce(
        [_ref_agg(0, 1), _ref_agg(1, 1)]))


# ---------------------------------------------------------------------------
# The acceptance scenario: 4-host gather under 1 crash, 1 corrupt epoch,
# 1 straggler, 1 sensor-channel dropout.
# ---------------------------------------------------------------------------

def test_quorum_gather_acceptance_scenario(tmp_path):
    plan = FaultPlan(
        seed=7,
        crashes=((1, 4),),                       # host 1 dies publishing 4
        leaf_faults=(                            # host 2's epoch 5 rots
            LeafFault(match="host_0002/epoch_000000005/arr"),),
        stragglers=((3, 2),),                    # host 3 stalls after 2
        dropouts=(ChannelDropout("hbm", 0.0, 1e9),),
    )
    _drive_fleet(tmp_path, [0, 1, 2, 3], 5, plan)

    res = ex.gather_shards(str(tmp_path), quorum=ex.QuorumPolicy(
        expected_hosts=(0, 1, 2, 3), min_hosts=2, min_epoch=3,
        backoff=0.0))
    by = {r.host_id: r for r in res.hosts}
    assert by[0].status == "merged" and by[0].epoch == 5
    assert by[1].status == "merged" and by[1].epoch == 3
    assert by[2].status == "degraded" and by[2].epoch == 4
    assert by[2].quarantined_epochs == (5,)
    assert by[2].requested_epoch == 5
    assert by[3].status == "stale" and by[3].epoch == 2
    assert not res.complete
    assert res.hosts_merged == (0, 1, 2, 3)
    assert res.hosts_degraded == (2,)
    assert res.hosts_stale == (3,)

    # Merged statistics are bit-exact to the same hosts' fault-free
    # shards at their effective epochs — no corrupt row leaked in.
    ref = ex.tree_reduce([_ref_agg(0, 5), _ref_agg(1, 3),
                          _ref_agg(2, 4), _ref_agg(3, 2)])
    _assert_stats_equal(res.agg, ref)

    # Provenance flows into the estimates and their report rendering.
    est = res.estimates(1.0, [f"r{i}" for i in range(R)])
    assert est.coverage is not None and not est.complete_coverage
    assert est.coverage["quarantined_epochs"] == {"2": [5]}
    from repro.core.attribution import AttributionReport
    assert "COVERAGE" in AttributionReport(est).table()

    # Same fleet, stricter policy: quorum failure is typed and loud.
    with pytest.raises(QuorumError):
        ex.gather_shards(str(tmp_path), quorum=ex.QuorumPolicy(
            expected_hosts=(0, 1, 2, 3, 7), min_hosts=5, backoff=0.0))

    # The same plan's sensor-channel dropout, at the trace-bank seam:
    # the hbm rail fails over to the (slower) fallback instrument.
    tl = synthesize(COSTS, steps=2, seed=3, domains=True)
    bank = FailoverTraceBank(
        InstantTraceSensor(tl),
        {"hbm": RaplTraceSensor(tl, update_period=1e-4)}, faults=plan)
    times = np.linspace(0.0, tl.t_exec, 64)[1:]
    pows = bank.read_rails(times)
    assert np.isfinite(pows).all()
    assert bank.failover_reads["hbm"] == len(times)


def test_fault_free_plan_reproduces_gather_byte_for_byte(tmp_path):
    a, b = tmp_path / "a", tmp_path / "b"
    _drive_fleet(a, [0, 1], 3, plan=None)
    _drive_fleet(b, [0, 1], 3, plan=FaultPlan())
    assert _tree_digest(a) == _tree_digest(b)
    ga = ex.gather_shards(str(a))
    gb = ex.gather_shards(str(b))
    _assert_stats_equal(ga, gb)
    # A full, fault-free quorum gather is bit-exact to the strict path.
    res = ex.gather_shards(str(b), quorum=ex.QuorumPolicy(backoff=0.0))
    assert res.complete
    assert res.coverage()["complete"]
    _assert_stats_equal(res.agg, ga)


def test_watermarks_pin_monotone_host_epochs(tmp_path):
    _drive_fleet(tmp_path, [0], 5)
    first = ex.gather_shards(str(tmp_path), quorum=ex.QuorumPolicy(
        backoff=0.0))
    assert first.host_epochs == {0: 5}
    # The tail rots after the first gather: epochs 4 and 5 get torn.
    hd = os.path.join(str(tmp_path), "host_0000")
    for e in (4, 5):
        leaf = os.path.join(hd, f"epoch_{e:09d}", "arr_00000.npy")
        with open(leaf, "wb") as f:
            f.write(b"\x00")
    res = ex.gather_shards(str(tmp_path), quorum=ex.QuorumPolicy(
        watermarks=first.host_epochs, backoff=0.0))
    by = {r.host_id: r for r in res.hosts}
    # The host folded back to epoch 3 — behind its own watermark, so it
    # can never silently regress: it is flagged stale (merged+disclosed).
    assert by[0].status == "stale" and by[0].epoch == 3
    _assert_stats_equal(res.agg, _ref_agg(0, 3))
    # drop_stale excludes it; with nothing else merged, quorum fails.
    with pytest.raises(QuorumError):
        ex.gather_shards(str(tmp_path), quorum=ex.QuorumPolicy(
            watermarks=first.host_epochs, drop_stale=True, backoff=0.0))


# ---------------------------------------------------------------------------
# HostSampler: thread death re-raised on the caller's thread (satellite 1).
# ---------------------------------------------------------------------------

class _ConstSensor:
    min_period = 0.0

    def __init__(self, v=42.0):
        self.v = v

    def read(self, t=None):
        return self.v


def _drain_until_raise(sampler, exc_type, deadline_s=10.0):
    deadline = time.monotonic() + deadline_s
    with pytest.raises(exc_type) as info:
        while True:
            time.sleep(2e-3)
            sampler.drain()
            assert time.monotonic() < deadline, \
                "sampler failure never surfaced at drain()"
    return info


def test_injected_sampler_fault_reraised_at_drain():
    plan = FaultPlan(sampler_fail_after=3)
    s = HostSampler(RegionMarker(), _ConstSensor(), period=1e-4,
                    jitter=0.0, faults=plan)
    with s:
        info = _drain_until_raise(s, RuntimeError)
    assert "injected sampler-thread fault" in str(info.value)


def test_real_sensor_exception_reraised_at_drain():
    class DyingSensor(_ConstSensor):
        n = 0

        def read(self, t=None):
            DyingSensor.n += 1
            if DyingSensor.n > 3:
                raise ZeroDivisionError("sensor bus died")
            return 1.0

    s = HostSampler(RegionMarker(), DyingSensor(), period=1e-4, jitter=0.0)
    with s:
        _drain_until_raise(s, ZeroDivisionError)
    # Each failure is raised exactly once — the session is then clean.
    s.drain()


def test_sampler_failure_surfaces_at_session_exit():
    plan = FaultPlan(sampler_fail_after=0)
    s = HostSampler(RegionMarker(), _ConstSensor(), period=1e-4,
                    jitter=0.0, faults=plan)
    with pytest.raises(RuntimeError, match="injected sampler-thread"):
        with s:
            time.sleep(50e-3)      # session never drains


def test_nonfinite_readings_dropped_and_counted():
    class NanSensor(_ConstSensor):
        def __init__(self):
            super().__init__()
            self.n = 0

        def read(self, t=None):
            self.n += 1
            return float("nan") if self.n % 2 else 1.0

    s = HostSampler(RegionMarker(), NanSensor(), period=1e-4, jitter=0.0)
    with s:
        time.sleep(50e-3)
    rids, pows = s.drain()
    assert s.dropped_samples > 0
    assert np.isfinite(pows).all()


# ---------------------------------------------------------------------------
# Sensor banks: per-channel dropout, failover, honest masking.
# ---------------------------------------------------------------------------

def test_failover_bank_substitutes_fallback_exactly_in_window():
    tl = synthesize(COSTS, steps=2, seed=2, domains=True)
    primary = InstantTraceSensor(tl)
    fb = RaplTraceSensor(tl, update_period=1e-4)
    t = tl.t_exec
    plan = FaultPlan(dropouts=(ChannelDropout("hbm", 0.25 * t, 0.5 * t),))
    bank = FailoverTraceBank(primary, {"hbm": fb}, faults=plan)
    times = np.linspace(0.0, t, 501)[1:]
    got = bank.read_rails(times)
    ref = np.array(primary.read_rails(times))
    in_w = (times >= 0.25 * t) & (times < 0.5 * t)
    j = tl.domain_names.index("hbm")
    assert np.array_equal(got[~in_w], ref[~in_w])     # untouched outside
    fb_col = np.asarray(fb.read_rails(times[in_w]))[:, j]
    assert np.array_equal(got[in_w, j], fb_col)       # substituted inside
    assert bank.failover_reads["hbm"] == int(in_w.sum())
    assert bank.masked_samples == 0
    # Period arbitration: the bank's floor covers the fallback.
    assert bank.effective_min_period() >= fb.min_period


def test_masked_channel_voids_samples_never_biases(tmp_path):
    tl = synthesize(COSTS, steps=2, seed=2, domains=True)
    t = tl.t_exec
    plan = FaultPlan(dropouts=(ChannelDropout("hbm", 0.2 * t, 0.6 * t),))

    def collect(p):
        bank = FailoverTraceBank(InstantTraceSensor(tl), faults=p)
        n = 0
        for rids, pows in iter_sample_chunks(tl, bank, period=1e-4,
                                             jitter=0.0, seed=5,
                                             chunk_size=4096):
            assert np.isfinite(pows).all()    # NaN rows voided, not folded
            n += len(rids)
        return n

    n_clean = collect(FaultPlan())
    n_masked = collect(plan)
    assert 0 < n_masked < n_clean             # fewer samples → wider CIs


def test_host_bank_failover_is_sticky():
    class FlakySensor(_ConstSensor):
        def __init__(self):
            super().__init__(5.0)
            self.n = 0

        def read(self, t=None):
            self.n += 1
            if self.n >= 2:
                raise IOError("powercap zone vanished")
            return self.v

    bank = HostSensorBank([("pkg", FlakySensor()), ("dram", FlakySensor())],
                          fallbacks={"pkg": _ConstSensor(7.0)})
    first = bank.read()
    assert first.tolist() == [5.0, 5.0]
    second = bank.read()
    assert second[0] == 7.0                   # failed over to fallback
    assert np.isnan(second[1])                # no fallback → masked
    third = bank.read()
    assert third[0] == 7.0                    # sticky, not retried
    assert np.isnan(third[1])
    assert bank.failover_events == {"pkg": 1, "dram": 1}


# ---------------------------------------------------------------------------
# PhaseEnergyAccountant: spill failures bounded-retried, drops counted.
# ---------------------------------------------------------------------------

def _busy(seconds):
    with regions_mod.region("chaos/serve"):
        t0 = time.monotonic()
        while time.monotonic() - t0 < seconds:
            pass


def test_accountant_retries_then_counts_drop(tmp_path):
    from repro.serve.engine import PhaseEnergyAccountant
    plan = FaultPlan(spill_failures=((0, 1), (0, 2), (0, 3)))
    acct = PhaseEnergyAccountant(period=1e-3, spill_dir=str(tmp_path),
                                 spill_every=1, spill_retries=3,
                                 faults=plan)
    with acct:
        for _ in range(4):
            _busy(2e-3)
            acct.drain()                      # epochs 1..4
    assert acct.spill_failures == 3           # epochs 1, 2, 3 each failed
    assert acct.spill_drops == 1              # retry budget exhausted once
    assert isinstance(acct.last_spill_error, SpillError)
    # The cumulative aggregator rode the next success: nothing lost.
    restored, epoch = ex.restore_shard(str(tmp_path), 0)
    assert epoch == acct._epoch
    assert np.array_equal(restored.counts, acct.agg.counts)
    assert np.array_equal(restored.chan_psum, acct.agg.chan_psum)


def test_accountant_exit_raises_when_it_cannot_publish(tmp_path):
    from repro.serve.engine import PhaseEnergyAccountant
    plan = FaultPlan(spill_failures=tuple((0, e) for e in range(1, 64)))
    acct = PhaseEnergyAccountant(period=1e-3, spill_dir=str(tmp_path),
                                 spill_every=0, spill_retries=2,
                                 faults=plan)
    with pytest.raises(SpillError):
        with acct:
            _busy(2e-3)
            acct.drain()
    assert acct.spill_failures >= 1           # loud, never a silent gap


def test_accountant_never_catches_injected_crash(tmp_path):
    from repro.serve.engine import PhaseEnergyAccountant
    plan = FaultPlan(crashes=((0, 1),))
    acct = PhaseEnergyAccountant(period=1e-3, spill_dir=str(tmp_path),
                                 spill_every=1, faults=plan)
    with pytest.raises(InjectedCrash):
        with acct:
            _busy(2e-3)
            acct.drain()
    assert acct.spill_failures == 0           # a crash is not an I/O retry
