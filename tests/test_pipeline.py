"""GPipe pipeline-parallel module: correctness vs sequential execution
(4-stage pipe mesh in a subprocess) + schedule math."""

import subprocess
import sys
import textwrap

import pytest

from repro.sharding.pipeline import bubble_fraction


def test_bubble_fraction():
    assert bubble_fraction(4, 4) == pytest.approx(3 / 7)
    assert bubble_fraction(1, 8) == 0.0
    assert bubble_fraction(4, 28) == pytest.approx(3 / 31)


_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import sys
    sys.path.insert(0, "src")
    import jax, jax.numpy as jnp
    import numpy as np
    from repro.sharding.pipeline import pipeline_forward
    from repro.launch.mesh import make_mesh_compat

    L, D, B, M = 8, 16, 12, 6
    key = jax.random.PRNGKey(0)
    w = 0.3 * jax.random.normal(key, (L, D, D), jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (B, D), jnp.float32)

    def layer(wi, h):
        return jnp.tanh(h @ wi)

    def stage_fn(ws, h):           # ws: [L/S, D, D]
        def body(h, wi):
            return layer(wi, h), None
        h, _ = jax.lax.scan(body, h, ws)
        return h

    # sequential reference
    ref = x
    for i in range(L):
        ref = layer(w[i], ref)

    mesh = make_mesh_compat((4,), ("pipe",))
    run = pipeline_forward(stage_fn, mesh, axis="pipe", n_micro=M)
    out = jax.jit(run)(w, x)
    err = float(jnp.max(jnp.abs(out - ref)))
    print("PIPEERR", err)
    assert err < 1e-5, err
    print("PIPEOK")
""")


@pytest.mark.slow
def test_pipeline_matches_sequential():
    res = subprocess.run([sys.executable, "-c", _SCRIPT],
                         capture_output=True, text=True, timeout=600,
                         cwd="/root/repo")
    assert res.returncode == 0, res.stderr[-2000:]
    assert "PIPEOK" in res.stdout, res.stdout
