"""Tier-1 serving-seam tests: queue-driven engine runs, step-clock
deadlines and energy budgets, typed drain timeouts, and the
per-request energy attribution path (deterministic — the accountant's
sampler is stubbed, so no timing dependence).
"""

import numpy as np
import pytest

import jax

from repro.configs.registry import get_config
from repro.core import regions as regions_mod
from repro.core.sampler import SampleBuffer
from repro.models import model as M
from repro.serve.engine import (Engine, PhaseEnergyAccountant,
                                PriceSignalUnavailableError, Request,
                                ServeConfig, ServeTimeoutError)

ARCH = "qwen3-1.7b"


@pytest.fixture(scope="module")
def arch_setup():
    cfg = get_config(ARCH).reduced()
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _prompt(cfg, n=5, seed=0):
    rng = np.random.default_rng(seed)
    return rng.integers(1, cfg.vocab_size, size=n).astype(np.int32)


# -- queue-driven engine -------------------------------------------------------

def test_submit_path_matches_direct_path(arch_setup):
    cfg, params = arch_setup
    scfg = ServeConfig(max_batch=2, max_len=48)
    reqs = lambda: [Request(i, _prompt(cfg, 4 + i, seed=i), max_new_tokens=4)
                    for i in range(3)]
    direct = Engine(cfg, params, scfg)
    ref = {r.rid: list(r.out_tokens)
           for r in direct.run_until_drained(reqs())}
    queued = Engine(cfg, params, scfg)
    for r in reqs():
        queued.submit(r)
    got = {r.rid: list(r.out_tokens) for r in queued.run_until_drained([])}
    assert got == ref
    assert queued.report.completed == 3


def test_deadline_abort_returns_partial_output(arch_setup):
    cfg, params = arch_setup
    eng = Engine(cfg, params, ServeConfig(max_batch=1, max_len=48))
    eng.submit(Request(0, _prompt(cfg), max_new_tokens=30, deadline=3))
    done = eng.run_until_drained([])
    (r,) = done
    assert r.status == "aborted_deadline" and not r.done
    assert 0 < len(r.out_tokens) <= 3          # partial, not silent loss
    rec = eng.report.request(0)
    assert rec.status == "aborted_deadline" and rec.error
    assert rec.tokens_out == len(r.out_tokens)


def test_energy_budget_abort_mid_decode(arch_setup):
    cfg, params = arch_setup
    scfg = ServeConfig(max_batch=1, max_len=48, step_energy=1.0)
    prompt = _prompt(cfg, 4)
    eng = Engine(cfg, params, scfg)
    # Budget covers prefill (4 J) + 2 decode steps; the 3rd decode
    # charge crosses it and the request leaves with 3 partial tokens.
    eng.submit(Request(0, prompt, max_new_tokens=30, energy_budget=6.0))
    (r,) = eng.run_until_drained([])
    assert r.status == "aborted_budget" and not r.done
    assert len(r.out_tokens) == 3
    assert r.energy_j == pytest.approx(7.0)    # the violating charge
    assert eng.report.aborted_budget == 1


def test_run_until_drained_timeout_is_typed(arch_setup):
    cfg, params = arch_setup
    eng = Engine(cfg, params, ServeConfig(max_batch=1, max_len=64))
    reqs = [Request(i, _prompt(cfg, 3, seed=i), max_new_tokens=40)
            for i in range(3)]
    with pytest.raises(ServeTimeoutError) as ei:
        eng.run_until_drained(reqs, max_steps=5)
    # Every abandoned request is named: the in-flight one plus the
    # ones still pending — never a silent partial return.
    assert set(ei.value.undrained) == {0, 1, 2}


# -- per-request attribution (deterministic: stubbed sampler) -----------------

class _FakeSampler:
    def __init__(self):
        self.period = 2e-3
        self.elapsed = 0.0
        self.buffer_overruns = 0
        self.queue = []

    def drain(self):
        if self.queue:
            return self.queue.pop(0)
        return np.empty(0, np.int64), np.empty(0)


def _acct_with_fake():
    acct = PhaseEnergyAccountant(track_requests=True)
    acct.sampler = _FakeSampler()
    return acct


def test_request_energy_split_partitions_samples():
    rid = regions_mod.registry.intern("serve/decode")
    acct = _acct_with_fake()
    # Epoch 1: one sample at 100 W while requests 1 and 2 are in flight.
    acct.sampler.queue.append((np.asarray([rid]), np.asarray([100.0])))
    acct.sampler.elapsed = 1.0
    acct.drain(active_requests=(1, 2))
    # Epoch 2: one sample at 200 W, only request 2 remains.
    acct.sampler.queue.append((np.asarray([rid]), np.asarray([200.0])))
    acct.sampler.elapsed = 2.0
    acct.drain(active_requests=(2,))
    assert acct.request_energy() == pytest.approx({1: 50.0, 2: 250.0})
    per_phase = acct.request_phase_energy()
    name = regions_mod.registry.names[rid]
    assert per_phase[1][name] == pytest.approx(50.0)
    assert per_phase[2][name] == pytest.approx(250.0)
    # Per-request cells partition the phase total: no double count.
    est = acct.estimates()
    phase_total = float(est.table.e_hat[list(est.table.names).index(name)])
    assert sum(sum(d.values()) for d in per_phase.values()) == (
        pytest.approx(phase_total))


def test_take_request_charges_consumes_delta():
    rid = regions_mod.registry.intern("serve/decode")
    acct = _acct_with_fake()
    acct.sampler.queue.append((np.asarray([rid]), np.asarray([10.0])))
    acct.sampler.elapsed = 1.0
    acct.drain(active_requests=(7,))
    assert acct.take_request_charges() == pytest.approx({7: 10.0})
    assert acct.take_request_charges() == {}     # consumed
    assert acct.request_energy() == pytest.approx({7: 10.0})  # cumulative


def test_scale_period_is_idempotent_from_base():
    acct = _acct_with_fake()
    base = acct.sampler.period
    acct.scale_period(4.0)
    acct.scale_period(4.0)                       # does not compound
    assert acct.sampler.period == pytest.approx(base * 4.0)
    acct.reset_period()
    assert acct.sampler.period == pytest.approx(base)


# -- live J/token price signal (typed-error quote path; stubbed sampler) ------

def _jpt_engine(arch_setup, acct=None, max_new=4):
    """Engine that has emitted tokens (so only the sample-side ladder of
    the quote's typed errors remains)."""
    cfg, params = arch_setup
    eng = Engine(cfg, params, ServeConfig(max_batch=1, max_len=48),
                 accountant=acct)
    eng.run_until_drained(
        [Request(0, _prompt(cfg), max_new_tokens=max_new)])
    assert eng._tokens_emitted > 0
    return eng


def _drain_mix(acct, n_decode=30, n_other=30, elapsed=2.0):
    """Drain a deterministic sample mix: n_decode serve/decode samples
    at 100 W against n_other elsewhere, over `elapsed` seconds."""
    rid = regions_mod.registry.intern("serve/decode")
    other = regions_mod.registry.intern("serve/prefill")
    rids = np.asarray([rid] * n_decode + [other] * n_other)
    acct.sampler.queue.append((rids, np.full(len(rids), 100.0)))
    acct.sampler.elapsed = elapsed
    acct.drain()


def test_jpt_requires_accountant(arch_setup):
    cfg, params = arch_setup
    eng = Engine(cfg, params, ServeConfig(max_batch=1, max_len=48))
    with pytest.raises(PriceSignalUnavailableError, match="accountant"):
        eng.current_joules_per_token()


def test_jpt_requires_emitted_tokens(arch_setup):
    cfg, params = arch_setup
    eng = Engine(cfg, params, ServeConfig(max_batch=1, max_len=48),
                 accountant=_acct_with_fake())
    with pytest.raises(PriceSignalUnavailableError, match="no tokens"):
        eng.current_joules_per_token()


def test_jpt_requires_drained_samples(arch_setup):
    eng = _jpt_engine(arch_setup, _acct_with_fake())
    with pytest.raises(PriceSignalUnavailableError, match="no samples"):
        eng.current_joules_per_token()


def test_jpt_requires_decode_phase_samples(arch_setup):
    eng = _jpt_engine(arch_setup, _acct_with_fake())
    _drain_mix(eng.accountant, n_decode=0, n_other=30)
    with pytest.raises(PriceSignalUnavailableError, match="decode-phase"):
        eng.current_joules_per_token()


def test_jpt_wald_normality_guard_blocks_quote(arch_setup):
    # Only serve/decode samples: p-hat == 1 so n*(1-p) == 0 — the Wald
    # guard fails and the quote is a typed reject, not a degenerate CI.
    eng = _jpt_engine(arch_setup, _acct_with_fake())
    _drain_mix(eng.accountant, n_decode=30, n_other=0)
    with pytest.raises(PriceSignalUnavailableError, match="normality"):
        eng.current_joules_per_token()


def test_jpt_ci_width_gate(arch_setup):
    eng = _jpt_engine(arch_setup, _acct_with_fake())
    _drain_mix(eng.accountant)
    with pytest.raises(PriceSignalUnavailableError, match="too wide"):
        eng.current_joules_per_token(max_rel_halfwidth=0.0)


def test_jpt_quote_brackets_estimate(arch_setup):
    eng = _jpt_engine(arch_setup, _acct_with_fake())
    _drain_mix(eng.accountant)
    q = eng.current_joules_per_token()
    assert q.tokens == eng._tokens_emitted > 0
    assert q.lo <= q.j_per_token <= q.hi
    assert q.energy_j > 0.0
    assert set(q.phases) <= {"serve/decode", "serve/draft", "serve/verify"}
    assert q.j_per_token == pytest.approx(q.energy_j / q.tokens)
    # p-hat = 0.5 of 2 s at a constant 100 W: 100 J in the decode phase.
    assert q.energy_j == pytest.approx(100.0)


def test_jpt_domain_must_be_measured(arch_setup):
    eng = _jpt_engine(arch_setup, _acct_with_fake())
    _drain_mix(eng.accountant)
    with pytest.raises(PriceSignalUnavailableError, match="not measured"):
        eng.current_joules_per_token(domain="hbm")


# -- bounded sample ring (satellite: overruns counted, never silent) ----------

def test_sample_buffer_bounded_growth_counts_drops():
    buf = SampleBuffer(capacity=16, max_capacity=20)
    for i in range(30):
        buf.append(i % 3, 1.0)
    assert buf.overruns == 10                    # 20 kept, 10 dropped
    rids, pows = buf.drain()
    assert len(rids) == 20
    assert buf.overruns == 10                    # counter survives drain
    buf.append(0, 1.0)                           # room again after drain
    assert buf.overruns == 10


def test_sample_buffer_unbounded_never_drops():
    buf = SampleBuffer(capacity=4)
    for i in range(100):
        buf.append(0, 1.0)
    assert buf.overruns == 0
    assert len(buf.drain()[0]) == 100
