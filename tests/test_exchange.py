"""Cross-host shard exchange: spill/restore round trips (killed hosts,
partial .tmp- dirs ignored), lazy interner dedup at merge vs single-host,
collective path == checkpointed path, and the profiler/serve wiring."""

import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core import exchange as ex
from repro.core.estimator import estimate_combinations
from repro.core.profiler import EnergyProfiler
from repro.core.streaming import (StreamingAggregator,
                                  StreamingCombinationAggregator)
from repro.core.timeline import RegionCost, synthesize


def _dyadic_stream(n, R, seed, width=None):
    """(ids-or-matrix, powers) with powers exactly representable (k/64),
    so sums are bit-exact under any association order."""
    rng = np.random.default_rng(seed)
    pows = rng.integers(50 * 64, 200 * 64, n) / 64.0
    if width is None:
        return rng.integers(0, R, n).astype(np.int64), pows
    return rng.integers(0, R, (n, width)).astype(np.int64), pows


def _table_equal(a, b):
    assert a.names == b.names
    for col in ("region_ids", "n_samples", "p_hat", "t_hat", "t_lo", "t_hi",
                "pow_hat", "pow_lo", "pow_hi", "e_hat", "e_lo", "e_hi",
                "ci_valid"):
        assert np.array_equal(getattr(a, col), getattr(b, col)), col


# ---------------------------------------------------------------------------
# Packed wire format
# ---------------------------------------------------------------------------

def test_pack_unpack_roundtrip_region():
    ids, pows = _dyadic_stream(5000, 17, 0)
    agg = StreamingAggregator(17).update(ids, pows)
    back = ex.unpack_shard(ex.pack_shard(agg, capacity=32))
    assert back.num_regions == 17
    assert np.array_equal(back.counts, agg.counts)
    assert np.array_equal(back.psum, agg.psum)
    assert np.array_equal(back.psumsq, agg.psumsq)


def test_pack_unpack_roundtrip_combination():
    mat, pows = _dyadic_stream(3000, 5, 1, width=3)
    cagg = StreamingCombinationAggregator().update(mat, pows)
    back = ex.unpack_shard(ex.pack_shard(cagg, capacity=256))
    assert back.interner.combos == cagg.interner.combos
    assert np.array_equal(back.agg.counts, cagg.agg.counts)
    assert np.array_equal(back.agg.psum, cagg.agg.psum)


def test_pack_capacity_too_small_raises():
    agg = StreamingAggregator(8)
    with pytest.raises(ValueError):
        ex.pack_shard(agg, capacity=4)


# ---------------------------------------------------------------------------
# Checkpointed path: spill / restore / gather
# ---------------------------------------------------------------------------

def _host_shards(n_hosts, n=4000, R=6, width=2):
    """Disjoint per-host chunks of one logical stream + the full stream."""
    mats, powss = [], []
    for h in range(n_hosts):
        m, p = _dyadic_stream(n, R, seed=100 + h, width=width)
        mats.append(m)
        powss.append(p)
    shards = [StreamingCombinationAggregator().update(m, p)
              for m, p in zip(mats, powss)]
    single = StreamingCombinationAggregator()
    for m, p in zip(mats, powss):
        single.update(m, p)
    return shards, single, np.concatenate(mats), np.concatenate(powss)


def test_gather_matches_single_host_bit_exact(tmp_path):
    """3-host spill + tree-reduce gather == one aggregator over the
    concatenated stream: same lazily-deduped ids, bit-identical stats."""
    shards, single, all_mat, all_pows = _host_shards(3)
    for h, s in enumerate(shards):
        ex.spill_shard(str(tmp_path), h, epoch=1, agg=s)
    merged = ex.gather_shards(str(tmp_path))
    assert merged.interner.combos == single.interner.combos
    assert np.array_equal(merged.agg.counts, single.agg.counts)
    assert np.array_equal(merged.agg.psum, single.agg.psum)
    assert np.array_equal(merged.agg.psumsq, single.agg.psumsq)

    names = [f"r{i}" for i in range(6)]
    est_m, combos_m = merged.estimates(2.0, names)
    est_s, combos_s = single.estimates(2.0, names)
    assert combos_m == combos_s
    _table_equal(est_m.table, est_s.table)

    # and against the one-shot np.unique path (different id order):
    # identical rows after aligning by combination name.
    est_o, _ = estimate_combinations(all_mat, all_pows, 2.0, names)
    by_name_m = {est_m.table.names[i]: i for i in range(len(est_m.table))}
    for j, nm in enumerate(est_o.table.names):
        i = by_name_m[nm]
        assert est_m.table.n_samples[i] == est_o.table.n_samples[j]
        assert est_m.table.e_hat[i] == est_o.table.e_hat[j]
        assert est_m.table.pow_hat[i] == est_o.table.pow_hat[j]


def test_gather_ignores_killed_host_partial_tmp(tmp_path):
    """A host that died mid-spill leaves only .tmp- litter: invisible."""
    shards, _, _, _ = _host_shards(2)
    for h, s in enumerate(shards):
        ex.spill_shard(str(tmp_path), h, epoch=1, agg=s)
    # host 2 crashed mid-write: partial tmp dir, no LATEST.
    dead = tmp_path / "host_0002" / "epoch_000000001.tmp-deadbeef"
    dead.mkdir(parents=True)
    (dead / "arr_00000.npy").write_bytes(b"\x93NUMPY partial garbage")
    # host 0 also has tmp litter next to its published epoch.
    lit = tmp_path / "host_0000" / "epoch_000000002.tmp-cafef00d"
    lit.mkdir()
    assert ex.list_spilled_hosts(str(tmp_path)) == [0, 1]
    merged = ex.gather_shards(str(tmp_path))
    ref = StreamingCombinationAggregator()
    ref.merge(shards[0]).merge(shards[1])
    assert np.array_equal(merged.agg.counts, ref.agg.counts)


def test_restore_shard_resume_and_restart_mid_run(tmp_path):
    """Acceptance: one host dies after a spill, restarts from its LATEST,
    replays its remaining chunks — gather is bit-exact vs single-host."""
    shards, single, _, _ = _host_shards(3)
    # hosts 0 and 2 complete normally
    ex.spill_shard(str(tmp_path), 0, epoch=1, agg=shards[0])
    ex.spill_shard(str(tmp_path), 2, epoch=1, agg=shards[2])

    # host 1 processes its stream in two halves, spills after the first,
    # then dies (in-memory aggregator lost).
    mat, pows = _dyadic_stream(4000, 6, seed=101, width=2)
    half = 2000
    first = StreamingCombinationAggregator().update(mat[:half], pows[:half])
    ex.spill_shard(str(tmp_path), 1, epoch=1, agg=first)
    del first

    # restart: resume from LATEST, replay the unspilled half, re-spill.
    resumed, epoch = ex.restore_shard(str(tmp_path), 1)
    assert epoch == 1
    resumed.update(mat[half:], pows[half:])
    ex.spill_shard(str(tmp_path), 1, epoch=2, agg=resumed)

    merged = ex.gather_shards(str(tmp_path))
    assert merged.interner.combos == single.interner.combos
    assert np.array_equal(merged.agg.counts, single.agg.counts)
    assert np.array_equal(merged.agg.psum, single.agg.psum)
    assert np.array_equal(merged.agg.psumsq, single.agg.psumsq)


def test_list_spilled_hosts_large_ids_numeric_order(tmp_path):
    """Ids >= 10000 exceed the :04d zero-pad; they must still publish,
    gather, and sort numerically (not lexicographically)."""
    ids, pows = _dyadic_stream(200, 3, 0)
    for h in (10000, 2, 9999):
        ex.spill_shard(str(tmp_path), h, epoch=1,
                       agg=StreamingAggregator(3).update(ids, pows))
    assert ex.list_spilled_hosts(str(tmp_path)) == [2, 9999, 10000]
    merged = ex.gather_shards(str(tmp_path))
    assert merged.n_total == 3 * 200


def test_restore_shard_absent_host(tmp_path):
    assert ex.restore_shard(str(tmp_path), 7) is None
    with pytest.raises(FileNotFoundError):
        ex.gather_shards(str(tmp_path / "nothing"))


def test_spill_gather_region_shards(tmp_path):
    """Plain per-region shards (serve accountant format) round-trip too,
    across hosts with different region counts."""
    aggs, ref = [], StreamingAggregator(9)
    for h, R in enumerate((5, 9, 7)):
        ids, pows = _dyadic_stream(3000, R, seed=h)
        a = StreamingAggregator(R).update(ids, pows)
        ex.spill_shard(str(tmp_path), h, epoch=1, agg=a)
        ref.merge(a)
    merged = ex.gather_shards(str(tmp_path))
    assert np.array_equal(merged.counts, ref.counts)
    assert np.array_equal(merged.psum, ref.psum)
    assert np.array_equal(merged.psumsq, ref.psumsq)


# ---------------------------------------------------------------------------
# Collective path
# ---------------------------------------------------------------------------

def test_collective_reduce_single_device_identity():
    mat, pows = _dyadic_stream(2000, 4, 3, width=2)
    cagg = StreamingCombinationAggregator().update(mat, pows)
    merged = ex.collective_reduce([cagg])
    assert merged.interner.combos == cagg.interner.combos
    assert np.array_equal(merged.agg.counts, cagg.agg.counts)
    assert np.array_equal(merged.agg.psum, cagg.agg.psum)

    ids, pows = _dyadic_stream(2000, 11, 4)
    agg = StreamingAggregator(11).update(ids, pows)
    m2 = ex.collective_reduce([agg])
    assert np.array_equal(m2.counts, agg.counts)
    assert np.array_equal(m2.psumsq, agg.psumsq)


_COLLECTIVE_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import sys
    sys.path.insert(0, "src")
    import numpy as np
    from repro.core import exchange as ex
    from repro.core.streaming import (StreamingAggregator,
                                      StreamingCombinationAggregator)

    rng = np.random.default_rng(0)
    def dyadic(n):
        return rng.integers(50 * 64, 200 * 64, n) / 64.0

    # 4 combination shards (host 2 idle: saw no traffic, width-0 key
    # table): collective all-gather+merge == checkpointed spill+gather
    # on the SAME shards, bit-exact.
    cshards = []
    for h in range(4):
        c = StreamingCombinationAggregator()
        if h != 2:
            m = rng.integers(0, 5, (1500, 2)).astype(np.int64)
            c.update(m, dyadic(1500))
        cshards.append(c)
    coll = ex.collective_reduce(cshards)

    d = "/tmp/exchange_collective_vs_ckpt"
    import shutil; shutil.rmtree(d, ignore_errors=True)
    for h, s in enumerate(cshards):
        ex.spill_shard(d, h, epoch=1, agg=s)
    ckpt = ex.gather_shards(d)

    assert coll.interner.combos == ckpt.interner.combos
    assert np.array_equal(coll.agg.counts, ckpt.agg.counts)
    assert np.array_equal(coll.agg.psum, ckpt.agg.psum)
    assert np.array_equal(coll.agg.psumsq, ckpt.agg.psumsq)
    print("COMBOK", len(coll.interner))

    # 4 plain region shards (ragged R): psum all-reduce == in-process merge.
    shards, ref = [], StreamingAggregator(8)
    for h, R in enumerate((8, 5, 8, 3)):
        ids = rng.integers(0, R, 2000).astype(np.int64)
        a = StreamingAggregator(R).update(ids, dyadic(2000))
        shards.append(a); ref.merge(a)
    coll2 = ex.collective_reduce(shards)
    assert np.array_equal(coll2.counts, ref.counts)
    assert np.array_equal(coll2.psum, ref.psum)
    assert np.array_equal(coll2.psumsq, ref.psumsq)
    print("REGIONOK")
""")


@pytest.mark.slow
def test_collective_equals_checkpointed_4hosts():
    """4 fake hosts on a 4-device mesh: collective == checkpointed."""
    res = subprocess.run([sys.executable, "-c", _COLLECTIVE_SCRIPT],
                         capture_output=True, text=True, timeout=600,
                         cwd="/root/repo")
    assert res.returncode == 0, res.stderr[-3000:]
    assert "COMBOK" in res.stdout and "REGIONOK" in res.stdout


# ---------------------------------------------------------------------------
# Profiler / serve wiring
# ---------------------------------------------------------------------------

def _timelines():
    costs = [RegionCost("mem", flops=1e10, hbm_bytes=5e10, invocations=4),
             RegionCost("alu", flops=6e11, hbm_bytes=2e9, invocations=4)]
    return [synthesize(costs, steps=80, seed=s) for s in (0, 1)]


def test_profiler_checkpoint_exchange_single_host(tmp_path):
    tls = _timelines()
    prof = EnergyProfiler(period=10e-3)
    est_ref, combos_ref = prof.profile_multiworker_streaming(
        tls, sensor="instant", chunk_size=256)
    est_ex, combos_ex = prof.profile_multiworker_streaming(
        tls, sensor="instant", chunk_size=256,
        exchange=ex.CheckpointExchange(str(tmp_path), host_id=0))
    assert combos_ex == combos_ref
    _table_equal(est_ex.table, est_ref.table)
    # the final shard was published durably
    assert ex.list_spilled_hosts(str(tmp_path)) == [0]

    # restart idempotency: a re-run against the same spill dir regenerates
    # the same deterministic stream and republishes — it must NOT merge
    # its own previous spill on top (that would double every count).
    est_again, _ = prof.profile_multiworker_streaming(
        tls, sensor="instant", chunk_size=256,
        exchange=ex.CheckpointExchange(str(tmp_path), host_id=0))
    assert est_again.n_total == est_ref.n_total
    _table_equal(est_again.table, est_ref.table)


def test_profiler_collective_exchange_single_host():
    tls = _timelines()
    prof = EnergyProfiler(period=10e-3)
    est_ref, combos_ref = prof.profile_multiworker_streaming(
        tls, sensor="instant", chunk_size=256)
    est_ex, combos_ex = prof.profile_multiworker_streaming(
        tls, sensor="instant", chunk_size=256,
        exchange=ex.CollectiveExchange())
    assert combos_ex == combos_ref
    _table_equal(est_ex.table, est_ref.table)


def test_accountant_periodic_spill(tmp_path):
    """PhaseEnergyAccountant publishes its shard every spill_every drains
    and once on exit; gather_estimates sees the fleet."""
    import time

    from repro.core import regions as regions_mod
    from repro.serve.engine import PhaseEnergyAccountant

    acct = PhaseEnergyAccountant(period=1e-3, jitter=1e-4,
                                 spill_dir=str(tmp_path), host_id=3,
                                 spill_every=5)
    with acct:
        for _ in range(12):
            with regions_mod.region("serve/busy"):
                t0 = time.monotonic()
                while time.monotonic() - t0 < 2e-3:
                    pass
            acct.drain()
    assert ex.list_spilled_hosts(str(tmp_path)) == [3]
    restored, epoch = ex.restore_shard(str(tmp_path), 3)
    assert epoch >= 10   # periodic spills happened, not just the exit one
    assert np.array_equal(restored.counts[:acct.agg.num_regions]
                          [:restored.num_regions],
                          acct.agg.counts[:restored.num_regions])
    if acct.agg.n_total:
        est = PhaseEnergyAccountant.gather_estimates(
            str(tmp_path), acct.sampler.elapsed)
        assert est.n_total == acct.agg.n_total

    # restart-and-rejoin: a new accountant on the same spill dir resumes
    # from LATEST (pre-crash samples survive, epochs keep counting up,
    # pre-crash wall time is carried) instead of republishing a fresh
    # empty shard over it.
    acct2 = PhaseEnergyAccountant(period=1e-3, jitter=1e-4,
                                  spill_dir=str(tmp_path), host_id=3,
                                  spill_every=5)
    assert acct2.agg.n_total == acct.agg.n_total
    assert acct2._epoch == epoch
    assert np.array_equal(acct2.agg.counts[:restored.num_regions],
                          restored.counts)
    assert acct2._elapsed_offset == pytest.approx(acct.sampler.elapsed)
