"""Per-kernel allclose validation vs pure-jnp oracles (interpret mode),
with shape/dtype sweeps (explicit grids + hypothesis)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # degrade gracefully: deterministic fixed-seed draws
    from _hypothesis_fallback import given, settings, st

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.rmsnorm.ops import rmsnorm
from repro.kernels.rmsnorm.ref import rmsnorm_ref
from repro.kernels.sample_attr.ops import as_aggregate_fn, sample_attr
from repro.kernels.sample_attr.ref import sample_attr_ref
from repro.core.estimator import aggregate_samples_np, estimate_regions


# ---------------------------------------------------------------------------
# sample_attr
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,R", [(16, 3), (1000, 7), (4096, 128),
                                 (5000, 37), (100, 1)])
def test_sample_attr_shapes(n, R):
    rng = np.random.default_rng(n + R)
    ids = rng.integers(0, R, n).astype(np.int32)
    pw = (rng.random(n) * 200).astype(np.float32)
    c, s, sq = sample_attr(jnp.asarray(ids), jnp.asarray(pw), R)
    cr, sr, sqr = sample_attr_ref(jnp.asarray(ids), jnp.asarray(pw), R)
    np.testing.assert_allclose(c, cr, rtol=1e-6)
    np.testing.assert_allclose(s, sr, rtol=1e-5)
    np.testing.assert_allclose(sq, sqr, rtol=1e-5)


@given(n=st.integers(1, 3000), r=st.integers(1, 64),
       seed=st.integers(0, 999))
@settings(max_examples=12, deadline=None)
def test_sample_attr_property(n, r, seed):
    rng = np.random.default_rng(seed)
    ids = rng.integers(0, r, n).astype(np.int32)
    pw = (rng.random(n) * 100).astype(np.float32)
    c, s, _ = sample_attr(jnp.asarray(ids), jnp.asarray(pw), r)
    counts, psum, _ = aggregate_samples_np(ids, pw, r)
    np.testing.assert_allclose(np.asarray(c), counts, rtol=1e-6)
    np.testing.assert_allclose(np.asarray(s), psum, rtol=2e-5)


def test_sample_attr_plugs_into_estimator():
    """The kernel is a drop-in aggregate_fn for the ALEA estimator."""
    rng = np.random.default_rng(3)
    ids = rng.integers(0, 4, 20000).astype(np.int32)
    pw = 100 + 10 * rng.random(20000)
    est_np = estimate_regions(ids, pw, 10.0, ["a", "b", "c", "d"])
    est_k = estimate_regions(ids, pw, 10.0, ["a", "b", "c", "d"],
                             aggregate_fn=as_aggregate_fn(interpret=True))
    for r1, r2 in zip(est_np.regions, est_k.regions):
        assert r1.n_samples == r2.n_samples
        # kernel accumulates fp32 (vs numpy fp64) → ~1e-6 relative drift
        assert r1.e_hat == pytest.approx(r2.e_hat, rel=1e-5)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,H,S,dh,causal", [
    (2, 4, 256, 64, True),
    (1, 2, 512, 128, True),
    (2, 2, 128, 64, False),
    (1, 1, 384, 128, True),     # non-pow2 block count
])
def test_flash_attention_shapes(B, H, S, dh, causal):
    rng = np.random.default_rng(S)
    q = jnp.asarray(rng.standard_normal((B, H, S, dh)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, H, S, dh)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, H, S, dh)), jnp.float32)
    out = flash_attention(q, k, v, causal=causal, block_q=128, block_kv=128,
                          interpret=True)
    ref = attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(out, ref, atol=2e-5, rtol=1e-4)


@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 2e-5),
                                       (jnp.bfloat16, 2e-2)])
def test_flash_attention_dtypes(dtype, tol):
    rng = np.random.default_rng(9)
    q = jnp.asarray(rng.standard_normal((1, 2, 256, 64)), dtype)
    k = jnp.asarray(rng.standard_normal((1, 2, 256, 64)), dtype)
    v = jnp.asarray(rng.standard_normal((1, 2, 256, 64)), dtype)
    out = flash_attention(q, k, v, causal=True, block_q=128, block_kv=128,
                          interpret=True)
    ref = attention_ref(q, k, v, causal=True)
    err = float(jnp.max(jnp.abs(out.astype(jnp.float32)
                                - ref.astype(jnp.float32))))
    assert err < tol


def test_flash_attention_matches_model_attention():
    """Kernel agrees with the model's chunked-jnp attention path."""
    from repro.configs.registry import get_config
    from repro.models import attention as A
    from repro.models import model as M
    cfg = get_config("yi-6b").reduced().replace(compute_dtype="float32")
    key = jax.random.PRNGKey(0)
    p = A.attention_init(key, cfg)
    x = 0.1 * jax.random.normal(key, (2, 256, cfg.d_model), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(256)[None], (2, 256))
    y_full = A.attention(p, cfg, x, pos, impl="full")
    y_pallas = A.attention(p, cfg, x, pos, impl="pallas")
    np.testing.assert_allclose(y_full, y_pallas, atol=2e-4, rtol=1e-3)


# ---------------------------------------------------------------------------
# rmsnorm
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,d", [(64, 128), (100, 100), (513, 768),
                                 (7, 4096), (1, 33)])
def test_rmsnorm_shapes(n, d):
    rng = np.random.default_rng(n * d)
    x = jnp.asarray(rng.standard_normal((n, d)), jnp.float32)
    s = jnp.asarray(rng.standard_normal((d,)), jnp.float32)
    out = rmsnorm(x, s, interpret=True)
    ref = rmsnorm_ref(x, s)
    np.testing.assert_allclose(out, ref, atol=1e-5, rtol=1e-5)


@given(n=st.integers(1, 300), d=st.integers(1, 512),
       seed=st.integers(0, 99))
@settings(max_examples=10, deadline=None)
def test_rmsnorm_property(n, d, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((n, d)), jnp.float32)
    s = jnp.asarray(rng.standard_normal((d,)), jnp.float32)
    np.testing.assert_allclose(rmsnorm(x, s, interpret=True),
                               rmsnorm_ref(x, s), atol=1e-5, rtol=1e-5)


def test_rmsnorm_bf16():
    rng = np.random.default_rng(5)
    x = jnp.asarray(rng.standard_normal((128, 256)), jnp.bfloat16)
    s = jnp.ones((256,), jnp.float32)
    out = rmsnorm(x, s, interpret=True)
    ref = rmsnorm_ref(x, s)
    assert out.dtype == jnp.bfloat16
    err = float(jnp.max(jnp.abs(out.astype(jnp.float32)
                                - ref.astype(jnp.float32))))
    assert err < 2e-2
