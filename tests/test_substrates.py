"""Substrate tests: optimizer, data pipeline, checkpointing, compression,
trainer fault tolerance, serving engine."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import ckpt as ckpt_mod
from repro.configs.registry import get_config
from repro.data.pipeline import MemmapTokens, Prefetcher, SyntheticTokens
from repro.models import model as M
from repro.optim.adamw import (AdamWConfig, adamw_init, adamw_update,
                               clip_by_global_norm, cosine_schedule,
                               global_norm)
from repro.optim.compression import (compress_decompress, compress_init,
                                     dequantize_int8, quantize_int8)
from repro.train.step import init_state, make_train_step
from repro.train.trainer import Trainer, TrainerConfig


# -- optimizer ----------------------------------------------------------------

def test_adamw_matches_reference_math():
    """One step against a hand-rolled numpy AdamW."""
    cfg = AdamWConfig(lr=1e-2, b1=0.9, b2=0.99, eps=1e-8, weight_decay=0.1,
                      grad_clip=1e9, warmup_steps=0, total_steps=10,
                      min_lr_ratio=1.0)
    p = {"w": jnp.array([[1.0, -2.0], [0.5, 3.0]]), "b": jnp.array([0.1])}
    g = {"w": jnp.array([[0.1, 0.2], [-0.3, 0.4]]), "b": jnp.array([0.05])}
    state = adamw_init(p)
    new_p, new_state, _ = adamw_update(cfg, p, g, state)

    for k, decay in (("w", 0.1), ("b", 0.0)):   # decay only on matrices
        gk = np.asarray(g[k])
        mu = 0.1 * gk
        nu = 0.01 * gk * gk
        mhat = mu / (1 - 0.9)
        vhat = nu / (1 - 0.99)
        expect = (np.asarray(p[k])
                  - 1e-2 * (mhat / (np.sqrt(vhat) + 1e-8)
                            + decay * np.asarray(p[k])))
        np.testing.assert_allclose(np.asarray(new_p[k]), expect, rtol=1e-5)
    assert int(new_state["step"]) == 1


def test_clip_and_schedule():
    g = {"a": jnp.full((10,), 3.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(np.sqrt(90.0))
    assert float(global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)

    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=110,
                      min_lr_ratio=0.1)
    sched = cosine_schedule(cfg)
    assert float(sched(jnp.asarray(5))) == pytest.approx(0.5)
    assert float(sched(jnp.asarray(10))) == pytest.approx(1.0)
    assert float(sched(jnp.asarray(110))) == pytest.approx(0.1, abs=1e-6)


def test_training_reduces_loss():
    """Tiny model, 30 steps: loss must drop (integration)."""
    cfg = get_config("qwen3-1.7b").reduced()
    opt_cfg = AdamWConfig(lr=1e-3, warmup_steps=5, total_steps=30)
    state = init_state(jax.random.PRNGKey(0), cfg, opt_cfg)
    step = jax.jit(make_train_step(cfg, opt_cfg))
    data = SyntheticTokens(vocab_size=cfg.vocab_size, seq_len=64,
                           global_batch=4)
    losses = []
    for i in range(30):
        batch = {k: jnp.asarray(v) for k, v in data.batch(i % 2).items()}
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0] - 0.3, losses[::6]


def test_grad_accumulation_matches_full_batch():
    cfg = get_config("qwen3-1.7b").reduced().replace(
        compute_dtype="float32")
    opt_cfg = AdamWConfig(grad_clip=1e9)
    state = init_state(jax.random.PRNGKey(1), cfg, opt_cfg)
    data = SyntheticTokens(vocab_size=cfg.vocab_size, seq_len=32,
                           global_batch=8)
    batch = {k: jnp.asarray(v) for k, v in data.batch(0).items()}
    s1, m1 = jax.jit(make_train_step(cfg, opt_cfg, accum_steps=1))(
        state, batch)
    s2, m2 = jax.jit(make_train_step(cfg, opt_cfg, accum_steps=4))(
        state, batch)
    assert float(m1["loss"]) == pytest.approx(float(m2["loss"]), rel=1e-5)
    for a, b in zip(jax.tree.leaves(s1["params"]),
                    jax.tree.leaves(s2["params"])):
        np.testing.assert_allclose(a, b, atol=2e-6)


# -- compression ----------------------------------------------------------------

def test_int8_quant_roundtrip_bounds():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal(1000) * 5)
    q, scale = quantize_int8(x)
    err = np.abs(np.asarray(dequantize_int8(q, scale)) - np.asarray(x))
    assert err.max() <= float(scale) / 2 + 1e-6


def test_error_feedback_preserves_sum():
    """Σ compressed grads + final residual == Σ raw grads (EF property)."""
    rng = np.random.default_rng(1)
    grads_seq = [{"w": jnp.asarray(rng.standard_normal((64, 64)) * 0.01)}
                 for _ in range(20)]
    residual = compress_init(grads_seq[0])
    total_sent = jnp.zeros((64, 64))
    for g in grads_seq:
        sent, residual = compress_decompress(g, residual)
        total_sent = total_sent + sent["w"]
    total_raw = sum(np.asarray(g["w"]) for g in grads_seq)
    drift = np.abs(np.asarray(total_sent + residual["w"]) - total_raw)
    assert drift.max() < 1e-5


# -- data pipeline ----------------------------------------------------------------

def test_synthetic_deterministic_and_shifted():
    src = SyntheticTokens(vocab_size=1000, seq_len=16, global_batch=4,
                          seed=7)
    b1, b2 = src.batch(3), src.batch(3)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    np.testing.assert_array_equal(b1["tokens"][:, 1:], b1["labels"][:, :-1])
    assert not np.array_equal(src.batch(4)["tokens"], b1["tokens"])


def test_memmap_pipeline(tmp_path):
    toks = np.arange(4 * 3 * 17, dtype=np.uint16)
    fp = tmp_path / "tokens.bin"
    toks.tofile(fp)
    src = MemmapTokens(str(fp), seq_len=16, global_batch=4)
    b0 = src.batch(0)
    assert b0["tokens"].shape == (4, 16)
    np.testing.assert_array_equal(b0["tokens"][0], np.arange(16))
    np.testing.assert_array_equal(b0["labels"][0], np.arange(1, 17))
    # wraps around
    assert src.batch(src.n_batches)["tokens"][0, 0] == 0


def test_prefetcher():
    src = SyntheticTokens(vocab_size=100, seq_len=8, global_batch=2)
    pf = Prefetcher(src, depth=2)
    a, b = pf.get(), pf.get()
    pf.close()
    np.testing.assert_array_equal(a["tokens"], src.batch(0)["tokens"])
    np.testing.assert_array_equal(b["tokens"], src.batch(1)["tokens"])


# -- checkpointing ----------------------------------------------------------------

def test_checkpoint_roundtrip_and_latest(tmp_path):
    tree = {"a": jnp.arange(10, dtype=jnp.float32),
            "b": {"c": jnp.ones((3, 4), jnp.bfloat16)}}
    ckpt_mod.save(str(tmp_path), 5, tree)
    ckpt_mod.save(str(tmp_path), 9, jax.tree.map(lambda x: x + 1, tree))
    assert ckpt_mod.latest_step(str(tmp_path)) == 9
    restored, step = ckpt_mod.restore(str(tmp_path), tree)
    assert step == 9
    np.testing.assert_allclose(np.asarray(restored["a"]),
                               np.arange(10) + 1)


def test_checkpoint_detects_corruption(tmp_path):
    tree = {"a": jnp.arange(100, dtype=jnp.float32)}
    path = ckpt_mod.save(str(tmp_path), 1, tree)
    # Corrupt a leaf file.
    victim = os.path.join(path, "arr_00000.npy")
    with open(victim, "r+b") as f:
        f.seek(200)
        f.write(b"\xff\xff\xff\xff")
    with pytest.raises(IOError):
        ckpt_mod.restore(str(tmp_path), tree)


def test_checkpoint_structure_mismatch(tmp_path):
    ckpt_mod.save(str(tmp_path), 1, {"a": jnp.zeros(3)})
    with pytest.raises(ValueError):
        ckpt_mod.restore(str(tmp_path), {"a": jnp.zeros(3),
                                         "b": jnp.zeros(2)})


def test_async_checkpointer_gc(tmp_path):
    ck = ckpt_mod.AsyncCheckpointer(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        ck.save_async(s, {"x": jnp.full((4,), s)})
    ck.wait()
    steps = sorted(int(n.split("_")[1]) for n in os.listdir(tmp_path)
                   if n.startswith("step_"))
    assert steps == [3, 4]


# -- trainer fault tolerance ----------------------------------------------------------------

def _tiny_trainer(tmp_path, total_steps=6):
    cfg = get_config("qwen3-1.7b").reduced()
    opt_cfg = AdamWConfig(total_steps=total_steps)
    state = init_state(jax.random.PRNGKey(0), cfg, opt_cfg)
    step = jax.jit(make_train_step(cfg, opt_cfg))
    data = SyntheticTokens(vocab_size=cfg.vocab_size, seq_len=32,
                           global_batch=2)
    tcfg = TrainerConfig(total_steps=total_steps, ckpt_dir=str(tmp_path),
                         ckpt_every=2, log_every=1)
    return Trainer(tcfg, step, state, data,
                   put_batch=lambda b: {k: jnp.asarray(v)
                                        for k, v in b.items()})


def test_trainer_checkpoint_restart(tmp_path):
    t1 = _tiny_trainer(tmp_path, total_steps=4)
    r1 = t1.run()
    assert r1["final_step"] == 4
    # "Crash" and restart: a fresh trainer resumes from step 4.
    t2 = _tiny_trainer(tmp_path, total_steps=6)
    assert t2.try_resume()
    assert t2.step == 4
    r2 = t2.run()
    assert r2["final_step"] == 6
    assert int(t2.state["opt"]["step"]) == 6


def test_trainer_records_metrics(tmp_path):
    t = _tiny_trainer(tmp_path, total_steps=3)
    r = t.run()
    assert len(r["metrics"]) == 3
    assert all(np.isfinite(m["loss"]) for m in r["metrics"])


# -- serving engine ----------------------------------------------------------------

def test_engine_serves_requests():
    cfg = get_config("qwen3-1.7b").reduced()
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    from repro.serve.engine import Engine, Request, ServeConfig
    eng = Engine(cfg, params, ServeConfig(max_batch=2, max_len=64,
                                          eos_token=-1))
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i, prompt=rng.integers(1, cfg.vocab_size, 5)
                    .astype(np.int32), max_new_tokens=4) for i in range(3)]
    done = eng.run_until_drained(reqs)
    assert len(done) == 3
    assert all(len(r.out_tokens) == 4 for r in done)


def test_engine_rejects_empty_prompt():
    cfg = get_config("qwen3-1.7b").reduced()
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    from repro.serve.engine import Engine, Request, ServeConfig
    eng = Engine(cfg, params, ServeConfig(max_batch=2, max_len=64,
                                          eos_token=-1))
    with pytest.raises(ValueError, match="empty prompt"):
        eng.add_request(Request(rid=0, prompt=np.zeros(0, np.int32)))
    # The engine stays usable: the bad request claimed no slot.
    ok = eng.add_request(Request(rid=1,
                                 prompt=np.array([1, 2, 3], np.int32),
                                 max_new_tokens=2))
    assert ok and eng.slot_req[0] is not None
