"""Sampler semantics (§4.5-4.7), sensor models, and end-to-end profiling."""

import time

import numpy as np
import pytest
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # degrade gracefully: deterministic fixed-seed draws
    from _hypothesis_fallback import given, settings, st

from repro.core import regions as regions_mod
from repro.core.estimator import estimate_regions
from repro.core.profiler import EnergyProfiler
from repro.core.sampler import sample_timeline
from repro.core.sensors import (Ina231TraceSensor, InstantTraceSensor,
                                ProcessActivitySensor, RaplTraceSensor)
from repro.core.timeline import RegionCost, Timeline, ground_truth, synthesize


def _two_region_timeline(reps=2000, d0=3e-3, d1=7e-3, p0=80.0, p1=120.0):
    return Timeline(
        region_ids=np.tile([0, 1], reps),
        durations=np.tile([d0, d1], reps),
        powers=np.tile([p0, p1], reps),
        names=("cold", "hot"))


def test_timeline_invariants():
    tl = _two_region_timeline()
    assert tl.t_exec == pytest.approx(2000 * 10e-3)
    gt = ground_truth(tl)
    assert gt["hot"]["time"] == pytest.approx(14.0)
    assert gt["hot"]["energy"] == pytest.approx(14.0 * 120.0)
    # region_at boundaries
    assert tl.region_at(np.array([1e-3]))[0] == 0
    assert tl.region_at(np.array([5e-3]))[0] == 1


def test_instant_sensor_exact():
    tl = _two_region_timeline()
    s = InstantTraceSensor(tl)
    np.testing.assert_allclose(s.read(np.array([1e-3, 5e-3])), [80.0, 120.0])


def test_rapl_sensor_energy_conservation():
    """Differenced energy-counter readings integrate back to total energy."""
    tl = _two_region_timeline(reps=500)
    s = RaplTraceSensor(tl, update_period=1e-3)
    times = np.arange(1e-3, tl.t_exec, 1e-3)
    pows = s.read_many(times)
    # Mean power over the run ≈ total energy / t_exec.
    total_e = sum(v["energy"] for v in ground_truth(tl).values())
    assert np.mean(pows) == pytest.approx(total_e / tl.t_exec, rel=0.01)


def test_ina231_window_average():
    tl = _two_region_timeline()
    s = Ina231TraceSensor(tl, window=280e-6)
    # Deep inside the hot region, the window sees only hot power.
    assert s.read(np.array([3e-3 + 2e-3]))[0] == pytest.approx(120.0)
    # Right after the cold→hot switch the average is blended.
    v = s.read(np.array([3e-3 + 140e-6]))[0]
    assert 80.0 < v < 120.0


def test_sampling_period_below_sensor_min_rejected():
    tl = _two_region_timeline()
    s = Ina231TraceSensor(tl, window=280e-6)
    with pytest.raises(ValueError):
        sample_timeline(tl, s, period=100e-6)


def test_aliasing_pathology_and_jitter_fix():
    """§4.6: exact-period sampling on a periodic program is catastrophically
    biased; timer jitter restores correctness."""
    tl = _two_region_timeline(reps=5000, d0=4e-3, d1=6e-3)
    s = InstantTraceSensor(tl)
    # Period == program period → every sample lands in the same region.
    aliased = sample_timeline(tl, s, period=10e-3, deliberate_alias=True,
                              seed=0)
    est_a = estimate_regions(aliased.region_ids, aliased.powers,
                             aliased.t_exec, tl.names)
    p_hot_aliased = est_a.by_name().get("hot")
    frac = p_hot_aliased.p_hat if p_hot_aliased else 0.0
    assert frac < 0.05 or frac > 0.95     # degenerate attribution

    jittered = sample_timeline(tl, s, period=10e-3, jitter=500e-6, seed=0)
    est_j = estimate_regions(jittered.region_ids, jittered.powers,
                             jittered.t_exec, tl.names)
    assert est_j.by_name()["hot"].p_hat == pytest.approx(0.6, abs=0.03)


def test_overhead_biases_estimates():
    """§4.7: per-sample suspension inflates measured time (systematic error)."""
    tl = _two_region_timeline(reps=2000)
    s = InstantTraceSensor(tl)
    clean = sample_timeline(tl, s, period=5e-3, seed=1)
    dirty = sample_timeline(tl, s, period=5e-3, overhead_per_sample=1e-3,
                            seed=1)
    assert dirty.t_exec > clean.t_exec
    assert dirty.overhead_time == pytest.approx(dirty.n * 1e-3)


@given(seed=st.integers(0, 10_000))
@settings(max_examples=15, deadline=None)
def test_property_profiler_accuracy(seed):
    """End-to-end: estimates within a few % of ground truth (paper §5)."""
    costs = [
        RegionCost("attn", flops=4e11, hbm_bytes=1.5e10, invocations=8),
        RegionCost("ffn", flops=9e11, hbm_bytes=2.5e10, invocations=8),
        RegionCost("opt", flops=2e10, hbm_bytes=4e10, invocations=1),
    ]
    tl = synthesize(costs, steps=150, seed=seed)
    prof = EnergyProfiler(period=10e-3, seed=seed + 1)
    est = prof.profile_timeline(tl, sensor="rapl")
    gt = ground_truth(tl)
    for name, g in gt.items():
        r = est.by_name()[name]
        assert r.t_hat == pytest.approx(g["time"], rel=0.10)
        assert r.e_hat == pytest.approx(g["energy"], rel=0.12)


def test_multiworker_combination_profiling():
    """§4.4: contention-aware combination attribution across 2 workers."""
    costs = [RegionCost("mem", flops=1e10, hbm_bytes=5e10, invocations=4),
             RegionCost("alu", flops=6e11, hbm_bytes=2e9, invocations=4)]
    tls = [synthesize(costs, steps=120, seed=s) for s in (0, 1)]
    prof = EnergyProfiler(period=10e-3)
    est, combos = prof.profile_multiworker(tls, sensor="instant")
    assert len(combos) >= 2
    assert sum(r.t_hat for r in est.regions) == pytest.approx(
        min(t.t_exec for t in tls), rel=1e-6)


def test_host_session_smoke():
    """Real control thread samples regions executed by this process.

    Thresholds are deliberately loose: on a loaded single-core host the
    sampler thread competes with the profiled loop (and with whatever else
    the machine runs), which stretches sleeps — the attribution stays
    correct but the busy fraction drops.
    """
    prof = EnergyProfiler(period=1e-3, jitter=1e-4)
    with prof.host_session() as sess:
        for _ in range(120):
            with regions_mod.region("busy"):
                t0 = time.monotonic()
                while time.monotonic() - t0 < 2e-3:
                    pass
            with regions_mod.region("idle"):
                time.sleep(0.5e-3)
    est = sess.estimates()
    names = {r.name for r in est.regions}
    assert "busy" in names
    busy = est.by_name()["busy"]
    assert busy.n_samples >= 5
    assert busy.p_hat > 0.2


def test_host_session_with_sensor_bank_reports_rails():
    """host_session(sensor=...) threads a multi-rail bank end to end:
    the session samples every rail and the estimates carry a per-domain
    energy split whose rails sum to the scalar total."""
    from repro.core.sensors import HostSensorBank

    class Const:
        min_period = 0.0

        def __init__(self, v):
            self.v = v

        def read(self, t=None):
            return self.v

    bank = HostSensorBank([("pkg", Const(50.0)), ("dram", Const(10.0))])
    prof = EnergyProfiler(period=1e-3, jitter=1e-4)
    with prof.host_session(sensor=bank) as sess:
        for _ in range(60):
            with regions_mod.region("railwork"):
                t0 = time.monotonic()
                while time.monotonic() - t0 < 2e-3:
                    pass
    est = sess.estimates()
    tbl = est.table
    assert tbl.domains == ("pkg", "dram")
    row = est.by_name()["railwork"]
    assert row.n_samples >= 3
    i = list(tbl.names).index("railwork")
    assert tbl.e_rails[i].sum() == pytest.approx(tbl.e_hat[i], rel=1e-6)
    # Constant rails: the split mirrors the configured powers exactly.
    assert tbl.e_rails[i, 0] == pytest.approx(tbl.e_hat[i] * 50.0 / 60.0,
                                              rel=1e-6)


def test_host_sampler_period_tracks_deadline_despite_read_cost():
    """Absolute-deadline scheduling: the achieved mean period tracks the
    configured one even when read() itself costs a large fraction of the
    period (naive sleep-after-read would stretch every period by the full
    read cost — here +50%)."""
    from repro.core.sampler import HostSampler, RegionMarker

    period, read_cost = 20e-3, 10e-3

    class SlowSensor:
        min_period = 0.0

        def read(self):
            time.sleep(read_cost)
            return 42.0

    sampler = HostSampler(RegionMarker(), SlowSensor(), period=period,
                          jitter=0.0)
    with sampler:
        time.sleep(1.0)
    rids, _pows = sampler.drain()
    n = len(rids)
    assert n >= 5
    achieved = sampler.elapsed / n
    # Generous upper bound for loaded CI hosts; the pre-fix behavior sat
    # at >= period + read_cost = 1.5x and must fail this.
    assert achieved == pytest.approx(period, rel=0.35)
    assert achieved < period + 0.8 * read_cost


def test_process_activity_sensor_reacts():
    s = ProcessActivitySensor()
    s.read()
    t0 = time.monotonic()
    while time.monotonic() - t0 < 20e-3:
        pass
    busy_p = s.read()
    time.sleep(20e-3)
    idle_p = s.read()
    assert busy_p > idle_p
