"""Admission control, deadlines, energy budgets, overload ladder.

The scheduler half is pure host logic (no model needed); the engine
integration tests run on one reduced architecture. Everything is keyed
on the deterministic engine step clock, so every scenario here is
exactly replayable.
"""

import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:                                   # pragma: no cover
    from _hypothesis_fallback import given, settings, st

from repro.core.faults import FaultPlan
from repro.serve.scheduler import (AdmissionQueue, DeadlineExceededError,
                                   EnergyBudgetExceededError, OverloadPolicy,
                                   QueueFullError, RequestRecord, ServeReport,
                                   ServeScheduler, AdmissionError)


class _Req:
    """Duck-typed stand-in for engine.Request at the scheduler seam."""

    def __init__(self, rid, priority=0, deadline=None):
        self.rid = rid
        self.priority = priority
        self.deadline = deadline
        self.status = "queued"
        self.submit_step = 0


# -- queue order ---------------------------------------------------------------

def test_pop_best_priority_then_fifo():
    q = AdmissionQueue(8)
    q.push(0, 0, "a")
    q.push(2, 1, "b")
    q.push(2, 2, "c")
    q.push(1, 3, "d")
    assert [q.pop_best() for _ in range(4)] == ["b", "c", "d", "a"]
    assert q.pop_best() is None


def test_shed_worst_lowest_priority_youngest_first():
    q = AdmissionQueue(8)
    q.push(1, 0, "old-low")
    q.push(1, 1, "new-low")
    q.push(5, 2, "high")
    assert q.shed_worst() == "new-low"
    assert q.shed_worst() == "old-low"
    assert q.shed_worst() == "high"


def test_queue_capacity_enforced():
    q = AdmissionQueue(2)
    q.push(0, 0, "a")
    q.push(0, 1, "b")
    with pytest.raises(QueueFullError):
        q.push(9, 2, "c")


@settings(max_examples=25, deadline=None)
@given(n=st.integers(min_value=1, max_value=30),
       seed=st.integers(min_value=0, max_value=10_000))
def test_admission_order_deterministic_under_equal_priorities(n, seed):
    # Satellite: under equal priorities, admission order is exactly the
    # submit order — a pure function of the submit sequence, never of
    # hashes, arrival timing, or dict iteration order.
    rng = np.random.default_rng(seed)
    prio = int(rng.integers(0, 3))
    q1, q2 = AdmissionQueue(n), AdmissionQueue(n)
    for s in range(n):
        q1.push(prio, s, s)
        q2.push(prio, s, s)
    order1 = [q1.pop_best() for _ in range(n)]
    order2 = [q2.pop_best() for _ in range(n)]
    assert order1 == order2 == list(range(n))


# -- policy validation ---------------------------------------------------------

def test_policy_threshold_ordering_validated():
    with pytest.raises(ValueError):
        OverloadPolicy(queue_capacity=8, backpressure_at=4, shed_at=2,
                       widen_at=6)
    with pytest.raises(ValueError):
        OverloadPolicy(queue_capacity=4, backpressure_at=1, shed_at=2,
                       widen_at=8)
    with pytest.raises(ValueError):
        OverloadPolicy(widen_factor=0.5)


# -- scheduler semantics -------------------------------------------------------

def _sched(cap=4, bp=None, shed=None, widen=None, **kw):
    bp = bp if bp is not None else max(1, cap // 2)
    shed = shed if shed is not None else max(bp, cap - 1)
    widen = widen if widen is not None else cap
    return ServeScheduler(OverloadPolicy(
        queue_capacity=cap, backpressure_at=bp, shed_at=shed,
        widen_at=widen), **kw)


def test_queue_full_rejection_is_counted_and_typed():
    s = _sched(cap=2)
    s.submit(_Req(0), 0)
    s.submit(_Req(1), 0)
    with pytest.raises(QueueFullError):
        s.submit(_Req(2), 0)
    assert s.report.rejected_full == 1
    assert s.report.request(2).status == "shed"
    assert s.report.request(2).reason == "queue_full"


def test_higher_priority_displaces_queued_lowest():
    s = _sched(cap=2)
    s.submit(_Req(0, priority=0), 0)
    s.submit(_Req(1, priority=1), 0)
    s.submit(_Req(2, priority=5), 1)      # displaces rid 0
    assert s.report.request(0).status == "shed"
    assert s.report.shed == 1
    assert s.admit(1).rid == 2
    assert s.admit(1).rid == 1


def test_deadline_expires_in_queue():
    s = _sched()
    s.submit(_Req(0, deadline=2), 0)
    s.submit(_Req(1), 0)
    assert s.admit(5).rid == 1            # rid 0 expired waiting
    rec = s.report.request(0)
    assert rec.status == "aborted_deadline"
    assert s.report.aborted_deadline == 1
    with pytest.raises(DeadlineExceededError):
        s.submit(_Req(2, deadline=0), 5)


def test_ladder_sheds_and_records_transitions():
    widened = []
    s = _sched(cap=6, bp=2, shed=4, widen=5)
    for rid in range(5):
        s.submit(_Req(rid, priority=rid), 0)
    s.tick(0, widen_fn=widened.append, unwiden_fn=lambda: widened.append(0))
    # shed down to backpressure_at=2, lowest-priority victims first
    assert s.report.shed == 3
    assert [r.rid for r in s.report.requests
            if r.status == "shed"] == [0, 1, 2]
    assert widened == [s.policy.widen_factor]
    # drain the queue -> de-escalates and unwidens
    while s.admit(1) is not None:
        pass
    s.tick(1, widen_fn=widened.append,
           unwiden_fn=lambda: widened.append(0))
    assert widened[-1] == 0
    levels = [(t[1], t[2]) for t in s.report.transitions]
    assert levels[0][1] == "degraded"
    assert levels[-1][1] == "normal"


def test_injected_admission_fault_is_counted():
    plan = FaultPlan(seed=0, admission_faults=(1,))
    s = _sched(faults=plan)
    s.submit(_Req(0), 0)
    with pytest.raises(AdmissionError):
        s.submit(_Req(1), 0)              # submit seq 1 faulted
    assert s.report.admission_faults == 1
    s.submit(_Req(2), 0)                  # transient: next submit fine
    assert len(s.queue) == 2


def test_duplicate_rid_rejected():
    s = _sched()
    s.submit(_Req(7), 0)
    with pytest.raises(ValueError):
        s.submit(_Req(7), 1)


# -- report provenance ---------------------------------------------------------

def test_report_round_trips_json():
    s = _sched(cap=2)
    s.submit(_Req(0), 0)
    s.submit(_Req(1, priority=3), 0)
    with pytest.raises(QueueFullError):
        s.submit(_Req(2), 1)
    s.report.transition(1, "normal", "backpressure", "depth 2")
    blob = s.report.to_json()
    back = ServeReport.from_json(blob)
    assert back.to_json() == blob
    assert back.rejected_full == 1
    assert back.request(1).priority == 3
    cov = back.coverage()
    assert cov["counters"]["rejected_full"] == 1
    assert "2" in cov["requests"]


def test_unknown_status_rejected():
    rep = ServeReport()
    rep.open(0, status="queued", step=0)
    with pytest.raises(ValueError):
        rep.set_status(0, "vanished")


def test_record_statuses_cover_contract():
    # The provenance vocabulary the ISSUE pins: every terminal path has
    # a distinct, countable status.
    rec = RequestRecord(rid=0, status="queued")
    for status in ("admitted", "completed", "shed", "aborted_deadline",
                   "aborted_budget", "recovered"):
        rep = ServeReport()
        rep.open(0, status="queued", step=0)
        rep.set_status(0, status, step=1)
    assert rec.to_json()["rid"] == 0
