"""Distribution-layer tests.

Numerical tests needing >1 device run in a subprocess (the device count
must be fixed before jax initializes; tests in THIS process keep 1 CPU
device per the assignment's instruction). The subprocess asserts:

  * pjit'd train step on a (2,2) mesh == single-device step (DP+TP+SP
    + FSDP sharding changes nothing numerically);
  * shard_map MoE (expert-parallel) == local MoE math.

Plus in-process tests for rules/specs and the roofline HLO parser.
"""

import json
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from jax.sharding import PartitionSpec as P

from repro.roofline.analysis import (model_flops, parse_collective_bytes,
                                     roofline_terms)

_SUBPROCESS_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys
    sys.path.insert(0, "src")
    import json
    import jax, jax.numpy as jnp
    import numpy as np
    from repro.configs.registry import get_config
    from repro.configs.base import ShapeConfig
    from repro.launch.dryrun import build_rules
    from repro.launch.mesh import make_mesh_compat
    from repro.optim.adamw import AdamWConfig
    from repro.sharding import params as sp
    from repro.sharding.rules import axis_rules
    from repro.train.step import init_state, make_train_step
    from repro.data.pipeline import SyntheticTokens

    out = {}
    # Dropless capacity: EP truncates per-shard, the local path globally —
    # equality needs no drops on either path (production MoE keeps the
    # standard capacity factor; this is a numerics test).
    cfg = get_config("qwen3-moe-30b-a3b").reduced().replace(
        compute_dtype="float32")
    cfg = cfg.replace(capacity_factor=float(cfg.n_experts / cfg.top_k))
    opt_cfg = AdamWConfig(grad_clip=1e9)
    shape = ShapeConfig("t", 64, 8, "train")
    data = SyntheticTokens(vocab_size=cfg.vocab_size, seq_len=64,
                           global_batch=8)
    batch = {k: jnp.asarray(v) for k, v in data.batch(0).items()}
    state = init_state(jax.random.PRNGKey(0), cfg, opt_cfg)
    step = make_train_step(cfg, opt_cfg)

    # single device reference
    s_ref, m_ref = jax.jit(step)(state, batch)
    out["loss_single"] = float(m_ref["loss"])

    # (2, 2) mesh: DP x TP(+EP via shard_map) + FSDP state sharding
    mesh = make_mesh_compat((2, 2), ("data", "model"))
    rules = build_rules(cfg, shape, mesh)
    with axis_rules(rules):
        state2 = init_state(jax.random.PRNGKey(0), cfg, opt_cfg)
        st_sh = sp.to_shardings(
            sp.param_specs(state2, rules, fsdp=True), rules)
        b_sh = sp.to_shardings(sp.batch_specs(batch, rules), rules)
        step2 = make_train_step(cfg, opt_cfg)
        fn = jax.jit(step2, in_shardings=(st_sh, b_sh),
                     out_shardings=(st_sh, None))
        with mesh:
            s_dist, m_dist = fn(state2, batch)
    out["loss_dist"] = float(m_dist["loss"])

    diffs = [float(jnp.max(jnp.abs(a - b))) for a, b in zip(
        jax.tree.leaves(s_ref["params"]), jax.tree.leaves(s_dist["params"]))]
    out["max_param_diff"] = max(diffs)
    print("RESULT " + json.dumps(out))
""")


@pytest.mark.slow
def test_distributed_train_step_matches_single_device():
    res = subprocess.run([sys.executable, "-c", _SUBPROCESS_SCRIPT],
                         capture_output=True, text=True, timeout=900,
                         cwd="/root/repo")
    assert res.returncode == 0, res.stderr[-3000:]
    line = [l for l in res.stdout.splitlines() if l.startswith("RESULT ")][0]
    out = json.loads(line[len("RESULT "):])
    assert out["loss_single"] == pytest.approx(out["loss_dist"], rel=1e-4)
    assert out["max_param_diff"] < 5e-4, out


# -- roofline HLO parsing ------------------------------------------------------

_FAKE_HLO = """
HloModule test
ENTRY main {
  %p0 = f32[16,128]{1,0} parameter(0)
  %ag = f32[16,2048]{1,0} all-gather(%p0), dim=1
  %ar = bf16[1024]{0} all-reduce(%x), to_apply=%sum
  %ar2.start = bf16[1024]{0} all-reduce-start(%x)
  %rs = f32[8,64]{1,0} reduce-scatter(%y), dimensions={0}
  %a2a = (f32[4,32]{1,0}, f32[4,32]{1,0}) all-to-all(%a, %b)
  %cp = u32[256]{0} collective-permute(%c), source_target_pairs={{0,1}}
  %add = f32[16,2048]{1,0} add(%ag, %ag)
}
"""


def test_parse_collective_bytes():
    st = parse_collective_bytes(_FAKE_HLO)
    assert st.bytes_by_kind["all-gather"] == 16 * 2048 * 4
    assert st.bytes_by_kind["all-reduce"] == 1024 * 2 * 2   # ar + ar2.start
    assert st.bytes_by_kind["reduce-scatter"] == 8 * 64 * 4
    assert st.bytes_by_kind["all-to-all"] == 2 * 4 * 32 * 4
    assert st.bytes_by_kind["collective-permute"] == 256 * 4
    assert st.count_by_kind["all-reduce"] == 2


def test_parse_ignores_non_collectives():
    st = parse_collective_bytes("%x = f32[10]{0} add(%a, %b)")
    assert st.total_bytes == 0


def test_roofline_terms_math():
    rep = roofline_terms(
        arch="a", shape="s", mesh_name="16x16", chips=256,
        cost_analysis={"flops": 197e12 * 1e-3,          # per-device
                       "bytes accessed": 819e9 * 2e-3},
        hlo_text=_FAKE_HLO, n_params_active=int(1e9), n_tokens=1000,
        training=True)
    assert rep.t_compute == pytest.approx(1e-3)
    assert rep.t_memory == pytest.approx(2e-3)
    assert rep.dominant == "memory"
    assert rep.model_flops_ == pytest.approx(6e12)
    assert 0 < rep.roofline_fraction <= 1.0


def test_model_flops():
    assert model_flops(100, 10, training=True) == 6000
    assert model_flops(100, 10, training=False) == 2000
