"""Contract auditor: each AST pass catches its known-bad fixture and
passes clean code; jaxpr audits flag f64 leaks / broken donation /
host callbacks; baselines ratchet (new fails, pinned passes, budgets
only go down); and the committed tree itself audits clean."""

import textwrap

import jax
import jax.numpy as jnp
import pytest

from repro.analysis import run_audit
from repro.analysis import baseline as bl
from repro.analysis.jaxpr_audit import (PathReport, audit_jaxpr,
                                        count_aliased_outputs,
                                        donation_of_jitted, jit_cache_size)
from repro.analysis.passes import (FaultSiteHygienePass, NoSilentExceptPass,
                                   NoWallclockPass, TypedSpillErrorsPass,
                                   X64ScopingPass, parse_unit, run_passes)


def _scan(src, modpath="core/device_pipeline.py", passes=None, extra=()):
    unit = parse_unit(f"src/repro/{modpath}", modpath,
                      textwrap.dedent(src))
    return run_passes([unit, *extra], passes)


# ---------------------------------------------------------------------------
# pass (a): no-wallclock
# ---------------------------------------------------------------------------

def test_wallclock_fixture_caught():
    bad = """\
        import time
        import numpy as np
        import random
        from datetime import datetime

        def f():
            t = time.time()
            r = random.random()
            x = np.random.rand(3)
            g = np.random.default_rng()
            d = datetime.now()
            return t, r, x, g, d
    """
    idents = {f.ident for f in _scan(bad, passes=[NoWallclockPass()])}
    assert idents == {"time.time", "random.random", "np.random.rand",
                      "np.random.default_rng", "datetime.datetime.now"}


def test_wallclock_clean_code_passes():
    clean = """\
        import time
        import numpy as np
        import jax

        def f(seed):
            time.sleep(0.1)                      # spends time, reads none
            rng = np.random.default_rng(seed)    # explicit seed
            key = jax.random.PRNGKey(seed)
            return rng, jax.random.uniform(key, (3,))
    """
    assert _scan(clean, passes=[NoWallclockPass()]) == []


def test_wallclock_only_in_critical_modules():
    bad = "import time\nt = time.time()\n"
    assert _scan(bad, modpath="core/report.py",
                 passes=[NoWallclockPass()]) == []
    assert len(_scan(bad, modpath="kernels/sample_attr/ops.py",
                     passes=[NoWallclockPass()])) == 1


def test_wallclock_sees_through_aliases():
    bad = "import time as t\nx = t.monotonic()\n"
    (f,) = _scan(bad, passes=[NoWallclockPass()])
    assert f.ident == "time.monotonic"


# ---------------------------------------------------------------------------
# pass (b): typed-spill-errors
# ---------------------------------------------------------------------------

def test_builtin_oserror_raise_caught():
    bad = """\
        def publish(path):
            raise IOError(f"spill failed: {path}")
    """
    (f,) = _scan(bad, modpath="core/exchange.py",
                 passes=[TypedSpillErrorsPass()])
    assert f.ident == "IOError" and f.line == 2


def test_typed_spill_raise_passes():
    clean = """\
        from repro.core.faults import CorruptShardError

        def publish(path):
            raise CorruptShardError(f"bad crc: {path}")
    """
    assert _scan(clean, modpath="checkpoint/ckpt.py",
                 passes=[TypedSpillErrorsPass()]) == []


def test_bare_reraise_passes():
    clean = """\
        def f():
            try:
                g()
            except IOError:
                raise
    """
    assert _scan(clean, modpath="core/exchange.py",
                 passes=[TypedSpillErrorsPass()]) == []


# ---------------------------------------------------------------------------
# pass (c): no-silent-except
# ---------------------------------------------------------------------------

def test_silent_except_variants_caught():
    bad = """\
        def f():
            try:
                g()
            except ValueError:
                pass
            try:
                g()
            except IOError:
                return None
            for _ in range(3):
                try:
                    g()
                except Exception:
                    print("oops")   # log-and-continue, no counter
    """
    found = _scan(bad, modpath="serve/engine.py",
                  passes=[NoSilentExceptPass()])
    assert len(found) == 3


def test_handled_except_passes():
    clean = """\
        def f(stats):
            try:
                g()
            except IOError as e:
                stats["errors"] += 1
            try:
                g()
            except ValueError as e:
                raise RuntimeError("ctx") from e
    """
    assert _scan(clean, modpath="core/exchange.py",
                 passes=[NoSilentExceptPass()]) == []


def test_pragma_suppresses_with_reason_block():
    ok = """\
        def f():
            try:
                g()
            # audit: allow(no-silent-except) absence means empty here —
            # callers treat a missing dir as no durable state
            except FileNotFoundError:
                return None
    """
    assert _scan(ok, modpath="core/exchange.py",
                 passes=[NoSilentExceptPass()]) == []


def test_pragma_is_per_pass():
    wrong_pass = """\
        def f():
            try:
                g()
            # audit: allow(no-wallclock) wrong pass name
            except FileNotFoundError:
                return None
    """
    assert len(_scan(wrong_pass, modpath="core/exchange.py",
                     passes=[NoSilentExceptPass()])) == 1


# ---------------------------------------------------------------------------
# pass (d): fault-site-hygiene
# ---------------------------------------------------------------------------

def _registry_unit(sites='("a.x", "b.y")'):
    return parse_unit("src/repro/core/faults.py", "core/faults.py",
                      f"FAULT_SITES = {sites}\n")


def test_fault_sites_clean():
    decls = 'from repro.core.faults import declare_site\n' \
            '_A = declare_site("a.x")\n_B = declare_site("b.y")\n'
    assert _scan(decls, modpath="core/seam.py",
                 passes=[FaultSiteHygienePass()],
                 extra=[_registry_unit()]) == []


def test_unregistered_site_caught():
    decls = '_C = declare_site("c.z")\n_A = declare_site("a.x")\n' \
            '_B = declare_site("b.y")\n'
    idents = {f.ident for f in _scan(decls, modpath="core/seam.py",
                                     passes=[FaultSiteHygienePass()],
                                     extra=[_registry_unit()])}
    assert idents == {"unregistered:c.z"}


def test_duplicate_and_undeclared_sites_caught():
    decls = '_A1 = declare_site("a.x")\n_A2 = declare_site("a.x")\n'
    idents = {f.ident for f in _scan(decls, modpath="core/seam.py",
                                     passes=[FaultSiteHygienePass()],
                                     extra=[_registry_unit()])}
    assert idents == {"duplicate:a.x", "undeclared:b.y"}


def test_non_literal_site_caught():
    decls = 'NAME = "a.x"\n_A = declare_site(NAME)\n' \
            '_B = declare_site("b.y")\n'
    idents = {f.ident for f in _scan(decls, modpath="core/seam.py",
                                     passes=[FaultSiteHygienePass()],
                                     extra=[_registry_unit()])}
    assert "<non-literal>" in idents


def test_runtime_registry_matches_static_declarations():
    """The live FAULT_SITES registry and the declared-site map agree:
    every site the static pass expects is declared at import time by
    the module the comments say owns it."""
    import repro.checkpoint.ckpt         # noqa: F401  (declares ckpt.*)
    import repro.core.exchange           # noqa: F401
    import repro.core.sampler            # noqa: F401
    import repro.core.sensors            # noqa: F401
    from repro.core.faults import FAULT_SITES, declared_sites
    assert set(declared_sites()) == set(FAULT_SITES)


def test_runtime_declare_rejects_unknown_and_cross_module_dup():
    from repro.core import faults
    with pytest.raises(ValueError, match="unregistered fault site"):
        faults.declare_site("nope.nope", module="m1")
    faults.declare_site("spiller.publish",
                        module="repro.core.exchange")     # idempotent
    with pytest.raises(ValueError, match="already declared"):
        faults.declare_site("spiller.publish", module="somewhere.else")


# ---------------------------------------------------------------------------
# pass (e): x64-scoping
# ---------------------------------------------------------------------------

def test_unscoped_x64_caught():
    bad = """\
        import jax
        from jax.experimental import enable_x64

        enable_x64()                                  # never entered
        jax.config.update("jax_enable_x64", True)     # global flip
    """
    idents = {f.ident for f in _scan(bad, modpath="core/anything.py",
                                     passes=[X64ScopingPass()])}
    assert idents == {"enable_x64-unscoped", "jax_enable_x64-global"}


def test_scoped_x64_passes():
    clean = """\
        from jax.experimental import enable_x64

        def f():
            with enable_x64():
                return 1
    """
    assert _scan(clean, modpath="core/anything.py",
                 passes=[X64ScopingPass()]) == []


# ---------------------------------------------------------------------------
# baseline ratchet (layer 1)
# ---------------------------------------------------------------------------

def _bad_unit():
    return parse_unit("src/repro/core/exchange.py", "core/exchange.py",
                      'def f():\n    raise IOError("x")\n')


def test_baseline_absorbs_pinned_and_fails_new(tmp_path):
    findings = run_passes([_bad_unit()], [TypedSpillErrorsPass()])
    assert len(findings) == 1

    # Unbaselined: the finding is new.
    res = bl.check_findings(findings, {})
    assert not res.ok and len(res.new) == 1

    # Pin it; same findings now absorb. Round-trip through the file.
    path = str(tmp_path / "baseline.json")
    bl.save_counts(bl.finding_counts(findings), path)
    res = bl.check_findings(findings, bl.load_counts(path))
    assert res.ok and len(res.baselined) == 1 and not res.stale_keys

    # A second identical violation exceeds the pinned count.
    two = parse_unit(
        "src/repro/core/exchange.py", "core/exchange.py",
        'def f():\n    raise IOError("x")\n'
        'def g():\n    raise IOError("y")\n')
    findings2 = run_passes([two], [TypedSpillErrorsPass()])
    res = bl.check_findings(findings2, bl.load_counts(path))
    assert not res.ok and len(res.new) == 1 and len(res.baselined) == 1


def test_baseline_reports_stale_keys(tmp_path):
    findings = run_passes([_bad_unit()], [TypedSpillErrorsPass()])
    path = str(tmp_path / "baseline.json")
    bl.save_counts(bl.finding_counts(findings), path)
    res = bl.check_findings([], bl.load_counts(path))
    assert res.ok and len(res.stale_keys) == 1


# ---------------------------------------------------------------------------
# jaxpr audit fixtures (layer 2)
# ---------------------------------------------------------------------------

def test_f64_leak_flagged():
    from jax.experimental import enable_x64
    with enable_x64():
        def leaky(x):
            return jnp.asarray(x, jnp.float64) * 2.0 + 1.0
        stats = audit_jaxpr(jax.make_jaxpr(leaky)(
            jnp.ones(4, jnp.float32)))
    assert stats.f64_ops >= 2
    assert stats.f64_widenings >= 1


def test_f32_code_not_flagged():
    def fine(x):
        return x * 2.0 + 1.0
    stats = audit_jaxpr(jax.make_jaxpr(fine)(jnp.ones(4, jnp.float32)))
    assert stats.f64_ops == 0 and stats.f64_widenings == 0


def test_audit_recurses_into_control_flow():
    from jax.experimental import enable_x64
    with enable_x64():
        def looped(x):
            return jax.lax.fori_loop(
                0, 3, lambda i, c: c + jnp.float64(1.5), x)
        stats = audit_jaxpr(jax.make_jaxpr(looped)(
            jnp.zeros((), jnp.float64)))
    assert any(p in stats.f64_by_prim for p in ("add", "convert_element_type"))


def test_non_donating_fn_flagged():
    x = jnp.ones(8, jnp.float32)
    plain = jax.jit(lambda a: a + 1.0)
    _, aliased = donation_of_jitted(plain, x, expected=1)
    assert aliased == 0

    donating = jax.jit(lambda a: a + 1.0, donate_argnums=(0,))
    _, aliased = donation_of_jitted(donating, x, expected=1)
    assert aliased == 1


def test_host_callback_detected():
    def chatty(x):
        jax.debug.print("x = {x}", x=x)
        return x * 2
    stats = audit_jaxpr(jax.make_jaxpr(chatty)(jnp.ones(3)))
    assert stats.host_callbacks >= 1


def test_count_aliased_outputs_parses_lowered_text():
    x = jnp.ones(8, jnp.float32)
    donating = jax.jit(lambda a, b: (a + b, b * 2), donate_argnums=(0, 1))
    text = donating.lower(x, jnp.ones(8, jnp.float32)).as_text()
    assert count_aliased_outputs(text) == 2


def test_jit_cache_size_counts_specializations():
    f = jax.jit(lambda a: a * 2)
    assert jit_cache_size(f) == 0
    f(jnp.ones(4, jnp.float32))
    f(jnp.ones(4, jnp.float32))      # same shape: cached
    assert jit_cache_size(f) == 1
    f(jnp.ones(5, jnp.float32))      # new shape: one more compile
    assert jit_cache_size(f) == 2


# ---------------------------------------------------------------------------
# x64 budget ratchet (layer 2)
# ---------------------------------------------------------------------------

def _report(name="p", f64=5, widen=1, cb=0, don=(0, 0)):
    return PathReport(name=name, eqn_count=10, f64_ops=f64,
                      f64_by_prim={"mul": f64}, f64_widenings=widen,
                      host_callbacks=cb, callback_prims=(),
                      donated_expected=don[0], donated_aliased=don[1])


def test_budget_over_and_under():
    budget = {"p": {"f64_ops": 5, "f64_widenings": 1, "host_callbacks": 0}}
    assert bl.check_budget([_report()], budget) == []
    assert bl.check_budget([_report(f64=4)], budget) == []   # ratchet down ok
    over = bl.check_budget([_report(f64=6)], budget)
    assert len(over) == 1 and "f64_ops grew" in over[0].message


def test_budget_unknown_path_fails():
    (v,) = bl.check_budget([_report()], {})
    assert "not in x64_budget.json" in v.message


def test_budget_donation_is_absolute():
    budget = {"p": {"f64_ops": 5, "f64_widenings": 1, "host_callbacks": 0}}
    (v,) = bl.check_budget([_report(don=(5, 4))], budget)
    assert "donation broken" in v.message
    assert bl.check_budget([_report(don=(5, 5))], budget) == []


def test_budget_update_refuses_increase(tmp_path):
    path = str(tmp_path / "budget.json")
    bl.save_budget(bl.merge_budget([_report(f64=5)], {}), path)
    existing = bl.load_budget(path)
    with pytest.raises(ValueError, match="refusing to raise"):
        bl.merge_budget([_report(f64=6)], existing)
    merged = bl.merge_budget([_report(f64=6)], existing,
                             allow_increase=True)
    assert merged["p"]["f64_ops"] == 6
    # Ratcheting down needs no force and rewrites the lower count.
    merged = bl.merge_budget([_report(f64=3)], existing)
    assert merged["p"]["f64_ops"] == 3


# ---------------------------------------------------------------------------
# the committed tree audits clean against its committed baseline
# ---------------------------------------------------------------------------

def test_repo_layer1_clean():
    result = run_audit(jaxpr=False)
    assert result.ratchet.ok, "\n".join(
        f.render() for f in result.ratchet.new)
    assert not result.ratchet.stale_keys
