"""Ragged continuous batching: the Engine's cache-position contract.

Slots at different depths share one decode step; each slot's KV entry
must land at its *own* position (per-slot ``cur_len`` vector), cache
writes must be masked to the prefilled slot / active slots (recurrent
SSM/xLSTM state advances on every call, and a reused slot must not
inherit its previous occupant's state), and MoE decode must be
dropless — otherwise batch composition leaks into per-request outputs.
The oracle is token-exact equivalence with one-request-at-a-time runs
of the same engine shape, across all four cache families.
"""

import jax
import numpy as np
import pytest

from repro.configs.registry import get_config
from repro.models import model as M
from repro.serve.engine import Engine, Request, ServeConfig

# dense + moe (positional KV) and ssm + hybrid (recurrent state)
ARCHS = ("qwen3-1.7b", "qwen3-moe-30b-a3b", "xlstm-125m", "zamba2-1.2b")


@pytest.fixture(scope="module", params=ARCHS)
def arch_setup(request):
    cfg = get_config(request.param).reduced()
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _scfg():
    return ServeConfig(max_batch=3, max_len=64, eos_token=-1)


def _prompts(cfg, lengths=(7, 3, 11), seed=42):
    rng = np.random.default_rng(seed)
    return [rng.integers(1, cfg.vocab_size, n).astype(np.int32)
            for n in lengths]


def _run_alone(cfg, params, prompt, rid, max_new=8):
    """Sequential baseline: one request in a fresh engine (same shapes)."""
    eng = Engine(cfg, params, _scfg())
    req = Request(rid=rid, prompt=prompt.copy(), max_new_tokens=max_new)
    done = eng.run_until_drained([req])
    assert len(done) == 1 and done[0].done
    return done[0].out_tokens


def test_ragged_staggered_matches_sequential(arch_setup):
    """Acceptance: 3 requests, staggered admission, mixed prompt lengths —
    token-exact vs one-request-at-a-time runs."""
    cfg, params = arch_setup
    prompts = _prompts(cfg)
    seq = [_run_alone(cfg, params, p, i) for i, p in enumerate(prompts)]

    eng = Engine(cfg, params, _scfg())
    reqs = [Request(rid=i, prompt=p.copy(), max_new_tokens=8)
            for i, p in enumerate(prompts)]
    # Staggered admission: each new request prefills while earlier ones
    # are mid-decode at different depths (the ragged regime).
    eng.add_request(reqs[0])
    for _ in range(2):
        eng.step()
    eng.add_request(reqs[1])
    for _ in range(2):
        eng.step()
    eng.add_request(reqs[2])
    for _ in range(40):
        eng.step()
        if all(r is None for r in eng.slot_req):
            break
    for i in range(3):
        assert reqs[i].done
        assert reqs[i].out_tokens == seq[i], f"request {i} diverged"


def test_admission_mid_decode_leaves_active_request_unchanged(arch_setup):
    """Regression: prefilling an admitted request must not stomp the
    caches of concurrently-active slots (KV at the prefilled positions,
    recurrent state on every call)."""
    cfg, params = arch_setup
    prompts = _prompts(cfg, lengths=(9, 6))
    base = _run_alone(cfg, params, prompts[0], 0, max_new=10)

    eng = Engine(cfg, params, _scfg())
    r0 = Request(rid=0, prompt=prompts[0].copy(), max_new_tokens=10)
    r1 = Request(rid=1, prompt=prompts[1].copy(), max_new_tokens=4)
    eng.add_request(r0)
    for _ in range(3):
        eng.step()
    eng.add_request(r1)          # admitted while r0 is mid-decode
    for _ in range(40):
        eng.step()
        if r0.done and r1.done:
            break
    assert r0.out_tokens == base, "mid-decode admission corrupted r0"


def test_ragged_depths_decode_to_distinct_positions(arch_setup):
    """Two slots at very different depths decode together; the shallow
    slot's output must match its solo run (a scalar max-depth position
    would write its KV into the wrong slot positions)."""
    cfg, params = arch_setup
    prompts = _prompts(cfg, lengths=(2, 20), seed=7)
    solo = [_run_alone(cfg, params, p, i, max_new=6)
            for i, p in enumerate(prompts)]
    eng = Engine(cfg, params, _scfg())
    reqs = [Request(rid=i, prompt=p.copy(), max_new_tokens=6)
            for i, p in enumerate(prompts)]
    for r in reqs:
        eng.add_request(r)
    for _ in range(30):
        eng.step()
        if all(r.done for r in reqs):
            break
    assert reqs[0].out_tokens == solo[0]
    assert reqs[1].out_tokens == solo[1]


def test_slot_reuse_does_not_inherit_previous_state(arch_setup):
    """A reused slot must behave as freshly initialized: recurrent
    SSM/xLSTM state is input to the next step, so the previous
    occupant's final state (and idle-step garbage) must be cleared at
    admission."""
    cfg, params = arch_setup
    prompts = _prompts(cfg, lengths=(8, 5), seed=11)
    solo_b = _run_alone(cfg, params, prompts[1], 1, max_new=6)

    eng = Engine(cfg, params, _scfg())
    ra = Request(rid=0, prompt=prompts[0].copy(), max_new_tokens=4)
    eng.add_request(ra)
    for _ in range(10):
        eng.step()
        if ra.done:
            break
    assert ra.done
    # a few empty steps after completion, then reuse the slot
    for _ in range(2):
        eng.step()
    rb = Request(rid=1, prompt=prompts[1].copy(), max_new_tokens=6)
    eng.add_request(rb)
    for _ in range(20):
        eng.step()
        if rb.done:
            break
    assert rb.out_tokens == solo_b, "reused slot leaked previous state"


def _scfg_spec(spec_len=4, **kw):
    return ServeConfig(max_batch=3, max_len=64, eos_token=-1,
                       spec_len=spec_len, spec_window=8, spec_sinks=2, **kw)


def _run_staggered(cfg, params, scfg, prompts, max_new=8):
    """The staggered-admission pattern from the acceptance test above:
    each new request prefills while earlier ones are mid-decode."""
    eng = Engine(cfg, params, scfg)
    reqs = [Request(rid=i, prompt=p.copy(), max_new_tokens=max_new)
            for i, p in enumerate(prompts)]
    eng.add_request(reqs[0])
    for _ in range(2):
        eng.step()
    eng.add_request(reqs[1])
    for _ in range(2):
        eng.step()
    eng.add_request(reqs[2])
    for _ in range(40):
        eng.step()
        if all(r is None for r in eng.slot_req):
            break
    assert all(r.done for r in reqs)
    return [r.out_tokens for r in reqs], eng


def test_speculative_staggered_token_exact(arch_setup):
    """Tentpole oracle: the self-speculative engine's streams are
    token-exact to the non-speculative engine under staggered ragged
    admission — KV families rewind by slot length, recurrent families
    checkpoint-and-replay, and neither may leak into the output."""
    cfg, params = arch_setup
    prompts = _prompts(cfg)
    base, _ = _run_staggered(cfg, params, _scfg(), prompts)
    spec, eng = _run_staggered(cfg, params, _scfg_spec(), prompts)
    assert spec == base
    rep = eng.report
    assert rep.drafted > 0
    # Window conservation: every drafted token is accepted or rejected.
    assert rep.accepted + rep.rejected == rep.drafted
    # Per-request provenance sums to the report counters.
    assert sum(r.spec_drafted for r in rep.requests) == rep.drafted
    assert sum(r.spec_accepted for r in rep.requests) == rep.accepted
    for rec in rep.requests:
        if rec.spec_drafted:
            assert rec.acceptance_rate == pytest.approx(
                rec.spec_accepted / rec.spec_drafted)
    cov = rep.coverage()
    assert "ACCEPTANCE" in cov["summary"]
    assert cov["counters"]["drafted"] == rep.drafted


def test_speculative_narrow_window_rolls_back_token_exact(arch_setup):
    """A draft window too narrow to predict well exercises the
    rejection/rollback path hard; the output must still be token-exact
    (rejected drafts must leave no trace in cache state)."""
    cfg, params = arch_setup
    prompts = _prompts(cfg, lengths=(13, 4, 9), seed=3)
    base, _ = _run_staggered(cfg, params, _scfg(), prompts, max_new=10)
    spec, eng = _run_staggered(
        cfg, params,
        ServeConfig(max_batch=3, max_len=64, eos_token=-1,
                    spec_len=3, spec_window=2, spec_sinks=0),
        prompts, max_new=10)
    assert spec == base
    assert eng.report.drafted > 0


def test_speculative_requires_greedy_sampler():
    cfg = get_config("qwen3-1.7b").reduced()
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    with pytest.raises(ValueError, match="greedy"):
        Engine(cfg, params, _scfg_spec(),
               sample=lambda logits: logits.argmax(-1))
    with pytest.raises(ValueError, match="spec_len"):
        Engine(cfg, params, ServeConfig(max_batch=2, spec_len=1))


def test_prompt_too_long_rejected():
    cfg = get_config("qwen3-1.7b").reduced()
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    scfg = ServeConfig(max_batch=2, max_len=16, eos_token=-1)
    eng = Engine(cfg, params, scfg)
    too_long = np.ones(16, np.int32)     # needs 17 cache slots
    with pytest.raises(ValueError, match="request 9"):
        eng.add_request(Request(rid=9, prompt=too_long))
    # The engine stays usable and the bad request claimed no slot.
    assert all(r is None for r in eng.slot_req)
    ok = eng.add_request(Request(rid=1, prompt=np.ones(15, np.int32),
                                 max_new_tokens=1))
    assert ok


def test_exact_fit_prompt_accepted():
    cfg = get_config("qwen3-1.7b").reduced()
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    scfg = ServeConfig(max_batch=1, max_len=16, eos_token=-1)
    eng = Engine(cfg, params, scfg)
    req = Request(rid=0, prompt=np.ones(15, np.int32), max_new_tokens=4)
    done = eng.run_until_drained([req])
    assert len(done) == 1 and done[0].done
    assert len(done[0].out_tokens) >= 1
